"""AOT pipeline tests: HLO text validity, weights.bin format, manifest echo."""

import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model
from compile.config import DETECTOR, PRESETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_present():
    return os.path.exists(os.path.join(ART, "MANIFEST.txt"))


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
class TestArtifacts:
    def test_hlo_text_parses_as_hlo_module(self):
        for name in ("prefill.hlo.txt", "decode_step.hlo.txt", "detector.hlo.txt"):
            text = open(os.path.join(ART, name)).read()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_no_custom_calls_in_hlo(self):
        """interpret=True must lower Pallas to plain HLO — a Mosaic
        custom-call would be unexecutable on the CPU PJRT client."""
        for name in ("prefill.hlo.txt", "decode_step.hlo.txt", "detector.hlo.txt"):
            text = open(os.path.join(ART, name)).read()
            assert "custom-call" not in text, name

    def test_manifest_matches_preset(self):
        kv = {}
        params = []
        for line in open(os.path.join(ART, "MANIFEST.txt")):
            key, _, val = line.strip().partition("=")
            if key == "param":
                params.append(val)
            elif key != "artifact":
                kv[key] = val
        cfg = PRESETS[kv["preset"]]
        assert int(kv["layers"]) == cfg.layers
        assert int(kv["d_model"]) == cfg.d_model
        assert int(kv["vocab"]) == cfg.vocab
        assert int(kv["batch"]) == cfg.batch
        assert int(kv["detector_windows"]) == DETECTOR.windows
        assert len(params) == len(cfg.param_specs())

    def test_weights_bin_roundtrip(self):
        path = os.path.join(ART, "weights.bin")
        with open(path, "rb") as f:
            magic = f.read(8)
            assert magic == aot.MAGIC
            (count,) = struct.unpack("<I", f.read(4))
            kv = {}
            for line in open(os.path.join(ART, "MANIFEST.txt")):
                key, _, val = line.strip().partition("=")
                kv.setdefault(key, val)
            cfg = PRESETS[kv["preset"]]
            specs = cfg.param_specs()
            assert count == len(specs)
            for name, shape in specs:
                (nlen,) = struct.unpack("<I", f.read(4))
                got_name = f.read(nlen).decode()
                assert got_name == name
                (ndim,) = struct.unpack("<I", f.read(4))
                dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
                assert tuple(dims) == tuple(shape), name
                (nbytes,) = struct.unpack("<Q", f.read(8))
                assert nbytes == 4 * int(np.prod(shape))
                f.seek(nbytes, 1)
            assert f.read(1) == b""  # no trailing junk

    def test_golden_file_structure(self):
        lines = [
            l.split()
            for l in open(os.path.join(ART, "golden.txt"))
            if l.strip() and not l.startswith("#")
        ]
        kinds = {l[0] for l in lines}
        assert kinds == {"prefill_logit", "greedy_token", "decode_logit"}
        # every recorded value must be finite
        for l in lines:
            float(l[-1])

    def test_golden_reproducible(self, tmp_path):
        """emit_golden is deterministic given the same weights."""
        kv = {}
        for line in open(os.path.join(ART, "MANIFEST.txt")):
            key, _, val = line.strip().partition("=")
            kv.setdefault(key, val)
        cfg = PRESETS[kv["preset"]]
        params = model.init_params(cfg, seed=0)
        p1 = tmp_path / "g1.txt"
        aot.emit_golden(str(p1), cfg, params, steps=1)
        recorded = open(os.path.join(ART, "golden.txt")).read().splitlines()
        fresh = open(p1).read().splitlines()
        # prefill logits section must match the recorded artifact exactly
        rec_prefill = [l for l in recorded if l.startswith("prefill_logit")]
        new_prefill = [l for l in fresh if l.startswith("prefill_logit")]
        assert rec_prefill == new_prefill


class TestGoldenInputs:
    def test_deterministic(self):
        cfg = PRESETS["toy"]
        t1, l1 = aot.golden_inputs(cfg)
        t2, l2 = aot.golden_inputs(cfg)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_lens_in_range(self):
        for cfg in PRESETS.values():
            _, lens = aot.golden_inputs(cfg)
            lens = np.asarray(lens)
            assert (lens >= 1).all() and (lens <= cfg.prefill_len).all()

    def test_tokens_in_vocab(self):
        for cfg in PRESETS.values():
            toks, _ = aot.golden_inputs(cfg)
            toks = np.asarray(toks)
            assert (toks >= 0).all() and (toks < cfg.vocab).all()
