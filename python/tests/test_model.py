"""L2 model correctness: shapes, prefill/decode consistency, causality."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.config import PRESETS, ModelConfig

CFG = PRESETS["toy"]  # smallest preset keeps interpret-mode tracing fast


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def _tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.prefill_len))
    return jnp.asarray(toks.astype(np.int32))


class TestShapes:
    def test_prefill_shapes(self, params):
        toks = _tokens(CFG)
        lens = jnp.asarray([CFG.prefill_len] * CFG.batch, dtype=jnp.int32)
        logits, kv = model.prefill(CFG, toks, lens, *params)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kv.shape == CFG.kv_shape()
        assert not np.any(np.isnan(np.asarray(logits)))

    def test_decode_shapes(self, params):
        toks = _tokens(CFG)
        lens = jnp.asarray([CFG.prefill_len] * CFG.batch, dtype=jnp.int32)
        _, kv = model.prefill(CFG, toks, lens, *params)
        cur = jnp.zeros((CFG.batch,), jnp.int32)
        logits, kv2 = model.decode_step(CFG, cur, lens, kv, *params)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kv2.shape == kv.shape
        assert not np.any(np.isnan(np.asarray(logits)))

    def test_param_specs_cover_init(self):
        specs = CFG.param_specs()
        ps = model.init_params(CFG, seed=1)
        assert len(specs) == len(ps)
        for (name, shape), arr in zip(specs, ps):
            assert tuple(arr.shape) == tuple(shape), name

    def test_n_params_reasonable(self):
        # toy preset should be order 100k-2M params
        n = CFG.n_params()
        assert 10_000 < n < 5_000_000


class TestConsistency:
    def test_decode_matches_prefill(self, params):
        """Prefill over t+1 tokens == prefill over t tokens + one decode step.

        This is THE invariant that validates the KV cache write/read path:
        the next-token logits must agree between the two code paths.
        """
        toks = _tokens(CFG, seed=3)
        t = CFG.prefill_len // 2
        # Path A: prefill with len t+1 -> logits at position t
        lens_a = jnp.asarray([t + 1] * CFG.batch, dtype=jnp.int32)
        logits_a, _ = model.prefill(CFG, toks, lens_a, *params)
        # Path B: prefill with len t, then decode token[t] at position t
        lens_b = jnp.asarray([t] * CFG.batch, dtype=jnp.int32)
        _, kv = model.prefill(CFG, toks, lens_b, *params)
        cur = toks[:, t]
        logits_b, _ = model.decode_step(CFG, cur, lens_b, kv, *params)
        np.testing.assert_allclose(
            np.asarray(logits_a), np.asarray(logits_b), rtol=5e-4, atol=5e-4
        )

    def test_decode_matches_prefill_ragged(self, params):
        """Same invariant with per-sequence lengths (continuous batching)."""
        toks = _tokens(CFG, seed=4)
        base = [CFG.prefill_len // 2, CFG.prefill_len // 4]
        lens_t = jnp.asarray(
            [base[i % 2] for i in range(CFG.batch)], dtype=jnp.int32
        )
        lens_t1 = lens_t + 1
        logits_a, _ = model.prefill(CFG, toks, lens_t1, *params)
        _, kv = model.prefill(CFG, toks, lens_t, *params)
        cur = jnp.take_along_axis(toks, lens_t[:, None], axis=1)[:, 0]
        logits_b, _ = model.decode_step(CFG, cur, lens_t, kv, *params)
        np.testing.assert_allclose(
            np.asarray(logits_a), np.asarray(logits_b), rtol=5e-4, atol=5e-4
        )

    def test_prefill_causal_wrt_padding(self, params):
        """Tokens beyond len must not affect the gathered logits."""
        toks = _tokens(CFG, seed=5)
        t = CFG.prefill_len // 2
        lens = jnp.asarray([t] * CFG.batch, dtype=jnp.int32)
        logits_a, _ = model.prefill(CFG, toks, lens, *params)
        toks2 = toks.at[:, t:].set(0)
        logits_b, _ = model.prefill(CFG, toks2, lens, *params)
        np.testing.assert_allclose(
            np.asarray(logits_a), np.asarray(logits_b), rtol=1e-5, atol=1e-5
        )

    def test_decode_steps_accumulate(self, params):
        """Multi-step greedy decode is deterministic and stays finite."""
        toks = _tokens(CFG, seed=6)
        lens = jnp.asarray([CFG.prefill_len // 2] * CFG.batch, dtype=jnp.int32)
        logits, kv = model.prefill(CFG, toks, lens, *params)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = lens
        seq1 = []
        for _ in range(4):
            logits, kv = model.decode_step(CFG, cur, pos, kv, *params)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            seq1.append(np.asarray(cur).copy())
            pos = pos + 1
            assert not np.any(np.isnan(np.asarray(logits)))
        # Re-run: determinism
        logits, kv = model.prefill(CFG, toks, lens, *params)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = lens
        for t in range(4):
            logits, kv = model.decode_step(CFG, cur, pos, kv, *params)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            np.testing.assert_array_equal(seq1[t], np.asarray(cur))
            pos = pos + 1


class TestConfig:
    def test_presets_valid(self):
        for name, cfg in PRESETS.items():
            assert cfg.d_model == cfg.n_heads * cfg.head_dim, name
            assert cfg.prefill_len <= cfg.max_seq, name

    def test_bad_config_rejected(self):
        with pytest.raises(AssertionError):
            ModelConfig(
                name="bad", layers=1, d_model=100, n_heads=3, head_dim=32,
                ffn=64, vocab=16, max_seq=8, prefill_len=4, batch=1,
            )

    def test_param_spec_order_stable(self):
        """Weight order is a cross-language ABI — pin its head and tail."""
        specs = [n for n, _ in PRESETS["small"].param_specs()]
        assert specs[0] == "embed"
        assert specs[1] == "pos_embed"
        assert specs[2] == "layer0.ln1_scale"
        assert specs[-1] == "ln_f_bias"
        assert specs[-2] == "ln_f_scale"
