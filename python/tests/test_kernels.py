"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Fixed-shape allclose checks plus hypothesis sweeps over shapes/lengths/seeds.
All Pallas calls run interpret=True (CPU), same as the AOT lowering path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref, scorer

RTOL, ATOL = 2e-4, 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def assert_prefill_matches(b, h, s, dh, lens, seed=0, block_q=32, block_k=32):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, b, h, s, dh) for _ in range(3))
    lens = jnp.asarray(lens, dtype=jnp.int32)
    got = np.asarray(
        attention.mha_prefill(q, k, v, lens, block_q=block_q, block_k=block_k)
    )
    want = np.asarray(ref.mha_prefill_ref(q, k, v, lens))
    for bi in range(b):
        n = int(lens[bi])
        np.testing.assert_allclose(
            got[bi, :, :n], want[bi, :, :n], rtol=RTOL, atol=ATOL
        )


class TestPrefill:
    def test_full_length(self):
        assert_prefill_matches(2, 2, 64, 32, [64, 64])

    def test_ragged_lengths(self):
        assert_prefill_matches(4, 2, 64, 32, [64, 33, 1, 17])

    def test_min_length_one(self):
        assert_prefill_matches(2, 1, 32, 16, [1, 1])

    def test_single_head(self):
        assert_prefill_matches(1, 1, 64, 32, [40])

    def test_small_blocks(self):
        assert_prefill_matches(2, 2, 64, 32, [64, 50], block_q=16, block_k=8)

    def test_block_equals_seq(self):
        assert_prefill_matches(1, 2, 32, 32, [32], block_q=32, block_k=32)

    def test_causality(self):
        """Changing tokens after position t must not change outputs <= t."""
        rng = np.random.default_rng(7)
        b, h, s, dh = 1, 2, 32, 16
        q, k, v = (_rand(rng, b, h, s, dh) for _ in range(3))
        lens = jnp.asarray([s], dtype=jnp.int32)
        base = np.asarray(attention.mha_prefill(q, k, v, lens))
        k2 = k.at[:, :, 20:, :].set(0.0)
        v2 = v.at[:, :, 20:, :].set(0.0)
        pert = np.asarray(attention.mha_prefill(q, k2, v2, lens))
        np.testing.assert_allclose(base[:, :, :20], pert[:, :, :20], rtol=RTOL, atol=ATOL)

    def test_softmax_rows_unit_norm_via_constant_v(self):
        """With V = all-ones, every output row must be exactly 1 (softmax sums)."""
        rng = np.random.default_rng(3)
        b, h, s, dh = 2, 2, 32, 16
        q, k = (_rand(rng, b, h, s, dh) for _ in range(2))
        v = jnp.ones((b, h, s, dh), jnp.float32)
        lens = jnp.asarray([s, 11], dtype=jnp.int32)
        out = np.asarray(attention.mha_prefill(q, k, v, lens))
        for bi, n in enumerate([s, 11]):
            np.testing.assert_allclose(
                out[bi, :, :n], np.ones_like(out[bi, :, :n]), rtol=1e-4, atol=1e-5
            )

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 3),
        s_pow=st.integers(3, 6),  # S = 8..64
        dh=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
        data=st.data(),
    )
    def test_hypothesis_sweep(self, b, h, s_pow, dh, seed, data):
        s = 2**s_pow
        lens = data.draw(
            st.lists(st.integers(1, s), min_size=b, max_size=b), label="lens"
        )
        assert_prefill_matches(b, h, s, dh, lens, seed=seed, block_q=8, block_k=8)


class TestDecode:
    def _case(self, b, h, s, dh, positions, seed=0):
        rng = np.random.default_rng(seed)
        q = _rand(rng, b, h, dh)
        k, v = (_rand(rng, b, h, s, dh) for _ in range(2))
        pos = jnp.asarray(positions, dtype=jnp.int32)
        got = np.asarray(attention.mha_decode(q, k, v, pos))
        want = np.asarray(ref.mha_decode_ref(q, k, v, pos))
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_basic(self):
        self._case(2, 2, 64, 32, [5, 63])

    def test_position_zero(self):
        self._case(2, 1, 32, 16, [0, 0])

    def test_last_slot(self):
        self._case(1, 4, 128, 32, [127])

    def test_ragged_positions(self):
        self._case(4, 2, 64, 32, [0, 1, 31, 63])

    def test_mask_excludes_future_slots(self):
        """Garbage beyond pos must not affect the result."""
        rng = np.random.default_rng(11)
        b, h, s, dh = 1, 2, 32, 16
        q = _rand(rng, b, h, dh)
        k, v = (_rand(rng, b, h, s, dh) for _ in range(2))
        pos = jnp.asarray([10], dtype=jnp.int32)
        base = np.asarray(attention.mha_decode(q, k, v, pos))
        k2 = k.at[:, :, 11:, :].set(999.0)
        v2 = v.at[:, :, 11:, :].set(-999.0)
        pert = np.asarray(attention.mha_decode(q, k2, v2, pos))
        np.testing.assert_allclose(base, pert, rtol=RTOL, atol=ATOL)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.integers(1, 4),
        s=st.sampled_from([16, 32, 64, 128]),
        dh=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
        data=st.data(),
    )
    def test_hypothesis_sweep(self, b, h, s, dh, seed, data):
        pos = data.draw(
            st.lists(st.integers(0, s - 1), min_size=b, max_size=b), label="pos"
        )
        self._case(b, h, s, dh, pos, seed=seed)


class TestScorer:
    def _case(self, w, n, seed=0, scale=1.0):
        rng = np.random.default_rng(seed)
        windows = jnp.asarray(scale * rng.normal(size=(w, n)).astype(np.float32))
        baseline = jnp.stack(
            [windows.mean(axis=1) * 0.8, windows.std(axis=1) + 0.1], axis=1
        )
        f, z = scorer.window_features(windows, baseline)
        fr, zr = ref.window_features_ref(windows, baseline)
        np.testing.assert_allclose(np.asarray(f), np.asarray(fr), rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=RTOL, atol=ATOL)

    def test_basic(self):
        self._case(8, 256)

    def test_single_window(self):
        self._case(1, 64)

    def test_aot_shape(self):
        from compile.config import DETECTOR

        self._case(DETECTOR.windows, DETECTOR.samples)

    def test_large_magnitudes(self):
        self._case(4, 128, scale=1e6)

    def test_feature_order_contract(self):
        """Feature index layout is a cross-language contract — pin it."""
        w = jnp.asarray(np.array([[1.0, 2.0, 3.0, 6.0]], dtype=np.float32))
        base = jnp.asarray(np.array([[2.0, 1.0]], dtype=np.float32))
        f, z = scorer.window_features(w, base)
        f = np.asarray(f)[0]
        assert abs(f[0] - 3.0) < 1e-5  # mean
        assert abs(f[2] - 6.0) < 1e-5  # max
        assert abs(f[3] - 1.0) < 1e-5  # min
        assert abs(f[6] - 5.0) < 1e-5  # spread
        assert abs(f[7] - np.asarray(z)[0]) < 1e-6  # z mirrored in features

    @settings(max_examples=10, deadline=None)
    @given(
        w=st.integers(1, 16),
        n=st.sampled_from([16, 64, 256]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, w, n, seed):
        self._case(w, n, seed=seed)
