"""AOT compile path: lower L2/L1 to HLO **text** artifacts for the Rust runtime.

Run once at build time (``make artifacts``). Emits into ``--outdir``:

  prefill.hlo.txt      prefill(tokens, lens, *weights) -> (logits, kv)
  decode_step.hlo.txt  decode_step(tokens, positions, kv, *weights) -> (logits, kv)
  detector.hlo.txt     window_features(windows, baseline) -> (features, z)
  weights.bin          flat f32 weights, param_specs order (self-describing)
  MANIFEST.txt         key=value config echo + param table (validated by Rust)
  golden.txt           numeric goldens for the Rust integration tests

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published ``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import functools
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import DETECTOR, PRESETS, DEFAULT_PRESET
from .kernels import scorer

MAGIC = b"DPLW0001"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path, cfg, params):
    """Self-describing little-endian container; order == param_specs order."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        specs = cfg.param_specs()
        f.write(struct.pack("<I", len(specs)))
        for (name, shape), arr in zip(specs, params):
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", len(shape)))
            for d in shape:
                f.write(struct.pack("<I", d))
            data = np.asarray(arr, dtype="<f4").tobytes()
            f.write(struct.pack("<Q", len(data)))
            f.write(data)


def golden_inputs(cfg):
    """Deterministic prompt block both sides can derive without sharing RNGs."""
    b, s0 = cfg.batch, cfg.prefill_len
    tokens = np.fromfunction(
        lambda i, j: (7 * i + 11 * j + 3) % cfg.vocab, (b, s0), dtype=np.int64
    ).astype(np.int32)
    lens = np.array(
        [max(1, (s0 // 2 + 5 * i + 1) % s0 + 1) for i in range(b)], dtype=np.int32
    )
    return jnp.asarray(tokens), jnp.asarray(lens)


def emit_golden(path, cfg, params, steps):
    """Run prefill + greedy decode in python; record logit samples for Rust."""
    tokens, lens = golden_inputs(cfg)
    logits, kv = model.prefill(cfg, tokens, lens, *params)
    lines = [f"# golden for preset={cfg.name} steps={steps}"]
    logits_np = np.asarray(logits)
    for b in range(cfg.batch):
        for j in range(8):
            lines.append(f"prefill_logit {b} {j} {logits_np[b, j]:.6e}")
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    positions = lens  # next slot after the prompt
    for t in range(steps):
        for b in range(cfg.batch):
            lines.append(f"greedy_token {t} {b} {int(cur[b])}")
        logits, kv = model.decode_step(cfg, cur, positions, kv, *params)
        logits_np = np.asarray(logits)
        for b in range(cfg.batch):
            for j in range(8):
                lines.append(f"decode_logit {t} {b} {j} {logits_np[b, j]:.6e}")
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        positions = positions + 1
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def emit_manifest(path, cfg, det, artifacts):
    lines = [
        "format=1",
        f"preset={cfg.name}",
        f"layers={cfg.layers}",
        f"d_model={cfg.d_model}",
        f"n_heads={cfg.n_heads}",
        f"head_dim={cfg.head_dim}",
        f"ffn={cfg.ffn}",
        f"vocab={cfg.vocab}",
        f"max_seq={cfg.max_seq}",
        f"prefill_len={cfg.prefill_len}",
        f"batch={cfg.batch}",
        f"detector_windows={det.windows}",
        f"detector_samples={det.samples}",
        f"detector_features={det.features}",
    ]
    lines += [f"artifact={a}" for a in artifacts]
    for name, shape in cfg.param_specs():
        lines.append(f"param={name}:{'x'.join(str(d) for d in shape)}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--preset", default=DEFAULT_PRESET, choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--golden-steps", type=int, default=4)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    det = DETECTOR
    os.makedirs(args.outdir, exist_ok=True)
    params = model.init_params(cfg, args.seed)
    wspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.param_specs()]

    def emit(name, fn, example_args):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    i32 = jnp.int32
    emit(
        "prefill.hlo.txt",
        functools.partial(model.prefill, cfg),
        [
            jax.ShapeDtypeStruct((cfg.batch, cfg.prefill_len), i32),
            jax.ShapeDtypeStruct((cfg.batch,), i32),
            *wspecs,
        ],
    )
    emit(
        "decode_step.hlo.txt",
        functools.partial(model.decode_step, cfg),
        [
            jax.ShapeDtypeStruct((cfg.batch,), i32),
            jax.ShapeDtypeStruct((cfg.batch,), i32),
            jax.ShapeDtypeStruct(cfg.kv_shape(), jnp.float32),
            *wspecs,
        ],
    )
    emit(
        "detector.hlo.txt",
        scorer.window_features,
        [
            jax.ShapeDtypeStruct((det.windows, det.samples), jnp.float32),
            jax.ShapeDtypeStruct((det.windows, 2), jnp.float32),
        ],
    )

    write_weights_bin(os.path.join(args.outdir, "weights.bin"), cfg, params)
    emit_golden(os.path.join(args.outdir, "golden.txt"), cfg, params, args.golden_steps)
    emit_manifest(
        os.path.join(args.outdir, "MANIFEST.txt"),
        cfg,
        det,
        ["prefill.hlo.txt", "decode_step.hlo.txt", "detector.hlo.txt"],
    )
    print("AOT artifacts complete", file=sys.stderr)


if __name__ == "__main__":
    main()
