"""Model + detector configuration shared by the L1/L2 compile path.

The Rust side never imports this; everything it needs is echoed into
``artifacts/MANIFEST.txt`` by ``aot.py`` and validated at load time.
"""

from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """A small decoder-only transformer preset.

    ``d_model == n_heads * head_dim`` is required (checked below). ``batch``
    and ``prefill_len`` are baked into the AOT artifacts: PJRT executables are
    fixed-shape, so the serving engine packs/pads to these.
    """

    name: str
    layers: int
    d_model: int
    n_heads: int
    head_dim: int
    ffn: int
    vocab: int
    max_seq: int
    prefill_len: int
    batch: int

    def __post_init__(self) -> None:
        assert self.d_model == self.n_heads * self.head_dim, (
            f"{self.name}: d_model {self.d_model} != n_heads*head_dim "
            f"{self.n_heads}*{self.head_dim}"
        )
        assert self.prefill_len <= self.max_seq

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list — THE parameter order contract.

        ``aot.py`` writes weights.bin in exactly this order and the lowered
        HLO entry computations take weights as trailing positional parameters
        in exactly this order. The LM head is tied to ``embed``.
        """
        d, h = self.d_model, self.n_heads * self.head_dim
        specs: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed", (self.vocab, d)),
            ("pos_embed", (self.max_seq, d)),
        ]
        for l in range(self.layers):
            p = f"layer{l}."
            specs += [
                (p + "ln1_scale", (d,)),
                (p + "ln1_bias", (d,)),
                (p + "wq", (d, h)),
                (p + "wk", (d, h)),
                (p + "wv", (d, h)),
                (p + "wo", (h, d)),
                (p + "ln2_scale", (d,)),
                (p + "ln2_bias", (d,)),
                (p + "w_up", (d, self.ffn)),
                (p + "b_up", (self.ffn,)),
                (p + "w_down", (self.ffn, d)),
                (p + "b_down", (d,)),
            ]
        specs += [("ln_f_scale", (d,)), ("ln_f_bias", (d,))]
        return specs

    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_specs():
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    def kv_shape(self) -> Tuple[int, ...]:
        """KV cache layout: [layers, 2 (k/v), batch, heads, max_seq, head_dim]."""
        return (
            self.layers,
            2,
            self.batch,
            self.n_heads,
            self.max_seq,
            self.head_dim,
        )


@dataclass(frozen=True)
class DetectorConfig:
    """Telemetry window scorer shapes (DPU-offloaded anomaly scoring)."""

    windows: int = 64   # windows scored per call (W)
    samples: int = 256  # telemetry samples per window (N)
    features: int = 8   # features per window (F) — see kernels/scorer.py


PRESETS = {
    "toy": ModelConfig(
        name="toy", layers=2, d_model=128, n_heads=4, head_dim=32,
        ffn=512, vocab=512, max_seq=64, prefill_len=32, batch=2,
    ),
    "small": ModelConfig(
        name="small", layers=4, d_model=256, n_heads=8, head_dim=32,
        ffn=1024, vocab=2048, max_seq=128, prefill_len=64, batch=4,
    ),
    "base": ModelConfig(
        name="base", layers=8, d_model=512, n_heads=8, head_dim=64,
        ffn=2048, vocab=4096, max_seq=256, prefill_len=128, batch=8,
    ),
}

DEFAULT_PRESET = "small"
DETECTOR = DetectorConfig()
