"""L2: decoder-only transformer (prefill + single-step decode) in JAX.

Calls the L1 Pallas kernels for the attention hot-spot. Lowered ONCE by
``aot.py`` to HLO text; the Rust runtime executes the compiled artifacts on
the request path — Python never serves.

Parameter passing contract: both entry points take the flat, ordered weight
list produced by ``ModelConfig.param_specs()`` as trailing positional
arguments (see ``config.py``). ``weights.bin`` is written in the same order.

KV cache layout: ``[L, 2, B, H, S_max, Dh]`` f32 (2 = key/value). Prefill
fills slots ``[0, prefill_len)``; decode writes slot ``positions[b]`` then
attends over ``slot <= positions[b]``.
"""

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention

LN_EPS = 1e-5


def _layernorm(x, scale, bias):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * scale + bias


def _unflatten(cfg: ModelConfig, flat: Sequence[jax.Array]) -> dict:
    specs = cfg.param_specs()
    assert len(flat) == len(specs), (len(flat), len(specs))
    params = {}
    for (name, shape), arr in zip(specs, flat):
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        params[name] = arr
    return params


def _split_heads(x, n_heads, head_dim):
    # [B, S, H*Dh] -> [B, H, S, Dh]
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # [B, H, S, Dh] -> [B, S, H*Dh]
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _mlp(x, p, prefix):
    hcur = jnp.dot(x, p[prefix + "w_up"]) + p[prefix + "b_up"]
    hcur = jax.nn.gelu(hcur)
    return jnp.dot(hcur, p[prefix + "w_down"]) + p[prefix + "b_down"]


def prefill(cfg: ModelConfig, tokens, lens, *flat_weights):
    """Prefill a padded prompt block.

    tokens: [B, S0] i32 (padded with any id beyond lens)
    lens:   [B] i32, 1 <= lens <= S0
    returns (logits [B, V] f32 — next-token logits at position len-1 per seq,
             kv [L, 2, B, H, S_max, Dh] f32 — slots [0, S0) filled)
    """
    p = _unflatten(cfg, flat_weights)
    b, s0 = tokens.shape
    assert s0 == cfg.prefill_len and b == cfg.batch

    x = p["embed"][tokens] + p["pos_embed"][None, :s0, :]  # [B, S0, D]

    kv_layers = []
    for l in range(cfg.layers):
        pre = f"layer{l}."
        hnorm = _layernorm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        q = _split_heads(jnp.dot(hnorm, p[pre + "wq"]), cfg.n_heads, cfg.head_dim)
        k = _split_heads(jnp.dot(hnorm, p[pre + "wk"]), cfg.n_heads, cfg.head_dim)
        v = _split_heads(jnp.dot(hnorm, p[pre + "wv"]), cfg.n_heads, cfg.head_dim)
        attn = attention.mha_prefill(q, k, v, lens)  # [B, H, S0, Dh]
        x = x + jnp.dot(_merge_heads(attn), p[pre + "wo"])
        hnorm2 = _layernorm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"])
        x = x + _mlp(hnorm2, p, pre)
        pad = cfg.max_seq - s0
        k_pad = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_layers.append(jnp.stack([k_pad, v_pad], axis=0))  # [2,B,H,Smax,Dh]

    kv = jnp.stack(kv_layers, axis=0)  # [L, 2, B, H, Smax, Dh]

    x = _layernorm(x, p["ln_f_scale"], p["ln_f_bias"])
    # Gather the hidden state at the last real token of each sequence.
    last = jnp.clip(lens - 1, 0, s0 - 1).astype(jnp.int32)  # [B]
    h_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0, :]
    logits = jnp.dot(h_last, p["embed"].T)  # tied LM head, [B, V]
    return logits, kv


def decode_step(cfg: ModelConfig, tokens, positions, kv, *flat_weights):
    """One autoregressive step for a ragged batch.

    tokens:    [B] i32 — current input token per sequence
    positions: [B] i32 — its slot (0-based); KV slots < pos already filled
    kv:        [L, 2, B, H, S_max, Dh] f32
    returns (logits [B, V], kv')
    """
    p = _unflatten(cfg, flat_weights)
    (b,) = tokens.shape
    assert b == cfg.batch

    x = p["embed"][tokens] + p["pos_embed"][positions]  # [B, D]
    batch_ix = jnp.arange(cfg.batch)

    for l in range(cfg.layers):
        pre = f"layer{l}."
        hnorm = _layernorm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        q = jnp.dot(hnorm, p[pre + "wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = jnp.dot(hnorm, p[pre + "wk"]).reshape(b, cfg.n_heads, cfg.head_dim)
        v = jnp.dot(hnorm, p[pre + "wv"]).reshape(b, cfg.n_heads, cfg.head_dim)
        # Scatter this token's K/V into its per-sequence slot.
        kv = kv.at[l, 0, batch_ix, :, positions, :].set(k)
        kv = kv.at[l, 1, batch_ix, :, positions, :].set(v)
        attn = attention.mha_decode(q, kv[l, 0], kv[l, 1], positions)  # [B,H,Dh]
        x = x + jnp.dot(attn.reshape(b, -1), p[pre + "wo"])
        hnorm2 = _layernorm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"])
        x = x + _mlp(hnorm2, p, pre)

    x = _layernorm(x, p["ln_f_scale"], p["ln_f_bias"])
    logits = jnp.dot(x, p["embed"].T)
    return logits, kv


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic random init in param_specs order (shared with Rust via
    weights.bin — Rust never re-derives these, it loads the file)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_bias", "b_up", "b_down")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return out
