"""L1 Pallas attention kernels (prefill + decode).

Hardware adaptation (paper targets CUDA GPUs; we target the TPU-shaped Pallas
model, run under interpret=True on CPU — see DESIGN.md §Hardware-Adaptation):

* The CUDA version of this hot-spot would tile Q into threadblocks and stream
  K/V through shared memory. Here the same schedule is expressed as the
  Pallas ``grid`` (batch, head, q-block) plus an in-kernel flash-style loop
  over K-chunks, so each grid step touches a bounded VMEM working set:
  ``BQ*Dh + KB*Dh + BQ*KB`` floats instead of ``S*S``.
* Contractions are plain ``jnp.dot``s shaped for the MXU (``[BQ,Dh]x[Dh,KB]``)
  rather than WMMA fragments.

``interpret=True`` is mandatory in this environment: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT client cannot execute. Interpret mode lowers
to plain HLO, which is exactly what the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_prefill_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, bq, kb, s):
    """One (batch, head, q-block) grid step of flash-style causal attention."""
    iq = pl.program_id(2)
    q = q_ref[0, 0]  # [BQ, Dh] — this q-tile's VMEM block
    k = k_ref[0, 0]  # [S, Dh]
    v = v_ref[0, 0]  # [S, Dh]
    seq_len = lens_ref[0]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)  # [BQ,1]
    n_chunks = s // kb

    def body(c, carry):
        m, l, acc = carry
        k_chunk = jax.lax.dynamic_slice(k, (c * kb, 0), (kb, dh))  # [KB, Dh]
        v_chunk = jax.lax.dynamic_slice(v, (c * kb, 0), (kb, dh))
        scores = jnp.dot(q, k_chunk.T) * scale  # [BQ, KB]
        k_pos = c * kb + jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1)
        mask = (k_pos <= q_pos) & (k_pos < seq_len)  # causal & within-prompt
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v_chunk)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)


def mha_prefill(q, k, v, lens, *, block_q=32, block_k=32):
    """Flash-style masked causal attention over a padded prompt block.

    q, k, v: [B, H, S, Dh] f32;  lens: [B] i32. Returns [B, H, S, Dh].
    Matches ``ref.mha_prefill_ref`` on rows < len (rows >= len are garbage by
    contract). S must be divisible by the block sizes (engine pads prompts).
    """
    b, h, s, dh = q.shape
    bq = min(block_q, s)
    kb = min(block_k, s)
    assert s % bq == 0 and s % kb == 0, (s, bq, kb)
    grid = (b, h, s // bq)
    kernel = functools.partial(_flash_prefill_kernel, bq=bq, kb=kb, s=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, qi: (bi,)),
            pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        interpret=True,
    )(lens, q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) grid step: single-query attention over the KV cache."""
    q = q_ref[0, 0]  # [Dh]
    k = k_ref[0, 0]  # [S, Dh]
    v = v_ref[0, 0]  # [S, Dh]
    pos = pos_ref[0]
    s, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.dot(k, q) * scale  # [S]
    slot = jax.lax.broadcasted_iota(jnp.int32, (s,), 0)
    scores = jnp.where(slot <= pos, scores, NEG_INF)
    m = scores.max()
    p = jnp.exp(scores - m)
    o_ref[0, 0] = jnp.dot(p, v) / p.sum()


def mha_decode(q, k_cache, v_cache, positions):
    """Single-token decode attention against the KV cache.

    q: [B, H, Dh];  k_cache/v_cache: [B, H, S, Dh];  positions: [B] i32
    (slot of the current token, already written into the cache).
    Returns [B, H, Dh]. Matches ``ref.mha_decode_ref``.
    """
    b, h, s, dh = k_cache.shape
    grid = (b, h)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
            pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,
    )(positions, q, k_cache, v_cache)
