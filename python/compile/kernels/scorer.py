"""L1 Pallas kernel: DPU telemetry window featurizer + anomaly z-score.

The paper positions the BlueField-3 as an observability node that scores
telemetry inline without burdening the host. This kernel is that scoring
hot-spot: it turns a batch of raw telemetry windows (inter-arrival gaps, DMA
sizes, queue depths, ...) into the feature vector the Rust-side detectors
consume, plus a z-score against the healthy baseline.

Feature order is a contract with ``rust/src/dpu/scorer.rs`` (and mirrored by
``ref.window_features_ref``):
  0 mean, 1 std, 2 max, 3 min, 4 cov, 5 burstiness, 6 spread, 7 z.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6
N_FEATURES = 8


def _scorer_kernel(w_ref, b_ref, f_ref, z_ref):
    x = w_ref[0]  # [N]
    base_mean = b_ref[0, 0]
    base_std = b_ref[0, 1]
    n = x.shape[0]
    mean = x.sum() / n
    var = ((x - mean) ** 2).sum() / n
    std = jnp.sqrt(var)
    mx = x.max()
    mn = x.min()
    cov = std / (jnp.abs(mean) + EPS)
    burst = mx / (jnp.abs(mean) + EPS)
    spread = mx - mn
    z = (mean - base_mean) / (base_std + EPS)
    f_ref[0] = jnp.stack([mean, std, mx, mn, cov, burst, spread, z])
    z_ref[0] = z


def window_features(windows, baseline):
    """windows [W, N] f32, baseline [W, 2] f32 -> (features [W, 8], z [W])."""
    w, n = windows.shape
    return pl.pallas_call(
        _scorer_kernel,
        grid=(w,),
        in_specs=[
            pl.BlockSpec((1, n), lambda wi: (wi, 0)),
            pl.BlockSpec((1, 2), lambda wi: (wi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N_FEATURES), lambda wi: (wi, 0)),
            pl.BlockSpec((1,), lambda wi: (wi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, N_FEATURES), jnp.float32),
            jax.ShapeDtypeStruct((w,), jnp.float32),
        ],
        interpret=True,
    )(windows, baseline)
