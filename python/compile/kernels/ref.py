"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest asserts the Pallas kernels
(interpret=True) match these within tolerance, and hypothesis sweeps shapes
against them. They are deliberately written in the most obvious way possible.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def mha_prefill_ref(q, k, v, lens):
    """Masked causal multi-head attention over a padded prompt block.

    q, k, v: [B, H, S, Dh] float32
    lens:    [B] int32 -- true prompt length per sequence (<= S)
    returns: [B, H, S, Dh]

    Mask: query i attends key j iff j <= i and j < len_b. Rows with
    i >= len_b are garbage by contract (callers gather only row len_b-1).
    """
    b, h, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    causal = kj <= qi  # [S, S]
    valid = jnp.arange(s)[None, :] < lens[:, None]  # [B, S] keys within prompt
    mask = causal[None, :, :] & valid[:, None, :]  # [B, S, S]
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def mha_decode_ref(q, k_cache, v_cache, positions):
    """Single-token decode attention against a KV cache.

    q:         [B, H, Dh]      -- current token's query
    k_cache:   [B, H, S, Dh]   -- keys, valid at slots 0..=pos_b
    v_cache:   [B, H, S, Dh]
    positions: [B] int32       -- slot of the current token (already written)
    returns:   [B, H, Dh]
    """
    b, h, s, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhd,bhkd->bhk", q, k_cache) * scale
    kj = jnp.arange(s)[None, :]  # [1, S]
    mask = kj <= positions[:, None]  # [B, S]
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", p, v_cache)


def window_features_ref(windows, baseline):
    """Telemetry window featurizer + anomaly z-score.

    windows:  [W, N] float32 -- per-window raw samples (e.g. inter-arrival
              gaps in ns, DMA sizes, queue depths)
    baseline: [W, 2] float32 -- (mean, std) of the healthy baseline for the
              window's stream
    returns:  (features [W, 8], z [W])

    Features per window (order is a contract with the Rust side):
      0 mean, 1 std, 2 max, 3 min, 4 cov (std/mean), 5 burstiness (max/mean),
      6 spread (max-min), 7 z-score of mean vs baseline.
    """
    eps = 1e-6
    mean = windows.mean(axis=1)
    var = windows.var(axis=1)
    std = jnp.sqrt(var)
    mx = windows.max(axis=1)
    mn = windows.min(axis=1)
    cov = std / (jnp.abs(mean) + eps)
    burst = mx / (jnp.abs(mean) + eps)
    spread = mx - mn
    z = (mean - baseline[:, 0]) / (baseline[:, 1] + eps)
    feats = jnp.stack([mean, std, mx, mn, cov, burst, spread, z], axis=1)
    return feats, z


def layernorm_ref(x, scale, bias, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias
