#!/usr/bin/env python3
"""Unit tests for the ci/perf_trajectory.py comparator and gate mode.

Run directly (`python3 ci/test_perf_trajectory.py`) or via unittest
discovery; CI's bench-smoke job runs them before the trajectory step.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import perf_trajectory as pt


def doc(ingest=100_000.0, p50=50.0, mx=200.0, matrix_ms=9_000.0):
    return {
        "ingest": {"events_per_sec": ingest},
        "snapshot": {"p50_us": p50, "max_us": mx},
        "matrix": {"elapsed_ms": matrix_ms, "events_per_sec": ingest / 2},
        "fleet": {"elapsed_ms": matrix_ms / 2, "events_per_sec": ingest / 3},
    }


class CompareTests(unittest.TestCase):
    def row(self, rows, label):
        matches = [r for r in rows if r[0] == label]
        self.assertEqual(len(matches), 1, label)
        return matches[0]

    def test_identical_runs_have_no_regressions(self):
        rows = pt.compare(doc(), doc())
        self.assertEqual(len(rows), len(pt.METRICS))
        self.assertTrue(all(not regressed for *_, regressed in rows))
        _, b, f, delta, _ = self.row(rows, "ingest events/s")
        self.assertEqual(b, f)
        self.assertAlmostEqual(delta, 0.0)

    def test_throughput_drop_beyond_tolerance_regresses(self):
        # 20% fewer events/s: regressed at 10% tolerance, fine at 25%.
        rows = pt.compare(doc(), doc(ingest=80_000.0), tolerance_pct=10.0)
        self.assertTrue(self.row(rows, "ingest events/s")[4])
        rows = pt.compare(doc(), doc(ingest=80_000.0), tolerance_pct=25.0)
        self.assertFalse(self.row(rows, "ingest events/s")[4])

    def test_latency_rise_is_a_regression_and_drop_is_not(self):
        rows = pt.compare(doc(), doc(p50=60.0))  # +20% p50
        self.assertTrue(self.row(rows, "snapshot p50 us")[4])
        rows = pt.compare(doc(), doc(p50=30.0))  # improvement
        self.assertFalse(self.row(rows, "snapshot p50 us")[4])

    def test_exactly_at_tolerance_does_not_regress(self):
        # A drop of exactly 10% sits on the boundary (strict inequality).
        rows = pt.compare(doc(ingest=100_000.0), doc(ingest=90_000.0), 10.0)
        self.assertFalse(self.row(rows, "ingest events/s")[4])

    def test_missing_or_zero_metrics_are_skipped(self):
        base = doc()
        del base["fleet"]
        rows = pt.compare(base, doc())
        label, b, f, delta, regressed = self.row(rows, "fleet wall ms")
        self.assertIsNone(delta)
        self.assertFalse(regressed)
        # Zero baselines can't anchor a ratio.
        rows = pt.compare(doc(ingest=0.0), doc())
        self.assertIsNone(self.row(rows, "ingest events/s")[3])


class RecordedTests(unittest.TestCase):
    def test_placeholder_is_not_a_baseline(self):
        placeholder = doc()
        placeholder["provenance"] = "unrecorded-placeholder"
        self.assertFalse(pt.is_recorded(placeholder))

    def test_all_zero_baseline_is_not_recorded(self):
        self.assertFalse(pt.is_recorded(doc(ingest=0.0, p50=0.0, mx=0.0, matrix_ms=0.0)))

    def test_real_baseline_is_recorded(self):
        self.assertTrue(pt.is_recorded(doc()))


class MainGateTests(unittest.TestCase):
    def write(self, tmp, name, payload):
        path = os.path.join(tmp, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def test_warn_only_never_fails(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(pt.main([base, fresh]), 0)

    def test_gate_fails_on_regression(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 1)

    def test_gate_passes_within_tolerance(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=95_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 0)

    def test_gate_tolerance_flag_widens_the_band(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=70_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 1)
            self.assertEqual(
                pt.main([base, fresh, "--gate", "--tolerance-pct", "40"]), 0
            )

    def test_malformed_tolerance_is_a_usage_error(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc())
            self.assertEqual(pt.main([base, fresh, "--tolerance-pct", "lots"]), 2)

    def test_unknown_flags_are_usage_errors_not_silent_passes(self):
        # A typo'd gate flag must fail loudly, never skip the comparison.
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(
                pt.main([base, fresh, "--gate", "--tolerence-pct", "5"]), 2
            )
            self.assertEqual(pt.main([base, fresh, "extra.json"]), 2)
            # Bare invocation still prints usage and exits 0 (help path).
            self.assertEqual(pt.main([]), 0)

    def test_placeholder_baseline_prints_instructions_and_passes_gate(self):
        placeholder = doc()
        placeholder["provenance"] = "unrecorded-placeholder"
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", placeholder)
            fresh = self.write(tmp, "fresh.json", doc())
            # Even under --gate: no baseline means nothing to gate on.
            self.assertEqual(pt.main([base, fresh, "--gate"]), 0)

    def test_unreadable_fresh_json_skips_warn_only_but_fails_the_gate(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            missing = os.path.join(tmp, "nope.json")
            self.assertEqual(pt.main([base, missing]), 0)
            # Gate mode must not pass without a measurement to compare.
            self.assertEqual(pt.main([base, missing, "--gate"]), 1)


if __name__ == "__main__":
    unittest.main()
