#!/usr/bin/env python3
"""Unit tests for the ci/perf_trajectory.py comparator and gate mode.

Run directly (`python3 ci/test_perf_trajectory.py`) or via unittest
discovery; CI's bench-smoke job runs them before the trajectory step.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import perf_trajectory as pt


def doc(ingest=100_000.0, p50=50.0, mx=200.0, matrix_ms=9_000.0):
    return {
        "ingest": {"events_per_sec": ingest},
        "snapshot": {"p50_us": p50, "max_us": mx},
        "matrix": {"elapsed_ms": matrix_ms, "events_per_sec": ingest / 2},
        "fleet": {"elapsed_ms": matrix_ms / 2, "events_per_sec": ingest / 3},
    }


def stress_doc(points, **kw):
    """A dpulens.perf.v2 document; points = [(replicas, events_per_sec,
    wall_ms_per_sim_s), ...]."""
    d = doc(**kw)
    d["schema"] = "dpulens.perf.v2"
    d["fleet_stress"] = {
        "threads": 8,
        "points": [
            {
                "replicas": r,
                "sim_ms": 400.0,
                "wall_ms": wall_per_sim_s * 0.4,
                "events": 1_000 * r,
                "events_per_sec": eps,
                "wall_ms_per_sim_s": wall_per_sim_s,
                "completed": 10 * r,
                "alloc_bytes": 1_000_000,
                "peak_alloc_bytes": 2_000_000,
            }
            for r, eps, wall_per_sim_s in points
        ],
    }
    return d


class CompareTests(unittest.TestCase):
    def row(self, rows, label):
        matches = [r for r in rows if r[0] == label]
        self.assertEqual(len(matches), 1, label)
        return matches[0]

    def test_identical_runs_have_no_regressions(self):
        rows = pt.compare(doc(), doc())
        self.assertEqual(len(rows), len(pt.METRICS))
        self.assertTrue(all(not regressed for *_, regressed in rows))
        _, b, f, delta, _ = self.row(rows, "ingest events/s")
        self.assertEqual(b, f)
        self.assertAlmostEqual(delta, 0.0)

    def test_throughput_drop_beyond_tolerance_regresses(self):
        # 20% fewer events/s: regressed at 10% tolerance, fine at 25%.
        rows = pt.compare(doc(), doc(ingest=80_000.0), tolerance_pct=10.0)
        self.assertTrue(self.row(rows, "ingest events/s")[4])
        rows = pt.compare(doc(), doc(ingest=80_000.0), tolerance_pct=25.0)
        self.assertFalse(self.row(rows, "ingest events/s")[4])

    def test_latency_rise_is_a_regression_and_drop_is_not(self):
        rows = pt.compare(doc(), doc(p50=60.0))  # +20% p50
        self.assertTrue(self.row(rows, "snapshot p50 us")[4])
        rows = pt.compare(doc(), doc(p50=30.0))  # improvement
        self.assertFalse(self.row(rows, "snapshot p50 us")[4])

    def test_exactly_at_tolerance_does_not_regress(self):
        # A drop of exactly 10% sits on the boundary (strict inequality).
        rows = pt.compare(doc(ingest=100_000.0), doc(ingest=90_000.0), 10.0)
        self.assertFalse(self.row(rows, "ingest events/s")[4])

    def test_missing_or_zero_metrics_are_skipped(self):
        base = doc()
        del base["fleet"]
        rows = pt.compare(base, doc())
        label, b, f, delta, regressed = self.row(rows, "fleet wall ms")
        self.assertIsNone(delta)
        self.assertFalse(regressed)
        # Zero baselines can't anchor a ratio.
        rows = pt.compare(doc(ingest=0.0), doc())
        self.assertIsNone(self.row(rows, "ingest events/s")[3])


class StressTests(unittest.TestCase):
    def row(self, rows, label):
        matches = [r for r in rows if r[0] == label]
        self.assertEqual(len(matches), 1, label)
        return matches[0]

    def test_stress_rows_append_after_the_base_metrics(self):
        base = stress_doc([(100, 50_000.0, 900.0), (1000, 40_000.0, 8_000.0)])
        rows = pt.compare(base, base)
        # Base rows first and complete, then 2 rows per shared point.
        self.assertEqual(len(rows), len(pt.METRICS) + 4)
        self.assertEqual(
            [r[0] for r in rows[: len(pt.METRICS)]],
            [label for _, label, _ in pt.METRICS],
        )
        self.assertEqual(
            [r[0] for r in rows[len(pt.METRICS) :]],
            [
                "stress 100 events/s",
                "stress 100 wall ms/sim s",
                "stress 1000 events/s",
                "stress 1000 wall ms/sim s",
            ],
        )
        self.assertTrue(all(not regressed for *_, regressed in rows))

    def test_stress_throughput_drop_and_wall_clock_rise_regress(self):
        base = stress_doc([(1000, 50_000.0, 8_000.0)])
        slower = stress_doc([(1000, 35_000.0, 8_000.0)])  # -30% events/s
        rows = pt.compare(base, slower, tolerance_pct=25.0)
        self.assertTrue(self.row(rows, "stress 1000 events/s")[4])
        self.assertFalse(self.row(rows, "stress 1000 wall ms/sim s")[4])
        heavier = stress_doc([(1000, 50_000.0, 12_000.0)])  # +50% wall/sim-s
        rows = pt.compare(base, heavier, tolerance_pct=25.0)
        self.assertTrue(self.row(rows, "stress 1000 wall ms/sim s")[4])
        faster = stress_doc([(1000, 60_000.0, 6_000.0)])  # improvements
        rows = pt.compare(base, faster, tolerance_pct=25.0)
        self.assertTrue(all(not regressed for *_, regressed in rows))

    def test_points_are_matched_by_replica_count(self):
        # A --quick fresh run (100-replica point only) against a full
        # baseline compares just the shared point; 250/500/1000 are skipped.
        full = stress_doc(
            [(100, 50_000.0, 900.0), (250, 48_000.0, 2_000.0), (1000, 40_000.0, 8_000.0)]
        )
        quick = stress_doc([(100, 50_000.0, 900.0)])
        rows = pt.compare(full, quick)
        stress_rows = rows[len(pt.METRICS) :]
        self.assertEqual(
            [r[0] for r in stress_rows],
            ["stress 100 events/s", "stress 100 wall ms/sim s"],
        )
        self.assertTrue(all(not regressed for *_, regressed in stress_rows))
        # And the reverse direction (fresh grew a point) is also just skipped.
        self.assertEqual(len(pt.compare(quick, full)), len(pt.METRICS) + 2)

    def test_v1_documents_grow_no_stress_rows(self):
        rows = pt.compare(doc(), stress_doc([(100, 50_000.0, 900.0)]))
        self.assertEqual(len(rows), len(pt.METRICS))

    def test_stress_only_baseline_counts_as_recorded(self):
        zeros = stress_doc(
            [(100, 50_000.0, 900.0)], ingest=0.0, p50=0.0, mx=0.0, matrix_ms=0.0
        )
        self.assertTrue(pt.is_recorded(zeros))


def reuse_doc(ratio=3.0, saved=2_000_000_000.0, **kw):
    """A dpulens.perf.v3 document with a snapshot-and-branch reuse section."""
    d = doc(**kw)
    d["schema"] = "dpulens.perf.v3"
    d["reuse"] = {
        "cells_total": 87,
        "prefixes_simulated": 29,
        "forked_branches": 58,
        "sim_ns_saved": saved,
        "reuse_ratio": ratio,
    }
    return d


class ReuseTests(unittest.TestCase):
    def row(self, rows, label):
        matches = [r for r in rows if r[0] == label]
        self.assertEqual(len(matches), 1, label)
        return matches[0]

    def test_reuse_rows_compare_in_the_base_metric_set(self):
        rows = pt.compare(reuse_doc(), reuse_doc())
        self.assertEqual(len(rows), len(pt.METRICS))
        _, b, f, delta, regressed = self.row(rows, "prefix reuse ratio")
        self.assertEqual(b, f)
        self.assertAlmostEqual(delta, 0.0)
        self.assertFalse(regressed)

    def test_shrinking_reuse_ratio_is_a_regression(self):
        # Cells stopped sharing prefixes: -50% ratio regresses, growth never.
        rows = pt.compare(reuse_doc(ratio=3.0), reuse_doc(ratio=1.5))
        self.assertTrue(self.row(rows, "prefix reuse ratio")[4])
        rows = pt.compare(reuse_doc(ratio=3.0), reuse_doc(ratio=6.0))
        self.assertFalse(self.row(rows, "prefix reuse ratio")[4])

    def test_pre_v3_documents_show_no_comparable_sample(self):
        # A v1/v2 baseline has no reuse section: delta is None, never a
        # regression, and the row set stays the full METRICS list.
        rows = pt.compare(doc(), reuse_doc())
        self.assertEqual(len(rows), len(pt.METRICS))
        label, b, f, delta, regressed = self.row(rows, "prefix reuse ratio")
        self.assertIsNone(delta)
        self.assertFalse(regressed)

    def test_reuse_only_baseline_counts_as_recorded(self):
        zeros = reuse_doc(ingest=0.0, p50=0.0, mx=0.0, matrix_ms=0.0)
        self.assertTrue(pt.is_recorded(zeros))


def iteration_doc(points, **kw):
    """A dpulens.perf.v4 document; points = [(batch, iters_per_sec,
    alloc_bytes_per_iter), ...]."""
    d = doc(**kw)
    d["schema"] = "dpulens.perf.v4"
    d["iteration"] = [
        {
            "batch": batch,
            "iters": 5_000,
            "wall_ms": 40.0,
            "iters_per_sec": ips,
            "alloc_bytes": int(bpi * 5_000),
            "alloc_bytes_per_iter": bpi,
        }
        for batch, ips, bpi in points
    ]
    return d


class IterationTests(unittest.TestCase):
    def row(self, rows, label):
        matches = [r for r in rows if r[0] == label]
        self.assertEqual(len(matches), 1, label)
        return matches[0]

    def test_iteration_rows_append_after_the_base_metrics(self):
        base = iteration_doc([(8, 90_000.0, 64.0), (256, 20_000.0, 64.0)])
        rows = pt.compare(base, base)
        self.assertEqual(len(rows), len(pt.METRICS) + 4)
        self.assertEqual(
            [r[0] for r in rows[len(pt.METRICS) :]],
            [
                "iter b8 iters/s",
                "iter b8 alloc B/iter",
                "iter b256 iters/s",
                "iter b256 alloc B/iter",
            ],
        )
        self.assertTrue(all(not regressed for *_, regressed in rows))

    def test_iteration_throughput_drop_and_alloc_rise_regress(self):
        base = iteration_doc([(64, 50_000.0, 64.0)])
        slower = iteration_doc([(64, 35_000.0, 64.0)])  # -30% iters/s
        rows = pt.compare(base, slower, tolerance_pct=25.0)
        self.assertTrue(self.row(rows, "iter b64 iters/s")[4])
        self.assertFalse(self.row(rows, "iter b64 alloc B/iter")[4])
        heavier = iteration_doc([(64, 50_000.0, 96.0)])  # +50% B/iter
        rows = pt.compare(base, heavier, tolerance_pct=25.0)
        self.assertTrue(self.row(rows, "iter b64 alloc B/iter")[4])
        leaner = iteration_doc([(64, 60_000.0, 32.0)])  # improvements
        rows = pt.compare(base, leaner, tolerance_pct=25.0)
        self.assertTrue(all(not regressed for *_, regressed in rows))

    def test_points_are_matched_by_batch_size(self):
        full = iteration_doc([(8, 90_000.0, 0.0), (64, 50_000.0, 0.0)])
        partial = iteration_doc([(64, 50_000.0, 0.0)])
        rows = pt.compare(full, partial)
        iter_rows = rows[len(pt.METRICS) :]
        self.assertEqual(
            [r[0] for r in iter_rows],
            ["iter b64 iters/s", "iter b64 alloc B/iter"],
        )
        self.assertTrue(all(not regressed for *_, regressed in iter_rows))

    def test_zero_alloc_baseline_rows_are_incomparable_not_regressions(self):
        # The expected steady state is 0 B/iter; a zero baseline can't
        # anchor a ratio (the exact property gates in tests/iter_hot_path.rs).
        base = iteration_doc([(64, 50_000.0, 0.0)])
        fresh = iteration_doc([(64, 50_000.0, 512.0)])
        rows = pt.compare(base, fresh)
        label, b, f, delta, regressed = self.row(rows, "iter b64 alloc B/iter")
        self.assertIsNone(delta)
        self.assertFalse(regressed)

    def test_pre_v4_baselines_grow_no_iteration_rows(self):
        rows = pt.compare(doc(), iteration_doc([(8, 90_000.0, 0.0)]))
        self.assertEqual(len(rows), len(pt.METRICS))

    def test_iteration_only_baseline_counts_as_recorded(self):
        zeros = iteration_doc(
            [(8, 90_000.0, 0.0)], ingest=0.0, p50=0.0, mx=0.0, matrix_ms=0.0
        )
        self.assertTrue(pt.is_recorded(zeros))

    def test_iteration_and_stress_rows_compose_in_order(self):
        d = iteration_doc([(8, 90_000.0, 0.0)])
        d["fleet_stress"] = stress_doc([(100, 50_000.0, 900.0)])["fleet_stress"]
        rows = pt.compare(d, d)
        self.assertEqual(
            [r[0] for r in rows[len(pt.METRICS) :]],
            [
                "iter b8 iters/s",
                "iter b8 alloc B/iter",
                "stress 100 events/s",
                "stress 100 wall ms/sim s",
            ],
        )


class RecordedTests(unittest.TestCase):
    def test_placeholder_is_not_a_baseline(self):
        placeholder = doc()
        placeholder["provenance"] = "unrecorded-placeholder"
        self.assertFalse(pt.is_recorded(placeholder))

    def test_all_zero_baseline_is_not_recorded(self):
        self.assertFalse(pt.is_recorded(doc(ingest=0.0, p50=0.0, mx=0.0, matrix_ms=0.0)))

    def test_real_baseline_is_recorded(self):
        self.assertTrue(pt.is_recorded(doc()))


class MainGateTests(unittest.TestCase):
    def write(self, tmp, name, payload):
        path = os.path.join(tmp, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def test_warn_only_never_fails(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(pt.main([base, fresh]), 0)

    def test_gate_fails_on_regression(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 1)

    def test_gate_passes_within_tolerance(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=95_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 0)

    def test_gate_tolerance_flag_widens_the_band(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=70_000.0))
            self.assertEqual(pt.main([base, fresh, "--gate"]), 1)
            self.assertEqual(
                pt.main([base, fresh, "--gate", "--tolerance-pct", "40"]), 0
            )

    def test_malformed_tolerance_is_a_usage_error(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc())
            self.assertEqual(pt.main([base, fresh, "--tolerance-pct", "lots"]), 2)

    def test_unknown_flags_are_usage_errors_not_silent_passes(self):
        # A typo'd gate flag must fail loudly, never skip the comparison.
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            fresh = self.write(tmp, "fresh.json", doc(ingest=50_000.0))
            self.assertEqual(
                pt.main([base, fresh, "--gate", "--tolerence-pct", "5"]), 2
            )
            self.assertEqual(pt.main([base, fresh, "extra.json"]), 2)
            # Bare invocation still prints usage and exits 0 (help path).
            self.assertEqual(pt.main([]), 0)

    def test_placeholder_baseline_prints_instructions_and_passes_gate(self):
        placeholder = doc()
        placeholder["provenance"] = "unrecorded-placeholder"
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", placeholder)
            fresh = self.write(tmp, "fresh.json", doc())
            # Even under --gate: no baseline means nothing to gate on.
            self.assertEqual(pt.main([base, fresh, "--gate"]), 0)

    def test_unreadable_fresh_json_skips_warn_only_but_fails_the_gate(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = self.write(tmp, "base.json", doc())
            missing = os.path.join(tmp, "nope.json")
            self.assertEqual(pt.main([base, missing]), 0)
            # Gate mode must not pass without a measurement to compare.
            self.assertEqual(pt.main([base, missing, "--gate"]), 1)


if __name__ == "__main__":
    unittest.main()
