#!/usr/bin/env python3
"""Perf-trajectory step: compare a fresh `dpulens perf` JSON against the
committed BENCH_pipeline.json baseline and print per-metric deltas.

Two modes:

* warn-only (default): never fails the build — runner noise is not yet
  characterized, so this reports trajectory instead of gating on it (see
  ROADMAP). Regressions land in the job log; exit code stays 0.
* gate (`--gate [--tolerance-pct P]`): exits 1 when any metric regresses
  more than the tolerance (default 10%). CI stays warn-only until the
  baseline is replaced with a characterized runner's artifact; the gate
  exists so flipping the switch is a one-flag change.

A committed placeholder baseline (provenance "unrecorded-placeholder", or
all-zero metrics) can't anchor a comparison in either mode: the script
prints this run's values as the candidate baseline together with the exact
commands to commit it, and exits 0.

`dpulens.perf.v2` documents additionally carry a `fleet_stress` scaling
curve; its points are compared pair-wise by replica count (a point present
on only one side — e.g. a `--quick` fresh run against a full baseline — is
skipped, never a failure). `dpulens.perf.v3` documents further carry a
`reuse` section (snapshot-and-branch prefix-reuse counters); its rows sit
in the base METRICS list, so documents missing the section simply show
"(no comparable sample)". `dpulens.perf.v4` documents add an `iteration`
array (the decode-iteration microbench); its points are compared pair-wise
by batch size — decode iterations/sec higher-is-better, heap bytes per
iteration lower-is-better. A pre-v4 baseline has no iteration points, so
those rows are simply absent until the baseline is refreshed. Note a 0.0
bytes/iter baseline (the expected steady state) cannot anchor a ratio; the
exact zero-allocation property is gated by `tests/iter_hot_path.rs`, not
here. v1 documents compare exactly as before.

Usage: ci/perf_trajectory.py BASELINE.json FRESH.json [--gate]
       [--tolerance-pct P]
"""

import json
import sys

# (json-path, label, higher-is-better)
METRICS = [
    (("ingest", "events_per_sec"), "ingest events/s", True),
    (("snapshot", "p50_us"), "snapshot p50 us", False),
    (("snapshot", "max_us"), "snapshot max us", False),
    (("matrix", "elapsed_ms"), "matrix wall ms", False),
    (("matrix", "events_per_sec"), "matrix events/s", True),
    (("fleet", "elapsed_ms"), "fleet wall ms", False),
    (("fleet", "events_per_sec"), "fleet events/s", True),
    # v3 `reuse` section: snapshot-and-branch effectiveness. A shrinking
    # ratio means cells stopped sharing prefixes (a grouping regression),
    # so higher is better for both.
    (("reuse", "reuse_ratio"), "prefix reuse ratio", True),
    (("reuse", "sim_ns_saved"), "reuse sim ns saved", True),
]

# Per-scaling-point metrics (v2 `fleet_stress.points`), appended after the
# base rows and matched by replica count: (key, label-suffix,
# higher-is-better).
STRESS_METRICS = [
    ("events_per_sec", "events/s", True),
    ("wall_ms_per_sim_s", "wall ms/sim s", False),
]

# Per-batch-size metrics (v4 `iteration` points), matched by batch size.
ITER_METRICS = [
    ("iters_per_sec", "iters/s", True),
    ("alloc_bytes_per_iter", "alloc B/iter", False),
]

DEFAULT_TOLERANCE_PCT = 10.0


def lookup(doc, path):
    for key in path:
        if not isinstance(doc, dict) or key not in doc:
            return None
        doc = doc[key]
    return doc if isinstance(doc, (int, float)) else None


def stress_points(doc):
    """The v2 `fleet_stress` points keyed by replica count ({} for v1)."""
    if not isinstance(doc, dict):
        return {}
    fs = doc.get("fleet_stress")
    if not isinstance(fs, dict) or not isinstance(fs.get("points"), list):
        return {}
    out = {}
    for point in fs["points"]:
        if isinstance(point, dict) and isinstance(point.get("replicas"), int):
            out[point["replicas"]] = point
    return out


def iteration_points(doc):
    """The v4 `iteration` points keyed by batch size ({} for pre-v4)."""
    if not isinstance(doc, dict):
        return {}
    pts = doc.get("iteration")
    if not isinstance(pts, list):
        return {}
    out = {}
    for point in pts:
        if isinstance(point, dict) and isinstance(point.get("batch"), int):
            out[point["batch"]] = point
    return out


def is_recorded(base):
    """A usable baseline: not the committed placeholder, and at least one
    comparable metric is non-zero."""
    if not isinstance(base, dict):
        return False
    if base.get("provenance") == "unrecorded-placeholder":
        return False
    if any((lookup(base, p) or 0) > 0 for p, _, _ in METRICS):
        return True
    for point in iteration_points(base).values():
        for key, _, _ in ITER_METRICS:
            v = point.get(key)
            if isinstance(v, (int, float)) and v > 0:
                return True
    for point in stress_points(base).values():
        for key, _, _ in STRESS_METRICS:
            v = point.get(key)
            if isinstance(v, (int, float)) and v > 0:
                return True
    return False


def compare(base, fresh, tolerance_pct=DEFAULT_TOLERANCE_PCT):
    """Compare fresh against base metric by metric.

    Returns a list of rows: (label, base, fresh, delta_pct, regressed).
    base/fresh are None when a side has no comparable sample (delta_pct is
    then None and regressed False). The base METRICS rows come first (always
    all of them, so v1 documents see an unchanged row set); v4 iteration
    rows follow, one pair per batch size present on both sides; then v2
    stress-point rows, one pair per replica count present on both sides.
    """
    rows = []
    threshold = tolerance_pct / 100.0

    def add_row(label, b, f, higher_better):
        if b is None or f is None or b == 0:
            rows.append((label, b, f, None, False))
            return
        ratio = f / b
        delta_pct = (ratio - 1.0) * 100.0
        regressed = (
            ratio < 1.0 - threshold if higher_better else ratio > 1.0 + threshold
        )
        rows.append((label, b, f, delta_pct, regressed))

    for path, label, higher_better in METRICS:
        add_row(label, lookup(base, path), lookup(fresh, path), higher_better)
    b_it, f_it = iteration_points(base), iteration_points(fresh)
    for batch in sorted(k for k in b_it if k in f_it):
        for key, suffix, higher_better in ITER_METRICS:
            b = b_it[batch].get(key)
            f = f_it[batch].get(key)
            b = b if isinstance(b, (int, float)) else None
            f = f if isinstance(f, (int, float)) else None
            add_row(f"iter b{batch} {suffix}", b, f, higher_better)
    b_pts, f_pts = stress_points(base), stress_points(fresh)
    for replicas in sorted(k for k in b_pts if k in f_pts):
        for key, suffix, higher_better in STRESS_METRICS:
            b = b_pts[replicas].get(key)
            f = f_pts[replicas].get(key)
            b = b if isinstance(b, (int, float)) else None
            f = f if isinstance(f, (int, float)) else None
            add_row(f"stress {replicas} {suffix}", b, f, higher_better)
    return rows


def print_candidate_instructions(base_path, fresh_path, fresh):
    print("perf-trajectory: no recorded baseline yet (placeholder or empty).")
    print("Candidate baseline from this run:")
    for path, label, _ in METRICS:
        v = lookup(fresh, path)
        if v is not None:
            print(f"  {label:>18}: {v:,.1f}")
    for batch, point in sorted(iteration_points(fresh).items()):
        for key, suffix, _ in ITER_METRICS:
            v = point.get(key)
            if isinstance(v, (int, float)):
                print(f"  {f'iter b{batch} {suffix}':>18}: {v:,.1f}")
    for replicas, point in sorted(stress_points(fresh).items()):
        for key, suffix, _ in STRESS_METRICS:
            v = point.get(key)
            if isinstance(v, (int, float)):
                print(f"  {f'stress {replicas} {suffix}':>18}: {v:,.1f}")
    print("To start the trajectory, commit this run's artifact as the baseline:")
    print(f"  cp {fresh_path} {base_path}")
    print(f"  git add {base_path}")
    print('  git commit -m "Record perf baseline from characterized CI runner"')
    print("(then flip the CI step to --gate once runner noise is characterized)")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    gate = "--gate" in argv
    argv = [a for a in argv if a != "--gate"]
    tolerance = DEFAULT_TOLERANCE_PCT
    if "--tolerance-pct" in argv:
        i = argv.index("--tolerance-pct")
        try:
            tolerance = float(argv[i + 1])
        except (IndexError, ValueError):
            print("perf-trajectory: --tolerance-pct needs a numeric value")
            return 2
        del argv[i : i + 2]
    # A typo'd flag must not silently degrade to "print usage, exit 0" —
    # in gate mode that would pass CI without ever comparing.
    unknown = [a for a in argv if a.startswith("--")]
    if unknown:
        print(f"perf-trajectory: unknown argument(s) {unknown}")
        return 2
    if not argv:
        print(__doc__)
        return 0
    if len(argv) != 2:
        print(f"perf-trajectory: expected BASELINE.json FRESH.json, got {argv}")
        return 2
    base_path, fresh_path = argv
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        # Warn-only mode tolerates a missing sample; the gate must not go
        # green without ever comparing — an unreadable fresh JSON means the
        # measurement itself failed.
        print(f"perf-trajectory: fresh perf JSON unreadable ({e})")
        if gate:
            print("perf-trajectory: GATING — no measurement to compare, failing")
            return 1
        print("perf-trajectory: skipping (warn-only)")
        return 0
    try:
        with open(base_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        base = {}

    if not is_recorded(base):
        print_candidate_instructions(base_path, fresh_path, fresh)
        return 0

    mode = f"gate, tolerance {tolerance:g}%" if gate else "warn-only"
    print(f"perf-trajectory vs committed {base_path} ({mode}):")
    worse = 0
    for label, b, f_, delta_pct, regressed in compare(base, fresh, tolerance):
        if delta_pct is None:
            print(f"  {label:>18}: (no comparable sample)")
            continue
        marker = f"  <-- WORSE (>{tolerance:g}%)" if regressed else ""
        worse += regressed
        print(f"  {label:>18}: {b:,.1f} -> {f_:,.1f}  ({delta_pct:+.1f}%){marker}")
    if worse:
        print(
            f"perf-trajectory: {worse} metric(s) regressed >{tolerance:g}% "
            + ("(GATING: failing the build)" if gate else "(warn-only, not gating)")
        )
        return 1 if gate else 0
    print(f"perf-trajectory: no metric regressed >{tolerance:g}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
