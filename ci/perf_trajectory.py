#!/usr/bin/env python3
"""Warn-only perf-trajectory step: compare a fresh `dpulens perf` JSON
against the committed BENCH_pipeline.json baseline and print per-metric
deltas.

Never fails the build: runner noise is not yet characterized, so this step
reports trajectory instead of gating on it (see ROADMAP). It exits 0 even on
regressions; the deltas land in the job log and the fresh JSON is uploaded
as an artifact.

Usage: ci/perf_trajectory.py BASELINE.json FRESH.json
"""

import json
import sys

# (json-path, label, higher-is-better)
METRICS = [
    (("ingest", "events_per_sec"), "ingest events/s", True),
    (("snapshot", "p50_us"), "snapshot p50 us", False),
    (("snapshot", "max_us"), "snapshot max us", False),
    (("matrix", "elapsed_ms"), "matrix wall ms", False),
    (("matrix", "events_per_sec"), "matrix events/s", True),
    (("fleet", "elapsed_ms"), "fleet wall ms", False),
    (("fleet", "events_per_sec"), "fleet events/s", True),
]


def lookup(doc, path):
    for key in path:
        if not isinstance(doc, dict) or key not in doc:
            return None
        doc = doc[key]
    return doc if isinstance(doc, (int, float)) else None


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf-trajectory: fresh perf JSON unreadable ({e}); skipping")
        return 0
    try:
        with open(base_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        base = {}

    recorded = base.get("provenance") != "unrecorded-placeholder" and any(
        (lookup(base, p) or 0) > 0 for p, _, _ in METRICS
    )
    if not recorded:
        print("perf-trajectory: no recorded baseline yet.")
        print("Candidate baseline from this run (commit the uploaded")
        print(f"BENCH_pipeline artifact as {base_path} to start the trajectory):")
        for path, label, _ in METRICS:
            v = lookup(fresh, path)
            if v is not None:
                print(f"  {label:>18}: {v:,.1f}")
        return 0

    print(f"perf-trajectory vs committed {base_path} (warn-only):")
    worse = 0
    for path, label, higher_better in METRICS:
        b, f_ = lookup(base, path), lookup(fresh, path)
        if b is None or f_ is None or b == 0:
            print(f"  {label:>18}: (no comparable sample)")
            continue
        ratio = f_ / b
        delta_pct = (ratio - 1.0) * 100.0
        regressed = ratio < 0.9 if higher_better else ratio > 1.1
        marker = "  <-- WORSE (>10%)" if regressed else ""
        worse += regressed
        print(f"  {label:>18}: {b:,.1f} -> {f_:,.1f}  ({delta_pct:+.1f}%){marker}")
    if worse:
        print(f"perf-trajectory: {worse} metric(s) regressed >10% (warn-only, not gating)")
    else:
        print("perf-trajectory: no metric regressed >10%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
