//! E1 — paper Table 3(a): the North-South runbook.
//!
//! For each of NS1..NS9: run healthy + injected scenarios, verify the DPU's
//! NIC-vantage detector fires, and report detection latency plus the
//! serving-side impact (the table's "Effect" column, measured).
//!
//! `cargo bench --bench bench_north_south` (harness = false: criterion is
//! not vendored offline; methodology is warm, seeded, deterministic runs).

use dpulens::coordinator::experiment::{
    condition_experiment, report_header, report_row, standard_cfg,
};
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::runbook;
use dpulens::util::table::Table;

fn main() {
    let conditions: Vec<Condition> =
        ALL_CONDITIONS.into_iter().filter(|c| c.table() == "3a").collect();
    let cfg = standard_cfg();
    let mut t = Table::new("E1 — Table 3(a) North-South runbook, reproduced")
        .header(&report_header());
    let t0 = std::time::Instant::now();
    let mut detected = 0;
    for c in conditions.iter().copied() {
        let rep = condition_experiment(c, &cfg, true);
        if rep.detected {
            detected += 1;
        }
        eprintln!(
            "[{}] {} -> detected={} latency={:?} impact={:.2}x",
            c.id(),
            rep.injection_desc,
            rep.detected,
            rep.detection_latency.map(|d| format!("{d}")),
            rep.throughput_impact(),
        );
        t.row(report_row(&rep));
    }
    print!("{}", t.render());
    // Paper-table echo: signal + lifecycle stages per row.
    let mut meta = Table::new("Table 3(a) rows (paper text)").header(&["id", "signal", "stages"]);
    for c in conditions.iter().copied() {
        let e = runbook::entry(c);
        meta.row(vec![c.id().into(), e.signal.into(), e.stages.into()]);
    }
    print!("{}", meta.render());
    println!(
        "north-south: {detected}/{} detected from NIC vantage; wallclock {:.1}s",
        conditions.len(),
        t0.elapsed().as_secs_f64()
    );
}
