//! E4 — paper Table 2(b): the real-time signal inventory.
//!
//! Three measurements:
//!  1. DPU hot path: raw telemetry ingest throughput (events/s through
//!     WindowAccum) and full 28-detector sweep cost per window tick.
//!  2. SW sensing cost: per-signal collection overhead (record-keeping vs
//!     NVML-style polling), per Table 2(b)'s Origin column.
//!  3. Telemetry scorer: native Rust vs the AOT-compiled Pallas kernel
//!     (PJRT), same feature math (skips gracefully if artifacts missing).
//!
//! `cargo bench --bench bench_signals`

use std::time::Instant;

use dpulens::dpu::detectors::{all_detectors, Baseline, DetectConfig, DetectCtx};
use dpulens::dpu::scorer::{NativeScorer, ScorerBackend};
use dpulens::ids::{FlowId, GpuId, NodeId};
use dpulens::sim::SimTime;
use dpulens::telemetry::event::{Phase, TelemetryEvent, TelemetryKind};
use dpulens::telemetry::window::WindowAccum;
use dpulens::telemetry::ALL_SW_SIGNALS;
use dpulens::util::rng::Rng;
use dpulens::util::table::{fmt_rate, Table};

fn synth_events(n: usize, seed: u64) -> Vec<TelemetryEvent> {
    let mut rng = Rng::seeded(seed);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = SimTime(i as u64 * 120);
        let kind = match rng.below(6) {
            0 => TelemetryKind::DmaH2d {
                gpu: GpuId(rng.below(4) as u32),
                bytes: 4096 + rng.below(65536),
                latency_ns: 2000 + rng.below(3000),
                phase: if rng.chance(0.3) { Phase::Prefill } else { Phase::Decode },
            },
            1 => TelemetryKind::DmaD2h {
                gpu: GpuId(rng.below(4) as u32),
                bytes: 1024 + rng.below(8192),
                latency_ns: 1500 + rng.below(2000),
                phase: Phase::Decode,
            },
            2 => TelemetryKind::Doorbell { gpu: GpuId(rng.below(4) as u32) },
            3 => TelemetryKind::NicRx {
                flow: FlowId(rng.below(64) as u32),
                bytes: 256 + rng.below(4096),
                queue_depth: rng.below(16) as u32,
            },
            4 => TelemetryKind::NicTx {
                flow: FlowId(rng.below(64) as u32),
                bytes: 128,
                queue_depth: rng.below(16) as u32,
                wait_ns: rng.below(4000),
            },
            _ => TelemetryKind::RdmaOp {
                qp: dpulens::ids::QpId(rng.below(12) as u32),
                bytes: 65536,
                credit_wait_ns: 0,
                latency_ns: 20_000 + rng.below(5_000),
            },
        };
        out.push(TelemetryEvent { t, node: NodeId(0), kind });
    }
    out
}

fn main() {
    println!("== E4 — Table 2(b) signal inventory, measured ==\n");

    // --- 1. DPU ingest hot path ---
    const N: usize = 2_000_000;
    let events = synth_events(N, 7);
    let mut accum = WindowAccum::new(NodeId(0), 4);
    let t0 = Instant::now();
    for ev in &events {
        accum.ingest(ev);
    }
    let ingest_s = t0.elapsed().as_secs_f64();
    let ingest_rate = N as f64 / ingest_s;
    let snap = accum.snapshot(SimTime(N as u64 * 120));

    // Detector sweep cost per window.
    let detectors = all_detectors();
    let mut baseline = Baseline::new();
    for d in &detectors {
        d.calibrate(&snap, &mut baseline);
    }
    baseline.freeze();
    let cfg = DetectConfig::default();
    let history = vec![snap.clone()];
    let sweeps = 10_000;
    let t1 = Instant::now();
    let mut fired = 0usize;
    for _ in 0..sweeps {
        let ctx = DetectCtx { snap: &snap, baseline: &baseline, history: &history, cfg: &cfg };
        for d in &detectors {
            if d.check(&ctx).is_some() {
                fired += 1;
            }
        }
    }
    let sweep_ns = t1.elapsed().as_nanos() as f64 / sweeps as f64;

    let mut hot = Table::new("DPU hot path").header(&["metric", "value"]);
    hot.row(vec!["telemetry ingest".into(), fmt_rate(ingest_rate)]);
    hot.row(vec!["ingest cost/event".into(), format!("{:.0}ns", 1e9 / ingest_rate)]);
    hot.row(vec!["28-detector sweep/window".into(), format!("{sweep_ns:.0}ns")]);
    hot.row(vec!["window budget (1ms) used".into(), format!("{:.2}%", sweep_ns / 1e4)]);
    print!("{}", hot.render());
    let _ = fired;

    // --- 2. SW signal inventory (Table 2(b) echo with measured overheads) ---
    let mut t = Table::new("Table 2(b) — signals: origin and per-sample cost").header(&[
        "signal", "origin", "overhead/sample", "samples/s @1% of one core",
    ]);
    for sig in ALL_SW_SIGNALS {
        let ovh = sig.overhead_ns();
        t.row(vec![
            sig.name().into(),
            sig.origin().into(),
            format!("{ovh}ns"),
            fmt_rate(0.01 * 1e9 / ovh as f64),
        ]);
    }
    print!("{}", t.render());
    println!(
        "shape check: NVML-style HW polling is {}x the cost of SW record-keeping;\n\
         the DPU ingests the same HW facts inline at {} with zero host cost.\n",
        dpulens::telemetry::SwSignal::GpuUtil.overhead_ns()
            / dpulens::telemetry::SwSignal::RequestArrival.overhead_ns(),
        fmt_rate(ingest_rate)
    );

    // --- 3. Scorer: native vs compiled Pallas kernel ---
    let mut native = NativeScorer;
    let windows: Vec<Vec<f32>> = (0..64)
        .map(|i| (0..256).map(|j| ((i * 37 + j * 11) % 97) as f32).collect())
        .collect();
    let baseline_rows: Vec<(f32, f32)> = (0..64).map(|_| (48.0, 28.0)).collect();
    let iters = 2000;
    let t2 = Instant::now();
    for _ in 0..iters {
        let _ = native.score(&windows, &baseline_rows);
    }
    let native_us = t2.elapsed().as_micros() as f64 / iters as f64;
    println!("scorer native:   {native_us:.1}us / 64-window block");

    compiled_scorer_section(&mut native, &windows, &baseline_rows);
}

#[cfg(feature = "pjrt")]
fn compiled_scorer_section(
    native: &mut NativeScorer,
    windows: &[Vec<f32>],
    baseline_rows: &[(f32, f32)],
) {
    match (dpulens::runtime::cpu_client(), dpulens::runtime::ArtifactSet::open_default()) {
        (Ok(client), Ok(arts)) => {
            match dpulens::runtime::CompiledScorer::load(&client, &arts) {
                Ok(mut compiled) => {
                    // Correctness parity first.
                    let (fn_, zn) = native.score(windows, baseline_rows);
                    let (fc, zc) = compiled.score(windows, baseline_rows);
                    let mut max_err = 0f32;
                    for (a, b) in fn_.iter().flatten().zip(fc.iter().flatten()) {
                        max_err = max_err.max((a - b).abs() / (1.0 + a.abs()));
                    }
                    for (a, b) in zn.iter().zip(&zc) {
                        max_err = max_err.max((a - b).abs() / (1.0 + a.abs()));
                    }
                    let iters_c = 50;
                    let t3 = Instant::now();
                    for _ in 0..iters_c {
                        let _ = compiled.score(windows, baseline_rows);
                    }
                    let compiled_us = t3.elapsed().as_micros() as f64 / iters_c as f64;
                    println!(
                        "scorer compiled: {compiled_us:.1}us / block (Pallas kernel via PJRT), \
                         max rel err vs native {max_err:.2e}"
                    );
                }
                Err(e) => println!("compiled scorer unavailable: {e:#}"),
            }
        }
        _ => println!("artifacts not built; skipping compiled-scorer comparison"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn compiled_scorer_section(
    _native: &mut NativeScorer,
    _windows: &[Vec<f32>],
    _baseline_rows: &[(f32, f32)],
) {
    println!("(built without the pjrt feature; skipping compiled-scorer comparison)");
}
