//! E2 — paper Table 3(b): the PCIe Observer runbook.
//!
//! PC1..PC10 injected one at a time; the DPU's PCIe-peer vantage (DMA
//! transactions, doorbells, registrations, link utilization) must flag each.
//!
//! `cargo bench --bench bench_pcie`

use dpulens::coordinator::experiment::{
    condition_experiment, report_header, report_row, standard_cfg,
};
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::runbook;
use dpulens::util::table::Table;

fn main() {
    let conditions: Vec<Condition> =
        ALL_CONDITIONS.into_iter().filter(|c| c.table() == "3b").collect();
    let cfg = standard_cfg();
    let mut t =
        Table::new("E2 — Table 3(b) PCIe Observer runbook, reproduced").header(&report_header());
    let t0 = std::time::Instant::now();
    let mut detected = 0;
    for c in conditions.iter().copied() {
        let rep = condition_experiment(c, &cfg, true);
        if rep.detected {
            detected += 1;
        }
        eprintln!(
            "[{}] {} -> detected={} latency={:?} impact={:.2}x",
            c.id(),
            rep.injection_desc,
            rep.detected,
            rep.detection_latency.map(|d| format!("{d}")),
            rep.throughput_impact(),
        );
        t.row(report_row(&rep));
    }
    print!("{}", t.render());
    let mut meta =
        Table::new("Table 3(b) rows (paper text)").header(&["id", "signal", "root cause"]);
    for c in conditions.iter().copied() {
        let e = runbook::entry(c);
        meta.row(vec![c.id().into(), e.signal.into(), e.root_cause.into()]);
    }
    print!("{}", meta.render());
    println!(
        "pcie-observer: {detected}/{} detected from PCIe vantage; wallclock {:.1}s",
        conditions.len(),
        t0.elapsed().as_secs_f64()
    );
}
