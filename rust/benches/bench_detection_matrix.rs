//! E5 — the paper's central thesis (§§4.1-4.3): what a DPU can and cannot
//! see, versus software-only sensing.
//!
//! 1. 28×28 injection × detection confusion matrix (diagonal dominance).
//! 2. DPU vs SW-only coverage: for each condition, did the DPU identify it;
//!    did SW-only sensing even notice (any alarm), and could it identify it?
//! 3. §4.3 negative controls: with TP kept on NVLink (single-node stages),
//!    a GPU straggler is INVISIBLE to the DPU — detections must stay ~zero.
//!
//! `cargo bench --bench bench_detection_matrix`

use dpulens::coordinator::experiment::{inject_time, standard_cfg};
use dpulens::coordinator::Scenario;
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::swdet;
use dpulens::engine::preset;
use dpulens::metrics::ConfusionMatrix;
use dpulens::util::table::Table;

/// Per-condition scenario shaping (see DESIGN.md §4).
fn cfg_for(c: Condition) -> dpulens::coordinator::ScenarioCfg {
    let mut cfg = standard_cfg();
    match c {
        // Compute-skew conditions need a compute-dominated cost profile for
        // a straggler/mispartition to move collective timing.
        Condition::Ew1TpStraggler
        | Condition::Ew3CrossNodeSkew
        | Condition::Ew4Congestion
        | Condition::Ew9EarlyStopSkew => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 150.0 };
        }
        // Pipeline-cadence detection needs a *busy* pipeline: idle lulls
        // produce ms-scale healthy gaps that mask a mispartitioned stage.
        Condition::Ew2PpBubble => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 500.0 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
        }
        // Early-stop conditions only bite when decode slots are saturated.
        Condition::Ns8EarlyCompletion => {
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 2000.0 };
            cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        // PC10's PCIe signature (shrinking decode D2H blocks) additionally
        // needs iterations slow enough that slots actually fill: use the
        // compute-heavy profile under sustained demand.
        Condition::Pc10DecodeEarlyStop => {
            cfg.engine.profile = preset("7b").unwrap();
            cfg.engine.policy.max_batch = 8;
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 1500.0 };
            cfg.workload.prompt_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 24 };
        }
        _ => {}
    }
    cfg
}

fn main() {
    let t0 = std::time::Instant::now();
    let mut cm = ConfusionMatrix::new();
    let mut coverage = Table::new("E5 — DPU vs software-only observability").header(&[
        "injected", "DPU identified", "diag precision", "SW noticed", "SW identified",
    ]);
    let mut dpu_hits = 0;
    let mut sw_notices = 0;
    let mut sw_idents = 0;

    // Healthy false-alarm floor.
    let healthy = Scenario::new(standard_cfg()).run();
    cm.record_healthy(&healthy.detections, healthy.windows);

    for c in ALL_CONDITIONS {
        let mut cfg = cfg_for(c);
        cfg.inject = Some((c, inject_time(&cfg)));
        let res = Scenario::new(cfg).run();
        let t_inj = res.injected_at.unwrap();
        let post: Vec<_> =
            res.detections.iter().filter(|d| d.at >= t_inj).cloned().collect();
        let hit = post.iter().any(|d| d.condition == c);
        cm.record(c, &post, hit);
        if hit {
            dpu_hits += 1;
        }
        // SW-only comparison: alarms raised after injection?
        let sw_noticed = res.sw_detections > 0;
        if sw_noticed {
            sw_notices += 1;
        }
        // SW identification: an alarm whose mapping names this condition.
        // (SwSuite alarms are generic; only application-level conditions map.)
        let sw_identified = sw_noticed
            && [
                swdet::SwAlarm::QueueGrowth,
                swdet::SwAlarm::ArrivalBurst,
                swdet::SwAlarm::StepTimeAnomaly,
                swdet::SwAlarm::KvPressure,
                swdet::SwAlarm::TransportLatency,
                swdet::SwAlarm::GpuUnderutilized,
            ]
            .iter()
            .any(|a| swdet::identifies(*a).contains(&c));
        if sw_identified {
            sw_idents += 1;
        }
        coverage.row(vec![
            c.id().into(),
            if hit { "yes".into() } else { "NO".into() },
            format!("{:.2}", cm.diagonal_precision(c)),
            if sw_noticed { "yes".into() } else { "no".into() },
            if sw_identified { "yes".into() } else { "no".into() },
        ]);
        eprintln!("[{}] dpu={} sw_noticed={}", c.id(), hit, sw_noticed);
    }

    print!("{}", coverage.render());
    print!("{}", cm.render());
    println!(
        "DPU identified {dpu_hits}/28; SW noticed {sw_notices}/28 but identified {sw_idents}/28 \
         (software sensing lacks the PCIe/NIC vantage — the paper's thesis)"
    );
    println!(
        "healthy false-alarm conditions: {} over {} windows",
        cm.false_alarms.len(),
        cm.healthy_windows
    );

    // --- §4.3 negative control: NVLink blindness ---
    let mut blind_cfg = standard_cfg();
    blind_cfg.engine.profile = preset("7b").unwrap();
    blind_cfg.engine.nodes_per_stage = 1; // TP stays intra-node on NVLink
    blind_cfg.cluster.pp_degree = 2;
    blind_cfg.inject = Some((Condition::Ew1TpStraggler, inject_time(&blind_cfg)));
    let blind = Scenario::new(blind_cfg).run();
    let t_inj = blind.injected_at.unwrap();
    let ew1_detected = blind
        .detections
        .iter()
        .any(|d| d.condition == Condition::Ew1TpStraggler && d.at >= t_inj);
    println!(
        "\n4.3 negative control (TP on NVLink, straggler injected): EW1 detected = {ew1_detected} \
         (expected false — NVLink collectives bypass the DPU)"
    );
    println!(
        "  invisible events dropped at the visibility boundary: {}",
        blind.dpu_invisible_dropped
    );
    println!("wallclock {:.1}s", t0.elapsed().as_secs_f64());
}
