//! E5 — the paper's central thesis (§§4.1-4.3): what a DPU can and cannot
//! see, versus software-only sensing.
//!
//! This bench is a thin wrapper over `coordinator::matrix`, the shared
//! parallel scorecard subsystem (also behind `dpulens matrix`):
//!
//! 1. 28×28 injection × detection confusion matrix (diagonal dominance).
//! 2. Per-condition scorecards: recall, time-to-detect, false-positive rate
//!    against the other 27 injections, attribution accuracy, DPU-vs-SW
//!    coverage.
//! 3. §4.3 negative control: with TP kept on NVLink (single-node stages), a
//!    GPU straggler is INVISIBLE to the DPU — EW1 detections must stay zero.
//!
//! `cargo bench --bench bench_detection_matrix [-- --replicates N --threads N]`

use dpulens::coordinator::matrix::{run_matrix, MatrixConfig};
use dpulens::util::cli::opt_parse;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut mc = MatrixConfig::fast();
    if let Some(r) = opt_parse::<usize>(&args, "--replicates") {
        mc.replicates = r;
    }
    if let Some(t) = opt_parse::<usize>(&args, "--threads") {
        mc.threads = t;
    }
    let t0 = std::time::Instant::now();
    let report = run_matrix(&mc);
    print!("{}", report.render_tables());
    println!("{}", report.summary_line());
    println!(
        "wallclock {:.1}s for {} cells on {} threads \
         (rerun with `-- --threads 1` for the serial baseline)",
        t0.elapsed().as_secs_f64(),
        report.cells_run,
        report.threads_used
    );
}
