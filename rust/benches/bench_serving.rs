//! E6 — end-to-end serving study (paper Table 1 presets + Table 2(a)
//! engine-policy comparison + the §5 closed loop).
//!
//! 1. Model-size sweep (Table 1 spirit): small/base/7b/13b cost profiles.
//! 2. Engine policy ablation (Table 2(a)): continuous+paged-KV (vLLM-like)
//!    vs static batching, and length bucketing on/off.
//! 3. Closed loop: pathological vs mitigated throughput recovery.
//! 4. Real-compute row (compiled transformer via PJRT) when artifacts exist.
//!
//! `cargo bench --bench bench_serving`

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::detectors::Condition;
use dpulens::engine::preset;
use dpulens::metrics::ServeMetrics;
use dpulens::sim::{SimDur, SimTime, MS};
use dpulens::util::table::Table;

fn base() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(1000);
    cfg.calib_windows = 200;
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 250.0 };
    cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 16 };
    cfg
}

fn main() {
    let t0 = std::time::Instant::now();

    // --- 1. model-size sweep ---
    let mut t1 = Table::new("E6.1 — model-size presets (Table 1 spirit, sim cost model)")
        .header(&ServeMetrics::table_header());
    for name in ["small", "base", "7b", "13b"] {
        let mut cfg = base();
        cfg.engine.profile = preset(name).unwrap();
        cfg.engine.policy.max_batch = cfg.engine.profile.batch.min(16);
        if name == "7b" || name == "13b" {
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 100.0 };
        }
        let res = Scenario::new(cfg).run();
        t1.row(res.metrics.row_cells(name));
        eprintln!("[{name}] {}", res.metrics.brief());
    }
    print!("{}", t1.render());

    // --- 2. engine policy ablation ---
    let mut t2 = Table::new("E6.2 — engine policies (Table 2(a) comparison)")
        .header(&ServeMetrics::table_header());
    let policies: [(&str, bool, bool, bool); 4] = [
        ("continuous+bucketing (vLLM-like)", true, true, true),
        ("continuous, no bucketing", true, false, true),
        ("static batching (baseline)", false, false, false),
        ("continuous, no inflight remap", true, true, false),
    ];
    for (label, continuous, bucketing, remap) in policies {
        let mut cfg = base();
        cfg.engine.policy.continuous = continuous;
        cfg.engine.policy.length_bucketing = bucketing;
        cfg.engine.policy.inflight_remap = remap;
        // Bimodal outputs make remap matter (the NS8 shape).
        cfg.workload.output_len =
            dpulens::sim::dist::LengthDist::Bimodal { short: 2, long: 32, p_short: 0.5 };
        let res = Scenario::new(cfg).run();
        t2.row(res.metrics.row_cells(label));
        eprintln!("[{label}] {}", res.metrics.brief());
    }
    print!("{}", t2.render());

    // --- 3. closed loop recovery (fabric loss) ---
    let mut t3 = Table::new("E6.3 — closed loop (§5): EW6 fabric loss")
        .header(&ServeMetrics::table_header());
    let healthy = Scenario::new(base()).run();
    t3.row(healthy.metrics.row_cells("healthy"));
    let mut inj = base();
    inj.inject = Some((Condition::Ew6Retransmissions, SimTime(400 * MS)));
    let faulted = Scenario::new(inj.clone()).run();
    t3.row(faulted.metrics.row_cells("EW6 injected"));
    let mut mit = inj.clone();
    mit.mitigate = true;
    let healed = Scenario::new(mit).run();
    t3.row(healed.metrics.row_cells("EW6 + closed loop"));
    print!("{}", t3.render());
    let h = healthy.metrics.tok_per_s();
    let f = faulted.metrics.tok_per_s();
    let m = healed.metrics.tok_per_s();
    println!(
        "closed loop recovered {:.0}% of lost throughput (healthy {h:.0}, faulted {f:.0}, healed {m:.0} tok/s)",
        if h - f > 1e-9 { (m - f) / (h - f) * 100.0 } else { 100.0 }
    );

    // --- 4. real compute row (pjrt feature only) ---
    real_compute_section();

    println!("bench_serving wallclock {:.1}s", t0.elapsed().as_secs_f64());
}

#[cfg(feature = "pjrt")]
fn real_compute_section() {
    use dpulens::engine::ComputeBackend;
    match (dpulens::runtime::cpu_client(), dpulens::runtime::ArtifactSet::open_default()) {
        (Ok(client), Ok(arts)) => {
            let mut cfg = base();
            cfg.max_requests = 64;
            cfg.duration = SimDur::from_ms(700);
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 8 };
            let n_rep =
                dpulens::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage).len();
            let backends: Vec<Box<dyn ComputeBackend>> = (0..n_rep)
                .map(|_| {
                    Box::new(
                        dpulens::runtime::TransformerSession::load(&client, &arts).expect("load"),
                    ) as Box<dyn ComputeBackend>
                })
                .collect();
            let wall = std::time::Instant::now();
            let res = Scenario::with_backends(cfg, backends).run();
            let mut t4 = Table::new("E6.4 — real compiled transformer (PJRT)")
                .header(&ServeMetrics::table_header());
            t4.row(res.metrics.row_cells("real (small preset)"));
            print!("{}", t4.render());
            println!(
                "real-compute: {} tokens generated by the compiled model in {:.1}s wallclock",
                res.metrics.tokens_out,
                wall.elapsed().as_secs_f64()
            );
        }
        _ => println!("(artifacts not built; skipping real-compute row — run `make artifacts`)"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn real_compute_section() {
    println!("(built without the pjrt feature; skipping real-compute row)");
}
