//! E6 — end-to-end serving study (paper Table 1 presets + Table 2(a)
//! engine-policy comparison + the §5 closed loop).
//!
//! 1. Model-size sweep (Table 1 spirit): small/base/7b/13b cost profiles.
//! 2. Engine policy ablation (Table 2(a)): continuous+paged-KV (vLLM-like)
//!    vs static batching, and length bucketing on/off.
//! 3. Closed loop: pathological vs mitigated throughput recovery.
//! 4. Real-compute row (compiled transformer via PJRT) when artifacts exist.
//!
//! `cargo bench --bench bench_serving [-- --json] [-- --json-out PATH]`
//!
//! `--json` replaces the tables with a deterministic JSON document;
//! `--json-out BENCH_serving.json` writes the same document to a file for
//! trajectory tracking (both reuse `util::cli`).

use dpulens::coordinator::{Scenario, ScenarioCfg};
use dpulens::dpu::detectors::Condition;
use dpulens::engine::preset;
use dpulens::metrics::ServeMetrics;
use dpulens::sim::{SimDur, SimTime, MS};
use dpulens::util::cli::{flag, opt_val};
use dpulens::util::json::Json;
use dpulens::util::table::Table;

fn base() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(1000);
    cfg.calib_windows = 200;
    cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 250.0 };
    cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 16 };
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_mode = flag(&args, "--json");
    let t0 = std::time::Instant::now();

    // --- 1. model-size sweep ---
    let mut t1 = Table::new("E6.1 — model-size presets (Table 1 spirit, sim cost model)")
        .header(&ServeMetrics::table_header());
    let mut j1 = Json::arr();
    for name in ["small", "base", "7b", "13b"] {
        let mut cfg = base();
        cfg.engine.profile = preset(name).unwrap();
        cfg.engine.policy.max_batch = cfg.engine.profile.batch.min(16);
        if name == "7b" || name == "13b" {
            cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 100.0 };
        }
        let res = Scenario::new(cfg).run();
        t1.row(res.metrics.row_cells(name));
        j1.push(res.metrics.to_json(name));
        eprintln!("[{name}] {}", res.metrics.brief());
    }

    // --- 2. engine policy ablation ---
    let mut t2 = Table::new("E6.2 — engine policies (Table 2(a) comparison)")
        .header(&ServeMetrics::table_header());
    let mut j2 = Json::arr();
    let policies: [(&str, bool, bool, bool); 4] = [
        ("continuous+bucketing (vLLM-like)", true, true, true),
        ("continuous, no bucketing", true, false, true),
        ("static batching (baseline)", false, false, false),
        ("continuous, no inflight remap", true, true, false),
    ];
    for (label, continuous, bucketing, remap) in policies {
        let mut cfg = base();
        cfg.engine.policy.continuous = continuous;
        cfg.engine.policy.length_bucketing = bucketing;
        cfg.engine.policy.inflight_remap = remap;
        // Bimodal outputs make remap matter (the NS8 shape).
        cfg.workload.output_len =
            dpulens::sim::dist::LengthDist::Bimodal { short: 2, long: 32, p_short: 0.5 };
        let res = Scenario::new(cfg).run();
        t2.row(res.metrics.row_cells(label));
        j2.push(res.metrics.to_json(label));
        eprintln!("[{label}] {}", res.metrics.brief());
    }

    // --- 3. closed loop recovery (fabric loss) ---
    let mut t3 = Table::new("E6.3 — closed loop (§5): EW6 fabric loss")
        .header(&ServeMetrics::table_header());
    let mut j3 = Json::arr();
    let healthy = Scenario::new(base()).run();
    t3.row(healthy.metrics.row_cells("healthy"));
    j3.push(healthy.metrics.to_json("healthy"));
    let mut inj = base();
    inj.inject = Some((Condition::Ew6Retransmissions, SimTime(400 * MS)));
    let faulted = Scenario::new(inj.clone()).run();
    t3.row(faulted.metrics.row_cells("EW6 injected"));
    j3.push(faulted.metrics.to_json("EW6 injected"));
    let mut mit = inj.clone();
    mit.mitigate = true;
    let healed = Scenario::new(mit).run();
    t3.row(healed.metrics.row_cells("EW6 + closed loop"));
    j3.push(healed.metrics.to_json("EW6 + closed loop"));
    let h = healthy.metrics.tok_per_s();
    let f = faulted.metrics.tok_per_s();
    let m = healed.metrics.tok_per_s();
    let recovery = if h - f > 1e-9 { (m - f) / (h - f) } else { 1.0 };

    let doc = Json::obj()
        .set("schema", "dpulens.bench_serving.v1")
        .set("model_sweep", j1)
        .set("policy_ablation", j2)
        .set(
            "closed_loop",
            Json::obj()
                .set("rows", j3)
                .set("healthy_tok_per_s", h)
                .set("faulted_tok_per_s", f)
                .set("healed_tok_per_s", m)
                .set("recovery", recovery),
        );

    if json_mode {
        println!("{}", doc.render());
    } else {
        print!("{}", t1.render());
        print!("{}", t2.render());
        print!("{}", t3.render());
        println!(
            "closed loop recovered {:.0}% of lost throughput (healthy {h:.0}, faulted {f:.0}, healed {m:.0} tok/s)",
            recovery * 100.0
        );
        // --- 4. real compute row (pjrt feature only) ---
        real_compute_section();
        println!("bench_serving wallclock {:.1}s", t0.elapsed().as_secs_f64());
    }

    if let Some(path) = opt_val(&args, "--json-out") {
        let mut body = doc.render();
        body.push('\n');
        std::fs::write(&path, body).expect("writing BENCH_serving.json");
        eprintln!("serving metrics JSON written to {path}");
    }
}

#[cfg(feature = "pjrt")]
fn real_compute_section() {
    use dpulens::engine::ComputeBackend;
    match (dpulens::runtime::cpu_client(), dpulens::runtime::ArtifactSet::open_default()) {
        (Ok(client), Ok(arts)) => {
            let mut cfg = base();
            cfg.max_requests = 64;
            cfg.duration = SimDur::from_ms(700);
            cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 8 };
            let n_rep =
                dpulens::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage).len();
            let backends: Vec<Box<dyn ComputeBackend>> = (0..n_rep)
                .map(|_| {
                    Box::new(
                        dpulens::runtime::TransformerSession::load(&client, &arts).expect("load"),
                    ) as Box<dyn ComputeBackend>
                })
                .collect();
            let wall = std::time::Instant::now();
            let res = Scenario::with_backends(cfg, backends).run();
            let mut t4 = Table::new("E6.4 — real compiled transformer (PJRT)")
                .header(&ServeMetrics::table_header());
            t4.row(res.metrics.row_cells("real (small preset)"));
            print!("{}", t4.render());
            println!(
                "real-compute: {} tokens generated by the compiled model in {:.1}s wallclock",
                res.metrics.tokens_out,
                wall.elapsed().as_secs_f64()
            );
        }
        _ => println!("(artifacts not built; skipping real-compute row — run `make artifacts`)"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn real_compute_section() {
    println!("(built without the pjrt feature; skipping real-compute row)");
}
