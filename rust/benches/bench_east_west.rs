//! E3 — paper Table 3(c): the East-West sensing runbook.
//!
//! EW1..EW9 over a compute-dominated profile (7B-class cost model) so that
//! stragglers and stage imbalance actually move collective-burst arrivals —
//! the paper's "max-min arrival gap" red flag.
//!
//! `cargo bench --bench bench_east_west`

use dpulens::coordinator::experiment::{
    condition_experiment, report_header, report_row, standard_cfg,
};
use dpulens::coordinator::ScenarioCfg;
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::runbook;
use dpulens::engine::preset;
use dpulens::util::table::Table;

fn ew_cfg(c: Condition) -> ScenarioCfg {
    let mut cfg = standard_cfg();
    // Compute-skew rows need a compute-dominated profile; queue/loss rows
    // are clearest at the default profile (big transfers mask bimodality).
    if matches!(
        c,
        Condition::Ew1TpStraggler
            | Condition::Ew3CrossNodeSkew
            | Condition::Ew4Congestion
            | Condition::Ew9EarlyStopSkew
    ) {
        cfg.engine.profile = preset("7b").unwrap();
        cfg.engine.policy.max_batch = 8;
        cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 150.0 };
        cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 4, hi: 12 };
    }
    if c == Condition::Ew2PpBubble {
        // Cadence detection needs a busy pipeline (see DESIGN.md §10).
        cfg.engine.profile = preset("7b").unwrap();
        cfg.engine.policy.max_batch = 8;
        cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate: 500.0 };
        cfg.workload.output_len = dpulens::sim::dist::LengthDist::Uniform { lo: 8, hi: 16 };
    }
    cfg
}

fn main() {
    let conditions: Vec<Condition> =
        ALL_CONDITIONS.into_iter().filter(|c| c.table() == "3c").collect();
    let mut t = Table::new("E3 — Table 3(c) East-West sensing runbook, reproduced")
        .header(&report_header());
    let t0 = std::time::Instant::now();
    let mut detected = 0;
    for c in conditions.iter().copied() {
        let cfg = ew_cfg(c);
        let rep = condition_experiment(c, &cfg, true);
        if rep.detected {
            detected += 1;
        }
        eprintln!(
            "[{}] {} -> detected={} latency={:?} impact={:.2}x fired={:?}",
            c.id(),
            rep.injection_desc,
            rep.detected,
            rep.detection_latency.map(|d| format!("{d}")),
            rep.throughput_impact(),
            rep.fired.iter().map(|(c, n)| format!("{}x{}", c.id(), n)).collect::<Vec<_>>(),
        );
        t.row(report_row(&rep));
    }
    print!("{}", t.render());
    let mut meta =
        Table::new("Table 3(c) rows (paper text)").header(&["id", "signal", "effect"]);
    for c in conditions.iter().copied() {
        let e = runbook::entry(c);
        meta.row(vec![c.id().into(), e.signal.into(), e.effect.into()]);
    }
    print!("{}", meta.render());
    println!(
        "east-west: {detected}/{} detected from fabric vantage; wallclock {:.1}s",
        conditions.len(),
        t0.elapsed().as_secs_f64()
    );
}
