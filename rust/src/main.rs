//! dpulens CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!   serve     [--real] [--duration-ms N] [--rate R] [--seed S]
//!   inject    <COND> [--mitigate] [--duration-ms N]
//!   sweep     [--mitigate]           run all 28 condition experiments
//!   runbook                          print the encoded Tables 3(a)-(c)
//!   signals                          print the Table 2(b) signal inventory
//!   attribution <COND>               inject + show root-cause attribution

use dpulens::coordinator::{condition_experiment, experiment, Scenario, ScenarioCfg};
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::runbook;
use dpulens::metrics::ServeMetrics;
use dpulens::sim::{SimDur, SimTime, MS};
use dpulens::telemetry::ALL_SW_SIGNALS;
use dpulens::util::table::Table;

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt_val(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn base_cfg(args: &[String]) -> ScenarioCfg {
    let mut cfg = experiment::standard_cfg();
    if let Some(ms) = opt_val(args, "--duration-ms").and_then(|v| v.parse::<u64>().ok()) {
        cfg.duration = SimDur::from_ms(ms);
    }
    if let Some(rate) = opt_val(args, "--rate").and_then(|v| v.parse::<f64>().ok()) {
        cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate };
    }
    if let Some(seed) = opt_val(args, "--seed").and_then(|v| v.parse::<u64>().ok()) {
        cfg.seed = seed;
    }
    if let Some(p) = opt_val(args, "--profile") {
        cfg.engine.profile = dpulens::engine::preset(&p).expect("unknown profile");
        cfg.engine.policy.max_batch = cfg.engine.profile.batch.min(8);
    }
    cfg.mitigate = flag(args, "--mitigate");
    cfg
}

fn cmd_serve(args: &[String]) {
    let cfg = base_cfg(args);
    let real = flag(args, "--real");
    let res = if real {
        let client = dpulens::runtime::cpu_client().expect("PJRT client");
        let arts = dpulens::runtime::ArtifactSet::open_default()
            .expect("artifacts missing; run `make artifacts`");
        let n_rep = {
            let plans =
                dpulens::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
            plans.len()
        };
        let backends: Vec<Box<dyn dpulens::engine::ComputeBackend>> = (0..n_rep)
            .map(|_| {
                Box::new(
                    dpulens::runtime::TransformerSession::load(&client, &arts)
                        .expect("artifact load"),
                ) as Box<dyn dpulens::engine::ComputeBackend>
            })
            .collect();
        Scenario::with_backends(cfg, backends).run()
    } else {
        Scenario::new(cfg).run()
    };
    let mut t = Table::new("serve").header(&ServeMetrics::table_header());
    t.row(res.metrics.row_cells(if real { "real-compute" } else { "simulated" }));
    print!("{}", t.render());
    println!(
        "telemetry: {} events published, {} DPU-ingested, {} invisible (§4.3), {} windows",
        res.telemetry_published, res.dpu_ingested, res.dpu_invisible_dropped, res.windows
    );
    println!("detections: {} | sw alarms: {}", res.detections.len(), res.sw_detections);
}

fn cmd_inject(args: &[String]) {
    let Some(id) = args.first() else {
        eprintln!("usage: dpulens inject <COND> (e.g. EW1, PC5, NS4)");
        std::process::exit(2);
    };
    let Some(cond) = Condition::from_id(&id.to_uppercase()) else {
        eprintln!("unknown condition {id}; one of {:?}", ALL_CONDITIONS.map(|c| c.id()));
        std::process::exit(2);
    };
    let cfg = base_cfg(args);
    let rep = condition_experiment(cond, &cfg, flag(args, "--mitigate"));
    let entry = runbook::entry(cond);
    println!("== {} — {} ==", cond.id(), entry.signal);
    println!("injected: {}", rep.injection_desc);
    println!(
        "detected: {} (latency {:?}), fired: {:?}",
        rep.detected,
        rep.detection_latency.map(|d| format!("{d}")),
        rep.fired.iter().map(|(c, n)| format!("{}x{}", c.id(), n)).collect::<Vec<_>>()
    );
    println!(
        "throughput impact {:.2}x, p99 TTFT inflation {:.1}x",
        rep.throughput_impact(),
        rep.p99_inflation()
    );
    if let Some(r) = rep.recovery() {
        println!("mitigation recovered {:.0}% of lost throughput", r * 100.0);
    }
    println!("paper directive: {}", entry.directive.paper_text());
}

fn cmd_sweep(args: &[String]) {
    let cfg = base_cfg(args);
    let mitigate = flag(args, "--mitigate");
    let mut t = Table::new("runbook sweep").header(&experiment::report_header());
    for c in ALL_CONDITIONS {
        let rep = condition_experiment(c, &cfg, mitigate);
        t.row(experiment::report_row(&rep));
    }
    print!("{}", t.render());
}

fn cmd_runbook() {
    for table in ["3a", "3b", "3c"] {
        let title = match table {
            "3a" => "Table 3(a) North-South Runbook",
            "3b" => "Table 3(b) PCIe Observer Runbook",
            _ => "Table 3(c) East-West Sensing Runbook",
        };
        let mut t =
            Table::new(title).header(&["id", "signal (red flag)", "root cause", "directive"]);
        for e in runbook::all_entries().into_iter().filter(|e| e.condition.table() == table) {
            t.row(vec![
                e.condition.id().into(),
                e.signal.into(),
                e.root_cause.into(),
                e.directive.paper_text().into(),
            ]);
        }
        print!("{}", t.render());
    }
}

fn cmd_signals() {
    let mut t = Table::new("Table 2(b) — real-time signals")
        .header(&["signal", "origin", "overhead/sample"]);
    for sig in ALL_SW_SIGNALS {
        t.row(vec![
            sig.name().into(),
            sig.origin().into(),
            format!("{}ns", sig.overhead_ns()),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_attribution(args: &[String]) {
    let Some(id) = args.first().and_then(|i| Condition::from_id(&i.to_uppercase())) else {
        eprintln!("usage: dpulens attribution <COND>");
        std::process::exit(2);
    };
    let mut cfg = base_cfg(args);
    cfg.inject = Some((id, SimTime(cfg.calib_windows * cfg.window.ns() + 200 * MS)));
    let res = Scenario::new(cfg).run();
    println!("== attributions for injected {} ==", id.id());
    for a in &res.attributions {
        println!(
            "  {:?} (confidence {:.0}%): {}",
            a.cause,
            a.confidence * 100.0,
            a.evidence
        );
    }
    if res.attributions.is_empty() {
        println!("  (none — condition not detected)");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("inject") => cmd_inject(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("runbook") => cmd_runbook(),
        Some("signals") => cmd_signals(),
        Some("attribution") => cmd_attribution(&args[1..]),
        _ => {
            eprintln!(
                "dpulens — DPU-vantage observability for LLM inference clusters\n\
                 usage: dpulens <serve|inject|sweep|runbook|signals|attribution> [flags]\n\
                 flags: --real --mitigate --duration-ms N --rate R --seed S"
            );
            std::process::exit(2);
        }
    }
}
