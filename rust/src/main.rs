//! dpulens CLI — the leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not vendored offline):
//!   serve     [--real] [--duration-ms N] [--rate R] [--seed S]
//!   inject    <COND> [--mitigate] [--duration-ms N]
//!   sweep     [--mitigate] [--threads N]   all 28 condition experiments,
//!                                          fanned out over worker threads
//!   matrix    [--replicates N] [--threads N] [--json] [--json-out PATH]
//!             [--no-reuse]
//!             run the full injection × detection scorecard matrix
//!             (28 conditions × seed replicates + healthy and §4.3
//!             NVLink-blindness controls, in parallel) and emit the
//!             per-condition detection-quality scorecard as a table
//!             and/or deterministic JSON for trajectory tracking; cells
//!             sharing a pre-injection prefix fork from one checkpoint
//!             (`--no-reuse` forces every cell from scratch)
//!   fleet     [--replicas N] [--threads N] [--json] [--json-out PATH]
//!             [--duration-ms N] [--seed S] [--disagg] [--no-reuse]
//!             [--prefill-pools K] [--decode-pools M] [--telemetry-faults]
//!             replicas × routing-policy sweep plus the DP1-DP3
//!             data-parallel condition experiments (inject → detect →
//!             mitigate), with per-replica skew columns; deterministic
//!             JSON across runs and thread counts. `--disagg` appends the
//!             phase-disaggregation study (colocated vs 2-pool topology +
//!             the PD1-PD3 family) and bumps the JSON to dpulens.fleet.v2;
//!             a pool-count flag appends the K×M multi-pool study (per-pool
//!             DP scoping, pool-pair handoff accounting, every fleet
//!             condition as a catalog-driven triple) and bumps it to v3;
//!             `--telemetry-faults` appends the degraded-telemetry study
//!             (TD1-TD3 triples on the telemetry-weighted baseline with the
//!             router fallback-ladder trace) and bumps it to v4
//!   campaign  <MANIFEST> [--threads N] [--json] [--json-out PATH]
//!             [--no-reuse]
//!             expand a TOML-subset manifest into workload × topology ×
//!             condition permutations (tenant SLO classes, diurnal/flash
//!             arrival shapes, heavy-tailed length mixes) and run every
//!             cell in parallel; emits deterministic dpulens.campaign.v1
//!             JSON with per-cell detection metrics and per-tenant
//!             TTFT/TPOT SLO attainment
//!   perf      [--quick] [--replicates N] [--threads N] [--json-out PATH]
//!             [--fleet-stress]
//!             pipeline benchmark: batched ingest throughput, snapshot
//!             latency, the decode-iteration microbench (rounds/sec and
//!             heap bytes per steady-state iteration at batch 8/64/256),
//!             matrix/fleet end-to-end wall-clock, and the
//!             snapshot-and-branch prefix-reuse counters, written as
//!             BENCH_pipeline.json (schema dpulens.perf.v4);
//!             --fleet-stress appends the 100→1000-replica multi-pool
//!             scaling curve (events/sec, wall-clock per sim-second,
//!             allocation counters)
//!   conditions [--md] [--json] [--json-out PATH]
//!             render the condition catalog (rust/src/conditions/) as a
//!             table, markdown (the EXPERIMENTS.md source of truth), or
//!             deterministic JSON (dpulens.conditions.v1)
//!   runbook                          print the encoded runbook tables
//!   signals                          print the Table 2(b) signal inventory
//!   attribution <COND>               inject + show root-cause attribution
//!
//! `serve --real` (PJRT-compiled transformer) requires building with
//! `--features pjrt` and `make artifacts`.

use dpulens::coordinator::{condition_experiment, experiment, Scenario, ScenarioCfg};
use dpulens::dpu::detectors::{Condition, ALL_CONDITIONS};
use dpulens::dpu::runbook;
use dpulens::metrics::ServeMetrics;
use dpulens::sim::{SimDur, SimTime, MS};
use dpulens::telemetry::ALL_SW_SIGNALS;
use dpulens::util::cli::{flag, opt_parse, opt_val};
use dpulens::util::table::Table;

// The fleet-stress bench's allocation counters (peak-RSS proxy); registered
// in the binary only, so library unit tests keep the default allocator and
// read zeroed counters.
#[global_allocator]
static ALLOC: dpulens::util::alloc::CountingAlloc = dpulens::util::alloc::CountingAlloc;

fn base_cfg(args: &[String]) -> ScenarioCfg {
    let mut cfg = experiment::standard_cfg();
    if let Some(ms) = opt_parse::<u64>(args, "--duration-ms") {
        cfg.duration = SimDur::from_ms(ms);
    }
    if let Some(rate) = opt_parse::<f64>(args, "--rate") {
        cfg.workload.arrival = dpulens::sim::dist::Arrival::Poisson { rate };
    }
    if let Some(seed) = opt_parse::<u64>(args, "--seed") {
        cfg.seed = seed;
    }
    if let Some(p) = opt_val(args, "--profile") {
        cfg.engine.profile = dpulens::engine::preset(&p).expect("unknown profile");
        cfg.engine.policy.max_batch = cfg.engine.profile.batch.min(8);
    }
    cfg.mitigate = flag(args, "--mitigate");
    cfg
}

/// The snapshot-and-branch accounting line the matrix/fleet/campaign
/// runners print under their wallclock summary.
fn reuse_line(r: &dpulens::coordinator::ReuseStats) -> String {
    format!(
        "prefix reuse: {} cells from {} simulated prefixes ({} forked branches, \
         {:.0} sim-ms saved, {:.1}x)",
        r.cells_total,
        r.prefixes_simulated,
        r.forked_branches,
        r.sim_ns_saved() as f64 / 1e6,
        r.reuse_ratio()
    )
}

#[cfg(feature = "pjrt")]
fn run_real(cfg: ScenarioCfg) -> dpulens::coordinator::RunResult {
    let client = dpulens::runtime::cpu_client().expect("PJRT client");
    let arts = dpulens::runtime::ArtifactSet::open_default()
        .expect("artifacts missing; run `make artifacts`");
    let n_rep = {
        let plans = dpulens::engine::build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage);
        plans.len()
    };
    let backends: Vec<Box<dyn dpulens::engine::ComputeBackend>> = (0..n_rep)
        .map(|_| {
            Box::new(
                dpulens::runtime::TransformerSession::load(&client, &arts)
                    .expect("artifact load"),
            ) as Box<dyn dpulens::engine::ComputeBackend>
        })
        .collect();
    Scenario::with_backends(cfg, backends).run()
}

#[cfg(not(feature = "pjrt"))]
fn run_real(_cfg: ScenarioCfg) -> dpulens::coordinator::RunResult {
    eprintln!("serve --real requires a build with `--features pjrt` (plus `make artifacts`)");
    std::process::exit(2);
}

fn cmd_serve(args: &[String]) {
    let cfg = base_cfg(args);
    let real = flag(args, "--real");
    let res = if real { run_real(cfg) } else { Scenario::new(cfg).run() };
    let mut t = Table::new("serve").header(&ServeMetrics::table_header());
    t.row(res.metrics.row_cells(if real { "real-compute" } else { "simulated" }));
    print!("{}", t.render());
    println!(
        "telemetry: {} events published, {} DPU-ingested, {} invisible (§4.3), {} windows",
        res.telemetry_published, res.dpu_ingested, res.dpu_invisible_dropped, res.windows
    );
    println!("detections: {} | sw alarms: {}", res.detections.len(), res.sw_detections);
}

fn cmd_inject(args: &[String]) {
    let Some(id) = args.first() else {
        eprintln!("usage: dpulens inject <COND> (e.g. EW1, PC5, NS4)");
        std::process::exit(2);
    };
    let Some(cond) = Condition::from_id(&id.to_uppercase()) else {
        eprintln!("unknown condition {id}; one of {:?}", ALL_CONDITIONS.map(|c| c.id()));
        std::process::exit(2);
    };
    let cfg = base_cfg(args);
    let rep = condition_experiment(cond, &cfg, flag(args, "--mitigate"));
    let entry = runbook::entry(cond);
    println!("== {} — {} ==", cond.id(), entry.signal);
    println!("injected: {}", rep.injection_desc);
    println!(
        "detected: {} (latency {:?}), fired: {:?}",
        rep.detected,
        rep.detection_latency.map(|d| format!("{d}")),
        rep.fired.iter().map(|(c, n)| format!("{}x{}", c.id(), n)).collect::<Vec<_>>()
    );
    println!(
        "throughput impact {:.2}x, p99 TTFT inflation {:.1}x",
        rep.throughput_impact(),
        rep.p99_inflation()
    );
    if let Some(r) = rep.recovery() {
        println!("mitigation recovered {:.0}% of lost throughput", r * 100.0);
    }
    println!("paper directive: {}", entry.directive.paper_text());
}

fn cmd_sweep(args: &[String]) {
    let cfg = base_cfg(args);
    let mitigate = flag(args, "--mitigate");
    let threads = opt_parse::<usize>(args, "--threads").unwrap_or(0);
    let t0 = std::time::Instant::now();
    let reports = dpulens::coordinator::matrix::run_sweep(&cfg, mitigate, threads);
    let mut t = Table::new("runbook sweep").header(&experiment::report_header());
    let mut detected = 0;
    for rep in &reports {
        if rep.detected {
            detected += 1;
        }
        t.row(experiment::report_row(rep));
    }
    print!("{}", t.render());
    println!(
        "{detected}/{} detected; wallclock {:.1}s",
        reports.len(),
        t0.elapsed().as_secs_f64()
    );
}

fn cmd_matrix(args: &[String]) {
    use dpulens::coordinator::matrix::{run_matrix, MatrixConfig};
    let mut mc = MatrixConfig::default();
    mc.base = base_cfg(args);
    if let Some(r) = opt_parse::<usize>(args, "--replicates") {
        mc.replicates = r;
    }
    if let Some(t) = opt_parse::<usize>(args, "--threads") {
        mc.threads = t;
    }
    if flag(args, "--no-negative-control") {
        mc.negative_control = false;
    }
    mc.no_reuse = flag(args, "--no-reuse");
    let report = run_matrix(&mc);
    if flag(args, "--json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render_tables());
        println!("{}", report.summary_line());
        println!(
            "wallclock {:.1}s for {} cells on {} threads ({} telemetry events, {:.0} events/s)",
            report.elapsed_ms / 1e3,
            report.cells_run,
            report.threads_used,
            report.events_total,
            report.events_per_sec()
        );
        println!("{}", reuse_line(&report.reuse));
    }
    if let Some(path) = opt_val(args, "--json-out") {
        let mut body = report.to_json().render();
        body.push('\n');
        std::fs::write(&path, body).expect("writing scorecard JSON");
        eprintln!("scorecard JSON written to {path}");
    }
}

fn cmd_fleet(args: &[String]) {
    use dpulens::coordinator::fleet::{run_fleet, FleetConfig, MultiPoolSpec};
    let replicas = opt_parse::<usize>(args, "--replicas").unwrap_or(4).max(1);
    let mut fc = FleetConfig::new(replicas);
    if let Some(ms) = opt_parse::<u64>(args, "--duration-ms") {
        fc.base.duration = SimDur::from_ms(ms);
    }
    if let Some(seed) = opt_parse::<u64>(args, "--seed") {
        fc.base.seed = seed;
    }
    if let Some(t) = opt_parse::<usize>(args, "--threads") {
        fc.threads = t;
    }
    fc.disagg = flag(args, "--disagg");
    fc.telemetry_faults = flag(args, "--telemetry-faults");
    fc.no_reuse = flag(args, "--no-reuse");
    // Any pool-count flag opts into the multi-pool study (schema v3); the
    // topology takes its replica count from --replicas.
    let prefill_pools = opt_parse::<usize>(args, "--prefill-pools");
    let decode_pools = opt_parse::<usize>(args, "--decode-pools");
    if prefill_pools.is_some() || decode_pools.is_some() {
        let mp = MultiPoolSpec {
            replicas,
            prefill_pools: prefill_pools.unwrap_or(1).max(1),
            decode_pools: decode_pools.unwrap_or(1).max(1),
        };
        if let Err(e) = mp.validate() {
            eprintln!("fleet: {e}");
            std::process::exit(2);
        }
        fc.multipool = Some(mp);
    }
    let report = run_fleet(&fc);
    if flag(args, "--json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render_tables());
        println!("{}", report.summary_line());
        println!(
            "wallclock {:.1}s for {} cells on {} threads ({} telemetry events, {:.0} events/s)",
            report.elapsed_ms / 1e3,
            report.cells_run,
            report.threads_used,
            report.events_total,
            report.events_per_sec()
        );
        println!("{}", reuse_line(&report.reuse));
    }
    if let Some(path) = opt_val(args, "--json-out") {
        let mut body = report.to_json().render();
        body.push('\n');
        std::fs::write(&path, body).expect("writing fleet JSON");
        eprintln!("fleet JSON written to {path}");
    }
}

fn cmd_campaign(args: &[String]) {
    use dpulens::coordinator::campaign::{run_campaign, CampaignConfig};
    let Some(path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: dpulens campaign <MANIFEST> [--threads N] [--json] [--json-out PATH]");
        std::process::exit(2);
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("campaign: cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    let mut cc = match CampaignConfig::parse(&text) {
        Ok(cc) => cc,
        Err(e) => {
            eprintln!("campaign: {path}: {e}");
            std::process::exit(2);
        }
    };
    if let Some(t) = opt_parse::<usize>(args, "--threads") {
        cc.threads = t;
    }
    cc.no_reuse = flag(args, "--no-reuse");
    let report = run_campaign(&cc);
    if flag(args, "--json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render_tables());
        println!("{}", report.summary_line());
        println!(
            "wallclock {:.1}s for {} cells on {} threads",
            report.elapsed_ms / 1e3,
            report.cells.len(),
            report.threads_used
        );
        println!("{}", reuse_line(&report.reuse));
    }
    if let Some(out) = opt_val(args, "--json-out") {
        let mut body = report.to_json().render();
        body.push('\n');
        std::fs::write(&out, body).expect("writing campaign JSON");
        eprintln!("campaign JSON written to {out}");
    }
}

fn cmd_perf(args: &[String]) {
    use dpulens::coordinator::perf::{run_perf, FleetStressConfig, PerfConfig};
    let mut pc = if flag(args, "--quick") { PerfConfig::quick() } else { PerfConfig::full() };
    if let Some(r) = opt_parse::<usize>(args, "--replicates") {
        pc.matrix_replicates = r;
    }
    if let Some(r) = opt_parse::<usize>(args, "--replicas") {
        pc.fleet_replicas = r;
    }
    if let Some(t) = opt_parse::<usize>(args, "--threads") {
        pc.threads = t;
    }
    if flag(args, "--micro-only") {
        pc.micro_only = true;
    }
    if flag(args, "--fleet-stress") {
        pc.fleet_stress = Some(if pc.quick {
            FleetStressConfig::quick(pc.threads)
        } else {
            FleetStressConfig::full(pc.threads)
        });
    }
    let report = run_perf(&pc);
    print!("{}", report.render());
    // Variant-specific default paths: a micro-only (zeroed matrix/fleet) or
    // quick run must not clobber a recorded full baseline. CI and scripts
    // pin the artifact name with --json-out.
    let default_path = if pc.micro_only {
        "BENCH_pipeline_micro.json"
    } else if pc.quick {
        "BENCH_pipeline_quick.json"
    } else {
        "BENCH_pipeline.json"
    };
    let path = opt_val(args, "--json-out").unwrap_or_else(|| default_path.to_string());
    let mut body = report.to_json().render();
    body.push('\n');
    std::fs::write(&path, body).expect("writing perf JSON");
    eprintln!("perf JSON written to {path}");
}

fn cmd_conditions(args: &[String]) {
    // The condition catalog, straight from rust/src/conditions/ — the
    // single source every layer dispatches through. `--md` emits the
    // markdown table EXPERIMENTS.md §Condition catalog is regenerated from.
    if flag(args, "--md") {
        print!("{}", dpulens::conditions::render_markdown());
    } else if flag(args, "--json") {
        println!("{}", dpulens::conditions::to_json().render());
    } else {
        print!("{}", dpulens::conditions::render_table());
    }
    if let Some(path) = opt_val(args, "--json-out") {
        let mut body = dpulens::conditions::to_json().render();
        body.push('\n');
        std::fs::write(&path, body).expect("writing conditions JSON");
        eprintln!("conditions JSON written to {path}");
    }
}

fn cmd_runbook() {
    for table in ["3a", "3b", "3c", "dp", "pd"] {
        let title = match table {
            "3a" => "Table 3(a) North-South Runbook",
            "3b" => "Table 3(b) PCIe Observer Runbook",
            "3c" => "Table 3(c) East-West Sensing Runbook",
            "dp" => "DP Fleet Runbook (data-parallel extension)",
            _ => "PD Runbook (phase-disaggregation extension)",
        };
        let mut t = Table::new(title)
            .header(&["id", "label", "signal (red flag)", "root cause", "directive"]);
        for e in runbook::all_entries().into_iter().filter(|e| e.condition.table() == table) {
            t.row(vec![
                e.condition.id().into(),
                dpulens::conditions::spec(e.condition).label.into(),
                e.signal.into(),
                e.root_cause.into(),
                e.directive.paper_text().into(),
            ]);
        }
        print!("{}", t.render());
    }
}

fn cmd_signals() {
    let mut t = Table::new("Table 2(b) — real-time signals")
        .header(&["signal", "origin", "overhead/sample"]);
    for sig in ALL_SW_SIGNALS {
        t.row(vec![
            sig.name().into(),
            sig.origin().into(),
            format!("{}ns", sig.overhead_ns()),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_attribution(args: &[String]) {
    let Some(id) = args.first().and_then(|i| Condition::from_id(&i.to_uppercase())) else {
        eprintln!("usage: dpulens attribution <COND>");
        std::process::exit(2);
    };
    let mut cfg = base_cfg(args);
    cfg.inject = Some((id, SimTime(cfg.calib_windows * cfg.window.ns() + 200 * MS)));
    let res = Scenario::new(cfg).run();
    println!("== attributions for injected {} ==", id.id());
    for a in &res.attributions {
        println!(
            "  {:?} (confidence {:.0}%): {}",
            a.cause,
            a.confidence * 100.0,
            a.evidence
        );
    }
    if res.attributions.is_empty() {
        println!("  (none — condition not detected)");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("inject") => cmd_inject(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("matrix") => cmd_matrix(&args[1..]),
        Some("fleet") => cmd_fleet(&args[1..]),
        Some("campaign") => cmd_campaign(&args[1..]),
        Some("perf") => cmd_perf(&args[1..]),
        Some("conditions") => cmd_conditions(&args[1..]),
        Some("runbook") => cmd_runbook(),
        Some("signals") => cmd_signals(),
        Some("attribution") => cmd_attribution(&args[1..]),
        _ => {
            // Usage renders from util::cli::CLI — the registry the
            // help-coverage test audits against the parsers above.
            eprint!("{}", dpulens::util::cli::usage());
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    /// The flags each cmd_* handler above actually parses (via `flag` /
    /// `opt_parse` / `opt_val` / `base_cfg`). Auditing happens here: when a
    /// handler gains or loses a flag, this mirror table and the
    /// `util::cli::CLI` spec must both move with it, and this test pins the
    /// two together — so the printed help can never drift from the parser
    /// again (the PR-3 `--threads`/`--json-out` drift).
    const PARSED: &[(&str, &[&str])] = &[
        ("serve", &["--real", "--duration-ms", "--rate", "--seed", "--profile", "--mitigate"]),
        ("inject", &["--duration-ms", "--rate", "--seed", "--profile", "--mitigate"]),
        (
            "sweep",
            &["--duration-ms", "--rate", "--seed", "--profile", "--mitigate", "--threads"],
        ),
        (
            "matrix",
            &[
                "--replicates",
                "--threads",
                "--json",
                "--json-out",
                "--no-negative-control",
                "--no-reuse",
                "--duration-ms",
                "--rate",
                "--seed",
                "--profile",
                "--mitigate",
            ],
        ),
        (
            "fleet",
            &[
                "--replicas",
                "--threads",
                "--json",
                "--json-out",
                "--duration-ms",
                "--seed",
                "--disagg",
                "--prefill-pools",
                "--decode-pools",
                "--telemetry-faults",
                "--no-reuse",
            ],
        ),
        ("campaign", &["--threads", "--json", "--json-out", "--no-reuse"]),
        (
            "perf",
            &[
                "--quick",
                "--micro-only",
                "--fleet-stress",
                "--replicates",
                "--replicas",
                "--threads",
                "--json-out",
            ],
        ),
        ("conditions", &["--md", "--json", "--json-out"]),
        ("runbook", &[]),
        ("signals", &[]),
        ("attribution", &["--duration-ms", "--rate", "--seed", "--profile", "--mitigate"]),
    ];

    #[test]
    fn help_covers_every_parsed_flag() {
        let usage = dpulens::util::cli::usage();
        for (cmd, flags) in PARSED {
            let spec = dpulens::util::cli::cmd_spec(cmd)
                .unwrap_or_else(|| panic!("subcommand {cmd} missing from CLI spec"));
            for fl in *flags {
                assert!(
                    spec.flags.iter().any(|s| s.name == *fl),
                    "{cmd}: parsed flag {fl} missing from the CLI spec"
                );
                assert!(usage.contains(fl), "{cmd}: parsed flag {fl} missing from usage text");
            }
            // And the reverse: the spec advertises nothing the parser
            // ignores.
            for s in spec.flags {
                assert!(
                    flags.contains(&s.name),
                    "{cmd}: spec advertises {} but the handler never parses it",
                    s.name
                );
            }
        }
        // Every spec'd subcommand is audited.
        assert_eq!(PARSED.len(), dpulens::util::cli::CLI.len());
    }
}
