//! Tensor/pipeline parallel plan: which nodes/GPUs form a replica, how
//! layers split across pipeline stages, and how work shards across GPUs
//! within a stage. Imbalance knobs here create EW2 (stage imbalance) and
//! EW3 (shard imbalance).

use crate::cluster::topology::{ClusterSpec, ReplicaRole, ReplicaShape};
use crate::ids::{GpuId, NodeId, StageId};

/// One pipeline stage: the nodes (and their GPUs) executing a layer slice.
#[derive(Debug, Clone)]
pub struct Stage {
    pub id: StageId,
    pub nodes: Vec<NodeId>,
    pub gpus: Vec<GpuId>,
    /// Fraction of total model FLOPs this stage owns (sums to 1 across stages).
    pub layer_frac: f64,
    /// Per-GPU shard fractions within the stage (sums to 1).
    pub shard_frac: Vec<f64>,
}

/// A replica: a full copy of the model across `pp` stages, tagged with its
/// pool role + parallelism shape (heterogeneous fleets mix shapes).
#[derive(Debug, Clone)]
pub struct ParallelPlan {
    pub replica: usize,
    pub shape: ReplicaShape,
    pub stages: Vec<Stage>,
}

impl ParallelPlan {
    /// Build the canonical colocated plan for one replica: stages take
    /// consecutive node groups; every GPU of a stage's nodes participates
    /// (TP spans the stage's nodes, so TP collectives cross the fabric and
    /// are DPU-observable — see DESIGN.md).
    pub fn build(spec: &ClusterSpec, replica: usize, nodes: &[NodeId]) -> Self {
        assert!(!nodes.is_empty());
        assert_eq!(nodes.len() % spec.pp_degree, 0, "nodes must split evenly into stages");
        let shape = ReplicaShape::new(
            ReplicaRole::Colocated,
            (nodes.len() / spec.pp_degree) * spec.gpus_per_node,
            spec.pp_degree,
        );
        Self::build_shaped(spec, replica, nodes, shape)
    }

    /// Build a plan with an explicit [`ReplicaShape`] (possibly different
    /// per replica: the phase-disaggregated pools use e.g. a TP8×PP1 prefill
    /// replica next to TP4×PP2 decode replicas).
    pub fn build_shaped(
        spec: &ClusterSpec,
        replica: usize,
        nodes: &[NodeId],
        shape: ReplicaShape,
    ) -> Self {
        assert!(!nodes.is_empty());
        assert_eq!(nodes.len() % shape.pp, 0, "nodes must split evenly into stages");
        let nodes_per_stage = nodes.len() / shape.pp;
        assert_eq!(
            nodes_per_stage * spec.gpus_per_node,
            shape.tp,
            "shape tp {} inconsistent with {} nodes/stage x {} gpus",
            shape.tp,
            nodes_per_stage,
            spec.gpus_per_node
        );
        let stages = (0..shape.pp)
            .map(|s| {
                let snodes: Vec<NodeId> =
                    nodes[s * nodes_per_stage..(s + 1) * nodes_per_stage].to_vec();
                let gpus: Vec<GpuId> =
                    snodes.iter().flat_map(|&n| spec.gpus_of_node(n)).collect();
                let n_gpus = gpus.len();
                Stage {
                    id: StageId(s as u32),
                    nodes: snodes,
                    gpus,
                    layer_frac: 1.0 / shape.pp as f64,
                    shard_frac: vec![1.0 / n_gpus as f64; n_gpus],
                }
            })
            .collect();
        ParallelPlan { replica, shape, stages }
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// All nodes of the replica, stage order.
    pub fn all_nodes(&self) -> Vec<NodeId> {
        self.stages.iter().flat_map(|s| s.nodes.clone()).collect()
    }

    /// First-stage nodes (where ingress feeds) and last-stage nodes (where
    /// logits come back / egress happens).
    pub fn entry_nodes(&self) -> &[NodeId] {
        &self.stages[0].nodes
    }

    pub fn exit_nodes(&self) -> &[NodeId] {
        &self.stages[self.stages.len() - 1].nodes
    }

    /// EW2 injector variant: a mispartitioned stage *recomputes* part of
    /// its slice (bad split boundaries), so its work inflates WITHOUT the
    /// other stages shrinking — this is what stretches the pipeline cadence.
    pub fn overload_stage(&mut self, stage: usize, factor: f64) {
        assert!(stage < self.stages.len());
        self.stages[stage].layer_frac *= factor;
    }

    /// EW2 injector: skew stage compute fractions (renormalized).
    pub fn skew_stages(&mut self, hot_stage: usize, factor: f64) {
        assert!(hot_stage < self.stages.len());
        let mut fr: Vec<f64> = self.stages.iter().map(|s| s.layer_frac).collect();
        fr[hot_stage] *= factor;
        let total: f64 = fr.iter().sum();
        for (s, f) in self.stages.iter_mut().zip(fr) {
            s.layer_frac = f / total;
        }
    }

    /// EW3 injector: skew shard fractions within a stage (renormalized).
    pub fn skew_shards(&mut self, stage: usize, hot_gpu: usize, factor: f64) {
        let st = &mut self.stages[stage];
        assert!(hot_gpu < st.shard_frac.len());
        st.shard_frac[hot_gpu] *= factor;
        let total: f64 = st.shard_frac.iter().sum();
        for f in &mut st.shard_frac {
            *f /= total;
        }
    }

    /// Rebalance mitigation: restore uniform fractions.
    pub fn rebalance(&mut self) {
        let n_stages = self.stages.len() as f64;
        for st in &mut self.stages {
            st.layer_frac = 1.0 / n_stages;
            let n = st.shard_frac.len() as f64;
            for f in &mut st.shard_frac {
                *f = 1.0 / n;
            }
        }
    }

    /// Sanity: fractions normalized.
    pub fn check(&self) -> Result<(), String> {
        let lf: f64 = self.stages.iter().map(|s| s.layer_frac).sum();
        if (lf - 1.0).abs() > 1e-9 {
            return Err(format!("layer fractions sum {lf}"));
        }
        for st in &self.stages {
            let sf: f64 = st.shard_frac.iter().sum();
            if (sf - 1.0).abs() > 1e-9 {
                return Err(format!("stage {} shard fractions sum {sf}", st.id));
            }
            if st.gpus.len() != st.shard_frac.len() {
                return Err("shard/gpu length mismatch".into());
            }
        }
        Ok(())
    }
}

/// Partition the cluster's nodes into replicas of `pp_degree *
/// nodes_per_stage` nodes each.
pub fn build_replicas(spec: &ClusterSpec, nodes_per_stage: usize) -> Vec<ParallelPlan> {
    let per_replica = spec.pp_degree * nodes_per_stage;
    assert!(per_replica > 0 && spec.n_nodes >= per_replica, "cluster too small for plan");
    let n_replicas = spec.n_nodes / per_replica;
    (0..n_replicas)
        .map(|r| {
            let nodes: Vec<NodeId> =
                (0..per_replica).map(|i| NodeId((r * per_replica + i) as u32)).collect();
            ParallelPlan::build(spec, r, &nodes)
        })
        .collect()
}

/// Partition the cluster's nodes into heterogeneous replicas, one per shape
/// (consecutive node ranges, shape order). This is the phase-disaggregated
/// builder: roles split the fleet into prefill/decode pools and each pool
/// may use a different TP×PP layout.
pub fn build_shaped_replicas(spec: &ClusterSpec, shapes: &[ReplicaShape]) -> Vec<ParallelPlan> {
    assert!(!shapes.is_empty(), "no replica shapes");
    let mut next = 0usize;
    shapes
        .iter()
        .enumerate()
        .map(|(r, &shape)| {
            let need = shape.nodes_needed(spec.gpus_per_node);
            assert!(
                next + need <= spec.n_nodes,
                "cluster of {} nodes too small for shapes (need > {})",
                spec.n_nodes,
                next + need - 1
            );
            let nodes: Vec<NodeId> = (next..next + need).map(|i| NodeId(i as u32)).collect();
            next += need;
            ParallelPlan::build_shaped(spec, r, &nodes, shape)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_shapes() {
        let spec = ClusterSpec::default(); // 4 nodes, pp=2
        let plans = build_replicas(&spec, 2);
        assert_eq!(plans.len(), 1);
        let p = &plans[0];
        assert_eq!(p.n_stages(), 2);
        assert_eq!(p.stages[0].nodes, vec![NodeId(0), NodeId(1)]);
        assert_eq!(p.stages[1].nodes, vec![NodeId(2), NodeId(3)]);
        assert_eq!(p.stages[0].gpus.len(), 8);
        p.check().unwrap();
    }

    #[test]
    fn two_replicas_when_single_node_stages() {
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, 1);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[1].all_nodes(), vec![NodeId(2), NodeId(3)]);
        for p in &plans {
            p.check().unwrap();
        }
    }

    #[test]
    fn skew_and_rebalance() {
        let spec = ClusterSpec::default();
        let mut p = build_replicas(&spec, 2).remove(0);
        p.skew_stages(0, 3.0);
        assert!(p.stages[0].layer_frac > 0.7);
        p.check().unwrap();
        p.skew_shards(1, 0, 4.0);
        assert!(p.stages[1].shard_frac[0] > 0.3);
        p.check().unwrap();
        p.rebalance();
        assert!((p.stages[0].layer_frac - 0.5).abs() < 1e-12);
        assert!((p.stages[1].shard_frac[0] - 0.125).abs() < 1e-12);
    }

    #[test]
    fn default_plans_carry_colocated_shapes() {
        let spec = ClusterSpec::default();
        let p = build_replicas(&spec, 2).remove(0);
        assert_eq!(p.shape, ReplicaShape::new(ReplicaRole::Colocated, 8, 2));
        let q = build_replicas(&spec, 1).remove(1);
        assert_eq!(q.shape, ReplicaShape::new(ReplicaRole::Colocated, 4, 2));
    }

    #[test]
    fn shaped_replicas_take_consecutive_node_ranges() {
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = [
            ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ];
        let plans = build_shaped_replicas(&spec, &shapes);
        assert_eq!(plans.len(), 3);
        // TP8 prefill replica: one 2-node stage (TP spans the fabric).
        assert_eq!(plans[0].n_stages(), 1);
        assert_eq!(plans[0].stages[0].nodes, vec![NodeId(0), NodeId(1)]);
        assert_eq!(plans[0].stages[0].gpus.len(), 8);
        // TP4xPP2 decode replicas: two single-node stages each.
        assert_eq!(plans[1].n_stages(), 2);
        assert_eq!(plans[1].all_nodes(), vec![NodeId(2), NodeId(3)]);
        assert_eq!(plans[2].all_nodes(), vec![NodeId(4), NodeId(5)]);
        for p in &plans {
            p.check().unwrap();
        }
        assert_eq!(plans[0].shape.role, ReplicaRole::Prefill);
        assert_eq!(plans[2].shape.role, ReplicaRole::Decode);
    }

    #[test]
    #[should_panic(expected = "too small for shapes")]
    fn shaped_overflow_panics() {
        let spec = ClusterSpec::default(); // 4 nodes
        build_shaped_replicas(
            &spec,
            &[
                ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
                ReplicaShape::new(ReplicaRole::Decode, 8, 2),
            ],
        );
    }

    #[test]
    #[should_panic]
    fn too_small_cluster_panics() {
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 1;
        spec.pp_degree = 1;
        build_replicas(&spec, 2);
    }
}
