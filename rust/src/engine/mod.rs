//! vLLM-like inference engine substrate: router → admission/batcher → paged
//! KV cache → TP/PP execution over the simulated cluster.
//!
//! The [`Engine`] struct composes per-replica state; the scenario loop
//! (`coordinator::scenario`) drives it through the discrete-event calendar.

pub mod batcher;
pub mod exec;
pub mod kvcache;
pub mod parallel;
pub mod profile;
pub mod router;

pub use batcher::{BatchPolicy, Batcher, Work};
pub use exec::{CollSeq, ComputeBackend, IterKind, IterTiming, SurrogateBackend};
pub use kvcache::{AllocResult, KvCache};
pub use parallel::{build_replicas, ParallelPlan};
pub use profile::{preset, ModelProfile};
pub use router::{RoutePolicy, Router};

use std::collections::HashMap;

use crate::ids::ReqId;
use crate::workload::request::InferenceRequest;

/// Engine-wide configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub profile: ModelProfile,
    pub policy: BatchPolicy,
    pub route_policy: RoutePolicy,
    /// KV pages per replica and tokens per page.
    pub kv_pages: u32,
    pub kv_page_tokens: u32,
    /// Nodes per pipeline stage (TP span across the fabric).
    pub nodes_per_stage: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let profile = preset("small").unwrap();
        let mut policy = BatchPolicy::default();
        policy.max_batch = profile.batch;
        EngineConfig {
            profile,
            policy,
            route_policy: RoutePolicy::FlowHash,
            kv_pages: 1024,
            kv_page_tokens: 16,
            nodes_per_stage: 2,
        }
    }
}

/// Per-replica serving state.
#[derive(Debug)]
pub struct Replica {
    pub plan: ParallelPlan,
    pub batcher: Batcher,
    pub kv: KvCache,
    pub colls: CollSeq,
    /// Whether an iteration is currently in flight (next one scheduled).
    pub busy: bool,
    pub iterations: u64,
    pub prefills: u64,
    pub decodes: u64,
}

/// The serving engine: router + replicas + request registry.
#[derive(Debug)]
pub struct Engine {
    pub cfg: EngineConfig,
    pub router: Router,
    pub replicas: Vec<Replica>,
    pub requests: HashMap<ReqId, InferenceRequest>,
    /// Which replica each request landed on.
    pub placement: HashMap<ReqId, usize>,
}

impl Engine {
    pub fn new(cfg: EngineConfig, plans: Vec<ParallelPlan>) -> Self {
        assert!(!plans.is_empty());
        let n = plans.len();
        let replicas = plans
            .into_iter()
            .map(|plan| Replica {
                plan,
                batcher: Batcher::new(cfg.policy.clone()),
                kv: KvCache::new(cfg.kv_pages, cfg.kv_page_tokens),
                colls: CollSeq::default(),
                busy: false,
                iterations: 0,
                prefills: 0,
                decodes: 0,
            })
            .collect();
        Engine {
            router: Router::new(n, cfg.route_policy),
            cfg,
            replicas,
            requests: HashMap::new(),
            placement: HashMap::new(),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Which replica's plan owns `node` (victim-replica resolution for the
    /// DP injectors and the drain directive).
    pub fn replica_of_node(&self, node: crate::ids::NodeId) -> Option<usize> {
        self.replicas
            .iter()
            .position(|r| r.plan.stages.iter().any(|s| s.nodes.contains(&node)))
    }

    /// Register an arriving request and route it. Returns the replica index.
    pub fn register(&mut self, req: InferenceRequest) -> usize {
        let r = self.router.route(req.flow);
        self.placement.insert(req.id, r);
        self.requests.insert(req.id, req);
        r
    }

    pub fn request(&self, id: ReqId) -> &InferenceRequest {
        &self.requests[&id]
    }

    pub fn request_mut(&mut self, id: ReqId) -> &mut InferenceRequest {
        self.requests.get_mut(&id).expect("unknown request")
    }

    /// Total tokens generated so far across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.requests.values().map(|r| r.tokens_generated() as u64).sum()
    }

    /// Aggregate queue depth (Table 2(b) signal).
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|r| r.batcher.queue_depth()).sum()
    }

    /// Mean KV occupancy across replicas.
    pub fn kv_occupancy(&self) -> f64 {
        let n = self.replicas.len() as f64;
        self.replicas.iter().map(|r| r.kv.occupancy()).sum::<f64>() / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::ids::FlowId;
    use crate::sim::SimTime;

    fn engine() -> Engine {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        Engine::new(cfg, plans)
    }

    fn req(id: u32, flow: u32) -> InferenceRequest {
        InferenceRequest::new(ReqId(id), FlowId(flow), SimTime(0), vec![1, 2, 3, 4], 4)
    }

    #[test]
    fn register_routes_and_tracks() {
        let mut e = engine();
        let r = e.register(req(1, 5));
        assert!(r < e.n_replicas());
        assert_eq!(e.placement[&ReqId(1)], r);
        assert_eq!(e.request(ReqId(1)).flow, FlowId(5));
    }

    #[test]
    fn default_config_consistent_with_profile() {
        let e = engine();
        assert_eq!(e.cfg.policy.max_batch, e.cfg.profile.batch);
        assert_eq!(e.n_replicas(), 1); // 4 nodes / (pp2 * 2 nodes-per-stage)
    }

    #[test]
    fn queue_and_kv_signals_start_clean() {
        let e = engine();
        assert_eq!(e.queue_depth(), 0);
        assert_eq!(e.kv_occupancy(), 0.0);
        assert_eq!(e.total_tokens(), 0);
    }
}
