//! vLLM-like inference engine substrate: router → admission/batcher → paged
//! KV cache → TP/PP execution over the simulated cluster.
//!
//! The [`Engine`] struct composes per-replica state; the scenario loop
//! (`coordinator::scenario`) drives it through the discrete-event calendar.

pub mod batcher;
pub mod exec;
pub mod kvcache;
pub mod parallel;
pub mod profile;
pub mod router;

pub use batcher::{BatchPolicy, Batcher, Work};
pub use exec::{CollSeq, ComputeBackend, IterKind, IterTiming, SurrogateBackend};
pub use kvcache::{AllocResult, KvCache};
pub use parallel::{build_replicas, build_shaped_replicas, ParallelPlan};
pub use profile::{preset, ModelProfile};
pub use router::{RoutePolicy, Router};

use std::collections::HashMap;

use crate::cluster::topology::{ReplicaRole, ReplicaShape};
use crate::ids::ReqId;
use crate::workload::request::InferenceRequest;

/// Engine-wide configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub profile: ModelProfile,
    pub policy: BatchPolicy,
    pub route_policy: RoutePolicy,
    /// Phase-transition (prefill→decode pool) routing policy. Handoffs have
    /// no session affinity to honor, so the default balances by load. Unused
    /// on colocated fleets.
    pub decode_route_policy: RoutePolicy,
    /// KV pages per replica and tokens per page.
    pub kv_pages: u32,
    pub kv_page_tokens: u32,
    /// Nodes per pipeline stage (TP span across the fabric) for the uniform
    /// colocated builder. Ignored when `shapes` is set.
    pub nodes_per_stage: usize,
    /// Heterogeneous per-replica shapes (phase-disaggregated pools). `None`
    /// keeps the classic uniform colocated fleet from `nodes_per_stage`.
    pub shapes: Option<Vec<ReplicaShape>>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let profile = preset("small").unwrap();
        let mut policy = BatchPolicy::default();
        policy.max_batch = profile.batch;
        EngineConfig {
            profile,
            policy,
            route_policy: RoutePolicy::FlowHash,
            decode_route_policy: RoutePolicy::LeastLoaded,
            kv_pages: 1024,
            kv_page_tokens: 16,
            nodes_per_stage: 2,
            shapes: None,
        }
    }
}

/// Per-replica serving state.
#[derive(Debug)]
pub struct Replica {
    pub plan: ParallelPlan,
    pub batcher: Batcher,
    pub kv: KvCache,
    pub colls: CollSeq,
    /// Whether an iteration is currently in flight (next one scheduled).
    pub busy: bool,
    pub iterations: u64,
    pub prefills: u64,
    pub decodes: u64,
}

/// The serving engine: the two-stage router pair (admission over the
/// prefill-capable pool, phase transition over the decode-capable pool) +
/// replicas + request registry. On a colocated fleet both pools are the full
/// replica set and only the admission router ever routes, reproducing the
/// classic single-stage plane exactly.
#[derive(Debug)]
pub struct Engine {
    pub cfg: EngineConfig,
    /// Admission router: new requests land on a prefill-capable replica.
    pub router: Router,
    /// Phase-transition router: completed prefills pick a decode-capable
    /// replica for the KV handoff. Idle on colocated fleets.
    pub decode_router: Router,
    pub replicas: Vec<Replica>,
    pub requests: HashMap<ReqId, InferenceRequest>,
    /// Which replica each request currently occupies (updated at the phase
    /// transition on disaggregated fleets).
    pub placement: HashMap<ReqId, usize>,
    /// Roles at construction time (heal/reset restores these after
    /// `RebalancePools` role shifts).
    base_roles: Vec<ReplicaRole>,
    disaggregated: bool,
}

impl Engine {
    pub fn new(cfg: EngineConfig, plans: Vec<ParallelPlan>) -> Self {
        assert!(!plans.is_empty());
        let n = plans.len();
        let base_roles: Vec<ReplicaRole> = plans.iter().map(|p| p.shape.role).collect();
        let disaggregated = base_roles.iter().any(|&r| r != ReplicaRole::Colocated);
        let (prefill_members, decode_members) = pool_members(&base_roles);
        let replicas = plans
            .into_iter()
            .map(|plan| Replica {
                plan,
                batcher: Batcher::new(cfg.policy.clone()),
                kv: KvCache::new(cfg.kv_pages, cfg.kv_page_tokens),
                colls: CollSeq::default(),
                busy: false,
                iterations: 0,
                prefills: 0,
                decodes: 0,
            })
            .collect();
        Engine {
            router: Router::with_members(n, cfg.route_policy, prefill_members),
            decode_router: Router::with_members(n, cfg.decode_route_policy, decode_members),
            cfg,
            replicas,
            requests: HashMap::new(),
            placement: HashMap::new(),
            base_roles,
            disaggregated,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Does this fleet run separate prefill/decode pools? (Sticky: a world
    /// built disaggregated stays phase-split even if mitigation later makes
    /// a pool's membership look colocated.)
    pub fn is_disaggregated(&self) -> bool {
        self.disaggregated
    }

    /// Current role of each replica (post any mitigation role shifts).
    pub fn roles(&self) -> Vec<ReplicaRole> {
        self.replicas.iter().map(|r| r.plan.shape.role).collect()
    }

    /// Reassign a replica's pool role (the `RebalancePools` autoscaling
    /// primitive) and rebuild both routers' pool membership. In-flight work
    /// on the replica is unaffected; only *new* routing follows the role.
    pub fn shift_role(&mut self, replica: usize, role: ReplicaRole) {
        assert!(replica < self.n_replicas());
        self.replicas[replica].plan.shape.role = role;
        self.refresh_pools();
    }

    /// Restore construction-time roles (heal between experiments).
    pub fn reset_roles(&mut self) {
        for r in 0..self.replicas.len() {
            self.replicas[r].plan.shape.role = self.base_roles[r];
        }
        self.refresh_pools();
    }

    fn refresh_pools(&mut self) {
        let roles = self.roles();
        let (prefill_members, decode_members) = pool_members(&roles);
        self.router.set_members(prefill_members);
        self.decode_router.set_members(decode_members);
    }

    /// Which replica's plan owns `node` (victim-replica resolution for the
    /// DP injectors and the drain directive).
    pub fn replica_of_node(&self, node: crate::ids::NodeId) -> Option<usize> {
        self.replicas
            .iter()
            .position(|r| r.plan.stages.iter().any(|s| s.nodes.contains(&node)))
    }

    /// Register an arriving request and route it onto the prefill-capable
    /// pool. Returns the replica index.
    pub fn register(&mut self, req: InferenceRequest) -> usize {
        let r = self.router.route(req.flow);
        self.placement.insert(req.id, r);
        self.requests.insert(req.id, req);
        r
    }

    /// Phase transition: pick the decode-pool replica that will adopt this
    /// request's KV, and move its placement there. The caller models the
    /// actual handoff transfer.
    pub fn route_decode(&mut self, req: ReqId) -> usize {
        let flow = self.requests[&req].flow;
        let d = self.decode_router.route(flow);
        self.placement.insert(req, d);
        d
    }

    pub fn request(&self, id: ReqId) -> &InferenceRequest {
        &self.requests[&id]
    }

    pub fn request_mut(&mut self, id: ReqId) -> &mut InferenceRequest {
        self.requests.get_mut(&id).expect("unknown request")
    }

    /// Total tokens generated so far across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.requests.values().map(|r| r.tokens_generated() as u64).sum()
    }

    /// Aggregate queue depth (Table 2(b) signal).
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|r| r.batcher.queue_depth()).sum()
    }

    /// Mean KV occupancy across replicas.
    pub fn kv_occupancy(&self) -> f64 {
        let n = self.replicas.len() as f64;
        self.replicas.iter().map(|r| r.kv.occupancy()).sum::<f64>() / n
    }
}

/// Split replica indices into (prefill-capable, decode-capable) pools.
fn pool_members(roles: &[ReplicaRole]) -> (Vec<usize>, Vec<usize>) {
    let prefill: Vec<usize> = roles
        .iter()
        .enumerate()
        .filter(|(_, r)| r.serves_prefill())
        .map(|(i, _)| i)
        .collect();
    let decode: Vec<usize> = roles
        .iter()
        .enumerate()
        .filter(|(_, r)| r.serves_decode())
        .map(|(i, _)| i)
        .collect();
    assert!(!prefill.is_empty(), "fleet has no prefill-capable replica");
    assert!(!decode.is_empty(), "fleet has no decode-capable replica");
    (prefill, decode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::ids::FlowId;
    use crate::sim::SimTime;

    fn engine() -> Engine {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        Engine::new(cfg, plans)
    }

    fn req(id: u32, flow: u32) -> InferenceRequest {
        InferenceRequest::new(ReqId(id), FlowId(flow), SimTime(0), vec![1, 2, 3, 4], 4)
    }

    #[test]
    fn register_routes_and_tracks() {
        let mut e = engine();
        let r = e.register(req(1, 5));
        assert!(r < e.n_replicas());
        assert_eq!(e.placement[&ReqId(1)], r);
        assert_eq!(e.request(ReqId(1)).flow, FlowId(5));
    }

    #[test]
    fn default_config_consistent_with_profile() {
        let e = engine();
        assert_eq!(e.cfg.policy.max_batch, e.cfg.profile.batch);
        assert_eq!(e.n_replicas(), 1); // 4 nodes / (pp2 * 2 nodes-per-stage)
    }

    #[test]
    fn queue_and_kv_signals_start_clean() {
        let e = engine();
        assert_eq!(e.queue_depth(), 0);
        assert_eq!(e.kv_occupancy(), 0.0);
        assert_eq!(e.total_tokens(), 0);
    }

    fn disagg_engine() -> Engine {
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = vec![
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Prefill, 8, 1),
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Decode, 4, 2),
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Decode, 4, 2),
        ];
        let mut cfg = EngineConfig::default();
        cfg.shapes = Some(shapes.clone());
        let plans = build_shaped_replicas(&spec, &shapes);
        Engine::new(cfg, plans)
    }

    #[test]
    fn colocated_engine_is_not_disaggregated() {
        let e = engine();
        assert!(!e.is_disaggregated());
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[0]);
    }

    #[test]
    fn two_stage_routing_respects_pools() {
        let mut e = disagg_engine();
        assert!(e.is_disaggregated());
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[1, 2]);
        let p = e.register(req(1, 5));
        assert_eq!(p, 0, "admission must land on the prefill pool");
        let d = e.route_decode(ReqId(1));
        assert!(d == 1 || d == 2, "transition must land on the decode pool");
        assert_eq!(e.placement[&ReqId(1)], d);
        // Accounting is split per stage.
        assert_eq!(e.router.outstanding()[0], 1);
        assert_eq!(e.decode_router.outstanding()[d], 1);
    }

    #[test]
    fn role_shift_moves_pool_membership_and_heals() {
        let mut e = disagg_engine();
        e.shift_role(2, crate::cluster::ReplicaRole::Prefill);
        assert_eq!(e.router.members(), &[0, 2]);
        assert_eq!(e.decode_router.members(), &[1]);
        assert!(e.is_disaggregated(), "role shifts don't collapse the phase split");
        e.reset_roles();
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[1, 2]);
    }
}
