//! vLLM-like inference engine substrate: router → admission/batcher → paged
//! KV cache → TP/PP execution over the simulated cluster.
//!
//! The [`Engine`] struct composes per-replica state; the scenario loop
//! (`coordinator::scenario`) drives it through the discrete-event calendar.

pub mod batcher;
pub mod exec;
pub mod kvcache;
pub mod parallel;
pub mod profile;
pub mod router;

pub use batcher::{BatchPolicy, Batcher, DecodeSpec, Lanes, Work};
pub use exec::{CollSeq, ComputeBackend, ExecScratch, IterKind, IterTiming, SurrogateBackend};
pub use kvcache::{AllocResult, KvCache};
pub use parallel::{build_replicas, build_shaped_replicas, ParallelPlan};
pub use profile::{preset, ModelProfile};
pub use router::{RoutePolicy, Router};

use std::collections::HashMap;

use crate::cluster::topology::{ReplicaRole, ReplicaShape};
use crate::ids::ReqId;
use crate::workload::request::InferenceRequest;

/// Engine-wide configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub profile: ModelProfile,
    pub policy: BatchPolicy,
    pub route_policy: RoutePolicy,
    /// Phase-transition (prefill→decode pool) routing policy. Handoffs have
    /// no session affinity to honor, so the default balances by load. Unused
    /// on colocated fleets.
    pub decode_route_policy: RoutePolicy,
    /// KV pages per replica and tokens per page.
    pub kv_pages: u32,
    pub kv_page_tokens: u32,
    /// Nodes per pipeline stage (TP span across the fabric) for the uniform
    /// colocated builder. Ignored when `shapes` is set.
    pub nodes_per_stage: usize,
    /// Heterogeneous per-replica shapes (phase-disaggregated pools). `None`
    /// keeps the classic uniform colocated fleet from `nodes_per_stage`.
    pub shapes: Option<Vec<ReplicaShape>>,
    /// How many admission pools the prefill-capable replicas split into
    /// (contiguous near-even partition). 1 = the classic single-pool plane,
    /// byte-identical to the pre-multi-pool engine.
    pub prefill_pools: usize,
    /// How many handoff pools the decode-capable replicas split into.
    /// Prefill pool `p` hands off to decode pool `p % decode_pools`.
    pub decode_pools: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let profile = preset("small").unwrap();
        let mut policy = BatchPolicy::default();
        policy.max_batch = profile.batch;
        EngineConfig {
            profile,
            policy,
            route_policy: RoutePolicy::FlowHash,
            decode_route_policy: RoutePolicy::LeastLoaded,
            kv_pages: 1024,
            kv_page_tokens: 16,
            nodes_per_stage: 2,
            shapes: None,
            prefill_pools: 1,
            decode_pools: 1,
        }
    }
}

/// The fleet's pool partition: prefill-capable replicas grouped into K
/// admission pools and decode-capable replicas into M handoff pools, all
/// indexing the same global replica space. The classic serving plane is the
/// K = M = 1 degenerate case (each union is its own single pool), and every
/// consumer — the two routers, the fleet sensor's skew scoping, the per-pair
/// handoff accounting — reproduces the pre-multi-pool arithmetic exactly
/// there.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolTopology {
    /// All prefill-capable replicas (the admission router's membership).
    pub prefill_members: Vec<usize>,
    /// All decode-capable replicas (the phase-transition router's membership).
    pub decode_members: Vec<usize>,
    /// Admission pools: contiguous near-even partition of `prefill_members`.
    pub prefill_pools: Vec<Vec<usize>>,
    /// Handoff pools: contiguous near-even partition of `decode_members`.
    pub decode_pools: Vec<Vec<usize>>,
}

/// Contiguous near-even partition of `members` into `k` pools (pool `i`
/// takes `members[i*n/k .. (i+1)*n/k]`). `k` is clamped so every pool is
/// non-empty.
fn chunk_even(members: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = members.len();
    let k = k.clamp(1, n.max(1));
    (0..k).map(|i| members[i * n / k..(i + 1) * n / k].to_vec()).collect()
}

impl PoolTopology {
    /// Partition by role into `k` prefill and `m` decode pools. Colocated
    /// replicas are members of both sides (classic single-stage serving).
    /// `m` additionally clamps to the effective `k`: under the `p % M`
    /// handoff pairing a decode pool with no prefill pool mapping to it
    /// would be permanently unreachable (silently starved), so extra decode
    /// pools merge instead. The CLI rejects K < M loudly before this.
    pub fn build(roles: &[ReplicaRole], k: usize, m: usize) -> Self {
        let prefill_members: Vec<usize> = roles
            .iter()
            .enumerate()
            .filter(|(_, r)| r.serves_prefill())
            .map(|(i, _)| i)
            .collect();
        let decode_members: Vec<usize> = roles
            .iter()
            .enumerate()
            .filter(|(_, r)| r.serves_decode())
            .map(|(i, _)| i)
            .collect();
        assert!(!prefill_members.is_empty(), "fleet has no prefill-capable replica");
        assert!(!decode_members.is_empty(), "fleet has no decode-capable replica");
        let prefill_pools = chunk_even(&prefill_members, k);
        let decode_pools = chunk_even(&decode_members, m.min(prefill_pools.len()));
        PoolTopology { prefill_members, decode_members, prefill_pools, decode_pools }
    }

    /// The classic single-pool partition (K = M = 1).
    pub fn from_roles(roles: &[ReplicaRole]) -> Self {
        Self::build(roles, 1, 1)
    }

    /// Which prefill pool `replica` belongs to (None if not prefill-capable).
    pub fn prefill_pool_of(&self, replica: usize) -> Option<usize> {
        self.prefill_pools.iter().position(|p| p.contains(&replica))
    }

    /// Which decode pool `replica` belongs to (None if not decode-capable).
    pub fn decode_pool_of(&self, replica: usize) -> Option<usize> {
        self.decode_pools.iter().position(|p| p.contains(&replica))
    }

    /// The decode pool that prefill pool `p` hands off to.
    pub fn paired_decode_pool(&self, p: usize) -> usize {
        p % self.decode_pools.len()
    }

    /// More than one pool on either side?
    pub fn is_multi_pool(&self) -> bool {
        self.prefill_pools.len() > 1 || self.decode_pools.len() > 1
    }
}

/// Deterministic flow → pool spreading for multi-pool admission (the
/// router's avalanche with a distinct salt, so the two hash levels don't
/// correlate but can never drift apart).
fn pool_of_flow(flow: crate::ids::FlowId, n_pools: usize) -> usize {
    (router::avalanche(flow.0 as u64 ^ 0xA5A5_D00D_F00D_5EED) % n_pools as u64) as usize
}

/// Per-replica serving state.
#[derive(Debug, Clone)]
pub struct Replica {
    pub plan: ParallelPlan,
    pub batcher: Batcher,
    pub kv: KvCache,
    pub colls: CollSeq,
    /// Whether an iteration is currently in flight (next one scheduled).
    pub busy: bool,
    pub iterations: u64,
    pub prefills: u64,
    pub decodes: u64,
}

/// The serving engine: the two-stage router pair (admission over the
/// prefill-capable pool, phase transition over the decode-capable pool) +
/// replicas + request registry. On a colocated fleet both pools are the full
/// replica set and only the admission router ever routes, reproducing the
/// classic single-stage plane exactly.
#[derive(Debug, Clone)]
pub struct Engine {
    pub cfg: EngineConfig,
    /// Admission router: new requests land on a prefill-capable replica.
    pub router: Router,
    /// Phase-transition router: completed prefills pick a decode-capable
    /// replica for the KV handoff. Idle on colocated fleets.
    pub decode_router: Router,
    pub replicas: Vec<Replica>,
    pub requests: HashMap<ReqId, InferenceRequest>,
    /// Which replica each request currently occupies (updated at the phase
    /// transition on disaggregated fleets).
    pub placement: HashMap<ReqId, usize>,
    /// Roles at construction time (heal/reset restores these after
    /// `RebalancePools` role shifts).
    base_roles: Vec<ReplicaRole>,
    /// Pool partition (admission + handoff pools) derived from the current
    /// roles and the configured pool counts.
    pools: PoolTopology,
    disaggregated: bool,
}

impl Engine {
    pub fn new(cfg: EngineConfig, plans: Vec<ParallelPlan>) -> Self {
        assert!(!plans.is_empty());
        let n = plans.len();
        let base_roles: Vec<ReplicaRole> = plans.iter().map(|p| p.shape.role).collect();
        let disaggregated = base_roles.iter().any(|&r| r != ReplicaRole::Colocated);
        let pools = PoolTopology::build(&base_roles, cfg.prefill_pools, cfg.decode_pools);
        let replicas = plans
            .into_iter()
            .map(|plan| Replica {
                plan,
                batcher: Batcher::new(cfg.policy.clone()),
                kv: KvCache::new(cfg.kv_pages, cfg.kv_page_tokens),
                colls: CollSeq::default(),
                busy: false,
                iterations: 0,
                prefills: 0,
                decodes: 0,
            })
            .collect();
        Engine {
            router: Router::with_members(n, cfg.route_policy, pools.prefill_members.clone()),
            decode_router: Router::with_members(
                n,
                cfg.decode_route_policy,
                pools.decode_members.clone(),
            ),
            cfg,
            replicas,
            requests: HashMap::new(),
            placement: HashMap::new(),
            base_roles,
            pools,
            disaggregated,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Does this fleet run separate prefill/decode pools? (Sticky: a world
    /// built disaggregated stays phase-split even if mitigation later makes
    /// a pool's membership look colocated.)
    pub fn is_disaggregated(&self) -> bool {
        self.disaggregated
    }

    /// Current role of each replica (post any mitigation role shifts).
    pub fn roles(&self) -> Vec<ReplicaRole> {
        self.replicas.iter().map(|r| r.plan.shape.role).collect()
    }

    /// Reassign a replica's pool role (the `RebalancePools` autoscaling
    /// primitive) and rebuild both routers' pool membership. In-flight work
    /// on the replica is unaffected; only *new* routing follows the role.
    pub fn shift_role(&mut self, replica: usize, role: ReplicaRole) {
        assert!(replica < self.n_replicas());
        self.replicas[replica].plan.shape.role = role;
        self.refresh_pools();
    }

    /// Restore construction-time roles (heal between experiments).
    pub fn reset_roles(&mut self) {
        for r in 0..self.replicas.len() {
            self.replicas[r].plan.shape.role = self.base_roles[r];
        }
        self.refresh_pools();
    }

    fn refresh_pools(&mut self) {
        let roles = self.roles();
        self.pools = PoolTopology::build(&roles, self.cfg.prefill_pools, self.cfg.decode_pools);
        self.router.set_members(self.pools.prefill_members.clone());
        self.decode_router.set_members(self.pools.decode_members.clone());
    }

    /// The current pool partition (kept in sync with role shifts).
    pub fn pools(&self) -> &PoolTopology {
        &self.pools
    }

    /// Which replica's plan owns `node` (victim-replica resolution for the
    /// DP injectors and the drain directive).
    pub fn replica_of_node(&self, node: crate::ids::NodeId) -> Option<usize> {
        self.replicas
            .iter()
            .position(|r| r.plan.stages.iter().any(|s| s.nodes.contains(&node)))
    }

    /// Register an arriving request and route it onto the prefill-capable
    /// pool. On a multi-pool plane the flow first hashes to an admission
    /// pool, then the router picks within it; single-pool fleets take the
    /// classic full-membership path bit for bit. Returns the replica index.
    pub fn register(&mut self, mut req: InferenceRequest) -> usize {
        // The registered copy is the one decode pushes tokens into; give it
        // full-budget capacity so the steady-state iteration never grows it
        // (clones don't inherit spare capacity from `InferenceRequest::new`).
        req.generated.reserve(req.max_new_tokens.saturating_sub(req.generated.len()));
        let r = if self.pools.prefill_pools.len() > 1 {
            let p = pool_of_flow(req.flow, self.pools.prefill_pools.len());
            self.router.route_in(req.flow, &self.pools.prefill_pools[p])
        } else {
            self.router.route(req.flow)
        };
        self.placement.insert(req.id, r);
        self.requests.insert(req.id, req);
        r
    }

    /// Phase transition: pick the decode-pool replica that will adopt this
    /// request's KV, and move its placement there. With multiple handoff
    /// pools the pick is confined to the decode pool paired with the
    /// request's prefill pool. The caller models the actual transfer.
    pub fn route_decode(&mut self, req: ReqId) -> usize {
        let flow = self.requests[&req].flow;
        let d = if self.pools.decode_pools.len() > 1 {
            let from = self.placement[&req];
            let p = self.pools.prefill_pool_of(from).unwrap_or(0);
            let pair = self.pools.paired_decode_pool(p);
            self.decode_router.route_in(flow, &self.pools.decode_pools[pair])
        } else {
            self.decode_router.route(flow)
        };
        self.placement.insert(req, d);
        d
    }

    pub fn request(&self, id: ReqId) -> &InferenceRequest {
        &self.requests[&id]
    }

    pub fn request_mut(&mut self, id: ReqId) -> &mut InferenceRequest {
        self.requests.get_mut(&id).expect("unknown request")
    }

    /// Total tokens generated so far across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.requests.values().map(|r| r.tokens_generated() as u64).sum()
    }

    /// Aggregate queue depth (Table 2(b) signal).
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|r| r.batcher.queue_depth()).sum()
    }

    /// Mean KV occupancy across replicas.
    pub fn kv_occupancy(&self) -> f64 {
        let n = self.replicas.len() as f64;
        self.replicas.iter().map(|r| r.kv.occupancy()).sum::<f64>() / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::ids::FlowId;
    use crate::sim::SimTime;

    fn engine() -> Engine {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        Engine::new(cfg, plans)
    }

    fn req(id: u32, flow: u32) -> InferenceRequest {
        InferenceRequest::new(ReqId(id), FlowId(flow), SimTime(0), vec![1, 2, 3, 4], 4)
    }

    #[test]
    fn register_routes_and_tracks() {
        let mut e = engine();
        let r = e.register(req(1, 5));
        assert!(r < e.n_replicas());
        assert_eq!(e.placement[&ReqId(1)], r);
        assert_eq!(e.request(ReqId(1)).flow, FlowId(5));
    }

    #[test]
    fn default_config_consistent_with_profile() {
        let e = engine();
        assert_eq!(e.cfg.policy.max_batch, e.cfg.profile.batch);
        assert_eq!(e.n_replicas(), 1); // 4 nodes / (pp2 * 2 nodes-per-stage)
    }

    #[test]
    fn queue_and_kv_signals_start_clean() {
        let e = engine();
        assert_eq!(e.queue_depth(), 0);
        assert_eq!(e.kv_occupancy(), 0.0);
        assert_eq!(e.total_tokens(), 0);
    }

    fn disagg_engine() -> Engine {
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = vec![
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Prefill, 8, 1),
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Decode, 4, 2),
            crate::cluster::ReplicaShape::new(crate::cluster::ReplicaRole::Decode, 4, 2),
        ];
        let mut cfg = EngineConfig::default();
        cfg.shapes = Some(shapes.clone());
        let plans = build_shaped_replicas(&spec, &shapes);
        Engine::new(cfg, plans)
    }

    #[test]
    fn colocated_engine_is_not_disaggregated() {
        let e = engine();
        assert!(!e.is_disaggregated());
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[0]);
    }

    #[test]
    fn two_stage_routing_respects_pools() {
        let mut e = disagg_engine();
        assert!(e.is_disaggregated());
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[1, 2]);
        let p = e.register(req(1, 5));
        assert_eq!(p, 0, "admission must land on the prefill pool");
        let d = e.route_decode(ReqId(1));
        assert!(d == 1 || d == 2, "transition must land on the decode pool");
        assert_eq!(e.placement[&ReqId(1)], d);
        // Accounting is split per stage.
        assert_eq!(e.router.outstanding()[0], 1);
        assert_eq!(e.decode_router.outstanding()[d], 1);
    }

    #[test]
    fn pool_topology_partitions_evenly_and_pairs() {
        use crate::cluster::ReplicaRole::*;
        // 2 prefill + 4 decode split into 2 admission / 1 handoff pools.
        let roles = vec![Prefill, Prefill, Decode, Decode, Decode, Decode];
        let t = PoolTopology::build(&roles, 2, 1);
        assert_eq!(t.prefill_pools, vec![vec![0], vec![1]]);
        assert_eq!(t.decode_pools, vec![vec![2, 3, 4, 5]]);
        assert_eq!(t.prefill_pool_of(1), Some(1));
        assert_eq!(t.prefill_pool_of(3), None);
        assert_eq!(t.decode_pool_of(5), Some(0));
        assert_eq!(t.paired_decode_pool(0), 0);
        assert_eq!(t.paired_decode_pool(1), 0);
        assert!(t.is_multi_pool());
        // Near-even decode split with M = 2 (K = 2 keeps every decode pool
        // reachable under the p % M pairing).
        let t2 = PoolTopology::build(&roles, 2, 2);
        assert_eq!(t2.decode_pools, vec![vec![2, 3], vec![4, 5]]);
        assert_eq!(t2.paired_decode_pool(0), 0);
        assert_eq!(t2.paired_decode_pool(1), 1);
        // M clamps to K: a decode pool no prefill pool maps to would be
        // permanently starved, so K = 1 merges the decode side into one.
        let merged = PoolTopology::build(&roles, 1, 2);
        assert_eq!(merged.decode_pools, vec![vec![2, 3, 4, 5]]);
        // Pool counts clamp to the member population.
        let t3 = PoolTopology::build(&roles, 5, 1);
        assert_eq!(t3.prefill_pools.len(), 2);
        assert!(t3.prefill_pools.iter().all(|p| !p.is_empty()));
        // The classic partition is the K = M = 1 case and is not multi-pool.
        let classic = PoolTopology::from_roles(&vec![Colocated; 3]);
        assert_eq!(classic.prefill_pools, vec![vec![0, 1, 2]]);
        assert_eq!(classic.decode_pools, vec![vec![0, 1, 2]]);
        assert!(!classic.is_multi_pool());
    }

    #[test]
    fn multi_pool_admission_confines_flows_to_their_pool() {
        // 4 colocated single-node replicas, 2 admission pools.
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 4;
        spec.pp_degree = 1;
        let mut cfg = EngineConfig::default();
        cfg.nodes_per_stage = 1;
        cfg.prefill_pools = 2;
        let plans = build_replicas(&spec, 1);
        let mut e = Engine::new(cfg, plans);
        assert_eq!(e.pools().prefill_pools, vec![vec![0, 1], vec![2, 3]]);
        // Every flow lands inside the pool its hash selects, and repeats
        // land on the same replica (affinity survives pooling).
        let mut first: HashMap<u32, usize> = HashMap::new();
        for round in 0..3u32 {
            for f in 0..64u32 {
                let req = InferenceRequest::new(
                    ReqId(round * 64 + f),
                    crate::ids::FlowId(f),
                    SimTime(0),
                    vec![1, 2, 3],
                    2,
                );
                let r = e.register(req);
                let p = pool_of_flow(crate::ids::FlowId(f), 2);
                assert!(e.pools().prefill_pools[p].contains(&r), "flow {f} escaped pool {p}");
                assert_eq!(*first.entry(f).or_insert(r), r, "affinity broken for flow {f}");
            }
        }
        // Both pools see traffic.
        let routed = e.router.routed_per_replica();
        assert!(routed[..2].iter().sum::<u64>() > 0);
        assert!(routed[2..].iter().sum::<u64>() > 0);
    }

    #[test]
    fn multi_pool_handoff_respects_pool_pairing() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        // 2 prefill + 4 decode single-node replicas; 2 admission pools,
        // 2 handoff pools: prefill pool p must hand off into decode pool p.
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        spec.pp_degree = 1;
        let shapes = vec![
            ReplicaShape::new(ReplicaRole::Prefill, 4, 1),
            ReplicaShape::new(ReplicaRole::Prefill, 4, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 1),
        ];
        let mut cfg = EngineConfig::default();
        cfg.shapes = Some(shapes.clone());
        cfg.prefill_pools = 2;
        cfg.decode_pools = 2;
        let plans = build_shaped_replicas(&spec, &shapes);
        let mut e = Engine::new(cfg, plans);
        assert_eq!(e.pools().decode_pools, vec![vec![2, 3], vec![4, 5]]);
        for f in 0..80u32 {
            let req =
                InferenceRequest::new(ReqId(f), crate::ids::FlowId(f), SimTime(0), vec![1], 4);
            let id = req.id;
            let pre = e.register(req);
            let p = e.pools().prefill_pool_of(pre).unwrap();
            let d = e.route_decode(id);
            let pair = e.pools().paired_decode_pool(p);
            assert!(
                e.pools().decode_pools[pair].contains(&d),
                "handoff from prefill pool {p} landed outside decode pool {pair}"
            );
        }
    }

    #[test]
    fn role_shift_moves_pool_membership_and_heals() {
        let mut e = disagg_engine();
        e.shift_role(2, crate::cluster::ReplicaRole::Prefill);
        assert_eq!(e.router.members(), &[0, 2]);
        assert_eq!(e.decode_router.members(), &[1]);
        assert!(e.is_disaggregated(), "role shifts don't collapse the phase split");
        e.reset_roles();
        assert_eq!(e.router.members(), &[0]);
        assert_eq!(e.decode_router.members(), &[1, 2]);
    }
}
