//! Paged KV-cache manager (vLLM-style): fixed-size pages, on-demand growth,
//! occupancy accounting, and allocation-failure signaling for admission.

use std::collections::HashMap;

use crate::ids::ReqId;

/// Outcome of a page allocation attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocResult {
    Ok,
    /// Not enough free pages; caller must queue, evict, or reject.
    OutOfPages,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    pages: u32,
    tokens: u32,
}

/// Paged allocator for one replica's KV memory.
#[derive(Debug, Clone)]
pub struct KvCache {
    total_pages: u32,
    /// The pool size the cache was built with; `total_pages` can fall below
    /// this while a DP2 restriction (leak/fragmentation injection) is live.
    configured_pages: u32,
    page_tokens: u32,
    free_pages: u32,
    /// Pages lost to an active leak (DP2): freed pages land here instead of
    /// returning to the free pool.
    leaked_pages: u32,
    leaking: bool,
    seqs: HashMap<ReqId, SeqAlloc>,
    /// Cumulative counters for metrics / Table 2(b) kv-occupancy signal.
    pub alloc_ops: u64,
    pub free_ops: u64,
    pub alloc_failures: u64,
}

impl KvCache {
    pub fn new(total_pages: u32, page_tokens: u32) -> Self {
        assert!(total_pages > 0 && page_tokens > 0);
        KvCache {
            total_pages,
            configured_pages: total_pages,
            page_tokens,
            free_pages: total_pages,
            leaked_pages: 0,
            leaking: false,
            seqs: HashMap::new(),
            alloc_ops: 0,
            free_ops: 0,
            alloc_failures: 0,
        }
    }

    /// Pages currently owned by live sequences.
    fn seq_used(&self) -> u32 {
        self.seqs.values().map(|s| s.pages).sum()
    }

    /// Capacity-restriction variant of the DP2 family: shrink the usable
    /// pool to `frac` of its configured size (never below what live
    /// sequences + leak already occupy, so accounting conserves). The stock
    /// DP2 injector uses the harder [`KvCache::start_leak`]; this knob
    /// models partial loss (e.g. a neighbor claiming HBM).
    pub fn restrict_to(&mut self, frac: f64) {
        let occupied = self.seq_used() + self.leaked_pages;
        let target =
            ((self.configured_pages as f64 * frac).ceil() as u32).max(1).max(occupied);
        self.total_pages = target;
        self.free_pages = target - occupied;
    }

    /// DP2 injector: start a hard allocator leak — every currently-free page
    /// is lost immediately and pages released by finishing sequences never
    /// return to the pool. Every subsequent admission/growth fails until
    /// [`KvCache::restore_capacity`] rebuilds the pool.
    pub fn start_leak(&mut self) {
        self.leaking = true;
        self.leaked_pages += self.free_pages;
        self.free_pages = 0;
    }

    /// Mitigation: rebuild the pool at configured capacity (clears any leak
    /// and restriction).
    pub fn restore_capacity(&mut self) {
        self.leaking = false;
        self.leaked_pages = 0;
        let used = self.seq_used();
        self.total_pages = self.configured_pages.max(used);
        self.free_pages = self.total_pages - used;
    }

    pub fn is_restricted(&self) -> bool {
        self.leaking || self.total_pages < self.configured_pages
    }

    fn pages_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.page_tokens)
    }

    /// Admit a sequence with `prompt_tokens` already known.
    pub fn admit(&mut self, req: ReqId, prompt_tokens: u32) -> AllocResult {
        debug_assert!(!self.seqs.contains_key(&req), "double admit {req}");
        let need = self.pages_for(prompt_tokens.max(1));
        if need > self.free_pages {
            self.alloc_failures += 1;
            return AllocResult::OutOfPages;
        }
        self.free_pages -= need;
        self.alloc_ops += 1;
        self.seqs.insert(req, SeqAlloc { pages: need, tokens: prompt_tokens.max(1) });
        AllocResult::Ok
    }

    /// Grow a sequence by one generated token; may allocate a page.
    ///
    /// "Allocate" here means pool accounting only: a [`SeqAlloc`] is a pair
    /// of `u32` counters, so this is pure arithmetic on the existing entry
    /// and the decode hot path (`coordinator::iterate`) can call it per
    /// lane per round without touching the heap.
    pub fn append_token(&mut self, req: ReqId) -> AllocResult {
        let Some(s) = self.seqs.get_mut(&req) else {
            debug_assert!(false, "append on unknown {req}");
            return AllocResult::OutOfPages;
        };
        s.tokens += 1;
        let need = s.tokens.div_ceil(self.page_tokens);
        if need > s.pages {
            if self.free_pages == 0 {
                s.tokens -= 1;
                self.alloc_failures += 1;
                return AllocResult::OutOfPages;
            }
            self.free_pages -= 1;
            s.pages += 1;
            self.alloc_ops += 1;
        }
        AllocResult::Ok
    }

    /// Release a finished (or evicted) sequence. Under an active leak the
    /// pages are lost instead of returning to the free pool.
    pub fn release(&mut self, req: ReqId) {
        if let Some(s) = self.seqs.remove(&req) {
            if self.leaking {
                self.leaked_pages += s.pages;
            } else {
                self.free_pages += s.pages;
            }
            self.free_ops += 1;
        }
    }

    pub fn can_admit(&self, prompt_tokens: u32) -> bool {
        self.pages_for(prompt_tokens.max(1)) <= self.free_pages
    }

    pub fn occupancy(&self) -> f64 {
        1.0 - self.free_pages as f64 / self.total_pages as f64
    }

    pub fn free_pages(&self) -> u32 {
        self.free_pages
    }

    pub fn total_pages(&self) -> u32 {
        self.total_pages
    }

    pub fn used_pages(&self) -> u32 {
        self.total_pages - self.free_pages
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn tokens_of(&self, req: ReqId) -> Option<u32> {
        self.seqs.get(&req).map(|s| s.tokens)
    }

    /// Invariant check used by property tests: page accounting conserves
    /// (live + free + leaked covers the pool exactly).
    pub fn check_conservation(&self) -> bool {
        self.seq_used() + self.free_pages + self.leaked_pages == self.total_pages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::prop_assert;

    #[test]
    fn admit_grow_release_conserves() {
        let mut kv = KvCache::new(16, 8);
        assert_eq!(kv.admit(ReqId(1), 10), AllocResult::Ok); // 2 pages
        assert_eq!(kv.used_pages(), 2);
        // grow within page
        for _ in 0..6 {
            assert_eq!(kv.append_token(ReqId(1)), AllocResult::Ok);
        }
        assert_eq!(kv.used_pages(), 2);
        // 17th token needs page 3
        assert_eq!(kv.append_token(ReqId(1)), AllocResult::Ok);
        assert_eq!(kv.used_pages(), 3);
        kv.release(ReqId(1));
        assert_eq!(kv.used_pages(), 0);
        assert!(kv.check_conservation());
    }

    #[test]
    fn out_of_pages_rejects_and_rolls_back() {
        let mut kv = KvCache::new(2, 4);
        assert_eq!(kv.admit(ReqId(1), 8), AllocResult::Ok); // uses both pages
        assert_eq!(kv.admit(ReqId(2), 1), AllocResult::OutOfPages);
        assert_eq!(kv.alloc_failures, 1);
        // growth failure rolls back the token count
        assert_eq!(kv.append_token(ReqId(1)), AllocResult::OutOfPages);
        assert_eq!(kv.tokens_of(ReqId(1)), Some(8));
        assert!(kv.check_conservation());
    }

    #[test]
    fn occupancy_tracks() {
        let mut kv = KvCache::new(10, 4);
        assert_eq!(kv.occupancy(), 0.0);
        kv.admit(ReqId(1), 20); // 5 pages
        assert!((kv.occupancy() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn prop_alloc_free_conservation() {
        check("kv-conservation", PropConfig::default().cases(48), |g| {
            let total = g.usize_in(4, 64) as u32;
            let page = g.usize_in(1, 16) as u32;
            let mut kv = KvCache::new(total, page);
            let mut live: Vec<ReqId> = Vec::new();
            let mut next = 0u32;
            for _ in 0..200 {
                let coin = g.rng.f64();
                if coin < 0.5 {
                    let toks = g.usize_in(1, 40) as u32;
                    let id = ReqId(next);
                    next += 1;
                    if kv.admit(id, toks) == AllocResult::Ok {
                        live.push(id);
                    }
                } else if coin < 0.8 && !live.is_empty() {
                    let idx = g.rng.index(live.len());
                    let _ = kv.append_token(live[idx]);
                } else if !live.is_empty() {
                    let idx = g.rng.index(live.len());
                    let id = live.swap_remove(idx);
                    kv.release(id);
                }
                prop_assert!(kv.check_conservation(), "conservation violated");
                prop_assert!(
                    kv.active_seqs() == live.len(),
                    "live mismatch {} vs {}",
                    kv.active_seqs(),
                    live.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn leak_starves_the_pool_until_restored() {
        let mut kv = KvCache::new(16, 4);
        assert_eq!(kv.admit(ReqId(1), 6), AllocResult::Ok); // 2 pages, 2 tokens slack
        kv.start_leak();
        assert!(kv.is_restricted());
        assert_eq!(kv.free_pages(), 0);
        assert!((kv.occupancy() - 1.0).abs() < 1e-12);
        assert!(kv.check_conservation());
        // New admissions and growth fail while the leak is live.
        assert_eq!(kv.admit(ReqId(2), 1), AllocResult::OutOfPages);
        for _ in 0..2 {
            kv.append_token(ReqId(1)); // within page 2
        }
        assert_eq!(kv.append_token(ReqId(1)), AllocResult::OutOfPages);
        // Freed pages leak instead of returning.
        kv.release(ReqId(1));
        assert_eq!(kv.free_pages(), 0);
        assert_eq!(kv.active_seqs(), 0);
        assert!(kv.check_conservation());
        // Restore rebuilds the configured pool.
        kv.restore_capacity();
        assert!(!kv.is_restricted());
        assert_eq!(kv.free_pages(), 16);
        assert_eq!(kv.admit(ReqId(3), 4), AllocResult::Ok);
        assert!(kv.check_conservation());
    }

    #[test]
    fn restrict_and_restore_conserve() {
        let mut kv = KvCache::new(100, 4);
        kv.admit(ReqId(1), 16); // 4 pages used
        kv.restrict_to(0.05); // 5 pages total
        assert!(kv.is_restricted());
        assert_eq!(kv.total_pages(), 5);
        assert_eq!(kv.free_pages(), 1);
        assert!(kv.check_conservation());
        assert!((kv.occupancy() - 0.8).abs() < 1e-9);
        // Restriction never truncates below live sequences.
        let mut kv2 = KvCache::new(100, 4);
        kv2.admit(ReqId(2), 64); // 16 pages
        kv2.restrict_to(0.05);
        assert_eq!(kv2.total_pages(), 16);
        assert_eq!(kv2.free_pages(), 0);
        assert!(kv2.check_conservation());
        kv2.restore_capacity();
        assert!(!kv2.is_restricted());
        assert_eq!(kv2.total_pages(), 100);
        assert_eq!(kv2.free_pages(), 84);
        assert!(kv2.check_conservation());
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut kv = KvCache::new(4, 4);
        kv.release(ReqId(99));
        assert!(kv.check_conservation());
        assert_eq!(kv.free_ops, 0);
    }
}
