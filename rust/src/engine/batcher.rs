//! Continuous batcher + admission queue for one replica.
//!
//! Implements the engine policies the paper's Table 2(a) survey contrasts:
//! continuous (vLLM-style) vs static batching, optional length bucketing,
//! and in-flight remapping of freed decode slots (the mitigation for
//! early-completion skew, NS8/PC10/EW9).
//!
//! The running set is stored as structure-of-arrays [`Lanes`]: parallel
//! `req`/`position`/`slot`/`last_token` columns plus an O(1) req→lane index,
//! so the per-iteration hot path (`coordinator::iterate`) reads positions,
//! KV slots, and last tokens as direct indexed slices instead of searching a
//! `Vec<RunningSeq>` per request. Lane order is admission order and every
//! mutation preserves it, which keeps decode-round iteration order — and
//! therefore every downstream event sequence — byte-identical to the old
//! AoS layout.

use std::collections::{HashMap, VecDeque};

use crate::ids::ReqId;
use crate::sim::SimTime;

/// Engine batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Decode slots (also the prefill batch cap).
    pub max_batch: usize,
    /// Continuous batching: admit new prefills while others decode.
    /// When false (static batching), a batch runs to full completion first.
    pub continuous: bool,
    /// Sort waiting requests by prompt length before forming prefill batches.
    pub length_bucketing: bool,
    /// Refill freed decode slots mid-flight (early-stop mitigation).
    pub inflight_remap: bool,
    /// Admission queue capacity (requests beyond this are rejected).
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            continuous: true,
            length_bucketing: true,
            inflight_remap: true,
            queue_cap: 512,
        }
    }
}

/// One prefill-completed sequence entering decode (input to
/// [`Batcher::start_decode`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeSpec {
    pub req: ReqId,
    /// KV position after prefill (== prompt length).
    pub prompt_len: u32,
    /// Output-token budget (`max_new_tokens`).
    pub budget: u32,
    /// The replica-local KV slot this sequence occupies.
    pub slot: usize,
}

/// Structure-of-arrays running set: one lane per in-flight decode sequence,
/// in admission order. All columns are index-parallel; `index` maps a
/// request id to its lane in O(1).
#[derive(Debug, Clone, Default)]
pub struct Lanes {
    req: Vec<ReqId>,
    /// Next KV slot to write (== tokens so far: prompt + generated).
    position: Vec<u32>,
    generated: Vec<u32>,
    budget: Vec<u32>,
    slot: Vec<usize>,
    /// Most recent token (the next decode step's input). 0 until the first
    /// `on_token`, which always precedes the first decode round.
    last_token: Vec<i32>,
    index: HashMap<ReqId, usize>,
}

impl Lanes {
    pub fn len(&self) -> usize {
        self.req.len()
    }

    pub fn is_empty(&self) -> bool {
        self.req.is_empty()
    }

    pub fn reqs(&self) -> &[ReqId] {
        &self.req
    }

    pub fn positions(&self) -> &[u32] {
        &self.position
    }

    pub fn slots(&self) -> &[usize] {
        &self.slot
    }

    pub fn last_tokens(&self) -> &[i32] {
        &self.last_token
    }

    /// O(1) lane lookup. A request missing from a decode round it is part
    /// of is a bookkeeping bug (see `coordinator::iterate`).
    pub fn lane_of(&self, req: ReqId) -> Option<usize> {
        self.index.get(&req).copied()
    }

    fn push(&mut self, req: ReqId, position: u32, generated: u32, budget: u32, slot: usize, last_token: i32) {
        let lane = self.req.len();
        self.req.push(req);
        self.position.push(position);
        self.generated.push(generated);
        self.budget.push(budget);
        self.slot.push(slot);
        self.last_token.push(last_token);
        let prev = self.index.insert(req, lane);
        debug_assert!(prev.is_none(), "request {req:?} already running");
    }

    /// Order-preserving removal: shift every later lane down one and
    /// reindex. O(B), matching the old `Vec::retain` exactly.
    fn remove(&mut self, lane: usize) {
        let req = self.req.remove(lane);
        self.position.remove(lane);
        self.generated.remove(lane);
        self.budget.remove(lane);
        self.slot.remove(lane);
        self.last_token.remove(lane);
        self.index.remove(&req);
        for j in lane..self.req.len() {
            self.index.insert(self.req[j], j);
        }
    }
}

/// What the executor should run next.
#[derive(Debug, Clone, PartialEq)]
pub enum Work {
    /// Prefill these queued requests (<= max_batch).
    Prefill(Vec<ReqId>),
    /// One decode step over the current running set (read it straight off
    /// [`Batcher::lanes`] — the round is the lane slice, not a copied list).
    DecodeRound,
    /// Nothing to do.
    Idle,
}

#[derive(Debug, Clone)]
struct Waiting {
    req: ReqId,
    prompt_len: u32,
    enqueued: SimTime,
}

/// Per-replica batcher state.
#[derive(Debug, Clone)]
pub struct Batcher {
    policy: BatchPolicy,
    waiting: VecDeque<Waiting>,
    lanes: Lanes,
    /// Static-batching latch: set while a batch is draining.
    draining: bool,
    pub rejected: u64,
    pub admitted: u64,
    /// Peak queue depth (Table 2(b) signal).
    pub peak_queue: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            waiting: VecDeque::new(),
            lanes: Lanes::default(),
            draining: false,
            rejected: 0,
            admitted: 0,
            peak_queue: 0,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn policy_mut(&mut self) -> &mut BatchPolicy {
        &mut self.policy
    }

    /// Try to enqueue an arrived request. Returns false if rejected.
    pub fn enqueue(&mut self, req: ReqId, prompt_len: u32, now: SimTime) -> bool {
        if self.waiting.len() >= self.policy.queue_cap {
            self.rejected += 1;
            return false;
        }
        self.waiting.push_back(Waiting { req, prompt_len, enqueued: now });
        self.peak_queue = self.peak_queue.max(self.waiting.len());
        self.admitted += 1;
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting.len()
    }

    /// The running set as SoA lanes (admission order).
    pub fn lanes(&self) -> &Lanes {
        &self.lanes
    }

    pub fn free_slots(&self) -> usize {
        self.policy.max_batch.saturating_sub(self.lanes.len())
    }

    /// Oldest enqueue time in the waiting queue (admission-wait signal).
    pub fn oldest_wait(&self, now: SimTime) -> Option<crate::sim::SimDur> {
        self.waiting.front().map(|w| now - w.enqueued)
    }

    /// Decide the next unit of work.
    pub fn next_work(&mut self) -> Work {
        let can_prefill = if self.policy.continuous {
            // Continuous: prefill whenever there are free slots, but avoid
            // starving decode: require either an empty running set or at
            // least one fully free slot.
            self.free_slots() > 0 && !self.waiting.is_empty()
        } else {
            // Static: only start a new batch when the previous fully drained.
            !self.draining && self.lanes.is_empty() && !self.waiting.is_empty()
        };

        if can_prefill {
            let n = self.free_slots().min(self.waiting.len());
            let picked = self.pick_waiting(n);
            if !picked.is_empty() {
                if !self.policy.continuous {
                    self.draining = true;
                }
                return Work::Prefill(picked);
            }
        }
        if !self.lanes.is_empty() {
            return Work::DecodeRound;
        }
        self.draining = false;
        Work::Idle
    }

    fn pick_waiting(&mut self, n: usize) -> Vec<ReqId> {
        if self.policy.length_bucketing && self.waiting.len() > 1 {
            // Group similar lengths: pick the n with the smallest spread by
            // sorting a snapshot of the queue by length, taking the best
            // contiguous run (FIFO-fair tiebreak: earliest enqueue first).
            let mut snapshot: Vec<(u32, usize)> = self
                .waiting
                .iter()
                .enumerate()
                .map(|(i, w)| (w.prompt_len, i))
                .collect();
            snapshot.sort();
            let mut best_start = 0;
            let mut best_spread = u32::MAX;
            for s in 0..snapshot.len().saturating_sub(n - 1) {
                let spread = snapshot[s + n - 1].0 - snapshot[s].0;
                if spread < best_spread {
                    best_spread = spread;
                    best_start = s;
                }
            }
            let mut idxs: Vec<usize> =
                snapshot[best_start..best_start + n].iter().map(|&(_, i)| i).collect();
            idxs.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
            let mut out = Vec::with_capacity(n);
            for i in idxs {
                out.push(self.waiting.remove(i).unwrap().req);
            }
            out.reverse();
            out
        } else {
            (0..n).filter_map(|_| self.waiting.pop_front().map(|w| w.req)).collect()
        }
    }

    /// Prefill finished: move requests into decode lanes.
    pub fn start_decode(&mut self, specs: &[DecodeSpec]) {
        for s in specs {
            debug_assert!(self.lanes.len() < self.policy.max_batch);
            self.lanes.push(s.req, s.prompt_len, 0, s.budget, s.slot, 0);
        }
    }

    /// Adopt a sequence arriving from another pool's prefill via KV handoff:
    /// it enters decode directly, with `generated` tokens (the prefill-side
    /// first token, `last_token`) already produced and its KV position past
    /// the prompt.
    pub fn adopt(
        &mut self,
        req: ReqId,
        position: u32,
        generated: u32,
        budget: u32,
        slot: usize,
        last_token: i32,
    ) {
        debug_assert!(self.lanes.len() < self.policy.max_batch, "adopt into full batch");
        self.lanes.push(req, position, generated, budget, slot, last_token);
    }

    /// Record one generated token for `req`; returns true if it finished.
    /// An untracked request is a bookkeeping bug (decode rounds only ever
    /// contain running lanes), asserted in debug builds.
    pub fn on_token(&mut self, req: ReqId, token: i32) -> bool {
        let Some(lane) = self.lanes.lane_of(req) else {
            debug_assert!(false, "on_token for untracked request {req:?}");
            return false;
        };
        self.lanes.generated[lane] += 1;
        self.lanes.position[lane] += 1;
        self.lanes.last_token[lane] = token;
        self.lanes.generated[lane] >= self.lanes.budget[lane]
    }

    /// Remove a finished sequence; returns whether its slot can be refilled
    /// immediately (in-flight remap policy).
    pub fn finish(&mut self, req: ReqId) -> bool {
        if let Some(lane) = self.lanes.lane_of(req) {
            self.lanes.remove(lane);
        }
        if self.lanes.is_empty() {
            self.draining = false;
        }
        self.policy.inflight_remap
    }

    /// Without in-flight remap, a freed slot stays empty until the whole
    /// batch drains — this helper says whether prefill may refill now.
    pub fn may_refill(&self) -> bool {
        if self.policy.inflight_remap {
            true
        } else {
            self.lanes.is_empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    fn rid(i: u32) -> ReqId {
        ReqId(i)
    }

    fn spec(i: u32, prompt_len: u32, budget: u32) -> DecodeSpec {
        DecodeSpec { req: rid(i), prompt_len, budget, slot: i as usize }
    }

    #[test]
    fn continuous_prefers_prefill_when_slots_free() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.enqueue(rid(1), 16, SimTime(0));
        b.enqueue(rid(2), 16, SimTime(0));
        match b.next_work() {
            Work::Prefill(v) => assert_eq!(v.len(), 2),
            w => panic!("expected prefill, got {w:?}"),
        }
        b.start_decode(&[spec(1, 16, 4), spec(2, 16, 4)]);
        assert_eq!(b.free_slots(), 2);
        // No waiting -> decode round over the lane slice.
        assert_eq!(b.next_work(), Work::DecodeRound);
        assert_eq!(b.lanes().len(), 2);
    }

    #[test]
    fn static_batching_waits_for_drain() {
        let mut pol = BatchPolicy::default();
        pol.continuous = false;
        pol.max_batch = 2;
        let mut b = Batcher::new(pol);
        b.enqueue(rid(1), 8, SimTime(0));
        b.enqueue(rid(2), 8, SimTime(0));
        b.enqueue(rid(3), 8, SimTime(0));
        let Work::Prefill(v) = b.next_work() else { panic!() };
        assert_eq!(v.len(), 2);
        b.start_decode(&[spec(1, 8, 2), spec(2, 8, 2)]);
        // Even though a request waits, static policy decodes the batch.
        assert!(matches!(b.next_work(), Work::DecodeRound));
        b.finish(rid(1));
        assert!(matches!(b.next_work(), Work::DecodeRound));
        b.finish(rid(2));
        // Drained: now the next batch may start.
        assert!(matches!(b.next_work(), Work::Prefill(_)));
    }

    #[test]
    fn length_bucketing_groups_similar() {
        let mut pol = BatchPolicy::default();
        pol.max_batch = 2;
        let mut b = Batcher::new(pol);
        b.enqueue(rid(1), 100, SimTime(0));
        b.enqueue(rid(2), 8, SimTime(0));
        b.enqueue(rid(3), 96, SimTime(0));
        b.enqueue(rid(4), 10, SimTime(0));
        let Work::Prefill(v) = b.next_work() else { panic!() };
        // Best contiguous pair by length is {8,10}.
        assert!(v.contains(&rid(2)) && v.contains(&rid(4)), "picked {v:?}");
    }

    #[test]
    fn queue_cap_rejects() {
        let mut pol = BatchPolicy::default();
        pol.queue_cap = 2;
        let mut b = Batcher::new(pol);
        assert!(b.enqueue(rid(1), 4, SimTime(0)));
        assert!(b.enqueue(rid(2), 4, SimTime(0)));
        assert!(!b.enqueue(rid(3), 4, SimTime(0)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn token_and_finish_lifecycle() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.start_decode(&[spec(1, 8, 2)]);
        assert!(!b.on_token(rid(1), 42));
        assert_eq!(b.lanes().last_tokens(), &[42]);
        assert_eq!(b.lanes().positions(), &[9]);
        assert!(b.on_token(rid(1), 43)); // budget reached
        assert!(b.finish(rid(1)));
        assert!(b.lanes().is_empty());
        assert_eq!(b.lanes().lane_of(rid(1)), None);
    }

    #[test]
    fn no_remap_blocks_refill_until_drain() {
        let mut pol = BatchPolicy::default();
        pol.inflight_remap = false;
        let mut b = Batcher::new(pol);
        b.start_decode(&[spec(1, 8, 4), spec(2, 8, 4)]);
        b.finish(rid(1));
        assert!(!b.may_refill());
        b.finish(rid(2));
        assert!(b.may_refill());
    }

    #[test]
    fn lane_removal_preserves_order_and_reindexes() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.start_decode(&[spec(1, 8, 4), spec(2, 9, 4), spec(3, 10, 4), spec(4, 11, 4)]);
        b.finish(rid(2));
        assert_eq!(b.lanes().reqs(), &[rid(1), rid(3), rid(4)]);
        assert_eq!(b.lanes().positions(), &[8, 10, 11]);
        assert_eq!(b.lanes().slots(), &[1, 3, 4]);
        assert_eq!(b.lanes().lane_of(rid(3)), Some(1));
        assert_eq!(b.lanes().lane_of(rid(4)), Some(2));
        assert_eq!(b.lanes().lane_of(rid(2)), None);
    }

    #[test]
    fn adopted_lane_carries_slot_and_last_token() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.adopt(rid(7), 33, 1, 8, 3, 1234);
        assert_eq!(b.lanes().reqs(), &[rid(7)]);
        assert_eq!(b.lanes().positions(), &[33]);
        assert_eq!(b.lanes().slots(), &[3]);
        assert_eq!(b.lanes().last_tokens(), &[1234]);
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        check("batcher-conservation", PropConfig::default().cases(40), |g| {
            let mut pol = BatchPolicy::default();
            pol.max_batch = g.usize_in(1, 6);
            pol.queue_cap = 64;
            pol.continuous = g.bool();
            pol.length_bucketing = g.bool();
            let mut b = Batcher::new(pol);
            let mut next = 0u32;
            let mut in_queue = 0usize;
            let mut seen_prefill: std::collections::HashSet<u32> = Default::default();
            for _ in 0..200 {
                if g.rng.chance(0.5) {
                    let id = next;
                    next += 1;
                    if b.enqueue(rid(id), g.usize_in(1, 64) as u32, SimTime(0)) {
                        in_queue += 1;
                    }
                }
                match b.next_work() {
                    Work::Prefill(v) => {
                        prop_assert!(v.len() <= b.policy().max_batch, "prefill too big");
                        for r in &v {
                            prop_assert!(seen_prefill.insert(r.0), "req {r} prefilled twice");
                        }
                        in_queue -= v.len();
                        let specs: Vec<DecodeSpec> = v
                            .iter()
                            .enumerate()
                            .map(|(i, r)| DecodeSpec {
                                req: *r,
                                prompt_len: 8,
                                budget: 2,
                                slot: i,
                            })
                            .collect();
                        b.start_decode(&specs);
                    }
                    Work::DecodeRound => {
                        prop_assert!(!b.lanes().is_empty(), "empty decode round");
                        let round: Vec<ReqId> = b.lanes().reqs().to_vec();
                        for r in round {
                            prop_assert!(
                                b.lanes().lane_of(r).is_some(),
                                "round member {r} untracked"
                            );
                            if b.on_token(r, r.0 as i32) {
                                b.finish(r);
                            }
                        }
                    }
                    Work::Idle => {}
                }
                prop_assert!(
                    b.queue_depth() == in_queue,
                    "queue depth {} != tracked {}",
                    b.queue_depth(),
                    in_queue
                );
                prop_assert!(
                    b.lanes().len() <= b.policy().max_batch,
                    "running overflow"
                );
            }
            Ok(())
        });
    }
}
