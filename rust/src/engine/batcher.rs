//! Continuous batcher + admission queue for one replica.
//!
//! Implements the engine policies the paper's Table 2(a) survey contrasts:
//! continuous (vLLM-style) vs static batching, optional length bucketing,
//! and in-flight remapping of freed decode slots (the mitigation for
//! early-completion skew, NS8/PC10/EW9).

use std::collections::VecDeque;

use crate::ids::ReqId;
use crate::sim::SimTime;

/// Engine batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Decode slots (also the prefill batch cap).
    pub max_batch: usize,
    /// Continuous batching: admit new prefills while others decode.
    /// When false (static batching), a batch runs to full completion first.
    pub continuous: bool,
    /// Sort waiting requests by prompt length before forming prefill batches.
    pub length_bucketing: bool,
    /// Refill freed decode slots mid-flight (early-stop mitigation).
    pub inflight_remap: bool,
    /// Admission queue capacity (requests beyond this are rejected).
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            continuous: true,
            length_bucketing: true,
            inflight_remap: true,
            queue_cap: 512,
        }
    }
}

/// A sequence occupying a decode slot.
#[derive(Debug, Clone)]
pub struct RunningSeq {
    pub req: ReqId,
    /// Next KV slot to write (== tokens so far: prompt + generated).
    pub position: u32,
    pub generated: u32,
    pub budget: u32,
}

impl RunningSeq {
    pub fn remaining(&self) -> u32 {
        self.budget.saturating_sub(self.generated)
    }
}

/// What the executor should run next.
#[derive(Debug, Clone, PartialEq)]
pub enum Work {
    /// Prefill these queued requests (<= max_batch).
    Prefill(Vec<ReqId>),
    /// One decode step over the current running set.
    DecodeRound(Vec<ReqId>),
    /// Nothing to do.
    Idle,
}

#[derive(Debug, Clone)]
struct Waiting {
    req: ReqId,
    prompt_len: u32,
    enqueued: SimTime,
}

/// Per-replica batcher state.
#[derive(Debug, Clone)]
pub struct Batcher {
    policy: BatchPolicy,
    waiting: VecDeque<Waiting>,
    running: Vec<RunningSeq>,
    /// Static-batching latch: set while a batch is draining.
    draining: bool,
    pub rejected: u64,
    pub admitted: u64,
    /// Peak queue depth (Table 2(b) signal).
    pub peak_queue: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            waiting: VecDeque::new(),
            running: Vec::new(),
            draining: false,
            rejected: 0,
            admitted: 0,
            peak_queue: 0,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn policy_mut(&mut self) -> &mut BatchPolicy {
        &mut self.policy
    }

    /// Try to enqueue an arrived request. Returns false if rejected.
    pub fn enqueue(&mut self, req: ReqId, prompt_len: u32, now: SimTime) -> bool {
        if self.waiting.len() >= self.policy.queue_cap {
            self.rejected += 1;
            return false;
        }
        self.waiting.push_back(Waiting { req, prompt_len, enqueued: now });
        self.peak_queue = self.peak_queue.max(self.waiting.len());
        self.admitted += 1;
        true
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> &[RunningSeq] {
        &self.running
    }

    pub fn running_mut(&mut self) -> &mut [RunningSeq] {
        &mut self.running
    }

    pub fn free_slots(&self) -> usize {
        self.policy.max_batch.saturating_sub(self.running.len())
    }

    /// Oldest enqueue time in the waiting queue (admission-wait signal).
    pub fn oldest_wait(&self, now: SimTime) -> Option<crate::sim::SimDur> {
        self.waiting.front().map(|w| now - w.enqueued)
    }

    /// Decide the next unit of work.
    pub fn next_work(&mut self) -> Work {
        let can_prefill = if self.policy.continuous {
            // Continuous: prefill whenever there are free slots, but avoid
            // starving decode: require either an empty running set or at
            // least one fully free slot.
            self.free_slots() > 0 && !self.waiting.is_empty()
        } else {
            // Static: only start a new batch when the previous fully drained.
            !self.draining && self.running.is_empty() && !self.waiting.is_empty()
        };

        if can_prefill {
            let n = self.free_slots().min(self.waiting.len());
            let picked = self.pick_waiting(n);
            if !picked.is_empty() {
                if !self.policy.continuous {
                    self.draining = true;
                }
                return Work::Prefill(picked);
            }
        }
        if !self.running.is_empty() {
            return Work::DecodeRound(self.running.iter().map(|r| r.req).collect());
        }
        self.draining = false;
        Work::Idle
    }

    fn pick_waiting(&mut self, n: usize) -> Vec<ReqId> {
        if self.policy.length_bucketing && self.waiting.len() > 1 {
            // Group similar lengths: pick the n with the smallest spread by
            // sorting a snapshot of the queue by length, taking the best
            // contiguous run (FIFO-fair tiebreak: earliest enqueue first).
            let mut snapshot: Vec<(u32, usize)> = self
                .waiting
                .iter()
                .enumerate()
                .map(|(i, w)| (w.prompt_len, i))
                .collect();
            snapshot.sort();
            let mut best_start = 0;
            let mut best_spread = u32::MAX;
            for s in 0..snapshot.len().saturating_sub(n - 1) {
                let spread = snapshot[s + n - 1].0 - snapshot[s].0;
                if spread < best_spread {
                    best_spread = spread;
                    best_start = s;
                }
            }
            let mut idxs: Vec<usize> =
                snapshot[best_start..best_start + n].iter().map(|&(_, i)| i).collect();
            idxs.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
            let mut out = Vec::with_capacity(n);
            for i in idxs {
                out.push(self.waiting.remove(i).unwrap().req);
            }
            out.reverse();
            out
        } else {
            (0..n).filter_map(|_| self.waiting.pop_front().map(|w| w.req)).collect()
        }
    }

    /// Prefill finished: move requests into decode slots.
    pub fn start_decode(&mut self, reqs: &[(ReqId, u32 /*prompt_len*/, u32 /*budget*/)]) {
        for &(req, prompt_len, budget) in reqs {
            debug_assert!(self.running.len() < self.policy.max_batch);
            self.running.push(RunningSeq { req, position: prompt_len, generated: 0, budget });
        }
    }

    /// Adopt a sequence arriving from another pool's prefill via KV handoff:
    /// it enters decode directly, with `generated` tokens (the prefill-side
    /// first token) already produced and its KV position past the prompt.
    pub fn adopt(&mut self, req: ReqId, position: u32, generated: u32, budget: u32) {
        debug_assert!(self.running.len() < self.policy.max_batch, "adopt into full batch");
        self.running.push(RunningSeq { req, position, generated, budget });
    }

    /// Record one generated token for `req`; returns true if it finished.
    pub fn on_token(&mut self, req: ReqId) -> bool {
        let Some(seq) = self.running.iter_mut().find(|s| s.req == req) else {
            return false;
        };
        seq.generated += 1;
        seq.position += 1;
        seq.generated >= seq.budget
    }

    /// Remove a finished sequence; returns whether its slot can be refilled
    /// immediately (in-flight remap policy).
    pub fn finish(&mut self, req: ReqId) -> bool {
        self.running.retain(|s| s.req != req);
        if self.running.is_empty() {
            self.draining = false;
        }
        self.policy.inflight_remap
    }

    /// Without in-flight remap, a freed slot stays empty until the whole
    /// batch drains — this helper says whether prefill may refill now.
    pub fn may_refill(&self) -> bool {
        if self.policy.inflight_remap {
            true
        } else {
            self.running.is_empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    fn rid(i: u32) -> ReqId {
        ReqId(i)
    }

    #[test]
    fn continuous_prefers_prefill_when_slots_free() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.enqueue(rid(1), 16, SimTime(0));
        b.enqueue(rid(2), 16, SimTime(0));
        match b.next_work() {
            Work::Prefill(v) => assert_eq!(v.len(), 2),
            w => panic!("expected prefill, got {w:?}"),
        }
        b.start_decode(&[(rid(1), 16, 4), (rid(2), 16, 4)]);
        assert_eq!(b.free_slots(), 2);
        // No waiting -> decode round
        match b.next_work() {
            Work::DecodeRound(v) => assert_eq!(v.len(), 2),
            w => panic!("expected decode, got {w:?}"),
        }
    }

    #[test]
    fn static_batching_waits_for_drain() {
        let mut pol = BatchPolicy::default();
        pol.continuous = false;
        pol.max_batch = 2;
        let mut b = Batcher::new(pol);
        b.enqueue(rid(1), 8, SimTime(0));
        b.enqueue(rid(2), 8, SimTime(0));
        b.enqueue(rid(3), 8, SimTime(0));
        let Work::Prefill(v) = b.next_work() else { panic!() };
        assert_eq!(v.len(), 2);
        b.start_decode(&[(rid(1), 8, 2), (rid(2), 8, 2)]);
        // Even though a request waits, static policy decodes the batch.
        assert!(matches!(b.next_work(), Work::DecodeRound(_)));
        b.finish(rid(1));
        assert!(matches!(b.next_work(), Work::DecodeRound(_)));
        b.finish(rid(2));
        // Drained: now the next batch may start.
        assert!(matches!(b.next_work(), Work::Prefill(_)));
    }

    #[test]
    fn length_bucketing_groups_similar() {
        let mut pol = BatchPolicy::default();
        pol.max_batch = 2;
        let mut b = Batcher::new(pol);
        b.enqueue(rid(1), 100, SimTime(0));
        b.enqueue(rid(2), 8, SimTime(0));
        b.enqueue(rid(3), 96, SimTime(0));
        b.enqueue(rid(4), 10, SimTime(0));
        let Work::Prefill(v) = b.next_work() else { panic!() };
        // Best contiguous pair by length is {8,10}.
        assert!(v.contains(&rid(2)) && v.contains(&rid(4)), "picked {v:?}");
    }

    #[test]
    fn queue_cap_rejects() {
        let mut pol = BatchPolicy::default();
        pol.queue_cap = 2;
        let mut b = Batcher::new(pol);
        assert!(b.enqueue(rid(1), 4, SimTime(0)));
        assert!(b.enqueue(rid(2), 4, SimTime(0)));
        assert!(!b.enqueue(rid(3), 4, SimTime(0)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn token_and_finish_lifecycle() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.start_decode(&[(rid(1), 8, 2)]);
        assert!(!b.on_token(rid(1)));
        assert!(b.on_token(rid(1))); // budget reached
        assert!(b.finish(rid(1)));
        assert!(b.running().is_empty());
    }

    #[test]
    fn no_remap_blocks_refill_until_drain() {
        let mut pol = BatchPolicy::default();
        pol.inflight_remap = false;
        let mut b = Batcher::new(pol);
        b.start_decode(&[(rid(1), 8, 4), (rid(2), 8, 4)]);
        b.finish(rid(1));
        assert!(!b.may_refill());
        b.finish(rid(2));
        assert!(b.may_refill());
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        check("batcher-conservation", PropConfig::default().cases(40), |g| {
            let mut pol = BatchPolicy::default();
            pol.max_batch = g.usize_in(1, 6);
            pol.queue_cap = 64;
            pol.continuous = g.bool();
            pol.length_bucketing = g.bool();
            let mut b = Batcher::new(pol);
            let mut next = 0u32;
            let mut in_queue = 0usize;
            let mut seen_prefill: std::collections::HashSet<u32> = Default::default();
            for _ in 0..200 {
                if g.rng.chance(0.5) {
                    let id = next;
                    next += 1;
                    if b.enqueue(rid(id), g.usize_in(1, 64) as u32, SimTime(0)) {
                        in_queue += 1;
                    }
                }
                match b.next_work() {
                    Work::Prefill(v) => {
                        prop_assert!(v.len() <= b.policy().max_batch, "prefill too big");
                        for r in &v {
                            prop_assert!(seen_prefill.insert(r.0), "req {r} prefilled twice");
                        }
                        in_queue -= v.len();
                        let specs: Vec<_> = v.iter().map(|r| (*r, 8u32, 2u32)).collect();
                        b.start_decode(&specs);
                    }
                    Work::DecodeRound(v) => {
                        prop_assert!(!v.is_empty(), "empty decode round");
                        for r in v {
                            if b.on_token(r) {
                                b.finish(r);
                            }
                        }
                    }
                    Work::Idle => {}
                }
                prop_assert!(
                    b.queue_depth() == in_queue,
                    "queue depth {} != tracked {}",
                    b.queue_depth(),
                    in_queue
                );
                prop_assert!(
                    b.running().len() <= b.policy().max_batch,
                    "running overflow"
                );
            }
            Ok(())
        });
    }
}
