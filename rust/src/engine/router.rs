//! Request router: session-affinity flow hashing across replicas, with load
//! accounting and the rebalance hooks the mitigation controller uses
//! (NS2/NS3 directives: "balance load balancer hashing", "rebalance RPC
//! streams").

use std::collections::HashMap;

use crate::ids::FlowId;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Pure hash(flow) -> replica: session affinity, skew-prone.
    FlowHash,
    /// Least-loaded replica (by outstanding requests), ignores affinity.
    LeastLoaded,
    /// Flow hash, but flows the mitigation controller remapped go to their
    /// override replica.
    HashWithOverrides,
}

#[derive(Debug)]
pub struct Router {
    n_replicas: usize,
    policy: RoutePolicy,
    overrides: HashMap<FlowId, usize>,
    outstanding: Vec<i64>,
    pub routed: u64,
}

impl Router {
    pub fn new(n_replicas: usize, policy: RoutePolicy) -> Self {
        assert!(n_replicas > 0);
        Router {
            n_replicas,
            policy,
            overrides: HashMap::new(),
            outstanding: vec![0; n_replicas],
            routed: 0,
        }
    }

    fn hash_flow(&self, flow: FlowId) -> usize {
        // splitmix-style avalanche so consecutive flow ids spread.
        let mut x = flow.0 as u64 + 0x9E3779B97F4A7C15;
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
        (x ^ (x >> 31)) as usize % self.n_replicas
    }

    /// Route a request's flow to a replica index.
    pub fn route(&mut self, flow: FlowId) -> usize {
        self.routed += 1;
        let r = match self.policy {
            RoutePolicy::FlowHash => self.hash_flow(flow),
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                for i in 1..self.n_replicas {
                    if self.outstanding[i] < self.outstanding[best] {
                        best = i;
                    }
                }
                best
            }
            RoutePolicy::HashWithOverrides => self
                .overrides
                .get(&flow)
                .copied()
                .unwrap_or_else(|| self.hash_flow(flow)),
        };
        self.outstanding[r] += 1;
        r
    }

    /// A request finished on replica `r` (load accounting).
    pub fn complete(&mut self, r: usize) {
        self.outstanding[r] -= 1;
        debug_assert!(self.outstanding[r] >= 0);
    }

    /// Mitigation hook: steer a flow to a specific replica.
    pub fn set_override(&mut self, flow: FlowId, replica: usize) {
        assert!(replica < self.n_replicas);
        self.overrides.insert(flow, replica);
    }

    pub fn clear_overrides(&mut self) {
        self.overrides.clear();
    }

    pub fn set_policy(&mut self, p: RoutePolicy) {
        self.policy = p;
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn outstanding(&self) -> &[i64] {
        &self.outstanding
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn same_flow_same_replica() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        let a = r.route(FlowId(42));
        for _ in 0..10 {
            assert_eq!(r.route(FlowId(42)), a);
        }
    }

    #[test]
    fn hash_spreads_flows() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        let mut counts = [0u32; 4];
        for f in 0..4000u32 {
            counts[r.route(FlowId(f))] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn least_loaded_balances_exactly() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        for f in 0..9u32 {
            r.route(FlowId(f));
        }
        assert_eq!(r.outstanding(), &[3, 3, 3]);
    }

    #[test]
    fn overrides_steer() {
        let mut r = Router::new(4, RoutePolicy::HashWithOverrides);
        let natural = r.route(FlowId(7));
        r.complete(natural);
        let target = (natural + 1) % 4;
        r.set_override(FlowId(7), target);
        assert_eq!(r.route(FlowId(7)), target);
    }

    #[test]
    fn prop_affinity_and_load_accounting() {
        check("router-invariants", PropConfig::default().cases(48), |g| {
            let n = g.usize_in(1, 8);
            let mut r = Router::new(n, RoutePolicy::FlowHash);
            let mut first: std::collections::HashMap<u32, usize> = Default::default();
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..300 {
                if g.rng.chance(0.7) || live.is_empty() {
                    let f = g.rng.below(64) as u32;
                    let got = r.route(FlowId(f));
                    prop_assert!(got < n, "replica {got} out of range {n}");
                    let prev = *first.entry(f).or_insert(got);
                    prop_assert!(prev == got, "affinity broken for flow {f}");
                    live.push(got);
                } else {
                    let idx = g.rng.index(live.len());
                    r.complete(live.swap_remove(idx));
                }
                let total: i64 = r.outstanding().iter().sum();
                prop_assert!(
                    total == live.len() as i64,
                    "outstanding {total} != live {}",
                    live.len()
                );
                prop_assert!(r.outstanding().iter().all(|&x| x >= 0), "negative load");
            }
            Ok(())
        });
    }
}
