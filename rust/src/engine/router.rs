//! Request routing across data-parallel replicas — the serving-plane layer
//! where fleet-scale imbalance is made or broken. Policies range from the
//! skew-prone session-affinity hash to telemetry-weighted balancing; the
//! mitigation controller uses the override/drain hooks (NS2/NS3 "rebalance
//! flows" and the DP1-DP3 data-parallel directives).

use std::collections::HashMap;

use crate::ids::FlowId;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Pure hash(flow) -> replica: session affinity, skew-prone.
    FlowHash,
    /// Strict rotation, ignoring affinity and load.
    RoundRobin,
    /// Least-loaded replica (by outstanding requests), ignores affinity.
    LeastLoaded,
    /// Power-of-two-choices: two hash candidates per flow, route to the
    /// less-loaded of the pair (bounded imbalance at hash-level cost).
    PowerOfTwo,
    /// Weighted by per-replica telemetry (queue depth + KV occupancy) plus
    /// outstanding load — what a DPU-fed load balancer can do.
    WeightedTelemetry,
    /// Flow hash, but flows the mitigation controller remapped go to their
    /// override replica. (Overrides actually take precedence under every
    /// policy; this variant exists as the explicit mitigated-hash mode.)
    HashWithOverrides,
}

/// The fleet-sweep policy set (excludes the mitigation-internal
/// `HashWithOverrides` mode, which is hash + steering, not a new strategy).
pub const ALL_POLICIES: [RoutePolicy; 5] = [
    RoutePolicy::FlowHash,
    RoutePolicy::RoundRobin,
    RoutePolicy::LeastLoaded,
    RoutePolicy::PowerOfTwo,
    RoutePolicy::WeightedTelemetry,
];

impl RoutePolicy {
    /// Stable identifier for CLI flags, tables, and JSON.
    pub fn id(&self) -> &'static str {
        match self {
            RoutePolicy::FlowHash => "flow-hash",
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PowerOfTwo => "po2",
            RoutePolicy::WeightedTelemetry => "weighted",
            RoutePolicy::HashWithOverrides => "hash-overrides",
        }
    }

    pub fn from_id(id: &str) -> Option<RoutePolicy> {
        ALL_POLICIES
            .into_iter()
            .chain([RoutePolicy::HashWithOverrides])
            .find(|p| p.id() == id)
    }
}

/// Splitmix-style avalanche so consecutive flow ids spread — shared by the
/// full-membership and pool-scoped hash paths, and by the engine's
/// flow-to-pool hash (same mix, different salt, so the two levels stay in
/// the same hash family without correlating).
pub(crate) fn avalanche(x: u64) -> u64 {
    let mut x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Score weights for [`RoutePolicy::WeightedTelemetry`]: queue depth counts
/// requests, KV occupancy is 0..1 (scaled up so a near-full cache outweighs
/// a short queue), outstanding load breaks ties within a window.
const QUEUE_WEIGHT: f64 = 1.0;
const KV_WEIGHT: f64 = 64.0;
const OUTSTANDING_WEIGHT: f64 = 0.5;

#[derive(Debug, Clone)]
pub struct Router {
    n_replicas: usize,
    /// The routable subset (pool membership): every pick lands on a member.
    /// Full membership (`0..n_replicas`) reproduces the classic single-pool
    /// router bit for bit; a phase-disaggregated engine runs one router over
    /// the prefill pool and one over the decode pool, both indexing the same
    /// global replica space.
    members: Vec<usize>,
    policy: RoutePolicy,
    overrides: HashMap<FlowId, usize>,
    /// Pathology hook (PD3): wedge every pick onto one replica. Overrides
    /// still win (mitigation outranks the fault), policies are bypassed.
    pin: Option<usize>,
    outstanding: Vec<i64>,
    routed_per_replica: Vec<u64>,
    /// Replicas taken out of rotation (DP3 straggler drain).
    drained: Vec<bool>,
    /// Last window's per-replica telemetry (queue depth, KV occupancy).
    telemetry_queue: Vec<f64>,
    telemetry_kv: Vec<f64>,
    /// Round-robin cursors, one per candidate set, keyed by the set's first
    /// member (pools of a partition are disjoint, so `allowed[0]` uniquely
    /// identifies a pool; the full membership keys `members[0]`). A shared
    /// cursor would degenerate under interleaved pool picks — alternating
    /// pools of equal size would pin each pool to one replica.
    rr_cursors: Vec<usize>,
    /// Telemetry-degradation ladder level, driven by the DPU freshness
    /// watchdog. Only the [`RoutePolicy::WeightedTelemetry`] pick consults
    /// it — the other policies never trusted the telemetry feed in the
    /// first place. 0 = full telemetry-weighted score (the byte-identical
    /// default), 1 = drop the KV term (occupancy rots fastest under stale
    /// feeds), 2 = outstanding-count only (least-loaded on router-local
    /// truth), 3 = round-robin (trust nothing but the rotation).
    degraded: u8,
    pub routed: u64,
}

impl Router {
    pub fn new(n_replicas: usize, policy: RoutePolicy) -> Self {
        Self::with_members(n_replicas, policy, (0..n_replicas).collect())
    }

    /// Router over a pool: picks are restricted to `members` (sorted, unique
    /// global replica indices). Load accounting stays globally indexed.
    pub fn with_members(n_replicas: usize, policy: RoutePolicy, members: Vec<usize>) -> Self {
        assert!(n_replicas > 0);
        assert!(!members.is_empty(), "router needs at least one member");
        assert!(members.windows(2).all(|w| w[0] < w[1]), "members must be sorted unique");
        assert!(*members.last().unwrap() < n_replicas, "member out of range");
        Router {
            n_replicas,
            members,
            policy,
            overrides: HashMap::new(),
            pin: None,
            outstanding: vec![0; n_replicas],
            routed_per_replica: vec![0; n_replicas],
            drained: vec![false; n_replicas],
            telemetry_queue: vec![0.0; n_replicas],
            telemetry_kv: vec![0.0; n_replicas],
            rr_cursors: vec![0; n_replicas],
            degraded: 0,
            routed: 0,
        }
    }

    fn hash_flow(&self, flow: FlowId, salt: u64) -> usize {
        self.members[avalanche(flow.0 as u64 ^ salt) as usize % self.members.len()]
    }

    /// The two hash candidates a flow has under power-of-two-choices
    /// (exposed for the property tests).
    pub fn po2_candidates(&self, flow: FlowId) -> (usize, usize) {
        (self.hash_flow(flow, 0), self.hash_flow(flow, 0x51F7_A2C9))
    }

    /// Route a request's flow to a replica index (over the full membership).
    pub fn route(&mut self, flow: FlowId) -> usize {
        // One pick path serves both the classic and the pool-scoped routes:
        // take the member table out for the pick (pick_in never reads it),
        // so the full membership IS just the widest candidate set.
        let members = std::mem::take(&mut self.members);
        let r = self.route_in_inner(flow, &members);
        self.members = members;
        r
    }

    /// Route confined to `allowed` (a pool of the router's membership) —
    /// the multi-pool plane's per-pick scoping. A full-pool `allowed` is
    /// exactly the classic [`Router::route`]. Scoped picks honor overrides
    /// and the pin only when their target sits inside the pool (pool
    /// confinement outranks steering into another pool), and skip drained
    /// replicas exactly like the classic path.
    pub fn route_in(&mut self, flow: FlowId, allowed: &[usize]) -> usize {
        debug_assert!(
            allowed.iter().all(|&r| self.is_member(r)),
            "pool {allowed:?} not a subset of members {:?}",
            self.members
        );
        self.route_in_inner(flow, allowed)
    }

    fn route_in_inner(&mut self, flow: FlowId, allowed: &[usize]) -> usize {
        self.routed += 1;
        let r = self.pick_in(flow, allowed);
        self.outstanding[r] += 1;
        self.routed_per_replica[r] += 1;
        r
    }

    /// Argmin of `key` over non-drained entries of `allowed` (lowest index
    /// wins ties). Non-finite keys (a NaN/inf telemetry gauge must never
    /// poison the comparison chain) are treated as +inf, so a replica with
    /// a degenerate score is picked only when every candidate's score is
    /// degenerate — and then the lowest index wins, deterministically.
    /// When everything in the pool is drained, falls back to least-loaded
    /// over the whole pool (drains included) rather than a silent
    /// first-entry pick: the caller still gets the sanest replica.
    fn argmin_live_in(&self, allowed: &[usize], key: impl Fn(usize) -> f64) -> usize {
        let mut best: Option<(usize, f64)> = None;
        for &i in allowed {
            if self.drained[i] {
                continue;
            }
            let raw = key(i);
            let k = if raw.is_finite() { raw } else { f64::INFINITY };
            match best {
                Some((_, bk)) if bk <= k => {}
                _ => best = Some((i, k)),
            }
        }
        match best {
            Some((i, _)) => i,
            None => {
                // Every candidate is drained: least-loaded beats allowed[0].
                let mut fb = allowed[0];
                for &i in &allowed[1..] {
                    if self.outstanding[i] < self.outstanding[fb] {
                        fb = i;
                    }
                }
                fb
            }
        }
    }

    fn hash_in(&self, flow: FlowId, salt: u64, allowed: &[usize]) -> usize {
        allowed[(avalanche(flow.0 as u64 ^ salt) % allowed.len() as u64) as usize]
    }

    fn redirect_if_drained_in(&self, r: usize, allowed: &[usize]) -> usize {
        if self.drained[r] {
            self.argmin_live_in(allowed, |i| self.outstanding[i] as f64)
        } else {
            r
        }
    }

    /// The single pick path: policy semantics over an explicit candidate
    /// set (the full membership for classic routes, one pool for scoped
    /// ones). Overrides take precedence under every policy, the PD3 pin
    /// bypasses policy (but not overrides or drains) — both only when
    /// their target sits inside the candidate set.
    fn pick_in(&mut self, flow: FlowId, allowed: &[usize]) -> usize {
        assert!(!allowed.is_empty(), "route over an empty candidate set");
        if let Some(&r) = self.overrides.get(&flow) {
            if allowed.contains(&r) {
                return r;
            }
        }
        if let Some(p) = self.pin {
            if allowed.contains(&p) {
                return self.redirect_if_drained_in(p, allowed);
            }
        }
        match self.policy {
            RoutePolicy::FlowHash | RoutePolicy::HashWithOverrides => {
                let r = self.hash_in(flow, 0, allowed);
                self.redirect_if_drained_in(r, allowed)
            }
            RoutePolicy::RoundRobin => self.pick_round_robin_in(allowed),
            RoutePolicy::LeastLoaded => {
                self.argmin_live_in(allowed, |i| self.outstanding[i] as f64)
            }
            RoutePolicy::PowerOfTwo => {
                let (a, b) =
                    (self.hash_in(flow, 0, allowed), self.hash_in(flow, 0x51F7_A2C9, allowed));
                let r = match (self.drained[a], self.drained[b]) {
                    (true, false) => b,
                    (false, true) => a,
                    _ => {
                        if self.outstanding[b] < self.outstanding[a] {
                            b
                        } else if self.outstanding[a] < self.outstanding[b] {
                            a
                        } else {
                            a.min(b)
                        }
                    }
                };
                self.redirect_if_drained_in(r, allowed)
            }
            RoutePolicy::WeightedTelemetry => self.pick_weighted_in(allowed),
        }
    }

    /// Per-pool round-robin (keyed by the set's first member): each pool
    /// rotates independently of interleaved picks on its siblings. Shared
    /// by the [`RoutePolicy::RoundRobin`] policy and ladder level 3, so a
    /// fully degraded weighted router rotates bit-identically to the real
    /// round-robin policy.
    fn pick_round_robin_in(&mut self, allowed: &[usize]) -> usize {
        let m = allowed.len();
        let mut k = self.rr_cursors[allowed[0]] % m;
        for _ in 0..m {
            if !self.drained[allowed[k]] {
                break;
            }
            k = (k + 1) % m;
        }
        self.rr_cursors[allowed[0]] = (k + 1) % m;
        allowed[k]
    }

    /// The [`RoutePolicy::WeightedTelemetry`] pick under the staged
    /// fallback ladder. Each level strips one more telemetry-derived term
    /// from the score, in rot order: the KV gauge goes first (a stale
    /// occupancy reading is the most misleading term — a replica that
    /// filled its cache after the freeze looks permanently empty), then
    /// the queue gauge, leaving router-local outstanding counts, and
    /// finally even those give way to a blind rotation.
    fn pick_weighted_in(&mut self, allowed: &[usize]) -> usize {
        match self.degraded {
            0 => self.argmin_live_in(allowed, |i| {
                self.telemetry_queue[i] * QUEUE_WEIGHT
                    + self.telemetry_kv[i] * KV_WEIGHT
                    + self.outstanding[i] as f64 * OUTSTANDING_WEIGHT
            }),
            1 => self.argmin_live_in(allowed, |i| {
                self.telemetry_queue[i] * QUEUE_WEIGHT
                    + self.outstanding[i] as f64 * OUTSTANDING_WEIGHT
            }),
            2 => self.argmin_live_in(allowed, |i| self.outstanding[i] as f64),
            _ => self.pick_round_robin_in(allowed),
        }
    }

    /// A request finished on replica `r` (load accounting).
    pub fn complete(&mut self, r: usize) {
        self.outstanding[r] -= 1;
        debug_assert!(self.outstanding[r] >= 0);
    }

    /// Mitigation hook: steer a flow to a specific replica.
    pub fn set_override(&mut self, flow: FlowId, replica: usize) {
        assert!(self.is_member(replica), "override target {replica} not in pool");
        self.overrides.insert(flow, replica);
    }

    pub fn clear_overrides(&mut self) {
        self.overrides.clear();
    }

    /// Pathology hook (PD3): wedge all picks onto `replica` / release it.
    pub fn set_pin(&mut self, pin: Option<usize>) {
        if let Some(p) = pin {
            assert!(self.is_member(p), "pin target {p} not in pool");
        }
        self.pin = pin;
    }

    pub fn pin(&self) -> Option<usize> {
        self.pin
    }

    /// Replace the pool membership (role shifts move replicas between
    /// pools). Load accounting is globally indexed and carries over; a pin
    /// or override pointing outside the new pool is dropped.
    pub fn set_members(&mut self, members: Vec<usize>) {
        assert!(!members.is_empty(), "router needs at least one member");
        assert!(members.windows(2).all(|w| w[0] < w[1]), "members must be sorted unique");
        assert!(*members.last().unwrap() < self.n_replicas, "member out of range");
        self.members = members;
        if let Some(p) = self.pin {
            if !self.is_member(p) {
                self.pin = None;
            }
        }
        let members = &self.members;
        self.overrides.retain(|_, r| members.binary_search(r).is_ok());
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub fn is_member(&self, replica: usize) -> bool {
        self.members.binary_search(&replica).is_ok()
    }

    /// Mitigation hook (DP3): take a replica out of / back into rotation.
    pub fn set_drained(&mut self, replica: usize, drained: bool) {
        assert!(replica < self.n_replicas);
        self.drained[replica] = drained;
    }

    pub fn is_drained(&self, replica: usize) -> bool {
        self.drained[replica]
    }

    pub fn clear_drained(&mut self) {
        self.drained.iter_mut().for_each(|d| *d = false);
    }

    /// Telemetry feed (window-tick granularity) for the weighted policy.
    pub fn update_telemetry(&mut self, replica: usize, queue_depth: f64, kv_occupancy: f64) {
        self.telemetry_queue[replica] = queue_depth;
        self.telemetry_kv[replica] = kv_occupancy;
    }

    /// Watchdog feed: set the telemetry-degradation ladder level (clamped
    /// to 3 = round-robin). Level 0 restores the full weighted score; only
    /// [`RoutePolicy::WeightedTelemetry`] picks are affected.
    pub fn set_degraded_level(&mut self, level: u8) {
        self.degraded = level.min(3);
    }

    pub fn degraded_level(&self) -> u8 {
        self.degraded
    }

    pub fn set_policy(&mut self, p: RoutePolicy) {
        self.policy = p;
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn outstanding(&self) -> &[i64] {
        &self.outstanding
    }

    /// Cumulative arrivals routed to each replica (DP1 skew signal).
    pub fn routed_per_replica(&self) -> &[u64] {
        &self.routed_per_replica
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn same_flow_same_replica() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        let a = r.route(FlowId(42));
        for _ in 0..10 {
            assert_eq!(r.route(FlowId(42)), a);
        }
    }

    #[test]
    fn hash_spreads_flows() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        let mut counts = [0u32; 4];
        for f in 0..4000u32 {
            counts[r.route(FlowId(f))] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn least_loaded_balances_exactly() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        for f in 0..9u32 {
            r.route(FlowId(f));
        }
        assert_eq!(r.outstanding(), &[3, 3, 3]);
    }

    #[test]
    fn round_robin_rotates() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6u32).map(|f| r.route(FlowId(f))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn overrides_steer() {
        let mut r = Router::new(4, RoutePolicy::HashWithOverrides);
        let natural = r.route(FlowId(7));
        r.complete(natural);
        let target = (natural + 1) % 4;
        r.set_override(FlowId(7), target);
        assert_eq!(r.route(FlowId(7)), target);
    }

    #[test]
    fn drained_replica_is_avoided() {
        let mut r = Router::new(2, RoutePolicy::FlowHash);
        let natural = r.route(FlowId(9));
        r.complete(natural);
        r.set_drained(natural, true);
        assert_eq!(r.route(FlowId(9)), 1 - natural, "drained replica still routed");
        r.clear_drained();
        assert_eq!(r.route(FlowId(9)), natural);
    }

    #[test]
    fn weighted_telemetry_avoids_hot_kv() {
        let mut r = Router::new(3, RoutePolicy::WeightedTelemetry);
        r.update_telemetry(0, 0.0, 0.99); // KV-exhausted
        r.update_telemetry(1, 2.0, 0.10);
        r.update_telemetry(2, 40.0, 0.10); // deep queue
        assert_eq!(r.route(FlowId(1)), 1);
    }

    #[test]
    fn weighted_nan_gauge_never_poisons_the_pick() {
        // A non-finite telemetry gauge (degenerate input from a rotted or
        // absent feed) must lose to every finite score, and an all-NaN
        // field must still pick deterministically (lowest index).
        let mut r = Router::new(3, RoutePolicy::WeightedTelemetry);
        r.update_telemetry(0, 5.0, 0.1);
        r.update_telemetry(1, 0.0, f64::NAN);
        r.update_telemetry(2, 1.0, 0.1);
        assert_eq!(r.route(FlowId(1)), 2, "NaN gauge captured the pick");
        let mut all_bad = Router::new(3, RoutePolicy::WeightedTelemetry);
        for i in 0..3 {
            all_bad.update_telemetry(i, f64::NAN, f64::NAN);
        }
        assert_eq!(all_bad.route(FlowId(1)), 0, "all-NaN pick not deterministic");
    }

    #[test]
    fn all_drained_pool_falls_back_to_least_loaded() {
        // When every candidate is drained the router must still answer —
        // with the least-loaded replica, not a silent first-entry pick.
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        for f in 0..5u32 {
            r.route(FlowId(f)); // outstanding ends [2, 2, 1]
        }
        assert_eq!(r.outstanding(), &[2, 2, 1]);
        for i in 0..3 {
            r.set_drained(i, true);
        }
        assert_eq!(r.route(FlowId(9)), 2, "all-drained fallback ignored load");
    }

    #[test]
    fn ladder_levels_strip_telemetry_terms() {
        // Level 1 drops the KV term: a replica whose only liability is a
        // (possibly rotted) KV gauge becomes eligible again.
        let mut r = Router::new(2, RoutePolicy::WeightedTelemetry);
        r.update_telemetry(0, 0.0, 0.99);
        r.update_telemetry(1, 5.0, 0.0);
        assert_eq!(r.route(FlowId(1)), 1, "level 0 must weigh KV");
        r.complete(1);
        r.set_degraded_level(1);
        assert_eq!(r.route(FlowId(2)), 0, "level 1 must ignore KV");
        r.complete(0);

        // Level 2 drops the queue gauge too: outstanding-only least-loaded.
        let mut r = Router::new(2, RoutePolicy::WeightedTelemetry);
        r.update_telemetry(0, 50.0, 0.9);
        r.set_degraded_level(2);
        assert_eq!(r.route(FlowId(1)), 0, "level 2 must ignore all telemetry");
        for f in 2..5u32 {
            r.route(FlowId(f));
        }
        assert_eq!(r.outstanding(), &[2, 2], "level 2 is least-loaded");

        // Level 3 is a blind rotation, whatever the gauges say.
        let mut r = Router::new(3, RoutePolicy::WeightedTelemetry);
        r.update_telemetry(1, 1000.0, 1.0);
        r.set_degraded_level(3);
        let picks: Vec<usize> = (0..6u32).map(|f| r.route(FlowId(f))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);

        // The level clamps at 3 and level 0 restores the full score.
        r.set_degraded_level(9);
        assert_eq!(r.degraded_level(), 3);
        r.set_degraded_level(0);
        r.update_telemetry(0, 0.0, 0.99);
        r.update_telemetry(1, 2.0, 0.1);
        r.update_telemetry(2, 40.0, 0.1);
        let got = r.route(FlowId(99));
        assert_eq!(got, 1, "level 0 must restore the weighted score");
    }

    #[test]
    fn ladder_only_affects_weighted_policy() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        let natural = r.route(FlowId(7));
        r.complete(natural);
        r.set_degraded_level(3);
        assert_eq!(r.route(FlowId(7)), natural, "ladder must not touch hashing");
    }

    #[test]
    fn policy_ids_roundtrip() {
        for p in ALL_POLICIES {
            assert_eq!(RoutePolicy::from_id(p.id()), Some(p));
        }
        assert_eq!(RoutePolicy::from_id("hash-overrides"), Some(RoutePolicy::HashWithOverrides));
        assert_eq!(RoutePolicy::from_id("nope"), None);
    }

    #[test]
    fn pool_router_only_picks_members() {
        for policy in ALL_POLICIES {
            let mut r = Router::with_members(5, policy, vec![1, 3, 4]);
            for f in 0..200u32 {
                let got = r.route(FlowId(f));
                assert!(r.is_member(got), "{policy:?} picked non-member {got}");
            }
            assert_eq!(r.outstanding()[0], 0);
            assert_eq!(r.outstanding()[2], 0);
        }
    }

    #[test]
    fn pin_wedges_all_picks_until_cleared() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        r.set_pin(Some(2));
        for f in 0..20u32 {
            assert_eq!(r.route(FlowId(f)), 2);
        }
        // Overrides outrank the pin (mitigation beats the fault)...
        r.set_override(FlowId(99), 0);
        assert_eq!(r.route(FlowId(99)), 0);
        // ...and draining the pinned replica redirects deterministically.
        r.set_drained(2, true);
        assert_ne!(r.route(FlowId(7)), 2);
        r.set_drained(2, false);
        r.set_pin(None);
        let mut seen = std::collections::HashSet::new();
        for f in 0..30u32 {
            seen.insert(r.route(FlowId(f)));
        }
        assert!(seen.len() > 1, "pin not released");
    }

    #[test]
    fn route_in_confines_picks_and_keeps_accounting() {
        for policy in ALL_POLICIES {
            let mut r = Router::new(6, policy);
            let (pool_a, pool_b): (&[usize], &[usize]) = (&[0, 1, 2], &[3, 4, 5]);
            for f in 0..200u32 {
                let pool = if f % 2 == 0 { pool_a } else { pool_b };
                let got = r.route_in(FlowId(f), pool);
                assert!(pool.contains(&got), "{policy:?} escaped pool: {got}");
            }
            let per_replica: u64 = r.routed_per_replica().iter().sum();
            assert_eq!(per_replica, r.routed);
            assert_eq!(r.outstanding().iter().sum::<i64>(), 200);
        }
    }

    #[test]
    fn route_in_full_pool_matches_classic_route() {
        for policy in ALL_POLICIES {
            let mut classic = Router::new(4, policy);
            let mut scoped = Router::new(4, policy);
            for f in 0..300u32 {
                assert_eq!(
                    classic.route(FlowId(f)),
                    scoped.route_in(FlowId(f), &[0, 1, 2, 3]),
                    "{policy:?} diverged"
                );
            }
        }
    }

    #[test]
    fn route_in_round_robin_rotates_per_pool() {
        // Interleaved picks across sibling pools must not collapse either
        // pool's rotation (a shared cursor would pin each pool to one
        // member under alternation).
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        let (a, b): (&[usize], &[usize]) = (&[0, 1], &[2, 3]);
        let mut picks_a = Vec::new();
        let mut picks_b = Vec::new();
        for f in 0..8u32 {
            picks_a.push(r.route_in(FlowId(f), a));
            picks_b.push(r.route_in(FlowId(f), b));
        }
        assert_eq!(picks_a, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        assert_eq!(picks_b, vec![2, 3, 2, 3, 2, 3, 2, 3]);
    }

    #[test]
    fn route_in_ignores_out_of_pool_pin_and_override() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        r.set_pin(Some(0));
        r.set_override(FlowId(7), 1);
        // Pool {2, 3}: neither the pin (0) nor the override (1) may pull a
        // pick out of the pool.
        for f in [7u32, 8, 9] {
            let got = r.route_in(FlowId(f), &[2, 3]);
            assert!(got == 2 || got == 3, "escaped pool: {got}");
        }
        // In-pool pin and override still win.
        assert_eq!(r.route_in(FlowId(3), &[0, 2]), 0, "in-pool pin ignored");
        assert_eq!(r.route_in(FlowId(7), &[1, 3]), 1, "in-pool override ignored");
    }

    #[test]
    fn route_in_skips_drained_replicas() {
        let mut r = Router::new(4, RoutePolicy::FlowHash);
        r.set_drained(2, true);
        for f in 0..60u32 {
            assert_eq!(r.route_in(FlowId(f), &[2, 3]), 3);
        }
    }

    #[test]
    fn set_members_drops_out_of_pool_pins_and_overrides() {
        let mut r = Router::with_members(4, RoutePolicy::FlowHash, vec![0, 1, 2, 3]);
        r.set_pin(Some(3));
        r.set_override(FlowId(5), 2);
        r.set_members(vec![0, 1, 2]);
        assert_eq!(r.pin(), None);
        assert_eq!(r.route(FlowId(5)), 2, "in-pool override survives");
        r.set_members(vec![0, 1]);
        assert!(r.route(FlowId(5)) < 2, "out-of-pool override dropped");
    }

    #[test]
    fn full_membership_matches_classic_hashing() {
        // Router::new must reproduce the pre-pool arithmetic exactly: the
        // member table is the identity, so hash % members.len() == hash % n.
        let mut classic = Router::new(4, RoutePolicy::FlowHash);
        let mut pooled = Router::with_members(4, RoutePolicy::FlowHash, vec![0, 1, 2, 3]);
        for f in 0..500u32 {
            assert_eq!(classic.route(FlowId(f)), pooled.route(FlowId(f)));
        }
    }

    #[test]
    fn prop_affinity_and_load_accounting() {
        check("router-invariants", PropConfig::default().cases(48), |g| {
            let n = g.usize_in(1, 8);
            let mut r = Router::new(n, RoutePolicy::FlowHash);
            let mut first: std::collections::HashMap<u32, usize> = Default::default();
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..300 {
                if g.rng.chance(0.7) || live.is_empty() {
                    let f = g.rng.below(64) as u32;
                    let got = r.route(FlowId(f));
                    prop_assert!(got < n, "replica {got} out of range {n}");
                    let prev = *first.entry(f).or_insert(got);
                    prop_assert!(prev == got, "affinity broken for flow {f}");
                    live.push(got);
                } else {
                    let idx = g.rng.index(live.len());
                    r.complete(live.swap_remove(idx));
                }
                let total: i64 = r.outstanding().iter().sum();
                prop_assert!(
                    total == live.len() as i64,
                    "outstanding {total} != live {}",
                    live.len()
                );
                prop_assert!(r.outstanding().iter().all(|&x| x >= 0), "negative load");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_no_request_loss_any_policy() {
        // Every routed request lands on exactly one in-range replica, and the
        // router's counters conserve: routed == sum(routed_per_replica) and
        // sum(outstanding) == live requests — under every policy, with
        // adversarial flow-id streams (hot single flow / tiny id space).
        check("router-no-loss", PropConfig::default().cases(48), |g| {
            let n = g.usize_in(1, 8);
            let policy = *g.rng.choose(&ALL_POLICIES);
            let mut r = Router::new(n, policy);
            let hot = g.rng.below(8) as u32;
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..300 {
                if g.rng.chance(0.7) || live.is_empty() {
                    // Adversarial stream: mostly one hot flow id.
                    let f = if g.rng.chance(0.6) { hot } else { g.rng.below(4) as u32 };
                    let got = r.route(FlowId(f));
                    prop_assert!(got < n, "replica {got} out of range {n}");
                    live.push(got);
                } else {
                    let idx = g.rng.index(live.len());
                    r.complete(live.swap_remove(idx));
                }
                let per_replica: u64 = r.routed_per_replica().iter().sum();
                prop_assert!(
                    per_replica == r.routed,
                    "routed {} != per-replica sum {per_replica} ({policy:?})",
                    r.routed
                );
                let total: i64 = r.outstanding().iter().sum();
                prop_assert!(
                    total == live.len() as i64,
                    "outstanding {total} != live {} ({policy:?})",
                    live.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_override_precedence_any_policy() {
        // A mitigation override must win under every policy, regardless of
        // load state or interleaved traffic.
        check("router-override-precedence", PropConfig::default().cases(48), |g| {
            let n = g.usize_in(2, 8);
            let policy = *g.rng.choose(&ALL_POLICIES);
            let mut r = Router::new(n, policy);
            let steered = FlowId(5);
            let target = g.rng.index(n);
            r.set_override(steered, target);
            for _ in 0..200 {
                if g.rng.chance(0.5) {
                    let got = r.route(steered);
                    prop_assert!(
                        got == target,
                        "override ignored: {got} != {target} ({policy:?})"
                    );
                    r.complete(got);
                } else {
                    let f = FlowId(g.rng.below(32) as u32 + 100);
                    let got = r.route(f);
                    prop_assert!(got < n, "out of range");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_balanced_policies_bound_outstanding_load() {
        // Least-loaded keeps max-min <= 1 with no completions; po2 keeps the
        // max within a small factor of the mean, and never routes to the
        // heavier of a flow's two candidates.
        check("router-load-bound", PropConfig::default().cases(48), |g| {
            let n = g.usize_in(2, 6);
            let routes = 300usize;
            // Least-loaded: perfectly bounded spread.
            let mut ll = Router::new(n, RoutePolicy::LeastLoaded);
            for _ in 0..routes {
                ll.route(FlowId(g.rng.below(64) as u32));
            }
            let max = *ll.outstanding().iter().max().unwrap();
            let min = *ll.outstanding().iter().min().unwrap();
            prop_assert!(max - min <= 1, "least-loaded spread {max}-{min}");

            // Power-of-two: the pick is never the strictly-heavier candidate,
            // and the max stays within a generous factor of the mean.
            let mut p2 = Router::new(n, RoutePolicy::PowerOfTwo);
            for _ in 0..routes {
                let f = FlowId(g.rng.below(64) as u32);
                let (a, b) = p2.po2_candidates(f);
                let (la, lb) = (p2.outstanding()[a], p2.outstanding()[b]);
                let got = p2.route(f);
                if got == a {
                    prop_assert!(la <= lb, "po2 chose heavier candidate a");
                } else if got == b {
                    prop_assert!(lb <= la, "po2 chose heavier candidate b");
                }
            }
            let max = *p2.outstanding().iter().max().unwrap() as f64;
            let mean = routes as f64 / n as f64;
            prop_assert!(
                max <= 2.0 * mean + 8.0,
                "po2 max {max} vs mean {mean} (n={n})"
            );
            Ok(())
        });
    }
}
