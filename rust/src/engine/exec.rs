//! Iteration executor: expands one engine iteration (prefill batch or decode
//! round) into its full causal hardware chain over the simulated cluster —
//! H2D feeds → doorbells → sharded compute → intra-node NVLink reduce →
//! cross-node TP allreduce → PP handoff (+KV streaming) → D2H logits.
//!
//! Every step emits the telemetry a DPU (or software observer) would see.
//! Token *content* is produced by a [`ComputeBackend`]: either the real
//! PJRT-compiled transformer (`runtime::model`) or a fast surrogate sampler.
//!
//! The hot entry point is [`run_iteration_in`], which threads an
//! [`ExecScratch`] arena through the stage walk so a steady-state iteration
//! allocates nothing; [`run_iteration`] is the allocating convenience
//! wrapper (tests, one-shot callers) returning an owned [`IterTiming`].

use crate::cluster::{Cluster, Outbox};
use crate::engine::parallel::ParallelPlan;
use crate::engine::profile::ModelProfile;
use crate::ids::{CollId, NodeId, ReqId};
use crate::sim::SimTime;
use crate::telemetry::event::{CollKind, Phase, TelemetryKind};

/// Produces actual next tokens for sequences. Implemented by the PJRT
/// runtime (real model) and by [`SurrogateBackend`] (hash sampler).
pub trait ComputeBackend {
    /// Prefill the prompts into the given batch slots; returns the first
    /// generated token per sequence (same order as `slots`). Prompts are
    /// borrowed slices — completing a prefill must not clone token buffers.
    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]]) -> Vec<i32>;
    /// One decode step for the given slots: last tokens + KV positions →
    /// next token per sequence, appended into `out` (cleared first). The
    /// steady-state entry point: implementations must not allocate beyond
    /// `out`'s existing capacity.
    fn decode_into(
        &mut self,
        slots: &[usize],
        last_tokens: &[i32],
        positions: &[u32],
        out: &mut Vec<i32>,
    );
    /// Allocating convenience wrapper over [`ComputeBackend::decode_into`].
    fn decode(&mut self, slots: &[usize], last_tokens: &[i32], positions: &[u32]) -> Vec<i32> {
        let mut out = Vec::with_capacity(slots.len());
        self.decode_into(slots, last_tokens, positions, &mut out);
        out
    }
    /// True when this backend runs the real compiled model.
    fn is_real(&self) -> bool {
        false
    }
    /// Deep-copy this backend for snapshot/fork execution. Only surrogate
    /// backends support forking; real (PJRT) backends hold device state
    /// that cannot be checkpointed, so they keep the panicking default and
    /// the snapshot layer must fall back to from-scratch runs.
    fn clone_box(&self) -> Box<dyn ComputeBackend> {
        panic!("this ComputeBackend does not support snapshot/fork cloning")
    }
}

/// Deterministic hash-based token sampler (sim-only runs). EOS is decided by
/// the engine's budget bookkeeping, not the backend.
#[derive(Debug, Clone, Default)]
pub struct SurrogateBackend {
    pub vocab: i32,
}

impl SurrogateBackend {
    pub fn new(vocab: usize) -> Self {
        SurrogateBackend { vocab: vocab as i32 }
    }

    fn hash_next(&self, seedlike: i64) -> i32 {
        let mut x = seedlike as u64 ^ 0x9E3779B97F4A7C15;
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 31;
        (3 + (x % (self.vocab as u64 - 3).max(1))) as i32
    }
}

impl ComputeBackend for SurrogateBackend {
    fn prefill(&mut self, _slots: &[usize], prompts: &[&[i32]]) -> Vec<i32> {
        prompts
            .iter()
            .map(|p| {
                let sum: i64 = p.iter().map(|&t| t as i64).sum();
                self.hash_next(sum)
            })
            .collect()
    }

    fn decode_into(
        &mut self,
        _slots: &[usize],
        last_tokens: &[i32],
        positions: &[u32],
        out: &mut Vec<i32>,
    ) {
        out.clear();
        for (&t, &p) in last_tokens.iter().zip(positions) {
            out.push(self.hash_next(t as i64 * 131 + p as i64));
        }
    }

    fn clone_box(&self) -> Box<dyn ComputeBackend> {
        Box::new(self.clone())
    }
}

/// One iteration's description.
#[derive(Debug, Clone)]
pub enum IterKind {
    /// Prefill of `reqs` with these (padded) prompt lengths.
    Prefill { reqs: Vec<ReqId>, prompt_lens: Vec<u32> },
    /// One decode step across `reqs` at these context lengths. The vectors
    /// are recycled through the coordinator's `IterScratch` between rounds.
    Decode { reqs: Vec<ReqId>, ctx_lens: Vec<u32> },
}

/// Timing outcome of an executed iteration.
#[derive(Debug, Clone)]
pub struct IterTiming {
    /// When the iteration's compute chain finished (logits at host).
    pub done: SimTime,
    /// Per-stage completion times.
    pub stage_done: Vec<SimTime>,
    /// Total FLOPs executed (metrics).
    pub flops: f64,
}

/// Monotonic collective-id allocator (one per replica executor).
#[derive(Debug, Clone, Default)]
pub struct CollSeq(u64);

impl CollSeq {
    pub fn next(&mut self) -> CollId {
        self.0 += 1;
        CollId(self.0 as u32)
    }
}

/// Reusable buffers for the stage walk. One per replica, recycled every
/// iteration: after warmup the capacities plateau and `run_iteration_in`
/// touches the heap zero times per round.
#[derive(Debug, Clone, Default)]
pub struct ExecScratch {
    /// Per-stage completion times (the wrapper moves this into
    /// [`IterTiming`]; hot callers read it in place).
    pub stage_done: Vec<SimTime>,
    node_done: Vec<SimTime>,
    gpus_here: Vec<usize>,
    node_frac: Vec<f64>,
    silent: Vec<bool>,
}

/// Execute one iteration over the cluster, emitting telemetry into `out`.
/// Allocation-free: all intermediate buffers live in `scratch`. Returns
/// `(done, flops)`; per-stage completion times are left in
/// `scratch.stage_done`.
#[allow(clippy::too_many_arguments)]
pub fn run_iteration_in(
    now: SimTime,
    kind: &IterKind,
    cluster: &mut Cluster,
    plan: &ParallelPlan,
    profile: &ModelProfile,
    colls: &mut CollSeq,
    out: &mut Outbox,
    scratch: &mut ExecScratch,
) -> (SimTime, f64) {
    let (phase, total_tokens, batch, mean_ctx) = match kind {
        IterKind::Prefill { prompt_lens, .. } => {
            let toks: u32 = prompt_lens.iter().sum();
            let mean = (toks / prompt_lens.len().max(1) as u32).max(1);
            (Phase::Prefill, toks as usize, prompt_lens.len(), mean as usize)
        }
        IterKind::Decode { reqs, ctx_lens } => {
            let mean = (ctx_lens.iter().sum::<u32>() / ctx_lens.len().max(1) as u32).max(1);
            (Phase::Decode, reqs.len(), reqs.len(), mean as usize)
        }
    };

    let total_flops = match phase {
        Phase::Prefill => profile.flops_prefill(total_tokens, mean_ctx),
        Phase::Decode => profile.flops_decode(batch, mean_ctx),
    };

    scratch.stage_done.clear();
    let mut stage_input_ready = now;

    for (si, stage) in plan.stages.iter().enumerate() {
        let stage_flops = total_flops * stage.layer_frac;
        let n_nodes = stage.nodes.len();

        // --- input feed ---
        // Stage 0 gets embeddings/ids over PCIe from the host; later stages
        // receive activations via the PP handoff (already accounted below).
        let feed_bytes = if si == 0 {
            profile.embed_bytes(total_tokens.max(batch))
        } else {
            0
        };

        // --- per-GPU compute, fed by per-GPU H2D slices ---
        scratch.node_done.clear();
        for (ni, &node) in stage.nodes.iter().enumerate() {
            let mut gpu_done_max = stage_input_ready;
            scratch.gpus_here.clear();
            scratch.gpus_here.extend(
                (0..stage.gpus.len()).filter(|&gi| cluster.node_of(stage.gpus[gi]) == node),
            );
            for &gi in &scratch.gpus_here {
                let gpu = stage.gpus[gi];
                let frac = stage.shard_frac[gi];
                let ready = if feed_bytes > 0 {
                    let slice = ((feed_bytes as f64) * frac).ceil() as u64;
                    cluster.h2d(stage_input_ready, gpu, slice.max(256), phase, out)
                } else {
                    // Decode/later stages still issue small control H2D
                    // (token ids / stage inputs land via handoff).
                    let ctrl = (batch * 8).max(64) as u64;
                    cluster.h2d(stage_input_ready, gpu, ctrl, phase, out)
                };
                let done = cluster.gpu_launch(ready, gpu, stage_flops * frac, out);
                gpu_done_max = gpu_done_max.max(done);
            }
            // Intra-node TP reduce over NVLink (DPU-invisible): lead GPU
            // gathers peers' partials.
            if scratch.gpus_here.len() > 1 {
                let lead = stage.gpus[scratch.gpus_here[0]];
                let part_bytes = profile.activation_bytes(total_tokens.max(batch))
                    / scratch.gpus_here.len() as u64;
                let mut reduce_done = gpu_done_max;
                for &gi in &scratch.gpus_here[1..] {
                    let done =
                        cluster.p2p(gpu_done_max, stage.gpus[gi], lead, part_bytes.max(64), out);
                    reduce_done = reduce_done.max(done);
                }
                gpu_done_max = reduce_done;
            }
            scratch.node_done.push(gpu_done_max);
            let _ = ni;
        }

        // --- cross-node TP allreduce (DPU-visible collective bursts) ---
        let mut stage_complete =
            *scratch.node_done.iter().max().unwrap_or(&stage_input_ready);
        if n_nodes > 1 {
            let coll = colls.next();
            let total_act = profile.activation_bytes(total_tokens.max(batch)).max(256);
            // Per-node payload follows that node's shard ownership: a
            // misaligned activation partitioning (EW3) shows up as uneven
            // per-source volume at every destination DPU.
            scratch.node_frac.clear();
            for &n in stage.nodes.iter() {
                scratch.node_frac.push(
                    stage
                        .gpus
                        .iter()
                        .zip(&stage.shard_frac)
                        .filter(|(g, _)| cluster.node_of(**g) == n)
                        .map(|(_, f)| *f)
                        .sum::<f64>(),
                );
            }
            let expected = n_nodes as u32;
            let mut last_arrival = stage_complete;
            // EW9: a node early-stopping without remap goes silent — its
            // bursts never arrive and destination collectives stall.
            scratch.silent.clear();
            for &n in stage.nodes.iter() {
                let p = cluster.nodes[n.idx()].knobs.collective_silence;
                scratch.silent.push(p > 0.0 && cluster.nodes[n.idx()].rng.chance(p));
            }
            for &dst in stage.nodes.iter() {
                // Each destination sees: its own shard completion ("self burst",
                // the outgoing RDMA doorbell) + one burst per peer.
                for (bi, &src) in stage.nodes.iter().enumerate() {
                    if scratch.silent[bi] && src != dst {
                        continue;
                    }
                    let act_bytes = ((total_act as f64) * scratch.node_frac[bi]
                        * n_nodes as f64)
                        .max(256.0) as u64;
                    let t_arrive = if src == dst {
                        scratch.node_done[bi]
                    } else {
                        cluster.rdma(scratch.node_done[bi], src, dst, act_bytes, false, out)
                    };
                    out.emit(
                        t_arrive,
                        dst,
                        TelemetryKind::CollectiveBurst {
                            coll,
                            kind: CollKind::TpAllreduce,
                            from_node: src,
                            rank: bi as u32,
                            expected_ranks: expected,
                            bytes: act_bytes,
                            latency_ns: (t_arrive - scratch.node_done[bi]).ns(),
                        },
                    );
                    last_arrival = last_arrival.max(t_arrive);
                }
            }
            stage_complete = last_arrival;
        }

        // --- PP handoff to the next stage (activations; KV stream on prefill) ---
        if si + 1 < plan.n_stages() {
            let next = &plan.stages[si + 1];
            let act_bytes = profile.activation_bytes(total_tokens.max(batch)).max(256);
            let coll = colls.next();
            let mut handoff_done = stage_complete;
            for (pi, (&src, &dst)) in
                stage.nodes.iter().zip(next.nodes.iter().cycle()).enumerate().take(n_nodes).map(|(i, p)| (i, p))
            {
                let arrive = cluster.rdma(stage_complete, src, dst, act_bytes, false, out);
                out.emit(
                    stage_complete,
                    src,
                    TelemetryKind::StageHandoff {
                        from_stage: stage.id,
                        to_stage: next.id,
                        bytes: act_bytes,
                        outbound: true,
                        phase,
                    },
                );
                out.emit(
                    arrive,
                    dst,
                    TelemetryKind::StageHandoff {
                        from_stage: stage.id,
                        to_stage: next.id,
                        bytes: act_bytes,
                        outbound: false,
                        phase,
                    },
                );
                // 1:1 pairing: each destination sees exactly one handoff
                // burst per collective instance.
                out.emit(
                    arrive,
                    dst,
                    TelemetryKind::CollectiveBurst {
                        coll,
                        kind: CollKind::PpHandoff,
                        from_node: src,
                        rank: pi as u32,
                        expected_ranks: 1,
                        bytes: act_bytes,
                        latency_ns: (arrive - stage_complete).ns(),
                    },
                );
                handoff_done = handoff_done.max(arrive);
            }
            // Prefill streams the new KV blocks for later-stage reuse
            // (disaggregated-style KV shipping; the EW8 path).
            if phase == Phase::Prefill {
                let kv_bytes = profile.kv_bytes(total_tokens).max(512) / n_nodes as u64;
                let kv_coll = colls.next();
                for (pi, (&src, &dst)) in
                    stage.nodes.iter().zip(next.nodes.iter().cycle()).enumerate().take(n_nodes).map(|(i, p)| (i, p))
                {
                    let arrive = cluster.rdma(stage_complete, src, dst, kv_bytes, true, out);
                    out.emit(
                        arrive,
                        dst,
                        TelemetryKind::CollectiveBurst {
                            coll: kv_coll,
                            kind: CollKind::KvTransfer,
                            from_node: src,
                            rank: pi as u32,
                            expected_ranks: 1,
                            bytes: kv_bytes,
                            latency_ns: (arrive - stage_complete).ns(),
                        },
                    );
                    handoff_done = handoff_done.max(arrive);
                }
            }
            stage_input_ready = handoff_done;
        }
        scratch.stage_done.push(stage_complete);
    }

    // --- D2H logits on the exit stage's lead node ---
    let exit = plan.exit_nodes()[0];
    let exit_gpu = *plan.stages[plan.n_stages() - 1]
        .gpus
        .iter()
        .find(|&&g| cluster.node_of(g) == exit)
        .expect("exit node has gpus");
    let logits_at = cluster.d2h(
        *scratch.stage_done.last().unwrap(),
        exit_gpu,
        profile.logits_bytes(batch).max(256),
        phase,
        out,
    );

    (logits_at, total_flops)
}

/// Allocating wrapper over [`run_iteration_in`] returning an owned
/// [`IterTiming`] (tests, one-shot callers).
pub fn run_iteration(
    now: SimTime,
    kind: &IterKind,
    cluster: &mut Cluster,
    plan: &ParallelPlan,
    profile: &ModelProfile,
    colls: &mut CollSeq,
    out: &mut Outbox,
) -> IterTiming {
    let mut scratch = ExecScratch::default();
    let (done, flops) = run_iteration_in(now, kind, cluster, plan, profile, colls, out, &mut scratch);
    IterTiming { done, stage_done: scratch.stage_done, flops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::engine::parallel::build_replicas;
    use crate::engine::profile::preset;

    fn setup() -> (Cluster, ParallelPlan, ModelProfile) {
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, 2);
        (Cluster::new(spec, 7), plans.into_iter().next().unwrap(), preset("small").unwrap())
    }

    #[test]
    fn prefill_chain_produces_all_event_classes() {
        let (mut cluster, plan, profile) = setup();
        let mut out = Outbox::new();
        let mut colls = CollSeq::default();
        let kind = IterKind::Prefill {
            reqs: vec![ReqId(1), ReqId(2)],
            prompt_lens: vec![64, 32],
        };
        let t = run_iteration(SimTime(1000), &kind, &mut cluster, &plan, &profile, &mut colls, &mut out);
        assert!(t.done > SimTime(1000));
        assert_eq!(t.stage_done.len(), 2);
        let classes: std::collections::HashSet<&str> =
            out.items.iter().map(|(_, _, k)| k.class()).collect();
        for want in ["dma_h2d", "doorbell", "gpu_kernel", "collective", "stage_handoff", "dma_d2h", "rdma_op"] {
            assert!(classes.contains(want), "missing {want}: {classes:?}");
        }
        // Prefill ships KV to the next stage.
        let kv_bursts = out
            .items
            .iter()
            .filter(|(_, _, k)| {
                matches!(k, TelemetryKind::CollectiveBurst { kind: CollKind::KvTransfer, .. })
            })
            .count();
        assert!(kv_bursts > 0);
    }

    #[test]
    fn scratch_reuse_matches_the_allocating_wrapper() {
        // The same iteration through a warm ExecScratch must reproduce the
        // wrapper's outcome exactly (same RNG-free path, same timings).
        let (mut c1, plan1, profile) = setup();
        let mut out1 = Outbox::new();
        let mut colls1 = CollSeq::default();
        let kind = IterKind::Decode { reqs: vec![ReqId(1); 3], ctx_lens: vec![40, 50, 60] };
        let t = run_iteration(SimTime(500), &kind, &mut c1, &plan1, &profile, &mut colls1, &mut out1);

        let (mut c2, plan2, _) = setup();
        let mut out2 = Outbox::new();
        let mut colls2 = CollSeq::default();
        let mut scratch = ExecScratch::default();
        // Warm the scratch on an unrelated iteration first.
        let warm = IterKind::Prefill { reqs: vec![ReqId(9)], prompt_lens: vec![16] };
        let mut warm_cluster = setup().0;
        let _ = run_iteration_in(
            SimTime(0), &warm, &mut warm_cluster, &plan2, &profile, &mut CollSeq::default(),
            &mut Outbox::new(), &mut scratch,
        );
        let (done, flops) = run_iteration_in(
            SimTime(500), &kind, &mut c2, &plan2, &profile, &mut colls2, &mut out2, &mut scratch,
        );
        assert_eq!(done, t.done);
        assert_eq!(flops, t.flops);
        assert_eq!(scratch.stage_done, t.stage_done);
        assert_eq!(out1.items, out2.items);
    }

    #[test]
    fn decode_is_cheaper_than_prefill() {
        let (mut cluster, plan, profile) = setup();
        let mut out = Outbox::new();
        let mut colls = CollSeq::default();
        let pre = IterKind::Prefill { reqs: vec![ReqId(1)], prompt_lens: vec![64] };
        let t_pre =
            run_iteration(SimTime(0), &pre, &mut cluster, &plan, &profile, &mut colls, &mut out);
        let (mut cluster2, plan2, _) = setup();
        let dec = IterKind::Decode { reqs: vec![ReqId(1)], ctx_lens: vec![65] };
        let t_dec =
            run_iteration(SimTime(0), &dec, &mut cluster2, &plan2, &profile, &mut colls, &mut out);
        assert!(
            t_dec.done < t_pre.done,
            "decode {:?} !< prefill {:?}",
            t_dec.done,
            t_pre.done
        );
        assert!(t_dec.flops < t_pre.flops);
    }

    #[test]
    fn straggler_gpu_widens_collective_spread() {
        // Use a compute-dominated profile: with the tiny "small" model the
        // iteration is network-bound and a slow GPU barely moves arrivals.
        let (mut cluster, plan, _) = setup();
        let profile = preset("7b").unwrap();
        // Slow one GPU on node 1 (stage 0 spans nodes 0-1).
        cluster.nodes[1].knobs.gpu_speed_factor[0] = 0.2;
        let mut out = Outbox::new();
        let mut colls = CollSeq::default();
        let kind = IterKind::Decode { reqs: vec![ReqId(1); 4], ctx_lens: vec![64; 4] };
        run_iteration(SimTime(0), &kind, &mut cluster, &plan, &profile, &mut colls, &mut out);
        // Find TP collective arrivals at node 0 and compute spread.
        let mut arrivals: Vec<u64> = Vec::new();
        for (t, node, k) in &out.items {
            if *node == NodeId(0) {
                if let TelemetryKind::CollectiveBurst { kind: CollKind::TpAllreduce, .. } = k {
                    arrivals.push(t.ns());
                }
            }
        }
        assert!(arrivals.len() >= 2);
        let spread = arrivals.iter().max().unwrap() - arrivals.iter().min().unwrap();
        // Healthy baseline for comparison.
        let (mut c2, plan2, _) = setup();
        let profile2 = preset("7b").unwrap();
        let mut out2 = Outbox::new();
        let kind2 = IterKind::Decode { reqs: vec![ReqId(1); 4], ctx_lens: vec![64; 4] };
        run_iteration(SimTime(0), &kind2, &mut c2, &plan2, &profile2, &mut colls, &mut out2);
        let mut arr2: Vec<u64> = Vec::new();
        for (t, node, k) in &out2.items {
            if *node == NodeId(0) {
                if let TelemetryKind::CollectiveBurst { kind: CollKind::TpAllreduce, .. } = k {
                    arr2.push(t.ns());
                }
            }
        }
        let spread2 = arr2.iter().max().unwrap() - arr2.iter().min().unwrap();
        assert!(spread > spread2 * 3, "straggler spread {spread} vs healthy {spread2}");
    }

    #[test]
    fn surrogate_backend_deterministic() {
        let mut b = SurrogateBackend::new(512);
        let (pa, pb): (&[i32], &[i32]) = (&[1, 2, 3], &[4, 5]);
        let p1 = b.prefill(&[0, 1], &[pa, pb]);
        let p2 = b.prefill(&[0, 1], &[pa, pb]);
        assert_eq!(p1, p2);
        assert!(p1.iter().all(|&t| (3..512).contains(&t)));
        let d1 = b.decode(&[0, 1], &[7, 9], &[10, 20]);
        let d2 = b.decode(&[0, 1], &[7, 9], &[10, 20]);
        assert_eq!(d1, d2);
        assert_ne!(d1[0], d1[1]);
        assert!(!b.is_real());
        // decode_into reuses the caller's buffer and matches decode.
        let mut buf = vec![0; 8];
        b.decode_into(&[0, 1], &[7, 9], &[10, 20], &mut buf);
        assert_eq!(buf, d1);
    }
}
