//! Model profiles: dimensions + analytic cost model (FLOPs, bytes) used by
//! the simulated execution path. The `toy`/`small`/`base` presets mirror
//! `python/compile/config.py` (the AOT artifacts); the larger profiles are
//! sim-only and follow the open-weight families of paper Table 1.

/// Dimensions of a decoder-only transformer + serving block shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
    /// AOT prefill block length (sequences pad/truncate to this).
    pub prefill_len: usize,
    /// AOT batch size (the compiled executable's fixed batch).
    pub batch: usize,
}

pub const BYTES_F32: u64 = 4;

impl ModelProfile {
    /// Approximate parameter count.
    pub fn params(&self) -> f64 {
        let d = self.d_model as f64;
        let attn = 4.0 * d * d;
        let mlp = 2.0 * d * self.ffn as f64;
        self.vocab as f64 * d
            + self.max_seq as f64 * d
            + self.layers as f64 * (attn + mlp)
    }

    /// FLOPs to prefill `tokens` total tokens (2*params per token, plus the
    /// quadratic attention term).
    pub fn flops_prefill(&self, tokens: usize, mean_len: usize) -> f64 {
        let linear = 2.0 * self.params() * tokens as f64;
        let attn = 2.0
            * self.layers as f64
            * (self.n_heads * self.head_dim) as f64
            * tokens as f64
            * mean_len as f64;
        linear + attn
    }

    /// FLOPs for one decode step over `batch` sequences at ~`ctx` context.
    pub fn flops_decode(&self, batch: usize, ctx: usize) -> f64 {
        let linear = 2.0 * self.params() * batch as f64;
        let attn = 2.0
            * self.layers as f64
            * (self.n_heads * self.head_dim) as f64
            * batch as f64
            * ctx as f64;
        linear + attn
    }

    /// H2D bytes to feed `tokens` of embeddings/ids for an iteration.
    pub fn embed_bytes(&self, tokens: usize) -> u64 {
        (tokens * self.d_model) as u64 * BYTES_F32
    }

    /// D2H bytes for logits of `batch` sequences.
    pub fn logits_bytes(&self, batch: usize) -> u64 {
        (batch * self.vocab) as u64 * BYTES_F32
    }

    /// KV-cache bytes per token (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.layers * self.n_heads * self.head_dim) as u64 * BYTES_F32
    }

    /// Activation bytes for `tokens` (TP allreduce / PP handoff payloads).
    pub fn activation_bytes(&self, tokens: usize) -> u64 {
        (tokens * self.d_model) as u64 * BYTES_F32
    }

    /// Per-sequence KV bytes at context length `ctx`.
    pub fn kv_bytes(&self, ctx: usize) -> u64 {
        self.kv_bytes_per_token() * ctx as u64
    }
}

/// Presets matching the AOT artifacts (`python/compile/config.py`).
pub fn preset(name: &str) -> Option<ModelProfile> {
    Some(match name {
        "toy" => ModelProfile {
            name: "toy", layers: 2, d_model: 128, n_heads: 4, head_dim: 32,
            ffn: 512, vocab: 512, max_seq: 64, prefill_len: 32, batch: 2,
        },
        "small" => ModelProfile {
            name: "small", layers: 4, d_model: 256, n_heads: 8, head_dim: 32,
            ffn: 1024, vocab: 2048, max_seq: 128, prefill_len: 64, batch: 4,
        },
        "base" => ModelProfile {
            name: "base", layers: 8, d_model: 512, n_heads: 8, head_dim: 64,
            ffn: 2048, vocab: 4096, max_seq: 256, prefill_len: 128, batch: 8,
        },
        // Sim-only profiles in the spirit of Table 1 (LLaMA-style dims).
        "7b" => ModelProfile {
            name: "7b", layers: 32, d_model: 4096, n_heads: 32, head_dim: 128,
            ffn: 11008, vocab: 32000, max_seq: 2048, prefill_len: 512, batch: 16,
        },
        "13b" => ModelProfile {
            name: "13b", layers: 40, d_model: 5120, n_heads: 40, head_dim: 128,
            ffn: 13824, vocab: 32000, max_seq: 2048, prefill_len: 512, batch: 16,
        },
        "70b" => ModelProfile {
            name: "70b", layers: 80, d_model: 8192, n_heads: 64, head_dim: 128,
            ffn: 28672, vocab: 32000, max_seq: 2048, prefill_len: 512, batch: 16,
        },
        _ => return None,
    })
}

pub const ALL_PRESETS: [&str; 6] = ["toy", "small", "base", "7b", "13b", "70b"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ALL_PRESETS {
            let p = preset(name).unwrap();
            assert_eq!(p.name, name);
            assert_eq!(p.d_model, p.n_heads * p.head_dim, "{name}");
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn param_counts_in_expected_range() {
        let small = preset("small").unwrap();
        let n = small.params();
        assert!((1e6..1e7).contains(&n), "small params {n}");
        let b7 = preset("7b").unwrap().params();
        assert!((5e9..9e9).contains(&b7), "7b params {b7}");
    }

    #[test]
    fn cost_model_monotone() {
        let p = preset("small").unwrap();
        assert!(p.flops_prefill(256, 64) > p.flops_prefill(128, 64));
        assert!(p.flops_decode(8, 128) > p.flops_decode(4, 128));
        assert!(p.flops_decode(4, 256) > p.flops_decode(4, 64));
        assert!(p.kv_bytes(128) == 128 * p.kv_bytes_per_token());
    }

    #[test]
    fn small_matches_python_config() {
        // Pin the cross-language contract (python/compile/config.py "small").
        let p = preset("small").unwrap();
        assert_eq!(
            (p.layers, p.d_model, p.n_heads, p.head_dim, p.ffn, p.vocab,
             p.max_seq, p.prefill_len, p.batch),
            (4, 256, 8, 32, 1024, 2048, 128, 64, 4)
        );
    }
}
