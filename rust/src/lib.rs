//! # dpulens
//!
//! DPU-vantage observability for LLM inference clusters: a reproduction of
//! Khan & Moye, *"A Study of Skews, Imbalances, and Pathological Conditions
//! in LLM Inference Deployment on GPU Clusters detectable from DPU"* (2025).
//!
//! The crate is a three-layer system (see DESIGN.md):
//!
//! * **L3 (this crate)** — simulated GPU cluster + vLLM-like serving engine +
//!   the paper's contribution: per-node DPU telemetry agents, 28 runbook
//!   detectors (Tables 3a-c), root-cause attribution, and a closed
//!   mitigation loop.
//! * **L2/L1 (build-time Python)** — a JAX transformer with Pallas attention
//!   kernels plus a Pallas telemetry-scoring kernel, AOT-lowered to HLO text
//!   and executed from Rust via PJRT (`runtime/`). Python never serves.
//!
//! Per-condition knowledge (inject recipe, runbook row, root-cause mapping,
//! directive, detector binding, shaping, label) lives in ONE place: the
//! [`conditions`] catalog, one `ConditionSpec` per condition. `pathology`,
//! `dpu::runbook`, `dpu::attribution`, the mitigation controller, and the
//! fleet sensors dispatch through it — adding a condition is a one-module
//! change (see `dpulens conditions`).
//!
//! ## Coordinator module map
//!
//! The serving plane (`coordinator/`) is decomposed into composable
//! sub-modules, with `scenario` as a thin orchestrator:
//!
//! | module | role |
//! |---|---|
//! | `coordinator::scenario` | config, result bundle, the event-dispatch loop |
//! | `coordinator::world` | world construction, event alphabet, calendar wiring |
//! | `coordinator::ingress` | arrival → routing/admission, egress accounting, replica-aware injection targeting |
//! | `coordinator::iterate` | per-replica iteration driving: batching, KV, prefill/decode, retirement |
//! | `coordinator::handoff` | prefill→decode KV handoff: phase transition, decode-pool adoption (disaggregated fleets) |
//! | `coordinator::observe` | DPU/SW windows, fleet (DP1-DP3) + pool (PD1-PD3) skew sensing, closed mitigation loop |
//! | `coordinator::experiment` | three-phase condition experiments + per-condition shaping |
//! | `coordinator::matrix` | the parallel 28-condition scorecard matrix |
//! | `coordinator::fleet` | replicas × routing-policy sweep with the DP condition family + the `--disagg` PD study (`dpulens fleet`) |
//! | `coordinator::perf` | pipeline benchmark: ingest/snapshot microbenches + matrix/fleet wall-clock (`dpulens perf`) |
//! | `coordinator::report` | machine-readable reports (run/runbook/matrix JSON) |

pub mod ids;
pub mod util;

pub mod sim;
pub mod telemetry;

pub mod cluster;

pub mod workload;
pub mod engine;

pub mod conditions;
pub mod dpu;
pub mod mitigation;
pub mod pathology;

pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;

pub mod coordinator;
