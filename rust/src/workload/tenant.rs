//! Multi-tenant session classes: priority/SLO labels threaded from the
//! workload spec through every [`InferenceRequest`] into per-tenant
//! TTFT/TPOT attainment scoring (`metrics::TenantLane`).
//!
//! Tenancy is a *deterministic partition of the session space*: each class
//! owns a contiguous range of session ids sized by its `share`, so the
//! request stream for a given seed is byte-identical whether or not tenants
//! are configured (no extra RNG draws). Under Zipf session skew the low
//! session ranks are the hottest, so classes listed first receive the
//! hotter traffic — list the latency-sensitive class first to stress its
//! SLOs the hardest.

/// One tenant class: a named priority band with TTFT/TPOT SLO targets and a
/// share of the session space.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    /// Smaller = more latency-sensitive (0 is the premium band).
    pub priority: u8,
    /// Fraction of sessions owned by this class (normalized over the list).
    pub share: f64,
    /// Time-to-first-token SLO, milliseconds.
    pub ttft_slo_ms: f64,
    /// Time-per-output-token SLO, milliseconds.
    pub tpot_slo_ms: f64,
}

impl TenantClass {
    pub fn new(name: &str, priority: u8, share: f64, ttft_slo_ms: f64, tpot_slo_ms: f64) -> Self {
        TenantClass { name: name.to_string(), priority, share, ttft_slo_ms, tpot_slo_ms }
    }
}

/// Map a session id to its tenant-class index: contiguous ranges over
/// `[0, n_sessions)` proportional to each class's normalized share, with the
/// last class absorbing the rounding remainder. Returns 0 when no classes
/// are configured (the single implicit tenant).
pub fn tenant_of_session(classes: &[TenantClass], session: usize, n_sessions: usize) -> u8 {
    if classes.len() <= 1 {
        return 0;
    }
    let total: f64 = classes.iter().map(|c| c.share.max(0.0)).sum();
    if total <= 0.0 {
        return 0;
    }
    let n = n_sessions.max(1) as f64;
    let mut cum = 0.0;
    for (i, c) in classes.iter().enumerate().take(classes.len() - 1) {
        cum += c.share.max(0.0) / total;
        if (session as f64) < (cum * n).floor() {
            return i as u8;
        }
    }
    (classes.len() - 1) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<TenantClass> {
        vec![
            TenantClass::new("interactive", 0, 0.5, 250.0, 40.0),
            TenantClass::new("batch", 1, 0.5, 2000.0, 200.0),
        ]
    }

    #[test]
    fn contiguous_partition_covers_all_sessions() {
        let cs = classes();
        let n = 64;
        let mut counts = [0usize; 2];
        for s in 0..n {
            counts[tenant_of_session(&cs, s, n) as usize] += 1;
        }
        assert_eq!(counts[0] + counts[1], n);
        assert_eq!(counts[0], 32);
        // First class owns the low (hot-under-Zipf) session ranks.
        assert_eq!(tenant_of_session(&cs, 0, n), 0);
        assert_eq!(tenant_of_session(&cs, n - 1, n), 1);
    }

    #[test]
    fn shares_are_normalized_and_remainder_goes_last() {
        let cs = vec![
            TenantClass::new("a", 0, 2.0, 100.0, 10.0),
            TenantClass::new("b", 1, 1.0, 100.0, 10.0),
            TenantClass::new("c", 2, 1.0, 100.0, 10.0),
        ];
        let n = 10;
        let mut counts = [0usize; 3];
        for s in 0..n {
            counts[tenant_of_session(&cs, s, n) as usize] += 1;
        }
        assert_eq!(counts, [5, 2, 3], "{counts:?}");
    }

    #[test]
    fn degenerate_configs_map_to_tenant_zero() {
        assert_eq!(tenant_of_session(&[], 5, 64), 0);
        let one = vec![TenantClass::new("solo", 0, 1.0, 100.0, 10.0)];
        assert_eq!(tenant_of_session(&one, 63, 64), 0);
        let zeroed = vec![
            TenantClass::new("a", 0, 0.0, 100.0, 10.0),
            TenantClass::new("b", 1, 0.0, 100.0, 10.0),
        ];
        assert_eq!(tenant_of_session(&zeroed, 5, 64), 0);
    }
}
