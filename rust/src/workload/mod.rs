//! Workload substrate: requests, a toy tokenizer over an embedded corpus,
//! arrival/length generators, and trace record/replay.

pub mod corpus;
pub mod generator;
pub mod request;
pub mod tenant;
pub mod tokenizer;
pub mod trace;

pub use generator::{WorkloadGen, WorkloadSpec};
pub use request::{InferenceRequest, ReqState};
pub use tenant::TenantClass;
pub use tokenizer::ToyTokenizer;
