//! Trace record/replay: capture a generated workload's shape to a text file
//! and replay it exactly (cross-run comparisons with identical arrivals).
//!
//! Line format: `arrival_ns flow prompt_len max_new` (prompt token ids are
//! re-derived deterministically at replay by hashing, keeping traces small).

use std::io::Write;
use std::path::Path;

use crate::ids::{FlowId, ReqId};
use crate::sim::SimTime;
use crate::workload::request::InferenceRequest;
use crate::workload::tokenizer::ToyTokenizer;
use crate::workload::corpus;

/// One trace row: the workload *shape* of a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRow {
    pub arrival_ns: u64,
    pub flow: u32,
    pub prompt_len: usize,
    pub max_new: usize,
}

pub fn record(reqs: &[InferenceRequest]) -> Vec<TraceRow> {
    reqs.iter()
        .map(|r| TraceRow {
            arrival_ns: r.arrival.ns(),
            flow: r.flow.0,
            prompt_len: r.prompt_len(),
            max_new: r.max_new_tokens,
        })
        .collect()
}

pub fn save(rows: &[TraceRow], path: &Path) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "# dpulens trace v1: arrival_ns flow prompt_len max_new")?;
    for r in rows {
        writeln!(f, "{} {} {} {}", r.arrival_ns, r.flow, r.prompt_len, r.max_new)?;
    }
    Ok(())
}

pub fn load(text: &str) -> Result<Vec<TraceRow>, String> {
    let mut rows = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 4 {
            return Err(format!("trace line {}: expected 4 fields, got {}", i + 1, parts.len()));
        }
        let parse = |s: &str, what: &str| -> Result<u64, String> {
            s.parse().map_err(|e| format!("trace line {}: bad {what}: {e}", i + 1))
        };
        rows.push(TraceRow {
            arrival_ns: parse(parts[0], "arrival")?,
            flow: parse(parts[1], "flow")? as u32,
            prompt_len: parse(parts[2], "prompt_len")? as usize,
            max_new: parse(parts[3], "max_new")? as usize,
        });
    }
    Ok(rows)
}

/// Materialize requests from trace rows (prompt tokens re-derived from the
/// corpus deterministically by row index).
pub fn replay(rows: &[TraceRow], vocab: usize) -> Vec<InferenceRequest> {
    let tok = ToyTokenizer::new(vocab);
    rows.iter()
        .enumerate()
        .map(|(i, row)| {
            let text = corpus::long_prompt(i, row.prompt_len * 6);
            let prompt = tok.encode_to_len(&text, row.prompt_len.max(2));
            InferenceRequest::new(
                ReqId(i as u32),
                FlowId(row.flow),
                SimTime(row.arrival_ns),
                prompt,
                row.max_new,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::{WorkloadGen, WorkloadSpec};

    #[test]
    fn roundtrip_preserves_shape() {
        let mut g = WorkloadGen::new(WorkloadSpec::default(), 512, 11);
        let reqs = g.take(20);
        let rows = record(&reqs);
        let text = {
            let mut s = String::from("# header\n");
            for r in &rows {
                s.push_str(&format!("{} {} {} {}\n", r.arrival_ns, r.flow, r.prompt_len, r.max_new));
            }
            s
        };
        let loaded = load(&text).unwrap();
        assert_eq!(rows, loaded);
        let replayed = replay(&loaded, 512);
        assert_eq!(replayed.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&replayed) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.flow, b.flow);
            assert_eq!(a.prompt_len(), b.prompt_len());
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let rows = vec![TraceRow { arrival_ns: 5, flow: 1, prompt_len: 8, max_new: 3 }];
        let a = replay(&rows, 512);
        let b = replay(&rows, 512);
        assert_eq!(a[0].prompt, b[0].prompt);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(load("1 2 3").is_err());
        assert!(load("a b c d").is_err());
        assert!(load("# comment only\n").unwrap().is_empty());
    }
}
