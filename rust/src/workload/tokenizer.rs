//! Toy byte-pair-free tokenizer: word pieces hashed into the model's vocab.
//!
//! Deterministic and reversible enough for demos (detokenize produces the
//! id stream's piece labels, not the original text). The model vocabulary is
//! small (e.g. 2048), so we hash word pieces into `[N_SPECIAL, vocab)`.

/// Reserved special ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const N_SPECIAL: i32 = 3;

#[derive(Debug, Clone)]
pub struct ToyTokenizer {
    vocab: i32,
}

impl ToyTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab as i32 > N_SPECIAL + 10, "vocab too small");
        ToyTokenizer { vocab: vocab as i32 }
    }

    fn hash_piece(&self, piece: &str) -> i32 {
        // FNV-1a over the piece bytes, folded into the non-special id range.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in piece.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let range = (self.vocab - N_SPECIAL) as u64;
        (N_SPECIAL as u64 + h % range) as i32
    }

    /// Tokenize text into ids: BOS + one id per word piece (words split on
    /// whitespace; long words chunked to 6 chars).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids = vec![BOS];
        for word in text.split_whitespace() {
            let chars: Vec<char> = word.chars().collect();
            for chunk in chars.chunks(6) {
                let piece: String = chunk.iter().collect();
                ids.push(self.hash_piece(&piece));
            }
        }
        ids
    }

    /// Encode and clamp/pad to exactly `len` tokens (pads with PAD).
    pub fn encode_to_len(&self, text: &str, len: usize) -> Vec<i32> {
        let mut ids = self.encode(text);
        ids.truncate(len);
        while ids.len() < len {
            ids.push(PAD);
        }
        ids
    }

    /// Human-readable rendering of an id stream.
    pub fn render(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| match id {
                PAD => "<pad>".to_string(),
                BOS => "<bos>".to_string(),
                EOS => "<eos>".to_string(),
                other => format!("t{other}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn vocab(&self) -> usize {
        self.vocab as usize
    }

    pub fn in_vocab(&self, id: i32) -> bool {
        (0..self.vocab).contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_is_deterministic_and_in_vocab() {
        let tok = ToyTokenizer::new(2048);
        let a = tok.encode("the quick brown fox");
        let b = tok.encode("the quick brown fox");
        assert_eq!(a, b);
        assert_eq!(a[0], BOS);
        assert!(a.iter().all(|&id| tok.in_vocab(id)));
        assert!(a.len() >= 5);
    }

    #[test]
    fn different_words_usually_differ() {
        let tok = ToyTokenizer::new(2048);
        let a = tok.encode("alpha");
        let b = tok.encode("omega");
        assert_ne!(a[1], b[1]);
    }

    #[test]
    fn encode_to_len_pads_and_truncates() {
        let tok = ToyTokenizer::new(512);
        let short = tok.encode_to_len("hi", 8);
        assert_eq!(short.len(), 8);
        assert!(short[4..].iter().all(|&t| t == PAD));
        let long = tok.encode_to_len(&"word ".repeat(100), 8);
        assert_eq!(long.len(), 8);
    }

    #[test]
    fn long_words_are_chunked() {
        let tok = ToyTokenizer::new(2048);
        let ids = tok.encode("internationalization");
        // 20 chars -> 4 chunks + BOS
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn render_labels_specials() {
        let tok = ToyTokenizer::new(512);
        assert_eq!(tok.render(&[BOS, 100, EOS]), "<bos> t100 <eos>");
    }
}
