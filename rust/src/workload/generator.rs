//! Workload generator: arrival processes × length mixes × session/flow
//! assignment. Knobs here create the *workload-shaped* pathologies (NS1
//! bursts, NS2 thin flows, NS3 flow skew, NS8/PC10/EW9 bimodal lengths).

use crate::ids::{FlowId, ReqId};
use crate::sim::dist::{Arrival, ArrivalSampler, LengthDist, RateShape};
use crate::sim::SimTime;
use crate::util::rng::{Rng, Zipf};
use crate::workload::corpus;
use crate::workload::request::InferenceRequest;
use crate::workload::tenant::{tenant_of_session, TenantClass};
use crate::workload::tokenizer::ToyTokenizer;

/// Declarative workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrival: Arrival,
    pub rate_shape: RateShape,
    pub prompt_len: LengthDist,
    pub output_len: LengthDist,
    /// Number of client sessions (flows).
    pub n_sessions: usize,
    /// Zipf exponent for session selection (0 = uniform; ≥1 = heavy skew, NS3).
    pub session_skew: f64,
    /// Thin-traffic injection (NS2): fraction of sessions that send with long
    /// idle gaps (their requests are delayed by an extra exponential gap).
    /// The thin slice is drawn from the *cold tail* of the session space
    /// (the highest session ids — the least popular ranks under Zipf skew).
    pub thin_session_frac: f64,
    pub thin_extra_gap_s: f64,
    /// Multi-tenant SLO classes; empty = one implicit tenant (class 0).
    /// Sessions partition into contiguous ranges by `TenantClass::share`.
    pub tenants: Vec<TenantClass>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrival: Arrival::Poisson { rate: 200.0 },
            rate_shape: RateShape::Constant,
            prompt_len: LengthDist::Uniform { lo: 8, hi: 64 },
            output_len: LengthDist::Uniform { lo: 4, hi: 32 },
            n_sessions: 64,
            session_skew: 0.0,
            thin_session_frac: 0.0,
            thin_extra_gap_s: 0.0,
            tenants: Vec::new(),
        }
    }
}

/// Stateful generator producing timestamped requests with real token ids.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    spec: WorkloadSpec,
    sampler: ArrivalSampler,
    zipf: Option<Zipf>,
    rng: Rng,
    tok: ToyTokenizer,
    next_id: u32,
    clock: SimTime,
    prompt_cursor: usize,
}

impl WorkloadGen {
    pub fn new(spec: WorkloadSpec, vocab: usize, seed: u64) -> Self {
        let mut root = Rng::new(seed, 0xAB);
        let sampler_rng = root.fork(1);
        let zipf = if spec.session_skew > 0.0 {
            Some(Zipf::new(spec.n_sessions.max(1), spec.session_skew))
        } else {
            None
        };
        WorkloadGen {
            sampler: ArrivalSampler::new(spec.arrival.clone(), sampler_rng),
            spec,
            zipf,
            rng: root,
            tok: ToyTokenizer::new(vocab),
            next_id: 0,
            clock: SimTime::ZERO,
            prompt_cursor: 0,
        }
    }

    /// Rebuild the generator for a new spec while *continuing* the id and
    /// prompt-corpus streams of `prev` (and its arrival clock). Mid-run
    /// workload swaps (workload-site injections) must use this: a fresh
    /// `new()` restarts `next_id` at 0, so post-swap requests would reuse
    /// live `ReqId`s and silently overwrite engine bookkeeping.
    pub fn resume(spec: WorkloadSpec, vocab: usize, seed: u64, prev: &WorkloadGen) -> Self {
        let mut g = WorkloadGen::new(spec, vocab, seed);
        g.next_id = prev.next_id;
        g.prompt_cursor = prev.prompt_cursor;
        g.clock = prev.clock;
        g
    }

    pub fn tokenizer(&self) -> &ToyTokenizer {
        &self.tok
    }

    /// The undelayed generation clock: the base arrival time of the last
    /// generated request, *before* any per-request delivery jitter. The
    /// scenario loop chains generation off this clock so thin-session
    /// delays never stall the rest of the stream.
    pub fn clock(&self) -> SimTime {
        self.clock
    }

    /// Id the next generated request will carry (diagnostics/tests).
    pub fn peek_next_id(&self) -> u32 {
        self.next_id
    }

    /// Generate the next request (base arrival times strictly increase;
    /// thin-session requests carry extra *delivery* jitter on top).
    pub fn next_request(&mut self) -> InferenceRequest {
        // Arrival gap, modulated by the rate shape (higher factor = faster).
        let base_gap = self.sampler.next_gap();
        let factor = self.spec.rate_shape.factor_at(self.clock.ns()).max(1e-3);
        let gap = base_gap.scale(1.0 / factor);
        self.clock = self.clock + gap;

        // Session / flow selection (Zipf skew when configured).
        let session = match &self.zipf {
            Some(z) => z.sample(&mut self.rng),
            None => self.rng.index(self.spec.n_sessions.max(1)),
        };
        let mut arrival = self.clock;
        // Thin sessions (NS2): a slice of sessions dribbles traffic in late.
        // The slice is the *cold tail* (highest session ids = least popular
        // Zipf ranks) — carving it from rank 0 would make the hottest
        // sessions thin and invert the NS2×NS3 composition.
        let n = self.spec.n_sessions.max(1);
        let thin_cut = (n as f64 * self.spec.thin_session_frac) as usize;
        if thin_cut > 0 && session >= n - thin_cut && self.spec.thin_extra_gap_s > 0.0 {
            let extra = self.rng.exponential(1.0 / self.spec.thin_extra_gap_s);
            arrival = arrival + crate::sim::SimDur::from_secs_f64(extra);
        }

        // Real prompt tokens from the corpus.
        let want_len = self.spec.prompt_len.sample(&mut self.rng).max(2);
        let text = corpus::long_prompt(self.prompt_cursor, want_len * 6);
        self.prompt_cursor += 1;
        let prompt = self.tok.encode_to_len(&text, want_len);

        let out_len = self.spec.output_len.sample(&mut self.rng).max(1);
        let id = ReqId(self.next_id);
        self.next_id += 1;
        let mut req = InferenceRequest::new(id, FlowId(session as u32), arrival, prompt, out_len);
        // Deterministic session→tenant partition: no RNG draws, so the
        // request stream is identical with or without tenant classes.
        req.tenant = tenant_of_session(&self.spec.tenants, session, n);
        req
    }

    /// Jump the arrival clock forward (used when an injector swaps the
    /// workload mid-run: the new generator resumes from "now").
    pub fn fast_forward(&mut self, t: SimTime) {
        if t > self.clock {
            self.clock = t;
        }
    }

    /// Generate `n` requests (sorted by arrival except thin-session jitter).
    pub fn take(&mut self, n: usize) -> Vec<InferenceRequest> {
        let mut v: Vec<InferenceRequest> = (0..n).map(|_| self.next_request()).collect();
        v.sort_by_key(|r| r.arrival);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_increase_and_tokens_valid() {
        let mut g = WorkloadGen::new(WorkloadSpec::default(), 2048, 7);
        let reqs = g.take(100);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for r in &reqs {
            assert!(r.prompt.iter().all(|&t| (0..2048).contains(&t)));
            assert!(r.prompt_len() >= 2);
            assert!(r.max_new_tokens >= 1);
        }
    }

    #[test]
    fn session_skew_concentrates_flows() {
        let mut spec = WorkloadSpec::default();
        spec.session_skew = 1.4;
        let mut g = WorkloadGen::new(spec, 2048, 7);
        let reqs = g.take(500);
        let mut counts = std::collections::HashMap::new();
        for r in &reqs {
            *counts.entry(r.flow).or_insert(0u32) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        assert!(max as f64 > 500.0 / 64.0 * 4.0, "max flow count {max} not skewed");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = WorkloadGen::new(WorkloadSpec::default(), 512, 3);
        let mut b = WorkloadGen::new(WorkloadSpec::default(), 512, 3);
        for _ in 0..50 {
            let (ra, rb) = (a.next_request(), b.next_request());
            assert_eq!(ra.arrival, rb.arrival);
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.flow, rb.flow);
        }
    }

    #[test]
    fn bimodal_output_lengths() {
        let mut spec = WorkloadSpec::default();
        spec.output_len = LengthDist::Bimodal { short: 2, long: 64, p_short: 0.5 };
        let mut g = WorkloadGen::new(spec, 512, 9);
        let reqs = g.take(200);
        let shorts = reqs.iter().filter(|r| r.max_new_tokens == 2).count();
        assert!((60..140).contains(&shorts), "shorts={shorts}");
    }

    #[test]
    fn thin_sessions_come_from_the_cold_tail() {
        // NS2×NS3 composition: with Zipf skew the thin slice must be the
        // *least popular* session ranks, never the hot head.
        let mut spec = WorkloadSpec::default();
        spec.session_skew = 1.6;
        spec.thin_session_frac = 0.25; // cold tail: sessions 48..64
        spec.thin_extra_gap_s = 0.05;
        let mut g = WorkloadGen::new(spec, 512, 11);
        let mut jittered = 0u32;
        for _ in 0..400 {
            let r = g.next_request();
            let delayed = r.arrival > g.clock();
            if delayed {
                jittered += 1;
                assert!(
                    r.flow.0 >= 48,
                    "hot session {} got thin-session jitter (thin slice must be the cold tail)",
                    r.flow.0
                );
            }
        }
        assert!(jittered > 0, "no thin-session request observed");
    }

    #[test]
    fn resume_continues_id_and_prompt_streams() {
        // A mid-run workload swap must not restart ReqIds at 0 (live ids
        // would be silently overwritten in the engine's bookkeeping).
        let mut a = WorkloadGen::new(WorkloadSpec::default(), 512, 3);
        let pre: Vec<u32> = (0..20).map(|_| a.next_request().id.0).collect();
        assert_eq!(*pre.last().unwrap(), 19);
        let mut swapped = WorkloadSpec::default();
        swapped.thin_session_frac = 0.4;
        swapped.thin_extra_gap_s = 0.05;
        let mut b = WorkloadGen::resume(swapped, 512, 3 ^ 0x5EED, &a);
        assert_eq!(b.peek_next_id(), 20);
        let clock_before = a.clock();
        assert_eq!(b.clock(), clock_before);
        let post: Vec<u32> = (0..20).map(|_| b.next_request().id.0).collect();
        assert_eq!(post[0], 20, "resumed generator restarted its id stream");
        assert!(pre.iter().all(|id| !post.contains(id)), "duplicate ids across swap");
        assert!(b.next_request().arrival > clock_before);
    }

    #[test]
    fn tenants_partition_sessions_deterministically() {
        use crate::workload::tenant::TenantClass;
        let mut spec = WorkloadSpec::default();
        spec.tenants = vec![
            TenantClass::new("interactive", 0, 0.5, 250.0, 40.0),
            TenantClass::new("batch", 1, 0.5, 2000.0, 200.0),
        ];
        let mut g = WorkloadGen::new(spec, 512, 3);
        // Same seed without tenants: identical ids/arrivals/flows (tenancy
        // adds no RNG draws), and the tenant label follows the session id.
        let mut plain = WorkloadGen::new(WorkloadSpec::default(), 512, 3);
        for _ in 0..100 {
            let (rt, rp) = (g.next_request(), plain.next_request());
            assert_eq!(rt.arrival, rp.arrival);
            assert_eq!(rt.flow, rp.flow);
            assert_eq!(rt.tenant, u8::from(rt.flow.0 >= 32));
            assert_eq!(rp.tenant, 0);
        }
    }

    #[test]
    fn rate_ramp_speeds_up_arrivals() {
        let mut spec = WorkloadSpec::default();
        spec.arrival = Arrival::Uniform { rate: 100.0 };
        spec.rate_shape = RateShape::Ramp { from: 1.0, to: 10.0, ramp_s: 0.5 };
        let mut g = WorkloadGen::new(spec, 512, 1);
        let reqs = g.take(400);
        let early_gap = (reqs[1].arrival - reqs[0].arrival).ns();
        let n = reqs.len();
        let late_gap = (reqs[n - 1].arrival - reqs[n - 2].arrival).ns();
        assert!(late_gap < early_gap, "late {late_gap} !< early {early_gap}");
    }
}
