//! The inference request model and its lifecycle states.

use crate::ids::{FlowId, NodeId, ReqId};
use crate::sim::SimTime;

/// Lifecycle of a request as it moves through the serving stack. Mirrors the
/// paper's token-lifecycle stages (ingress → PCIe feed → compute → egress).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqState {
    /// Created; in flight from the client.
    InFlight,
    /// Delivered by the NIC, waiting in the admission queue.
    Queued,
    /// Scheduled into a prefill batch.
    Prefilling,
    /// Phase transition on a disaggregated fleet: prefill finished, the
    /// sequence's KV is in flight (or parked) toward a decode-pool replica.
    KvHandoff,
    /// Generating tokens.
    Decoding,
    /// All tokens generated and flushed.
    Done,
    /// Rejected by admission control.
    Rejected,
}

/// One inference request, including its *real* prompt tokens (decoded output
/// is also real when the PJRT backend is active).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: ReqId,
    pub flow: FlowId,
    pub arrival: SimTime,
    /// Prompt token ids (toy-tokenizer output over the corpus).
    pub prompt: Vec<i32>,
    /// Generation budget for this request.
    pub max_new_tokens: usize,
    pub state: ReqState,
    /// Tenant-class index (`WorkloadSpec::tenants`); 0 when no classes are
    /// configured (the single implicit tenant).
    pub tenant: u8,
    /// Node group (replica) the router assigned.
    pub assigned_node: Option<NodeId>,

    // --- lifecycle timestamps (metrics) ---
    pub admitted_at: Option<SimTime>,
    pub prefill_start: Option<SimTime>,
    pub first_token_at: Option<SimTime>,
    pub done_at: Option<SimTime>,

    // --- phase transition (disaggregated fleets only; None/0 otherwise) ---
    /// When the KV handoff left the prefill pool.
    pub handoff_start: Option<SimTime>,
    /// When the KV handoff arrived at the decode pool.
    pub handoff_done: Option<SimTime>,
    /// Modeled handoff size: f(prompt_len, model dims) KV bytes.
    pub kv_handoff_bytes: u64,

    // --- decode progress ---
    pub generated: Vec<i32>,
}

impl InferenceRequest {
    pub fn new(id: ReqId, flow: FlowId, arrival: SimTime, prompt: Vec<i32>, max_new: usize) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        InferenceRequest {
            id,
            flow,
            arrival,
            prompt,
            max_new_tokens: max_new.max(1),
            state: ReqState::InFlight,
            tenant: 0,
            assigned_node: None,
            admitted_at: None,
            prefill_start: None,
            first_token_at: None,
            done_at: None,
            handoff_start: None,
            handoff_done: None,
            kv_handoff_bytes: 0,
            // Full-budget capacity up front so steady-state decode pushes
            // never reallocate (the zero-alloc iteration invariant).
            generated: Vec::with_capacity(max_new.max(1)),
        }
    }

    /// Did this request cross the prefill→decode pool boundary (or is it
    /// crossing it now)? Decides which router's accounting it closes.
    pub fn transitioned(&self) -> bool {
        self.handoff_start.is_some()
    }

    /// Fabric latency of the KV handoff, if it completed.
    pub fn handoff_latency(&self) -> Option<crate::sim::SimDur> {
        match (self.handoff_start, self.handoff_done) {
            (Some(s), Some(d)) => Some(d - s),
            _ => None,
        }
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    pub fn tokens_generated(&self) -> usize {
        self.generated.len()
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, ReqState::Done | ReqState::Rejected)
    }

    /// Time to first token, if reached.
    pub fn ttft(&self) -> Option<crate::sim::SimDur> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Mean time per output token after the first, if finished.
    pub fn tpot_ns(&self) -> Option<f64> {
        match (self.first_token_at, self.done_at) {
            (Some(first), Some(done)) if self.generated.len() > 1 => {
                Some((done - first).ns() as f64 / (self.generated.len() - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_metrics() {
        let mut r = InferenceRequest::new(ReqId(1), FlowId(2), SimTime(1000), vec![1, 2, 3], 4);
        assert_eq!(r.prompt_len(), 3);
        assert!(r.ttft().is_none());
        r.first_token_at = Some(SimTime(5000));
        r.done_at = Some(SimTime(11_000));
        r.generated = vec![7, 8, 9, 10];
        assert_eq!(r.ttft().unwrap().ns(), 4000);
        assert!((r.tpot_ns().unwrap() - 2000.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        InferenceRequest::new(ReqId(0), FlowId(0), SimTime(0), vec![], 1);
    }

    #[test]
    fn handoff_lifecycle_fields() {
        let mut r = InferenceRequest::new(ReqId(1), FlowId(2), SimTime(0), vec![1, 2], 4);
        assert!(!r.transitioned());
        assert!(r.handoff_latency().is_none());
        r.state = ReqState::KvHandoff;
        r.handoff_start = Some(SimTime(1_000));
        assert!(r.transitioned() && r.handoff_latency().is_none());
        r.handoff_done = Some(SimTime(3_500));
        assert_eq!(r.handoff_latency().unwrap().ns(), 2_500);
        assert!(!r.is_finished());
    }

    #[test]
    fn max_new_at_least_one() {
        let r = InferenceRequest::new(ReqId(0), FlowId(0), SimTime(0), vec![1], 0);
        assert_eq!(r.max_new_tokens, 1);
    }
}
