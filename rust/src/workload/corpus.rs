//! Embedded mini-corpus for end-to-end runs: prompts are drawn from real
//! English text so the toy tokenizer produces realistic token statistics
//! (the serving path carries *actual* token ids end to end).

/// A small public-domain-style corpus: paraphrased systems-paper prose.
pub const CORPUS: &[&str] = &[
    "Autoregressive inference in large transformer language models presents \
     significant challenges for runtime efficiency, particularly during the \
     decode phase where load imbalance across GPU shards can cause throughput \
     degradation and latency spikes.",
    "Data processing units sit inline with the network interface and process \
     all ingress and egress traffic before it reaches the host, a vantage \
     point that makes them uniquely positioned to observe network anomalies \
     that impact distributed inference.",
    "Token batching improves average throughput, but the decode phase often \
     suffers from irregularities in token computation cost, leading to skew \
     across parallel workers and idle bubbles in the pipeline.",
    "Every host to device transfer, including embeddings, key value cache \
     writes and logits, travels as direct memory access transactions across \
     the root complex where a peer device can observe them at high resolution.",
    "When phase boundaries stretch abnormally, for example a prolonged prefill \
     burst before compute begins, the observer can flag potential host side \
     tokenization or batching bottlenecks without modifying the application.",
    "Paged attention manages the key value cache like a virtual memory system, \
     reusing and evicting cache blocks so that memory is not wasted while many \
     requests share the accelerator concurrently.",
    "Microbursts are short traffic spikes that overflow switch buffers and \
     introduce jitter, while persistent congestion inflates token streaming \
     latency for every user of the cluster.",
    "The scheduler maintains the number of pending requests per batch, the \
     queue depth, and wait times, using these to drive admission control and \
     to balance throughput against latency by adjusting batch sizes.",
    "If one GPU consistently exhibits delayed bus activity after ingress, the \
     slowdown can be attributed to local imbalance such as preprocessing lag \
     rather than to network effects on the fabric.",
    "Collective operations stall waiting for the slowest peer, so a wide \
     spread between the first and last arrival of collective bursts is the \
     classic signature of a straggling shard.",
];

/// Deterministically pick a prompt string by index.
pub fn prompt(idx: usize) -> &'static str {
    CORPUS[idx % CORPUS.len()]
}

/// Concatenate prompts to reach at least `min_chars` characters.
pub fn long_prompt(start: usize, min_chars: usize) -> String {
    let mut s = String::new();
    let mut i = start;
    while s.len() < min_chars {
        s.push_str(prompt(i));
        s.push(' ');
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_nonempty_and_indexable() {
        assert!(CORPUS.len() >= 10);
        assert!(!prompt(0).is_empty());
        assert_eq!(prompt(0), prompt(CORPUS.len()));
    }

    #[test]
    fn long_prompt_reaches_length() {
        let p = long_prompt(3, 800);
        assert!(p.len() >= 800);
    }
}
