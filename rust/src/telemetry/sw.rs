//! Software-side signals (paper Table 2(b)): what the inference engine's own
//! record-keeping can see *without* a DPU.
//!
//! This is the comparison baseline for E4/E5: SW sensing has rich
//! application-level state (arrival times, queue depth, KV occupancy, decode
//! progress) but is blind to PCIe/NIC-level phenomena and pays per-sample
//! instrumentation overhead on the host.

use crate::sim::{SimDur, SimTime};
use crate::util::stats::Welford;

/// One software-observable signal class, mirroring Table 2(b) rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SwSignal {
    /// Request arrival timestamp recorded by the scheduler.
    RequestArrival,
    /// Tokenized sequence length at admission.
    SequenceLength,
    /// Tokens generated so far per running request.
    DecodeProgress,
    /// Engine queue depth / wait time.
    QueueDepth,
    /// KV-cache occupancy (pages in use).
    KvOccupancy,
    /// GPU utilization proxy (what NVML would report, sampled coarsely).
    GpuUtil,
    /// GPU memory in use.
    GpuMemory,
    /// Host<->GPU copy throughput as seen from the runtime (coarse).
    CopyThroughput,
    /// Per-iteration kernel/step execution time (CUDA-events equivalent).
    StepTime,
    /// Server transport latency per response.
    TransportLatency,
}

pub const ALL_SW_SIGNALS: [SwSignal; 10] = [
    SwSignal::RequestArrival,
    SwSignal::SequenceLength,
    SwSignal::DecodeProgress,
    SwSignal::QueueDepth,
    SwSignal::KvOccupancy,
    SwSignal::GpuUtil,
    SwSignal::GpuMemory,
    SwSignal::CopyThroughput,
    SwSignal::StepTime,
    SwSignal::TransportLatency,
];

impl SwSignal {
    pub fn name(&self) -> &'static str {
        match self {
            SwSignal::RequestArrival => "request_arrival",
            SwSignal::SequenceLength => "sequence_length",
            SwSignal::DecodeProgress => "decode_progress",
            SwSignal::QueueDepth => "queue_depth",
            SwSignal::KvOccupancy => "kv_occupancy",
            SwSignal::GpuUtil => "gpu_util",
            SwSignal::GpuMemory => "gpu_memory",
            SwSignal::CopyThroughput => "copy_throughput",
            SwSignal::StepTime => "step_time",
            SwSignal::TransportLatency => "transport_latency",
        }
    }

    /// Origin per Table 2(b): software record-keeping vs hardware counters.
    pub fn origin(&self) -> &'static str {
        match self {
            SwSignal::RequestArrival
            | SwSignal::SequenceLength
            | SwSignal::DecodeProgress
            | SwSignal::QueueDepth
            | SwSignal::KvOccupancy
            | SwSignal::TransportLatency => "SW (record keeping)",
            SwSignal::GpuUtil | SwSignal::GpuMemory => "HW counters via NVML",
            SwSignal::CopyThroughput => "HW counters via driver",
            SwSignal::StepTime => "HW accessible (CUDA events)",
        }
    }

    /// Per-sample host-side collection overhead model, in ns. SW
    /// record-keeping is cheap; NVML-style polling is notoriously not.
    pub fn overhead_ns(&self) -> u64 {
        match self {
            SwSignal::RequestArrival | SwSignal::SequenceLength => 80,
            SwSignal::DecodeProgress | SwSignal::QueueDepth => 60,
            SwSignal::KvOccupancy => 120,
            SwSignal::TransportLatency => 150,
            SwSignal::GpuUtil | SwSignal::GpuMemory => 25_000, // NVML ioctl
            SwSignal::CopyThroughput => 12_000,
            SwSignal::StepTime => 3_000, // cudaEventElapsedTime sync
        }
    }
}

/// Windowed accumulation of software signals for one engine instance.
#[derive(Debug, Clone, Default)]
pub struct SwWindow {
    stats: [Welford; ALL_SW_SIGNALS.len()],
    samples: u64,
    overhead_ns: u64,
    start: SimTime,
}

/// Snapshot of software-side features for one window.
#[derive(Debug, Clone, Default)]
pub struct SwSnapshot {
    pub start: SimTime,
    pub end: SimTime,
    pub stats: [Welford; ALL_SW_SIGNALS.len()],
    pub samples: u64,
    /// Host CPU time burned collecting these samples this window.
    pub overhead_ns: u64,
}

fn idx(sig: SwSignal) -> usize {
    ALL_SW_SIGNALS.iter().position(|s| *s == sig).unwrap()
}

impl SwWindow {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&mut self, sig: SwSignal, value: f64) {
        self.stats[idx(sig)].push(value);
        self.samples += 1;
        self.overhead_ns += sig.overhead_ns();
    }

    pub fn snapshot(&mut self, now: SimTime) -> SwSnapshot {
        let snap = SwSnapshot {
            start: self.start,
            end: now,
            stats: std::mem::take(&mut self.stats),
            samples: self.samples,
            overhead_ns: self.overhead_ns,
        };
        self.samples = 0;
        self.overhead_ns = 0;
        self.start = now;
        snap
    }
}

impl SwSnapshot {
    pub fn get(&self, sig: SwSignal) -> &Welford {
        &self.stats[idx(sig)]
    }

    pub fn duration(&self) -> SimDur {
        self.end - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let mut w = SwWindow::new();
        w.record(SwSignal::QueueDepth, 5.0);
        w.record(SwSignal::QueueDepth, 7.0);
        w.record(SwSignal::GpuUtil, 0.9);
        let s = w.snapshot(SimTime(1000));
        assert_eq!(s.get(SwSignal::QueueDepth).count(), 2);
        assert!((s.get(SwSignal::QueueDepth).mean() - 6.0).abs() < 1e-12);
        assert_eq!(s.samples, 3);
        // NVML poll dominates overhead
        assert!(s.overhead_ns > 25_000);
        // reset after snapshot
        let s2 = w.snapshot(SimTime(2000));
        assert_eq!(s2.samples, 0);
        assert_eq!(s2.start, SimTime(1000));
    }

    #[test]
    fn signal_table_is_complete() {
        for sig in ALL_SW_SIGNALS {
            assert!(!sig.name().is_empty());
            assert!(!sig.origin().is_empty());
            assert!(sig.overhead_ns() > 0);
        }
    }

    #[test]
    fn nvml_polling_costlier_than_record_keeping() {
        assert!(SwSignal::GpuUtil.overhead_ns() > 100 * SwSignal::RequestArrival.overhead_ns());
    }
}
