//! Per-node telemetry distribution: hardware models publish, observers drain.
//!
//! Single-threaded and deterministic: the scenario loop drains pending
//! events into each observer after every simulation event, so observers see
//! a causally-ordered stream exactly as a bump-in-the-wire DPU would.

use crate::ids::NodeId;
use crate::telemetry::event::{TelemetryEvent, TelemetryKind};
use crate::util::ring::Ring;
use std::collections::HashMap;

/// Pending event queues, one per node, plus class counters and an optional
/// bounded trace recorder.
#[derive(Debug)]
pub struct TelemetryBus {
    pending: Vec<Vec<TelemetryEvent>>,
    class_counts: HashMap<&'static str, u64>,
    total: u64,
    recorder: Option<Ring<TelemetryEvent>>,
}

impl TelemetryBus {
    pub fn new(n_nodes: usize) -> Self {
        TelemetryBus {
            pending: (0..n_nodes).map(|_| Vec::new()).collect(),
            class_counts: HashMap::new(),
            total: 0,
            recorder: None,
        }
    }

    /// Attach a bounded full-event recorder (debugging / evidence dumps).
    pub fn with_recorder(mut self, capacity: usize) -> Self {
        self.recorder = Some(Ring::with_capacity(capacity));
        self
    }

    pub fn n_nodes(&self) -> usize {
        self.pending.len()
    }

    /// Publish an event to its node's queue.
    #[inline]
    pub fn publish(&mut self, ev: TelemetryEvent) {
        debug_assert!((ev.node.idx()) < self.pending.len());
        self.total += 1;
        *self.class_counts.entry(ev.kind.class()).or_insert(0) += 1;
        if let Some(rec) = &mut self.recorder {
            rec.push(ev.clone());
        }
        self.pending[ev.node.idx()].push(ev);
    }

    /// Convenience: publish by parts.
    #[inline]
    pub fn emit(&mut self, t: crate::sim::SimTime, node: NodeId, kind: TelemetryKind) {
        self.publish(TelemetryEvent { t, node, kind });
    }

    /// Drain a node's pending events (ownership moves to the observer).
    pub fn drain_node(&mut self, node: NodeId) -> Vec<TelemetryEvent> {
        std::mem::take(&mut self.pending[node.idx()])
    }

    /// Visit-and-clear every node's queue.
    pub fn drain_all(&mut self, mut f: impl FnMut(NodeId, Vec<TelemetryEvent>)) {
        for i in 0..self.pending.len() {
            if !self.pending[i].is_empty() {
                f(NodeId(i as u32), std::mem::take(&mut self.pending[i]));
            }
        }
    }

    pub fn total_published(&self) -> u64 {
        self.total
    }

    pub fn count_for_class(&self, class: &str) -> u64 {
        self.class_counts.get(class).copied().unwrap_or(0)
    }

    pub fn class_counts(&self) -> &HashMap<&'static str, u64> {
        &self.class_counts
    }

    pub fn recorded(&self) -> Option<&Ring<TelemetryEvent>> {
        self.recorder.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::GpuId;
    use crate::sim::SimTime;

    fn doorbell(t: u64, node: u32) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::Doorbell { gpu: GpuId(0) },
        }
    }

    #[test]
    fn publish_and_drain_per_node() {
        let mut bus = TelemetryBus::new(2);
        bus.publish(doorbell(1, 0));
        bus.publish(doorbell(2, 1));
        bus.publish(doorbell(3, 0));
        let n0 = bus.drain_node(NodeId(0));
        assert_eq!(n0.len(), 2);
        assert!(bus.drain_node(NodeId(0)).is_empty());
        assert_eq!(bus.drain_node(NodeId(1)).len(), 1);
        assert_eq!(bus.total_published(), 3);
        assert_eq!(bus.count_for_class("doorbell"), 3);
    }

    #[test]
    fn drain_all_visits_nonempty_nodes() {
        let mut bus = TelemetryBus::new(3);
        bus.publish(doorbell(1, 0));
        bus.publish(doorbell(1, 2));
        let mut seen = Vec::new();
        bus.drain_all(|n, evs| seen.push((n, evs.len())));
        assert_eq!(seen, vec![(NodeId(0), 1), (NodeId(2), 1)]);
    }

    #[test]
    fn recorder_caps() {
        let mut bus = TelemetryBus::new(1).with_recorder(2);
        for i in 0..5 {
            bus.publish(doorbell(i, 0));
        }
        let rec = bus.recorded().unwrap();
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped(), 3);
    }
}
