//! Per-node telemetry distribution: hardware models enqueue, observers take
//! time-ordered batches.
//!
//! This is the single-dispatch fan-out stage of the event hot path. The
//! scenario loop enqueues every emission into a reusable, pre-sized per-node
//! buffer (no calendar entry, no boxing, no per-event clone) and, at each
//! window tick, `deliver_due` hands each node's due events to its observer
//! as one slice. Delivery preserves the per-event calendar semantics:
//! events are ordered by `(t, emission sequence)` per node — a stable sort
//! on `t` over the emission-ordered buffer — and an event stamped exactly
//! at the tick time is held for the next window, matching the calendar's
//! insertion-sequence tie-break for the common case of events emitted
//! within the window they land in. (An event stamped exactly on a tick
//! boundary but emitted more than a window ahead of it would, under the
//! old calendar, have slipped into the closing window; here it always
//! opens the next one. Same rule every run, so determinism is unaffected.)
//!
//! Accounting (total + per-class counters, a dense `[u64; N_CLASSES]` array
//! indexed by `TelemetryKind::class_id`) happens at delivery, so
//! `total_published` counts exactly the events observers saw. The optional
//! bounded [`Ring`] recorder is the only clone site on the pipeline; it
//! captures events in emission order.

use crate::ids::NodeId;
use crate::telemetry::event::{TelemetryEvent, TelemetryKind, CLASS_NAMES};
use crate::util::ring::Ring;
use std::collections::HashMap;

/// Initial capacity of each node's event buffer; window batches on the
/// standard scenarios run a few hundred to a few thousand events.
const NODE_BUF_CAPACITY: usize = 1024;

/// Above this node count the per-node prealloc is scaled down (fleet-stress
/// worlds run 1000+ single-node replicas; 1024 slots × 64-byte events ×
/// thousands of nodes is real memory, and huge worlds see proportionally
/// fewer events per node per window anyway). Buffers still grow on demand.
const PREALLOC_FULL_NODES: usize = 256;

/// Per-node buffer prealloc for an `n_nodes`-node bus.
fn node_buf_capacity(n_nodes: usize) -> usize {
    if n_nodes <= PREALLOC_FULL_NODES {
        NODE_BUF_CAPACITY
    } else {
        (NODE_BUF_CAPACITY * PREALLOC_FULL_NODES / n_nodes).max(64)
    }
}

/// Sort `buf` into delivery order — stable on `t`, so emission order breaks
/// ties, reproducing the calendar's `(t, seq)` order per node — and return
/// how many leading events are due (strictly before `now`). Skips the sort
/// when the buffer is already ordered, the overwhelmingly common case:
/// hardware models emit near-monotone timestamps.
pub(crate) fn sort_and_partition(buf: &mut [TelemetryEvent], now: crate::sim::SimTime) -> usize {
    if !buf.windows(2).all(|w| w[0].t <= w[1].t) {
        buf.sort_by_key(|e| e.t);
    }
    buf.partition_point(|e| e.t < now)
}

/// Reusable pending-event buffers, one per node, plus class counters and an
/// optional bounded trace recorder.
#[derive(Debug)]
pub struct TelemetryBus {
    pending: Vec<Vec<TelemetryEvent>>,
    class_counts: [u64; TelemetryKind::N_CLASSES],
    total: u64,
    recorder: Option<Ring<TelemetryEvent>>,
}

/// Snapshot/fork support. Pending events are copied field-wise rather than
/// via `TelemetryEvent::clone`, which would trip the zero-copy pipeline's
/// clone probe: a snapshot is a world copy, not a pipeline copy. The
/// recorder ring stays a plain clone — it is the sanctioned clone site.
impl Clone for TelemetryBus {
    fn clone(&self) -> Self {
        TelemetryBus {
            pending: self
                .pending
                .iter()
                .map(|buf| {
                    buf.iter()
                        .map(|e| TelemetryEvent { t: e.t, node: e.node, kind: e.kind.clone() })
                        .collect()
                })
                .collect(),
            class_counts: self.class_counts,
            total: self.total,
            recorder: self.recorder.clone(),
        }
    }
}

impl TelemetryBus {
    pub fn new(n_nodes: usize) -> Self {
        let cap = node_buf_capacity(n_nodes);
        TelemetryBus {
            pending: (0..n_nodes).map(|_| Vec::with_capacity(cap)).collect(),
            class_counts: [0; TelemetryKind::N_CLASSES],
            total: 0,
            recorder: None,
        }
    }

    /// Attach a bounded full-event recorder (debugging / evidence dumps).
    pub fn with_recorder(mut self, capacity: usize) -> Self {
        self.recorder = Some(Ring::with_capacity(capacity));
        self
    }

    pub fn n_nodes(&self) -> usize {
        self.pending.len()
    }

    /// Enqueue an event into its node's buffer. The common path moves the
    /// event straight into the reusable buffer; only the optional recorder
    /// clones.
    #[inline]
    pub fn enqueue(&mut self, ev: TelemetryEvent) {
        debug_assert!((ev.node.idx()) < self.pending.len());
        if let Some(rec) = &mut self.recorder {
            rec.push(ev.clone());
        }
        self.pending[ev.node.idx()].push(ev);
    }

    /// Convenience: enqueue by parts.
    #[inline]
    pub fn emit(&mut self, t: crate::sim::SimTime, node: NodeId, kind: TelemetryKind) {
        self.enqueue(TelemetryEvent { t, node, kind });
    }

    /// Deliver every event with `t < now` to its node's observer as one
    /// time-ordered slice, retaining later events (and the buffers'
    /// capacity) for the next window. Counts delivered events into the
    /// total/class accounting.
    pub fn deliver_due(
        &mut self,
        now: crate::sim::SimTime,
        mut f: impl FnMut(NodeId, &[TelemetryEvent]),
    ) {
        for i in 0..self.pending.len() {
            let buf = &mut self.pending[i];
            if buf.is_empty() {
                continue;
            }
            // (t, emission-order) delivery — the old calendar's order for
            // this node; already-sorted buffers skip the sort entirely.
            let due = sort_and_partition(buf, now);
            if due == 0 {
                continue;
            }
            self.total += due as u64;
            for ev in &buf[..due] {
                self.class_counts[ev.kind.class_id()] += 1;
            }
            f(NodeId(i as u32), &buf[..due]);
            buf.drain(..due);
        }
    }

    /// The per-node pending buffers, exposed for the parallel observe path
    /// (`DpuPlane::ingest_due_parallel`): each worker sorts, consumes, and
    /// drains its own nodes' buffers, then the caller folds the delivery
    /// counts back in via [`TelemetryBus::commit_delivered`] so the
    /// accounting matches a serial [`TelemetryBus::deliver_due`] exactly.
    pub fn pending_buffers_mut(&mut self) -> &mut [Vec<TelemetryEvent>] {
        &mut self.pending
    }

    /// Fold per-node delivery counts from a parallel observer back into the
    /// bus accounting. Integer sums, so the result is independent of worker
    /// scheduling.
    pub fn commit_delivered(&mut self, total: u64, class_counts: &[u64; TelemetryKind::N_CLASSES]) {
        self.total += total;
        for (acc, n) in self.class_counts.iter_mut().zip(class_counts.iter()) {
            *acc += n;
        }
    }

    /// Events enqueued but not yet delivered.
    pub fn pending_events(&self) -> usize {
        self.pending.iter().map(|b| b.len()).sum()
    }

    /// Events delivered to observers so far.
    pub fn total_published(&self) -> u64 {
        self.total
    }

    pub fn count_for_class(&self, class: &str) -> u64 {
        CLASS_NAMES
            .iter()
            .position(|&n| n == class)
            .map(|i| self.class_counts[i])
            .unwrap_or(0)
    }

    /// Dense per-class delivery counters, `class_id` order.
    pub fn class_counts(&self) -> &[u64; TelemetryKind::N_CLASSES] {
        &self.class_counts
    }

    /// Name-keyed view of the class counters (cold path: reports). Only
    /// classes actually seen carry an entry, matching the old map form.
    pub fn class_counts_map(&self) -> HashMap<&'static str, u64> {
        CLASS_NAMES
            .iter()
            .zip(self.class_counts.iter())
            .filter(|(_, &n)| n > 0)
            .map(|(&name, &n)| (name, n))
            .collect()
    }

    pub fn recorded(&self) -> Option<&Ring<TelemetryEvent>> {
        self.recorder.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::GpuId;
    use crate::sim::SimTime;

    fn doorbell(t: u64, node: u32) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::Doorbell { gpu: GpuId(0) },
        }
    }

    #[test]
    fn enqueue_and_deliver_per_node() {
        let mut bus = TelemetryBus::new(2);
        bus.enqueue(doorbell(1, 0));
        bus.enqueue(doorbell(2, 1));
        bus.enqueue(doorbell(3, 0));
        let mut seen = Vec::new();
        bus.deliver_due(SimTime(10), |n, evs| seen.push((n, evs.len())));
        assert_eq!(seen, vec![(NodeId(0), 2), (NodeId(1), 1)]);
        assert_eq!(bus.total_published(), 3);
        assert_eq!(bus.count_for_class("doorbell"), 3);
        assert_eq!(bus.pending_events(), 0);
        // Nothing left to deliver.
        bus.deliver_due(SimTime(20), |_, _| panic!("no events expected"));
    }

    #[test]
    fn delivery_holds_events_at_or_past_the_tick() {
        let mut bus = TelemetryBus::new(1);
        bus.enqueue(doorbell(5, 0));
        bus.enqueue(doorbell(10, 0)); // == tick: next window
        bus.enqueue(doorbell(15, 0)); // future: next window
        let mut delivered = Vec::new();
        bus.deliver_due(SimTime(10), |_, evs| {
            delivered.extend(evs.iter().map(|e| e.t.ns()));
        });
        assert_eq!(delivered, vec![5]);
        assert_eq!(bus.pending_events(), 2);
        assert_eq!(bus.total_published(), 1);
        bus.deliver_due(SimTime(20), |_, evs| {
            delivered.extend(evs.iter().map(|e| e.t.ns()));
        });
        assert_eq!(delivered, vec![5, 10, 15]);
        assert_eq!(bus.total_published(), 3);
    }

    #[test]
    fn delivery_is_time_ordered_with_emission_tie_break() {
        let mut bus = TelemetryBus::new(1);
        // Emitted out of time order, with a timestamp tie.
        bus.enqueue(doorbell(30, 0));
        bus.enqueue(TelemetryEvent {
            t: SimTime(10),
            node: NodeId(0),
            kind: TelemetryKind::Doorbell { gpu: GpuId(1) },
        });
        bus.enqueue(TelemetryEvent {
            t: SimTime(10),
            node: NodeId(0),
            kind: TelemetryKind::Doorbell { gpu: GpuId(2) },
        });
        let mut order = Vec::new();
        bus.deliver_due(SimTime(100), |_, evs| {
            for e in evs {
                if let TelemetryKind::Doorbell { gpu } = e.kind {
                    order.push((e.t.ns(), gpu.0));
                }
            }
        });
        // Time order, and gpu1 before gpu2 at the shared timestamp.
        assert_eq!(order, vec![(10, 1), (10, 2), (30, 0)]);
    }

    #[test]
    fn out_of_order_buffer_still_sorts() {
        // The sorted-skip fast path must not leak unsorted buffers through:
        // a deliberately out-of-order emission sequence still delivers in
        // (t, emission) order.
        let mut bus = TelemetryBus::new(1);
        for &t in &[50, 10, 40, 10, 30] {
            bus.enqueue(doorbell(t, 0));
        }
        let mut order = Vec::new();
        bus.deliver_due(SimTime(100), |_, evs| {
            order.extend(evs.iter().map(|e| e.t.ns()));
        });
        assert_eq!(order, vec![10, 10, 30, 40, 50]);
    }

    #[test]
    fn already_sorted_buffer_delivers_identically() {
        // Same events, pre-sorted (fast path) vs shuffled (sort path):
        // identical delivery.
        let deliver = |ts: &[u64]| {
            let mut bus = TelemetryBus::new(1);
            for &t in ts {
                bus.enqueue(doorbell(t, 0));
            }
            let mut order = Vec::new();
            bus.deliver_due(SimTime(100), |_, evs| {
                order.extend(evs.iter().map(|e| e.t.ns()));
            });
            order
        };
        assert_eq!(deliver(&[5, 10, 20, 20, 30]), deliver(&[20, 5, 30, 10, 20]));
    }

    #[test]
    fn parallel_commit_matches_serial_accounting() {
        let mut serial = TelemetryBus::new(2);
        let mut par = TelemetryBus::new(2);
        for bus in [&mut serial, &mut par] {
            bus.enqueue(doorbell(1, 0));
            bus.enqueue(doorbell(2, 1));
            bus.enqueue(doorbell(30, 1)); // not due
        }
        serial.deliver_due(SimTime(10), |_, _| {});
        // Parallel-shaped path: consume buffers directly, commit the sums.
        let mut total = 0u64;
        let mut classes = [0u64; TelemetryKind::N_CLASSES];
        for buf in par.pending_buffers_mut() {
            let due = sort_and_partition(buf, SimTime(10));
            total += due as u64;
            for ev in &buf[..due] {
                classes[ev.kind.class_id()] += 1;
            }
            buf.drain(..due);
        }
        par.commit_delivered(total, &classes);
        assert_eq!(par.total_published(), serial.total_published());
        assert_eq!(par.class_counts(), serial.class_counts());
        assert_eq!(par.pending_events(), serial.pending_events());
    }

    #[test]
    fn huge_fleets_scale_down_the_prealloc() {
        assert_eq!(node_buf_capacity(8), NODE_BUF_CAPACITY);
        assert_eq!(node_buf_capacity(PREALLOC_FULL_NODES), NODE_BUF_CAPACITY);
        let big = node_buf_capacity(2048);
        assert!(big < NODE_BUF_CAPACITY, "prealloc must shrink for huge fleets");
        assert!(big >= 64, "floor keeps buffers useful");
        let bus = TelemetryBus::new(2048);
        assert!(bus.pending[0].capacity() < NODE_BUF_CAPACITY);
    }

    #[test]
    fn buffers_retain_capacity_across_windows() {
        let mut bus = TelemetryBus::new(1);
        for i in 0..100 {
            bus.enqueue(doorbell(i, 0));
        }
        let cap_before = bus.pending[0].capacity();
        bus.deliver_due(SimTime(1000), |_, _| {});
        assert!(bus.pending[0].capacity() >= cap_before, "delivery shrank the buffer");
        assert_eq!(bus.pending_events(), 0);
    }

    #[test]
    fn recorder_caps() {
        let mut bus = TelemetryBus::new(1).with_recorder(2);
        for i in 0..5 {
            bus.enqueue(doorbell(i, 0));
        }
        let rec = bus.recorded().unwrap();
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped(), 3);
    }

    #[test]
    fn class_counts_map_only_carries_seen_classes() {
        let mut bus = TelemetryBus::new(1);
        bus.enqueue(doorbell(1, 0));
        bus.deliver_due(SimTime(10), |_, _| {});
        let m = bus.class_counts_map();
        assert_eq!(m.len(), 1);
        assert_eq!(m["doorbell"], 1);
    }
}
