//! Telemetry event vocabulary — everything any vantage point could observe.
//!
//! Emission is unconditional (the *cluster* produces all events); which
//! events a given observer can *see* is decided by `dpu::visibility` (the
//! DPU sees NIC + PCIe; it must NOT see NVLink, intra-GPU, or CPU-local
//! events — paper §4.3) and by `telemetry::sw` (software-level signals per
//! Table 2(b)).

use crate::ids::{CollId, FlowId, GpuId, LinkId, NodeId, QpId, ReqId, StageId};
use crate::sim::SimTime;

/// Which lifecycle phase generated a PCIe transaction (prefill bursts vs
/// decode's many small reads — §4.2's phase-level tracing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Collective operation families the fabric carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollKind {
    /// Tensor-parallel allreduce within a layer group.
    TpAllreduce,
    /// Pipeline-parallel activation handoff between stages.
    PpHandoff,
    /// Sharded KV-cache block transfer (decode phase).
    KvTransfer,
}

/// One observable happening, timestamped with sub-microsecond resolution.
#[derive(Debug, Clone, PartialEq)]
pub enum TelemetryKind {
    // ---- PCIe observer vantage (DPU-visible, Table 3b) ----
    /// Host-to-device DMA completion.
    DmaH2d { gpu: GpuId, bytes: u64, latency_ns: u64, phase: Phase },
    /// Device-to-host DMA completion.
    DmaD2h { gpu: GpuId, bytes: u64, latency_ns: u64, phase: Phase },
    /// Kernel-launch doorbell write observed on the root complex.
    Doorbell { gpu: GpuId },
    /// Memory registration (map/unmap) around DMA buffers.
    MemRegistration { gpu: GpuId, bytes: u64, unmap: bool },
    /// GPU peer-to-peer DMA routed over PCIe (NVLink P2P is a separate,
    /// DPU-invisible event).
    P2pPcie { from: GpuId, to: GpuId, bytes: u64, latency_ns: u64 },
    /// Periodic PCIe link busy-fraction sample.
    PcieUtil { link: LinkId, busy: f64 },

    // ---- NIC vantage, north-south (DPU-visible, Table 3a) ----
    /// Ingress packet/burst delivered to the host.
    NicRx { flow: FlowId, bytes: u64, queue_depth: u32 },
    /// Egress packet leaving the NIC; `wait_ns` = time spent queued.
    NicTx { flow: FlowId, bytes: u64, queue_depth: u32, wait_ns: u64 },
    /// Retransmission observed (dup ACK / handshake retry / storm member).
    /// `fabric` marks east-west RDMA retransmits vs north-south client flows.
    Retransmit { flow: FlowId, ingress: bool, fabric: bool },
    /// Packet drop inside NIC queues.
    PktDrop { flow: FlowId, ingress: bool, fabric: bool },
    /// An egress response stream finished (last token sent).
    FlowEnd { flow: FlowId, req: ReqId },

    // ---- NIC vantage, east-west (DPU-visible, Table 3c) ----
    /// One rank's burst for a collective arrived at this node's NIC.
    CollectiveBurst {
        coll: CollId,
        kind: CollKind,
        from_node: NodeId,
        rank: u32,
        expected_ranks: u32,
        bytes: u64,
        /// Send-to-arrival latency of this rank's burst, ns.
        latency_ns: u64,
    },
    /// Pipeline stage handoff burst observed leaving (`outbound`) the
    /// source node or arriving at the destination.
    StageHandoff {
        from_stage: StageId,
        to_stage: StageId,
        bytes: u64,
        outbound: bool,
        phase: Phase,
    },
    /// RDMA op completed; `credit_wait_ns` = stall waiting for remote
    /// credit, `latency_ns` = send-to-arrival path latency (DPUs derive this
    /// from RDMA ACK timing / header timestamps).
    RdmaOp { qp: QpId, bytes: u64, credit_wait_ns: u64, latency_ns: u64 },
    /// Remote credit update arrived for a QP.
    CreditUpdate { qp: QpId },

    // ---- DPU-INVISIBLE events (paper §4.3) ----
    /// GPU-to-GPU transfer over NVLink/NVSwitch — bypasses the root complex.
    NvlinkBurst { from: GpuId, to: GpuId, bytes: u64 },
    /// Intra-GPU kernel execution (never traverses PCIe).
    GpuKernel { gpu: GpuId, dur_ns: u64, flops: f64 },
    /// CPU-local work (tokenization, scheduling) with no PCIe/NIC footprint.
    CpuLocal { dur_ns: u64 },
}

/// A timestamped, node-attributed telemetry record.
///
/// `Clone` is implemented by hand so the `perf-probe` build can count every
/// clone on the ingest path: the batched bus → agent pipeline must move or
/// borrow events, never copy them (the optional recorder ring is the one
/// sanctioned clone site).
#[derive(Debug, PartialEq)]
pub struct TelemetryEvent {
    pub t: SimTime,
    pub node: NodeId,
    pub kind: TelemetryKind,
}

impl Clone for TelemetryEvent {
    fn clone(&self) -> Self {
        crate::util::perf::probe::count_event_clone();
        TelemetryEvent { t: self.t, node: self.node, kind: self.kind.clone() }
    }
}

/// Class labels in `class_id` order (dense per-class accounting).
pub const CLASS_NAMES: [&str; TelemetryKind::N_CLASSES] = [
    "dma_h2d",
    "dma_d2h",
    "doorbell",
    "mem_reg",
    "p2p_pcie",
    "pcie_util",
    "nic_rx",
    "nic_tx",
    "retransmit",
    "pkt_drop",
    "flow_end",
    "collective",
    "stage_handoff",
    "rdma_op",
    "credit_update",
    "nvlink",
    "gpu_kernel",
    "cpu_local",
];

impl TelemetryKind {
    /// Number of distinct event classes (the span of `class_id`).
    pub const N_CLASSES: usize = 18;

    /// Dense class index for array-based per-class counters — the hot-path
    /// replacement for string-keyed accounting.
    #[inline]
    pub fn class_id(&self) -> usize {
        use TelemetryKind::*;
        match self {
            DmaH2d { .. } => 0,
            DmaD2h { .. } => 1,
            Doorbell { .. } => 2,
            MemRegistration { .. } => 3,
            P2pPcie { .. } => 4,
            PcieUtil { .. } => 5,
            NicRx { .. } => 6,
            NicTx { .. } => 7,
            Retransmit { .. } => 8,
            PktDrop { .. } => 9,
            FlowEnd { .. } => 10,
            CollectiveBurst { .. } => 11,
            StageHandoff { .. } => 12,
            RdmaOp { .. } => 13,
            CreditUpdate { .. } => 14,
            NvlinkBurst { .. } => 15,
            GpuKernel { .. } => 16,
            CpuLocal { .. } => 17,
        }
    }

    /// Short class label, used in reports and per-class accounting.
    pub fn class(&self) -> &'static str {
        CLASS_NAMES[self.class_id()]
    }

    /// Is this event observable from the DPU vantage point (NIC inline +
    /// PCIe peer)? Encodes paper §4.1-§4.3.
    pub fn dpu_visible(&self) -> bool {
        use TelemetryKind::*;
        !matches!(self, NvlinkBurst { .. } | GpuKernel { .. } | CpuLocal { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visibility_boundary_matches_paper() {
        // §4.2: PCIe + NIC traffic is visible.
        assert!(TelemetryKind::DmaH2d {
            gpu: GpuId(0), bytes: 1, latency_ns: 1, phase: Phase::Prefill
        }
        .dpu_visible());
        assert!(TelemetryKind::Doorbell { gpu: GpuId(0) }.dpu_visible());
        assert!(TelemetryKind::NicRx { flow: FlowId(0), bytes: 1, queue_depth: 0 }.dpu_visible());
        assert!(TelemetryKind::RdmaOp { qp: QpId(0), bytes: 1, credit_wait_ns: 0, latency_ns: 0 }.dpu_visible());
        // §4.3: NVLink, intra-GPU, CPU-local are NOT.
        assert!(!TelemetryKind::NvlinkBurst { from: GpuId(0), to: GpuId(1), bytes: 1 }
            .dpu_visible());
        assert!(!TelemetryKind::GpuKernel { gpu: GpuId(0), dur_ns: 1, flops: 1.0 }.dpu_visible());
        assert!(!TelemetryKind::CpuLocal { dur_ns: 1 }.dpu_visible());
    }

    #[test]
    fn classes_are_distinct() {
        let classes = [
            TelemetryKind::Doorbell { gpu: GpuId(0) }.class(),
            TelemetryKind::NicRx { flow: FlowId(0), bytes: 0, queue_depth: 0 }.class(),
            TelemetryKind::CreditUpdate { qp: QpId(0) }.class(),
        ];
        assert_eq!(classes.len(), 3);
        assert_ne!(classes[0], classes[1]);
        assert_ne!(classes[1], classes[2]);
    }

    #[test]
    fn class_ids_are_dense_and_name_aligned() {
        // Every name is distinct and class() goes through the dense table.
        for (i, a) in CLASS_NAMES.iter().enumerate() {
            for b in CLASS_NAMES.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
        let ev = TelemetryKind::RdmaOp { qp: QpId(1), bytes: 8, credit_wait_ns: 0, latency_ns: 1 };
        assert!(ev.class_id() < TelemetryKind::N_CLASSES);
        assert_eq!(CLASS_NAMES[ev.class_id()], ev.class());
        assert_eq!(TelemetryKind::CpuLocal { dur_ns: 1 }.class_id(), TelemetryKind::N_CLASSES - 1);
    }
}
