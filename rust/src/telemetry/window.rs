//! Sliding-window feature extraction over telemetry streams.
//!
//! [`WindowAccum`] ingests [`TelemetryEvent`]s in O(1) each (this is the DPU
//! hot path — see EXPERIMENTS.md §Perf) and produces a [`WindowSnapshot`] at
//! every window tick. Detectors consume snapshots, never raw events.
//!
//! Cross-window state (last-event times for gap statistics, flow lifetimes,
//! in-flight collective trackers) survives the snapshot; per-window
//! accumulators reset.

use crate::util::fastmap::FastMap;

use crate::ids::{CollId, FlowId, NodeId};
use crate::sim::{SimDur, SimTime};
use crate::telemetry::event::{CollKind, Phase, TelemetryEvent, TelemetryKind};
use crate::util::stats::Welford;

/// Per-direction transfer statistics for one window.
#[derive(Debug, Clone, Default)]
pub struct XferStats {
    pub count: u64,
    pub bytes: Welford,
    pub gap_ns: Welford,
    pub latency_ns: Welford,
    /// Counts split by lifecycle phase (prefill vs decode), §4.2 tracing.
    pub prefill_count: u64,
    pub decode_count: u64,
    /// Decode-phase transaction sizes (batch shrinkage shows here, PC10).
    pub decode_bytes: Welford,
}

impl XferStats {
    fn record(&mut self, bytes: u64, latency_ns: u64, phase: Option<Phase>) {
        self.count += 1;
        self.bytes.push(bytes as f64);
        self.latency_ns.push(latency_ns as f64);
        match phase {
            Some(Phase::Prefill) => self.prefill_count += 1,
            Some(Phase::Decode) => {
                self.decode_count += 1;
                self.decode_bytes.push(bytes as f64);
            }
            None => {}
        }
    }

    pub fn total_bytes(&self) -> f64 {
        self.bytes.mean() * self.count as f64
    }
}

/// Per-GPU activity within one window (intra-node skew detection, PC4/PC10).
#[derive(Debug, Clone, Default)]
pub struct GpuWindow {
    pub h2d_count: u64,
    pub h2d_bytes: u64,
    pub d2h_count: u64,
    pub d2h_bytes: u64,
    pub doorbell_count: u64,
    pub p2p_count: u64,
}

/// Lifetime state for one flow (persists across windows).
#[derive(Debug, Clone)]
pub struct FlowState {
    pub first_seen: SimTime,
    pub last_tx: Option<SimTime>,
    pub ended: bool,
    pub total_tx_count: u64,
    pub total_rx_bytes: u64,
    // per-window accumulators (reset each snapshot)
    pub win_rx_bytes: u64,
    pub win_tx_count: u64,
    pub win_tx_gap: Welford,
    pub win_rx_gap: Welford,
    pub last_rx: Option<SimTime>,
}

impl FlowState {
    fn new(t: SimTime) -> Self {
        FlowState {
            first_seen: t,
            last_tx: None,
            ended: false,
            total_tx_count: 0,
            total_rx_bytes: 0,
            win_rx_bytes: 0,
            win_tx_count: 0,
            win_tx_gap: Welford::new(),
            win_rx_gap: Welford::new(),
            last_rx: None,
        }
    }
}

/// In-flight collective arrival tracker.
#[derive(Debug, Clone)]
struct CollTrack {
    kind: CollKind,
    first: SimTime,
    last: SimTime,
    seen: u32,
    expected: u32,
    bytes_per_rank: Welford,
}

/// Per-collective-kind window statistics.
#[derive(Debug, Clone, Default)]
pub struct CollStats {
    pub completed: u64,
    pub stalled: u64,
    /// Max-min arrival spread of completed collectives (ns) — the TP
    /// straggler red flag.
    pub spread_ns: Welford,
    pub bytes_per_rank_cov: Welford,
    pub burst_count: u64,
    pub total_bytes: u64,
    /// Per-burst send-to-arrival latency (ns).
    pub latency_ns: Welford,
}

/// One finished window of DPU-observable features for a node.
#[derive(Debug, Clone, Default)]
pub struct WindowSnapshot {
    pub node: NodeId,
    pub start: SimTime,
    pub end: SimTime,

    // PCIe observer
    pub h2d: XferStats,
    pub d2h: XferStats,
    pub doorbell_count: u64,
    pub doorbell_gap_ns: Welford,
    /// Gap from an H2D completion to the next doorbell on the same GPU —
    /// long gaps mean the GPU got data but nothing launched (PC1/PC3/PC8).
    pub h2d_to_doorbell_ns: Welford,
    pub mem_reg_count: u64,
    pub mem_unreg_count: u64,
    pub p2p_pcie: XferStats,
    pub pcie_busy: Welford,
    pub per_gpu: Vec<GpuWindow>,

    // NIC north-south
    pub nic_rx_count: u64,
    pub nic_rx_bytes: u64,
    pub nic_rx_gap_ns: Welford,
    pub nic_rx_qdepth: Welford,
    pub nic_tx_count: u64,
    pub nic_tx_bytes: u64,
    pub nic_tx_gap_ns: Welford,
    pub nic_tx_qdepth: Welford,
    pub nic_tx_wait_ns: Welford,
    pub retx_in: u64,
    pub retx_out: u64,
    pub retx_fabric: u64,
    pub drop_in: u64,
    pub drop_out: u64,
    pub drop_fabric: u64,
    pub flow_ends: u64,
    pub active_flows: u64,
    /// Dispersion of per-flow ingress volume across flows active this window
    /// (flow skew, NS3).
    pub flow_rx_dispersion: Welford,
    /// EWMA share of ingress bytes owned by the hottest flow (NS3): a
    /// decayed per-flow byte counter smoothed across windows.
    pub top_flow_share: f64,
    /// Mean per-flow egress inter-departure CoV (egress jitter, NS6).
    pub egress_jitter_cov: f64,
    /// Flows that ended this window with ≪ median egress activity of their
    /// still-active peers (early completion skew, NS8).
    pub early_end_count: u64,
    /// Median egress length of flows ending this window relative to the
    /// median of still-active peers (1.0 = equal; small = early stops).
    pub end_len_ratio: f64,
    /// Dispersion (CoV) of completed flows' egress lengths this window —
    /// bimodal completions (early stops among long peers) inflate this.
    pub ended_len_cov: f64,

    // East-west
    pub tp: CollStats,
    pub pp: CollStats,
    pub kv: CollStats,
    /// Gap between successive stage-handoff bursts (PP bubble, EW2).
    pub handoff_gap_ns: Welford,
    pub handoff_count: u64,
    pub handoff_bytes: u64,
    /// Gap from this node's last kernel doorbell to its outbound handoff
    /// send — the stage's compute span, observable at the source (EW2).
    /// Decode-phase only: prefill spans are ms-scale and would swamp it.
    pub db_to_handoff_ns: Welford,
    /// Per-source-node collective bytes dispersion (cross-node skew, EW3).
    pub node_coll_dispersion: Welford,
    pub rdma_count: u64,
    pub rdma_credit_wait_ns: Welford,
    pub rdma_latency_ns: Welford,
    pub credit_update_gap_ns: Welford,
}

impl WindowSnapshot {
    pub fn duration(&self) -> SimDur {
        self.end - self.start
    }

    fn dur_s(&self) -> f64 {
        self.duration().as_secs_f64().max(1e-9)
    }

    /// Events/sec style rate helpers used by the detectors.
    pub fn h2d_rate(&self) -> f64 {
        self.h2d.count as f64 / self.dur_s()
    }

    pub fn d2h_rate(&self) -> f64 {
        self.d2h.count as f64 / self.dur_s()
    }

    pub fn rx_byte_rate(&self) -> f64 {
        self.nic_rx_bytes as f64 / self.dur_s()
    }

    pub fn tx_byte_rate(&self) -> f64 {
        self.nic_tx_bytes as f64 / self.dur_s()
    }

    pub fn doorbell_rate(&self) -> f64 {
        self.doorbell_count as f64 / self.dur_s()
    }

    pub fn pcie_byte_rate(&self) -> f64 {
        (self.h2d.total_bytes() + self.d2h.total_bytes() + self.p2p_pcie.total_bytes())
            / self.dur_s()
    }
}

/// Streaming accumulator; one per (node, vantage).
#[derive(Debug, Clone)]
pub struct WindowAccum {
    node: NodeId,
    n_gpus_hint: usize,
    window_start: SimTime,

    cur: WindowSnapshot,

    // cross-window gap state
    last_h2d: Option<SimTime>,
    last_d2h: Option<SimTime>,
    last_doorbell: Option<SimTime>,
    last_h2d_per_gpu: FastMap<u32, SimTime>,
    last_rx: Option<SimTime>,
    last_tx: Option<SimTime>,
    last_handoff: Option<SimTime>,
    last_credit: FastMap<u32, SimTime>,

    flows: FastMap<u32, FlowState>,
    colls: FastMap<u32, CollTrack>,
    node_coll_bytes: FastMap<u32, u64>,
    /// Decayed cumulative RX bytes per flow (NS3 skew feature).
    flow_rx_ewma: FastMap<u32, f64>,

    /// Scratch buffers for the snapshot-time median features; cleared and
    /// reused every window so the steady state allocates nothing.
    active_tx_scratch: Vec<f64>,
    ended_tx_scratch: Vec<f64>,
}

/// Cap on tracked flows; beyond this, new flows share an overflow bucket.
/// A real DPU flow table is similarly bounded (CAM/SRAM limits).
const FLOW_TABLE_CAP: usize = 4096;
/// Warm-start capacity of the flow-keyed maps: large enough that the
/// standard scenarios never rehash on the hot path, small enough that a
/// many-node fleet stays cheap to build.
const FLOW_WARM_CAPACITY: usize = 256;
/// Collectives that have not completed within this many ns by snapshot time
/// count as stalled.
const COLL_STALL_NS: u64 = 50_000_000; // 50 ms

/// A `FastMap` pre-sized to `n` entries (capacity hints from cluster shape).
fn warm_map<K, V>(n: usize) -> FastMap<K, V> {
    FastMap::with_capacity_and_hasher(n, Default::default())
}

/// Median via in-place quickselect over a reusable scratch buffer (upper
/// median, matching `sorted[len / 2]`). Returns `None` on an empty slice —
/// an all-idle window must not panic.
fn median_of(xs: &mut [f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mid = xs.len() / 2;
    let (_, m, _) = xs.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
    Some(*m)
}

impl WindowAccum {
    pub fn new(node: NodeId, n_gpus_hint: usize) -> Self {
        Self::with_hints(node, n_gpus_hint, 8)
    }

    /// Build with cluster-shape capacity hints so the per-event maps never
    /// rehash mid-run: `n_nodes_hint` sizes the per-source collective
    /// ledger, the GPU count sizes the per-GPU gap state, and the flow maps
    /// warm-start at a fleet-scale working set.
    pub fn with_hints(node: NodeId, n_gpus_hint: usize, n_nodes_hint: usize) -> Self {
        let mut cur = WindowSnapshot::default();
        cur.node = node;
        cur.per_gpu = vec![GpuWindow::default(); n_gpus_hint];
        WindowAccum {
            node,
            n_gpus_hint,
            window_start: SimTime::ZERO,
            cur,
            last_h2d: None,
            last_d2h: None,
            last_doorbell: None,
            last_h2d_per_gpu: warm_map(n_gpus_hint.max(1)),
            last_rx: None,
            last_tx: None,
            last_handoff: None,
            last_credit: warm_map(4 * n_nodes_hint.max(1)),
            flows: warm_map(FLOW_WARM_CAPACITY),
            colls: warm_map(64),
            node_coll_bytes: warm_map(n_nodes_hint.max(1)),
            flow_rx_ewma: warm_map(FLOW_WARM_CAPACITY),
            active_tx_scratch: Vec::with_capacity(FLOW_WARM_CAPACITY),
            ended_tx_scratch: Vec::with_capacity(64),
        }
    }

    fn gpu_slot(&mut self, gpu_global: u32) -> &mut GpuWindow {
        // Per-node GPU indices: global id modulo the node's GPU count.
        let idx = (gpu_global as usize) % self.n_gpus_hint.max(1);
        &mut self.cur.per_gpu[idx]
    }

    /// Ingest one event. O(1); the telemetry hot path.
    pub fn ingest(&mut self, ev: &TelemetryEvent) {
        debug_assert_eq!(ev.node, self.node);
        let t = ev.t;
        match &ev.kind {
            TelemetryKind::DmaH2d { gpu, bytes, latency_ns, phase } => {
                if let Some(prev) = self.last_h2d.replace(t) {
                    self.cur.h2d.gap_ns.push((t - prev).ns() as f64);
                }
                self.cur.h2d.record(*bytes, *latency_ns, Some(*phase));
                self.last_h2d_per_gpu.insert(gpu.0, t);
                let slot = self.gpu_slot(gpu.0);
                slot.h2d_count += 1;
                slot.h2d_bytes += bytes;
            }
            TelemetryKind::DmaD2h { gpu, bytes, latency_ns, phase } => {
                if let Some(prev) = self.last_d2h.replace(t) {
                    self.cur.d2h.gap_ns.push((t - prev).ns() as f64);
                }
                self.cur.d2h.record(*bytes, *latency_ns, Some(*phase));
                let slot = self.gpu_slot(gpu.0);
                slot.d2h_count += 1;
                slot.d2h_bytes += bytes;
            }
            TelemetryKind::Doorbell { gpu } => {
                self.cur.doorbell_count += 1;
                if let Some(prev) = self.last_doorbell.replace(t) {
                    self.cur.doorbell_gap_ns.push((t - prev).ns() as f64);
                }
                if let Some(h2d_t) = self.last_h2d_per_gpu.get(&gpu.0) {
                    self.cur.h2d_to_doorbell_ns.push((t - *h2d_t).ns() as f64);
                }
                self.gpu_slot(gpu.0).doorbell_count += 1;
            }
            TelemetryKind::MemRegistration { unmap, .. } => {
                if *unmap {
                    self.cur.mem_unreg_count += 1;
                } else {
                    self.cur.mem_reg_count += 1;
                }
            }
            TelemetryKind::P2pPcie { from, bytes, latency_ns, .. } => {
                self.cur.p2p_pcie.record(*bytes, *latency_ns, None);
                self.gpu_slot(from.0).p2p_count += 1;
            }
            TelemetryKind::PcieUtil { busy, .. } => {
                self.cur.pcie_busy.push(*busy);
            }
            TelemetryKind::NicRx { flow, bytes, queue_depth } => {
                self.cur.nic_rx_count += 1;
                self.cur.nic_rx_bytes += bytes;
                self.cur.nic_rx_qdepth.push(*queue_depth as f64);
                if let Some(prev) = self.last_rx.replace(t) {
                    self.cur.nic_rx_gap_ns.push((t - prev).ns() as f64);
                }
                *self.flow_rx_ewma.entry(flow.0).or_insert(0.0) += *bytes as f64;
                let fs = self.flow_entry(*flow, t);
                fs.total_rx_bytes += bytes;
                fs.win_rx_bytes += bytes;
                if let Some(prev) = fs.last_rx.replace(t) {
                    fs.win_rx_gap.push((t - prev).ns() as f64);
                }
            }
            TelemetryKind::NicTx { flow, bytes, queue_depth, wait_ns } => {
                self.cur.nic_tx_count += 1;
                self.cur.nic_tx_bytes += bytes;
                self.cur.nic_tx_qdepth.push(*queue_depth as f64);
                self.cur.nic_tx_wait_ns.push(*wait_ns as f64);
                if let Some(prev) = self.last_tx.replace(t) {
                    self.cur.nic_tx_gap_ns.push((t - prev).ns() as f64);
                }
                let fs = self.flow_entry(*flow, t);
                fs.total_tx_count += 1;
                fs.win_tx_count += 1;
                if let Some(prev) = fs.last_tx.replace(t) {
                    fs.win_tx_gap.push((t - prev).ns() as f64);
                }
            }
            TelemetryKind::Retransmit { ingress, fabric, .. } => {
                if *fabric {
                    self.cur.retx_fabric += 1;
                } else if *ingress {
                    self.cur.retx_in += 1;
                } else {
                    self.cur.retx_out += 1;
                }
            }
            TelemetryKind::PktDrop { ingress, fabric, .. } => {
                if *fabric {
                    self.cur.drop_fabric += 1;
                } else if *ingress {
                    self.cur.drop_in += 1;
                } else {
                    self.cur.drop_out += 1;
                }
            }
            TelemetryKind::FlowEnd { flow, .. } => {
                self.cur.flow_ends += 1;
                let fs = self.flow_entry(*flow, t);
                fs.ended = true;
            }
            TelemetryKind::CollectiveBurst {
                coll, kind, from_node, expected_ranks, bytes, latency_ns, ..
            } => {
                *self.node_coll_bytes.entry(from_node.0).or_insert(0) += bytes;
                let stats = self.coll_stats_mut(*kind);
                stats.burst_count += 1;
                stats.total_bytes += bytes;
                stats.latency_ns.push(*latency_ns as f64);
                let tr = self.colls.entry(coll.0).or_insert_with(|| CollTrack {
                    kind: *kind,
                    first: t,
                    last: t,
                    seen: 0,
                    expected: *expected_ranks,
                    bytes_per_rank: Welford::new(),
                });
                tr.seen += 1;
                tr.last = t;
                tr.bytes_per_rank.push(*bytes as f64);
                if tr.seen >= tr.expected {
                    let spread = (tr.last - tr.first).ns() as f64;
                    let cov = tr.bytes_per_rank.cov();
                    let kind = tr.kind;
                    self.colls.remove(&coll.0);
                    let stats = self.coll_stats_mut(kind);
                    stats.completed += 1;
                    stats.spread_ns.push(spread);
                    stats.bytes_per_rank_cov.push(cov);
                }
            }
            TelemetryKind::StageHandoff { bytes, outbound, phase, .. } => {
                if *outbound {
                    // Source-side: measure the stage's compute span (last
                    // doorbell -> handoff send). Decode only: prefill spans
                    // are orders of magnitude longer and poison the stat.
                    if *phase == Phase::Decode {
                        if let Some(db) = self.last_doorbell {
                            self.cur.db_to_handoff_ns.push((t - db).ns() as f64);
                        }
                    }
                } else {
                    self.cur.handoff_count += 1;
                    self.cur.handoff_bytes += bytes;
                    if let Some(prev) = self.last_handoff.replace(t) {
                        self.cur.handoff_gap_ns.push((t - prev).ns() as f64);
                    }
                }
            }
            TelemetryKind::RdmaOp { bytes: _, credit_wait_ns, latency_ns, .. } => {
                self.cur.rdma_count += 1;
                self.cur.rdma_credit_wait_ns.push(*credit_wait_ns as f64);
                self.cur.rdma_latency_ns.push(*latency_ns as f64);
            }
            TelemetryKind::CreditUpdate { qp } => {
                if let Some(prev) = self.last_credit.insert(qp.0, t) {
                    self.cur.credit_update_gap_ns.push((t - prev).ns() as f64);
                }
            }
            // DPU-invisible kinds must be filtered by the caller
            // (dpu::visibility); if they reach here we're a software observer
            // that can legitimately count them — ignore for window features.
            TelemetryKind::NvlinkBurst { .. }
            | TelemetryKind::GpuKernel { .. }
            | TelemetryKind::CpuLocal { .. } => {}
        }
    }

    fn flow_entry(&mut self, flow: FlowId, t: SimTime) -> &mut FlowState {
        if self.flows.len() >= FLOW_TABLE_CAP && !self.flows.contains_key(&flow.0) {
            // overflow bucket: fold into flow 0 semantics
            return self.flows.entry(u32::MAX).or_insert_with(|| FlowState::new(t));
        }
        self.flows.entry(flow.0).or_insert_with(|| FlowState::new(t))
    }

    fn coll_stats_mut(&mut self, kind: CollKind) -> &mut CollStats {
        match kind {
            CollKind::TpAllreduce => &mut self.cur.tp,
            CollKind::PpHandoff => &mut self.cur.pp,
            CollKind::KvTransfer => &mut self.cur.kv,
        }
    }

    /// Close the window at `now`, emit the snapshot, and reset per-window state.
    pub fn snapshot(&mut self, now: SimTime) -> WindowSnapshot {
        self.snapshot_reusing(now, None)
    }

    /// [`WindowAccum::snapshot`], recycling a spent snapshot's heap storage
    /// (the per-GPU lane vector) as the next window's accumulator, so
    /// steady-state ticks allocate nothing. The caller hands back a
    /// snapshot it has finished with — the agent's history eviction.
    pub fn snapshot_reusing(
        &mut self,
        now: SimTime,
        spare: Option<WindowSnapshot>,
    ) -> WindowSnapshot {
        // Finalize flow-derived dispersion features. The median inputs go
        // into scratch buffers that persist across windows (capacity reuse;
        // quickselect instead of clone + full sort).
        let mut active = 0u64;
        let mut rx_disp = Welford::new();
        let mut jitter_sum = 0.0;
        let mut jitter_n = 0u64;
        self.active_tx_scratch.clear();
        self.ended_tx_scratch.clear();
        for fs in self.flows.values() {
            if fs.ended {
                self.ended_tx_scratch.push(fs.total_tx_count as f64);
                continue;
            }
            active += 1;
            if fs.win_rx_bytes > 0 {
                rx_disp.push(fs.win_rx_bytes as f64);
            }
            if fs.win_tx_gap.count() >= 3 {
                jitter_sum += fs.win_tx_gap.cov();
                jitter_n += 1;
            }
            if fs.win_tx_count > 0 {
                self.active_tx_scratch.push(fs.total_tx_count as f64);
            }
        }
        self.cur.active_flows = active;
        self.cur.flow_rx_dispersion = rx_disp;
        self.cur.egress_jitter_cov = if jitter_n > 0 { jitter_sum / jitter_n as f64 } else { 0.0 };
        // Early-end: flows that ended this window with well under the median
        // egress activity of still-active peers. `median_of` is None on an
        // all-idle window (no active egress / no completions), which must
        // leave the defaults untouched rather than panic.
        self.cur.early_end_count = 0;
        self.cur.end_len_ratio = 1.0;
        self.cur.ended_len_cov = 0.0;
        if self.ended_tx_scratch.len() >= 3 {
            let mut w = Welford::new();
            for &e in &self.ended_tx_scratch {
                w.push(e);
            }
            self.cur.ended_len_cov = w.cov();
        }
        if self.cur.flow_ends > 0 {
            if let (Some(median), true) =
                (median_of(&mut self.active_tx_scratch), !self.ended_tx_scratch.is_empty())
            {
                self.cur.early_end_count = self
                    .ended_tx_scratch
                    .iter()
                    .filter(|&&txc| txc < 0.5 * median && median >= 3.0)
                    .count() as u64;
                let end_median =
                    median_of(&mut self.ended_tx_scratch).expect("non-empty by guard");
                if median >= 1.0 {
                    self.cur.end_len_ratio = (end_median / median).min(4.0);
                }
            }
        }

        // Top-flow share from the decayed per-flow RX counters.
        let total_ewma: f64 = self.flow_rx_ewma.values().sum();
        let top_ewma = self.flow_rx_ewma.values().fold(0.0_f64, |acc, &v| acc.max(v));
        self.cur.top_flow_share = if total_ewma > 1.0 { top_ewma / total_ewma } else { 0.0 };
        for v in self.flow_rx_ewma.values_mut() {
            *v *= 0.95;
        }
        self.flow_rx_ewma.retain(|_, v| *v > 1.0);

        // Cross-node collective byte dispersion.
        let mut nd = Welford::new();
        for &b in self.node_coll_bytes.values() {
            nd.push(b as f64);
        }
        self.cur.node_coll_dispersion = nd;

        // Stalled collectives: in flight and old.
        let stall_before = SimTime(now.ns().saturating_sub(COLL_STALL_NS));
        let mut stalled: Vec<u32> = Vec::new();
        for (id, tr) in &self.colls {
            if tr.first <= stall_before {
                stalled.push(*id);
            }
        }
        for id in stalled {
            if let Some(tr) = self.colls.remove(&id) {
                self.coll_stats_mut(tr.kind).stalled += 1;
            }
        }

        let mut snap = WindowSnapshot::default();
        snap.node = self.node;
        snap.per_gpu = match spare {
            // Reuse the retired snapshot's lane vector in place of a fresh
            // allocation; contents are overwritten to defaults.
            Some(mut old) => {
                let mut lanes = std::mem::take(&mut old.per_gpu);
                lanes.clear();
                lanes.resize(self.n_gpus_hint, GpuWindow::default());
                lanes
            }
            None => vec![GpuWindow::default(); self.n_gpus_hint],
        };
        std::mem::swap(&mut snap, &mut self.cur);
        snap.start = self.window_start;
        snap.end = now;
        self.window_start = now;

        // Reset per-window flow accumulators; drop ended flows (their
        // lifetime stats have been consumed).
        self.flows.retain(|_, fs| !fs.ended);
        for fs in self.flows.values_mut() {
            fs.win_rx_bytes = 0;
            fs.win_tx_count = 0;
            fs.win_tx_gap = Welford::new();
            fs.win_rx_gap = Welford::new();
        }
        self.node_coll_bytes.clear();
        snap
    }

    pub fn tracked_flows(&self) -> usize {
        self.flows.len()
    }

    pub fn inflight_collectives(&self) -> usize {
        self.colls.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{GpuId, QpId, ReqId, StageId};

    fn ev(t: u64, kind: TelemetryKind) -> TelemetryEvent {
        TelemetryEvent { t: SimTime(t), node: NodeId(0), kind }
    }

    #[test]
    fn h2d_gap_and_rate() {
        let mut w = WindowAccum::new(NodeId(0), 2);
        for i in 0..10u64 {
            w.ingest(&ev(
                i * 1000,
                TelemetryKind::DmaH2d {
                    gpu: GpuId(0),
                    bytes: 4096,
                    latency_ns: 500,
                    phase: Phase::Prefill,
                },
            ));
        }
        let s = w.snapshot(SimTime(10_000));
        assert_eq!(s.h2d.count, 10);
        assert_eq!(s.h2d.prefill_count, 10);
        assert!((s.h2d.gap_ns.mean() - 1000.0).abs() < 1e-9);
        assert!((s.h2d_rate() - 1e6).abs() / 1e6 < 0.01);
    }

    #[test]
    fn gap_state_survives_snapshot() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(1000, TelemetryKind::Doorbell { gpu: GpuId(0) }));
        let _ = w.snapshot(SimTime(2000));
        w.ingest(&ev(3000, TelemetryKind::Doorbell { gpu: GpuId(0) }));
        let s = w.snapshot(SimTime(4000));
        // Gap spans the window boundary: 3000-1000.
        assert_eq!(s.doorbell_gap_ns.count(), 1);
        assert!((s.doorbell_gap_ns.mean() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn collective_spread_on_completion() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        for (rank, t) in [(0u32, 100u64), (1, 200), (2, 900)] {
            w.ingest(&ev(
                t,
                TelemetryKind::CollectiveBurst {
                    coll: CollId(7),
                    kind: CollKind::TpAllreduce,
                    from_node: NodeId(rank),
                    rank,
                    expected_ranks: 3,
                    bytes: 1024,
                    latency_ns: 500,
                },
            ));
        }
        let s = w.snapshot(SimTime(10_000));
        assert_eq!(s.tp.completed, 1);
        assert_eq!(s.tp.stalled, 0);
        assert!((s.tp.spread_ns.mean() - 800.0).abs() < 1e-9);
    }

    #[test]
    fn incomplete_old_collective_counts_stalled() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(
            100,
            TelemetryKind::CollectiveBurst {
                coll: CollId(9),
                kind: CollKind::PpHandoff,
                from_node: NodeId(1),
                rank: 0,
                expected_ranks: 4,
                bytes: 10,
                latency_ns: 500,
            },
        ));
        let s = w.snapshot(SimTime(COLL_STALL_NS + 200));
        assert_eq!(s.pp.stalled, 1);
        assert_eq!(w.inflight_collectives(), 0);
    }

    #[test]
    fn early_end_detected() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        // 3 active flows with healthy egress counts
        for f in 1..=3u32 {
            for i in 0..20u64 {
                w.ingest(&ev(
                    i * 100 + f as u64,
                    TelemetryKind::NicTx {
                        flow: FlowId(f),
                        bytes: 64,
                        queue_depth: 1,
                        wait_ns: 10,
                    },
                ));
            }
        }
        // flow 9 sends 2 tokens then ends
        for i in 0..2u64 {
            w.ingest(&ev(
                i * 100,
                TelemetryKind::NicTx { flow: FlowId(9), bytes: 64, queue_depth: 1, wait_ns: 10 },
            ));
        }
        w.ingest(&ev(300, TelemetryKind::FlowEnd { flow: FlowId(9), req: ReqId(0) }));
        let s = w.snapshot(SimTime(10_000));
        assert_eq!(s.flow_ends, 1);
        assert_eq!(s.early_end_count, 1);
        assert_eq!(s.active_flows, 3);
    }

    #[test]
    fn all_idle_window_snapshot_does_not_panic() {
        // Regression: an all-idle window (no flows at all) must produce the
        // neutral defaults instead of indexing an empty median buffer.
        let mut w = WindowAccum::new(NodeId(0), 1);
        let s = w.snapshot(SimTime(10_000));
        assert_eq!(s.early_end_count, 0);
        assert_eq!(s.end_len_ratio, 1.0);
        assert_eq!(s.ended_len_cov, 0.0);
        assert_eq!(s.active_flows, 0);
        // And again on the next window: scratch reuse must not leak state.
        let s2 = w.snapshot(SimTime(20_000));
        assert_eq!(s2.end_len_ratio, 1.0);
    }

    #[test]
    fn flow_end_with_no_active_egress_does_not_panic() {
        // A FlowEnd lands in a window where no active peer sent anything:
        // flow_ends > 0 with an empty active-egress median input.
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(
            0,
            TelemetryKind::NicTx { flow: FlowId(1), bytes: 64, queue_depth: 0, wait_ns: 0 },
        ));
        let _ = w.snapshot(SimTime(1_000));
        w.ingest(&ev(1_500, TelemetryKind::FlowEnd { flow: FlowId(1), req: ReqId(0) }));
        let s = w.snapshot(SimTime(2_000));
        assert_eq!(s.flow_ends, 1);
        assert_eq!(s.early_end_count, 0);
        assert_eq!(s.end_len_ratio, 1.0);
    }

    #[test]
    fn ended_flows_are_dropped_after_snapshot() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(
            0,
            TelemetryKind::NicTx { flow: FlowId(1), bytes: 1, queue_depth: 0, wait_ns: 0 },
        ));
        w.ingest(&ev(10, TelemetryKind::FlowEnd { flow: FlowId(1), req: ReqId(0) }));
        let _ = w.snapshot(SimTime(100));
        assert_eq!(w.tracked_flows(), 0);
    }

    #[test]
    fn handoff_gap_tracked() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        for t in [0u64, 500, 2500] {
            w.ingest(&ev(
                t,
                TelemetryKind::StageHandoff {
                    from_stage: StageId(0),
                    to_stage: StageId(1),
                    bytes: 100,
                    outbound: false,
                    phase: Phase::Decode,
                },
            ));
        }
        let s = w.snapshot(SimTime(5000));
        assert_eq!(s.handoff_count, 3);
        assert_eq!(s.handoff_gap_ns.count(), 2);
        assert_eq!(s.handoff_gap_ns.max(), 2000.0);
    }

    #[test]
    fn credit_gap_per_qp() {
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(0, TelemetryKind::CreditUpdate { qp: QpId(1) }));
        w.ingest(&ev(100, TelemetryKind::CreditUpdate { qp: QpId(2) }));
        w.ingest(&ev(5000, TelemetryKind::CreditUpdate { qp: QpId(1) }));
        let s = w.snapshot(SimTime(10_000));
        // Only the QP1 pair forms a gap (5000ns); QP2 has no second update.
        assert_eq!(s.credit_update_gap_ns.count(), 1);
        assert!((s.credit_update_gap_ns.mean() - 5000.0).abs() < 1e-9);
    }

    #[test]
    fn invisible_kinds_do_not_crash_or_count(){
        let mut w = WindowAccum::new(NodeId(0), 1);
        w.ingest(&ev(0, TelemetryKind::NvlinkBurst { from: GpuId(0), to: GpuId(1), bytes: 10 }));
        w.ingest(&ev(0, TelemetryKind::GpuKernel { gpu: GpuId(0), dur_ns: 10, flops: 1.0 }));
        let s = w.snapshot(SimTime(100));
        assert_eq!(s.h2d.count, 0);
        assert_eq!(s.nic_rx_count, 0);
    }
}
