//! Telemetry fault injection — the monitoring path itself as the victim.
//!
//! Every detector and the `WeightedTelemetry` router assume the DPU signal
//! is fresh, complete, and on time. This layer sits between the per-node
//! [`TelemetryBus`] buffers and `DpuPlane::ingest` and breaks exactly that
//! assumption, per node, in one of three ways (the TD condition family):
//!
//! - **Freeze** (TD1, stale-frozen): every due event is discarded at the
//!   boundary — the exporter is wedged, the observer sees *nothing* new and
//!   keeps reasoning over its last window forever.
//! - **Drop { p }** (TD2, lossy-drop): each due event independently survives
//!   with probability `1 - p` (seeded Bernoulli, own PCG stream forked from
//!   the scenario seed — other subsystems' draw counts are untouched).
//! - **Lag { windows }** (TD3, lagging-delivery): due events are parked in a
//!   per-node hold queue and released, in original order, `windows` delivery
//!   ticks later. Clearing the fault flushes the backlog.
//!
//! Accounting: all due events are counted into the bus publish totals at the
//! moment they become due (the cluster *did* publish them), so with faults
//! the pristine `published == ingested + invisible` invariant widens to
//! `published == ingested + invisible + fault_dropped + fault_held_at_end`.
//!
//! The layer keeps a per-node [`FreshnessStat`] — signal age, delivery
//! completeness, hold-queue depth, release delay — which is exactly what the
//! `dpu::watchdog::FreshnessWatchdog` and the fleet sensor's TD rules
//! consume. When no fault mode has ever been set the scenario never routes
//! delivery through this layer at all, so the disabled path is byte-identical
//! to the pristine pipeline by construction.

use crate::ids::NodeId;
use crate::sim::SimTime;
use crate::telemetry::bus::{sort_and_partition, TelemetryBus};
use crate::telemetry::event::{TelemetryEvent, TelemetryKind};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Per-node fault mode, stored on the cluster (`Cluster::tele_faults`) so
/// injections set it, `Cluster::heal` clears it, and mitigation directives
/// clear one node's entry.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum TeleFaultMode {
    /// Healthy delivery.
    #[default]
    None,
    /// Exporter wedged: due events discarded, signal frozen at its last value.
    Freeze,
    /// Lossy path: each event independently dropped with probability `p`.
    Drop { p: f64 },
    /// Delayed path: events delivered `windows` ticks late, in order.
    Lag { windows: u64 },
}

impl TeleFaultMode {
    pub fn is_none(&self) -> bool {
        matches!(self, TeleFaultMode::None)
    }

    /// Evidence label for injection descriptions and reports.
    pub fn describe(&self) -> String {
        match self {
            TeleFaultMode::None => "healthy".to_string(),
            TeleFaultMode::Freeze => "telemetry frozen (exporter wedged)".to_string(),
            TeleFaultMode::Drop { p } => format!("telemetry lossy (drop p={p:.2})"),
            TeleFaultMode::Lag { windows } => {
                format!("telemetry lagging ({windows} windows late)")
            }
        }
    }
}

/// Per-node signal-health counters maintained at each delivery tick. The
/// cumulative counters (`emitted`/`delivered`/`dropped`) are monotone so the
/// fleet sensor can diff them over its horizon; `age_windows`, `held`, and
/// `lag_windows` are instantaneous.
#[derive(Debug, Clone, Copy, Default)]
pub struct FreshnessStat {
    /// Delivery ticks since the observer last received anything from this
    /// node (0 = delivered this tick).
    pub age_windows: u64,
    /// Cumulative events that reached the fault boundary (became due).
    pub emitted: u64,
    /// Cumulative events handed to the observer.
    pub delivered: u64,
    /// Cumulative events discarded (freeze or lossy drop).
    pub dropped: u64,
    /// Events currently parked in the lag hold queue.
    pub held: u64,
    /// Release delay: windows between enqueue and release of the most
    /// recently released batch, or the age of the oldest held event while
    /// the backlog is still building; 0 when nothing is held or late.
    pub lag_windows: u64,
}

/// Gauge history depth for the router-feed rot path — bounds the largest
/// expressible lag on the queue/kv gauges.
const MAX_GAUGE_HIST: usize = 64;

/// RNG stream tag for the fault layer's private PCG stream.
const FAULT_STREAM: u64 = 0x7D;

/// The runtime: hold queues, seeded RNG, per-node freshness stats, and the
/// delivery-tick counter. Owned by the scenario; reads the per-node modes
/// live from the cluster at every delivery so injections and mitigations
/// take effect mid-run.
#[derive(Debug)]
pub struct TelemetryFaults {
    rng: Rng,
    /// Delivery ticks seen (bumped once per `deliver_due_faulted` call).
    window: u64,
    /// Per-node lag hold queue: (enqueue_window, release_window, event).
    hold: Vec<VecDeque<(u64, u64, TelemetryEvent)>>,
    stats: Vec<FreshnessStat>,
    /// Per-node (queue_depth, kv_occ) gauge history for router-feed rot.
    gauges: Vec<VecDeque<(f64, f64)>>,
    /// Reused delivery batch buffer.
    scratch: Vec<TelemetryEvent>,
    /// Latched true the first time any non-None mode is observed; the
    /// scenario keeps using the pristine delivery path until then.
    engaged: bool,
}

/// Snapshot/fork support. Held events are copied field-wise rather than via
/// `TelemetryEvent::clone` (which counts against the zero-copy pipeline's
/// clone probe); `scratch` is a delivery-tick scratch buffer that is always
/// empty between ticks, so the copy starts it fresh.
impl Clone for TelemetryFaults {
    fn clone(&self) -> Self {
        TelemetryFaults {
            rng: self.rng.clone(),
            window: self.window,
            hold: self
                .hold
                .iter()
                .map(|q| {
                    q.iter()
                        .map(|(enq, rel, e)| {
                            (*enq, *rel, TelemetryEvent { t: e.t, node: e.node, kind: e.kind.clone() })
                        })
                        .collect()
                })
                .collect(),
            stats: self.stats.clone(),
            gauges: self.gauges.clone(),
            scratch: Vec::new(),
            engaged: self.engaged,
        }
    }
}

impl TelemetryFaults {
    pub fn new(seed: u64, n_nodes: usize) -> Self {
        TelemetryFaults {
            rng: Rng::new(seed, FAULT_STREAM),
            window: 0,
            hold: (0..n_nodes).map(|_| VecDeque::new()).collect(),
            stats: vec![FreshnessStat::default(); n_nodes],
            gauges: (0..n_nodes).map(|_| VecDeque::new()).collect(),
            scratch: Vec::new(),
            engaged: false,
        }
    }

    /// Latch the layer on the first sight of a non-None mode; returns
    /// whether the faulted delivery path should be used. Once engaged the
    /// layer stays engaged (recovery runs through it too, so ages and the
    /// backlog flush are tracked), but a never-faulted run never enters it.
    pub fn check_engaged(&mut self, modes: &[TeleFaultMode]) -> bool {
        if !self.engaged && modes.iter().any(|m| !m.is_none()) {
            self.engaged = true;
        }
        self.engaged
    }

    pub fn is_engaged(&self) -> bool {
        self.engaged
    }

    /// Delivery ticks processed so far.
    pub fn window(&self) -> u64 {
        self.window
    }

    pub fn stats(&self) -> &[FreshnessStat] {
        &self.stats
    }

    /// Cumulative events discarded at the fault boundary.
    pub fn total_dropped(&self) -> u64 {
        self.stats.iter().map(|s| s.dropped).sum()
    }

    /// Events still parked in hold queues.
    pub fn total_held(&self) -> u64 {
        self.hold.iter().map(|q| q.len() as u64).sum()
    }

    /// The faulted counterpart of [`TelemetryBus::deliver_due`]: same
    /// delivery order and accounting when every mode is `None`, fault
    /// semantics per node otherwise. Always serial — the fault path trades
    /// the parallel observe fan-out for trivially thread-stable bookkeeping.
    pub fn deliver_due_faulted(
        &mut self,
        bus: &mut TelemetryBus,
        now: SimTime,
        modes: &[TeleFaultMode],
        mut f: impl FnMut(NodeId, &[TelemetryEvent]),
    ) {
        self.window += 1;
        let mut total = 0u64;
        let mut classes = [0u64; TelemetryKind::N_CLASSES];
        let bufs = bus.pending_buffers_mut();
        let n = bufs.len();
        debug_assert_eq!(n, modes.len());
        for i in 0..n {
            let mode = modes[i];
            self.scratch.clear();
            // Release lag-held events first — they are older than anything
            // due this tick. A cleared fault (mode no longer Lag) flushes
            // the whole backlog at once: the path recovered and the queued
            // telemetry arrives in a burst.
            let flush = !matches!(mode, TeleFaultMode::Lag { .. });
            let mut released_lag = 0u64;
            loop {
                let (enq_w, rel_w) = match self.hold[i].front() {
                    Some(&(e, r, _)) => (e, r),
                    None => break,
                };
                if !flush && rel_w > self.window {
                    break;
                }
                let (_, _, ev) = self.hold[i].pop_front().unwrap();
                released_lag = released_lag.max(self.window.saturating_sub(enq_w));
                self.scratch.push(ev);
            }
            // Current-tick due events, (t, emission) order as the bus would.
            let buf = &mut bufs[i];
            let due = if buf.is_empty() { 0 } else { sort_and_partition(buf, now) };
            if due > 0 {
                total += due as u64;
                for ev in &buf[..due] {
                    classes[ev.kind.class_id()] += 1;
                }
                self.stats[i].emitted += due as u64;
                match mode {
                    TeleFaultMode::None => {
                        self.scratch.extend(buf.drain(..due));
                    }
                    TeleFaultMode::Freeze => {
                        self.stats[i].dropped += due as u64;
                        buf.drain(..due);
                    }
                    TeleFaultMode::Drop { p } => {
                        for ev in buf.drain(..due) {
                            if self.rng.chance(p) {
                                self.stats[i].dropped += 1;
                            } else {
                                self.scratch.push(ev);
                            }
                        }
                    }
                    TeleFaultMode::Lag { windows } => {
                        let rel = self.window + windows;
                        for ev in buf.drain(..due) {
                            self.hold[i].push_back((self.window, rel, ev));
                        }
                    }
                }
            }
            let st = &mut self.stats[i];
            st.held = self.hold[i].len() as u64;
            st.lag_windows = if released_lag > 0 {
                released_lag
            } else if let Some(&(enq_w, _, _)) = self.hold[i].front() {
                self.window.saturating_sub(enq_w)
            } else {
                0
            };
            if self.scratch.is_empty() {
                st.age_windows += 1;
            } else {
                st.delivered += self.scratch.len() as u64;
                st.age_windows = 0;
                f(NodeId(i as u32), &self.scratch);
                self.scratch.clear();
            }
        }
        bus.commit_delivered(total, &classes);
    }

    /// Router-feed rot: pass a ground-truth (queue_depth, kv_occ) gauge pair
    /// through the node's fault mode. `None` return = no update reaches the
    /// router this window (it keeps its previous value — exactly what a
    /// frozen or dropped gauge looks like); `Some` = the value that arrives,
    /// which under lag is the gauge from `windows` ticks ago.
    pub fn rot_gauge(
        &mut self,
        node: usize,
        mode: TeleFaultMode,
        fresh: (f64, f64),
    ) -> Option<(f64, f64)> {
        let hist = &mut self.gauges[node];
        match mode {
            // Exporter wedged: nothing arrives, nothing new is recorded.
            TeleFaultMode::Freeze => None,
            TeleFaultMode::None => {
                hist.push_back(fresh);
                if hist.len() > MAX_GAUGE_HIST {
                    hist.pop_front();
                }
                Some(fresh)
            }
            TeleFaultMode::Drop { p } => {
                hist.push_back(fresh);
                if hist.len() > MAX_GAUGE_HIST {
                    hist.pop_front();
                }
                if self.rng.chance(p) {
                    None
                } else {
                    Some(fresh)
                }
            }
            TeleFaultMode::Lag { windows } => {
                hist.push_back(fresh);
                if hist.len() > MAX_GAUGE_HIST {
                    hist.pop_front();
                }
                let k = windows as usize;
                if hist.len() > k {
                    Some(hist[hist.len() - 1 - k])
                } else {
                    None
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::GpuId;

    fn doorbell(t: u64, node: u32) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::Doorbell { gpu: GpuId(0) },
        }
    }

    fn filled_bus(n_nodes: usize, per_node: u64) -> TelemetryBus {
        let mut bus = TelemetryBus::new(n_nodes);
        for node in 0..n_nodes as u32 {
            for t in 0..per_node {
                bus.enqueue(doorbell(t + 1, node));
            }
        }
        bus
    }

    #[test]
    fn all_none_matches_pristine_delivery_exactly() {
        let mut pristine = filled_bus(3, 5);
        let mut faulted = filled_bus(3, 5);
        let mut a = Vec::new();
        pristine.deliver_due(SimTime(100), |n, evs| {
            a.push((n, evs.iter().map(|e| e.t.ns()).collect::<Vec<_>>()));
        });
        let mut fl = TelemetryFaults::new(42, 3);
        let modes = vec![TeleFaultMode::None; 3];
        let mut b = Vec::new();
        fl.deliver_due_faulted(&mut faulted, SimTime(100), &modes, |n, evs| {
            b.push((n, evs.iter().map(|e| e.t.ns()).collect::<Vec<_>>()));
        });
        assert_eq!(a, b);
        assert_eq!(pristine.total_published(), faulted.total_published());
        assert_eq!(pristine.class_counts(), faulted.class_counts());
        assert_eq!(fl.total_dropped(), 0);
        assert_eq!(fl.total_held(), 0);
        assert_eq!(fl.stats()[0].delivered, 5);
        assert_eq!(fl.stats()[0].emitted, 5);
    }

    #[test]
    fn freeze_discards_and_ages_the_signal() {
        let mut fl = TelemetryFaults::new(7, 2);
        let modes = [TeleFaultMode::Freeze, TeleFaultMode::None];
        for tick in 1..=4u64 {
            let mut bus = filled_bus(2, 3);
            let mut seen = Vec::new();
            fl.deliver_due_faulted(&mut bus, SimTime(100), &modes, |n, evs| {
                seen.push((n, evs.len()));
            });
            // Only the healthy node delivers; published counts both.
            assert_eq!(seen, vec![(NodeId(1), 3)]);
            assert_eq!(bus.total_published(), 6);
            assert_eq!(fl.stats()[0].age_windows, tick);
            assert_eq!(fl.stats()[1].age_windows, 0);
        }
        assert_eq!(fl.stats()[0].dropped, 12);
        assert_eq!(fl.stats()[0].delivered, 0);
        assert_eq!(fl.total_dropped(), 12);
    }

    #[test]
    fn drop_is_partial_and_seed_deterministic() {
        let run = |seed| {
            let mut fl = TelemetryFaults::new(seed, 1);
            let modes = [TeleFaultMode::Drop { p: 0.5 }];
            let mut delivered = Vec::new();
            for _ in 0..10 {
                let mut bus = filled_bus(1, 20);
                fl.deliver_due_faulted(&mut bus, SimTime(100), &modes, |_, evs| {
                    delivered.extend(evs.iter().map(|e| e.t.ns()));
                });
            }
            (delivered, fl.stats()[0].dropped, fl.stats()[0].delivered)
        };
        let (d1, drop1, del1) = run(5);
        let (d2, drop2, del2) = run(5);
        assert_eq!(d1, d2, "same seed must drop the same events");
        assert_eq!((drop1, del1), (drop2, del2));
        assert_eq!(drop1 + del1, 200, "every emitted event is dropped or delivered");
        assert!(drop1 > 50 && del1 > 50, "p=0.5 loses some, passes some: {drop1}/{del1}");
        let (d3, _, _) = run(6);
        assert_ne!(d1, d3, "different seed, different loss pattern");
    }

    #[test]
    fn lag_holds_then_releases_in_order() {
        let mut fl = TelemetryFaults::new(1, 1);
        let modes = [TeleFaultMode::Lag { windows: 2 }];
        // Tick 1: 2 events become due, parked.
        let mut bus = filled_bus(1, 2);
        fl.deliver_due_faulted(&mut bus, SimTime(100), &modes, |_, _| {
            panic!("nothing may deliver while lagged")
        });
        assert_eq!(fl.stats()[0].held, 2);
        assert_eq!(fl.stats()[0].age_windows, 1);
        // Tick 2: nothing due, backlog not yet released.
        let mut empty = TelemetryBus::new(1);
        fl.deliver_due_faulted(&mut empty, SimTime(100), &modes, |_, _| {
            panic!("release is at enqueue+2")
        });
        assert_eq!(fl.stats()[0].lag_windows, 1, "backlog age while building");
        // Tick 3: release window reached; both arrive, original order.
        let mut empty = TelemetryBus::new(1);
        let mut got = Vec::new();
        fl.deliver_due_faulted(&mut empty, SimTime(100), &modes, |_, evs| {
            got.extend(evs.iter().map(|e| e.t.ns()));
        });
        assert_eq!(got, vec![1, 2]);
        assert_eq!(fl.stats()[0].held, 0);
        assert_eq!(fl.stats()[0].lag_windows, 2);
        assert_eq!(fl.stats()[0].age_windows, 0);
        assert_eq!(fl.stats()[0].delivered, 2);
    }

    #[test]
    fn clearing_lag_flushes_the_backlog() {
        let mut fl = TelemetryFaults::new(1, 1);
        let lag = [TeleFaultMode::Lag { windows: 50 }];
        let mut bus = filled_bus(1, 4);
        fl.deliver_due_faulted(&mut bus, SimTime(100), &lag, |_, _| panic!("parked"));
        assert_eq!(fl.total_held(), 4);
        // Mitigation cleared the mode: held events arrive immediately.
        let healed = [TeleFaultMode::None];
        let mut empty = TelemetryBus::new(1);
        let mut got = Vec::new();
        fl.deliver_due_faulted(&mut empty, SimTime(100), &healed, |_, evs| {
            got.extend(evs.iter().map(|e| e.t.ns()));
        });
        assert_eq!(got, vec![1, 2, 3, 4]);
        assert_eq!(fl.total_held(), 0);
    }

    #[test]
    fn engagement_latches_on_first_fault() {
        let mut fl = TelemetryFaults::new(1, 2);
        assert!(!fl.check_engaged(&[TeleFaultMode::None, TeleFaultMode::None]));
        assert!(!fl.is_engaged());
        assert!(fl.check_engaged(&[TeleFaultMode::None, TeleFaultMode::Freeze]));
        // Stays engaged after the fault clears (recovery tracking).
        assert!(fl.check_engaged(&[TeleFaultMode::None, TeleFaultMode::None]));
    }

    #[test]
    fn rot_gauge_models_all_three_faults() {
        let mut fl = TelemetryFaults::new(9, 1);
        // Healthy: identity.
        assert_eq!(fl.rot_gauge(0, TeleFaultMode::None, (3.0, 0.5)), Some((3.0, 0.5)));
        // Freeze: no update ever arrives.
        assert_eq!(fl.rot_gauge(0, TeleFaultMode::Freeze, (9.0, 0.9)), None);
        // Lag k=2: the value from two windows ago arrives.
        let mut fl = TelemetryFaults::new(9, 1);
        let lag = TeleFaultMode::Lag { windows: 2 };
        assert_eq!(fl.rot_gauge(0, lag, (1.0, 0.1)), None);
        assert_eq!(fl.rot_gauge(0, lag, (2.0, 0.2)), None);
        assert_eq!(fl.rot_gauge(0, lag, (3.0, 0.3)), Some((1.0, 0.1)));
        assert_eq!(fl.rot_gauge(0, lag, (4.0, 0.4)), Some((2.0, 0.2)));
        // Drop p=1: every update lost; p=0: none lost.
        let mut fl = TelemetryFaults::new(9, 1);
        assert_eq!(fl.rot_gauge(0, TeleFaultMode::Drop { p: 1.0 }, (1.0, 0.1)), None);
        assert_eq!(fl.rot_gauge(0, TeleFaultMode::Drop { p: 0.0 }, (2.0, 0.2)), Some((2.0, 0.2)));
    }

    #[test]
    fn conservation_extends_to_fault_counters() {
        let mut fl = TelemetryFaults::new(3, 3);
        let modes =
            [TeleFaultMode::Freeze, TeleFaultMode::Drop { p: 0.6 }, TeleFaultMode::Lag { windows: 8 }];
        let mut delivered = 0u64;
        let mut published = 0u64;
        for _ in 0..5 {
            let mut bus = filled_bus(3, 10);
            fl.deliver_due_faulted(&mut bus, SimTime(100), &modes, |_, evs| {
                delivered += evs.len() as u64;
            });
            published += bus.total_published();
        }
        assert_eq!(published, 150, "all due events count as published");
        assert_eq!(
            published,
            delivered + fl.total_dropped() + fl.total_held(),
            "published == delivered + dropped + still-held"
        );
        assert!(fl.total_held() > 0, "lagged node must be holding a backlog");
    }
}
