//! Telemetry plane: event vocabulary, per-node buses, windowed feature
//! extraction, and the software-signal baseline (Table 2(b)).

pub mod bus;
pub mod event;
pub mod faults;
pub mod sw;
pub mod window;

pub use bus::TelemetryBus;
pub use faults::{FreshnessStat, TeleFaultMode, TelemetryFaults};
pub use event::{CollKind, Phase, TelemetryEvent, TelemetryKind};
pub use sw::{SwSignal, SwSnapshot, SwWindow, ALL_SW_SIGNALS};
pub use window::{WindowAccum, WindowSnapshot};
