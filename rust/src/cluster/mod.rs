//! Simulated GPU cluster substrate: topology, per-node hardware (PCIe
//! complex, NIC, GPUs), the inter-node fabric, and the pathology knobs.

pub mod fabric;
pub mod models;
pub mod topology;

pub use fabric::Fabric;
pub use models::{GpuModel, LinkModel, Nic, Outbox, PcieComplex};
pub use topology::{ClusterSpec, FabricKnobs, NodeKnobs, ReplicaRole, ReplicaShape};

use crate::ids::{GpuId, NodeId};
use crate::sim::SimTime;
use crate::telemetry::event::{Phase, TelemetryKind};
use crate::telemetry::faults::TeleFaultMode;
use crate::util::rng::Rng;

/// One host node's hardware.
#[derive(Debug, Clone)]
pub struct NodeHw {
    pub node: NodeId,
    pub pcie: PcieComplex,
    pub nic: Nic,
    pub gpus: Vec<GpuModel>,
    pub knobs: NodeKnobs,
    pub rng: Rng,
}

/// The whole cluster: nodes + fabric + fabric knobs.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub spec: ClusterSpec,
    pub nodes: Vec<NodeHw>,
    pub fabric: Fabric,
    pub fabric_knobs: FabricKnobs,
    /// Per-node telemetry fault mode (TD family): the monitoring path's own
    /// pathology knob. Set by TD injections, read live by the scenario's
    /// `TelemetryFaults` runtime, cleared by `heal` and the TD directives.
    pub tele_faults: Vec<TeleFaultMode>,
}

/// Default simulated GPU peak throughput (FLOP/s) — A100-class bf16 order.
pub const GPU_FLOPS: f64 = 150e12;

impl Cluster {
    pub fn new(spec: ClusterSpec, seed: u64) -> Self {
        spec.validate().expect("invalid cluster spec");
        let mut root = Rng::new(seed, 0xC1);
        let nodes = (0..spec.n_nodes)
            .map(|n| {
                let node = NodeId(n as u32);
                NodeHw {
                    node,
                    pcie: PcieComplex::new(node, &spec),
                    nic: Nic::new(node, &spec),
                    gpus: spec
                        .gpus_of_node(node)
                        .into_iter()
                        .map(|g| GpuModel::new(g, node, GPU_FLOPS))
                        .collect(),
                    knobs: NodeKnobs::healthy(spec.gpus_per_node),
                    rng: root.fork(n as u64),
                }
            })
            .collect();
        let fabric = Fabric::new(&spec);
        let tele_faults = vec![TeleFaultMode::None; spec.n_nodes];
        Cluster { spec, nodes, fabric, fabric_knobs: FabricKnobs::default(), tele_faults }
    }

    pub fn node(&self, n: NodeId) -> &NodeHw {
        &self.nodes[n.idx()]
    }

    pub fn node_mut(&mut self, n: NodeId) -> &mut NodeHw {
        &mut self.nodes[n.idx()]
    }

    pub fn node_of(&self, gpu: GpuId) -> NodeId {
        self.spec.node_of_gpu(gpu)
    }

    /// H2D DMA to `gpu`; returns completion time.
    pub fn h2d(
        &mut self,
        now: SimTime,
        gpu: GpuId,
        bytes: u64,
        phase: Phase,
        out: &mut Outbox,
    ) -> SimTime {
        let n = self.node_of(gpu);
        let hw = &mut self.nodes[n.idx()];
        hw.pcie.h2d(now, gpu, bytes, phase, &hw.knobs, out)
    }

    /// D2H DMA from `gpu`; returns completion time.
    pub fn d2h(
        &mut self,
        now: SimTime,
        gpu: GpuId,
        bytes: u64,
        phase: Phase,
        out: &mut Outbox,
    ) -> SimTime {
        let n = self.node_of(gpu);
        let hw = &mut self.nodes[n.idx()];
        hw.pcie.d2h(now, gpu, bytes, phase, &hw.knobs, out)
    }

    /// Launch a kernel on `gpu` when its inputs are ready; returns completion.
    pub fn gpu_launch(&mut self, ready: SimTime, gpu: GpuId, flops: f64, out: &mut Outbox) -> SimTime {
        let n = self.node_of(gpu);
        let hw = &mut self.nodes[n.idx()];
        let local = gpu.idx() % self.spec.gpus_per_node;
        hw.gpus[local].launch(ready, flops, &hw.knobs, out)
    }

    /// Intra-node GPU-to-GPU transfer: NVLink when available (DPU-invisible)
    /// unless forced over PCIe; returns completion.
    pub fn p2p(
        &mut self,
        now: SimTime,
        from: GpuId,
        to: GpuId,
        bytes: u64,
        out: &mut Outbox,
    ) -> SimTime {
        debug_assert_eq!(self.node_of(from), self.node_of(to));
        let n = self.node_of(from);
        let use_nvlink = self.spec.nvlink && !self.nodes[n.idx()].knobs.p2p_over_pcie;
        if use_nvlink {
            let dur_ns = (bytes as f64 / self.spec.nvlink_bw * 1e9).ceil() as u64 + 300;
            let done = now + crate::sim::SimDur(dur_ns);
            out.emit(done, n, TelemetryKind::NvlinkBurst { from, to, bytes });
            done
        } else {
            let hw = &mut self.nodes[n.idx()];
            hw.pcie.p2p(now, from, to, bytes, &hw.knobs, out)
        }
    }

    /// Client -> node ingress (north-south).
    pub fn ingress(
        &mut self,
        now: SimTime,
        node: NodeId,
        flow: crate::ids::FlowId,
        bytes: u64,
        out: &mut Outbox,
    ) -> SimTime {
        let hw = &mut self.nodes[node.idx()];
        hw.nic.ingress(now, flow, bytes, &hw.knobs, &mut hw.rng, out)
    }

    /// Node -> client egress (north-south).
    pub fn egress(
        &mut self,
        now: SimTime,
        node: NodeId,
        flow: crate::ids::FlowId,
        bytes: u64,
        out: &mut Outbox,
    ) -> SimTime {
        let hw = &mut self.nodes[node.idx()];
        hw.nic.egress(now, flow, bytes, &hw.knobs, &mut hw.rng, out)
    }

    /// Inter-node RDMA (east-west). KV-transfer budgets apply the fabric
    /// knob's budget factor (EW8) by inflating effective bytes.
    pub fn rdma(
        &mut self,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        bytes: u64,
        kv_transfer: bool,
        out: &mut Outbox,
    ) -> SimTime {
        let eff_bytes = if kv_transfer {
            (bytes as f64 / self.fabric_knobs.kv_link_budget_factor.max(0.05)) as u64
        } else {
            bytes
        };
        let hw_rng = &mut self.nodes[from.idx()].rng;
        self.fabric.rdma(now, from, to, eff_bytes, &self.fabric_knobs, hw_rng, out)
    }

    /// Prefill→decode KV handoff: the phase-transition transfer that moves a
    /// sequence's KV pages between pools. It rides the same fabric as every
    /// other east-west byte, so the destination DPU sees it (RdmaOp plus an
    /// explicit KvTransfer burst). PD2's knob throttles only this path.
    #[allow(clippy::too_many_arguments)]
    pub fn kv_handoff(
        &mut self,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        bytes: u64,
        coll: crate::ids::CollId,
        out: &mut Outbox,
    ) -> SimTime {
        let eff_bytes =
            (bytes as f64 / self.fabric_knobs.handoff_budget_factor.max(0.05)) as u64;
        let hw_rng = &mut self.nodes[from.idx()].rng;
        let arrive =
            self.fabric.rdma(now, from, to, eff_bytes.max(512), &self.fabric_knobs, hw_rng, out);
        out.emit(
            arrive,
            to,
            TelemetryKind::CollectiveBurst {
                coll,
                kind: crate::telemetry::event::CollKind::KvTransfer,
                from_node: from,
                rank: 0,
                expected_ranks: 1,
                bytes: eff_bytes.max(512),
                latency_ns: (arrive - now).ns(),
            },
        );
        arrive
    }

    /// Window-tick maintenance: background load + PCIe utilization samples.
    pub fn on_window_tick(&mut self, now: SimTime, window_ns: u64, out: &mut Outbox) {
        for hw in &mut self.nodes {
            hw.pcie.apply_background(now, window_ns, &hw.knobs);
            hw.nic.apply_background(now, window_ns, &hw.knobs);
            hw.pcie.sample_util(now, out);
            // A background tenant's packets are traffic the DPU sees too
            // (NS9: shared NIC with storage/other jobs).
            if hw.knobs.nic_background_frac > 0.0 {
                let bytes =
                    (hw.knobs.nic_background_frac * hw.nic.rx.bw * window_ns as f64 / 1e9) as u64;
                let depth = (hw.knobs.nic_background_frac * 128.0) as u32;
                let bg_flow = crate::ids::FlowId(u32::MAX);
                out.emit(now, hw.node, TelemetryKind::NicRx {
                    flow: bg_flow, bytes, queue_depth: depth,
                });
                out.emit(now, hw.node, TelemetryKind::NicTx {
                    flow: bg_flow, bytes, queue_depth: depth,
                    wait_ns: (window_ns / 100).max(1_000),
                });
            }
        }
    }

    /// Reset all pathology knobs to healthy.
    pub fn heal(&mut self) {
        let g = self.spec.gpus_per_node;
        for hw in &mut self.nodes {
            hw.knobs = NodeKnobs::healthy(g);
        }
        self.fabric_knobs = FabricKnobs::default();
        for m in &mut self.tele_faults {
            *m = TeleFaultMode::None;
        }
    }

    pub fn all_healthy(&self) -> bool {
        self.fabric_knobs.is_healthy()
            && self.nodes.iter().all(|n| n.knobs.is_healthy())
            && self.tele_faults.iter().all(|m| m.is_none())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::FlowId;

    #[test]
    fn build_and_route() {
        let c = Cluster::new(ClusterSpec::default(), 42);
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.nodes[2].gpus.len(), 4);
        assert_eq!(c.node_of(GpuId(9)), NodeId(2));
        assert!(c.all_healthy());
    }

    #[test]
    fn p2p_uses_nvlink_by_default_and_pcie_when_forced() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        let mut out = Outbox::new();
        c.p2p(SimTime(0), GpuId(0), GpuId(1), 1 << 20, &mut out);
        assert!(matches!(out.items.last().unwrap().2, TelemetryKind::NvlinkBurst { .. }));
        c.nodes[0].knobs.p2p_over_pcie = true;
        c.p2p(SimTime(0), GpuId(0), GpuId(1), 1 << 20, &mut out);
        assert!(matches!(out.items.last().unwrap().2, TelemetryKind::P2pPcie { .. }));
    }

    #[test]
    fn heal_restores_health() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        c.nodes[1].knobs.gpu_speed_factor[0] = 0.3;
        c.fabric_knobs.loss_prob = 0.1;
        assert!(!c.all_healthy());
        c.heal();
        assert!(c.all_healthy());
    }

    #[test]
    fn telemetry_faults_count_as_unhealthy_and_heal() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        assert!(c.all_healthy());
        c.tele_faults[2] = TeleFaultMode::Freeze;
        assert!(!c.all_healthy(), "a wedged exporter is a pathology");
        c.heal();
        assert!(c.all_healthy());
        assert!(c.tele_faults.iter().all(|m| m.is_none()));
    }

    #[test]
    fn kv_budget_factor_slows_kv_transfers() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        let mut out = Outbox::new();
        let base = c.rdma(SimTime(0), NodeId(0), NodeId(1), 1 << 22, true, &mut out);
        let mut c2 = Cluster::new(ClusterSpec::default(), 1);
        c2.fabric_knobs.kv_link_budget_factor = 0.25;
        let slow = c2.rdma(SimTime(0), NodeId(0), NodeId(1), 1 << 22, true, &mut out);
        assert!(slow.ns() > base.ns() * 2);
    }

    #[test]
    fn handoff_budget_throttles_only_the_handoff_path() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        let mut out = Outbox::new();
        let base =
            c.kv_handoff(SimTime(0), NodeId(0), NodeId(2), 1 << 22, crate::ids::CollId(1), &mut out);
        assert!(matches!(
            out.items.last().unwrap().2,
            TelemetryKind::CollectiveBurst {
                kind: crate::telemetry::event::CollKind::KvTransfer,
                ..
            }
        ));
        let mut c2 = Cluster::new(ClusterSpec::default(), 1);
        c2.fabric_knobs.handoff_budget_factor = 0.2;
        let slow = c2.kv_handoff(
            SimTime(0),
            NodeId(0),
            NodeId(2),
            1 << 22,
            crate::ids::CollId(1),
            &mut out,
        );
        assert!(slow.ns() > base.ns() * 3, "slow={} base={}", slow.ns(), base.ns());
        // EW8's kv budget path is untouched by the handoff knob.
        let mut c3 = Cluster::new(ClusterSpec::default(), 1);
        c3.fabric_knobs.handoff_budget_factor = 0.2;
        let kv = c3.rdma(SimTime(0), NodeId(0), NodeId(2), 1 << 22, true, &mut out);
        let mut c4 = Cluster::new(ClusterSpec::default(), 1);
        let kv_base = c4.rdma(SimTime(0), NodeId(0), NodeId(2), 1 << 22, true, &mut out);
        assert_eq!(kv.ns(), kv_base.ns());
    }

    #[test]
    fn ingress_egress_roundtrip_emits_rx_tx() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        let mut out = Outbox::new();
        let t1 = c.ingress(SimTime(0), NodeId(0), FlowId(5), 2048, &mut out);
        let t2 = c.egress(t1, NodeId(0), FlowId(5), 4096, &mut out);
        assert!(t2 > t1);
        let classes: Vec<&str> = out.items.iter().map(|(_, _, k)| k.class()).collect();
        assert!(classes.contains(&"nic_rx"));
        assert!(classes.contains(&"nic_tx"));
    }

    #[test]
    fn window_tick_emits_util_samples() {
        let mut c = Cluster::new(ClusterSpec::default(), 1);
        let mut out = Outbox::new();
        c.on_window_tick(SimTime(1_000_000), 1_000_000, &mut out);
        let utils = out
            .items
            .iter()
            .filter(|(_, _, k)| matches!(k, TelemetryKind::PcieUtil { .. }))
            .count();
        assert_eq!(utils, 4); // one per node
    }
}
