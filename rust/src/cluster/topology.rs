//! Cluster topology: nodes × GPUs, PCIe tree, NICs, fabric — plus the
//! pathology knobs that injectors turn.
//!
//! Defaults approximate a DGX-class node: PCIe Gen4 x16 per GPU (~24 GB/s
//! effective), 400 Gb/s NIC, NVLink intra-node, fat-tree fabric with a
//! configurable oversubscription factor.

use crate::ids::{GpuId, NodeId};

/// Which serving phase a replica's pool handles. Colocated replicas run the
/// classic vLLM-style loop (prefill and decode interleaved on one engine);
/// Prefill/Decode replicas form the two pools of a phase-disaggregated
/// deployment, connected by an explicit KV handoff over the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReplicaRole {
    Colocated,
    Prefill,
    Decode,
}

impl ReplicaRole {
    pub fn id(&self) -> &'static str {
        match self {
            ReplicaRole::Colocated => "colocated",
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
        }
    }

    /// May the admission router place new prompts here?
    pub fn serves_prefill(&self) -> bool {
        matches!(self, ReplicaRole::Colocated | ReplicaRole::Prefill)
    }

    /// May the phase-transition router place decode work here?
    pub fn serves_decode(&self) -> bool {
        matches!(self, ReplicaRole::Colocated | ReplicaRole::Decode)
    }
}

/// One replica's shape: its pool role and parallelism degrees. `tp` counts
/// GPUs per pipeline stage (stages span whole nodes, so TP collectives cross
/// the fabric and stay DPU-observable), `pp` counts pipeline stages; a
/// replica therefore consumes `pp * tp / gpus_per_node` nodes. Pools can mix
/// shapes — e.g. one TP8 prefill replica beside TP4×PP2 decode replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaShape {
    pub role: ReplicaRole,
    pub tp: usize,
    pub pp: usize,
}

impl ReplicaShape {
    pub fn new(role: ReplicaRole, tp: usize, pp: usize) -> Self {
        ReplicaShape { role, tp, pp }
    }

    /// Nodes this shape occupies on a cluster with `gpus_per_node` GPUs per
    /// node (TP spans whole nodes).
    pub fn nodes_needed(&self, gpus_per_node: usize) -> usize {
        assert!(self.tp > 0 && self.pp > 0, "degenerate shape");
        assert!(
            gpus_per_node > 0 && self.tp % gpus_per_node == 0,
            "tp {} must be a whole-node multiple of {gpus_per_node}",
            self.tp
        );
        self.pp * (self.tp / gpus_per_node)
    }

    /// Stable label for tables and JSON, e.g. `prefill:tp8xpp1`.
    pub fn label(&self) -> String {
        format!("{}:tp{}xpp{}", self.role.id(), self.tp, self.pp)
    }
}

/// Static description of the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub n_nodes: usize,
    pub gpus_per_node: usize,
    /// Effective per-GPU PCIe bandwidth, bytes/sec.
    pub pcie_bw: f64,
    /// PCIe base (propagation + root-complex) latency per transaction, ns.
    pub pcie_base_lat_ns: u64,
    /// Whether GPUs within a node have an NVLink path (DPU-invisible).
    pub nvlink: bool,
    /// NVLink bandwidth, bytes/sec.
    pub nvlink_bw: f64,
    /// NIC line rate, bytes/sec.
    pub nic_bw: f64,
    /// NIC queue capacity (packets) before tail drops.
    pub nic_queue_cap: u32,
    /// Fabric per-hop base latency, ns.
    pub fabric_base_lat_ns: u64,
    /// Fat-tree oversubscription factor (1.0 = non-blocking).
    pub oversubscription: f64,
    /// Tensor-parallel degree (GPUs per shard group, intra-node).
    pub tp_degree: usize,
    /// Pipeline-parallel degree (stages, across nodes).
    pub pp_degree: usize,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            n_nodes: 4,
            gpus_per_node: 4,
            pcie_bw: 24e9,
            pcie_base_lat_ns: 900,
            nvlink: true,
            nvlink_bw: 300e9,
            nic_bw: 50e9, // 400 Gb/s
            nic_queue_cap: 2048,
            fabric_base_lat_ns: 1_500,
            oversubscription: 1.0,
            tp_degree: 4,
            pp_degree: 2,
        }
    }
}

impl ClusterSpec {
    pub fn n_gpus(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    pub fn node_of_gpu(&self, gpu: GpuId) -> NodeId {
        NodeId((gpu.idx() / self.gpus_per_node) as u32)
    }

    pub fn gpus_of_node(&self, node: NodeId) -> Vec<GpuId> {
        let base = node.idx() * self.gpus_per_node;
        (0..self.gpus_per_node).map(|i| GpuId((base + i) as u32)).collect()
    }

    /// Validate internal consistency (TP fits in a node, PP fits the cluster).
    pub fn validate(&self) -> Result<(), String> {
        if self.n_nodes == 0 || self.gpus_per_node == 0 {
            return Err("empty cluster".into());
        }
        if self.tp_degree == 0 || self.tp_degree > self.gpus_per_node {
            return Err(format!(
                "tp_degree {} must be in 1..={}",
                self.tp_degree, self.gpus_per_node
            ));
        }
        if self.pp_degree == 0 || self.pp_degree > self.n_nodes {
            return Err(format!(
                "pp_degree {} must be in 1..={}",
                self.pp_degree, self.n_nodes
            ));
        }
        if self.oversubscription < 1.0 {
            return Err("oversubscription < 1.0".into());
        }
        Ok(())
    }
}

/// Per-node pathology knobs. All default to "healthy"; injectors mutate these
/// (possibly time-varying via scheduled toggle events).
#[derive(Debug, Clone)]
pub struct NodeKnobs {
    /// Multiplies effective H2D bandwidth (PC1: cap it).
    pub h2d_bw_factor: f64,
    /// Multiplies effective D2H bandwidth (PC2).
    pub d2h_bw_factor: f64,
    /// Extra per-transaction PCIe latency, ns (PC2 IOMMU contention).
    pub pcie_extra_lat_ns: u64,
    /// Pageable (unpinned) host buffers: extra staging copy + latency (PC1).
    pub unpinned_buffers: bool,
    /// Pinned-pool fragmentation: DMAs split into many small transactions (PC7).
    pub pinned_pool_frag: bool,
    /// Added delay between data-ready and kernel doorbell, ns (PC3).
    pub doorbell_delay_ns: u64,
    /// Tiny-kernel storm: multiplies kernel-launch count per step (PC3).
    pub kernel_fission: u32,
    /// Host CPU contention factor >= 1.0: slows host-side ops (PC8, NS5).
    pub cpu_contention: f64,
    /// Registration churn: map/unmap around every DMA (PC9).
    pub mem_reg_churn: bool,
    /// Per-local-GPU compute speed factor (1.0 healthy; <1.0 slow) (PC4, EW1).
    pub gpu_speed_factor: Vec<f64>,
    /// Force intra-node P2P over PCIe even when NVLink exists (PC6).
    pub p2p_over_pcie: bool,
    /// Fraction of PCIe bandwidth consumed by a competing tenant (PC5).
    pub pcie_background_load: f64,
    /// Ingress packet loss probability (NS4).
    pub nic_rx_loss: f64,
    /// Egress packet loss probability (NS7).
    pub nic_tx_loss: f64,
    /// Fraction of NIC line rate consumed by background traffic (NS9).
    pub nic_background_frac: f64,
    /// Shrink TX buffering (NS5): queue capacity factor.
    pub nic_tx_buffer_factor: f64,
    /// Egress scheduler jitter multiplier (NS6).
    pub egress_jitter: f64,
    /// Probability this node goes silent in a collective (EW9: early-stop
    /// ranks not masked by the scheduler).
    pub collective_silence: f64,
}

impl Default for NodeKnobs {
    fn default() -> Self {
        NodeKnobs {
            h2d_bw_factor: 1.0,
            d2h_bw_factor: 1.0,
            pcie_extra_lat_ns: 0,
            unpinned_buffers: false,
            pinned_pool_frag: false,
            doorbell_delay_ns: 0,
            kernel_fission: 1,
            cpu_contention: 1.0,
            mem_reg_churn: false,
            gpu_speed_factor: Vec::new(), // sized by Cluster::new
            p2p_over_pcie: false,
            pcie_background_load: 0.0,
            nic_rx_loss: 0.0,
            nic_tx_loss: 0.0,
            nic_background_frac: 0.0,
            nic_tx_buffer_factor: 1.0,
            egress_jitter: 0.0,
            collective_silence: 0.0,
        }
    }
}

impl NodeKnobs {
    pub fn healthy(n_gpus: usize) -> Self {
        let mut k = NodeKnobs::default();
        k.gpu_speed_factor = vec![1.0; n_gpus];
        k
    }

    pub fn is_healthy(&self) -> bool {
        let d = NodeKnobs::default();
        self.h2d_bw_factor == d.h2d_bw_factor
            && self.d2h_bw_factor == d.d2h_bw_factor
            && self.pcie_extra_lat_ns == 0
            && !self.unpinned_buffers
            && !self.pinned_pool_frag
            && self.doorbell_delay_ns == 0
            && self.kernel_fission == 1
            && self.cpu_contention == 1.0
            && !self.mem_reg_churn
            && self.gpu_speed_factor.iter().all(|&f| f == 1.0)
            && !self.p2p_over_pcie
            && self.pcie_background_load == 0.0
            && self.nic_rx_loss == 0.0
            && self.nic_tx_loss == 0.0
            && self.nic_background_frac == 0.0
            && self.nic_tx_buffer_factor == 1.0
            && self.egress_jitter == 0.0
            && self.collective_silence == 0.0
    }
}

/// Fabric-level pathology knobs (shared across nodes).
#[derive(Debug, Clone)]
pub struct FabricKnobs {
    /// Extra load factor on "hot" uplinks (EW4); 0 = none.
    pub hot_uplink_load: f64,
    /// Which node's uplink is hot (EW4); None = all equally.
    pub hot_node: Option<NodeId>,
    /// Packet/burst loss probability in the fabric (EW6).
    pub loss_prob: f64,
    /// Head-of-line blocking: serialize flows through one queue (EW5).
    pub hol_blocking: bool,
    /// RDMA credit window (messages in flight before requiring a credit
    /// update); small values starve (EW7).
    pub credit_window: u32,
    /// Multiplies KV-transfer link budget (EW8: <1 shrinks it).
    pub kv_link_budget_factor: f64,
    /// Multiplies the prefill→decode KV-handoff link budget (PD2: <1 makes
    /// the phase-transition transfer crawl without touching EW8's path).
    pub handoff_budget_factor: f64,
}

impl Default for FabricKnobs {
    fn default() -> Self {
        FabricKnobs {
            hot_uplink_load: 0.0,
            hot_node: None,
            loss_prob: 0.0,
            hol_blocking: false,
            credit_window: 64,
            kv_link_budget_factor: 1.0,
            handoff_budget_factor: 1.0,
        }
    }
}

impl FabricKnobs {
    pub fn is_healthy(&self) -> bool {
        self.hot_uplink_load == 0.0
            && self.loss_prob == 0.0
            && !self.hol_blocking
            && self.credit_window >= 64
            && self.kv_link_budget_factor == 1.0
            && self.handoff_budget_factor == 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_validates() {
        assert!(ClusterSpec::default().validate().is_ok());
    }

    #[test]
    fn invalid_specs_rejected() {
        let mut s = ClusterSpec::default();
        s.tp_degree = 8; // > gpus_per_node
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::default();
        s.pp_degree = 9;
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::default();
        s.oversubscription = 0.5;
        assert!(s.validate().is_err());
    }

    #[test]
    fn gpu_node_mapping() {
        let s = ClusterSpec::default(); // 4 nodes x 4 gpus
        assert_eq!(s.node_of_gpu(GpuId(0)), NodeId(0));
        assert_eq!(s.node_of_gpu(GpuId(5)), NodeId(1));
        assert_eq!(s.node_of_gpu(GpuId(15)), NodeId(3));
        assert_eq!(s.gpus_of_node(NodeId(1)), vec![GpuId(4), GpuId(5), GpuId(6), GpuId(7)]);
    }

    #[test]
    fn replica_shapes_size_and_label() {
        let p = ReplicaShape::new(ReplicaRole::Prefill, 8, 1);
        assert_eq!(p.nodes_needed(4), 2);
        assert_eq!(p.label(), "prefill:tp8xpp1");
        assert!(p.role.serves_prefill() && !p.role.serves_decode());
        let d = ReplicaShape::new(ReplicaRole::Decode, 4, 2);
        assert_eq!(d.nodes_needed(4), 2);
        assert!(d.role.serves_decode() && !d.role.serves_prefill());
        let c = ReplicaShape::new(ReplicaRole::Colocated, 8, 2);
        assert_eq!(c.nodes_needed(4), 4);
        assert!(c.role.serves_prefill() && c.role.serves_decode());
    }

    #[test]
    #[should_panic(expected = "whole-node multiple")]
    fn fractional_node_shape_rejected() {
        ReplicaShape::new(ReplicaRole::Prefill, 6, 1).nodes_needed(4);
    }

    #[test]
    fn handoff_budget_is_a_health_knob() {
        let mut f = FabricKnobs::default();
        assert!(f.is_healthy());
        f.handoff_budget_factor = 0.2;
        assert!(!f.is_healthy());
    }

    #[test]
    fn knob_health_checks() {
        let k = NodeKnobs::healthy(4);
        assert!(k.is_healthy());
        let mut k2 = k.clone();
        k2.gpu_speed_factor[2] = 0.5;
        assert!(!k2.is_healthy());
        assert!(FabricKnobs::default().is_healthy());
        let mut f = FabricKnobs::default();
        f.loss_prob = 0.01;
        assert!(!f.is_healthy());
    }
}
