//! Hardware component models: bandwidth-queued links, the per-node PCIe
//! complex, NIC queues with loss/retransmit, and a GPU execution model.
//!
//! Every model is a passive state machine: callers pass `now`, models return
//! completion times and emit telemetry into an [`Outbox`]. All timing flows
//! through busy-until bandwidth queueing — simple, O(1), and it produces the
//! queueing/burst/starvation signatures the runbooks describe.

use crate::cluster::topology::{ClusterSpec, NodeKnobs};
use crate::ids::{GpuId, LinkId, NodeId};
use crate::sim::{SimDur, SimTime};
use crate::telemetry::event::{Phase, TelemetryKind};
use crate::util::rng::Rng;

/// Deferred telemetry emissions: (timestamp, node, kind). The scenario loop
/// drains `items` into the telemetry bus's per-node buffers (capacity is
/// reused), and the bus batch-delivers them time-ordered at window ticks.
#[derive(Debug, Clone, Default)]
pub struct Outbox {
    pub items: Vec<(SimTime, NodeId, TelemetryKind)>,
}

impl Outbox {
    pub fn new() -> Self {
        Outbox { items: Vec::new() }
    }

    #[inline]
    pub fn emit(&mut self, t: SimTime, node: NodeId, kind: TelemetryKind) {
        self.items.push((t, node, kind));
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// A bandwidth-queued link: transfers serialize; queueing delay emerges from
/// `busy_until`. Tracks busy-time for utilization sampling.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub bw: f64, // bytes/sec
    pub base_lat_ns: u64,
    busy_until: SimTime,
    busy_ns_accum: u64,
    last_sample: SimTime,
    pub bytes_total: u64,
}

impl LinkModel {
    pub fn new(bw: f64, base_lat_ns: u64) -> Self {
        LinkModel {
            bw,
            base_lat_ns,
            busy_until: SimTime::ZERO,
            busy_ns_accum: 0,
            last_sample: SimTime::ZERO,
            bytes_total: 0,
        }
    }

    /// Queue a transfer of `bytes` at `now` with an effective bandwidth
    /// factor; returns (service_start, completion).
    pub fn transfer(&mut self, now: SimTime, bytes: u64, bw_factor: f64) -> (SimTime, SimTime) {
        let start = now.max(self.busy_until);
        let eff_bw = (self.bw * bw_factor).max(1.0);
        let service_ns = (bytes as f64 / eff_bw * 1e9).ceil() as u64;
        let done = start + SimDur(service_ns + self.base_lat_ns);
        self.busy_until = SimTime(start.ns() + service_ns);
        self.busy_ns_accum += service_ns;
        self.bytes_total += bytes;
        (start, done)
    }

    /// Instantaneous backlog at `now`, in ns of queued service time.
    pub fn backlog_ns(&self, now: SimTime) -> u64 {
        self.busy_until.ns().saturating_sub(now.ns())
    }

    /// Busy fraction since the last utilization sample.
    pub fn utilization_sample(&mut self, now: SimTime) -> f64 {
        let span = (now - self.last_sample).ns().max(1);
        let frac = (self.busy_ns_accum as f64 / span as f64).min(1.0);
        self.busy_ns_accum = 0;
        self.last_sample = now;
        frac
    }

    /// Reserve a fraction of this link (background tenant): advances
    /// busy_until as if `frac` of the elapsed window were consumed.
    pub fn consume_background(&mut self, now: SimTime, window_ns: u64, frac: f64) {
        if frac <= 0.0 {
            return;
        }
        let burn = (window_ns as f64 * frac) as u64;
        let base = now.max(self.busy_until);
        self.busy_until = SimTime(base.ns() + burn);
        self.busy_ns_accum += burn;
    }
}

/// Fragment size when the pinned pool is fragmented (PC7).
const FRAG_BYTES: u64 = 64 * 1024;
/// Max fragments per logical DMA (bounds event volume).
const MAX_FRAGS: u64 = 8;
/// Registration (map/unmap) cost when churn is active (PC9).
const MEM_REG_NS: u64 = 2_000;
/// Extra staging latency for pageable buffers (PC1 flavor).
const UNPINNED_STAGE_NS: u64 = 15_000;

/// Per-node PCIe root complex: per-GPU x16 links plus a shared switch uplink
/// that P2P and background tenants contend on.
#[derive(Debug, Clone)]
pub struct PcieComplex {
    node: NodeId,
    pub per_gpu: Vec<LinkModel>,
    pub switch_uplink: LinkModel,
    dma_seq: u64,
}

impl PcieComplex {
    pub fn new(node: NodeId, spec: &ClusterSpec) -> Self {
        PcieComplex {
            node,
            per_gpu: (0..spec.gpus_per_node)
                .map(|_| LinkModel::new(spec.pcie_bw, spec.pcie_base_lat_ns))
                .collect(),
            // Switch uplink is shared: model at 2x a single GPU link.
            switch_uplink: LinkModel::new(spec.pcie_bw * 2.0, spec.pcie_base_lat_ns),
            dma_seq: 0,
        }
    }

    fn local_idx(&self, gpu: GpuId) -> usize {
        gpu.idx() % self.per_gpu.len()
    }

    /// Host-to-device DMA. Returns completion time.
    pub fn h2d(
        &mut self,
        now: SimTime,
        gpu: GpuId,
        bytes: u64,
        phase: Phase,
        knobs: &NodeKnobs,
        out: &mut Outbox,
    ) -> SimTime {
        self.dma(now, gpu, bytes, phase, knobs, out, /*h2d=*/ true)
    }

    /// Device-to-host DMA. Returns completion time.
    pub fn d2h(
        &mut self,
        now: SimTime,
        gpu: GpuId,
        bytes: u64,
        phase: Phase,
        knobs: &NodeKnobs,
        out: &mut Outbox,
    ) -> SimTime {
        self.dma(now, gpu, bytes, phase, knobs, out, /*h2d=*/ false)
    }

    fn dma(
        &mut self,
        now: SimTime,
        gpu: GpuId,
        bytes: u64,
        phase: Phase,
        knobs: &NodeKnobs,
        out: &mut Outbox,
        h2d: bool,
    ) -> SimTime {
        self.dma_seq += 1;
        let node = self.node;
        let idx = self.local_idx(gpu);
        let bw_factor = if h2d { knobs.h2d_bw_factor } else { knobs.d2h_bw_factor }
            * (1.0 - knobs.pcie_background_load).max(0.05);
        let mut issue = now;
        // Pageable buffers stage through a bounce buffer first.
        if knobs.unpinned_buffers {
            issue = issue + SimDur(UNPINNED_STAGE_NS);
        }
        // Registration churn maps before and unmaps after.
        if knobs.mem_reg_churn {
            out.emit(issue, node, TelemetryKind::MemRegistration { gpu, bytes, unmap: false });
            issue = issue + SimDur(MEM_REG_NS);
        }
        // Fragmentation splits the logical DMA into small transactions.
        let n_frags = if knobs.pinned_pool_frag {
            (bytes / FRAG_BYTES).clamp(4, MAX_FRAGS)
        } else {
            1
        };
        let frag_bytes = bytes / n_frags;
        let extra = SimDur(knobs.pcie_extra_lat_ns);
        let mut done = issue;
        for _ in 0..n_frags {
            let (start, frag_done) = self.per_gpu[idx].transfer(issue, frag_bytes, bw_factor);
            let frag_done = frag_done + extra;
            let lat = (frag_done - start).ns();
            let kind = if h2d {
                TelemetryKind::DmaH2d { gpu, bytes: frag_bytes, latency_ns: lat, phase }
            } else {
                TelemetryKind::DmaD2h { gpu, bytes: frag_bytes, latency_ns: lat, phase }
            };
            out.emit(frag_done, node, kind);
            done = frag_done;
            issue = start; // fragments pipeline behind each other
        }
        done
    }

    /// GPU-to-GPU transfer over the PCIe switch (when NVLink is absent or
    /// disabled). Returns completion.
    pub fn p2p(
        &mut self,
        now: SimTime,
        from: GpuId,
        to: GpuId,
        bytes: u64,
        knobs: &NodeKnobs,
        out: &mut Outbox,
    ) -> SimTime {
        let bw_factor = (1.0 - knobs.pcie_background_load).max(0.05);
        let (start, done) = self.switch_uplink.transfer(now, bytes, bw_factor);
        let lat = (done - start).ns();
        out.emit(done, self.node, TelemetryKind::P2pPcie { from, to, bytes, latency_ns: lat });
        done
    }

    /// Periodic utilization sample across the per-GPU links.
    pub fn sample_util(&mut self, now: SimTime, out: &mut Outbox) {
        let mut total = 0.0;
        let n = self.per_gpu.len();
        for link in &mut self.per_gpu {
            total += link.utilization_sample(now);
        }
        let busy = total / n.max(1) as f64;
        out.emit(now, self.node, TelemetryKind::PcieUtil { link: LinkId(self.node.0), busy });
    }

    /// Apply background tenant load for the elapsed window (PC5).
    pub fn apply_background(&mut self, now: SimTime, window_ns: u64, knobs: &NodeKnobs) {
        if knobs.pcie_background_load > 0.0 {
            for link in &mut self.per_gpu {
                link.consume_background(now, window_ns, knobs.pcie_background_load);
            }
            self.switch_uplink.consume_background(now, window_ns, knobs.pcie_background_load);
        }
    }

    pub fn backlog_ns(&self, now: SimTime, gpu: GpuId) -> u64 {
        self.per_gpu[self.local_idx(gpu)].backlog_ns(now)
    }
}

/// Retransmission timeout for lost packets.
const RETX_TIMEOUT_NS: u64 = 50_000;
/// Max retransmission attempts before we give up and deliver anyway (the
/// transport eventually succeeds; we only model added latency + signals).
const MAX_RETX: u32 = 3;
/// Nominal packet size for queue-depth estimation.
const PKT_BYTES: u64 = 4096;

/// NIC model: RX and TX queues at line rate with loss/retransmit and
/// background-traffic contention.
#[derive(Debug, Clone)]
pub struct Nic {
    node: NodeId,
    pub rx: LinkModel,
    pub tx: LinkModel,
    queue_cap: u32,
    pub rx_drops: u64,
    pub tx_drops: u64,
}

impl Nic {
    pub fn new(node: NodeId, spec: &ClusterSpec) -> Self {
        Nic {
            node,
            rx: LinkModel::new(spec.nic_bw, 500),
            tx: LinkModel::new(spec.nic_bw, 500),
            queue_cap: spec.nic_queue_cap,
            rx_drops: 0,
            tx_drops: 0,
        }
    }

    fn qdepth(link: &LinkModel, now: SimTime, bw: f64) -> u32 {
        let ns_per_pkt = (PKT_BYTES as f64 / bw * 1e9).max(1.0);
        (link.backlog_ns(now) as f64 / ns_per_pkt) as u32
    }

    /// Ingress delivery: returns when the payload reaches the host.
    /// Loss inflates latency by retransmission rounds and emits signals.
    pub fn ingress(
        &mut self,
        now: SimTime,
        flow: crate::ids::FlowId,
        bytes: u64,
        knobs: &NodeKnobs,
        rng: &mut Rng,
        out: &mut Outbox,
    ) -> SimTime {
        let bw_factor = (1.0 - knobs.nic_background_frac).max(0.05);
        let mut attempt_start = now;
        let mut attempts = 0;
        while attempts < MAX_RETX && rng.chance(knobs.nic_rx_loss) {
            attempts += 1;
            self.rx_drops += 1;
            out.emit(attempt_start, self.node, TelemetryKind::PktDrop { flow, ingress: true, fabric: false });
            let retx_at = attempt_start + SimDur(RETX_TIMEOUT_NS);
            out.emit(retx_at, self.node, TelemetryKind::Retransmit { flow, ingress: true, fabric: false });
            attempt_start = retx_at;
        }
        let (start, done) = self.rx.transfer(attempt_start, bytes, bw_factor);
        let depth = Self::qdepth(&self.rx, start, self.rx.bw);
        out.emit(done, self.node, TelemetryKind::NicRx { flow, bytes, queue_depth: depth });
        done
    }

    /// Egress: returns when the last byte leaves the wire.
    pub fn egress(
        &mut self,
        now: SimTime,
        flow: crate::ids::FlowId,
        bytes: u64,
        knobs: &NodeKnobs,
        rng: &mut Rng,
        out: &mut Outbox,
    ) -> SimTime {
        // Host-side copy cost (CPU contention) before the NIC sees it.
        let copy_ns = (2_000.0 * knobs.cpu_contention) as u64;
        // Egress scheduler jitter (NS6).
        let jitter_ns = if knobs.egress_jitter > 0.0 {
            (rng.exponential(1.0 / (knobs.egress_jitter * 20_000.0)).min(500_000.0)) as u64
        } else {
            0
        };
        let enqueue = now + SimDur(copy_ns + jitter_ns);
        let bw_factor =
            (1.0 - knobs.nic_background_frac).max(0.05) * knobs.nic_tx_buffer_factor.min(1.0);
        let mut attempt_start = enqueue;
        let mut attempts = 0;
        while attempts < MAX_RETX && rng.chance(knobs.nic_tx_loss) {
            attempts += 1;
            self.tx_drops += 1;
            out.emit(attempt_start, self.node, TelemetryKind::PktDrop { flow, ingress: false, fabric: false });
            let retx_at = attempt_start + SimDur(RETX_TIMEOUT_NS);
            out.emit(retx_at, self.node, TelemetryKind::Retransmit { flow, ingress: false, fabric: false });
            attempt_start = retx_at;
        }
        let (start, done) = self.tx.transfer(attempt_start, bytes, bw_factor);
        // Wait = request-to-wire delay: host copy + scheduler jitter +
        // retransmit rounds + queueing. This is what a DPU timestamps.
        let wait = (start - now).ns();
        let cap = (self.queue_cap as f64 * knobs.nic_tx_buffer_factor) as u32;
        let depth = Self::qdepth(&self.tx, start, self.tx.bw).min(cap.max(1));
        out.emit(
            done,
            self.node,
            TelemetryKind::NicTx { flow, bytes, queue_depth: depth, wait_ns: wait },
        );
        done
    }

    pub fn apply_background(&mut self, now: SimTime, window_ns: u64, knobs: &NodeKnobs) {
        if knobs.nic_background_frac > 0.0 {
            self.rx.consume_background(now, window_ns, knobs.nic_background_frac);
            self.tx.consume_background(now, window_ns, knobs.nic_background_frac);
        }
    }
}

/// Fixed kernel-launch overhead (doorbell to execution start).
const KERNEL_LAUNCH_NS: u64 = 4_000;

/// GPU execution model: serial kernel slots with a per-GPU speed factor.
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub gpu: GpuId,
    node: NodeId,
    /// Peak throughput, FLOP/s.
    pub flops_per_s: f64,
    busy_until: SimTime,
    pub kernels_run: u64,
    pub busy_ns_total: u64,
}

impl GpuModel {
    pub fn new(gpu: GpuId, node: NodeId, flops_per_s: f64) -> Self {
        GpuModel {
            gpu,
            node,
            flops_per_s,
            busy_until: SimTime::ZERO,
            kernels_run: 0,
            busy_ns_total: 0,
        }
    }

    /// Issue the doorbell (DPU-visible) then run the kernel (DPU-invisible).
    /// Returns kernel completion time.
    pub fn launch(
        &mut self,
        ready: SimTime,
        flops: f64,
        knobs: &NodeKnobs,
        out: &mut Outbox,
    ) -> SimTime {
        let local = self.gpu.idx() % knobs.gpu_speed_factor.len().max(1);
        let speed = knobs.gpu_speed_factor.get(local).copied().unwrap_or(1.0).max(0.01);
        let fission = knobs.kernel_fission.max(1) as u64;
        // Host-side launch path: doorbell delayed by CPU contention + knob.
        let db_delay = (knobs.doorbell_delay_ns as f64 * knobs.cpu_contention) as u64
            + ((knobs.cpu_contention - 1.0).max(0.0) * 10_000.0) as u64;
        let mut t = ready + SimDur(db_delay);
        let flops_per_kernel = flops / fission as f64;
        for _ in 0..fission {
            out.emit(t, self.node, TelemetryKind::Doorbell { gpu: self.gpu });
            let start = t.max(self.busy_until) + SimDur(KERNEL_LAUNCH_NS);
            let dur_ns = (flops_per_kernel / (self.flops_per_s * speed) * 1e9).ceil() as u64;
            let done = start + SimDur(dur_ns);
            out.emit(
                done,
                self.node,
                TelemetryKind::GpuKernel { gpu: self.gpu, dur_ns, flops: flops_per_kernel },
            );
            self.busy_until = done;
            self.kernels_run += 1;
            self.busy_ns_total += dur_ns;
            t = done;
        }
        self.busy_until
    }

    pub fn busy_until(&self) -> SimTime {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::FlowId;

    fn spec() -> ClusterSpec {
        ClusterSpec::default()
    }

    #[test]
    fn link_serializes_transfers() {
        let mut l = LinkModel::new(1e9, 0); // 1 GB/s
        let (s1, d1) = l.transfer(SimTime(0), 1_000_000, 1.0); // 1ms service
        assert_eq!(s1, SimTime(0));
        assert_eq!(d1.ns(), 1_000_000);
        let (s2, d2) = l.transfer(SimTime(0), 1_000_000, 1.0);
        assert_eq!(s2.ns(), 1_000_000); // queued behind first
        assert_eq!(d2.ns(), 2_000_000);
        assert_eq!(l.backlog_ns(SimTime(0)), 2_000_000);
    }

    #[test]
    fn bw_factor_slows_transfer() {
        let mut l = LinkModel::new(1e9, 0);
        let (_, d) = l.transfer(SimTime(0), 1_000_000, 0.5);
        assert_eq!(d.ns(), 2_000_000);
    }

    #[test]
    fn h2d_emits_event_and_respects_knobs() {
        let mut pcie = PcieComplex::new(NodeId(0), &spec());
        let knobs = NodeKnobs::healthy(4);
        let mut out = Outbox::new();
        let done = pcie.h2d(SimTime(0), GpuId(0), 1 << 20, Phase::Prefill, &knobs, &mut out);
        assert_eq!(out.len(), 1);
        assert!(done.ns() > 0);
        // Slow H2D doubles the time.
        let mut pcie2 = PcieComplex::new(NodeId(0), &spec());
        let mut slow = NodeKnobs::healthy(4);
        slow.h2d_bw_factor = 0.5;
        let done_slow =
            pcie2.h2d(SimTime(0), GpuId(0), 1 << 20, Phase::Prefill, &slow, &mut out);
        assert!(done_slow > done);
    }

    #[test]
    fn fragmentation_raises_dma_count() {
        let mut pcie = PcieComplex::new(NodeId(0), &spec());
        let mut knobs = NodeKnobs::healthy(4);
        knobs.pinned_pool_frag = true;
        let mut out = Outbox::new();
        pcie.h2d(SimTime(0), GpuId(0), 1 << 20, Phase::Prefill, &knobs, &mut out);
        assert!(out.len() >= 2, "expected multiple fragment DMAs, got {}", out.len());
    }

    #[test]
    fn reg_churn_emits_registration() {
        let mut pcie = PcieComplex::new(NodeId(0), &spec());
        let mut knobs = NodeKnobs::healthy(4);
        knobs.mem_reg_churn = true;
        let mut out = Outbox::new();
        pcie.h2d(SimTime(0), GpuId(0), 4096, Phase::Decode, &knobs, &mut out);
        let has_reg = out
            .items
            .iter()
            .any(|(_, _, k)| matches!(k, TelemetryKind::MemRegistration { .. }));
        assert!(has_reg);
    }

    #[test]
    fn nic_loss_adds_retransmit_latency() {
        let s = spec();
        let mut nic = Nic::new(NodeId(0), &s);
        let healthy = NodeKnobs::healthy(4);
        let mut lossy = NodeKnobs::healthy(4);
        lossy.nic_rx_loss = 1.0; // always lose (capped at MAX_RETX)
        let mut rng = Rng::seeded(1);
        let mut out = Outbox::new();
        let d_ok = nic.ingress(SimTime(0), FlowId(0), 4096, &healthy, &mut rng, &mut out);
        let mut nic2 = Nic::new(NodeId(0), &s);
        let d_lossy = nic2.ingress(SimTime(0), FlowId(0), 4096, &lossy, &mut rng, &mut out);
        assert!(d_lossy.ns() >= d_ok.ns() + RETX_TIMEOUT_NS);
        let retx = out
            .items
            .iter()
            .filter(|(_, _, k)| matches!(k, TelemetryKind::Retransmit { .. }))
            .count();
        assert_eq!(retx, MAX_RETX as usize);
    }

    #[test]
    fn gpu_speed_factor_stretches_kernels() {
        let mut g = GpuModel::new(GpuId(0), NodeId(0), 100e12);
        let mut out = Outbox::new();
        let healthy = NodeKnobs::healthy(1);
        let d1 = g.launch(SimTime(0), 1e12, &healthy, &mut out);
        let mut g2 = GpuModel::new(GpuId(0), NodeId(0), 100e12);
        let mut slow = NodeKnobs::healthy(1);
        slow.gpu_speed_factor[0] = 0.5;
        let d2 = g2.launch(SimTime(0), 1e12, &slow, &mut out);
        assert!(d2.ns() > (d1.ns() as f64 * 1.8) as u64);
    }

    #[test]
    fn kernel_fission_multiplies_doorbells() {
        let mut g = GpuModel::new(GpuId(0), NodeId(0), 100e12);
        let mut out = Outbox::new();
        let mut knobs = NodeKnobs::healthy(1);
        knobs.kernel_fission = 8;
        g.launch(SimTime(0), 1e9, &knobs, &mut out);
        let doorbells = out
            .items
            .iter()
            .filter(|(_, _, k)| matches!(k, TelemetryKind::Doorbell { .. }))
            .count();
        assert_eq!(doorbells, 8);
        assert_eq!(g.kernels_run, 8);
    }

    #[test]
    fn utilization_sample_resets() {
        let mut l = LinkModel::new(1e9, 0);
        l.transfer(SimTime(0), 500_000, 1.0); // 0.5ms busy
        let u = l.utilization_sample(SimTime(1_000_000));
        assert!((u - 0.5).abs() < 0.01, "u={u}");
        let u2 = l.utilization_sample(SimTime(2_000_000));
        assert_eq!(u2, 0.0);
    }
}
