//! Inter-node fabric: per-node uplinks into a fat-tree core, RDMA queue
//! pairs with credit flow-control, loss, head-of-line blocking, and hot-link
//! oversubscription — the east-west substrate for Table 3(c).

use std::collections::HashMap;

use crate::cluster::models::{LinkModel, Outbox};
use crate::cluster::topology::{ClusterSpec, FabricKnobs};
use crate::ids::{NodeId, QpId};
use crate::sim::{SimDur, SimTime};
use crate::telemetry::event::TelemetryKind;
use crate::util::rng::Rng;

/// Retransmission timeout inside the fabric.
const FABRIC_RETX_NS: u64 = 80_000;
/// Credit-update round trip once the window empties.
const CREDIT_RTT_NS: u64 = 12_000;

/// One RDMA queue pair's flow-control state.
#[derive(Debug, Clone, Default)]
struct QpState {
    in_flight: u32,
    next_credit_at: SimTime,
}

/// The cluster fabric: per-node up/down links + a shared core.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub uplinks: Vec<LinkModel>,
    pub downlinks: Vec<LinkModel>,
    pub core: LinkModel,
    base_lat_ns: u64,
    qps: HashMap<QpId, QpState>,
    /// Serializer used when HOL blocking is injected: all flows share it.
    hol_queue: LinkModel,
    pub transfers: u64,
    pub loss_events: u64,
}

impl Fabric {
    pub fn new(spec: &ClusterSpec) -> Self {
        let core_bw = spec.nic_bw * spec.n_nodes as f64 / spec.oversubscription;
        Fabric {
            uplinks: (0..spec.n_nodes).map(|_| LinkModel::new(spec.nic_bw, 200)).collect(),
            downlinks: (0..spec.n_nodes).map(|_| LinkModel::new(spec.nic_bw, 200)).collect(),
            core: LinkModel::new(core_bw, spec.fabric_base_lat_ns),
            base_lat_ns: spec.fabric_base_lat_ns,
            qps: HashMap::new(),
            hol_queue: LinkModel::new(spec.nic_bw, 0),
            transfers: 0,
            loss_events: 0,
        }
    }

    /// QP id for a (src, dst) node pair — one QP per directed pair.
    pub fn qp_for(&self, from: NodeId, to: NodeId) -> QpId {
        QpId(from.0 * 1024 + to.0)
    }

    /// Transfer `bytes` from `from` to `to` as one RDMA burst.
    ///
    /// Emits, at the *destination* node (where that node's DPU sees it):
    /// RdmaOp (+credit wait), plus loss/retransmit signals on the path.
    /// Returns arrival time of the last byte.
    #[allow(clippy::too_many_arguments)]
    pub fn rdma(
        &mut self,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        bytes: u64,
        knobs: &FabricKnobs,
        rng: &mut Rng,
        out: &mut Outbox,
    ) -> SimTime {
        self.transfers += 1;
        let qp = self.qp_for(from, to);
        // --- credit flow control (EW7) ---
        let window = knobs.credit_window.max(1);
        let st = self.qps.entry(qp).or_default();
        let mut start = now;
        let mut credit_wait = 0u64;
        if st.in_flight >= window {
            // Stall until the remote returns credits.
            let credit_at = st.next_credit_at.max(now + SimDur(CREDIT_RTT_NS));
            credit_wait = (credit_at - now).ns();
            start = credit_at;
            st.in_flight = 0;
            out.emit(credit_at, to, TelemetryKind::CreditUpdate { qp });
        }
        st.in_flight += 1;
        st.next_credit_at = start + SimDur(CREDIT_RTT_NS);

        // --- loss / retransmit (EW6) ---
        let mut attempt = start;
        let mut rounds = 0;
        while rounds < 3 && rng.chance(knobs.loss_prob) {
            rounds += 1;
            self.loss_events += 1;
            out.emit(
                attempt,
                to,
                TelemetryKind::PktDrop { flow: crate::ids::FlowId(qp.0), ingress: true, fabric: true },
            );
            let retx = attempt + SimDur(FABRIC_RETX_NS);
            out.emit(
                retx,
                to,
                TelemetryKind::Retransmit { flow: crate::ids::FlowId(qp.0), ingress: true, fabric: true },
            );
            attempt = retx;
        }

        // --- path: src uplink -> core -> dst downlink ---
        let hot = knobs.hot_node.map_or(knobs.hot_uplink_load > 0.0, |n| n == from)
            && knobs.hot_uplink_load > 0.0;
        let up_factor = if hot { 1.0 / (1.0 + knobs.hot_uplink_load) } else { 1.0 };
        let (_, up_done) = self.uplinks[from.idx()].transfer(attempt, bytes, up_factor);
        let (_, core_done) = self.core.transfer(up_done, bytes, 1.0);
        // HOL blocking (EW5): flows hashed to the exhausted queue serialize
        // behind each other while other flows pass — the bimodal signature.
        let hol_hash = ((qp.0 >> 10) + (qp.0 & 1023)) % 2 == 0;
        let pre_down = if knobs.hol_blocking && hol_hash {
            let (_, hol_done) = self.hol_queue.transfer(core_done, bytes, 0.25);
            hol_done
        } else {
            core_done
        };
        let (_, down_done) = self.downlinks[to.idx()].transfer(pre_down, bytes, 1.0);
        let arrival = down_done + SimDur(self.base_lat_ns);

        let latency_ns = (arrival - now).ns();
        out.emit(
            arrival,
            to,
            TelemetryKind::RdmaOp { qp, bytes, credit_wait_ns: credit_wait, latency_ns },
        );
        arrival
    }

    /// Observable backlog on a node's uplink.
    pub fn uplink_backlog_ns(&self, node: NodeId, now: SimTime) -> u64 {
        self.uplinks[node.idx()].backlog_ns(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Fabric, FabricKnobs, Rng, Outbox) {
        (
            Fabric::new(&ClusterSpec::default()),
            FabricKnobs::default(),
            Rng::seeded(1),
            Outbox::new(),
        )
    }

    #[test]
    fn rdma_emits_op_at_destination() {
        let (mut f, knobs, mut rng, mut out) = setup();
        let arr = f.rdma(SimTime(0), NodeId(0), NodeId(1), 1 << 20, &knobs, &mut rng, &mut out);
        assert!(arr.ns() > 0);
        let (t, node, kind) = out.items.last().unwrap();
        assert_eq!(*node, NodeId(1));
        assert_eq!(*t, arr);
        assert!(matches!(kind, TelemetryKind::RdmaOp { .. }));
    }

    #[test]
    fn small_credit_window_stalls() {
        let (mut f, mut knobs, mut rng, mut out) = setup();
        knobs.credit_window = 1;
        let mut last = SimTime(0);
        let mut credit_waits = 0;
        for _ in 0..8 {
            last = f.rdma(last, NodeId(0), NodeId(1), 4096, &knobs, &mut rng, &mut out);
        }
        for (_, _, k) in &out.items {
            if let TelemetryKind::RdmaOp { credit_wait_ns, .. } = k {
                if *credit_wait_ns > 0 {
                    credit_waits += 1;
                }
            }
        }
        assert!(credit_waits >= 3, "credit_waits={credit_waits}");
        let updates = out
            .items
            .iter()
            .filter(|(_, _, k)| matches!(k, TelemetryKind::CreditUpdate { .. }))
            .count();
        assert!(updates >= 3);
    }

    #[test]
    fn hot_uplink_slows_only_hot_node() {
        let (mut f, mut knobs, mut rng, mut out) = setup();
        knobs.hot_uplink_load = 4.0;
        knobs.hot_node = Some(NodeId(0));
        let a_hot = f.rdma(SimTime(0), NodeId(0), NodeId(2), 1 << 22, &knobs, &mut rng, &mut out);
        let mut f2 = Fabric::new(&ClusterSpec::default());
        let a_cool =
            f2.rdma(SimTime(0), NodeId(1), NodeId(2), 1 << 22, &knobs, &mut rng, &mut out);
        assert!(a_hot.ns() > a_cool.ns() * 2, "hot={} cool={}", a_hot.ns(), a_cool.ns());
    }

    #[test]
    fn loss_adds_retransmits() {
        let (mut f, mut knobs, mut rng, mut out) = setup();
        knobs.loss_prob = 1.0;
        f.rdma(SimTime(0), NodeId(0), NodeId(1), 4096, &knobs, &mut rng, &mut out);
        let retx = out
            .items
            .iter()
            .filter(|(_, _, k)| matches!(k, TelemetryKind::Retransmit { .. }))
            .count();
        assert_eq!(retx, 3);
        assert_eq!(f.loss_events, 3);
    }

    #[test]
    fn hol_blocking_stalls_only_hashed_flows() {
        // HOL blocking exhausts one shared queue: flows hashed onto it
        // (even qp ids) stall; other flows pass — the bimodal signature
        // EW5's detector keys on.
        let (mut f, mut knobs, mut rng, mut out) = setup();
        knobs.hol_blocking = true;
        // hash = (from+to)%2: (0->2) blocked, (1->0) free (disjoint links).
        let blocked = f.rdma(SimTime(0), NodeId(0), NodeId(2), 1 << 22, &knobs, &mut rng, &mut out);
        let free = f.rdma(SimTime(0), NodeId(1), NodeId(0), 1 << 22, &knobs, &mut rng, &mut out);
        assert!(blocked.ns() > free.ns() * 2, "blocked={} free={}", blocked.ns(), free.ns());
        // Without HOL, the blocked-hash path is as fast as any other.
        let (mut f2, knobs2, mut rng2, mut out2) = setup();
        let b2 = f2.rdma(SimTime(0), NodeId(0), NodeId(2), 1 << 22, &knobs2, &mut rng2, &mut out2);
        assert!(blocked.ns() > b2.ns() * 2, "hol={} healthy={}", blocked.ns(), b2.ns());
    }

    #[test]
    fn oversubscribed_core_is_slower_under_fanin(){
        let mut spec = ClusterSpec::default();
        spec.oversubscription = 8.0;
        let mut f_over = Fabric::new(&spec);
        let f_knobs = FabricKnobs::default();
        let mut rng = Rng::seeded(2);
        let mut out = Outbox::new();
        // all nodes send to node 0 simultaneously
        let mut worst_over = SimTime(0);
        for n in 1..4u32 {
            let a = f_over.rdma(SimTime(0), NodeId(n), NodeId(0), 1 << 22, &f_knobs, &mut rng, &mut out);
            worst_over = worst_over.max(a);
        }
        let mut f_nb = Fabric::new(&ClusterSpec::default());
        let mut worst_nb = SimTime(0);
        for n in 1..4u32 {
            let a = f_nb.rdma(SimTime(0), NodeId(n), NodeId(0), 1 << 22, &f_knobs, &mut rng, &mut out);
            worst_nb = worst_nb.max(a);
        }
        assert!(worst_over > worst_nb);
    }
}
