//! Discrete-event simulation core: sim-time, the event calendar, and the
//! stochastic processes that shape workloads.

pub mod dist;
pub mod engine;
pub mod time;

pub use engine::{CalendarKind, Engine};
pub use time::{SimDur, SimTime, MS, NS, SEC, US};
