//! Workload-shaping stochastic processes on top of `util::rng`.
//!
//! These produce the traffic *shapes* the paper's runbook conditions are
//! sensitive to: Poisson vs bursty (ON-OFF) arrivals, heavy-tailed and
//! bimodal sequence lengths, and diurnal-style rate modulation.

use crate::sim::time::{SimDur, SEC};
use crate::util::rng::Rng;

/// Inter-arrival process for requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Poisson arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// Markov-modulated ON-OFF bursts: exponential dwell in each phase,
    /// Poisson arrivals at `on_rate` during ON, `off_rate` during OFF.
    OnOff {
        on_rate: f64,
        off_rate: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
    /// Fixed-interval arrivals (closed-loop benchmarks).
    Uniform { rate: f64 },
}

/// Stateful sampler for an [`Arrival`] process.
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    proc: Arrival,
    rng: Rng,
    in_on_phase: bool,
    phase_left_s: f64,
}

impl ArrivalSampler {
    pub fn new(proc: Arrival, rng: Rng) -> Self {
        ArrivalSampler { proc, rng, in_on_phase: true, phase_left_s: 0.0 }
    }

    /// Next inter-arrival gap.
    pub fn next_gap(&mut self) -> SimDur {
        match self.proc {
            Arrival::Poisson { rate } => SimDur::from_secs_f64(self.rng.exponential(rate)),
            Arrival::Uniform { rate } => SimDur::from_secs_f64(1.0 / rate),
            Arrival::OnOff { on_rate, off_rate, mean_on_s, mean_off_s } => {
                // Advance through phases until an arrival lands inside one.
                let mut gap_s = 0.0;
                loop {
                    if self.phase_left_s <= 0.0 {
                        self.in_on_phase = !self.in_on_phase;
                        let mean = if self.in_on_phase { mean_on_s } else { mean_off_s };
                        self.phase_left_s = self.rng.exponential(1.0 / mean.max(1e-9));
                    }
                    let rate = if self.in_on_phase { on_rate } else { off_rate };
                    if rate <= 1e-9 {
                        gap_s += self.phase_left_s;
                        self.phase_left_s = 0.0;
                        continue;
                    }
                    let draw = self.rng.exponential(rate);
                    if draw <= self.phase_left_s {
                        self.phase_left_s -= draw;
                        gap_s += draw;
                        return SimDur::from_secs_f64(gap_s);
                    }
                    gap_s += self.phase_left_s;
                    self.phase_left_s = 0.0;
                }
            }
        }
    }
}

/// Sequence-length distribution for prompts and outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    /// All sequences the same length.
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform { lo: usize, hi: usize },
    /// Log-normal (token counts), clamped to [lo, hi].
    LogNormal { mu: f64, sigma: f64, lo: usize, hi: usize },
    /// Bimodal mixture: short with prob p_short, else long — the shape that
    /// drives early-completion skew (NS8/PC10/EW9).
    Bimodal { short: usize, long: usize, p_short: f64 },
    /// Heavy-tailed Pareto with scale `lo` (the minimum) and tail exponent
    /// `alpha`, clamped at `hi` — production prompt/output mixes where a
    /// small fraction of giant sequences carries most of the token mass.
    Pareto { alpha: f64, lo: usize, hi: usize },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform { lo, hi } => rng.range_u64(lo as u64, hi as u64) as usize,
            LengthDist::LogNormal { mu, sigma, lo, hi } => {
                (rng.lognormal(mu, sigma).round() as usize).clamp(lo, hi)
            }
            LengthDist::Bimodal { short, long, p_short } => {
                if rng.chance(p_short) { short } else { long }
            }
            LengthDist::Pareto { alpha, lo, hi } => {
                (rng.pareto(lo.max(1) as f64, alpha).round() as usize).clamp(lo, hi)
            }
        }
    }

    /// Mean of the distribution (analytic where possible; used by cost models).
    pub fn mean(&self) -> f64 {
        match *self {
            LengthDist::Fixed(n) => n as f64,
            LengthDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
            LengthDist::LogNormal { mu, sigma, lo, hi } => {
                (mu + sigma * sigma / 2.0).exp().clamp(lo as f64, hi as f64)
            }
            LengthDist::Bimodal { short, long, p_short } => {
                p_short * short as f64 + (1.0 - p_short) * long as f64
            }
            LengthDist::Pareto { alpha, lo, hi } => {
                // Analytic mean alpha·x_m/(alpha-1) for alpha > 1; the
                // clamped tail keeps it below hi. alpha ≤ 1 has no finite
                // mean — the clamp bound is the honest summary.
                if alpha > 1.0 {
                    (alpha * lo.max(1) as f64 / (alpha - 1.0)).min(hi as f64)
                } else {
                    hi as f64
                }
            }
        }
    }
}

/// Multiplicative rate modulation over sim time (diurnal / ramp / flash
/// shapes). Shapes compose multiplicatively via [`RateShape::Compose`], so
/// a diurnal curve can carry ON-OFF bursts *and* a flash crowd at once.
#[derive(Debug, Clone, PartialEq)]
pub enum RateShape {
    Constant,
    /// Sinusoidal between `min_factor` and 1.0 with the given period.
    Diurnal { period_s: f64, min_factor: f64 },
    /// Linear ramp from `from` to `to` across `ramp_s`, then hold.
    Ramp { from: f64, to: f64, ramp_s: f64 },
    /// Flash crowd: baseline 1.0 until `at_s`, then an instantaneous jump
    /// to `surge`× decaying exponentially back toward baseline with time
    /// constant `decay_s` (the thundering-herd arrival spike).
    FlashCrowd { at_s: f64, surge: f64, decay_s: f64 },
    /// Product of two shapes (e.g. diurnal × flash crowd).
    Compose(Box<RateShape>, Box<RateShape>),
}

impl RateShape {
    /// Convenience constructor for the composed (product) shape.
    pub fn compose(a: RateShape, b: RateShape) -> RateShape {
        RateShape::Compose(Box::new(a), Box::new(b))
    }

    pub fn factor_at(&self, t_ns: u64) -> f64 {
        let t_s = t_ns as f64 / SEC as f64;
        match *self {
            RateShape::Constant => 1.0,
            RateShape::Diurnal { period_s, min_factor } => {
                let phase = (t_s / period_s) * std::f64::consts::TAU;
                let x = (phase.sin() + 1.0) / 2.0; // 0..1
                min_factor + (1.0 - min_factor) * x
            }
            RateShape::Ramp { from, to, ramp_s } => {
                if t_s >= ramp_s {
                    to
                } else {
                    from + (to - from) * (t_s / ramp_s)
                }
            }
            RateShape::FlashCrowd { at_s, surge, decay_s } => {
                if t_s < at_s {
                    1.0
                } else {
                    1.0 + (surge - 1.0) * (-(t_s - at_s) / decay_s.max(1e-9)).exp()
                }
            }
            RateShape::Compose(ref a, ref b) => a.factor_at(t_ns) * b.factor_at(t_ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let mut s = ArrivalSampler::new(Arrival::Poisson { rate: 100.0 }, Rng::seeded(1));
        let n = 20_000;
        let total: f64 = (0..n).map(|_| s.next_gap().as_secs_f64()).sum();
        let rate = n as f64 / total;
        assert!((rate - 100.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn onoff_burstier_than_poisson() {
        let cv = |mut s: ArrivalSampler| {
            let xs: Vec<f64> = (0..20_000).map(|_| s.next_gap().as_secs_f64()).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
            var.sqrt() / mean
        };
        let poisson = cv(ArrivalSampler::new(Arrival::Poisson { rate: 100.0 }, Rng::seeded(2)));
        let onoff = cv(ArrivalSampler::new(
            Arrival::OnOff { on_rate: 500.0, off_rate: 1.0, mean_on_s: 0.05, mean_off_s: 0.2 },
            Rng::seeded(2),
        ));
        assert!(onoff > poisson * 1.5, "onoff={onoff} poisson={poisson}");
    }

    #[test]
    fn uniform_gap_is_constant() {
        let mut s = ArrivalSampler::new(Arrival::Uniform { rate: 10.0 }, Rng::seeded(3));
        let a = s.next_gap();
        let b = s.next_gap();
        assert_eq!(a, b);
        assert!((a.as_secs_f64() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn length_dists_within_bounds() {
        let mut r = Rng::seeded(4);
        let d = LengthDist::LogNormal { mu: 3.0, sigma: 1.0, lo: 4, hi: 64 };
        for _ in 0..1000 {
            let n = d.sample(&mut r);
            assert!((4..=64).contains(&n));
        }
        let bi = LengthDist::Bimodal { short: 4, long: 60, p_short: 0.7 };
        let xs: Vec<usize> = (0..5000).map(|_| bi.sample(&mut r)).collect();
        let n_short = xs.iter().filter(|&&x| x == 4).count();
        assert!((3000..4000).contains(&n_short), "n_short={n_short}");
    }

    #[test]
    fn pareto_lengths_are_heavy_tailed_and_bounded() {
        let mut r = Rng::seeded(7);
        let d = LengthDist::Pareto { alpha: 1.3, lo: 8, hi: 512 };
        let xs: Vec<usize> = (0..5000).map(|_| d.sample(&mut r)).collect();
        assert!(xs.iter().all(|&x| (8..=512).contains(&x)));
        // Heavy tail: some samples far above the scale, most near it.
        let big = xs.iter().filter(|&&x| x > 80).count();
        let small = xs.iter().filter(|&&x| x < 16).count();
        assert!(big > 50, "big={big}");
        assert!(small > 2500, "small={small}");
        let m = d.mean();
        assert!(m > 8.0 && m < 512.0, "mean={m}");
        // alpha ≤ 1: no finite mean, report the clamp bound.
        assert_eq!(LengthDist::Pareto { alpha: 0.9, lo: 8, hi: 512 }.mean(), 512.0);
    }

    #[test]
    fn flash_crowd_surges_then_decays() {
        let f = RateShape::FlashCrowd { at_s: 2.0, surge: 5.0, decay_s: 0.5 };
        assert!((f.factor_at(SEC) - 1.0).abs() < 1e-9, "baseline before the flash");
        assert!((f.factor_at(2 * SEC) - 5.0).abs() < 1e-9, "full surge at onset");
        let mid = f.factor_at(2 * SEC + SEC / 2); // one decay constant later
        assert!(mid > 1.0 && mid < 5.0, "mid={mid}");
        assert!(f.factor_at(20 * SEC) < 1.01, "decayed back to baseline");
    }

    #[test]
    fn composed_shapes_multiply() {
        let c = RateShape::compose(
            RateShape::Diurnal { period_s: 10.0, min_factor: 0.5 },
            RateShape::FlashCrowd { at_s: 1.0, surge: 4.0, decay_s: 1.0 },
        );
        let d = RateShape::Diurnal { period_s: 10.0, min_factor: 0.5 };
        let f = RateShape::FlashCrowd { at_s: 1.0, surge: 4.0, decay_s: 1.0 };
        for t in [0, SEC, 3 * SEC / 2, 5 * SEC] {
            let want = d.factor_at(t) * f.factor_at(t);
            assert!((c.factor_at(t) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn rate_shapes() {
        let d = RateShape::Diurnal { period_s: 10.0, min_factor: 0.2 };
        for t in 0..100 {
            let f = d.factor_at(t * SEC / 10);
            assert!((0.2..=1.0001).contains(&f));
        }
        let r = RateShape::Ramp { from: 1.0, to: 3.0, ramp_s: 10.0 };
        assert!((r.factor_at(0) - 1.0).abs() < 1e-9);
        assert!((r.factor_at(5 * SEC) - 2.0).abs() < 1e-9);
        assert!((r.factor_at(20 * SEC) - 3.0).abs() < 1e-9);
    }
}
