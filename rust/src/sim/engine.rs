//! Discrete-event simulation core: a calendar of timestamped events with a
//! deterministic tie-break (insertion sequence), popped in time order.
//!
//! Generic over the world's event payload type `E`. The world (see
//! `coordinator::scenario`) owns all state; this engine only orders time.
//!
//! # Backends
//!
//! Two interchangeable backends produce the SAME total order — `(t, seq)`
//! with a globally monotone insertion sequence — so every simulation result
//! is byte-identical whichever one runs (asserted by the in-module
//! equivalence property tests and by `rust/tests/perf_scale_suite.rs` on
//! full worlds):
//!
//! - [`CalendarKind::Heap`]: the original global `BinaryHeap`. O(log n) per
//!   operation; kept as the reference implementation.
//! - [`CalendarKind::Bucket`] (default): a two-level calendar queue. A ring
//!   of fixed-width time buckets covers the near horizon where the dense
//!   event mass lives (iteration completions, arrivals, window ticks);
//!   events beyond the ring land in an overflow heap that is drained into
//!   the ring as the horizon slides forward. Schedule and pop are O(1)
//!   amortized for near-horizon events, independent of calendar size.
//!
//! The bucket backend is additionally **sharded**: the world routes each
//! event to a shard (by pool, see `coordinator::world`), and `pop` runs a
//! k-way merge over the shard heads on `(t, seq)`. Because `seq` is unique
//! and globally monotone across shards, the merge reproduces exactly the
//! single-queue total order — shard assignment is a locality optimization
//! with no semantic content, which is the determinism argument for the
//! sharded merge.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::{SimDur, SimTime};

/// Which calendar implementation a scenario runs on. Both produce identical
/// event orders (see the module docs); `Bucket` is the default, `Heap` is
/// kept for the old-vs-new equivalence suites.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CalendarKind {
    #[default]
    Bucket,
    Heap,
}

#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        o.at.cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

/// Ring size × width of one two-level shard. 128 buckets of 2^18 ns cover a
/// ~33.6 ms horizon: iteration completions (tens to hundreds of µs out) and
/// the 10 ms window tick land in the ring; only scenario-end style events
/// take the overflow path.
const N_BUCKETS: usize = 128;
const BUCKET_NS: u64 = 1 << 18;
const RING_SPAN_NS: u64 = N_BUCKETS as u64 * BUCKET_NS;

/// One two-level bucket queue (a "calendar queue" shard).
///
/// Invariants that make `front()`/`pop_front()` correct:
/// - every item in `cur` has `t < base`;
/// - every item in the ring has `t` within its bucket's span, all spans
///   `>= base`;
/// - every item in `overflow` has `t >=` the ring end as of the last drain,
///   which is `>= base`.
///
/// So whenever `cur` is non-empty its back (smallest `(t, seq)`) is the
/// shard minimum. New events landing before `base` — always legal, the
/// engine clamps to `now` and `now` can trail `base` arbitrarily — are
/// merge-inserted into `cur`, preserving the invariant.
#[derive(Debug, Clone)]
struct BucketShard<E> {
    /// Promoted working set, sorted DESCENDING by `(t, seq)`; popped from
    /// the back. The promoted bucket's `Vec` is swapped in, so steady-state
    /// promotion allocates nothing.
    cur: Vec<Scheduled<E>>,
    /// The ring: `buckets[(head + i) % N_BUCKETS]` covers
    /// `[base + i*BUCKET_NS, base + (i+1)*BUCKET_NS)`.
    buckets: Vec<Vec<Scheduled<E>>>,
    head: usize,
    /// Start (ns) of the ring's coverage.
    base: u64,
    /// Events at or beyond the ring end (min-first via the inverted `Ord`).
    overflow: BinaryHeap<Scheduled<E>>,
    len: usize,
}

impl<E> BucketShard<E> {
    fn new() -> Self {
        BucketShard {
            cur: Vec::new(),
            buckets: (0..N_BUCKETS).map(|_| Vec::new()).collect(),
            head: 0,
            base: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    fn schedule(&mut self, at: SimTime, seq: u64, payload: E) {
        let t = at.0;
        let it = Scheduled { at, seq, payload };
        if t < self.base {
            // Before the cursor: merge into the working set. Correct even
            // though earlier items may already have popped — a new event
            // carries the globally largest seq and (after the engine's
            // clamp) t >= now >= every popped timestamp, so it can never
            // sort before an already-delivered event.
            let key = (it.at, it.seq);
            let pos = self.cur.partition_point(|x| (x.at, x.seq) > key);
            self.cur.insert(pos, it);
        } else if t - self.base >= RING_SPAN_NS {
            self.overflow.push(it);
        } else {
            let idx = ((t - self.base) / BUCKET_NS) as usize;
            self.buckets[(self.head + idx) % N_BUCKETS].push(it);
        }
        self.len += 1;
    }

    /// Move overflow events the sliding ring has reached into their buckets.
    fn drain_overflow(&mut self) {
        let end = self.base + RING_SPAN_NS;
        while let Some(s) = self.overflow.peek() {
            if s.at.0 >= end {
                break;
            }
            let s = self.overflow.pop().expect("peeked");
            debug_assert!(s.at.0 >= self.base, "overflow fell behind the ring");
            let idx = ((s.at.0 - self.base) / BUCKET_NS) as usize;
            self.buckets[(self.head + idx) % N_BUCKETS].push(s);
        }
    }

    /// Refill `cur` from the next non-empty bucket (advancing the ring), or
    /// from the overflow heap when the ring runs dry. Leaves `cur` empty
    /// only when the shard is empty.
    fn refill(&mut self) {
        debug_assert!(self.cur.is_empty());
        loop {
            self.drain_overflow();
            let found = (0..N_BUCKETS)
                .find(|&i| !self.buckets[(self.head + i) % N_BUCKETS].is_empty());
            if let Some(i) = found {
                let idx = (self.head + i) % N_BUCKETS;
                std::mem::swap(&mut self.cur, &mut self.buckets[idx]);
                self.cur.sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
                self.head = (idx + 1) % N_BUCKETS;
                self.base += (i as u64 + 1) * BUCKET_NS;
                return;
            }
            // Ring empty: jump the horizon to the overflow minimum.
            let Some(min) = self.overflow.peek() else { return };
            self.base = (min.at.0 / BUCKET_NS) * BUCKET_NS;
            self.head = 0;
        }
    }

    /// Shard head key, lazily promoting so the check is O(1) amortized.
    fn front(&mut self) -> Option<(SimTime, u64)> {
        if self.cur.is_empty() {
            self.refill();
        }
        self.cur.last().map(|s| (s.at, s.seq))
    }

    fn pop_front(&mut self) -> Option<Scheduled<E>> {
        if self.cur.is_empty() {
            self.refill();
        }
        let s = self.cur.pop()?;
        self.len -= 1;
        Some(s)
    }

    /// Read-only head timestamp (for `peek_time`): min over `cur`, the
    /// first non-empty ring bucket (earlier buckets cover earlier spans),
    /// and the overflow heap.
    fn peek_at(&self) -> Option<SimTime> {
        if let Some(s) = self.cur.last() {
            return Some(s.at);
        }
        let bucket_min = (0..N_BUCKETS)
            .map(|i| &self.buckets[(self.head + i) % N_BUCKETS])
            .find(|b| !b.is_empty())
            .and_then(|b| b.iter().map(|s| s.at).min());
        let over_min = self.overflow.peek().map(|s| s.at);
        match (bucket_min, over_min) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn clear(&mut self) {
        self.cur.clear();
        for b in &mut self.buckets {
            b.clear();
        }
        self.overflow.clear();
        self.head = 0;
        self.base = 0;
        self.len = 0;
    }
}

#[derive(Debug, Clone)]
enum Backend<E> {
    Heap(BinaryHeap<Scheduled<E>>),
    Bucket(Vec<BucketShard<E>>),
}

/// The event calendar + clock.
#[derive(Debug, Clone)]
pub struct Engine<E> {
    now: SimTime,
    seq: u64,
    backend: Backend<E>,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    /// Default calendar: single-shard bucket queue.
    pub fn new() -> Self {
        Self::with_shards(CalendarKind::default(), 1)
    }

    /// A calendar on an explicit backend (single shard).
    pub fn with_backend(kind: CalendarKind) -> Self {
        Self::with_shards(kind, 1)
    }

    /// A calendar with `shards` independent bucket queues merged on
    /// `(t, seq)` at pop. The heap backend ignores the shard count (it is a
    /// single global queue by construction).
    pub fn with_shards(kind: CalendarKind, shards: usize) -> Self {
        let backend = match kind {
            CalendarKind::Heap => Backend::Heap(BinaryHeap::new()),
            CalendarKind::Bucket => {
                Backend::Bucket((0..shards.max(1)).map(|_| BucketShard::new()).collect())
            }
        };
        Engine { now: SimTime::ZERO, seq: 0, backend, processed: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Bucket(shards) => shards.iter().map(|s| s.len).sum(),
        }
    }

    /// Schedule `payload` at absolute time `at` (clamped to now if in the
    /// past) on shard 0.
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        self.schedule_at_shard(0, at, payload);
    }

    /// Schedule on a specific shard (clamped to the shard count). Shard
    /// choice never affects pop order — the merge key `(t, seq)` is global —
    /// only which queue absorbs the event's bucket traffic.
    pub fn schedule_at_shard(&mut self, shard: usize, at: SimTime, payload: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(Scheduled { at, seq, payload }),
            Backend::Bucket(shards) => {
                let i = shard.min(shards.len() - 1);
                shards[i].schedule(at, seq, payload);
            }
        }
    }

    /// Mint the next insertion sequence without scheduling anything. Event
    /// coalescing (see `coordinator::iterate`) pre-mints one seq per logical
    /// sub-event at the exact program point the uncoalesced code would have
    /// scheduled it, then carries the batch under a single calendar entry —
    /// the `(t, seq)` keyspace, and therefore the total order, is identical
    /// to the per-event schedule it replaces.
    pub fn alloc_seq(&mut self) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        seq
    }

    /// Schedule with a pre-minted sequence from [`Engine::alloc_seq`]. The
    /// caller must pass each minted seq at most once; `(at, seq)` then slots
    /// into the total order exactly where an inline schedule at mint time
    /// would have.
    pub fn schedule_at_shard_seq(&mut self, shard: usize, at: SimTime, seq: u64, payload: E) {
        let at = at.max(self.now);
        debug_assert!(seq < self.seq, "seq {seq} was never minted");
        match &mut self.backend {
            Backend::Heap(h) => h.push(Scheduled { at, seq, payload }),
            Backend::Bucket(shards) => {
                let i = shard.min(shards.len() - 1);
                shards[i].schedule(at, seq, payload);
            }
        }
    }

    /// Schedule `payload` after a delay from now (shard 0).
    pub fn schedule_in(&mut self, delay: SimDur, payload: E) {
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = match &mut self.backend {
            Backend::Heap(h) => h.pop()?,
            Backend::Bucket(shards) => {
                // Deterministic k-way merge: the smallest (t, seq) across
                // shard heads. seq is globally unique, so the winner is too.
                let mut best: Option<(usize, (SimTime, u64))> = None;
                for (i, sh) in shards.iter_mut().enumerate() {
                    if let Some(k) = sh.front() {
                        if best.map_or(true, |(_, bk)| k < bk) {
                            best = Some((i, k));
                        }
                    }
                }
                let (i, _) = best?;
                shards[i].pop_front().expect("front() guaranteed an event")
            }
        };
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.at),
            Backend::Bucket(shards) => shards.iter().filter_map(|s| s.peek_at()).min(),
        }
    }

    /// Peek the next event's full `(t, seq)` merge key without popping —
    /// the drain limit for coalesced-event dispatch: everything in a batch
    /// with a key below this would have popped before the calendar's next
    /// entry. Uses the same lazy shard promotion as `pop`, hence `&mut`.
    pub fn peek_key(&mut self) -> Option<(SimTime, u64)> {
        match &mut self.backend {
            Backend::Heap(h) => h.peek().map(|e| (e.at, e.seq)),
            Backend::Bucket(shards) => {
                let mut best: Option<(SimTime, u64)> = None;
                for sh in shards.iter_mut() {
                    if let Some(k) = sh.front() {
                        if best.map_or(true, |bk| k < bk) {
                            best = Some(k);
                        }
                    }
                }
                best
            }
        }
    }

    /// Drop all pending events. This is a *partial* teardown: the clock
    /// (`now`), the insertion sequence (`seq`), and the `processed` count
    /// keep running, so events scheduled afterwards still clamp to the old
    /// clock and tie-break after everything that came before. Use
    /// [`Engine::reset`] when the calendar is being reused for a fresh
    /// world (back-to-back scenario cells on one worker thread).
    pub fn clear(&mut self) {
        match &mut self.backend {
            Backend::Heap(h) => h.clear(),
            Backend::Bucket(shards) => {
                for s in shards {
                    s.clear();
                }
            }
        }
    }

    /// Full teardown: drop pending events AND rewind the clock, insertion
    /// sequence, and processed count to a fresh-engine state. Scenario
    /// teardown calls this so a calendar (or worker) reused for the next
    /// cell cannot inherit clock/seq state from the previous run.
    pub fn reset(&mut self) {
        self.clear();
        self.now = SimTime::ZERO;
        self.seq = 0;
        self.processed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn both_kinds() -> [CalendarKind; 2] {
        [CalendarKind::Bucket, CalendarKind::Heap]
    }

    #[test]
    fn pops_in_time_order() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            e.schedule_at(SimTime(30), 3);
            e.schedule_at(SimTime(10), 1);
            e.schedule_at(SimTime(20), 2);
            let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            for i in 0..10 {
                e.schedule_at(SimTime(5), i);
            }
            let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, (0..10).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            e.schedule_at(SimTime(100), 0);
            e.schedule_at(SimTime(50), 1);
            let mut last = SimTime::ZERO;
            while let Some((t, _)) = e.pop() {
                assert!(t >= last);
                last = t;
            }
            assert_eq!(e.now(), SimTime(100));
            assert_eq!(e.processed(), 2);
        }
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            e.schedule_at(SimTime(100), 0);
            e.pop();
            e.schedule_at(SimTime(10), 1); // in the past
            let (t, _) = e.pop().unwrap();
            assert_eq!(t, SimTime(100));
        }
    }

    #[test]
    fn schedule_in_is_relative() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            e.schedule_at(SimTime(1000), 0);
            e.pop();
            e.schedule_in(SimDur(500), 1);
            assert_eq!(e.peek_time(), Some(SimTime(1500)));
        }
    }

    #[test]
    fn far_horizon_events_take_the_overflow_path_in_order() {
        let mut e: Engine<u32> = Engine::with_backend(CalendarKind::Bucket);
        // Far beyond the ring span (33.6 ms), near the ring, and in between.
        e.schedule_at(SimTime(10 * RING_SPAN_NS), 3);
        e.schedule_at(SimTime(100), 1);
        e.schedule_at(SimTime(2 * RING_SPAN_NS), 2);
        e.schedule_at(SimTime(30 * RING_SPAN_NS), 4);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
        assert_eq!(e.now(), SimTime(30 * RING_SPAN_NS));
    }

    #[test]
    fn schedule_behind_the_ring_cursor_stays_ordered() {
        let mut e: Engine<u32> = Engine::with_backend(CalendarKind::Bucket);
        // Promote the ring cursor far forward, then schedule at `now`
        // (behind the cursor's bucket base) — the common `kick()` pattern.
        e.schedule_at(SimTime(5 * RING_SPAN_NS), 0);
        e.pop();
        e.schedule_at(e.now() + SimDur(10), 1);
        e.schedule_at(e.now(), 2); // same t as pending? no: t = now < now+10
        let (t2, p2) = e.pop().unwrap();
        assert_eq!((t2, p2), (SimTime(5 * RING_SPAN_NS), 2));
        let (t1, p1) = e.pop().unwrap();
        assert_eq!((t1, p1), (SimTime(5 * RING_SPAN_NS + 10), 1));
    }

    /// The headline invariant: both backends — and any shard assignment —
    /// produce the identical pop sequence under a random interleaving of
    /// schedules and pops.
    #[test]
    fn bucket_heap_and_sharded_calendars_agree() {
        for seed in 0..8u64 {
            let mut rng = Rng::seeded(0xCA1E_0000 + seed);
            let mut heap: Engine<u64> = Engine::with_backend(CalendarKind::Heap);
            let mut bucket: Engine<u64> = Engine::with_backend(CalendarKind::Bucket);
            let mut sharded: Engine<u64> = Engine::with_shards(CalendarKind::Bucket, 5);
            let mut popped: Vec<(SimTime, u64)> = Vec::new();
            let mut id = 0u64;
            for _ in 0..4000 {
                if rng.chance(0.6) {
                    // Mix of near-horizon, mid, far, and at-now times.
                    let dt = match rng.below(10) {
                        0..=5 => rng.below(200_000),          // dense near mass
                        6 | 7 => rng.below(RING_SPAN_NS),     // within the ring
                        8 => rng.below(4 * RING_SPAN_NS),     // overflow
                        _ => 0,                               // exactly now
                    };
                    let at = heap.now() + SimDur(dt);
                    heap.schedule_at(at, id);
                    bucket.schedule_at(at, id);
                    sharded.schedule_at_shard(rng.index(5), at, id);
                    id += 1;
                } else {
                    let h = heap.pop();
                    let b = bucket.pop();
                    let s = sharded.pop();
                    assert_eq!(h, b, "heap vs bucket diverged (seed {seed})");
                    assert_eq!(h, s, "heap vs sharded diverged (seed {seed})");
                    if let Some(ev) = h {
                        popped.push((ev.0, ev.1));
                    }
                }
            }
            // Drain the rest and check the total order end to end.
            loop {
                let h = heap.pop();
                assert_eq!(h, bucket.pop(), "drain: heap vs bucket (seed {seed})");
                assert_eq!(h, sharded.pop(), "drain: heap vs sharded (seed {seed})");
                match h {
                    Some(ev) => popped.push((ev.0, ev.1)),
                    None => break,
                }
            }
            assert_eq!(popped.len() as u64, id, "every scheduled event popped");
            assert!(popped.windows(2).all(|w| w[0].0 <= w[1].0), "time-ordered");
        }
    }

    #[test]
    fn clear_keeps_clock_and_seq_running() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_backend(kind);
            e.schedule_at(SimTime(100), 0);
            e.pop();
            e.schedule_at(SimTime(200), 1);
            e.clear();
            assert_eq!(e.pending(), 0);
            // The documented invariant: clear() is partial — the clock and
            // processed count survive, and past schedules still clamp.
            assert_eq!(e.now(), SimTime(100));
            assert_eq!(e.processed(), 1);
            e.schedule_at(SimTime(10), 2);
            assert_eq!(e.pop(), Some((SimTime(100), 2)), "{kind:?}");
        }
    }

    /// Satellite regression: back-to-back scenario cells reusing one worker
    /// must not inherit clock/seq state — reset() restores a fresh engine.
    #[test]
    fn reset_restores_a_fresh_engine() {
        for kind in both_kinds() {
            let run = |e: &mut Engine<u32>| -> Vec<(SimTime, u32)> {
                e.schedule_at(SimTime(500), 0);
                e.schedule_at(SimTime(250), 1);
                e.schedule_at(SimTime(250), 2);
                std::iter::from_fn(|| e.pop()).collect()
            };
            let mut fresh: Engine<u32> = Engine::with_backend(kind);
            let first = run(&mut fresh);
            let mut reused: Engine<u32> = Engine::with_backend(kind);
            reused.schedule_at(SimTime(9_999), 7);
            let _ = reused.pop();
            reused.schedule_at(SimTime(1), 8); // left pending on purpose
            reused.reset();
            assert_eq!(reused.now(), SimTime::ZERO);
            assert_eq!(reused.processed(), 0);
            assert_eq!(reused.pending(), 0);
            let second = run(&mut reused);
            assert_eq!(first, second, "{kind:?}: reused engine must replay identically");
        }
    }

    /// Pre-minted seqs slot into the total order exactly where an inline
    /// schedule at mint time would have, on both backends and across shards.
    #[test]
    fn pre_minted_seqs_keep_the_inline_total_order() {
        for kind in both_kinds() {
            let mut e: Engine<u32> = Engine::with_shards(kind, 3);
            e.schedule_at_shard(1, SimTime(50), 0);
            let s1 = e.alloc_seq(); // would have been the tie at t=50
            e.schedule_at_shard(2, SimTime(50), 2); // later mint, same t
            e.schedule_at_shard_seq(0, SimTime(50), s1, 1);
            let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
            assert_eq!(order, vec![0, 1, 2], "{kind:?}");
        }
    }

    #[test]
    fn peek_key_matches_the_next_pop() {
        for kind in both_kinds() {
            let mut e: Engine<u64> = Engine::with_shards(kind, 4);
            assert_eq!(e.peek_key(), None, "{kind:?}");
            let mut rng = Rng::seeded(0xBEEF);
            for i in 0..500u64 {
                e.schedule_at_shard(rng.index(4), SimTime(rng.below(300_000)), i);
            }
            while let Some(key) = e.peek_key() {
                let (t, _) = e.pop().expect("peek_key implies a pending event");
                assert_eq!(t, key.0, "{kind:?}");
            }
            assert_eq!(e.pending(), 0);
        }
    }
}
