//! Discrete-event simulation core: a calendar of timestamped events with a
//! deterministic tie-break (insertion sequence), popped in time order.
//!
//! Generic over the world's event payload type `E`. The world (see
//! `coordinator::scenario`) owns all state; this engine only orders time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::{SimDur, SimTime};

#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        o.at.cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

/// The event calendar + clock.
#[derive(Debug)]
pub struct Engine<E> {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Scheduled<E>>,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine { now: SimTime::ZERO, seq: 0, heap: BinaryHeap::new(), processed: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (clamped to now if in the past).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        let at = at.max(self.now);
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimDur, payload: E) {
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    /// Drop all pending events (scenario teardown).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(SimTime(30), 3);
        e.schedule_at(SimTime(10), 1);
        e.schedule_at(SimTime(20), 2);
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule_at(SimTime(5), i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| e.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(SimTime(100), 0);
        e.schedule_at(SimTime(50), 1);
        let mut last = SimTime::ZERO;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(e.now(), SimTime(100));
        assert_eq!(e.processed(), 2);
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(SimTime(100), 0);
        e.pop();
        e.schedule_at(SimTime(10), 1); // in the past
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, SimTime(100));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(SimTime(1000), 0);
        e.pop();
        e.schedule_in(SimDur(500), 1);
        assert_eq!(e.peek_time(), Some(SimTime(1500)));
    }
}
