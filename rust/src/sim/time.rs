//! Simulated time: u64 nanoseconds since scenario start.
//!
//! A newtype keeps sim-time from ever mixing with wallclock. All hardware
//! models and telemetry timestamps use [`SimTime`]; only the bench harness
//! measures wallclock (for *our* code's performance, not the simulated
//! cluster's).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Nanoseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of simulated time, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDur(pub u64);

pub const NS: u64 = 1;
pub const US: u64 = 1_000;
pub const MS: u64 = 1_000_000;
pub const SEC: u64 = 1_000_000_000;

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn ns(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / SEC as f64
    }

    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / MS as f64
    }

    pub fn since(self, earlier: SimTime) -> SimDur {
        SimDur(self.0.saturating_sub(earlier.0))
    }
}

impl SimDur {
    pub const ZERO: SimDur = SimDur(0);

    pub fn from_ns(ns: u64) -> SimDur {
        SimDur(ns)
    }

    pub fn from_us(us: u64) -> SimDur {
        SimDur(us * US)
    }

    pub fn from_ms(ms: u64) -> SimDur {
        SimDur(ms * MS)
    }

    pub fn from_secs_f64(s: f64) -> SimDur {
        SimDur((s * SEC as f64).round().max(0.0) as u64)
    }

    pub fn ns(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / SEC as f64
    }

    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / US as f64
    }

    pub fn scale(self, factor: f64) -> SimDur {
        SimDur((self.0 as f64 * factor).round().max(0.0) as u64)
    }
}

impl Add<SimDur> for SimTime {
    type Output = SimTime;
    fn add(self, d: SimDur) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDur> for SimTime {
    fn add_assign(&mut self, d: SimDur) {
        self.0 += d.0;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = SimDur;
    fn sub(self, o: SimTime) -> SimDur {
        SimDur(self.0.saturating_sub(o.0))
    }
}

impl Add<SimDur> for SimDur {
    type Output = SimDur;
    fn add(self, o: SimDur) -> SimDur {
        SimDur(self.0 + o.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::table::fmt_ns(self.0 as f64))
    }
}

impl fmt::Display for SimDur {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::table::fmt_ns(self.0 as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime(100) + SimDur::from_us(1);
        assert_eq!(t.ns(), 1_100);
        assert_eq!((t - SimTime(100)).ns(), 1_000);
        assert_eq!(t.since(SimTime(2_000)).ns(), 0); // saturating
    }

    #[test]
    fn conversions() {
        assert_eq!(SimDur::from_ms(2).ns(), 2_000_000);
        assert!((SimDur::from_secs_f64(0.5).as_secs_f64() - 0.5).abs() < 1e-12);
        assert_eq!(SimDur::from_ns(1500).as_us_f64(), 1.5);
    }

    #[test]
    fn scale_rounds() {
        assert_eq!(SimDur(100).scale(2.5).ns(), 250);
        assert_eq!(SimDur(100).scale(0.0).ns(), 0);
    }
}
