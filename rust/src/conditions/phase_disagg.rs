//! Phase-disaggregation family (pool-boundary vantage): PD1-PD3, one
//! [`ConditionSpec`] each. Like the DP family, the detector bindings are
//! fleet rules evaluated by `dpu::fleet::FleetSensor`; these read the
//! pool-boundary sample (KV-handoff counters) that only disaggregated
//! fleets produce.

use super::{
    cause_client, cause_network, scale_rate, ConditionSpec, DetectorBinding, Family, FleetScope,
    InjectCtx, InjectSite,
};
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::detectors::Condition;
use crate::dpu::fleet::{argmax_u64, first_max_by, PdCtx, RuleHit};
use crate::mitigation::directive::Directive;
use crate::sim::dist::{Arrival, LengthDist};

/// PD1: prefill-pool backlog floor and the decode-utilization ceiling that
/// distinguishes "prefill starves decode" from "everything is busy".
const PD1_MIN_QUEUE: u64 = 24;
const PD1_DECODE_UTIL_MAX: f64 = 0.5;
/// PD2: observed-over-expected handoff latency ratio + a minimum population
/// over the horizon so a few straggling transfers can't fire it. The
/// in-flight floor catches the degenerate total stall, where so few
/// transfers land that no latency sample exists at all.
const PD2_LAT_FACTOR: f64 = 3.0;
const PD2_MIN_HANDOFFS: u64 = 4;
const PD2_STALL_INFLIGHT: u64 = 12;
/// PD3: handoff-share margin over the fair share (mirrors DP1's margin).
const PD3_SHARE_MARGIN: f64 = 0.35;
const PD3_MIN_ARRIVALS: u64 = 24;
/// Hops a handoff traverses (uplink → core → downlink) for the line-rate
/// latency expectation, plus a fixed base allowance.
const PD2_PATH_HOPS: f64 = 3.0;
const PD2_BASE_ALLOWANCE_NS: f64 = 10_000.0;

// ---- injections ----

fn inject_pd1(cx: &mut InjectCtx) -> String {
    // Prompt flood: long prompts at a surged rate overrun the prefill pool
    // while decode demand (tokens out) barely moves.
    cx.wl.prompt_len = LengthDist::Uniform { lo: 48, hi: 64 };
    if let Arrival::Poisson { rate } = &cx.wl.arrival {
        let surged = rate * 2.5;
        cx.wl.arrival = Arrival::Poisson { rate: surged };
    }
    "prompt flood: 48-64-token prompts at 2.5x rate overrun the prefill pool".into()
}

fn inject_pd2(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.handoff_budget_factor = 0.2;
    "prefill→decode KV-handoff link budget collapsed to 20%".into()
}

fn inject_pd3(cx: &mut InjectCtx) -> String {
    // Wedged handoff routing: every phase transition lands on one decode
    // replica; its pool peers starve.
    let hot = cx
        .engine
        .replica_of_node(cx.target)
        .filter(|&ri| cx.engine.replicas[ri].plan.shape.role.serves_decode())
        .unwrap_or_else(|| cx.engine.decode_router.members()[0]);
    cx.engine.decode_router.set_pin(Some(hot));
    format!("handoff routing wedged: every KV handoff lands on decode replica {hot}")
}

// ---- fleet rules ----

/// PD1 — prefill-pool saturation: admission backlog accumulates across the
/// prefill pool while its paired decode pool sits far below slot capacity.
fn rule_pd1(cx: &PdCtx) -> Option<RuleHit> {
    let prefill_q: u64 = cx.pool.iter().map(|&r| cx.cur.prefill_queue[r]).sum();
    let old_q: u64 = cx.pool.iter().map(|&r| cx.old.prefill_queue[r]).sum();
    let slots: u64 = cx.other_pool.iter().map(|&r| cx.cur.decode_slots[r]).sum();
    let running: u64 = cx.other_pool.iter().map(|&r| cx.cur.decode_running[r]).sum();
    let decode_util = running as f64 / slots.max(1) as f64;
    let hit =
        prefill_q >= PD1_MIN_QUEUE && prefill_q > old_q && decode_util <= PD1_DECODE_UTIL_MAX;
    if !hit {
        return None;
    }
    let hot = first_max_by(cx.pool, |r| cx.cur.prefill_queue[r] as f64);
    Some(RuleHit {
        replica: hot,
        severity: prefill_q as f64 / PD1_MIN_QUEUE as f64,
        evidence: format!(
            "prefill pool backlog {prefill_q} (was {old_q} a horizon ago) while \
             the decode pool runs {running}/{slots} slots ({:.0}% busy)",
            decode_util * 100.0
        ),
    })
}

/// PD2 — KV-handoff stall: the phase-transition transfer's fabric latency
/// blows past its line-rate expectation. Measured over the whole horizon,
/// not one window: completions under a stall arrive sparse-then-bursty, and
/// a single thin window must neither fire nor reset the streak.
fn rule_pd2(cx: &PdCtx) -> Option<RuleHit> {
    cx.prev?;
    let done = cx.cur.handoffs_completed.saturating_sub(cx.old.handoffs_completed);
    let inflight = cx.cur.handoffs_started.saturating_sub(cx.cur.handoffs_completed);
    if done < PD2_MIN_HANDOFFS && inflight >= PD2_STALL_INFLIGHT {
        // Degenerate total stall: transfers pile up on the fabric with
        // (almost) nothing landing — no latency sample will ever
        // accumulate, so the backlog itself is the red flag.
        let dst = first_max_by(cx.pool, |r| cx.cur.handoff_arrivals[r] as f64);
        return Some(RuleHit {
            replica: dst,
            severity: inflight as f64 / PD2_STALL_INFLIGHT as f64,
            evidence: format!(
                "KV handoffs frozen: {inflight} in flight on the fabric with \
                 only {done} landing over the horizon"
            ),
        });
    }
    if done >= PD2_MIN_HANDOFFS {
        let lat_sum = cx.cur.handoff_lat_sum_ns.saturating_sub(cx.old.handoff_lat_sum_ns);
        let bytes = cx.cur.handoff_bytes.saturating_sub(cx.old.handoff_bytes);
        let mean_lat = lat_sum as f64 / done as f64;
        let mean_bytes = bytes as f64 / done as f64;
        let expected =
            mean_bytes / cx.nic_bw.max(1.0) * 1e9 * PD2_PATH_HOPS + PD2_BASE_ALLOWANCE_NS;
        if mean_lat >= PD2_LAT_FACTOR * expected {
            let dst = first_max_by(cx.pool, |r| {
                cx.cur.handoff_arrivals[r].saturating_sub(cx.old.handoff_arrivals[r]) as f64
            });
            return Some(RuleHit {
                replica: dst,
                severity: mean_lat / expected.max(1.0),
                evidence: format!(
                    "KV handoffs average {:.0} us over {done} transfers vs \
                     {:.0} us line-rate expectation ({:.0} KB mean)",
                    mean_lat / 1e3,
                    expected / 1e3,
                    mean_bytes / 1e3
                ),
            });
        }
    }
    None
}

/// PD3 — decode-pool starvation: handoff arrivals concentrate on one decode
/// replica while its pool peers starve.
fn rule_pd3(cx: &PdCtx) -> Option<RuleHit> {
    let pool = cx.pool;
    let nd = pool.len();
    if nd < 2 {
        return None;
    }
    let arrivals: Vec<u64> = pool
        .iter()
        .map(|&r| cx.cur.handoff_arrivals[r].saturating_sub(cx.old.handoff_arrivals[r]))
        .collect();
    let total: u64 = arrivals.iter().sum();
    if total < PD3_MIN_ARRIVALS {
        return None;
    }
    let hot_k = argmax_u64(&arrivals);
    let hot = pool[hot_k];
    let share = arrivals[hot_k] as f64 / total as f64;
    let threshold = (1.0 / nd as f64 + PD3_SHARE_MARGIN).min(0.92);
    if share < threshold {
        return None;
    }
    Some(RuleHit {
        replica: hot,
        severity: share * nd as f64,
        evidence: format!(
            "decode replica {hot} receives {:.0}% of {total} KV handoffs \
             (fair share {:.0}%); {} parked awaiting admission",
            share * 100.0,
            100.0 / nd as f64,
            cx.cur.stalled_wait_depth
        ),
    })
}

// ---- fleet-triple shaping ----

// Decode-slot pressure: the wedged replica must actually be the constraint,
// so lengthen outputs and raise demand until the decode pool runs near its
// slot capacity.
fn shape_pd3(cfg: &mut ScenarioCfg) {
    cfg.workload.output_len = LengthDist::Uniform { lo: 24, hi: 48 };
    scale_rate(cfg, 2.0);
}

pub static SPECS: [ConditionSpec; 3] = [
    ConditionSpec {
        condition: Condition::Pd1PrefillSaturation,
        label: "prefill-pool saturation",
        family: Family::PhaseDisagg,
        binding: DetectorBinding::FleetPd {
            scope: FleetScope::PerPrefillPool,
            confirm: 3,
            min_pool: 1,
            eval: rule_pd1,
        },
        site: InjectSite::Workload,
        inject: inject_pd1,
        signal: "Prefill-pool admission backlog grows while decode slots idle",
        stages: "Prefill pool (admission -> first token)",
        effect: "TTFT inflates fleet-wide; decode pool starves for handoffs",
        root_cause_text: "Prompt-heavy demand vs prefill pool sizing (roles misprovisioned)",
        directive: Directive::RebalancePools,
        cause: cause_client,
        expected_causes: &["client"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pd2KvHandoffStall,
        label: "KV-handoff stall",
        family: Family::PhaseDisagg,
        binding: DetectorBinding::FleetPd {
            scope: FleetScope::DecodeUnion,
            confirm: 2,
            min_pool: 1,
            eval: rule_pd2,
        },
        site: InjectSite::Fabric,
        inject: inject_pd2,
        signal: "KV-handoff fabric latency far above line-rate expectation",
        stages: "Phase transition (prefill -> decode pool)",
        effect: "Sequences pile up between pools; decode admission runs dry",
        root_cause_text: "Handoff link budget collapse: congestion, misrouted path, QoS",
        // PD2 shares EW8's KV-transfer directive: the handoff IS a KV
        // transfer, just across the pool boundary.
        directive: Directive::CompressKvTransfers,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pd3DecodeStarvation,
        label: "decode-pool starvation",
        family: Family::PhaseDisagg,
        binding: DetectorBinding::FleetPd {
            scope: FleetScope::PerDecodePool,
            confirm: 3,
            min_pool: 2,
            eval: rule_pd3,
        },
        site: InjectSite::Engine,
        inject: inject_pd3,
        signal: "KV handoffs concentrate on one decode replica; peers starve",
        stages: "Phase transition routing (decode pool)",
        effect: "One decode replica saturates its slots while peers sit idle",
        root_cause_text: "Wedged/skewed handoff routing after a config or failover event",
        directive: Directive::RebalanceHandoffRouting,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: Some(shape_pd3),
    },
];
