//! North-South family (paper Table 3a): ingress/egress conditions sensed at
//! the cluster boundary — NS1-NS9, one [`ConditionSpec`] each.

use super::{
    cause_client, cause_network, cause_workload, ConditionSpec, DetectorBinding, Family,
    InjectCtx, InjectSite,
};
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::detectors::Condition;
use crate::mitigation::directive::Directive;
use crate::sim::dist::{Arrival, LengthDist};

fn inject_ns1(cx: &mut InjectCtx) -> String {
    cx.wl.arrival = Arrival::OnOff {
        on_rate: 3000.0,
        off_rate: 5.0,
        mean_on_s: 0.02,
        mean_off_s: 0.08,
    };
    "ON-OFF client bursts (3000 req/s in 20ms spikes)".into()
}

fn inject_ns2(cx: &mut InjectCtx) -> String {
    // Upstream service jitter: traffic pauses entirely for long stretches,
    // then resumes at the normal rate (thin, gappy feed).
    cx.wl.arrival = Arrival::OnOff {
        on_rate: 400.0,
        off_rate: 0.0,
        mean_on_s: 0.025,
        mean_off_s: 0.12,
    };
    cx.wl.thin_session_frac = 0.4;
    cx.wl.thin_extra_gap_s = 0.05;
    "upstream jitter: ~120ms silences between normal-rate bursts".into()
}

fn inject_ns3(cx: &mut InjectCtx) -> String {
    cx.wl.session_skew = 1.6;
    "Zipf(1.6) session selection: few flows dominate ingress".into()
}

fn inject_ns4(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().nic_rx_loss = 0.15;
    format!("15% ingress loss on {target} (MTU mismatch/link errors)")
}

fn inject_ns5(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.cpu_contention = 3.5;
    k.nic_tx_buffer_factor = 0.35;
    format!("CPU copy bottleneck + small TX buffers on {target}")
}

fn inject_ns6(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().egress_jitter = 3.0;
    format!("egress scheduler variance on {target}")
}

fn inject_ns7(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().nic_tx_loss = 0.15;
    format!("15% egress loss on {target} (offload misconfig)")
}

fn inject_ns8(cx: &mut InjectCtx) -> String {
    cx.wl.output_len = LengthDist::Bimodal { short: 2, long: 48, p_short: 0.5 };
    for r in &mut cx.engine.replicas {
        r.batcher.policy_mut().inflight_remap = false;
    }
    "bimodal output lengths (2 vs 48 tokens), freed slots not remapped".into()
}

fn inject_ns9(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().nic_background_frac = 0.85;
    format!("background tenant burns 85% of {target}'s NIC")
}

// Early-stop conditions only bite when decode slots are saturated.
fn shape_ns8(cfg: &mut ScenarioCfg) {
    cfg.workload.arrival = Arrival::Poisson { rate: 2000.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 8, hi: 24 };
}

pub static SPECS: [ConditionSpec; 9] = [
    ConditionSpec {
        condition: Condition::Ns1BurstBacklog,
        label: "burst backlog at ingress",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Workload,
        inject: inject_ns1,
        signal: "Sudden ingress spikes followed by queueing delay",
        stages: "Ingress (prefill/start)",
        effect: "Downstream GPU sees uneven load; internode bursts clump",
        root_cause_text: "Client load spike, front-end batching, NIC queue limits",
        directive: Directive::SmoothAdmission,
        cause: cause_client,
        expected_causes: &["client"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns2IngressStarvation,
        label: "ingress starvation",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Workload,
        inject: inject_ns2,
        signal: "Long gaps between ingress packets for some tokens",
        stages: "Ingress -> PCIe feed",
        effect: "Token stalls; fewer collective ops downstream",
        root_cause_text: "Upstream service jitter, uneven client distribution",
        directive: Directive::RebalanceFlows,
        cause: cause_client,
        expected_causes: &["client"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns3FlowSkew,
        label: "ingress flow skew",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Workload,
        inject: inject_ns3,
        signal: "Some ingress flows high-volume, others sparse",
        stages: "Ingress (per-request)",
        effect: "Imbalanced TP/PP participation across tokens",
        root_cause_text: "Session affinity mismatch, QUIC stream imbalance",
        directive: Directive::RebalanceFlows,
        cause: cause_client,
        expected_causes: &["client"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns4IngressRetx,
        label: "ingress retransmissions",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ns4,
        signal: "Missing or retransmitted initial packets",
        stages: "Ingress (request birth)",
        effect: "Token ID not consistently assigned; lifecycle gaps",
        root_cause_text: "Congestion, MTU mismatch, link errors",
        directive: Directive::FixIngressPath,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns5EgressBacklog,
        label: "egress backlog",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ns5,
        signal: "Responses accumulate in NIC queues before send",
        stages: "Egress (response flush)",
        effect: "Downstream clients see latency spikes",
        root_cause_text: "CPU copy bottleneck, NIC buffer exhaustion",
        directive: Directive::ZeroCopyEgress,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns6EgressJitter,
        label: "egress jitter",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ns6,
        signal: "Outgoing packets for a token spread unevenly over time",
        stages: "Egress (decode outputs)",
        effect: "Clients see irregular token cadence",
        root_cause_text: "Scheduler variance, CPU<->NIC contention",
        directive: Directive::PinIrqsIsolateThreads,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns7EgressRetx,
        label: "egress retransmissions",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ns7,
        signal: "Retransmissions or gaps in final response streams",
        stages: "Egress",
        effect: "Client-visible stalls; retries inflate latency",
        root_cause_text: "NIC offload misconfig, fabric congestion, buffer underrun",
        directive: Directive::FixEgressPath,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns8EarlyCompletion,
        label: "early stream completion",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Workload,
        inject: inject_ns8,
        signal: "Some egress flows terminate far earlier than peers",
        stages: "Egress (multi-stream decode)",
        effect: "Internode peers still busy; imbalance in final stages",
        root_cause_text: "Early-stop on short sequences; no remap of freed resources",
        directive: Directive::EnableInflightRemap,
        cause: cause_workload,
        expected_causes: &["workload"],
        compute_skew: false,
        shape_matrix: Some(shape_ns8),
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ns9BandwidthSaturation,
        label: "NIC bandwidth saturation",
        family: Family::NorthSouth,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ns9,
        signal: "NIC RX/TX at or near link capacity; queue buildup",
        stages: "Ingress + Egress",
        effect: "All internode phases elongated; cluster-level slowdown",
        root_cause_text: "Shared NIC with storage/other jobs; insufficient link",
        directive: Directive::QosPartitionNic,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
];
