//! Telemetry-dropout family (the monitoring path as the victim): TD1-TD3,
//! one [`ConditionSpec`] each. Unlike every other family, these conditions
//! degrade the *signal about* the cluster rather than the cluster itself:
//! the injection flips the victim node's `Cluster::tele_faults` mode and the
//! `telemetry::faults` boundary layer does the damage. Detection reads the
//! per-replica freshness stats that same boundary maintains — the DPU can
//! always see whether its own inbox is stale, thin, or late, even when the
//! events themselves never arrive — via `DetectorBinding::FleetTd` rules
//! evaluated by `dpu::fleet::FleetSensor::td_window_tick`.
//!
//! The three signatures are mutually exclusive by construction:
//! - TD1 (stale-frozen): signal age grows with an EMPTY hold queue — the
//!   exporter is wedged, nothing is merely delayed.
//! - TD2 (lossy-drop): deliveries keep flowing but a material fraction of
//!   the emitted events never arrive — partial loss, not silence.
//! - TD3 (lagging-delivery): events arrive complete but windows late, with
//!   a standing in-flight backlog — fabric-visible as a queue, not a gap.

use super::{
    cause_network, ConditionSpec, DetectorBinding, Family, InjectCtx, InjectSite,
};
use crate::dpu::detectors::Condition;
use crate::dpu::fleet::{RuleHit, TdCtx};
use crate::mitigation::directive::Directive;
use crate::telemetry::faults::TeleFaultMode;

/// TD1: windows of total silence (with nothing held) before the signal
/// counts as frozen rather than momentarily quiet.
const TD1_STALE_WINDOWS: u64 = 4;
/// TD2: horizon drop ratio that counts as lossy, and the emission floor
/// that keeps a thin window from producing a meaningless ratio.
const TD2_DROP_RATIO: f64 = 0.2;
const TD2_MIN_EMITTED: u64 = 16;
/// TD3: release delay (windows) that counts as lagging rather than jitter.
const TD3_LAG_WINDOWS: u64 = 3;

/// Injection magnitudes: strong enough that every signature clears its
/// threshold with margin on the standard fleet configs.
const TD2_INJECT_DROP_P: f64 = 0.75;
const TD3_INJECT_LAG: u64 = 6;

// ---- injections ----

fn inject_td1(cx: &mut InjectCtx) -> String {
    cx.cluster.tele_faults[cx.target.idx()] = TeleFaultMode::Freeze;
    format!("telemetry exporter wedged on node {}: all DPU signal frozen", cx.target)
}

fn inject_td2(cx: &mut InjectCtx) -> String {
    cx.cluster.tele_faults[cx.target.idx()] = TeleFaultMode::Drop { p: TD2_INJECT_DROP_P };
    format!(
        "telemetry path lossy on node {}: {:.0}% of DPU events dropped",
        cx.target,
        TD2_INJECT_DROP_P * 100.0
    )
}

fn inject_td3(cx: &mut InjectCtx) -> String {
    cx.cluster.tele_faults[cx.target.idx()] = TeleFaultMode::Lag { windows: TD3_INJECT_LAG };
    format!(
        "telemetry delivery lagging on node {}: DPU signal arrives {TD3_INJECT_LAG} windows late",
        cx.target
    )
}

// ---- freshness rules ----

/// TD1 — stale-frozen signal: a replica's telemetry age grows past the
/// stale threshold while its hold queue is empty (nothing is merely in
/// flight) and the node demonstrably kept emitting over the horizon — the
/// exporter died, the node did not.
fn rule_td1(cx: &TdCtx) -> Option<RuleHit> {
    cx.prev?;
    let mut best: Option<(usize, u64)> = None;
    for r in 0..cx.cur.age_windows.len() {
        let age = cx.cur.age_windows[r];
        let emitted_h = cx.cur.emitted[r].saturating_sub(cx.old.emitted[r]);
        if age >= TD1_STALE_WINDOWS && cx.cur.held[r] == 0 && emitted_h > 0 {
            match best {
                Some((_, b)) if b >= age => {}
                _ => best = Some((r, age)),
            }
        }
    }
    let (r, age) = best?;
    let emitted_h = cx.cur.emitted[r].saturating_sub(cx.old.emitted[r]);
    Some(RuleHit {
        replica: r,
        severity: age as f64 / TD1_STALE_WINDOWS as f64,
        evidence: format!(
            "replica {r} telemetry frozen: nothing delivered for {age} windows \
             while {emitted_h} events were emitted over the horizon"
        ),
    })
}

/// TD2 — lossy-drop: deliveries still flow (this is loss, not silence) but
/// the horizon drop ratio is material.
fn rule_td2(cx: &TdCtx) -> Option<RuleHit> {
    cx.prev?;
    let mut best: Option<(usize, f64)> = None;
    for r in 0..cx.cur.age_windows.len() {
        let emitted_h = cx.cur.emitted[r].saturating_sub(cx.old.emitted[r]);
        let delivered_h = cx.cur.delivered[r].saturating_sub(cx.old.delivered[r]);
        let dropped_h = cx.cur.dropped[r].saturating_sub(cx.old.dropped[r]);
        if emitted_h < TD2_MIN_EMITTED || delivered_h == 0 {
            continue;
        }
        let ratio = dropped_h as f64 / emitted_h as f64;
        if ratio >= TD2_DROP_RATIO {
            match best {
                Some((_, b)) if b >= ratio => {}
                _ => best = Some((r, ratio)),
            }
        }
    }
    let (r, ratio) = best?;
    let emitted_h = cx.cur.emitted[r].saturating_sub(cx.old.emitted[r]);
    let dropped_h = cx.cur.dropped[r].saturating_sub(cx.old.dropped[r]);
    Some(RuleHit {
        replica: r,
        severity: ratio / TD2_DROP_RATIO,
        evidence: format!(
            "replica {r} telemetry lossy: {dropped_h} of {emitted_h} events \
             ({:.0}%) lost over the horizon with partial signal still flowing",
            ratio * 100.0
        ),
    })
}

/// TD3 — lagging delivery: a standing in-flight backlog whose release delay
/// exceeds jitter — events arrive complete but windows late, which from the
/// DPU vantage is a visible queue, not a gap.
fn rule_td3(cx: &TdCtx) -> Option<RuleHit> {
    cx.prev?;
    let mut best: Option<(usize, u64)> = None;
    for r in 0..cx.cur.age_windows.len() {
        let lag = cx.cur.lag_windows[r];
        if cx.cur.held[r] > 0 && lag >= TD3_LAG_WINDOWS {
            match best {
                Some((_, b)) if b >= lag => {}
                _ => best = Some((r, lag)),
            }
        }
    }
    let (r, lag) = best?;
    Some(RuleHit {
        replica: r,
        severity: lag as f64 / TD3_LAG_WINDOWS as f64,
        evidence: format!(
            "replica {r} telemetry lagging: delivery {lag} windows late with \
             {} events in flight",
            cx.cur.held[r]
        ),
    })
}

pub static SPECS: [ConditionSpec; 3] = [
    ConditionSpec {
        condition: Condition::Td1StaleFrozen,
        label: "stale-frozen telemetry",
        family: Family::TelemetryDropout,
        binding: DetectorBinding::FleetTd { confirm: 3, eval: rule_td1 },
        site: InjectSite::Node,
        inject: inject_td1,
        signal: "Signal age grows unbounded: zero deliveries, empty hold queue",
        stages: "Monitoring path (node exporter -> DPU observer)",
        effect: "Detectors and router weights reason over a dead snapshot",
        root_cause_text: "Wedged telemetry exporter/agent on the node (process hung, buffer pinned)",
        directive: Directive::RestartTelemetryExporter,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Td2LossyDrop,
        label: "lossy telemetry drop",
        family: Family::TelemetryDropout,
        binding: DetectorBinding::FleetTd { confirm: 3, eval: rule_td2 },
        site: InjectSite::Node,
        inject: inject_td2,
        signal: "Delivered/emitted completeness collapses while signal still flows",
        stages: "Monitoring path (per-event loss on the export channel)",
        effect: "Windowed rates read low; z-score baselines drift on thin samples",
        root_cause_text: "Lossy export channel: overflowing mirror queue, drops on the oob path",
        directive: Directive::RepairTelemetryPath,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Td3LaggingDelivery,
        label: "lagging telemetry delivery",
        family: Family::TelemetryDropout,
        binding: DetectorBinding::FleetTd { confirm: 3, eval: rule_td3 },
        site: InjectSite::Node,
        inject: inject_td3,
        signal: "Standing export backlog: events arrive complete but windows late",
        stages: "Monitoring path (delayed delivery, in-order backlog)",
        effect: "Router weights and detections trail reality by the lag depth",
        root_cause_text: "Starved/deprioritized telemetry class on a congested export path",
        directive: Directive::PrioritizeTelemetryClass,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
];
