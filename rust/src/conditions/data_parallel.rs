//! Data-parallel fleet family (router/LB vantage): DP1-DP3, one
//! [`ConditionSpec`] each. The detector bindings here ARE the fleet rules —
//! `dpu::fleet::FleetSensor` is a generic streak-confirmation engine that
//! evaluates them per pool each window; all per-condition thresholds and
//! evidence live in this module.

use super::{
    cause_gpu, cause_network, scale_rate, ConditionSpec, DetectorBinding, Family, FleetScope,
    InjectCtx, InjectSite,
};
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::detectors::Condition;
use crate::dpu::fleet::{argmax_u64, first_max_by, DpCtx, RuleHit};
use crate::engine::preset;
use crate::mitigation::directive::Directive;
use crate::sim::dist::Arrival;

/// Minimum arrivals across the horizon before flow-share skew is judged.
const MIN_ARRIVALS: u64 = 32;
/// DP2: hot-replica occupancy floor and hot-cold disparity floor.
const KV_HOT_OCC: f64 = 0.85;
const KV_DISPARITY: f64 = 0.3;
/// DP3: backlog dominance + lagging iteration rate.
const STRAGGLER_MIN_QUEUE: u64 = 10;
const STRAGGLER_QUEUE_FACTOR: f64 = 5.0;
const STRAGGLER_ITER_RATIO: f64 = 0.8;

/// DP1 fires when one replica's arrival share exceeds the hash-fair share
/// by an absolute margin. The margin (0.3) sits well above the binomial
/// noise of hashing the default 64-session population onto any pool size,
/// while Zipf-concentrated floods land far past it.
fn share_threshold(n: usize) -> f64 {
    (1.0 / n as f64 + 0.3).min(0.92)
}

// ---- injections ----

fn inject_dp1(cx: &mut InjectCtx) -> String {
    cx.wl.n_sessions = 12;
    cx.wl.session_skew = 2.5;
    if let Arrival::Poisson { rate } = &cx.wl.arrival {
        let surged = rate * 2.5;
        cx.wl.arrival = Arrival::Poisson { rate: surged };
    }
    cx.engine.router.set_policy(crate::engine::RoutePolicy::FlowHash);
    "flash crowd: Zipf(2.5) over 12 sessions at 2.5x rate under affinity hashing".into()
}

fn inject_dp2(cx: &mut InjectCtx) -> String {
    let ri = cx.engine.replica_of_node(cx.target).unwrap_or(0);
    cx.engine.replicas[ri].kv.start_leak();
    format!("replica {ri} KV allocator leaks: freed pages never return, admissions thrash")
}

fn inject_dp3(cx: &mut InjectCtx) -> String {
    let ri = cx.engine.replica_of_node(cx.target).unwrap_or(0);
    for n in cx.engine.replicas[ri].plan.all_nodes() {
        for f in &mut cx.cluster.nodes[n.idx()].knobs.gpu_speed_factor {
            *f = 0.05;
        }
    }
    format!("replica {ri} degraded: every GPU at 5% speed (straggler replica)")
}

// ---- fleet rules (evaluated per pool by the sensor) ----

/// DP1 — router flow skew: one replica's share of routed arrivals far
/// exceeds the hash-fair share over the horizon.
fn rule_dp1(cx: &DpCtx) -> Option<RuleHit> {
    let pool = cx.pool;
    let np = pool.len();
    if np < 2 {
        return None;
    }
    let arrivals: Vec<u64> =
        pool.iter().map(|&r| cx.cur.routed[r].saturating_sub(cx.old.routed[r])).collect();
    let total: u64 = arrivals.iter().sum();
    if total < MIN_ARRIVALS {
        return None;
    }
    let hot_k = argmax_u64(&arrivals);
    let hot = pool[hot_k];
    let share = arrivals[hot_k] as f64 / total as f64;
    let threshold = share_threshold(np);
    if share < threshold {
        return None;
    }
    Some(RuleHit {
        replica: hot,
        severity: share * np as f64,
        evidence: format!(
            "replica {hot} absorbs {:.0}% of {total} arrivals \
             (fair share {:.0}%, threshold {:.0}%)",
            share * 100.0,
            100.0 / np as f64,
            threshold * 100.0
        ),
    })
}

/// DP2 — hot-replica KV exhaustion: occupancy pinned near capacity with
/// admission failures while the coldest peer sits far below.
fn rule_dp2(cx: &DpCtx) -> Option<RuleHit> {
    let pool = cx.pool;
    if pool.len() < 2 {
        return None;
    }
    let prev = cx.prev?;
    let hot = first_max_by(pool, |r| cx.cur.kv_occupancy[r]);
    let hot_occ = cx.cur.kv_occupancy[hot];
    let min_occ = pool
        .iter()
        .filter(|&&r| r != hot)
        .map(|&r| cx.cur.kv_occupancy[r])
        .fold(f64::INFINITY, f64::min);
    let failures = cx.cur.alloc_failures[hot].saturating_sub(prev.alloc_failures[hot]);
    if hot_occ >= KV_HOT_OCC && failures >= 1 && hot_occ - min_occ >= KV_DISPARITY {
        Some(RuleHit {
            replica: hot,
            severity: hot_occ - min_occ,
            evidence: format!(
                "replica {hot} KV at {:.0}% with {failures} admission \
                 failures this window; coldest peer at {:.0}%",
                hot_occ * 100.0,
                min_occ * 100.0
            ),
        })
    } else {
        None
    }
}

/// DP3 — straggler replica: backlog dominates the pool while the iteration
/// rate lags the peers that are keeping up.
fn rule_dp3(cx: &DpCtx) -> Option<RuleHit> {
    let pool = cx.pool;
    let nd = pool.len();
    if nd < 2 {
        return None;
    }
    let lag = first_max_by(pool, |r| cx.cur.queue_depth[r] as f64);
    let lag_q = cx.cur.queue_depth[lag];
    let iters_of = |r: usize| cx.cur.iterations[r].saturating_sub(cx.old.iterations[r]);
    let others_q: u64 = pool.iter().filter(|&&r| r != lag).map(|&r| cx.cur.queue_depth[r]).sum();
    let others_mean_q = others_q as f64 / (nd - 1) as f64;
    let others_it: u64 = pool.iter().filter(|&&r| r != lag).map(|&r| iters_of(r)).sum();
    let others_mean_it = others_it as f64 / (nd - 1) as f64;
    let hit = lag_q >= STRAGGLER_MIN_QUEUE
        && lag_q as f64 >= STRAGGLER_QUEUE_FACTOR * (others_mean_q + 1.0)
        && (iters_of(lag) as f64) < STRAGGLER_ITER_RATIO * (others_mean_it + 1.0);
    if !hit {
        return None;
    }
    Some(RuleHit {
        replica: lag,
        severity: lag_q as f64 / (others_mean_q + 1.0),
        evidence: format!(
            "replica {lag} backlog {lag_q} vs peer mean {others_mean_q:.1}; \
             {} iterations over the horizon vs peer mean {others_mean_it:.0}",
            iters_of(lag)
        ),
    })
}

// ---- fleet-triple shaping ----
// Saturation-sensitive conditions need a compute-dominated cost profile
// (cf. the EW1 matrix shaping): on the fast `small` model a hot or slowed
// replica never runs out of capacity, so flow concentration / degraded GPUs
// would not move throughput. The rate scale keeps the hot/slow lane
// decisively past the 7b compute bound while healthy lanes stay inside it.

fn shape_dp1(cfg: &mut ScenarioCfg) {
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    scale_rate(cfg, 3.0);
}

fn shape_dp3(cfg: &mut ScenarioCfg) {
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    scale_rate(cfg, 2.0);
}

pub static SPECS: [ConditionSpec; 3] = [
    ConditionSpec {
        condition: Condition::Dp1RouterFlowSkew,
        label: "router flow skew",
        family: Family::DataParallel,
        binding: DetectorBinding::FleetDp {
            scope: FleetScope::PerPrefillPool,
            confirm: 3,
            min_pool: 2,
            eval: rule_dp1,
        },
        site: InjectSite::Workload,
        inject: inject_dp1,
        signal: "One replica's routed-arrival share far exceeds hash-fair share",
        stages: "Ingress routing (data-parallel)",
        effect: "Hot replica queues while peers idle; fleet capped by one replica",
        root_cause_text: "Session-affinity hashing + heavy-tailed session popularity",
        directive: Directive::RebalanceFlows,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: Some(shape_dp1),
    },
    ConditionSpec {
        condition: Condition::Dp2HotReplicaKv,
        label: "hot-replica KV exhaustion",
        family: Family::DataParallel,
        binding: DetectorBinding::FleetDp {
            scope: FleetScope::PerDecodePool,
            confirm: 2,
            min_pool: 2,
            eval: rule_dp2,
        },
        site: InjectSite::Engine,
        inject: inject_dp2,
        signal: "One replica's KV pinned at capacity with admission failures",
        stages: "Decode admission (data-parallel)",
        effect: "Hot replica thrashes admissions; its flows see inflated TTFT",
        root_cause_text: "KV fragmentation/leak or flow concentration on one replica",
        directive: Directive::KvAwareRouting,
        cause: cause_gpu,
        expected_causes: &["gpu"],
        compute_skew: false,
        shape_matrix: None,
        // DP2's KV leak is capacity-independent: the victim's pool starves
        // outright regardless of the cost profile.
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Dp3StragglerReplica,
        label: "straggler replica",
        family: Family::DataParallel,
        binding: DetectorBinding::FleetDp {
            scope: FleetScope::PerDecodePool,
            confirm: 2,
            min_pool: 2,
            eval: rule_dp3,
        },
        site: InjectSite::Node,
        inject: inject_dp3,
        signal: "A replica's backlog dominates while its iteration rate lags",
        stages: "All phases on one replica (data-parallel)",
        effect: "Affinity keeps feeding the slow replica; it dominates fleet p99",
        root_cause_text: "Degraded node(s) in one replica: thermal/power/faulty GPU",
        directive: Directive::DrainStragglerReplica,
        cause: cause_gpu,
        expected_causes: &["gpu"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: Some(shape_dp3),
    },
];
