//! PCIe-observer family (paper Table 3b): host↔GPU conditions sensed from
//! the PCIe vantage — PC1-PC10, one [`ConditionSpec`] each.

use super::{
    cause_gpu, cause_host, cause_workload, ConditionSpec, DetectorBinding, Family, InjectCtx,
    InjectSite,
};
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::detectors::Condition;
use crate::engine::preset;
use crate::mitigation::directive::Directive;
use crate::sim::dist::{Arrival, LengthDist};

fn inject_pc1(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.h2d_bw_factor = 0.12;
    k.unpinned_buffers = true;
    format!("H2D capped to 12% + pageable buffers on {target}")
}

fn inject_pc2(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.d2h_bw_factor = 0.12;
    k.pcie_extra_lat_ns = 25_000;
    format!("D2H capped to 12% + IOMMU contention on {target}")
}

fn inject_pc3(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.doorbell_delay_ns = 150_000;
    k.kernel_fission = 12;
    format!("runtime launch overhead + tiny-kernel storm on {target}")
}

fn inject_pc4(cx: &mut InjectCtx) -> String {
    // Memory pressure on one GPU: the scheduler underfeeds it.
    let target = cx.target;
    let stage_idx = cx
        .engine
        .replicas
        .iter()
        .position(|r| r.plan.stages.iter().any(|s| s.nodes.contains(&target)));
    if let Some(ri) = stage_idx {
        let spec = &cx.cluster.spec;
        let plan = &mut cx.engine.replicas[ri].plan;
        let si = plan.stages.iter().position(|s| s.nodes.contains(&target)).unwrap();
        let gi = plan.stages[si]
            .gpus
            .iter()
            .position(|&g| spec.node_of_gpu(g) == target)
            .unwrap();
        plan.skew_shards(si, gi, 0.1);
    }
    cx.cluster.nodes[target.idx()].knobs.gpu_speed_factor[0] = 0.6;
    format!("one GPU on {target} underfed (memory pressure) and slowed")
}

fn inject_pc5(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().pcie_background_load = 0.8;
    format!("competing DMA tenant burns 80% of {target}'s PCIe")
}

fn inject_pc6(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.p2p_over_pcie = true;
    k.pcie_background_load = 0.3;
    format!("P2P forced over shared PCIe switch on {target}")
}

fn inject_pc7(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().pinned_pool_frag = true;
    format!("pinned pool fragmented on {target}: DMAs split small")
}

fn inject_pc8(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    let k = cx.knobs();
    k.cpu_contention = 4.0;
    k.doorbell_delay_ns = 60_000;
    format!("host CPU contention on {target}: doorbells delayed")
}

fn inject_pc9(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().mem_reg_churn = true;
    format!("short-lived buffers: map/unmap around every DMA on {target}")
}

fn inject_pc10(cx: &mut InjectCtx) -> String {
    cx.wl.output_len = LengthDist::Bimodal { short: 2, long: 48, p_short: 0.6 };
    for r in &mut cx.engine.replicas {
        r.batcher.policy_mut().inflight_remap = false;
    }
    "sequence-length variance with no decode rebalancing".into()
}

// PC10's PCIe signature (shrinking decode D2H blocks) additionally needs
// iterations slow enough that slots actually fill: compute-heavy profile
// under sustained demand.
fn shape_pc10(cfg: &mut ScenarioCfg) {
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.workload.arrival = Arrival::Poisson { rate: 1500.0 };
    cfg.workload.prompt_len = LengthDist::Uniform { lo: 8, hi: 16 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 8, hi: 24 };
}

pub static SPECS: [ConditionSpec; 10] = [
    ConditionSpec {
        condition: Condition::Pc1H2dStarvation,
        label: "H2D starvation",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc1,
        signal: "Large/clustered H2D DMAs then long gaps before doorbells",
        stages: "Ingress -> PCIe (prefill & decode input feed)",
        effect: "Fewer/late internode bursts; downstream TP/PP idles",
        root_cause_text: "PCIe BW cap, NUMA miss, pageable (unpinned) host buffers",
        directive: Directive::PinMemoryPools,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc2D2hBottleneck,
        label: "D2H bottleneck",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc2,
        signal: "D2H DMAs linger / complete slowly; backlog after kernels",
        stages: "Egress (logits/tokens back to host)",
        effect: "Late responses; backpressure into next token step",
        root_cause_text: "PCIe saturation, IOMMU contention, CPU copy hotspots",
        directive: Directive::FixReturnPath,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc3LaunchLatency,
        label: "kernel launch latency",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc3,
        signal: "Doorbells sporadic; idle gaps between H2D bursts and launch",
        stages: "Compute (GPU underutilized across prefill/decode)",
        effect: "TP collectives delayed, PP handoffs drift",
        root_cause_text: "Runtime overhead, CPU scheduler delays, too many tiny kernels",
        directive: Directive::FuseKernelsIsolateCpu,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc4IntraNodeSkew,
        label: "intra-node GPU skew",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc4,
        signal: "One GPU shows thin/irregular DMA; peers steady",
        stages: "Compute (per-layer) -> propagates to internode",
        effect: "TP collectives widen (straggler), PP stage misalignment",
        root_cause_text: "Uneven microbatching, memory pressure on a single GPU",
        directive: Directive::RebalanceShards,
        cause: cause_gpu,
        expected_causes: &["gpu"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc5PcieSaturation,
        label: "PCIe saturation",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc5,
        signal: "Sustained near-peak PCIe throughput; compute stalls periodically",
        stages: "Ingress -> PCIe, Egress",
        effect: "Burstiness in internode waves; elongates token step",
        root_cause_text: "Oversubscribed PCIe switch / x8 link, competing DMAs",
        directive: Directive::MovePcieTenants,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc6P2pThrottling,
        label: "P2P throttling",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc6,
        signal: "P2P DMAs slow/variable; no NVLink path",
        stages: "Compute (intra-box TP/PP)",
        effect: "Internode timing jitter (collectives wait on slow intra-box move)",
        root_cause_text: "Shared uplink on PCIe switch; ACS/ATS settings",
        directive: Directive::PreferNvlink,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc7PinnedShortage,
        label: "pinned-memory shortage",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc7,
        signal: "Many small DMAs vs large coalesced; rising DMA count",
        stages: "Ingress -> PCIe (feed) and Egress (returns)",
        effect: "Micro-jitter; uneven stage timing",
        root_cause_text: "Insufficient pinned pools; fallback to pageable",
        directive: Directive::PinMemoryPools,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc8HostCpuBottleneck,
        label: "host CPU bottleneck",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc8,
        signal: "Low DMA rate despite available PCIe BW; delayed doorbells",
        stages: "Compute orchestration",
        effect: "Irregular TP cadence; PP bubbles",
        root_cause_text: "CPU contention, IRQ affinity, polling disabled",
        directive: Directive::FuseKernelsIsolateCpu,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc9RegistrationChurn,
        label: "registration churn",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_pc9,
        signal: "Frequent map/unmap patterns around DMAs",
        stages: "Ingress -> PCIe",
        effect: "Small timing gaps accumulating per token",
        root_cause_text: "Repeated registration due to short-lived buffers",
        directive: Directive::PersistentRegistration,
        cause: cause_host,
        expected_causes: &["host"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Pc10DecodeEarlyStop,
        label: "decode early stop",
        family: Family::Pcie,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Workload,
        inject: inject_pc10,
        signal: "D2H drops off early on some streams/GPUs",
        stages: "Compute (decode) -> Egress",
        effect: "Some peers go silent; collectives wait for remaining peers",
        root_cause_text: "Sequence length variance; scheduler not rebalancing",
        directive: Directive::EnableInflightRemap,
        cause: cause_workload,
        expected_causes: &["workload"],
        compute_skew: false,
        shape_matrix: Some(shape_pc10),
        shape_fleet: None,
    },
];
