//! East-West family (paper Table 3c): inter-node conditions sensed from the
//! fabric vantage — EW1-EW9, one [`ConditionSpec`] each.

use super::{
    cause_gpu, cause_network, cause_workload, ConditionSpec, DetectorBinding, Family, InjectCtx,
    InjectSite,
};
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::detectors::Condition;
use crate::engine::preset;
use crate::mitigation::directive::Directive;
use crate::sim::dist::{Arrival, LengthDist};

fn inject_ew1(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().gpu_speed_factor[0] = 0.2;
    format!("GPU0 on {target} runs at 20% speed (straggling shard)")
}

fn inject_ew2(cx: &mut InjectCtx) -> String {
    for r in &mut cx.engine.replicas {
        r.plan.overload_stage(0, 3.0);
    }
    "stage 0 mispartitioned (3x recompute): downstream stages idle".into()
}

fn inject_ew3(cx: &mut InjectCtx) -> String {
    for r in &mut cx.engine.replicas {
        let n_g = r.plan.stages[0].shard_frac.len();
        for g in 0..n_g / 2 {
            r.plan.skew_shards(0, g, 4.0);
        }
    }
    "activation partitioning misaligned: one node owns most shards".into()
}

fn inject_ew4(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.hot_uplink_load = 5.0;
    cx.cluster.fabric_knobs.hot_node = None;
    "fat-tree uplinks oversubscribed 5x (hot ToR)".into()
}

fn inject_ew5(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.hol_blocking = true;
    "shared-queue exhaustion: flows serialize through one queue".into()
}

fn inject_ew6(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.loss_prob = 0.10;
    "10% fabric loss (misconfigured PFC)".into()
}

fn inject_ew7(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.credit_window = 2;
    "RDMA QP window shrunk to 2 (credit depletion)".into()
}

fn inject_ew8(cx: &mut InjectCtx) -> String {
    cx.cluster.fabric_knobs.kv_link_budget_factor = 0.12;
    cx.wl.prompt_len = LengthDist::Uniform { lo: 48, hi: 64 };
    "sharded KV exceeds link budget (12%) with long prompts".into()
}

fn inject_ew9(cx: &mut InjectCtx) -> String {
    let target = cx.target;
    cx.knobs().collective_silence = 0.5;
    format!("{target} goes silent in 50% of collectives (unmasked early exit)")
}

// Compute-skew conditions need a compute-dominated cost profile for a
// straggler/mispartition to move collective timing.
fn shape_ew_compute(cfg: &mut ScenarioCfg) {
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.workload.arrival = Arrival::Poisson { rate: 150.0 };
}

// Pipeline-cadence detection needs a *busy* pipeline: idle lulls produce
// ms-scale healthy gaps that mask a mispartitioned stage.
fn shape_ew2(cfg: &mut ScenarioCfg) {
    cfg.engine.profile = preset("7b").unwrap();
    cfg.engine.policy.max_batch = 8;
    cfg.workload.arrival = Arrival::Poisson { rate: 500.0 };
    cfg.workload.output_len = LengthDist::Uniform { lo: 8, hi: 16 };
}

pub static SPECS: [ConditionSpec; 9] = [
    ConditionSpec {
        condition: Condition::Ew1TpStraggler,
        label: "TP straggler",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ew1,
        signal: "Wide arrival spread of collective bursts (max-min gap up)",
        stages: "Compute (tensor-parallel collectives)",
        effect: "Collective ops stall waiting for slowest peer",
        root_cause_text: "Skewed GPU load, PCIe starvation, memory imbalance on one node",
        directive: Directive::RebalanceShards,
        cause: cause_gpu,
        expected_causes: &["gpu", "network"],
        compute_skew: true,
        shape_matrix: Some(shape_ew_compute),
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew2PpBubble,
        label: "PP bubble",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Engine,
        inject: inject_ew2,
        signal: "Large or growing gaps between stage handoff bursts",
        stages: "Pipeline parallel",
        effect: "Downstream stage idles; upstream builds backlog",
        root_cause_text: "Load imbalance across pipeline stages, early token exit variance",
        directive: Directive::RebalanceStages,
        cause: cause_gpu,
        expected_causes: &["gpu", "network"],
        compute_skew: true,
        shape_matrix: Some(shape_ew2),
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew3CrossNodeSkew,
        label: "cross-node shard skew",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Engine,
        inject: inject_ew3,
        signal: "Uneven traffic volume per node for same collective",
        stages: "TP/PP compute -> internode",
        effect: "Some nodes oversend/undersend; throughput uneven",
        root_cause_text: "Shard imbalance, misaligned activation partitioning",
        directive: Directive::RebalanceAcrossNodes,
        cause: cause_gpu,
        expected_causes: &["gpu", "network"],
        compute_skew: true,
        shape_matrix: Some(shape_ew_compute),
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew4Congestion,
        label: "fabric congestion",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Fabric,
        inject: inject_ew4,
        signal: "Periodic spikes in latency + jitter across many links",
        stages: "Internode transfers (collectives & stage handoff)",
        effect: "Token step elongates cluster-wide",
        root_cause_text: "Fat-tree oversubscription, ToR link hot spot",
        directive: Directive::AdaptiveRouting,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: Some(shape_ew_compute),
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew5HolBlocking,
        label: "head-of-line blocking",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Fabric,
        inject: inject_ew5,
        signal: "Some streams stall while others flow; out-of-order bursts",
        stages: "Collective streams / P2P flows",
        effect: "Latency-sensitive ops delayed",
        root_cause_text: "Shared queue depth exhaustion, RoCE/NIC queue imbalance",
        directive: Directive::FixQueueSharing,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew6Retransmissions,
        label: "fabric retransmissions",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Fabric,
        inject: inject_ew6,
        signal: "Gaps + duplicate traffic or sudden retransmit storms",
        stages: "All distributed phases",
        effect: "Bursty latency; collectives jitter",
        root_cause_text: "Fabric errors, congestion collapse, misconfigured PFC",
        directive: Directive::LosslessFabricConfig,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew7CreditStarvation,
        label: "credit starvation",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Fabric,
        inject: inject_ew7,
        signal: "Long silence periods until remote credit update",
        stages: "Internode (RDMA ops)",
        effect: "Under-utilized links; token latency grows",
        root_cause_text: "Too-small RDMA window, NIC credit depletion",
        directive: Directive::TuneCreditWindow,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew8KvBottleneck,
        label: "KV-transfer bottleneck",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Fabric,
        inject: inject_ew8,
        signal: "Repeated large bursts for some tokens, others silent",
        stages: "Decode phase (PP handoff)",
        effect: "Uneven memory pressure per stage; downstream skew",
        root_cause_text: "Sharded KV too large for link budget; non-uniform length",
        directive: Directive::CompressKvTransfers,
        cause: cause_network,
        expected_causes: &["network"],
        compute_skew: false,
        shape_matrix: None,
        shape_fleet: None,
    },
    ConditionSpec {
        condition: Condition::Ew9EarlyStopSkew,
        label: "early-stop skew",
        family: Family::EastWest,
        binding: DetectorBinding::NodeWindow,
        site: InjectSite::Node,
        inject: inject_ew9,
        signal: "Some nodes stop sending mid-iteration while others continue",
        stages: "Decode (multi-node)",
        effect: "Collectives/pipeline hang waiting for peers",
        root_cause_text: "Sequence length divergence; scheduler not masking early exits",
        directive: Directive::EnableInflightRemap,
        cause: cause_workload,
        expected_causes: &["workload"],
        compute_skew: false,
        shape_matrix: Some(shape_ew_compute),
        shape_fleet: None,
    },
];
