//! The condition catalog: one [`ConditionSpec`] per runbook condition, the
//! single home of every piece of per-condition knowledge the system needs —
//! inject site + recipe, runbook row (signal / stages / effect / likely root
//! cause / mitigation directive), root-cause mapping, attribution scoring
//! classes, detector binding, scenario shaping, and the scorecard label.
//!
//! Before this registry existed, each condition's knowledge was smeared
//! across ~12 parallel `match`-on-`Condition` sites in eight files
//! (`pathology`, `dpu/runbook`, `dpu/attribution`, `mitigation/controller`,
//! the two fleet layers, `coordinator/experiment`, `main.rs`) — every new
//! condition family paid that shotgun-surgery tax. Now `pathology`,
//! `runbook`, `attribution`, the mitigation controller, and the fleet
//! sensors all dispatch through [`spec`]; adding a condition is a one-module
//! change (a new entry in its family's `SPECS` array) and the
//! `catalog_covers_every_condition_exactly_once` test names any variant that
//! is missing one.
//!
//! Specs are grouped into per-family modules mirroring the paper tables:
//! `north_south` (3a), `pcie` (3b), `east_west` (3c), plus the
//! serving-scale extensions `data_parallel` (DP), `phase_disagg` (PD), and
//! `telemetry_dropout` (TD — the monitoring path itself as the victim).

pub mod data_parallel;
pub mod east_west;
pub mod north_south;
pub mod pcie;
pub mod phase_disagg;
pub mod telemetry_dropout;

use crate::cluster::Cluster;
use crate::coordinator::scenario::ScenarioCfg;
use crate::dpu::attribution::RootCause;
use crate::dpu::detectors::Condition;
use crate::dpu::fleet::{DpCtx, PdCtx, RuleHit, TdCtx};
use crate::engine::Engine;
use crate::ids::NodeId;
use crate::mitigation::directive::Directive;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::generator::WorkloadSpec;

/// Which runbook family a condition belongs to (paper table or extension).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Table 3(a) — North-South (ingress/egress) sensing.
    NorthSouth,
    /// Table 3(b) — PCIe observer.
    Pcie,
    /// Table 3(c) — East-West (inter-node) sensing.
    EastWest,
    /// Data-parallel fleet extension (router/LB vantage).
    DataParallel,
    /// Phase-disaggregation extension (pool-boundary vantage).
    PhaseDisagg,
    /// Telemetry-dropout extension (the monitoring path itself degrades:
    /// stale, lossy, or lagging DPU signal — sensed by the freshness
    /// watchdog rather than the signal content).
    TelemetryDropout,
}

impl Family {
    /// The runbook-table id the rest of the system keys on.
    pub fn table(&self) -> &'static str {
        match self {
            Family::NorthSouth => "3a",
            Family::Pcie => "3b",
            Family::EastWest => "3c",
            Family::DataParallel => "dp",
            Family::PhaseDisagg => "pd",
            Family::TelemetryDropout => "td",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Family::NorthSouth => "north-south",
            Family::Pcie => "pcie",
            Family::EastWest => "east-west",
            Family::DataParallel => "data-parallel",
            Family::PhaseDisagg => "phase-disagg",
            Family::TelemetryDropout => "telemetry-dropout",
        }
    }
}

/// Where a condition's knobs live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectSite {
    /// Per-node hardware knobs (which node matters).
    Node,
    /// Fabric-wide knobs.
    Fabric,
    /// Workload generator shape.
    Workload,
    /// Engine policy / parallel plan.
    Engine,
}

impl InjectSite {
    pub fn id(&self) -> &'static str {
        match self {
            InjectSite::Node => "node",
            InjectSite::Fabric => "fabric",
            InjectSite::Workload => "workload",
            InjectSite::Engine => "engine",
        }
    }
}

/// Which pool a fleet rule is evaluated against each window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetScope {
    /// Once per prefill pool (the paired decode pool is the counterpart).
    PerPrefillPool,
    /// Once per decode pool.
    PerDecodePool,
    /// Once over the union of all decode members (rules that read the
    /// fleet-wide handoff counters rather than a per-pool signal).
    DecodeUnion,
}

/// How a condition is sensed.
#[derive(Clone, Copy)]
pub enum DetectorBinding {
    /// One of the 28 per-node window detectors (`dpu::detectors` registry —
    /// the paper's Tables 3a-c diagonal).
    NodeWindow,
    /// Cross-replica rule run by `dpu::fleet::FleetSensor` at window ticks
    /// on the per-replica serving sample.
    FleetDp {
        scope: FleetScope,
        /// Consecutive confirming windows before the detection fires.
        confirm: u32,
        /// Smallest pool the rule can judge: 2 for peer-comparison rules
        /// (skew across pool members is undefined on a singleton), 1 for
        /// aggregate rules. The rule itself also guards; studies use this
        /// to skip triples that are structurally inert on a topology.
        min_pool: usize,
        eval: fn(&DpCtx) -> Option<RuleHit>,
    },
    /// Pool-boundary rule run by the sensor on disaggregated fleets.
    FleetPd {
        scope: FleetScope,
        confirm: u32,
        min_pool: usize,
        eval: fn(&PdCtx) -> Option<RuleHit>,
    },
    /// Freshness-plane rule run by the sensor on the per-replica telemetry
    /// delivery stats (`TdCtx`). No scope/min-pool: the rule judges the
    /// whole fleet once per window (freshness of a single replica's signal
    /// is well-defined, unlike peer skew) and the hit names the worst
    /// replica.
    FleetTd {
        confirm: u32,
        eval: fn(&TdCtx) -> Option<RuleHit>,
    },
}

impl DetectorBinding {
    pub fn id(&self) -> &'static str {
        match self {
            DetectorBinding::NodeWindow => "window",
            DetectorBinding::FleetDp { .. } => "fleet-dp",
            DetectorBinding::FleetPd { .. } => "fleet-pd",
            DetectorBinding::FleetTd { .. } => "fleet-td",
        }
    }
}

impl std::fmt::Debug for DetectorBinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

/// The live world an injection mutates.
pub struct InjectCtx<'a> {
    /// Victim node for node-scoped conditions (egress conditions get an exit
    /// node, ingress/PCIe conditions an entry node; DP/PD injections resolve
    /// their victim replica from it).
    pub target: NodeId,
    pub cluster: &'a mut Cluster,
    pub engine: &'a mut Engine,
    pub wl: &'a mut WorkloadSpec,
}

impl InjectCtx<'_> {
    /// The victim node's pathology knobs.
    pub fn knobs(&mut self) -> &mut crate::cluster::NodeKnobs {
        &mut self.cluster.nodes[self.target.idx()].knobs
    }
}

/// Everything the system knows about one condition — the catalog row.
pub struct ConditionSpec {
    pub condition: Condition,
    /// Short human name: the scorecard / table label.
    pub label: &'static str,
    pub family: Family,
    /// How the condition is sensed (per-node window detector or fleet rule).
    pub binding: DetectorBinding,
    /// Which subsystem the injection touches (scenarios use this to decide
    /// whether the workload generator must be rebuilt).
    pub site: InjectSite,
    /// Turn the knobs that create exactly the paper's "likely root cause";
    /// returns the evidence description for reports.
    pub inject: fn(&mut InjectCtx) -> String,
    /// Runbook row (paper Tables 3a-c and the DP/PD extensions).
    pub signal: &'static str,
    pub stages: &'static str,
    pub effect: &'static str,
    pub root_cause_text: &'static str,
    pub directive: Directive,
    /// Default root-cause verdict for a detection at `node` (§4.2).
    pub cause: fn(NodeId) -> RootCause,
    /// Cause classes that count as a correct attribution (matrix scoring).
    pub expected_causes: &'static [&'static str],
    /// §4.2 refinement tag: cross-node compute skew (EW1-EW3), which the
    /// attribution layer refines against PCIe-vantage evidence.
    pub compute_skew: bool,
    /// Matrix/sweep scenario shaping (None = the standard config already
    /// produces the red flag).
    pub shape_matrix: Option<fn(&mut ScenarioCfg)>,
    /// Fleet-triple shaping applied on top of the DP/PD/multi-pool base
    /// configs (healthy cells share it, so recovery stays like-for-like).
    pub shape_fleet: Option<fn(&mut ScenarioCfg)>,
}

/// Every catalog row, runbook-table order: NS1-NS9, PC1-PC10, EW1-EW9, then
/// the DP, PD, and TD extensions — the same order as `ALL_CONDITIONS` +
/// `DP_CONDITIONS` + `PD_CONDITIONS` + `TD_CONDITIONS`.
pub fn all_specs() -> impl Iterator<Item = &'static ConditionSpec> {
    north_south::SPECS
        .iter()
        .chain(pcie::SPECS.iter())
        .chain(east_west::SPECS.iter())
        .chain(data_parallel::SPECS.iter())
        .chain(phase_disagg::SPECS.iter())
        .chain(telemetry_dropout::SPECS.iter())
}

/// Look up the catalog row for a condition. Panics (naming the variant) if a
/// condition was added without a spec — the registry-audit test catches this
/// before any runtime path does.
pub fn spec(c: Condition) -> &'static ConditionSpec {
    all_specs().find(|s| s.condition == c).unwrap_or_else(|| {
        panic!("no ConditionSpec for {c:?} — add one to rust/src/conditions/")
    })
}

/// Which subsystem an injection touches.
pub fn site(c: Condition) -> InjectSite {
    spec(c).site
}

/// Apply the injection for `c`; returns the evidence description.
pub fn inject(
    c: Condition,
    target: NodeId,
    cluster: &mut Cluster,
    engine: &mut Engine,
    wl: &mut WorkloadSpec,
) -> String {
    let mut cx = InjectCtx { target, cluster, engine, wl };
    (spec(c).inject)(&mut cx)
}

/// Revert everything any injection touched (used between bench scenarios).
/// Injections share the cluster/engine/workload knob surface, so healing is
/// a catalog-level sweep rather than a per-row recipe.
pub fn heal_all(cluster: &mut Cluster, engine: &mut Engine, wl: &mut WorkloadSpec) {
    cluster.heal();
    for r in &mut engine.replicas {
        r.plan.rebalance();
        r.kv.restore_capacity();
        let pol = r.batcher.policy_mut();
        pol.inflight_remap = true;
        pol.continuous = true;
    }
    engine.reset_roles();
    engine.router.clear_overrides();
    engine.router.clear_drained();
    engine.decode_router.set_pin(None);
    engine.decode_router.clear_overrides();
    engine.decode_router.clear_drained();
    *wl = WorkloadSpec::default();
}

// Shared root-cause constructors for the per-family spec tables.
pub(crate) fn cause_client(_: NodeId) -> RootCause {
    RootCause::ClientSide
}
pub(crate) fn cause_network(_: NodeId) -> RootCause {
    RootCause::NetworkSide
}
pub(crate) fn cause_workload(_: NodeId) -> RootCause {
    RootCause::WorkloadShape
}
pub(crate) fn cause_host(n: NodeId) -> RootCause {
    RootCause::HostLocal(n)
}
pub(crate) fn cause_gpu(n: NodeId) -> RootCause {
    RootCause::GpuSide(n)
}

/// Shared shaping helper: scale a Poisson arrival rate (no-op for other
/// arrival processes — injections that surge demand do it the same way).
pub fn scale_rate(cfg: &mut ScenarioCfg, factor: f64) {
    if let crate::sim::dist::Arrival::Poisson { rate } = &cfg.workload.arrival {
        let scaled = rate * factor;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: scaled };
    }
}

/// The catalog as a human table (`dpulens conditions`).
pub fn render_table() -> String {
    let mut t = Table::new("Condition catalog — one ConditionSpec per runbook row").header(&[
        "id", "label", "family", "detector", "site", "directive",
    ]);
    for s in all_specs() {
        t.row(vec![
            s.condition.id().to_string(),
            s.label.to_string(),
            s.family.name().to_string(),
            s.binding.id().to_string(),
            s.site.id().to_string(),
            format!("{:?}", s.directive),
        ]);
    }
    t.render()
}

/// The catalog as a markdown table — EXPERIMENTS.md §Condition catalog is
/// regenerated from this exact output (`dpulens conditions --md`), and the
/// `experiments_md_condition_table_is_generated` test keeps them in sync.
pub fn render_markdown() -> String {
    let mut s = String::from(
        "| id | label | family | detector | site | directive |\n\
         |----|-------|--------|----------|------|-----------|\n",
    );
    for sp in all_specs() {
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:?} |\n",
            sp.condition.id(),
            sp.label,
            sp.family.name(),
            sp.binding.id(),
            sp.site.id(),
            sp.directive,
        ));
    }
    s
}

/// The catalog as deterministic JSON (`dpulens conditions --json`, schema
/// `dpulens.conditions.v1`).
pub fn to_json() -> Json {
    let mut rows = Json::arr();
    for s in all_specs() {
        let mut causes = Json::arr();
        for &c in s.expected_causes {
            causes.push(c);
        }
        rows.push(
            Json::obj()
                .set("id", s.condition.id())
                .set("label", s.label)
                .set("family", s.family.name())
                .set("table", s.family.table())
                .set("detector", s.binding.id())
                .set("site", s.site.id())
                .set("signal", s.signal)
                .set("stages", s.stages)
                .set("effect", s.effect)
                .set("root_cause", s.root_cause_text)
                .set("directive", format!("{:?}", s.directive))
                .set("directive_text", s.directive.paper_text())
                .set("expected_causes", causes)
                .set("compute_skew", s.compute_skew),
        );
    }
    Json::obj()
        .set("schema", "dpulens.conditions.v1")
        .set("conditions", Json::Int(all_specs().count() as i64))
        .set("catalog", rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::detectors::{ALL_CONDITIONS, DP_CONDITIONS, PD_CONDITIONS, TD_CONDITIONS};

    fn every_condition() -> Vec<Condition> {
        ALL_CONDITIONS
            .iter()
            .chain(DP_CONDITIONS.iter())
            .chain(PD_CONDITIONS.iter())
            .chain(TD_CONDITIONS.iter())
            .copied()
            .collect()
    }

    /// The registry-audit satellite: every `Condition` variant has exactly
    /// one spec, and that spec carries the full knowledge set (inject +
    /// runbook + attribution + label). A variant added without a catalog
    /// entry fails here BY NAME.
    #[test]
    fn catalog_covers_every_condition_exactly_once() {
        let conditions = every_condition();
        assert_eq!(all_specs().count(), conditions.len(), "catalog/condition count mismatch");
        let mut missing = Vec::new();
        for &c in &conditions {
            let n = all_specs().filter(|s| s.condition == c).count();
            match n {
                0 => missing.push(c),
                1 => {}
                n => panic!("{c:?} has {n} ConditionSpecs (must be exactly one)"),
            }
        }
        assert!(missing.is_empty(), "conditions missing a ConditionSpec: {missing:?}");
        for s in all_specs() {
            let id = s.condition.id();
            assert!(!s.label.is_empty(), "{id}: empty scorecard label");
            assert!(!s.signal.is_empty(), "{id}: empty runbook signal");
            assert!(!s.stages.is_empty(), "{id}: empty runbook stages");
            assert!(!s.effect.is_empty(), "{id}: empty runbook effect");
            assert!(!s.root_cause_text.is_empty(), "{id}: empty runbook root cause");
            assert!(!s.expected_causes.is_empty(), "{id}: no attribution classes");
        }
    }

    #[test]
    fn catalog_order_matches_the_runbook_tables() {
        let conditions = every_condition();
        for (c, s) in conditions.iter().zip(all_specs()) {
            assert_eq!(*c, s.condition, "catalog order diverges at {c:?}");
        }
        // Family tags agree with the id-prefix table mapping.
        for s in all_specs() {
            assert_eq!(s.family.table(), s.condition.table(), "{}", s.condition.id());
        }
    }

    #[test]
    fn bindings_partition_by_family() {
        for s in all_specs() {
            match s.family {
                Family::NorthSouth | Family::Pcie | Family::EastWest => {
                    assert!(
                        matches!(s.binding, DetectorBinding::NodeWindow),
                        "{} must bind to a per-node window detector",
                        s.condition.id()
                    );
                }
                Family::DataParallel => {
                    assert!(
                        matches!(s.binding, DetectorBinding::FleetDp { .. }),
                        "{} must bind to a fleet DP rule",
                        s.condition.id()
                    );
                }
                Family::PhaseDisagg => {
                    assert!(
                        matches!(s.binding, DetectorBinding::FleetPd { .. }),
                        "{} must bind to a fleet PD rule",
                        s.condition.id()
                    );
                }
                Family::TelemetryDropout => {
                    assert!(
                        matches!(s.binding, DetectorBinding::FleetTd { .. }),
                        "{} must bind to a fleet TD (freshness) rule",
                        s.condition.id()
                    );
                }
            }
        }
    }

    #[test]
    fn labels_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for s in all_specs() {
            assert!(seen.insert(s.label), "duplicate label {:?}", s.label);
        }
    }

    #[test]
    fn renderers_cover_the_whole_catalog() {
        let table = render_table();
        let md = render_markdown();
        let json = to_json().render();
        for c in every_condition() {
            assert!(table.contains(c.id()), "table missing {}", c.id());
            assert!(md.contains(&format!("| {} |", c.id())), "markdown missing {}", c.id());
            assert!(json.contains(&format!("\"id\":\"{}\"", c.id())), "json missing {}", c.id());
        }
        assert!(json.contains("\"schema\":\"dpulens.conditions.v1\""));
        assert!(json.contains("\"conditions\":37"));
    }

    /// Docs can't drift: the EXPERIMENTS.md condition table is the exact
    /// `render_markdown()` output (regenerate with `dpulens conditions --md`).
    #[test]
    fn experiments_md_condition_table_is_generated() {
        let doc = include_str!("../../../EXPERIMENTS.md");
        let md = render_markdown();
        assert!(
            doc.contains(&md),
            "EXPERIMENTS.md §Condition catalog is stale — regenerate it with \
             `dpulens conditions --md` and paste the table"
        );
    }
}
