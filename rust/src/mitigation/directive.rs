//! Mitigation directives — the actionable form of every "Mitigation
//! Directives" cell in paper Tables 3(a)-(c). The controller
//! (`mitigation::controller`) applies them to the cluster/engine knobs.

/// An action the orchestrator can take in response to a detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Directive {
    /// NS1: smooth input batching / rate-limit clients / deepen NIC queues.
    SmoothAdmission,
    /// NS2/NS3: rebalance load-balancer hashing / RPC streams.
    RebalanceFlows,
    /// NS4: enable NIC offloads, fix MTU/link errors (clears ingress loss).
    FixIngressPath,
    /// NS5: zero-copy send + bigger TX buffers + offload checksums.
    ZeroCopyEgress,
    /// NS6: isolate runtime threads, pin NIC IRQs, widen batching window.
    PinIrqsIsolateThreads,
    /// NS7: fix offload config / congestion control (clears egress loss).
    FixEgressPath,
    /// NS8/PC10/EW9: in-flight request remapping / load stealing for decode.
    EnableInflightRemap,
    /// NS9: QoS partitioning / move background tenants off the NIC.
    QosPartitionNic,
    /// PC1/PC7: pin memory, pre-allocate larger pinned pools, coalesce DMAs.
    PinMemoryPools,
    /// PC2: large pinned buffers, fewer copies, fix IOMMU/ATS config.
    FixReturnPath,
    /// PC3/PC8: batch ops, fuse kernels, isolate CPU cores for the runtime.
    FuseKernelsIsolateCpu,
    /// PC4/EW1: rebalance shards across GPUs (speed-aware fractions).
    RebalanceShards,
    /// PC5: move competing DMA tenants off the shared PCIe switch.
    MovePcieTenants,
    /// PC6: prefer NVLink / place GPUs under the same switch.
    PreferNvlink,
    /// PC9: reuse registered buffers / persistent memory regions.
    PersistentRegistration,
    /// EW2: repartition microbatches / reassign stages.
    RebalanceStages,
    /// EW3: validate shard sizes, rebalance across nodes.
    RebalanceAcrossNodes,
    /// EW4: adaptive routing / spread ranks off the hot uplink.
    AdaptiveRouting,
    /// EW5: deepen NIC queues, QoS/ECN, verify fair sharing.
    FixQueueSharing,
    /// EW6: verify lossless config (PFC/ECN), buffers, optics.
    LosslessFabricConfig,
    /// EW7: increase QP window / tune flow-control credits.
    TuneCreditWindow,
    /// EW8: compress KV, shard differently, apply caching policies.
    CompressKvTransfers,
    /// DP2: rebuild the hot replica's KV pool and weight routing by
    /// queue-depth/KV-occupancy telemetry.
    KvAwareRouting,
    /// DP3: take the straggling replica out of rotation until it recovers.
    DrainStragglerReplica,
    /// PD1: shift a spare decode-pool replica into the prefill pool — the
    /// role-level autoscaling primitive of a disaggregated fleet.
    RebalancePools,
    /// PD3: unwedge the phase-transition router (clear pins/overrides,
    /// balance KV handoffs by decode-pool load).
    RebalanceHandoffRouting,
    /// TD1: bounce the wedged telemetry exporter/agent on the node.
    RestartTelemetryExporter,
    /// TD2: repair the lossy export channel (resize mirror queues, fix the
    /// oob path) so every emitted event reaches the observer again.
    RepairTelemetryPath,
    /// TD3: lift the telemetry class out of the congested queue (QoS
    /// priority for the export path) so delivery catches back up.
    PrioritizeTelemetryClass,
}

impl Directive {
    /// Whether the directive's knob changes target the detected node (host
    /// fixes, NIC path fixes, per-replica drains) rather than the fabric,
    /// engine policy, or fleet-wide state. Directive-level knowledge: the
    /// controller applies one action per (directive, scope) pair.
    pub fn node_scoped(&self) -> bool {
        use Directive::*;
        matches!(
            self,
            PinMemoryPools
                | FixReturnPath
                | FuseKernelsIsolateCpu
                | MovePcieTenants
                | PreferNvlink
                | PersistentRegistration
                | ZeroCopyEgress
                | PinIrqsIsolateThreads
                | FixIngressPath
                | FixEgressPath
                | QosPartitionNic
                | SmoothAdmission
                | DrainStragglerReplica
                | RestartTelemetryExporter
                | RepairTelemetryPath
                | PrioritizeTelemetryClass
        )
    }

    /// The paper's own wording for the directive (report rendering).
    pub fn paper_text(&self) -> &'static str {
        use Directive::*;
        match self {
            SmoothAdmission => "Smooth input batching, rate-limit clients, increase NIC queue depth",
            RebalanceFlows => "Balance load balancer hashing, check NIC RSS/flow steering",
            FixIngressPath => "Enable NIC offloads (TSO/GRO), verify MTU settings, check cabling",
            ZeroCopyEgress => "Offload checksums, use zero-copy send, increase NIC buffer size",
            PinIrqsIsolateThreads => "Isolate runtime threads, pin NIC IRQs, increase batching window",
            FixEgressPath => "Check offload settings, enable congestion control (ECN/PFC)",
            EnableInflightRemap => "Enable inflight remapping / load stealing for decode",
            QosPartitionNic => "Upgrade NIC, QoS partitioning, stagger workloads",
            PinMemoryPools => "Pin memory, bind to correct NUMA socket, pre-allocate pinned pools",
            FixReturnPath => "Enable large pinned buffers, reduce copies, check IOMMU/ATS config",
            FuseKernelsIsolateCpu => "Batch ops, fuse kernels, raise launch queues, isolate CPU cores",
            RebalanceShards => "Rebalance shards, check PCIe feeds per node, adjust affinity",
            MovePcieTenants => "Verify x16 lanes, move devices off shared switch, stagger I/O",
            PreferNvlink => "Prefer NVLink/NVSwitch; place GPUs under same switch, tune ACS/ATS",
            PersistentRegistration => "Reuse registered buffers; RDMA/GPUDirect with persistent MR",
            RebalanceStages => "Adjust microbatch partitioning, reassign stages, speculative fill",
            RebalanceAcrossNodes => "Validate shard sizes, rebalance across nodes",
            AdaptiveRouting => "Check fabric counters, enable adaptive routing, spread ranks",
            FixQueueSharing => "Increase NIC queue depth, enable QoS/ECN, verify fair sharing",
            LosslessFabricConfig => "Verify lossless config, tune buffer thresholds, check optics",
            TuneCreditWindow => "Increase QP window, tune flow control params",
            CompressKvTransfers => "Compress KV, shard differently, apply caching policies",
            KvAwareRouting => "Rebuild KV pools; weight LB by queue/KV telemetry from the DPU",
            DrainStragglerReplica => "Drain the straggler replica; respread its sessions",
            RebalancePools => "Shift a replica between prefill/decode roles toward the saturated pool",
            RebalanceHandoffRouting => "Rebalance KV-handoff routing across the decode pool",
            RestartTelemetryExporter => "Restart the node's telemetry exporter; verify agent liveness probes",
            RepairTelemetryPath => "Resize mirror queues, repair the oob export channel, stop event loss",
            PrioritizeTelemetryClass => "Give the telemetry class QoS priority on the congested export path",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_text_nonempty() {
        for d in [
            Directive::SmoothAdmission,
            Directive::EnableInflightRemap,
            Directive::CompressKvTransfers,
        ] {
            assert!(!d.paper_text().is_empty());
        }
    }
}
