//! Closed-loop mitigation: directives (the actionable runbook cells) and the
//! controller that applies them to the live cluster/engine.

pub mod controller;
pub mod directive;

pub use controller::{AppliedAction, Controller};
pub use directive::Directive;
