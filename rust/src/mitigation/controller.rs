//! Mitigation controller: applies runbook directives to the live system —
//! the actuation half of the paper's closed feedback loop (§5).
//!
//! Each directive maps to concrete knob changes on the cluster, fabric,
//! engine policy, or parallel plan. (In a real deployment these would be
//! ncclreconfig / driver / scheduler calls; here they operate the same
//! levers the injectors pathologized.)

use crate::cluster::Cluster;
use crate::dpu::detectors::Detection;
use crate::engine::Engine;
use crate::ids::NodeId;
use crate::mitigation::directive::Directive;
use crate::sim::SimTime;

/// One applied action, for the audit log.
#[derive(Debug, Clone)]
pub struct AppliedAction {
    pub at: SimTime,
    pub directive: Directive,
    pub node: Option<NodeId>,
    pub detail: String,
}

/// The controller: consumes detections, applies directives, keeps a log.
#[derive(Debug, Clone, Default)]
pub struct Controller {
    pub log: Vec<AppliedAction>,
    /// Directives applied at most once per (directive, node) pair.
    applied: std::collections::HashSet<(Directive, Option<NodeId>)>,
    pub enabled: bool,
}

impl Controller {
    pub fn new(enabled: bool) -> Self {
        Controller { log: Vec::new(), applied: Default::default(), enabled }
    }

    /// React to a window's detections. Returns the number of new actions.
    pub fn react(
        &mut self,
        now: SimTime,
        detections: &[Detection],
        cluster: &mut Cluster,
        engine: &mut Engine,
    ) -> usize {
        if !self.enabled {
            return 0;
        }
        let mut applied = 0;
        for det in detections {
            // The detection → directive mapping is catalog knowledge; the
            // node scope is directive knowledge. No condition arms here.
            let directive = crate::conditions::spec(det.condition).directive;
            let node_scope = if directive.node_scoped() { Some(det.node) } else { None };
            if !self.applied.insert((directive, node_scope)) {
                continue; // already applied
            }
            let detail = self.apply(directive, node_scope, cluster, engine);
            self.log.push(AppliedAction { at: now, directive, node: node_scope, detail });
            applied += 1;
        }
        applied
    }

    fn apply(
        &self,
        directive: Directive,
        node: Option<NodeId>,
        cluster: &mut Cluster,
        engine: &mut Engine,
    ) -> String {
        use Directive::*;
        fn node_knobs<'a>(
            c: &'a mut Cluster,
            n: Option<NodeId>,
        ) -> &'a mut crate::cluster::NodeKnobs {
            let idx = n.map(|n| n.idx()).unwrap_or(0);
            &mut c.nodes[idx].knobs
        }
        match directive {
            SmoothAdmission => {
                for r in &mut engine.replicas {
                    r.batcher.policy_mut().queue_cap = r.batcher.policy().queue_cap.max(2048);
                }
                "admission smoothing: deepened queues, paced intake".into()
            }
            RebalanceFlows => {
                engine.router.set_policy(crate::engine::RoutePolicy::LeastLoaded);
                "router switched to least-loaded (affinity hash bypassed)".into()
            }
            FixIngressPath => {
                let k = node_knobs(cluster, node);
                k.nic_rx_loss = 0.0;
                "ingress offloads/MTU fixed: RX loss cleared".into()
            }
            ZeroCopyEgress => {
                let k = node_knobs(cluster, node);
                k.cpu_contention = 1.0;
                k.nic_tx_buffer_factor = 1.0;
                "zero-copy egress: CPU copy removed, TX buffers restored".into()
            }
            PinIrqsIsolateThreads => {
                let k = node_knobs(cluster, node);
                k.egress_jitter = 0.0;
                "IRQs pinned, runtime threads isolated: egress jitter cleared".into()
            }
            FixEgressPath => {
                let k = node_knobs(cluster, node);
                k.nic_tx_loss = 0.0;
                "egress offloads/ECN fixed: TX loss cleared".into()
            }
            EnableInflightRemap => {
                for r in &mut engine.replicas {
                    r.batcher.policy_mut().inflight_remap = true;
                    r.batcher.policy_mut().continuous = true;
                }
                "in-flight remapping enabled: freed decode slots refill".into()
            }
            QosPartitionNic => {
                let k = node_knobs(cluster, node);
                k.nic_background_frac = 0.0;
                "NIC QoS partition: background tenant isolated".into()
            }
            PinMemoryPools => {
                let k = node_knobs(cluster, node);
                k.unpinned_buffers = false;
                k.pinned_pool_frag = false;
                k.h2d_bw_factor = 1.0;
                "pinned pools pre-allocated: staging + fragmentation removed".into()
            }
            FixReturnPath => {
                let k = node_knobs(cluster, node);
                k.d2h_bw_factor = 1.0;
                k.pcie_extra_lat_ns = 0;
                "return path fixed: IOMMU/copy overhead removed".into()
            }
            FuseKernelsIsolateCpu => {
                let k = node_knobs(cluster, node);
                k.kernel_fission = 1;
                k.doorbell_delay_ns = 0;
                k.cpu_contention = 1.0;
                "kernels fused, CPU cores isolated: launch path restored".into()
            }
            RebalanceShards => {
                // Speed-aware shard fractions: give slow GPUs less work.
                for r in &mut engine.replicas {
                    for stage in &mut r.plan.stages {
                        let speeds: Vec<f64> = stage
                            .gpus
                            .iter()
                            .map(|&g| {
                                let n = cluster.spec.node_of_gpu(g);
                                let local = g.idx() % cluster.spec.gpus_per_node;
                                cluster.nodes[n.idx()].knobs.gpu_speed_factor[local].max(0.01)
                            })
                            .collect();
                        let total: f64 = speeds.iter().sum();
                        for (f, s) in stage.shard_frac.iter_mut().zip(&speeds) {
                            *f = s / total;
                        }
                    }
                }
                "shards rebalanced proportional to measured GPU speed".into()
            }
            MovePcieTenants => {
                let k = node_knobs(cluster, node);
                k.pcie_background_load = 0.0;
                "competing DMA tenant moved off the PCIe switch".into()
            }
            PreferNvlink => {
                let k = node_knobs(cluster, node);
                k.p2p_over_pcie = false;
                "P2P restored to NVLink path".into()
            }
            PersistentRegistration => {
                let k = node_knobs(cluster, node);
                k.mem_reg_churn = false;
                "persistent MRs: registration churn removed".into()
            }
            RebalanceStages => {
                for r in &mut engine.replicas {
                    r.plan.rebalance();
                }
                "pipeline stages repartitioned evenly".into()
            }
            RebalanceAcrossNodes => {
                for r in &mut engine.replicas {
                    r.plan.rebalance();
                }
                "activation partitioning realigned across nodes".into()
            }
            AdaptiveRouting => {
                cluster.fabric_knobs.hot_uplink_load = 0.0;
                cluster.fabric_knobs.hot_node = None;
                "adaptive routing: ranks spread off hot uplink".into()
            }
            FixQueueSharing => {
                cluster.fabric_knobs.hol_blocking = false;
                "per-flow queues restored: HOL blocking removed".into()
            }
            LosslessFabricConfig => {
                cluster.fabric_knobs.loss_prob = 0.0;
                "PFC/ECN verified: fabric loss cleared".into()
            }
            TuneCreditWindow => {
                cluster.fabric_knobs.credit_window = cluster.fabric_knobs.credit_window.max(64);
                "QP window raised: credit starvation cleared".into()
            }
            CompressKvTransfers => {
                cluster.fabric_knobs.kv_link_budget_factor =
                    cluster.fabric_knobs.kv_link_budget_factor.max(1.0);
                // The prefill→decode handoff is a KV transfer too (PD2's
                // stalled pool-boundary link rides the same directive).
                cluster.fabric_knobs.handoff_budget_factor =
                    cluster.fabric_knobs.handoff_budget_factor.max(1.0);
                "KV compressed/resharded to fit link budget".into()
            }
            KvAwareRouting => {
                for r in &mut engine.replicas {
                    r.kv.restore_capacity();
                }
                engine.router.set_policy(crate::engine::RoutePolicy::WeightedTelemetry);
                "KV pools rebuilt; router weighted by queue/KV telemetry".into()
            }
            DrainStragglerReplica => {
                match node.and_then(|n| engine.replica_of_node(n)) {
                    Some(ri) => {
                        engine.router.set_drained(ri, true);
                        format!("replica {ri} drained from rotation (straggler)")
                    }
                    None => "straggler replica unresolved; no drain applied".into(),
                }
            }
            RebalancePools => {
                // Move the least-loaded decode-only replica into the prefill
                // pool — but never the last one (the decode pool must stay
                // serviceable).
                let decode_only: Vec<usize> = engine
                    .roles()
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| **r == crate::cluster::ReplicaRole::Decode)
                    .map(|(i, _)| i)
                    .collect();
                if decode_only.len() >= 2 && engine.decode_router.members().len() >= 2 {
                    let spare = *decode_only
                        .iter()
                        .min_by_key(|&&r| engine.decode_router.outstanding()[r])
                        .unwrap();
                    engine.shift_role(spare, crate::cluster::ReplicaRole::Prefill);
                    format!("replica {spare} reassigned decode→prefill (pool rebalanced)")
                } else {
                    "no spare decode replica; pools unchanged".into()
                }
            }
            RebalanceHandoffRouting => {
                engine.decode_router.set_pin(None);
                engine.decode_router.clear_overrides();
                engine
                    .decode_router
                    .set_policy(crate::engine::RoutePolicy::LeastLoaded);
                "handoff routing unwedged: pin cleared, decode pool balanced by load".into()
            }
            // The three TD directives all clear the victim node's telemetry
            // fault mode — the distinct real-world action (restart the
            // exporter / repair the channel / reprioritize the class) is the
            // directive text; the lever is the same knob the injector set.
            // Recovery of the router's fallback ladder then happens on its
            // own through the freshness watchdog's hysteresis.
            RestartTelemetryExporter => {
                let idx = node.map(|n| n.idx()).unwrap_or(0);
                cluster.tele_faults[idx] = crate::telemetry::faults::TeleFaultMode::None;
                "telemetry exporter restarted: signal flowing again".into()
            }
            RepairTelemetryPath => {
                let idx = node.map(|n| n.idx()).unwrap_or(0);
                cluster.tele_faults[idx] = crate::telemetry::faults::TeleFaultMode::None;
                "telemetry export channel repaired: event loss stopped".into()
            }
            PrioritizeTelemetryClass => {
                let idx = node.map(|n| n.idx()).unwrap_or(0);
                cluster.tele_faults[idx] = crate::telemetry::faults::TeleFaultMode::None;
                "telemetry class prioritized: delivery backlog drains".into()
            }
        }
    }

    pub fn actions_taken(&self) -> usize {
        self.log.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::dpu::detectors::Condition;
    use crate::engine::{build_replicas, EngineConfig};

    fn setup() -> (Cluster, Engine) {
        let cfg = EngineConfig::default();
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, cfg.nodes_per_stage);
        (Cluster::new(spec, 1), Engine::new(cfg, plans))
    }

    fn det(c: Condition, node: u32) -> Detection {
        Detection {
            condition: c,
            node: NodeId(node),
            at: SimTime(0),
            severity: 5.0,
            evidence: String::new(),
        }
    }

    #[test]
    fn reacts_once_per_directive_and_node() {
        let (mut cluster, mut engine) = setup();
        cluster.nodes[1].knobs.nic_rx_loss = 0.2;
        let mut ctl = Controller::new(true);
        let d = det(Condition::Ns4IngressRetx, 1);
        assert_eq!(ctl.react(SimTime(0), &[d.clone()], &mut cluster, &mut engine), 1);
        assert_eq!(cluster.nodes[1].knobs.nic_rx_loss, 0.0);
        // Re-fire: no duplicate action.
        assert_eq!(ctl.react(SimTime(1), &[d], &mut cluster, &mut engine), 0);
        assert_eq!(ctl.actions_taken(), 1);
    }

    #[test]
    fn disabled_controller_does_nothing() {
        let (mut cluster, mut engine) = setup();
        cluster.fabric_knobs.loss_prob = 0.1;
        let mut ctl = Controller::new(false);
        ctl.react(SimTime(0), &[det(Condition::Ew6Retransmissions, 0)], &mut cluster, &mut engine);
        assert_eq!(cluster.fabric_knobs.loss_prob, 0.1);
    }

    #[test]
    fn shard_rebalance_is_speed_aware() {
        let (mut cluster, mut engine) = setup();
        cluster.nodes[0].knobs.gpu_speed_factor[0] = 0.25; // GPU0 4x slower
        let mut ctl = Controller::new(true);
        ctl.react(SimTime(0), &[det(Condition::Ew1TpStraggler, 0)], &mut cluster, &mut engine);
        let stage0 = &engine.replicas[0].plan.stages[0];
        // GPU0's shard must now be the smallest.
        let f0 = stage0.shard_frac[0];
        assert!(stage0.shard_frac[1..].iter().all(|&f| f > f0), "{:?}", stage0.shard_frac);
        engine.replicas[0].plan.check().unwrap();
    }

    #[test]
    fn dp_directives_drain_and_reroute() {
        // Two replicas (single-node stages) so DP directives have a fleet.
        let mut cfg = EngineConfig::default();
        cfg.nodes_per_stage = 1;
        let spec = ClusterSpec::default();
        let plans = build_replicas(&spec, 1);
        let mut engine = Engine::new(cfg, plans);
        let mut cluster = Cluster::new(ClusterSpec::default(), 1);
        engine.replicas[1].kv.restrict_to(0.05);
        let mut ctl = Controller::new(true);
        // DP3 on replica 1's entry node drains that replica.
        let entry = engine.replicas[1].plan.entry_nodes()[0];
        ctl.react(
            SimTime(0),
            &[det(Condition::Dp3StragglerReplica, entry.0)],
            &mut cluster,
            &mut engine,
        );
        assert!(engine.router.is_drained(1));
        assert!(!engine.router.is_drained(0));
        // DP2 restores KV capacity and switches to telemetry routing.
        ctl.react(
            SimTime(1),
            &[det(Condition::Dp2HotReplicaKv, entry.0)],
            &mut cluster,
            &mut engine,
        );
        assert!(!engine.replicas[1].kv.is_restricted());
        assert_eq!(engine.router.policy(), crate::engine::RoutePolicy::WeightedTelemetry);
    }

    #[test]
    fn pd_directives_rebalance_pools_and_handoff_routing() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 6;
        let shapes = vec![
            ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ];
        let mut ecfg = EngineConfig::default();
        ecfg.shapes = Some(shapes.clone());
        let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
        let mut engine = Engine::new(ecfg, plans);
        let mut cluster = Cluster::new(spec, 1);
        // PD3's wedge, then its mitigation unwedges the decode router.
        engine.decode_router.set_pin(Some(1));
        let mut ctl = Controller::new(true);
        ctl.react(
            SimTime(0),
            &[det(Condition::Pd3DecodeStarvation, 2)],
            &mut cluster,
            &mut engine,
        );
        assert_eq!(engine.decode_router.pin(), None);
        assert_eq!(engine.decode_router.policy(), crate::engine::RoutePolicy::LeastLoaded);
        // PD1's mitigation shifts a spare decode replica into the prefill
        // pool, leaving the decode pool non-empty.
        ctl.react(
            SimTime(1),
            &[det(Condition::Pd1PrefillSaturation, 0)],
            &mut cluster,
            &mut engine,
        );
        assert_eq!(engine.router.members().len(), 2, "{:?}", engine.roles());
        assert_eq!(engine.decode_router.members().len(), 1);
        // PD2's directive restores the handoff link budget.
        cluster.fabric_knobs.handoff_budget_factor = 0.2;
        ctl.react(
            SimTime(2),
            &[det(Condition::Pd2KvHandoffStall, 2)],
            &mut cluster,
            &mut engine,
        );
        assert_eq!(cluster.fabric_knobs.handoff_budget_factor, 1.0);
    }

    #[test]
    fn rebalance_pools_never_empties_the_decode_pool() {
        use crate::cluster::{ReplicaRole, ReplicaShape};
        let mut spec = ClusterSpec::default();
        spec.n_nodes = 4;
        let shapes = vec![
            ReplicaShape::new(ReplicaRole::Prefill, 8, 1),
            ReplicaShape::new(ReplicaRole::Decode, 4, 2),
        ];
        let mut ecfg = EngineConfig::default();
        ecfg.shapes = Some(shapes.clone());
        let plans = crate::engine::build_shaped_replicas(&spec, &shapes);
        let mut engine = Engine::new(ecfg, plans);
        let mut cluster = Cluster::new(spec, 1);
        let mut ctl = Controller::new(true);
        ctl.react(
            SimTime(0),
            &[det(Condition::Pd1PrefillSaturation, 0)],
            &mut cluster,
            &mut engine,
        );
        assert_eq!(engine.decode_router.members(), &[1], "sole decode replica must stay");
    }

    #[test]
    fn td_directives_clear_the_node_fault_mode() {
        use crate::telemetry::faults::TeleFaultMode;
        let (mut cluster, mut engine) = setup();
        cluster.tele_faults[1] = TeleFaultMode::Freeze;
        cluster.tele_faults[2] = TeleFaultMode::Drop { p: 0.75 };
        cluster.tele_faults[3] = TeleFaultMode::Lag { windows: 6 };
        let mut ctl = Controller::new(true);
        ctl.react(SimTime(0), &[det(Condition::Td1StaleFrozen, 1)], &mut cluster, &mut engine);
        assert!(cluster.tele_faults[1].is_none(), "TD1 directive restarts the exporter");
        assert!(!cluster.tele_faults[2].is_none(), "other nodes' faults untouched");
        ctl.react(SimTime(1), &[det(Condition::Td2LossyDrop, 2)], &mut cluster, &mut engine);
        assert!(cluster.tele_faults[2].is_none(), "TD2 directive repairs the path");
        ctl.react(SimTime(2), &[det(Condition::Td3LaggingDelivery, 3)], &mut cluster, &mut engine);
        assert!(cluster.tele_faults[3].is_none(), "TD3 directive reprioritizes the class");
        assert_eq!(ctl.actions_taken(), 3);
    }

    #[test]
    fn remap_directive_flips_engine_policy() {
        let (mut cluster, mut engine) = setup();
        for r in &mut engine.replicas {
            r.batcher.policy_mut().inflight_remap = false;
        }
        let mut ctl = Controller::new(true);
        ctl.react(SimTime(0), &[det(Condition::Ns8EarlyCompletion, 0)], &mut cluster, &mut engine);
        assert!(engine.replicas.iter().all(|r| r.batcher.policy().inflight_remap));
    }
}
