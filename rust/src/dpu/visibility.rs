//! §4.3 visibility boundary, as a standalone filter with accounting.
//!
//! The DPU sits inline with the NIC and as a PCIe peer: it observes all
//! NIC traffic and all root-complex DMA/doorbell activity, but it CANNOT see
//! intra-GPU kernels, NVLink/NVSwitch collectives, or CPU-only work. The
//! filter here is the single place that boundary is decided; `Agent::ingest`
//! applies it, and the E5 negative controls verify it end to end.

use crate::telemetry::event::TelemetryEvent;

/// Split events into (dpu_visible, invisible).
pub fn partition(events: Vec<TelemetryEvent>) -> (Vec<TelemetryEvent>, Vec<TelemetryEvent>) {
    events.into_iter().partition(|e| e.kind.dpu_visible())
}

/// Visibility accounting over a stream.
#[derive(Debug, Clone, Default)]
pub struct VisibilityStats {
    pub visible: u64,
    pub invisible: u64,
    pub invisible_by_class: std::collections::BTreeMap<&'static str, u64>,
}

impl VisibilityStats {
    pub fn observe(&mut self, ev: &TelemetryEvent) {
        if ev.kind.dpu_visible() {
            self.visible += 1;
        } else {
            self.invisible += 1;
            *self.invisible_by_class.entry(ev.kind.class()).or_insert(0) += 1;
        }
    }

    /// Fraction of the total stream a DPU can see.
    pub fn coverage(&self) -> f64 {
        let total = self.visible + self.invisible;
        if total == 0 {
            return 1.0;
        }
        self.visible as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{GpuId, NodeId};
    use crate::sim::SimTime;
    use crate::telemetry::event::TelemetryKind;

    fn ev(kind: TelemetryKind) -> TelemetryEvent {
        TelemetryEvent { t: SimTime(0), node: NodeId(0), kind }
    }

    #[test]
    fn partition_and_stats_agree() {
        let events = vec![
            ev(TelemetryKind::Doorbell { gpu: GpuId(0) }),
            ev(TelemetryKind::NvlinkBurst { from: GpuId(0), to: GpuId(1), bytes: 8 }),
            ev(TelemetryKind::GpuKernel { gpu: GpuId(0), dur_ns: 5, flops: 1.0 }),
            ev(TelemetryKind::CpuLocal { dur_ns: 5 }),
        ];
        let mut stats = VisibilityStats::default();
        for e in &events {
            stats.observe(e);
        }
        let (vis, invis) = partition(events);
        assert_eq!(vis.len(), 1);
        assert_eq!(invis.len(), 3);
        assert_eq!(stats.visible, 1);
        assert_eq!(stats.invisible, 3);
        assert!((stats.coverage() - 0.25).abs() < 1e-12);
        assert_eq!(stats.invisible_by_class.len(), 3);
    }
}
