//! Telemetry window scoring: the feature/z-score computation that the paper
//! positions as offloadable to the DPU's own compute.
//!
//! Two interchangeable backends:
//! * [`NativeScorer`] — plain Rust (what BlueField ARM cores would run).
//! * `runtime::CompiledScorer` — the AOT-compiled Pallas kernel
//!   (`artifacts/detector.hlo.txt`) executed via PJRT, implementing the same
//!   [`ScorerBackend`] trait; pytest + an integration test pin both to the
//!   same numbers.
//!
//! Feature order contract (must match `python/compile/kernels/scorer.py`):
//! `0 mean, 1 std, 2 max, 3 min, 4 cov, 5 burstiness, 6 spread, 7 z`.

pub const N_FEATURES: usize = 8;
const EPS: f32 = 1e-6;

/// Scores batches of raw telemetry windows.
pub trait ScorerBackend {
    /// windows: W rows of N samples; baseline: W rows of (mean, std).
    /// Returns (features `[W][8]`, z `[W]`).
    fn score(
        &mut self,
        windows: &[Vec<f32>],
        baseline: &[(f32, f32)],
    ) -> (Vec<[f32; N_FEATURES]>, Vec<f32>);

    fn name(&self) -> &'static str;
}

/// Pure-Rust scorer; mirrors the Pallas kernel arithmetic exactly.
#[derive(Debug, Default)]
pub struct NativeScorer;

impl ScorerBackend for NativeScorer {
    fn score(
        &mut self,
        windows: &[Vec<f32>],
        baseline: &[(f32, f32)],
    ) -> (Vec<[f32; N_FEATURES]>, Vec<f32>) {
        assert_eq!(windows.len(), baseline.len());
        let mut feats = Vec::with_capacity(windows.len());
        let mut zs = Vec::with_capacity(windows.len());
        for (row, &(bmean, bstd)) in windows.iter().zip(baseline) {
            let n = row.len().max(1) as f32;
            let mean = row.iter().sum::<f32>() / n;
            let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n;
            let std = var.sqrt();
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mn = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let cov = std / (mean.abs() + EPS);
            let burst = mx / (mean.abs() + EPS);
            let spread = mx - mn;
            let z = (mean - bmean) / (bstd + EPS);
            feats.push([mean, std, mx, mn, cov, burst, spread, z]);
            zs.push(z);
        }
        (feats, zs)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pack a ragged set of telemetry series into fixed-shape scorer input
/// (pad/truncate each series to `n_samples`); used when feeding the
/// compiled kernel, whose shapes are baked at AOT time.
pub fn pack_windows(series: &[Vec<f32>], n_samples: usize) -> Vec<Vec<f32>> {
    series
        .iter()
        .map(|s| {
            let mut row = s.clone();
            row.truncate(n_samples);
            // Pad with the series mean so padding doesn't shift features.
            let pad = if row.is_empty() { 0.0 } else { row.iter().sum::<f32>() / row.len() as f32 };
            while row.len() < n_samples {
                row.push(pad);
            }
            row
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_scorer_matches_hand_math() {
        let mut s = NativeScorer;
        let (f, z) = s.score(&[vec![1.0, 2.0, 3.0, 6.0]], &[(2.0, 1.0)]);
        let row = f[0];
        assert!((row[0] - 3.0).abs() < 1e-5); // mean
        assert!((row[2] - 6.0).abs() < 1e-5); // max
        assert!((row[3] - 1.0).abs() < 1e-5); // min
        assert!((row[6] - 5.0).abs() < 1e-5); // spread
        assert!((z[0] - 1.0).abs() < 1e-4); // (3-2)/(1+eps)
        assert!((row[7] - z[0]).abs() < 1e-6);
    }

    #[test]
    fn pack_pads_with_mean() {
        let packed = pack_windows(&[vec![2.0, 4.0]], 4);
        assert_eq!(packed[0], vec![2.0, 4.0, 3.0, 3.0]);
        let truncated = pack_windows(&[vec![1.0; 10]], 4);
        assert_eq!(truncated[0].len(), 4);
    }

    #[test]
    fn constant_window_zero_variance() {
        let mut s = NativeScorer;
        let (f, _) = s.score(&[vec![5.0; 16]], &[(5.0, 1.0)]);
        assert!(f[0][1].abs() < 1e-6); // std
        assert!(f[0][6].abs() < 1e-6); // spread
    }
}
