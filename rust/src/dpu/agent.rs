//! The DPU plane: one agent per node, each a bump-in-the-wire observer with
//! the §4.3 visibility boundary enforced, plus the shared detector registry,
//! calibration, and the detection log.

use crate::dpu::detectors::{
    all_detectors, Baseline, Condition, DetectConfig, DetectCtx, Detection, Detector,
};
use crate::ids::NodeId;
use crate::sim::SimTime;
use crate::telemetry::event::{TelemetryEvent, TelemetryKind};
use crate::telemetry::window::{WindowAccum, WindowSnapshot};
use crate::telemetry::TelemetryBus;

/// Snapshots of history kept per agent for trend detectors.
const HISTORY_DEPTH: usize = 8;
/// Confirmation hysteresis: a detection is reported when the condition
/// fires in 2 windows within any 3-window span (kills one-window noise
/// without suppressing intermittent-but-real anomalies).
const CONFIRM_SPAN: u64 = 3;

/// One node's DPU agent.
#[derive(Debug, Clone)]
pub struct Agent {
    pub node: NodeId,
    accum: WindowAccum,
    pub baseline: Baseline,
    history: Vec<WindowSnapshot>,
    /// Events rejected by the §4.3 visibility boundary.
    pub invisible_dropped: u64,
    pub events_ingested: u64,
    /// Last window index each condition fired in (confirmation hysteresis).
    last_fired: std::collections::HashMap<Condition, u64>,
    window_idx: u64,
}

impl Agent {
    pub fn new(node: NodeId, n_gpus: usize, n_nodes_hint: usize) -> Self {
        Agent {
            node,
            accum: WindowAccum::with_hints(node, n_gpus, n_nodes_hint),
            baseline: Baseline::new(),
            history: Vec::with_capacity(HISTORY_DEPTH),
            invisible_dropped: 0,
            events_ingested: 0,
            last_fired: std::collections::HashMap::new(),
            window_idx: 0,
        }
    }

    /// Ingest a batch of events, applying the DPU visibility filter.
    pub fn ingest(&mut self, events: &[TelemetryEvent]) {
        for ev in events {
            if !ev.kind.dpu_visible() {
                self.invisible_dropped += 1;
                continue;
            }
            self.events_ingested += 1;
            self.accum.ingest(ev);
        }
    }

    /// Advance the window: close the accumulator into a new history entry.
    /// The evicted oldest snapshot's heap buffers are recycled into the
    /// accumulator, so a steady-state tick allocates nothing (and the old
    /// per-tick snapshot clone is gone — observers borrow from history).
    fn roll_window(&mut self, now: SimTime) {
        let spare = if self.history.len() == HISTORY_DEPTH {
            Some(self.history.remove(0))
        } else {
            None
        };
        let snap = self.accum.snapshot_reusing(now, spare);
        self.history.push(snap);
    }

    /// Close the current window; returns the snapshot (the history's
    /// newest entry).
    pub fn tick(&mut self, now: SimTime) -> &WindowSnapshot {
        self.roll_window(now);
        self.history.last().expect("roll_window pushed")
    }

    pub fn history(&self) -> &[WindowSnapshot] {
        &self.history
    }
}

/// The whole DPU observability plane.
pub struct DpuPlane {
    pub agents: Vec<Agent>,
    detectors: Vec<Box<dyn Detector>>,
    pub cfg: DetectConfig,
    calibrating: bool,
    /// Windows discarded before calibration starts (startup transient).
    pub warmup_windows: u64,
    /// Full detection log (node-attributed, timestamped).
    pub detections: Vec<Detection>,
    pub windows_processed: u64,
    /// Worker threads for the per-window observe fan-out (`util::par`
    /// semantics: 0 = auto, 1 = serial). Per-agent work is independent and
    /// results reduce in agent order, so the thread count never changes a
    /// result — scenario sweeps keep the default 1 (the cells themselves
    /// parallelize); fleet-stress worlds raise it.
    pub observe_threads: usize,
}

/// Snapshot/fork support: detectors are stateless registry entries (all
/// per-node state lives in the agents), so a clone rebuilds the registry
/// via [`all_detectors`] instead of copying trait objects.
impl Clone for DpuPlane {
    fn clone(&self) -> Self {
        DpuPlane {
            agents: self.agents.clone(),
            detectors: all_detectors(),
            cfg: self.cfg.clone(),
            calibrating: self.calibrating,
            warmup_windows: self.warmup_windows,
            detections: self.detections.clone(),
            windows_processed: self.windows_processed,
            observe_threads: self.observe_threads,
        }
    }
}

impl std::fmt::Debug for DpuPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DpuPlane")
            .field("agents", &self.agents.len())
            .field("detections", &self.detections.len())
            .field("calibrating", &self.calibrating)
            .finish()
    }
}

impl DpuPlane {
    pub fn new(n_nodes: usize, gpus_per_node: usize, cfg: DetectConfig) -> Self {
        DpuPlane {
            agents: (0..n_nodes)
                .map(|n| Agent::new(NodeId(n as u32), gpus_per_node, n_nodes))
                .collect(),
            detectors: all_detectors(),
            cfg,
            calibrating: true,
            warmup_windows: 50,
            detections: Vec::new(),
            windows_processed: 0,
            observe_threads: 1,
        }
    }

    pub fn is_calibrating(&self) -> bool {
        self.calibrating
    }

    /// End the calibration phase; baselines freeze, detectors go live.
    pub fn go_live(&mut self) {
        for a in &mut self.agents {
            a.baseline.freeze();
        }
        self.calibrating = false;
    }

    /// Route drained telemetry to the owning agent.
    pub fn ingest(&mut self, node: NodeId, events: &[TelemetryEvent]) {
        self.agents[node.idx()].ingest(events);
    }

    /// Parallel single-dispatch fan-out: each node's due telemetry is
    /// sorted, consumed by its own agent, and drained on the observe pool
    /// (`observe_threads`; 1 = plain serial loop). Per-node work is
    /// independent and the delivery accounting reduces by integer sums, so
    /// the result is byte-identical to a serial
    /// [`TelemetryBus::deliver_due`] + [`DpuPlane::ingest`] sweep for any
    /// thread count.
    pub fn ingest_due_parallel(&mut self, bus: &mut TelemetryBus, now: SimTime) {
        let threads = self.observe_threads;
        let bufs = bus.pending_buffers_mut();
        debug_assert_eq!(bufs.len(), self.agents.len(), "one bus buffer per agent");
        let per_node = crate::util::par::parallel_zip_mut(
            &mut self.agents,
            bufs,
            threads,
            |_, agent, buf| {
                let mut counts = (0u64, [0u64; TelemetryKind::N_CLASSES]);
                if buf.is_empty() {
                    return counts;
                }
                let due = crate::telemetry::bus::sort_and_partition(buf, now);
                if due == 0 {
                    return counts;
                }
                counts.0 = due as u64;
                for ev in &buf[..due] {
                    counts.1[ev.kind.class_id()] += 1;
                }
                agent.ingest(&buf[..due]);
                buf.drain(..due);
                counts
            },
        );
        let mut total = 0u64;
        let mut classes = [0u64; TelemetryKind::N_CLASSES];
        for (t, c) in per_node {
            total += t;
            for (acc, n) in classes.iter_mut().zip(c.iter()) {
                *acc += n;
            }
        }
        bus.commit_delivered(total, &classes);
    }

    /// Window tick across all agents: snapshot, then calibrate or detect.
    /// Returns the detections fired this tick. Fans out across the observe
    /// pool; per-agent results concatenate in agent order, so any thread
    /// count reproduces the serial detection sequence exactly.
    pub fn window_tick(&mut self, now: SimTime) -> Vec<Detection> {
        let in_warmup = self.calibrating
            && self.windows_processed < self.warmup_windows * self.agents.len() as u64;
        let calibrating = self.calibrating;
        // Hoisted off the per-agent path (and the parallel workers).
        let debug = std::env::var("DPULENS_DEBUG").is_ok();
        let detectors = &self.detectors;
        let cfg = &self.cfg;
        let per_agent = crate::util::par::parallel_map_mut(
            &mut self.agents,
            self.observe_threads,
            |_, a| Self::agent_window_tick(a, now, in_warmup, calibrating, debug, detectors, cfg),
        );
        self.windows_processed += self.agents.len() as u64;
        let fired: Vec<Detection> = per_agent.into_iter().flatten().collect();
        self.detections.extend(fired.iter().cloned());
        fired
    }

    /// One agent's share of a window tick: roll the window, then calibrate
    /// or detect against the agent-local baseline/history. Touches nothing
    /// outside `a`, which is what makes the fan-out deterministic.
    fn agent_window_tick(
        a: &mut Agent,
        now: SimTime,
        in_warmup: bool,
        calibrating: bool,
        debug: bool,
        detectors: &[Box<dyn Detector>],
        cfg: &DetectConfig,
    ) -> Vec<Detection> {
        a.roll_window(now);
        let mut fired = Vec::new();
        if in_warmup {
            // Startup transient: observe nothing.
            return fired;
        }
        if calibrating {
            let (hist, baseline) = (&a.history, &mut a.baseline);
            let snap = hist.last().expect("just rolled");
            for d in detectors {
                d.calibrate(snap, baseline);
            }
            baseline.end_window();
            return fired;
        }
        {
            // History excludes the snapshot just taken (it's the last
            // element) so trend detectors compare against the past.
            let hist_len = a.history.len().saturating_sub(1);
            let snap = a.history.last().expect("just rolled");
            let ctx = DetectCtx {
                snap,
                baseline: &a.baseline,
                history: &a.history[..hist_len],
                cfg,
            };
            if debug && snap.node.0 <= 3 {
                eprintln!(
                    "[dbg n{} t={}ms] h2d_rate={:.0} z={:.2} db2h={:.0}us z={:.2} beyond={:.2} busy={:.2} | hgap={:.0}us z={:.2} beyond={:.2} cnt={} | ends={} ratio={:.2} z={:.2} act={}",
                    snap.node.0, now.ns()/1_000_000,
                    snap.h2d_rate(), a.baseline.z("pc8.h2d_rate", snap.h2d_rate()),
                    snap.h2d_to_doorbell_ns.mean()/1e3, a.baseline.z("pc8.h2d_to_db", snap.h2d_to_doorbell_ns.mean()),
                    a.baseline.above_max("pc8.h2d_to_db", snap.h2d_to_doorbell_ns.mean()),
                    snap.pcie_busy.mean(),
                    snap.handoff_gap_ns.mean()/1e3, a.baseline.z("ew2.handoff_gap", snap.handoff_gap_ns.mean()),
                    a.baseline.above_max("ew2.handoff_gap", snap.handoff_gap_ns.mean()),
                    snap.handoff_count,
                    snap.flow_ends, snap.end_len_ratio, a.baseline.z("ns8.end_ratio", snap.end_len_ratio),
                    snap.active_flows,
                );
                eprintln!(
                    "[dbg2 n{} t={}ms] span={:.0}us n={} z={:.2} beyond={:.2} | d2h_dec_bytes={:.0} z={:.2} cnt={}",
                    snap.node.0, now.ns()/1_000_000,
                    snap.db_to_handoff_ns.mean()/1e3, snap.db_to_handoff_ns.count(),
                    a.baseline.z("ew2.stage_span", snap.db_to_handoff_ns.mean()),
                    a.baseline.above_max("ew2.stage_span", snap.db_to_handoff_ns.mean()),
                    snap.d2h.decode_bytes.mean(),
                    a.baseline.z("pc10.decode_bytes", snap.d2h.decode_bytes.mean()),
                    snap.d2h.decode_count,
                );
            }
            let mut this_window: Vec<Detection> = Vec::new();
            for d in detectors {
                if let Some(det) = d.check(&ctx) {
                    this_window.push(det);
                }
            }
            // Confirmation hysteresis: report when the condition fired
            // twice within a CONFIRM_SPAN-window span on this node.
            a.window_idx += 1;
            for det in this_window {
                let prev = a.last_fired.insert(det.condition, a.window_idx);
                if let Some(p) = prev {
                    if a.window_idx - p < CONFIRM_SPAN {
                        fired.push(det);
                    }
                }
            }
        }
        fired
    }

    /// Detection counts per condition (reporting).
    pub fn counts_by_condition(&self) -> std::collections::BTreeMap<Condition, usize> {
        let mut m = std::collections::BTreeMap::new();
        for d in &self.detections {
            *m.entry(d.condition).or_insert(0) += 1;
        }
        m
    }

    /// First detection of a condition at/after `t0` (detection latency).
    pub fn first_detection_after(&self, c: Condition, t0: SimTime) -> Option<&Detection> {
        self.detections.iter().filter(|d| d.condition == c && d.at >= t0).min_by_key(|d| d.at)
    }

    /// Total events the visibility boundary rejected (§4.3 proof).
    pub fn total_invisible_dropped(&self) -> u64 {
        self.agents.iter().map(|a| a.invisible_dropped).sum()
    }

    pub fn total_ingested(&self) -> u64 {
        self.agents.iter().map(|a| a.events_ingested).sum()
    }

    pub fn clear_detections(&mut self) {
        self.detections.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::GpuId;
    use crate::telemetry::event::{Phase, TelemetryKind};

    fn h2d_ev(t: u64, node: u32, lat: u64) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::DmaH2d {
                gpu: GpuId(0),
                bytes: 65536,
                latency_ns: lat,
                phase: Phase::Prefill,
            },
        }
    }

    fn invisible_ev(t: u64, node: u32) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::GpuKernel { gpu: GpuId(0), dur_ns: 100, flops: 1.0 },
        }
    }

    #[test]
    fn visibility_boundary_enforced() {
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.ingest(NodeId(0), &[h2d_ev(1, 0, 100), invisible_ev(2, 0), invisible_ev(3, 0)]);
        assert_eq!(plane.total_ingested(), 1);
        assert_eq!(plane.total_invisible_dropped(), 2);
    }

    #[test]
    fn calibrate_then_detect_pc2() {
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.warmup_windows = 0;
        // Calibration: 20 healthy windows of D2H at ~3us.
        for w in 0..20u64 {
            let base = w * 1_000_000;
            for i in 0..10u64 {
                plane.ingest(
                    NodeId(0),
                    &[TelemetryEvent {
                        t: SimTime(base + i * 50_000),
                        node: NodeId(0),
                        kind: TelemetryKind::DmaD2h {
                            gpu: GpuId(0),
                            bytes: 4096,
                            latency_ns: 3_000 + (i % 3) * 100,
                            phase: Phase::Decode,
                        },
                    }],
                );
            }
            let fired = plane.window_tick(SimTime(base + 1_000_000));
            assert!(fired.is_empty(), "no detections during calibration");
        }
        plane.go_live();
        // Healthy window: no fire.
        for i in 0..10u64 {
            plane.ingest(
                NodeId(0),
                &[TelemetryEvent {
                    t: SimTime(20_000_000 + i * 50_000),
                    node: NodeId(0),
                    kind: TelemetryKind::DmaD2h {
                        gpu: GpuId(0),
                        bytes: 4096,
                        latency_ns: 3_100,
                        phase: Phase::Decode,
                    },
                }],
            );
        }
        let fired = plane.window_tick(SimTime(21_000_000));
        assert!(
            !fired.iter().any(|d| d.condition == Condition::Pc2D2hBottleneck),
            "healthy window must not fire PC2: {fired:?}"
        );
        // Pathological: slow D2H across two windows (confirmation).
        let mut fired_any = Vec::new();
        for w in 0..2u64 {
            let base = 21_000_000 + w * 1_000_000;
            for i in 0..10u64 {
                plane.ingest(
                    NodeId(0),
                    &[TelemetryEvent {
                        t: SimTime(base + i * 50_000),
                        node: NodeId(0),
                        kind: TelemetryKind::DmaD2h {
                            gpu: GpuId(0),
                            bytes: 4096,
                            latency_ns: 90_000,
                            phase: Phase::Decode,
                        },
                    }],
                );
            }
            fired_any.extend(plane.window_tick(SimTime(base + 1_000_000)));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pc2D2hBottleneck),
            "slow D2H must fire PC2, got {fired_any:?}"
        );
        assert!(plane.first_detection_after(Condition::Pc2D2hBottleneck, SimTime(21_000_000)).is_some());
    }

    fn d2h_ev(t: u64, node: u32, lat: u64) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::DmaD2h {
                gpu: GpuId(0),
                bytes: 4096,
                latency_ns: lat,
                phase: Phase::Decode,
            },
        }
    }

    /// The parallel observe fan-out (sorted bus buffers → per-node agents →
    /// per-agent window ticks) must reproduce the serial
    /// `deliver_due` + `ingest` + `window_tick` path exactly, for any
    /// thread count.
    #[test]
    fn parallel_observe_path_matches_serial() {
        let run = |threads: usize, parallel_path: bool| {
            let mut plane = DpuPlane::new(6, 4, DetectConfig::default());
            plane.warmup_windows = 0;
            plane.observe_threads = threads;
            let mut bus = TelemetryBus::new(6);
            for w in 0..26u64 {
                let base = w * 1_000_000;
                // Healthy D2H during calibration; nodes 0-2 turn slow after
                // go-live so real detections flow through both paths.
                let lat = if w >= 21 { 90_000 } else { 3_000 };
                for n in 0..6u32 {
                    let node_lat = if n <= 2 { lat } else { 3_000 };
                    for i in 0..10u64 {
                        bus.enqueue(d2h_ev(base + i * 50_000 + n as u64, n, node_lat));
                    }
                }
                let now = SimTime(base + 1_000_000);
                if parallel_path {
                    plane.ingest_due_parallel(&mut bus, now);
                } else {
                    let p = &mut plane;
                    bus.deliver_due(now, |node, evs| p.ingest(node, evs));
                }
                plane.window_tick(now);
                if w == 20 {
                    plane.go_live();
                }
            }
            (
                plane.counts_by_condition(),
                plane.total_ingested(),
                plane.windows_processed,
                bus.total_published(),
                bus.class_counts().to_vec(),
            )
        };
        let serial = run(1, false);
        assert!(!serial.0.is_empty(), "the fixture must produce detections");
        for threads in [1, 2, 8] {
            assert_eq!(run(threads, true), serial, "threads={threads}");
        }
    }

    #[test]
    fn invisible_events_cannot_trigger_anything() {
        // NVLink-only anomaly: the DPU plane must stay silent (§4.3).
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.warmup_windows = 0;
        for w in 0..10u64 {
            plane.window_tick(SimTime((w + 1) * 1_000_000));
        }
        plane.go_live();
        for i in 0..1000u64 {
            plane.ingest(NodeId(0), &[invisible_ev(11_000_000 + i, 0)]);
        }
        let fired = plane.window_tick(SimTime(12_000_000));
        assert!(fired.is_empty());
        assert_eq!(plane.total_invisible_dropped(), 1000);
    }
}
