//! The DPU plane: one agent per node, each a bump-in-the-wire observer with
//! the §4.3 visibility boundary enforced, plus the shared detector registry,
//! calibration, and the detection log.

use crate::dpu::detectors::{
    all_detectors, Baseline, Condition, DetectConfig, DetectCtx, Detection, Detector,
};
use crate::ids::NodeId;
use crate::sim::SimTime;
use crate::telemetry::event::TelemetryEvent;
use crate::telemetry::window::{WindowAccum, WindowSnapshot};

/// Snapshots of history kept per agent for trend detectors.
const HISTORY_DEPTH: usize = 8;
/// Confirmation hysteresis: a detection is reported when the condition
/// fires in 2 windows within any 3-window span (kills one-window noise
/// without suppressing intermittent-but-real anomalies).
const CONFIRM_SPAN: u64 = 3;

/// One node's DPU agent.
#[derive(Debug)]
pub struct Agent {
    pub node: NodeId,
    accum: WindowAccum,
    pub baseline: Baseline,
    history: Vec<WindowSnapshot>,
    /// Events rejected by the §4.3 visibility boundary.
    pub invisible_dropped: u64,
    pub events_ingested: u64,
    /// Last window index each condition fired in (confirmation hysteresis).
    last_fired: std::collections::HashMap<Condition, u64>,
    window_idx: u64,
}

impl Agent {
    pub fn new(node: NodeId, n_gpus: usize, n_nodes_hint: usize) -> Self {
        Agent {
            node,
            accum: WindowAccum::with_hints(node, n_gpus, n_nodes_hint),
            baseline: Baseline::new(),
            history: Vec::with_capacity(HISTORY_DEPTH),
            invisible_dropped: 0,
            events_ingested: 0,
            last_fired: std::collections::HashMap::new(),
            window_idx: 0,
        }
    }

    /// Ingest a batch of events, applying the DPU visibility filter.
    pub fn ingest(&mut self, events: &[TelemetryEvent]) {
        for ev in events {
            if !ev.kind.dpu_visible() {
                self.invisible_dropped += 1;
                continue;
            }
            self.events_ingested += 1;
            self.accum.ingest(ev);
        }
    }

    /// Close the current window; returns the snapshot.
    pub fn tick(&mut self, now: SimTime) -> WindowSnapshot {
        let snap = self.accum.snapshot(now);
        if self.history.len() == HISTORY_DEPTH {
            self.history.remove(0);
        }
        self.history.push(snap.clone());
        snap
    }

    pub fn history(&self) -> &[WindowSnapshot] {
        &self.history
    }
}

/// The whole DPU observability plane.
pub struct DpuPlane {
    pub agents: Vec<Agent>,
    detectors: Vec<Box<dyn Detector>>,
    pub cfg: DetectConfig,
    calibrating: bool,
    /// Windows discarded before calibration starts (startup transient).
    pub warmup_windows: u64,
    /// Full detection log (node-attributed, timestamped).
    pub detections: Vec<Detection>,
    pub windows_processed: u64,
}

impl std::fmt::Debug for DpuPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DpuPlane")
            .field("agents", &self.agents.len())
            .field("detections", &self.detections.len())
            .field("calibrating", &self.calibrating)
            .finish()
    }
}

impl DpuPlane {
    pub fn new(n_nodes: usize, gpus_per_node: usize, cfg: DetectConfig) -> Self {
        DpuPlane {
            agents: (0..n_nodes)
                .map(|n| Agent::new(NodeId(n as u32), gpus_per_node, n_nodes))
                .collect(),
            detectors: all_detectors(),
            cfg,
            calibrating: true,
            warmup_windows: 50,
            detections: Vec::new(),
            windows_processed: 0,
        }
    }

    pub fn is_calibrating(&self) -> bool {
        self.calibrating
    }

    /// End the calibration phase; baselines freeze, detectors go live.
    pub fn go_live(&mut self) {
        for a in &mut self.agents {
            a.baseline.freeze();
        }
        self.calibrating = false;
    }

    /// Route drained telemetry to the owning agent.
    pub fn ingest(&mut self, node: NodeId, events: &[TelemetryEvent]) {
        self.agents[node.idx()].ingest(events);
    }

    /// Window tick across all agents: snapshot, then calibrate or detect.
    /// Returns the detections fired this tick.
    pub fn window_tick(&mut self, now: SimTime) -> Vec<Detection> {
        let mut fired = Vec::new();
        let in_warmup = self.calibrating
            && self.windows_processed < self.warmup_windows * self.agents.len() as u64;
        for a in &mut self.agents {
            self.windows_processed += 1;
            let snap = a.tick(now);
            if in_warmup {
                // Startup transient: observe nothing.
            } else if self.calibrating {
                for d in &self.detectors {
                    d.calibrate(&snap, &mut a.baseline);
                }
                a.baseline.end_window();
            } else {
                // History excludes the snapshot just taken (it's the last
                // element) so trend detectors compare against the past.
                let hist_len = a.history.len().saturating_sub(1);
                let ctx = DetectCtx {
                    snap: &snap,
                    baseline: &a.baseline,
                    history: &a.history[..hist_len],
                    cfg: &self.cfg,
                };
                if std::env::var("DPULENS_DEBUG").is_ok() && snap.node.0 <= 3 {
                    eprintln!(
                        "[dbg n{} t={}ms] h2d_rate={:.0} z={:.2} db2h={:.0}us z={:.2} beyond={:.2} busy={:.2} | hgap={:.0}us z={:.2} beyond={:.2} cnt={} | ends={} ratio={:.2} z={:.2} act={}",
                        snap.node.0, now.ns()/1_000_000,
                        snap.h2d_rate(), a.baseline.z("pc8.h2d_rate", snap.h2d_rate()),
                        snap.h2d_to_doorbell_ns.mean()/1e3, a.baseline.z("pc8.h2d_to_db", snap.h2d_to_doorbell_ns.mean()),
                        a.baseline.above_max("pc8.h2d_to_db", snap.h2d_to_doorbell_ns.mean()),
                        snap.pcie_busy.mean(),
                        snap.handoff_gap_ns.mean()/1e3, a.baseline.z("ew2.handoff_gap", snap.handoff_gap_ns.mean()),
                        a.baseline.above_max("ew2.handoff_gap", snap.handoff_gap_ns.mean()),
                        snap.handoff_count,
                        snap.flow_ends, snap.end_len_ratio, a.baseline.z("ns8.end_ratio", snap.end_len_ratio),
                        snap.active_flows,
                    );
                    eprintln!(
                        "[dbg2 n{} t={}ms] span={:.0}us n={} z={:.2} beyond={:.2} | d2h_dec_bytes={:.0} z={:.2} cnt={}",
                        snap.node.0, now.ns()/1_000_000,
                        snap.db_to_handoff_ns.mean()/1e3, snap.db_to_handoff_ns.count(),
                        a.baseline.z("ew2.stage_span", snap.db_to_handoff_ns.mean()),
                        a.baseline.above_max("ew2.stage_span", snap.db_to_handoff_ns.mean()),
                        snap.d2h.decode_bytes.mean(),
                        a.baseline.z("pc10.decode_bytes", snap.d2h.decode_bytes.mean()),
                        snap.d2h.decode_count,
                    );
                }
                let mut this_window: Vec<Detection> = Vec::new();
                for d in &self.detectors {
                    if let Some(det) = d.check(&ctx) {
                        this_window.push(det);
                    }
                }
                // Confirmation hysteresis: report when the condition fired
                // twice within a CONFIRM_SPAN-window span on this node.
                a.window_idx += 1;
                for det in this_window {
                    let prev = a.last_fired.insert(det.condition, a.window_idx);
                    if let Some(p) = prev {
                        if a.window_idx - p < CONFIRM_SPAN {
                            fired.push(det);
                        }
                    }
                }
            }
        }
        self.detections.extend(fired.iter().cloned());
        fired
    }

    /// Detection counts per condition (reporting).
    pub fn counts_by_condition(&self) -> std::collections::BTreeMap<Condition, usize> {
        let mut m = std::collections::BTreeMap::new();
        for d in &self.detections {
            *m.entry(d.condition).or_insert(0) += 1;
        }
        m
    }

    /// First detection of a condition at/after `t0` (detection latency).
    pub fn first_detection_after(&self, c: Condition, t0: SimTime) -> Option<&Detection> {
        self.detections.iter().filter(|d| d.condition == c && d.at >= t0).min_by_key(|d| d.at)
    }

    /// Total events the visibility boundary rejected (§4.3 proof).
    pub fn total_invisible_dropped(&self) -> u64 {
        self.agents.iter().map(|a| a.invisible_dropped).sum()
    }

    pub fn total_ingested(&self) -> u64 {
        self.agents.iter().map(|a| a.events_ingested).sum()
    }

    pub fn clear_detections(&mut self) {
        self.detections.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::GpuId;
    use crate::telemetry::event::{Phase, TelemetryKind};

    fn h2d_ev(t: u64, node: u32, lat: u64) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::DmaH2d {
                gpu: GpuId(0),
                bytes: 65536,
                latency_ns: lat,
                phase: Phase::Prefill,
            },
        }
    }

    fn invisible_ev(t: u64, node: u32) -> TelemetryEvent {
        TelemetryEvent {
            t: SimTime(t),
            node: NodeId(node),
            kind: TelemetryKind::GpuKernel { gpu: GpuId(0), dur_ns: 100, flops: 1.0 },
        }
    }

    #[test]
    fn visibility_boundary_enforced() {
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.ingest(NodeId(0), &[h2d_ev(1, 0, 100), invisible_ev(2, 0), invisible_ev(3, 0)]);
        assert_eq!(plane.total_ingested(), 1);
        assert_eq!(plane.total_invisible_dropped(), 2);
    }

    #[test]
    fn calibrate_then_detect_pc2() {
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.warmup_windows = 0;
        // Calibration: 20 healthy windows of D2H at ~3us.
        for w in 0..20u64 {
            let base = w * 1_000_000;
            for i in 0..10u64 {
                plane.ingest(
                    NodeId(0),
                    &[TelemetryEvent {
                        t: SimTime(base + i * 50_000),
                        node: NodeId(0),
                        kind: TelemetryKind::DmaD2h {
                            gpu: GpuId(0),
                            bytes: 4096,
                            latency_ns: 3_000 + (i % 3) * 100,
                            phase: Phase::Decode,
                        },
                    }],
                );
            }
            let fired = plane.window_tick(SimTime(base + 1_000_000));
            assert!(fired.is_empty(), "no detections during calibration");
        }
        plane.go_live();
        // Healthy window: no fire.
        for i in 0..10u64 {
            plane.ingest(
                NodeId(0),
                &[TelemetryEvent {
                    t: SimTime(20_000_000 + i * 50_000),
                    node: NodeId(0),
                    kind: TelemetryKind::DmaD2h {
                        gpu: GpuId(0),
                        bytes: 4096,
                        latency_ns: 3_100,
                        phase: Phase::Decode,
                    },
                }],
            );
        }
        let fired = plane.window_tick(SimTime(21_000_000));
        assert!(
            !fired.iter().any(|d| d.condition == Condition::Pc2D2hBottleneck),
            "healthy window must not fire PC2: {fired:?}"
        );
        // Pathological: slow D2H across two windows (confirmation).
        let mut fired_any = Vec::new();
        for w in 0..2u64 {
            let base = 21_000_000 + w * 1_000_000;
            for i in 0..10u64 {
                plane.ingest(
                    NodeId(0),
                    &[TelemetryEvent {
                        t: SimTime(base + i * 50_000),
                        node: NodeId(0),
                        kind: TelemetryKind::DmaD2h {
                            gpu: GpuId(0),
                            bytes: 4096,
                            latency_ns: 90_000,
                            phase: Phase::Decode,
                        },
                    }],
                );
            }
            fired_any.extend(plane.window_tick(SimTime(base + 1_000_000)));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pc2D2hBottleneck),
            "slow D2H must fire PC2, got {fired_any:?}"
        );
        assert!(plane.first_detection_after(Condition::Pc2D2hBottleneck, SimTime(21_000_000)).is_some());
    }

    #[test]
    fn invisible_events_cannot_trigger_anything() {
        // NVLink-only anomaly: the DPU plane must stay silent (§4.3).
        let mut plane = DpuPlane::new(1, 4, DetectConfig::default());
        plane.warmup_windows = 0;
        for w in 0..10u64 {
            plane.window_tick(SimTime((w + 1) * 1_000_000));
        }
        plane.go_live();
        for i in 0..1000u64 {
            plane.ingest(NodeId(0), &[invisible_ev(11_000_000 + i, 0)]);
        }
        let fired = plane.window_tick(SimTime(12_000_000));
        assert!(fired.is_empty());
        assert_eq!(plane.total_invisible_dropped(), 1000);
    }
}
