//! Freshness watchdog — the DPU-side brain of the router-fallback ladder.
//!
//! The telemetry fault boundary (`telemetry::faults`) maintains per-replica
//! [`FreshnessStat`]s: how old the newest delivered signal is, how complete
//! the delivered stream is against what the node emitted, and how far behind
//! a lagging export path is running. The watchdog folds the fleet's worst
//! replica into a single ladder level for the router:
//!
//! | level | trust                | router behaviour                     |
//! |-------|----------------------|--------------------------------------|
//! | 0     | telemetry fresh      | full telemetry-weighted score        |
//! | 1     | mildly degraded      | drop the KV term (rots fastest)      |
//! | 2     | badly degraded       | outstanding-count only (least-loaded)|
//! | 3     | telemetry unusable   | round-robin                          |
//!
//! Degradation is asymmetric by design: the level jumps *up* to the raw
//! assessment immediately (one window of rotted weights is one window too
//! many), but steps *down* one level at a time, and only after
//! [`RECOVERY_STREAK`] consecutive windows assessed calmer than the current
//! level — the hysteresis that keeps a flapping exporter from whipsawing
//! the routing policy.

use crate::telemetry::faults::FreshnessStat;

/// Signal age (windows since the last delivery) at which each ladder level
/// engages. A freeze crosses all three in order as the silence stretches.
const AGE_L1: u64 = 3;
const AGE_L2: u64 = 6;
const AGE_L3: u64 = 12;

/// Horizon completeness (delivered/emitted) below which levels engage: a
/// lossy path thins the windowed rates before it silences them.
const COMPLETENESS_L1: f64 = 0.9;
const COMPLETENESS_L2: f64 = 0.5;

/// Release lag (windows) at which levels engage. Lag alone never forces
/// level 3: a late-but-complete signal still beats a blind rotation.
const LAG_L1: u64 = 3;
const LAG_L2: u64 = 6;

/// Consecutive calmer-than-current windows required before the watchdog
/// steps the ladder down one level.
pub const RECOVERY_STREAK: u32 = 5;

/// Horizon (windows) of cumulative (emitted, delivered) counters kept for
/// the completeness ratio — long enough to smooth per-window jitter, short
/// enough that a repaired path recovers within one recovery streak.
const COMPLETENESS_HORIZON: usize = 8;

/// Maps per-replica freshness to a router ladder level with degrade-fast /
/// recover-slow hysteresis. One instance watches one router's feed.
#[derive(Debug, Clone)]
pub struct FreshnessWatchdog {
    level: u8,
    /// Ring of fleet-wide cumulative (emitted, delivered) totals, newest
    /// last, for the horizon completeness ratio.
    totals: Vec<(u64, u64)>,
    calm_streak: u32,
}

impl Default for FreshnessWatchdog {
    fn default() -> Self {
        Self::new()
    }
}

impl FreshnessWatchdog {
    pub fn new() -> Self {
        FreshnessWatchdog { level: 0, totals: Vec::new(), calm_streak: 0 }
    }

    pub fn level(&self) -> u8 {
        self.level
    }

    /// The raw (memoryless) ladder level a single replica's freshness
    /// warrants: the max over the age, completeness, and lag axes, each
    /// mapped monotonically.
    fn raw_replica_level(stat: &FreshnessStat, completeness: f64) -> u8 {
        let by_age = if stat.age_windows >= AGE_L3 {
            3
        } else if stat.age_windows >= AGE_L2 {
            2
        } else if stat.age_windows >= AGE_L1 {
            1
        } else {
            0
        };
        let by_completeness = if completeness < COMPLETENESS_L2 {
            2
        } else if completeness < COMPLETENESS_L1 {
            1
        } else {
            0
        };
        let by_lag = if stat.lag_windows >= LAG_L2 {
            2
        } else if stat.lag_windows >= LAG_L1 {
            1
        } else {
            0
        };
        by_age.max(by_completeness).max(by_lag)
    }

    /// One window tick: fold the fleet's freshness stats into the ladder
    /// level. Returns the (possibly unchanged) level after hysteresis.
    pub fn window_tick(&mut self, stats: &[FreshnessStat]) -> u8 {
        // Horizon completeness is assessed fleet-wide (one ring instead of
        // one per replica): the ladder level is a fleet-wide max anyway,
        // and per-replica localization is the TD detectors' job, not the
        // watchdog's.
        let fleet_totals: (u64, u64) = stats
            .iter()
            .fold((0, 0), |(e, d), s| (e + s.emitted, d + s.delivered));
        self.totals.push(fleet_totals);
        if self.totals.len() > COMPLETENESS_HORIZON + 1 {
            self.totals.remove(0);
        }
        let (old_e, old_d) = self.totals[0];
        let emitted_h = fleet_totals.0.saturating_sub(old_e);
        let delivered_h = fleet_totals.1.saturating_sub(old_d);
        // An idle horizon (nothing emitted) is complete, not suspicious.
        let fleet_completeness =
            if emitted_h == 0 { 1.0 } else { delivered_h as f64 / emitted_h as f64 };

        let raw = stats
            .iter()
            .map(|s| Self::raw_replica_level(s, fleet_completeness))
            .max()
            .unwrap_or(0);

        if raw > self.level {
            // Degrade fast: jump straight to the assessment.
            self.level = raw;
            self.calm_streak = 0;
        } else if raw < self.level {
            // Recover slow: one level per sustained calm streak.
            self.calm_streak += 1;
            if self.calm_streak >= RECOVERY_STREAK {
                self.level -= 1;
                self.calm_streak = 0;
            }
        } else {
            self.calm_streak = 0;
        }
        self.level
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> FreshnessStat {
        FreshnessStat { emitted: 100, delivered: 100, ..Default::default() }
    }

    fn tick_n(w: &mut FreshnessWatchdog, stats: &[FreshnessStat], n: usize) -> u8 {
        let mut l = w.level();
        for _ in 0..n {
            l = w.window_tick(stats);
        }
        l
    }

    #[test]
    fn fresh_fleet_stays_at_level_zero() {
        let mut w = FreshnessWatchdog::new();
        assert_eq!(tick_n(&mut w, &[fresh(), fresh()], 20), 0);
    }

    #[test]
    fn raw_level_is_monotone_in_each_axis() {
        // Worsening any single axis never lowers the raw level.
        let mut last = 0;
        for age in 0..20u64 {
            let s = FreshnessStat { age_windows: age, ..Default::default() };
            let l = FreshnessWatchdog::raw_replica_level(&s, 1.0);
            assert!(l >= last, "age {age}: level dropped {last} -> {l}");
            last = l;
        }
        assert_eq!(last, 3);
        let mut last = 0;
        for lag in 0..10u64 {
            let s = FreshnessStat { lag_windows: lag, ..Default::default() };
            let l = FreshnessWatchdog::raw_replica_level(&s, 1.0);
            assert!(l >= last, "lag {lag}: level dropped {last} -> {l}");
            last = l;
        }
        assert_eq!(last, 2, "lag alone must not force round-robin");
        let mut last = 0;
        for pct in (0..=100u64).rev() {
            let s = FreshnessStat::default();
            let l = FreshnessWatchdog::raw_replica_level(&s, pct as f64 / 100.0);
            assert!(l >= last, "completeness {pct}%: level dropped {last} -> {l}");
            last = l;
        }
        assert_eq!(last, 2, "loss alone must not force round-robin");
    }

    #[test]
    fn worst_replica_sets_the_fleet_level() {
        let mut w = FreshnessWatchdog::new();
        let mut stats = vec![fresh(); 4];
        stats[2].age_windows = AGE_L2; // one replica badly stale
        assert_eq!(w.window_tick(&stats), 2);
    }

    #[test]
    fn degrades_immediately_recovers_one_level_per_streak() {
        let mut w = FreshnessWatchdog::new();
        let frozen = [FreshnessStat { age_windows: AGE_L3, emitted: 50, ..Default::default() }];
        // Degrade-fast: a single bad window jumps straight to level 3.
        assert_eq!(w.window_tick(&frozen), 3);

        // Recovery: RECOVERY_STREAK calm windows per step, one level each.
        let calm = [fresh()];
        for _ in 0..RECOVERY_STREAK - 1 {
            assert_eq!(w.window_tick(&calm), 3, "recovered before the streak");
        }
        assert_eq!(w.window_tick(&calm), 2);
        assert_eq!(tick_n(&mut w, &calm, RECOVERY_STREAK as usize), 1);
        assert_eq!(tick_n(&mut w, &calm, RECOVERY_STREAK as usize), 0);
    }

    #[test]
    fn relapse_resets_the_recovery_streak() {
        let mut w = FreshnessWatchdog::new();
        let stale = [FreshnessStat { age_windows: AGE_L1, emitted: 50, ..Default::default() }];
        let calm = [fresh()];
        assert_eq!(w.window_tick(&stale), 1);
        // Almost recovered...
        tick_n(&mut w, &calm, RECOVERY_STREAK as usize - 1);
        // ...then one equally-bad window: the streak starts over.
        assert_eq!(w.window_tick(&stale), 1);
        assert_eq!(
            tick_n(&mut w, &calm, RECOVERY_STREAK as usize - 1),
            1,
            "partial streak must not carry across a relapse"
        );
        assert_eq!(w.window_tick(&calm), 0);
    }

    #[test]
    fn fleet_loss_ratio_raises_the_level() {
        let mut w = FreshnessWatchdog::new();
        // Cumulative counters: every window emits 100, delivers 40 — a 60%
        // loss ratio over the horizon must push the ladder to level 2.
        let mut emitted = 0;
        let mut delivered = 0;
        let mut level = 0;
        for _ in 0..COMPLETENESS_HORIZON + 2 {
            emitted += 100;
            delivered += 40;
            let s = [FreshnessStat { emitted, delivered, ..Default::default() }];
            level = w.window_tick(&s);
        }
        assert_eq!(level, 2);
    }

    #[test]
    fn idle_horizon_counts_as_complete() {
        let mut w = FreshnessWatchdog::new();
        // Nothing emitted at all: not a loss signature.
        assert_eq!(tick_n(&mut w, &[FreshnessStat::default()], 10), 0);
    }
}
