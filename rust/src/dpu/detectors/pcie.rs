//! Table 3(b) detectors — the PCIe Observer runbook: conditions visible to a
//! DPU as a PCIe peer on the root complex (DMA transactions, doorbells,
//! registrations, link utilization).

use super::{fire, Baseline, Condition, DetectCtx, Detection, Detector};
use crate::telemetry::window::WindowSnapshot;

pub fn detectors() -> Vec<Box<dyn Detector>> {
    vec![
        Box::new(H2dStarvation),
        Box::new(D2hBottleneck),
        Box::new(LaunchLatency),
        Box::new(IntraNodeSkew),
        Box::new(PcieSaturation),
        Box::new(P2pThrottling),
        Box::new(PinnedShortage),
        Box::new(HostCpuBottleneck),
        Box::new(RegistrationChurn),
        Box::new(DecodeEarlyStop),
    ]
}

/// Dispersion (max/min) of a per-GPU counter across GPUs that saw activity.
fn gpu_ratio(per_gpu: &[crate::telemetry::window::GpuWindow], f: impl Fn(&crate::telemetry::window::GpuWindow) -> u64) -> Option<f64> {
    let counts: Vec<u64> = per_gpu.iter().map(f).collect();
    let active: Vec<u64> = counts.iter().copied().filter(|&c| c > 0).collect();
    if active.len() < 2 {
        return None;
    }
    let mx = *counts.iter().max().unwrap() as f64;
    let mn = *counts.iter().min().unwrap() as f64;
    Some(mx / mn.max(1.0))
}

/// PC1 — H2D DMAs slow/clustered; GPU starves before doorbells.
pub struct H2dStarvation;

impl Detector for H2dStarvation {
    fn condition(&self) -> Condition {
        Condition::Pc1H2dStarvation
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.h2d.count > 0 {
            b.observe("pc1.h2d_lat", s.h2d.latency_ns.mean());
            b.observe("pc1.h2d_lat_max", s.h2d.latency_ns.max());
            b.observe("pc1.h2d_gap_max", s.h2d.gap_ns.max());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.h2d.count < 4 {
            return None;
        }
        // The big prefill feed DMAs carry the signal; decode's tiny control
        // DMAs drown the mean, so gate on the worst transaction.
        let z_lat = ctx.baseline.z("pc1.h2d_lat", s.h2d.latency_ns.mean());
        let z_max = ctx.baseline.z("pc1.h2d_lat_max", s.h2d.latency_ns.max());
        let beyond = ctx.baseline.above_max("pc1.h2d_lat_max", s.h2d.latency_ns.max());
        if (z_lat > ctx.cfg.z_fire || (z_max > ctx.cfg.z_fire && beyond > 2.0)) && s.h2d.count >= 4 {
            return fire(
                self.condition(),
                s,
                z_lat,
                format!(
                    "H2D latency {:.0}us (z={:.1}), max inter-DMA gap {:.0}us",
                    s.h2d.latency_ns.mean() / 1e3,
                    z_lat,
                    s.h2d.gap_ns.max() / 1e3
                ),
            );
        }
        None
    }
}

/// PC2 — D2H return path lingers; backlog after kernels.
pub struct D2hBottleneck;

impl Detector for D2hBottleneck {
    fn condition(&self) -> Condition {
        Condition::Pc2D2hBottleneck
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.d2h.count > 0 {
            b.observe("pc2.d2h_lat", s.d2h.latency_ns.mean());
            b.observe("pc2.d2h_lat_max", s.d2h.latency_ns.max());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.d2h.count < 2 {
            return None;
        }
        let z = ctx.baseline.z("pc2.d2h_lat", s.d2h.latency_ns.mean());
        let z_max = ctx.baseline.z("pc2.d2h_lat_max", s.d2h.latency_ns.max());
        let beyond = ctx.baseline.above_max("pc2.d2h_lat_max", s.d2h.latency_ns.max());
        if z > ctx.cfg.z_fire || (z_max > ctx.cfg.z_fire && beyond > 2.0) {
            return fire(
                self.condition(),
                s,
                z,
                format!("D2H latency {:.0}us (z={:.1})", s.d2h.latency_ns.mean() / 1e3, z),
            );
        }
        None
    }
}

/// PC3 — doorbells sporadic: long idle gap between data-ready and launch.
pub struct LaunchLatency;

impl Detector for LaunchLatency {
    fn condition(&self) -> Condition {
        Condition::Pc3LaunchLatency
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.doorbell_count > 0 {
            b.observe("pc3.h2d_to_db", s.h2d_to_doorbell_ns.mean());
            b.observe("pc3.db_count", s.doorbell_count as f64);
            b.observe("pc3.h2d_lat", s.h2d.latency_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.doorbell_count < 2 {
            return None;
        }
        let z_d = ctx.baseline.z("pc3.h2d_to_db", s.h2d_to_doorbell_ns.mean());
        let z_lat = ctx.baseline.z("pc3.h2d_lat", s.h2d.latency_ns.mean());
        let z_cnt = ctx.baseline.z("pc3.db_count", s.doorbell_count as f64);
        // Either launches lag behind healthy DMAs, or a tiny-kernel storm
        // multiplies doorbells — both are control-path, not data-path.
        if (z_d > ctx.cfg.z_fire && z_lat < 2.0) || z_cnt > 2.0 * ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z_d.max(z_cnt),
                format!(
                    "data-to-doorbell {:.0}us (z={:.1}), {} doorbells (z={:.1}), H2D z={:.1}",
                    s.h2d_to_doorbell_ns.mean() / 1e3,
                    z_d,
                    s.doorbell_count,
                    z_cnt,
                    z_lat
                ),
            );
        }
        None
    }
}

/// PC4 — one GPU's DMA stream thin/irregular while peers are steady.
pub struct IntraNodeSkew;

impl Detector for IntraNodeSkew {
    fn condition(&self) -> Condition {
        Condition::Pc4IntraNodeSkew
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if let Some(r) = gpu_ratio(&s.per_gpu, |g| g.h2d_bytes + g.doorbell_count) {
            b.observe("pc4.gpu_ratio", r);
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let r = gpu_ratio(&s.per_gpu, |g| g.h2d_bytes + g.doorbell_count)?;
        let z = ctx.baseline.z("pc4.gpu_ratio", r);
        if z > ctx.cfg.z_fire && r > 2.0 {
            return fire(
                self.condition(),
                s,
                z,
                format!("per-GPU activity max/min ratio {r:.1} (z={z:.1})"),
            );
        }
        None
    }
}

/// PC5 — sustained near-peak PCIe utilization, compute stalls periodically.
pub struct PcieSaturation;

impl Detector for PcieSaturation {
    fn condition(&self) -> Condition {
        Condition::Pc5PcieSaturation
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.pcie_busy.count() > 0 {
            b.observe("pc5.busy", s.pcie_busy.mean());
            b.observe("pc5.h2d_lat", s.h2d.latency_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.pcie_busy.count() == 0 {
            return None;
        }
        let busy = s.pcie_busy.mean();
        let z_busy = ctx.baseline.z("pc5.busy", busy);
        let z_lat = ctx.baseline.z("pc5.h2d_lat", s.h2d.latency_ns.mean());
        if busy > 0.7 && z_busy > ctx.cfg.z_fire && z_lat > 1.0 {
            return fire(
                self.condition(),
                s,
                z_busy,
                format!("PCIe busy {:.0}% (z={:.1}), H2D latency z={:.1}", busy * 100.0, z_busy, z_lat),
            );
        }
        None
    }
}

/// PC6 — P2P DMAs slow/variable over PCIe with no NVLink path.
pub struct P2pThrottling;

impl Detector for P2pThrottling {
    fn condition(&self) -> Condition {
        Condition::Pc6P2pThrottling
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("pc6.p2p_count", s.p2p_pcie.count as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let z = ctx.baseline.z("pc6.p2p_count", s.p2p_pcie.count as f64);
        // Healthy clusters with NVLink show ~zero PCIe P2P; a surge of PCIe
        // P2P traffic is itself the red flag.
        if s.p2p_pcie.count >= 4 && z > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z,
                format!(
                    "{} P2P DMAs routed over PCIe (z={:.1}), mean latency {:.0}us",
                    s.p2p_pcie.count,
                    z,
                    s.p2p_pcie.latency_ns.mean() / 1e3
                ),
            );
        }
        None
    }
}

/// PC7 — many small DMAs instead of large coalesced ones.
pub struct PinnedShortage;

impl Detector for PinnedShortage {
    fn condition(&self) -> Condition {
        Condition::Pc7PinnedShortage
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.h2d.count > 0 {
            b.observe("pc7.h2d_count", s.h2d.count as f64);
            b.observe("pc7.h2d_mean_bytes", s.h2d.bytes.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.h2d.count < 8 {
            return None;
        }
        let z_cnt = ctx.baseline.z("pc7.h2d_count", s.h2d.count as f64);
        let z_sz = ctx.baseline.z("pc7.h2d_mean_bytes", s.h2d.bytes.mean());
        if z_cnt > ctx.cfg.z_fire && z_sz < -1.5 {
            return fire(
                self.condition(),
                s,
                z_cnt,
                format!(
                    "{} DMAs (z={:.1}) with mean size {:.0}B (z={:.1}) — fragmentation",
                    s.h2d.count, z_cnt, s.h2d.bytes.mean(), z_sz
                ),
            );
        }
        None
    }
}

/// PC8 — low DMA rate despite idle PCIe; doorbells delayed (host CPU bound).
pub struct HostCpuBottleneck;

impl Detector for HostCpuBottleneck {
    fn condition(&self) -> Condition {
        Condition::Pc8HostCpuBottleneck
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("pc8.h2d_rate", s.h2d_rate());
        if s.doorbell_count > 0 {
            b.observe("pc8.h2d_to_db", s.h2d_to_doorbell_ns.mean());
        }
        if s.pcie_busy.count() > 0 {
            b.observe("pc8.busy", s.pcie_busy.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.doorbell_count < 2 {
            return None;
        }
        let z_rate = ctx.baseline.z("pc8.h2d_rate", s.h2d_rate());
        let z_db = ctx.baseline.z("pc8.h2d_to_db", s.h2d_to_doorbell_ns.mean());
        let db_beyond =
            ctx.baseline.above_max("pc8.h2d_to_db", s.h2d_to_doorbell_ns.mean());
        let busy = s.pcie_busy.mean();
        if z_db > ctx.cfg.z_fire && db_beyond > 1.5 && z_rate < -0.3 && busy < 0.5 {
            return fire(
                self.condition(),
                s,
                z_db,
                format!(
                    "H2D rate z={:.1} with doorbell delay z={:.1} and idle PCIe ({:.0}%)",
                    z_rate,
                    z_db,
                    busy * 100.0
                ),
            );
        }
        None
    }
}

/// PC9 — frequent map/unmap registration churn around DMAs.
pub struct RegistrationChurn;

impl Detector for RegistrationChurn {
    fn condition(&self) -> Condition {
        Condition::Pc9RegistrationChurn
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("pc9.reg_count", (s.mem_reg_count + s.mem_unreg_count) as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let total = s.mem_reg_count + s.mem_unreg_count;
        let z = ctx.baseline.z("pc9.reg_count", total as f64);
        if total >= 8 && z > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z,
                format!("{} registration ops around {} DMAs (z={:.1})", total, s.h2d.count, z),
            );
        }
        None
    }
}

/// PC10 — D2H drops off early on some streams/GPUs during decode.
pub struct DecodeEarlyStop;

impl Detector for DecodeEarlyStop {
    fn condition(&self) -> Condition {
        Condition::Pc10DecodeEarlyStop
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.d2h.decode_count > 0 {
            b.observe("pc10.decode_d2h", s.d2h.decode_count as f64);
            b.observe("pc10.decode_bytes", s.d2h.decode_bytes.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        // Decode-phase D2H rate collapsed vs baseline while prefill-phase
        // traffic continues — streams going silent mid-decode.
        if !ctx.baseline.has("pc10.decode_d2h") {
            return None;
        }
        let base = ctx.baseline.mean("pc10.decode_d2h");
        let cur = s.d2h.decode_count as f64;
        let z = ctx.baseline.z("pc10.decode_d2h", cur);
        // Primary signature: decode-phase D2H transactions SHRINK — streams
        // went silent mid-batch, so each returned logits block covers fewer
        // live sequences (early-stop without remapping).
        let bytes_base = ctx.baseline.mean("pc10.decode_bytes");
        let bytes_cur = s.d2h.decode_bytes.mean();
        let z_bytes = ctx.baseline.z("pc10.decode_bytes", bytes_cur);
        // Require history: the drop must follow observed decode activity.
        let had_recent = ctx
            .history
            .iter()
            .rev()
            .take(3)
            .any(|h| h.d2h.decode_count as f64 > 0.5 * base);
        if had_recent
            && ((z < -1.2 && cur < 0.8 * base)
                || (s.d2h.decode_count >= 4 && z_bytes < -2.5 && bytes_cur < 0.9 * bytes_base))
        {
            return fire(
                self.condition(),
                s,
                (-z).max(-z_bytes),
                format!(
                    "decode D2H {cur:.0}/window (base {base:.0}), txn {bytes_cur:.0}B vs                      {bytes_base:.0}B (z={z_bytes:.1}) — streams going silent"
                ),
            );
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::NodeId;
    use crate::sim::SimTime;
    use crate::telemetry::window::{GpuWindow, WindowSnapshot};
    use crate::util::stats::Welford;

    fn wf(vals: &[f64]) -> Welford {
        let mut w = Welford::new();
        for &v in vals {
            w.push(v);
        }
        w
    }

    fn healthy_snap() -> WindowSnapshot {
        let mut s = WindowSnapshot::default();
        s.node = NodeId(0);
        s.end = SimTime(1_000_000);
        s.h2d.count = 40;
        s.h2d.bytes = wf(&[65536.0; 40]);
        s.h2d.latency_ns = wf(&[4000.0, 4100.0, 3900.0, 4000.0]);
        s.h2d.gap_ns = wf(&[20_000.0, 21_000.0, 19_000.0]);
        s.d2h.count = 20;
        s.d2h.latency_ns = wf(&[3000.0, 3100.0, 2900.0]);
        s.d2h.decode_count = 16;
        s.doorbell_count = 40;
        s.h2d_to_doorbell_ns = wf(&[5_000.0, 5_200.0, 4_800.0]);
        s.pcie_busy = wf(&[0.3, 0.32, 0.28]);
        s.per_gpu = vec![
            GpuWindow { h2d_count: 10, h2d_bytes: 655360, doorbell_count: 10, ..Default::default() };
            4
        ];
        s
    }

    fn calib(det: &dyn Detector, n: usize) -> Baseline {
        let mut b = Baseline::new();
        for _ in 0..n {
            det.calibrate(&healthy_snap(), &mut b);
            b.end_window();
        }
        b.freeze();
        b
    }

    #[test]
    fn pc2_fires_on_slow_d2h_only() {
        let det = D2hBottleneck;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let healthy = healthy_snap();
        let ctx = DetectCtx { snap: &healthy, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        let mut s = healthy_snap();
        s.d2h.latency_ns = wf(&[80_000.0, 90_000.0, 85_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn pc4_fires_on_gpu_imbalance() {
        let det = IntraNodeSkew;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let mut s = healthy_snap();
        s.per_gpu[2] = GpuWindow { h2d_count: 10, h2d_bytes: 4096, doorbell_count: 10, ..Default::default() };
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        let d = det.check(&ctx).expect("skew should fire");
        assert!(d.evidence.contains("ratio"));
    }

    #[test]
    fn pc7_needs_count_up_and_size_down() {
        let det = PinnedShortage;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        // more DMAs of the same size: no fire (that's just load)
        let mut s = healthy_snap();
        s.h2d.count = 400;
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        // more + smaller: fire
        s.h2d.bytes = wf(&[2048.0; 40]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn pc10_requires_recent_decode_activity() {
        let det = DecodeEarlyStop;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let mut s = healthy_snap();
        s.d2h.decode_count = 2;
        // no history -> no fire
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        // with recent healthy history -> fire
        let hist = vec![healthy_snap(), healthy_snap()];
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &hist, cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn all_ten_present() {
        assert_eq!(detectors().len(), 10);
    }
}
