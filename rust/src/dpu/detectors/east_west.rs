//! Table 3(c) detectors — the East-West sensing runbook: conditions visible
//! in inter-node RDMA/collective traffic at the NIC.

use super::{fire, Baseline, Condition, DetectCtx, Detection, Detector};
use crate::telemetry::window::WindowSnapshot;

pub fn detectors() -> Vec<Box<dyn Detector>> {
    vec![
        Box::new(TpStraggler),
        Box::new(PpBubble),
        Box::new(CrossNodeSkew),
        Box::new(Congestion),
        Box::new(HolBlocking),
        Box::new(Retransmissions),
        Box::new(CreditStarvation),
        Box::new(KvBottleneck),
        Box::new(EarlyStopSkew),
    ]
}

/// EW1 — wide max-min arrival spread of TP collective bursts.
pub struct TpStraggler;

impl Detector for TpStraggler {
    fn condition(&self) -> Condition {
        Condition::Ew1TpStraggler
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.tp.completed > 0 {
            b.observe("ew1.tp_spread", s.tp.spread_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.tp.completed < 2 {
            return None;
        }
        let z = ctx.baseline.z("ew1.tp_spread", s.tp.spread_ns.mean());
        let beyond = ctx.baseline.above_max("ew1.tp_spread", s.tp.spread_ns.mean());
        if z > ctx.cfg.z_fire && beyond > 1.3 {
            return fire(
                self.condition(),
                s,
                z,
                format!(
                    "TP burst arrival spread {:.0}us (z={:.1}) over {} collectives",
                    s.tp.spread_ns.mean() / 1e3,
                    z,
                    s.tp.completed
                ),
            );
        }
        None
    }
}

/// EW2 — large/growing gaps between stage handoff bursts.
pub struct PpBubble;

impl Detector for PpBubble {
    fn condition(&self) -> Condition {
        Condition::Ew2PpBubble
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        // The stage's compute span: last doorbell -> outbound handoff send.
        // Arrival-rate independent (unlike inter-handoff gaps, which are
        // dominated by workload cadence when the pipeline isn't saturated).
        if s.db_to_handoff_ns.count() >= 3 {
            b.observe("ew2.stage_span", s.db_to_handoff_ns.mean());
        }
        if s.handoff_count >= 5 {
            b.observe("ew2.handoff_gap", s.handoff_gap_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        // Upstream (sending) nodes carry the compute-span signal and receive
        // no handoffs themselves; don't gate on inbound traffic.
        if s.handoff_count < 2 && s.pp.stalled == 0 && s.db_to_handoff_ns.count() < 3 {
            return None;
        }
        let z_span = ctx.baseline.z("ew2.stage_span", s.db_to_handoff_ns.mean());
        let span_beyond = ctx.baseline.above_max("ew2.stage_span", s.db_to_handoff_ns.mean());
        let z_gap = ctx.baseline.z("ew2.handoff_gap", s.handoff_gap_ns.mean());
        let beyond = ctx.baseline.above_max("ew2.handoff_gap", s.handoff_gap_ns.mean());
        if (s.db_to_handoff_ns.count() >= 3 && z_span > 2.5 && span_beyond > 1.1)
            || (z_gap > ctx.cfg.z_fire && beyond > 1.3 && s.handoff_count >= 5)
            || s.pp.stalled > 0
        {
            return fire(
                self.condition(),
                s,
                z_span.max(z_gap).max(s.pp.stalled as f64 * 4.0),
                format!(
                    "stage compute span {:.0}us (z={:.1}), handoff gap z={:.1}, {} stalled",
                    s.db_to_handoff_ns.mean() / 1e3,
                    z_span,
                    z_gap,
                    s.pp.stalled
                ),
            );
        }
        None
    }
}

/// EW3 — uneven per-node traffic volume for the same collectives.
pub struct CrossNodeSkew;

impl Detector for CrossNodeSkew {
    fn condition(&self) -> Condition {
        Condition::Ew3CrossNodeSkew
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.node_coll_dispersion.count() >= 2 {
            b.observe("ew3.node_cov", s.node_coll_dispersion.cov());
            b.observe("ew3.bytes_cov", s.tp.bytes_per_rank_cov.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.node_coll_dispersion.count() < 2 {
            return None;
        }
        let cov = s.node_coll_dispersion.cov();
        let z = ctx.baseline.z("ew3.node_cov", cov);
        let z_b = ctx.baseline.z("ew3.bytes_cov", s.tp.bytes_per_rank_cov.mean());
        if (z > ctx.cfg.z_fire && cov > 0.3) || z_b > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z.max(z_b),
                format!(
                    "per-node collective bytes CoV {:.2} (z={:.1}), per-rank bytes CoV z={:.1}",
                    cov, z, z_b
                ),
            );
        }
        None
    }
}

/// EW4 — periodic latency+jitter spikes across many links.
pub struct Congestion;

impl Detector for Congestion {
    fn condition(&self) -> Condition {
        Condition::Ew4Congestion
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.rdma_count > 0 {
            b.observe("ew4.rdma_lat", s.rdma_latency_ns.mean());
            b.observe("ew4.rdma_lat_cov", s.rdma_latency_ns.cov());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.rdma_count < 4 {
            return None;
        }
        let z_lat = ctx.baseline.z("ew4.rdma_lat", s.rdma_latency_ns.mean());
        let beyond = ctx.baseline.above_max("ew4.rdma_lat", s.rdma_latency_ns.mean());
        // Congestion raises latency across the board (jitter secondary);
        // loss-free (distinguishes from EW6) and affecting the mean
        // (distinguishes from EW5's bimodal stall pattern).
        if z_lat > ctx.cfg.z_fire && beyond > 1.3 && s.retx_fabric < 3 {
            return fire(
                self.condition(),
                s,
                z_lat,
                format!(
                    "fabric RDMA latency {:.0}us (z={:.1}) across {} ops, no loss",
                    s.rdma_latency_ns.mean() / 1e3,
                    z_lat,
                    s.rdma_count
                ),
            );
        }
        None
    }
}

/// EW5 — some streams stall while others flow (shared-queue HOL).
pub struct HolBlocking;

impl Detector for HolBlocking {
    fn condition(&self) -> Condition {
        Condition::Ew5HolBlocking
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.rdma_count > 0 {
            b.observe("ew5.lat_cov", s.rdma_latency_ns.cov());
            b.observe("ew5.lat_burst", s.rdma_latency_ns.burstiness());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.rdma_count < 6 {
            return None;
        }
        let cov = s.rdma_latency_ns.cov();
        let burst = s.rdma_latency_ns.burstiness();
        let z_cov = ctx.baseline.z("ew5.lat_cov", cov);
        let z_b = ctx.baseline.z("ew5.lat_burst", burst);
        let beyond = ctx.baseline.above_max("ew5.lat_cov", cov);
        // Bimodal latencies: tail blows out while median stays — the classic
        // head-of-line signature (vs EW4's uniform inflation).
        if z_cov > ctx.cfg.z_fire && beyond > 1.2 && z_b > 1.5 && s.retx_fabric < 3 {
            return fire(
                self.condition(),
                s,
                z_cov,
                format!(
                    "RDMA latency CoV {:.2} (z={:.1}), max/mean {:.1}x — stalled streams",
                    cov, z_cov, burst
                ),
            );
        }
        None
    }
}

/// EW6 — retransmit storms / packet loss in the fabric.
pub struct Retransmissions;

impl Detector for Retransmissions {
    fn condition(&self) -> Condition {
        Condition::Ew6Retransmissions
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ew6.retx", s.retx_fabric as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let recent: u64 = s.retx_fabric
            + ctx.history.iter().rev().take(4).map(|h| h.retx_fabric).sum::<u64>();
        let z = ctx.baseline.z("ew6.retx", s.retx_fabric as f64);
        if recent >= 3 && s.retx_fabric >= 1 && z > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z,
                format!(
                    "{} fabric retransmits, {} drops (z={:.1})",
                    s.retx_fabric, s.drop_fabric, z
                ),
            );
        }
        None
    }
}

/// EW7 — long silences until remote credit updates (RDMA flow control).
pub struct CreditStarvation;

impl Detector for CreditStarvation {
    fn condition(&self) -> Condition {
        Condition::Ew7CreditStarvation
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.rdma_count > 0 {
            b.observe("ew7.credit_wait", s.rdma_credit_wait_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.rdma_count < 4 {
            return None;
        }
        let z = ctx.baseline.z("ew7.credit_wait", s.rdma_credit_wait_ns.mean());
        let beyond = ctx.baseline.above_max("ew7.credit_wait", s.rdma_credit_wait_ns.mean());
        if z > ctx.cfg.z_fire && (beyond > 1.5 || beyond == 0.0)
            && s.rdma_credit_wait_ns.mean() > 1_000.0 {
            return fire(
                self.condition(),
                s,
                z,
                format!(
                    "mean credit wait {:.0}us (z={:.1}) over {} RDMA ops",
                    s.rdma_credit_wait_ns.mean() / 1e3,
                    z,
                    s.rdma_count
                ),
            );
        }
        None
    }
}

/// EW8 — repeated large KV bursts for some tokens, others silent.
pub struct KvBottleneck;

impl Detector for KvBottleneck {
    fn condition(&self) -> Condition {
        Condition::Ew8KvBottleneck
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.kv.burst_count > 0 {
            b.observe("ew8.kv_lat", s.kv.latency_ns.mean());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.kv.burst_count == 0 {
            return None;
        }
        // Sharded KV exceeding the link budget shows as KV bursts taking
        // far longer than the healthy baseline (while others go silent).
        let z = ctx.baseline.z("ew8.kv_lat", s.kv.latency_ns.mean());
        let beyond = ctx.baseline.above_max("ew8.kv_lat", s.kv.latency_ns.mean());
        if (z > ctx.cfg.z_fire && beyond > 1.4) || s.kv.stalled > 0 {
            return fire(
                self.condition(),
                s,
                z.max(s.kv.stalled as f64 * 4.0),
                format!(
                    "KV burst latency {:.0}us (z={:.1}), {} stalled, {:.1}MB moved",
                    s.kv.latency_ns.mean() / 1e3,
                    z,
                    s.kv.stalled,
                    s.kv.total_bytes as f64 / 1e6
                ),
            );
        }
        None
    }
}

/// EW9 — some nodes stop sending mid-iteration while peers continue.
pub struct EarlyStopSkew;

impl Detector for EarlyStopSkew {
    fn condition(&self) -> Condition {
        Condition::Ew9EarlyStopSkew
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ew9.tp_stalled", s.tp.stalled as f64);
        if s.node_coll_dispersion.count() >= 2 {
            b.observe("ew9.node_cov", s.node_coll_dispersion.cov());
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let z_st = ctx.baseline.z("ew9.tp_stalled", s.tp.stalled as f64);
        // Stalled collectives (peers gone silent) are the primary red flag;
        // per-node send volume divergence corroborates.
        if s.tp.stalled >= 2 && z_st > ctx.cfg.z_fire {
            let cov = s.node_coll_dispersion.cov();
            return fire(
                self.condition(),
                s,
                z_st,
                format!(
                    "{} collectives waiting on silent peers (z={:.1}), node volume CoV {:.2}",
                    s.tp.stalled, z_st, cov
                ),
            );
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::NodeId;
    use crate::sim::SimTime;
    use crate::telemetry::window::WindowSnapshot;
    use crate::util::stats::Welford;

    fn wf(vals: &[f64]) -> Welford {
        let mut w = Welford::new();
        for &v in vals {
            w.push(v);
        }
        w
    }

    fn healthy_snap() -> WindowSnapshot {
        let mut s = WindowSnapshot::default();
        s.node = NodeId(0);
        s.end = SimTime(1_000_000);
        s.tp.completed = 10;
        s.tp.spread_ns = wf(&[8_000.0, 8_500.0, 7_500.0]);
        s.tp.bytes_per_rank_cov = wf(&[0.02, 0.03]);
        s.pp.completed = 5;
        s.pp.spread_ns = wf(&[6_000.0, 6_200.0]);
        s.handoff_count = 10;
        s.handoff_gap_ns = wf(&[50_000.0, 52_000.0, 48_000.0]);
        s.kv.completed = 5;
        s.kv.burst_count = 10;
        s.kv.spread_ns = wf(&[9_000.0, 9_300.0]);
        s.rdma_count = 30;
        s.rdma_latency_ns = wf(&[30_000.0, 31_000.0, 29_000.0, 30_500.0]);
        s.rdma_credit_wait_ns = wf(&[0.0, 0.0, 100.0]);
        s.node_coll_dispersion = wf(&[1_000_000.0, 1_050_000.0, 980_000.0]);
        s
    }

    fn calib(det: &dyn Detector, n: usize) -> Baseline {
        let mut b = Baseline::new();
        for _ in 0..n {
            det.calibrate(&healthy_snap(), &mut b);
            b.end_window();
        }
        b.freeze();
        b
    }

    #[test]
    fn ew1_fires_on_wide_spread() {
        let det = TpStraggler;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let healthy = healthy_snap();
        let ctx = DetectCtx { snap: &healthy, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        let mut s = healthy_snap();
        s.tp.spread_ns = wf(&[300_000.0, 280_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        let d = det.check(&ctx).expect("straggler fires");
        assert!(d.severity > 3.0);
    }

    #[test]
    fn ew4_vs_ew6_distinguished_by_loss() {
        let cong = Congestion;
        let retx = Retransmissions;
        let b_c = calib(&cong, 20);
        let b_r = calib(&retx, 20);
        let cfg = super::super::DetectConfig::default();
        // Pure congestion: latency up, no retransmits.
        let mut s = healthy_snap();
        s.rdma_latency_ns = wf(&[300_000.0, 310_000.0, 290_000.0, 305_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b_c, history: &[], cfg: &cfg };
        assert!(cong.check(&ctx).is_some());
        let ctx = DetectCtx { snap: &s, baseline: &b_r, history: &[], cfg: &cfg };
        assert!(retx.check(&ctx).is_none());
        // Loss storm: EW6 fires, EW4 suppressed.
        s.retx_fabric = 20;
        let ctx = DetectCtx { snap: &s, baseline: &b_r, history: &[], cfg: &cfg };
        assert!(retx.check(&ctx).is_some());
        let ctx = DetectCtx { snap: &s, baseline: &b_c, history: &[], cfg: &cfg };
        assert!(cong.check(&ctx).is_none());
    }

    #[test]
    fn ew5_needs_bimodal_not_uniform() {
        let det = HolBlocking;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        // Uniform inflation (congestion-like): CoV unchanged -> no fire.
        let mut s = healthy_snap();
        s.rdma_latency_ns = wf(&[300_000.0, 310_000.0, 290_000.0, 305_000.0, 300_000.0, 295_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        // Bimodal: most fast, some stalled -> fire.
        s.rdma_latency_ns =
            wf(&[30_000.0, 31_000.0, 29_000.0, 30_000.0, 900_000.0, 950_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn ew7_fires_on_credit_waits() {
        let det = CreditStarvation;
        let b = calib(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let mut s = healthy_snap();
        s.rdma_credit_wait_ns = wf(&[50_000.0, 60_000.0, 55_000.0]);
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn all_nine_present() {
        assert_eq!(detectors().len(), 9);
    }
}
