//! Detector framework: the 28 runbook conditions (paper Tables 3a-c), the
//! healthy-baseline model, and the `Detector` trait each condition
//! implements.

pub mod east_west;
pub mod north_south;
pub mod pcie;

use std::collections::HashMap;

use crate::ids::NodeId;
use crate::sim::SimTime;
use crate::telemetry::window::WindowSnapshot;
use crate::util::stats::Welford;

/// Every skew/imbalance/pathological condition in the paper's runbooks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Condition {
    // Table 3(a) — North-South
    Ns1BurstBacklog,
    Ns2IngressStarvation,
    Ns3FlowSkew,
    Ns4IngressRetx,
    Ns5EgressBacklog,
    Ns6EgressJitter,
    Ns7EgressRetx,
    Ns8EarlyCompletion,
    Ns9BandwidthSaturation,
    // Table 3(b) — PCIe Observer
    Pc1H2dStarvation,
    Pc2D2hBottleneck,
    Pc3LaunchLatency,
    Pc4IntraNodeSkew,
    Pc5PcieSaturation,
    Pc6P2pThrottling,
    Pc7PinnedShortage,
    Pc8HostCpuBottleneck,
    Pc9RegistrationChurn,
    Pc10DecodeEarlyStop,
    // Table 3(c) — East-West
    Ew1TpStraggler,
    Ew2PpBubble,
    Ew3CrossNodeSkew,
    Ew4Congestion,
    Ew5HolBlocking,
    Ew6Retransmissions,
    Ew7CreditStarvation,
    Ew8KvBottleneck,
    Ew9EarlyStopSkew,
    // Data-parallel fleet family (cross-replica, router/LB vantage) — the
    // serving-scale extension of the paper's within-replica runbooks.
    Dp1RouterFlowSkew,
    Dp2HotReplicaKv,
    Dp3StragglerReplica,
    // Phase-disaggregation family (prefill/decode pools + KV handoff) — the
    // pathologies only a pool-split topology can exhibit, sensed from the
    // router/handoff vantage where the pool boundary is network traffic.
    Pd1PrefillSaturation,
    Pd2KvHandoffStall,
    Pd3DecodeStarvation,
    // Telemetry-dropout family (the monitoring path itself degrades) — the
    // DPU's own signal goes stale, lossy, or late, and the router
    // mis-balances *because its weights rotted*. Sensed by the freshness
    // watchdog in `dpu::fleet`, not by any detector that trusts the signal.
    Td1StaleFrozen,
    Td2LossyDrop,
    Td3LaggingDelivery,
}

pub const ALL_CONDITIONS: [Condition; 28] = [
    Condition::Ns1BurstBacklog,
    Condition::Ns2IngressStarvation,
    Condition::Ns3FlowSkew,
    Condition::Ns4IngressRetx,
    Condition::Ns5EgressBacklog,
    Condition::Ns6EgressJitter,
    Condition::Ns7EgressRetx,
    Condition::Ns8EarlyCompletion,
    Condition::Ns9BandwidthSaturation,
    Condition::Pc1H2dStarvation,
    Condition::Pc2D2hBottleneck,
    Condition::Pc3LaunchLatency,
    Condition::Pc4IntraNodeSkew,
    Condition::Pc5PcieSaturation,
    Condition::Pc6P2pThrottling,
    Condition::Pc7PinnedShortage,
    Condition::Pc8HostCpuBottleneck,
    Condition::Pc9RegistrationChurn,
    Condition::Pc10DecodeEarlyStop,
    Condition::Ew1TpStraggler,
    Condition::Ew2PpBubble,
    Condition::Ew3CrossNodeSkew,
    Condition::Ew4Congestion,
    Condition::Ew5HolBlocking,
    Condition::Ew6Retransmissions,
    Condition::Ew7CreditStarvation,
    Condition::Ew8KvBottleneck,
    Condition::Ew9EarlyStopSkew,
];

/// The data-parallel (cross-replica) condition family. Sensed by
/// `dpu::fleet::FleetSensor` from the router/LB vantage rather than by the
/// 28 per-node window detectors, so it is deliberately NOT part of
/// [`ALL_CONDITIONS`] (the paper's Tables 3a-c diagonal).
pub const DP_CONDITIONS: [Condition; 3] = [
    Condition::Dp1RouterFlowSkew,
    Condition::Dp2HotReplicaKv,
    Condition::Dp3StragglerReplica,
];

/// The phase-disaggregation condition family (prefill-pool saturation,
/// KV-handoff stall, decode-pool starvation). Sensed by `dpu::fleet` from
/// the pool-boundary vantage; inert on colocated fleets, so neither the
/// 28-condition matrix nor the v1 fleet study ever sees them.
pub const PD_CONDITIONS: [Condition; 3] = [
    Condition::Pd1PrefillSaturation,
    Condition::Pd2KvHandoffStall,
    Condition::Pd3DecodeStarvation,
];

/// The telemetry-dropout condition family (stale-frozen, lossy-drop,
/// lagging-delivery monitoring signal). Sensed by the freshness watchdog in
/// `dpu::fleet::FleetSensor` — deliberately a detector that does NOT trust
/// the telemetry content, only its age/completeness/latency — so it stays
/// off the Tables 3a-c diagonal like the DP/PD families.
pub const TD_CONDITIONS: [Condition; 3] = [
    Condition::Td1StaleFrozen,
    Condition::Td2LossyDrop,
    Condition::Td3LaggingDelivery,
];

impl Condition {
    pub fn id(&self) -> &'static str {
        use Condition::*;
        match self {
            Ns1BurstBacklog => "NS1",
            Ns2IngressStarvation => "NS2",
            Ns3FlowSkew => "NS3",
            Ns4IngressRetx => "NS4",
            Ns5EgressBacklog => "NS5",
            Ns6EgressJitter => "NS6",
            Ns7EgressRetx => "NS7",
            Ns8EarlyCompletion => "NS8",
            Ns9BandwidthSaturation => "NS9",
            Pc1H2dStarvation => "PC1",
            Pc2D2hBottleneck => "PC2",
            Pc3LaunchLatency => "PC3",
            Pc4IntraNodeSkew => "PC4",
            Pc5PcieSaturation => "PC5",
            Pc6P2pThrottling => "PC6",
            Pc7PinnedShortage => "PC7",
            Pc8HostCpuBottleneck => "PC8",
            Pc9RegistrationChurn => "PC9",
            Pc10DecodeEarlyStop => "PC10",
            Ew1TpStraggler => "EW1",
            Ew2PpBubble => "EW2",
            Ew3CrossNodeSkew => "EW3",
            Ew4Congestion => "EW4",
            Ew5HolBlocking => "EW5",
            Ew6Retransmissions => "EW6",
            Ew7CreditStarvation => "EW7",
            Ew8KvBottleneck => "EW8",
            Ew9EarlyStopSkew => "EW9",
            Dp1RouterFlowSkew => "DP1",
            Dp2HotReplicaKv => "DP2",
            Dp3StragglerReplica => "DP3",
            Pd1PrefillSaturation => "PD1",
            Pd2KvHandoffStall => "PD2",
            Pd3DecodeStarvation => "PD3",
            Td1StaleFrozen => "TD1",
            Td2LossyDrop => "TD2",
            Td3LaggingDelivery => "TD3",
        }
    }

    /// Which runbook table the condition belongs to ("3a"-"3c" are the
    /// paper's; "dp" is the data-parallel fleet extension, "pd" the
    /// phase-disaggregation family, "td" the telemetry-dropout family).
    pub fn table(&self) -> &'static str {
        let id = self.id();
        if id.starts_with("NS") {
            "3a"
        } else if id.starts_with("PC") {
            "3b"
        } else if id.starts_with("EW") {
            "3c"
        } else if id.starts_with("DP") {
            "dp"
        } else if id.starts_with("TD") {
            "td"
        } else {
            "pd"
        }
    }

    pub fn from_id(id: &str) -> Option<Condition> {
        ALL_CONDITIONS
            .iter()
            .chain(DP_CONDITIONS.iter())
            .chain(PD_CONDITIONS.iter())
            .chain(TD_CONDITIONS.iter())
            .copied()
            .find(|c| c.id() == id)
    }
}

/// A fired detection.
#[derive(Debug, Clone)]
pub struct Detection {
    pub condition: Condition,
    pub node: NodeId,
    pub at: SimTime,
    /// Anomaly magnitude (z-score-like; larger = stronger).
    pub severity: f64,
    /// Human-readable evidence string for the report.
    pub evidence: String,
}

/// Healthy-baseline model: per-feature mean/std learned during calibration.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    feats: HashMap<&'static str, Welford>,
    pub windows_observed: u64,
    frozen: bool,
}

impl Baseline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a feature sample (calibration phase only).
    pub fn observe(&mut self, name: &'static str, value: f64) {
        if !self.frozen {
            self.feats.entry(name).or_default().push(value);
        }
    }

    pub fn end_window(&mut self) {
        if !self.frozen {
            self.windows_observed += 1;
        }
    }

    /// Stop learning; z-scores become stable.
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// z-score of `value` against the learned distribution of `name`.
    /// The std is floored at 10% of |mean| (and an absolute epsilon) so
    /// near-constant healthy features don't explode into infinite z.
    pub fn z(&self, name: &'static str, value: f64) -> f64 {
        match self.feats.get(name) {
            None => 0.0,
            Some(w) if w.count() < 3 => 0.0,
            Some(w) => {
                let floor = (0.1 * w.mean().abs()).max(1e-6);
                (value - w.mean()) / w.std().max(floor)
            }
        }
    }

    pub fn mean(&self, name: &'static str) -> f64 {
        self.feats.get(name).map(|w| w.mean()).unwrap_or(0.0)
    }

    /// Largest value seen during calibration (heavy-tail guard).
    pub fn max_seen(&self, name: &'static str) -> f64 {
        self.feats.get(name).map(|w| w.max()).unwrap_or(0.0)
    }

    /// Ratio of `value` to the calibration max (one-sided anomaly gate for
    /// heavy-tailed features like max-gaps and spreads). 0 when unknown.
    pub fn above_max(&self, name: &'static str, value: f64) -> f64 {
        match self.feats.get(name) {
            // A zero calibration max means the feature never moved when
            // healthy — any positive value is infinitely beyond it.
            Some(w) if w.count() >= 3 => value / w.max().max(1e-9),
            _ => 0.0,
        }
    }

    pub fn has(&self, name: &'static str) -> bool {
        self.feats.get(name).map(|w| w.count() >= 3).unwrap_or(false)
    }
}

/// Static context shared by detectors (line rates for saturation checks).
#[derive(Debug, Clone)]
pub struct DetectConfig {
    /// NIC line rate, bytes/sec (NS9 threshold).
    pub nic_bw: f64,
    /// Fire threshold on z-scores.
    pub z_fire: f64,
}

impl Default for DetectConfig {
    fn default() -> Self {
        DetectConfig { nic_bw: 50e9, z_fire: 4.0 }
    }
}

/// Everything a detector sees at a window tick.
pub struct DetectCtx<'a> {
    pub snap: &'a WindowSnapshot,
    pub baseline: &'a Baseline,
    /// Recent prior snapshots, newest last (trend detectors).
    pub history: &'a [WindowSnapshot],
    pub cfg: &'a DetectConfig,
}

/// One runbook-row detector. `Send + Sync` because the registry is shared
/// read-only across the parallel per-window observe path (detectors are
/// stateless — all mutable state lives in the per-node `Agent`).
pub trait Detector: Send + Sync {
    fn condition(&self) -> Condition;
    /// Update the baseline with this window's features (calibration phase).
    fn calibrate(&self, snap: &WindowSnapshot, baseline: &mut Baseline);
    /// Check one window; return a detection if the red flag fires.
    fn check(&self, ctx: &DetectCtx) -> Option<Detection>;
}

/// The full 28-detector registry, runbook order.
pub fn all_detectors() -> Vec<Box<dyn Detector>> {
    let mut v: Vec<Box<dyn Detector>> = Vec::with_capacity(28);
    v.extend(north_south::detectors());
    v.extend(pcie::detectors());
    v.extend(east_west::detectors());
    v
}

/// Helper: build a Detection from snapshot context.
pub(crate) fn fire(
    condition: Condition,
    snap: &WindowSnapshot,
    severity: f64,
    evidence: String,
) -> Option<Detection> {
    Some(Detection { condition, node: snap.node, at: snap.end, severity, evidence })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_28_uniquely() {
        let dets = all_detectors();
        assert_eq!(dets.len(), 28);
        let mut seen = std::collections::HashSet::new();
        for d in &dets {
            assert!(seen.insert(d.condition()), "duplicate {:?}", d.condition());
        }
        for c in ALL_CONDITIONS {
            assert!(seen.contains(&c), "missing detector for {c:?}");
        }
    }

    #[test]
    fn condition_ids_roundtrip() {
        for c in ALL_CONDITIONS {
            assert_eq!(Condition::from_id(c.id()), Some(c));
        }
        for c in DP_CONDITIONS.into_iter().chain(PD_CONDITIONS).chain(TD_CONDITIONS) {
            assert_eq!(Condition::from_id(c.id()), Some(c));
        }
        assert_eq!(Condition::from_id("XX"), None);
        assert_eq!(Condition::Ns1BurstBacklog.table(), "3a");
        assert_eq!(Condition::Pc5PcieSaturation.table(), "3b");
        assert_eq!(Condition::Ew8KvBottleneck.table(), "3c");
        assert_eq!(Condition::Dp1RouterFlowSkew.table(), "dp");
        assert_eq!(Condition::Pd2KvHandoffStall.table(), "pd");
        assert_eq!(Condition::Td1StaleFrozen.table(), "td");
        // The DP/PD/TD families stay off the per-node detector diagonal.
        for c in DP_CONDITIONS.into_iter().chain(PD_CONDITIONS).chain(TD_CONDITIONS) {
            assert!(!ALL_CONDITIONS.contains(&c));
        }
    }

    #[test]
    fn baseline_z_scores() {
        let mut b = Baseline::new();
        for i in 0..50 {
            b.observe("x", 100.0 + (i % 5) as f64);
        }
        b.freeze();
        assert!(b.z("x", 102.0).abs() < 1.0);
        assert!(b.z("x", 200.0) > 5.0);
        assert_eq!(b.z("unknown", 42.0), 0.0);
        // frozen: further observes are ignored
        b.observe("x", 1e9);
        assert!(b.z("x", 102.0).abs() < 1.0);
    }

    #[test]
    fn baseline_floors_constant_features() {
        let mut b = Baseline::new();
        for _ in 0..10 {
            b.observe("c", 50.0);
        }
        // std=0 -> floored at 10% of mean -> z = (55-50)/5 = 1
        assert!((b.z("c", 55.0) - 1.0).abs() < 1e-9);
    }
}
