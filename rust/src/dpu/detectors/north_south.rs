//! Table 3(a) detectors — the North-South runbook: conditions visible at
//! the ingress/egress NIC from the DPU's bump-in-the-wire vantage.

use super::{fire, Baseline, Condition, DetectCtx, Detection, Detector};
use crate::telemetry::window::WindowSnapshot;

pub fn detectors() -> Vec<Box<dyn Detector>> {
    vec![
        Box::new(BurstBacklog),
        Box::new(IngressStarvation),
        Box::new(FlowSkew),
        Box::new(IngressRetx),
        Box::new(EgressBacklog),
        Box::new(EgressJitter),
        Box::new(EgressRetx),
        Box::new(EarlyCompletion),
        Box::new(BandwidthSaturation),
    ]
}

/// NS1 — sudden ingress spikes followed by queueing delay.
pub struct BurstBacklog;

impl Detector for BurstBacklog {
    fn condition(&self) -> Condition {
        Condition::Ns1BurstBacklog
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns1.rx_qdepth", s.nic_rx_qdepth.mean());
        b.observe("ns1.rx_gap_cov", s.nic_rx_gap_ns.cov());
        b.observe("ns1.rx_count", s.nic_rx_count as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.nic_rx_count < 4 {
            return None;
        }
        let z_q = ctx.baseline.z("ns1.rx_qdepth", s.nic_rx_qdepth.mean());
        let z_burst = ctx.baseline.z("ns1.rx_gap_cov", s.nic_rx_gap_ns.cov());
        let z_cnt = ctx.baseline.z("ns1.rx_count", s.nic_rx_count as f64);
        // Two routes to the red flag: queue buildup with bursty arrivals, or
        // an outright arrival-count spike with burst-shaped gaps (the NIC
        // queue may absorb short spikes that still clump downstream load).
        if (z_q > ctx.cfg.z_fire && (z_burst > 1.5 || z_cnt > 1.5))
            || (z_cnt > ctx.cfg.z_fire && z_burst > ctx.cfg.z_fire)
        {
            return fire(
                self.condition(),
                s,
                z_q,
                format!(
                    "RX queue depth {:.1} (z={:.1}), inter-arrival CoV {:.2} (z={:.1}), {} pkts",
                    s.nic_rx_qdepth.mean(),
                    z_q,
                    s.nic_rx_gap_ns.cov(),
                    z_burst,
                    s.nic_rx_count
                ),
            );
        }
        None
    }
}

/// NS2 — long gaps between ingress packets while queues sit empty.
pub struct IngressStarvation;

impl Detector for IngressStarvation {
    fn condition(&self) -> Condition {
        Condition::Ns2IngressStarvation
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns2.rx_count", s.nic_rx_count as f64);
        b.observe("ns2.rx_gap_max", s.nic_rx_gap_ns.max());
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        // Starvation is a *persistent absence*: the feed goes silent for
        // windows at a time on a NIC that normally sees steady arrivals.
        let base_count = ctx.baseline.mean("ns2.rx_count");
        if base_count >= 2.0 && s.nic_rx_count == 0 {
            return fire(
                self.condition(),
                s,
                base_count,
                format!("zero ingress this window vs {base_count:.1}/window baseline"),
            );
        }
        // Or: a resuming burst after an anomalously long silence.
        let z_gap = ctx.baseline.z("ns2.rx_gap_max", s.nic_rx_gap_ns.max());
        let beyond = ctx.baseline.above_max("ns2.rx_gap_max", s.nic_rx_gap_ns.max());
        if s.nic_rx_count >= 1 && z_gap > ctx.cfg.z_fire && beyond > 3.0 {
            return fire(
                self.condition(),
                s,
                z_gap,
                format!("ingress resumed after {:.1}ms silence", s.nic_rx_gap_ns.max() / 1e6),
            );
        }
        None
    }
}

/// NS3 — some ingress flows high-volume, others sparse.
pub struct FlowSkew;

impl Detector for FlowSkew {
    fn condition(&self) -> Condition {
        Condition::Ns3FlowSkew
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.top_flow_share > 0.0 {
            b.observe("ns3.top_share", s.top_flow_share);
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.top_flow_share <= 0.0 {
            return None;
        }
        let z = ctx.baseline.z("ns3.top_share", s.top_flow_share);
        let beyond = ctx.baseline.above_max("ns3.top_share", s.top_flow_share);
        if z > ctx.cfg.z_fire && beyond > 1.3 {
            return fire(
                self.condition(),
                s,
                z,
                format!(
                    "hottest flow owns {:.0}% of ingress bytes (z={:.1})",
                    s.top_flow_share * 100.0,
                    z
                ),
            );
        }
        None
    }
}

/// NS4 — missing/retransmitted ingress packets.
pub struct IngressRetx;

impl Detector for IngressRetx {
    fn condition(&self) -> Condition {
        Condition::Ns4IngressRetx
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns4.retx_in", s.retx_in as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        // Loss events are sparse; accumulate over the recent past.
        let recent: u64 = s.retx_in
            + ctx.history.iter().rev().take(4).map(|h| h.retx_in).sum::<u64>();
        let z = ctx.baseline.z("ns4.retx_in", s.retx_in as f64);
        if recent >= 3 && s.retx_in >= 1 && z > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z,
                format!("{} ingress retransmits, {} drops (z={:.1})", s.retx_in, s.drop_in, z),
            );
        }
        None
    }
}

/// NS5 — responses accumulate in NIC TX queues before send.
pub struct EgressBacklog;

impl Detector for EgressBacklog {
    fn condition(&self) -> Condition {
        Condition::Ns5EgressBacklog
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns5.tx_wait", s.nic_tx_wait_ns.mean());
        b.observe("ns5.tx_qdepth", s.nic_tx_qdepth.mean());
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.nic_tx_count < 4 {
            return None;
        }
        let z_wait = ctx.baseline.z("ns5.tx_wait", s.nic_tx_wait_ns.mean());
        let z_q = ctx.baseline.z("ns5.tx_qdepth", s.nic_tx_qdepth.mean());
        // Systemic pre-wire delay: mean wait inflated with LOW dispersion
        // (a copy bottleneck delays every response uniformly; contrast
        // NS6's jitter, which blows up the variance instead).
        let wait_cov = s.nic_tx_wait_ns.cov();
        if z_wait > ctx.cfg.z_fire && wait_cov < 0.6 && z_q > -1.0 {
            return fire(
                self.condition(),
                s,
                z_wait,
                format!(
                    "TX queue wait {:.0}us (z={:.1}), depth {:.1}",
                    s.nic_tx_wait_ns.mean() / 1e3,
                    z_wait,
                    s.nic_tx_qdepth.mean()
                ),
            );
        }
        None
    }
}

/// NS6 — outgoing packets for a token stream spread unevenly in time.
pub struct EgressJitter;

impl Detector for EgressJitter {
    fn condition(&self) -> Condition {
        Condition::Ns6EgressJitter
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        if s.nic_tx_count > 0 {
            b.observe("ns6.wait_cov", s.nic_tx_wait_ns.cov());
            b.observe("ns6.wait_mean", s.nic_tx_wait_ns.mean());
        }
        if s.egress_jitter_cov > 0.0 {
            b.observe("ns6.jitter_cov", s.egress_jitter_cov);
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        if s.nic_tx_count < 8 {
            return None;
        }
        // Scheduler variance: send-path delay becomes *erratic* — wait mean
        // AND dispersion inflate together (vs NS5's uniform copy delay).
        let z_mean = ctx.baseline.z("ns6.wait_mean", s.nic_tx_wait_ns.mean());
        let z_cov = ctx.baseline.z("ns6.wait_cov", s.nic_tx_wait_ns.cov());
        let z_flow = ctx.baseline.z("ns6.jitter_cov", s.egress_jitter_cov);
        if (z_mean > ctx.cfg.z_fire && z_cov > 1.5 && s.nic_tx_wait_ns.cov() > 0.6)
            || z_flow > 2.0 * ctx.cfg.z_fire
        {
            return fire(
                self.condition(),
                s,
                z_mean.max(z_flow),
                format!(
                    "TX wait {:.0}us CoV {:.2} (z mean={:.1}, cov={:.1}), per-flow cadence z={:.1}",
                    s.nic_tx_wait_ns.mean() / 1e3,
                    s.nic_tx_wait_ns.cov(),
                    z_mean,
                    z_cov,
                    z_flow
                ),
            );
        }
        None
    }
}

/// NS7 — retransmissions/gaps in final response streams.
pub struct EgressRetx;

impl Detector for EgressRetx {
    fn condition(&self) -> Condition {
        Condition::Ns7EgressRetx
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns7.retx_out", s.retx_out as f64);
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let recent: u64 = s.retx_out
            + ctx.history.iter().rev().take(4).map(|h| h.retx_out).sum::<u64>();
        let z = ctx.baseline.z("ns7.retx_out", s.retx_out as f64);
        if recent >= 3 && s.retx_out >= 1 && z > ctx.cfg.z_fire {
            return fire(
                self.condition(),
                s,
                z,
                format!("{} egress retransmits, {} drops (z={:.1})", s.retx_out, s.drop_out, z),
            );
        }
        None
    }
}

/// NS8 — some egress flows terminate far earlier than their peers.
pub struct EarlyCompletion;

impl Detector for EarlyCompletion {
    fn condition(&self) -> Condition {
        Condition::Ns8EarlyCompletion
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns8.early_ends", s.early_end_count as f64);
        if s.end_len_ratio < 1.0 {
            b.observe("ns8.end_ratio", s.end_len_ratio);
        }
        if s.ended_len_cov > 0.0 {
            b.observe("ns8.end_cov", s.ended_len_cov);
        }
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let z = ctx.baseline.z("ns8.early_ends", s.early_end_count as f64);
        // Ended streams are dramatically shorter than their still-running
        // peers (bimodal completion shape).
        let z_ratio = ctx.baseline.z("ns8.end_ratio", s.end_len_ratio);
        let z_cov = ctx.baseline.z("ns8.end_cov", s.ended_len_cov);
        if (s.early_end_count >= 2 && s.active_flows >= 2 && z > ctx.cfg.z_fire)
            || (s.flow_ends >= 2
                && s.active_flows >= 2
                && s.end_len_ratio < 0.3
                && z_ratio < -2.0)
            || (s.flow_ends >= 3 && s.ended_len_cov > 0.8 && z_cov > ctx.cfg.z_fire)
        {
            return fire(
                self.condition(),
                s,
                z.max(-z_ratio),
                format!(
                    "{} flows ended; completion-length CoV {:.2} (z={:.1}), \
                     end/peer ratio {:.0}%, {} peers active",
                    s.flow_ends,
                    s.ended_len_cov,
                    z_cov,
                    s.end_len_ratio * 100.0,
                    s.active_flows
                ),
            );
        }
        None
    }
}

/// NS9 — NIC RX/TX at or near line capacity with queue buildup.
pub struct BandwidthSaturation;

impl Detector for BandwidthSaturation {
    fn condition(&self) -> Condition {
        Condition::Ns9BandwidthSaturation
    }

    fn calibrate(&self, s: &WindowSnapshot, b: &mut Baseline) {
        b.observe("ns9.tx_qdepth", s.nic_tx_qdepth.mean());
    }

    fn check(&self, ctx: &DetectCtx) -> Option<Detection> {
        let s = ctx.snap;
        let line = ctx.cfg.nic_bw;
        let rx_frac = s.rx_byte_rate() / line;
        let tx_frac = s.tx_byte_rate() / line;
        let frac = rx_frac.max(tx_frac);
        let z_q = ctx.baseline.z("ns9.tx_qdepth", s.nic_tx_qdepth.mean());
        if frac > 0.75 && z_q > 1.5 {
            return fire(
                self.condition(),
                s,
                frac * 4.0,
                format!(
                    "NIC at {:.0}% line rate (rx {:.0}%, tx {:.0}%), TX queue z={:.1}",
                    frac * 100.0,
                    rx_frac * 100.0,
                    tx_frac * 100.0,
                    z_q
                ),
            );
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::NodeId;
    use crate::sim::SimTime;
    use crate::telemetry::window::WindowSnapshot;
    use crate::util::stats::Welford;

    fn healthy_snap() -> WindowSnapshot {
        let mut s = WindowSnapshot::default();
        s.node = NodeId(0);
        s.start = SimTime(0);
        s.end = SimTime(1_000_000);
        s.nic_rx_count = 50;
        s.nic_tx_count = 50;
        let mut q = Welford::new();
        for _ in 0..50 {
            q.push(2.0);
        }
        s.nic_rx_qdepth = q.clone();
        s.nic_tx_qdepth = q.clone();
        let mut gap = Welford::new();
        for i in 0..50 {
            gap.push(20_000.0 + (i % 3) as f64 * 1000.0);
        }
        s.nic_rx_gap_ns = gap.clone();
        s.nic_tx_gap_ns = gap;
        let mut w = Welford::new();
        for _ in 0..50 {
            w.push(1_000.0);
        }
        s.nic_tx_wait_ns = w;
        s
    }

    fn calibrated(det: &dyn Detector, n: usize) -> Baseline {
        let mut b = Baseline::new();
        for _ in 0..n {
            det.calibrate(&healthy_snap(), &mut b);
            b.end_window();
        }
        b.freeze();
        b
    }

    #[test]
    fn ns1_fires_on_burst_not_on_healthy() {
        let det = BurstBacklog;
        let b = calibrated(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let healthy = healthy_snap();
        let ctx = DetectCtx { snap: &healthy, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        // Pathological: queue depth 40, bursty gaps
        let mut s = healthy_snap();
        let mut q = Welford::new();
        for _ in 0..50 {
            q.push(40.0);
        }
        s.nic_rx_qdepth = q;
        let mut gap = Welford::new();
        for i in 0..50 {
            gap.push(if i % 10 == 0 { 500_000.0 } else { 100.0 });
        }
        s.nic_rx_gap_ns = gap;
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        let d = det.check(&ctx).expect("should fire");
        assert!(d.severity > 3.0);
        assert_eq!(d.condition.id(), "NS1");
    }

    #[test]
    fn ns4_needs_absolute_floor() {
        let det = IngressRetx;
        let b = calibrated(&det, 20);
        let cfg = super::super::DetectConfig::default();
        let mut s = healthy_snap();
        s.retx_in = 2; // below floor of 3
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        s.retx_in = 20;
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn ns9_requires_both_rate_and_queue() {
        let det = BandwidthSaturation;
        let b = calibrated(&det, 20);
        let cfg = super::super::DetectConfig::default();
        // High rate but healthy queue: no fire.
        let mut s = healthy_snap();
        s.nic_rx_bytes = (0.9 * cfg.nic_bw * 0.001) as u64; // 90% over 1ms window
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_none());
        // Rate + queue buildup: fire.
        let mut q = Welford::new();
        for _ in 0..50 {
            q.push(64.0);
        }
        s.nic_tx_qdepth = q;
        let ctx = DetectCtx { snap: &s, baseline: &b, history: &[], cfg: &cfg };
        assert!(det.check(&ctx).is_some());
    }

    #[test]
    fn all_nine_present() {
        assert_eq!(detectors().len(), 9);
    }
}
