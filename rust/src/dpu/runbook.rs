//! The runbooks of paper Tables 3(a)-(c), encoded: for every condition, the
//! red-flag signal, affected lifecycle stages, node↔node effect, likely root
//! cause, and the mitigation directive. This is the lookup the closed loop
//! uses, and `metrics::report` renders it back out as the paper tables.

use crate::dpu::detectors::Condition;
use crate::mitigation::directive::Directive;

/// One runbook row (a paper table row).
#[derive(Debug, Clone)]
pub struct RunbookEntry {
    pub condition: Condition,
    pub signal: &'static str,
    pub stages: &'static str,
    pub effect: &'static str,
    pub root_cause: &'static str,
    pub directive: Directive,
}

/// Look up the runbook row for a condition.
pub fn entry(c: Condition) -> RunbookEntry {
    use Condition::*;
    let (signal, stages, effect, root_cause, directive) = match c {
        Ns1BurstBacklog => (
            "Sudden ingress spikes followed by queueing delay",
            "Ingress (prefill/start)",
            "Downstream GPU sees uneven load; internode bursts clump",
            "Client load spike, front-end batching, NIC queue limits",
            Directive::SmoothAdmission,
        ),
        Ns2IngressStarvation => (
            "Long gaps between ingress packets for some tokens",
            "Ingress -> PCIe feed",
            "Token stalls; fewer collective ops downstream",
            "Upstream service jitter, uneven client distribution",
            Directive::RebalanceFlows,
        ),
        Ns3FlowSkew => (
            "Some ingress flows high-volume, others sparse",
            "Ingress (per-request)",
            "Imbalanced TP/PP participation across tokens",
            "Session affinity mismatch, QUIC stream imbalance",
            Directive::RebalanceFlows,
        ),
        Ns4IngressRetx => (
            "Missing or retransmitted initial packets",
            "Ingress (request birth)",
            "Token ID not consistently assigned; lifecycle gaps",
            "Congestion, MTU mismatch, link errors",
            Directive::FixIngressPath,
        ),
        Ns5EgressBacklog => (
            "Responses accumulate in NIC queues before send",
            "Egress (response flush)",
            "Downstream clients see latency spikes",
            "CPU copy bottleneck, NIC buffer exhaustion",
            Directive::ZeroCopyEgress,
        ),
        Ns6EgressJitter => (
            "Outgoing packets for a token spread unevenly over time",
            "Egress (decode outputs)",
            "Clients see irregular token cadence",
            "Scheduler variance, CPU<->NIC contention",
            Directive::PinIrqsIsolateThreads,
        ),
        Ns7EgressRetx => (
            "Retransmissions or gaps in final response streams",
            "Egress",
            "Client-visible stalls; retries inflate latency",
            "NIC offload misconfig, fabric congestion, buffer underrun",
            Directive::FixEgressPath,
        ),
        Ns8EarlyCompletion => (
            "Some egress flows terminate far earlier than peers",
            "Egress (multi-stream decode)",
            "Internode peers still busy; imbalance in final stages",
            "Early-stop on short sequences; no remap of freed resources",
            Directive::EnableInflightRemap,
        ),
        Ns9BandwidthSaturation => (
            "NIC RX/TX at or near link capacity; queue buildup",
            "Ingress + Egress",
            "All internode phases elongated; cluster-level slowdown",
            "Shared NIC with storage/other jobs; insufficient link",
            Directive::QosPartitionNic,
        ),
        Pc1H2dStarvation => (
            "Large/clustered H2D DMAs then long gaps before doorbells",
            "Ingress -> PCIe (prefill & decode input feed)",
            "Fewer/late internode bursts; downstream TP/PP idles",
            "PCIe BW cap, NUMA miss, pageable (unpinned) host buffers",
            Directive::PinMemoryPools,
        ),
        Pc2D2hBottleneck => (
            "D2H DMAs linger / complete slowly; backlog after kernels",
            "Egress (logits/tokens back to host)",
            "Late responses; backpressure into next token step",
            "PCIe saturation, IOMMU contention, CPU copy hotspots",
            Directive::FixReturnPath,
        ),
        Pc3LaunchLatency => (
            "Doorbells sporadic; idle gaps between H2D bursts and launch",
            "Compute (GPU underutilized across prefill/decode)",
            "TP collectives delayed, PP handoffs drift",
            "Runtime overhead, CPU scheduler delays, too many tiny kernels",
            Directive::FuseKernelsIsolateCpu,
        ),
        Pc4IntraNodeSkew => (
            "One GPU shows thin/irregular DMA; peers steady",
            "Compute (per-layer) -> propagates to internode",
            "TP collectives widen (straggler), PP stage misalignment",
            "Uneven microbatching, memory pressure on a single GPU",
            Directive::RebalanceShards,
        ),
        Pc5PcieSaturation => (
            "Sustained near-peak PCIe throughput; compute stalls periodically",
            "Ingress -> PCIe, Egress",
            "Burstiness in internode waves; elongates token step",
            "Oversubscribed PCIe switch / x8 link, competing DMAs",
            Directive::MovePcieTenants,
        ),
        Pc6P2pThrottling => (
            "P2P DMAs slow/variable; no NVLink path",
            "Compute (intra-box TP/PP)",
            "Internode timing jitter (collectives wait on slow intra-box move)",
            "Shared uplink on PCIe switch; ACS/ATS settings",
            Directive::PreferNvlink,
        ),
        Pc7PinnedShortage => (
            "Many small DMAs vs large coalesced; rising DMA count",
            "Ingress -> PCIe (feed) and Egress (returns)",
            "Micro-jitter; uneven stage timing",
            "Insufficient pinned pools; fallback to pageable",
            Directive::PinMemoryPools,
        ),
        Pc8HostCpuBottleneck => (
            "Low DMA rate despite available PCIe BW; delayed doorbells",
            "Compute orchestration",
            "Irregular TP cadence; PP bubbles",
            "CPU contention, IRQ affinity, polling disabled",
            Directive::FuseKernelsIsolateCpu,
        ),
        Pc9RegistrationChurn => (
            "Frequent map/unmap patterns around DMAs",
            "Ingress -> PCIe",
            "Small timing gaps accumulating per token",
            "Repeated registration due to short-lived buffers",
            Directive::PersistentRegistration,
        ),
        Pc10DecodeEarlyStop => (
            "D2H drops off early on some streams/GPUs",
            "Compute (decode) -> Egress",
            "Some peers go silent; collectives wait for remaining peers",
            "Sequence length variance; scheduler not rebalancing",
            Directive::EnableInflightRemap,
        ),
        Ew1TpStraggler => (
            "Wide arrival spread of collective bursts (max-min gap up)",
            "Compute (tensor-parallel collectives)",
            "Collective ops stall waiting for slowest peer",
            "Skewed GPU load, PCIe starvation, memory imbalance on one node",
            Directive::RebalanceShards,
        ),
        Ew2PpBubble => (
            "Large or growing gaps between stage handoff bursts",
            "Pipeline parallel",
            "Downstream stage idles; upstream builds backlog",
            "Load imbalance across pipeline stages, early token exit variance",
            Directive::RebalanceStages,
        ),
        Ew3CrossNodeSkew => (
            "Uneven traffic volume per node for same collective",
            "TP/PP compute -> internode",
            "Some nodes oversend/undersend; throughput uneven",
            "Shard imbalance, misaligned activation partitioning",
            Directive::RebalanceAcrossNodes,
        ),
        Ew4Congestion => (
            "Periodic spikes in latency + jitter across many links",
            "Internode transfers (collectives & stage handoff)",
            "Token step elongates cluster-wide",
            "Fat-tree oversubscription, ToR link hot spot",
            Directive::AdaptiveRouting,
        ),
        Ew5HolBlocking => (
            "Some streams stall while others flow; out-of-order bursts",
            "Collective streams / P2P flows",
            "Latency-sensitive ops delayed",
            "Shared queue depth exhaustion, RoCE/NIC queue imbalance",
            Directive::FixQueueSharing,
        ),
        Ew6Retransmissions => (
            "Gaps + duplicate traffic or sudden retransmit storms",
            "All distributed phases",
            "Bursty latency; collectives jitter",
            "Fabric errors, congestion collapse, misconfigured PFC",
            Directive::LosslessFabricConfig,
        ),
        Ew7CreditStarvation => (
            "Long silence periods until remote credit update",
            "Internode (RDMA ops)",
            "Under-utilized links; token latency grows",
            "Too-small RDMA window, NIC credit depletion",
            Directive::TuneCreditWindow,
        ),
        Ew8KvBottleneck => (
            "Repeated large bursts for some tokens, others silent",
            "Decode phase (PP handoff)",
            "Uneven memory pressure per stage; downstream skew",
            "Sharded KV too large for link budget; non-uniform length",
            Directive::CompressKvTransfers,
        ),
        Ew9EarlyStopSkew => (
            "Some nodes stop sending mid-iteration while others continue",
            "Decode (multi-node)",
            "Collectives/pipeline hang waiting for peers",
            "Sequence length divergence; scheduler not masking early exits",
            Directive::EnableInflightRemap,
        ),
        // ---- data-parallel fleet extension (router/LB vantage) ----
        Dp1RouterFlowSkew => (
            "One replica's routed-arrival share far exceeds hash-fair share",
            "Ingress routing (data-parallel)",
            "Hot replica queues while peers idle; fleet capped by one replica",
            "Session-affinity hashing + heavy-tailed session popularity",
            Directive::RebalanceFlows,
        ),
        Dp2HotReplicaKv => (
            "One replica's KV pinned at capacity with admission failures",
            "Decode admission (data-parallel)",
            "Hot replica thrashes admissions; its flows see inflated TTFT",
            "KV fragmentation/leak or flow concentration on one replica",
            Directive::KvAwareRouting,
        ),
        Dp3StragglerReplica => (
            "A replica's backlog dominates while its iteration rate lags",
            "All phases on one replica (data-parallel)",
            "Affinity keeps feeding the slow replica; it dominates fleet p99",
            "Degraded node(s) in one replica: thermal/power/faulty GPU",
            Directive::DrainStragglerReplica,
        ),
        // ---- phase-disaggregation extension (pool-boundary vantage) ----
        Pd1PrefillSaturation => (
            "Prefill-pool admission backlog grows while decode slots idle",
            "Prefill pool (admission -> first token)",
            "TTFT inflates fleet-wide; decode pool starves for handoffs",
            "Prompt-heavy demand vs prefill pool sizing (roles misprovisioned)",
            Directive::RebalancePools,
        ),
        Pd2KvHandoffStall => (
            "KV-handoff fabric latency far above line-rate expectation",
            "Phase transition (prefill -> decode pool)",
            "Sequences pile up between pools; decode admission runs dry",
            "Handoff link budget collapse: congestion, misrouted path, QoS",
            Directive::CompressKvTransfers,
        ),
        Pd3DecodeStarvation => (
            "KV handoffs concentrate on one decode replica; peers starve",
            "Phase transition routing (decode pool)",
            "One decode replica saturates its slots while peers sit idle",
            "Wedged/skewed handoff routing after a config or failover event",
            Directive::RebalanceHandoffRouting,
        ),
    };
    RunbookEntry { condition: c, signal, stages, effect, root_cause, directive }
}

/// All runbook rows, table order: the paper's 28 plus the DP fleet family
/// and the PD phase-disaggregation family.
pub fn all_entries() -> Vec<RunbookEntry> {
    crate::dpu::detectors::ALL_CONDITIONS
        .iter()
        .chain(crate::dpu::detectors::DP_CONDITIONS.iter())
        .chain(crate::dpu::detectors::PD_CONDITIONS.iter())
        .map(|&c| entry(c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::detectors::ALL_CONDITIONS;

    #[test]
    fn runbook_is_complete() {
        use crate::dpu::detectors::{DP_CONDITIONS, PD_CONDITIONS};
        let entries = all_entries();
        assert_eq!(entries.len(), 34);
        for (c, e) in ALL_CONDITIONS
            .iter()
            .chain(DP_CONDITIONS.iter())
            .chain(PD_CONDITIONS.iter())
            .zip(&entries)
        {
            assert_eq!(*c, e.condition);
            assert!(!e.signal.is_empty());
            assert!(!e.stages.is_empty());
            assert!(!e.effect.is_empty());
            assert!(!e.root_cause.is_empty());
        }
    }

    #[test]
    fn pd_family_has_pool_level_directives() {
        assert_eq!(entry(Condition::Pd1PrefillSaturation).directive, Directive::RebalancePools);
        assert_eq!(
            entry(Condition::Pd3DecodeStarvation).directive,
            Directive::RebalanceHandoffRouting
        );
        // PD2 shares EW8's KV-transfer directive: the handoff IS a KV
        // transfer, just across the pool boundary.
        assert_eq!(entry(Condition::Pd2KvHandoffStall).directive, Directive::CompressKvTransfers);
    }

    #[test]
    fn early_stop_family_shares_remap_directive() {
        // Paper: NS8, PC10, EW9 all mitigate via inflight remapping.
        for c in [
            Condition::Ns8EarlyCompletion,
            Condition::Pc10DecodeEarlyStop,
            Condition::Ew9EarlyStopSkew,
        ] {
            assert_eq!(entry(c).directive, Directive::EnableInflightRemap);
        }
    }
}
