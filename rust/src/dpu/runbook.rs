//! The runbooks of paper Tables 3(a)-(c), as a stable view over the
//! condition catalog: for every condition, the red-flag signal, affected
//! lifecycle stages, node↔node effect, likely root cause, and the mitigation
//! directive. The knowledge itself lives in [`crate::conditions`] (one
//! `ConditionSpec` per condition); this module projects it into the shape
//! the closed loop and `metrics::report` render back out as paper tables —
//! no per-condition arms remain here.

use crate::dpu::detectors::Condition;
use crate::mitigation::directive::Directive;

/// One runbook row (a paper table row).
#[derive(Debug, Clone)]
pub struct RunbookEntry {
    pub condition: Condition,
    pub signal: &'static str,
    pub stages: &'static str,
    pub effect: &'static str,
    pub root_cause: &'static str,
    pub directive: Directive,
}

/// Look up the runbook row for a condition — a projection of its
/// [`crate::conditions::ConditionSpec`] catalog entry.
pub fn entry(c: Condition) -> RunbookEntry {
    let s = crate::conditions::spec(c);
    RunbookEntry {
        condition: c,
        signal: s.signal,
        stages: s.stages,
        effect: s.effect,
        root_cause: s.root_cause_text,
        directive: s.directive,
    }
}

/// All runbook rows, table order: the paper's 28 plus the DP fleet family
/// and the PD phase-disaggregation family.
pub fn all_entries() -> Vec<RunbookEntry> {
    crate::conditions::all_specs().map(|s| entry(s.condition)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::detectors::ALL_CONDITIONS;

    #[test]
    fn runbook_is_complete() {
        use crate::dpu::detectors::{DP_CONDITIONS, PD_CONDITIONS, TD_CONDITIONS};
        let entries = all_entries();
        assert_eq!(entries.len(), 37);
        for (c, e) in ALL_CONDITIONS
            .iter()
            .chain(DP_CONDITIONS.iter())
            .chain(PD_CONDITIONS.iter())
            .chain(TD_CONDITIONS.iter())
            .zip(&entries)
        {
            assert_eq!(*c, e.condition);
            assert!(!e.signal.is_empty());
            assert!(!e.stages.is_empty());
            assert!(!e.effect.is_empty());
            assert!(!e.root_cause.is_empty());
        }
    }

    #[test]
    fn pd_family_has_pool_level_directives() {
        assert_eq!(entry(Condition::Pd1PrefillSaturation).directive, Directive::RebalancePools);
        assert_eq!(
            entry(Condition::Pd3DecodeStarvation).directive,
            Directive::RebalanceHandoffRouting
        );
        // PD2 shares EW8's KV-transfer directive: the handoff IS a KV
        // transfer, just across the pool boundary.
        assert_eq!(entry(Condition::Pd2KvHandoffStall).directive, Directive::CompressKvTransfers);
    }

    #[test]
    fn early_stop_family_shares_remap_directive() {
        // Paper: NS8, PC10, EW9 all mitigate via inflight remapping.
        for c in [
            Condition::Ns8EarlyCompletion,
            Condition::Pc10DecodeEarlyStop,
            Condition::Ew9EarlyStopSkew,
        ] {
            assert_eq!(entry(c).directive, Directive::EnableInflightRemap);
        }
    }

    #[test]
    fn entries_project_the_catalog_verbatim() {
        for s in crate::conditions::all_specs() {
            let e = entry(s.condition);
            assert_eq!(e.signal, s.signal);
            assert_eq!(e.directive, s.directive);
        }
    }
}
