//! The paper's contribution: the DPU observability plane.
//!
//! Per-node agents tap the NIC + PCIe telemetry streams (and ONLY those —
//! `visibility` enforces §4.3's blindness to NVLink/intra-GPU/CPU-local
//! events), extract windowed features, run the 28 runbook detectors of
//! Tables 3(a)-(c), attribute root causes across vantage points (§4.2), and
//! hand mitigation directives to the controller.

pub mod agent;
pub mod attribution;
pub mod detectors;
pub mod fleet;
pub mod runbook;
pub mod scorer;
pub mod swdet;
pub mod visibility;
pub mod watchdog;

pub use agent::{Agent, DpuPlane};
pub use attribution::{attribute, Attribution, RootCause};
pub use detectors::{Baseline, Condition, DetectConfig, Detection, ALL_CONDITIONS, DP_CONDITIONS};
pub use fleet::{FleetSample, FleetSensor};
pub use runbook::{all_entries, entry, RunbookEntry};
pub use scorer::{NativeScorer, ScorerBackend};
pub use swdet::{SwAlarm, SwSuite};
pub use watchdog::FreshnessWatchdog;
