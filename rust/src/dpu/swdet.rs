//! Software-only observability baseline (the comparator for E5): detectors
//! that see ONLY the engine's own record-keeping (`telemetry::sw`), i.e.
//! what vLLM/TGI could do without a DPU.
//!
//! SW sensing notices *that* something is wrong (step times inflate, queues
//! grow) but — lacking PCIe/NIC vantage — mostly cannot say *which* runbook
//! condition is at fault. The bench reports both "noticed" and "identified".

use crate::dpu::detectors::Condition;
use crate::sim::SimTime;
use crate::telemetry::sw::{SwSignal, SwSnapshot};
use crate::util::stats::Welford;

/// Alarms a software-only observer can raise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SwAlarm {
    /// Request queue / wait time growth.
    QueueGrowth,
    /// Iteration (step) time inflated.
    StepTimeAnomaly,
    /// KV occupancy pressure.
    KvPressure,
    /// Arrival-rate burst.
    ArrivalBurst,
    /// Transport-level latency inflation (client-visible).
    TransportLatency,
    /// GPU under-utilization (NVML-style, coarse).
    GpuUnderutilized,
}

#[derive(Debug, Clone)]
pub struct SwDetection {
    pub alarm: SwAlarm,
    pub at: SimTime,
    pub severity: f64,
}

/// Which runbook conditions a SW alarm correctly *identifies* (vs merely
/// noticing). Encodes Table 2(b)'s "Use" column: software signals identify
/// application-level causes only.
pub fn identifies(alarm: SwAlarm) -> &'static [Condition] {
    match alarm {
        SwAlarm::QueueGrowth => &[Condition::Ns1BurstBacklog],
        SwAlarm::ArrivalBurst => &[Condition::Ns1BurstBacklog],
        SwAlarm::KvPressure => &[],
        SwAlarm::StepTimeAnomaly => &[],
        SwAlarm::TransportLatency => &[],
        SwAlarm::GpuUnderutilized => &[],
    }
}

/// Software-only detector suite with its own baseline.
#[derive(Debug, Clone, Default)]
pub struct SwSuite {
    base: [Welford; 6],
    calibrating: bool,
    pub detections: Vec<SwDetection>,
}

const Z_FIRE: f64 = 3.0;

impl SwSuite {
    pub fn new() -> Self {
        SwSuite { base: Default::default(), calibrating: true, detections: Vec::new() }
    }

    pub fn go_live(&mut self) {
        self.calibrating = false;
    }

    fn z(&self, i: usize, v: f64) -> f64 {
        let w = &self.base[i];
        if w.count() < 3 {
            return 0.0;
        }
        let floor = (0.1 * w.mean().abs()).max(1e-6);
        (v - w.mean()) / w.std().max(floor)
    }

    /// Feed one window's SW snapshot; returns alarms fired.
    pub fn window_tick(&mut self, snap: &SwSnapshot) -> Vec<SwDetection> {
        let feats = [
            snap.get(SwSignal::QueueDepth).mean(),
            snap.get(SwSignal::StepTime).mean(),
            snap.get(SwSignal::KvOccupancy).mean(),
            snap.get(SwSignal::RequestArrival).count() as f64,
            snap.get(SwSignal::TransportLatency).mean(),
            -snap.get(SwSignal::GpuUtil).mean(), // inverted: low util fires
        ];
        if self.calibrating {
            for (w, &f) in self.base.iter_mut().zip(&feats) {
                w.push(f);
            }
            return Vec::new();
        }
        let alarms = [
            SwAlarm::QueueGrowth,
            SwAlarm::StepTimeAnomaly,
            SwAlarm::KvPressure,
            SwAlarm::ArrivalBurst,
            SwAlarm::TransportLatency,
            SwAlarm::GpuUnderutilized,
        ];
        let mut fired = Vec::new();
        for (i, alarm) in alarms.iter().enumerate() {
            let z = self.z(i, feats[i]);
            if z > Z_FIRE {
                fired.push(SwDetection { alarm: *alarm, at: snap.end, severity: z });
            }
        }
        self.detections.extend(fired.iter().cloned());
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::sw::SwWindow;

    fn window(queue: f64, step: f64) -> SwSnapshot {
        let mut w = SwWindow::new();
        for _ in 0..10 {
            w.record(SwSignal::QueueDepth, queue);
            w.record(SwSignal::StepTime, step);
            w.record(SwSignal::KvOccupancy, 0.4);
            w.record(SwSignal::RequestArrival, 1.0);
            w.record(SwSignal::TransportLatency, 500.0);
            w.record(SwSignal::GpuUtil, 0.8);
        }
        w.snapshot(SimTime(1_000_000))
    }

    #[test]
    fn fires_on_queue_growth_after_calibration() {
        let mut suite = SwSuite::new();
        for _ in 0..20 {
            suite.window_tick(&window(3.0, 1000.0));
        }
        suite.go_live();
        assert!(suite.window_tick(&window(3.2, 1010.0)).is_empty());
        let fired = suite.window_tick(&window(80.0, 1000.0));
        assert!(fired.iter().any(|d| d.alarm == SwAlarm::QueueGrowth));
    }

    #[test]
    fn identification_mapping_is_narrow() {
        // SW alarms identify at most the application-level conditions.
        assert_eq!(identifies(SwAlarm::QueueGrowth), &[Condition::Ns1BurstBacklog]);
        assert!(identifies(SwAlarm::StepTimeAnomaly).is_empty());
        // No SW alarm identifies any PCIe-table condition.
        for alarm in [
            SwAlarm::QueueGrowth,
            SwAlarm::StepTimeAnomaly,
            SwAlarm::KvPressure,
            SwAlarm::ArrivalBurst,
            SwAlarm::TransportLatency,
            SwAlarm::GpuUnderutilized,
        ] {
            assert!(identifies(alarm).iter().all(|c| c.table() != "3b"));
        }
    }
}
