//! Root-cause attribution (paper §4.2): combine detections across vantage
//! points and nodes to decide *where* a skew originates — host-side (CPU,
//! PCIe, memory), GPU-side, network-side, or workload shape.
//!
//! "If one GPU consistently exhibits delayed PCIe activity after ingress,
//!  the DPU can attribute the slowdown to local imbalance rather than
//!  network effects. Conversely, if PCIe patterns are healthy but responses
//!  stall at egress, the issue is likely network-side."

use std::collections::BTreeMap;

use crate::dpu::detectors::{Condition, Detection};
use crate::ids::NodeId;

/// Where the root cause lives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RootCause {
    /// Host-side on a specific node: CPU, pinned memory, PCIe feed.
    HostLocal(NodeId),
    /// A specific node's GPU(s) lag (straggler).
    GpuSide(NodeId),
    /// The inter-node fabric or NIC path.
    NetworkSide,
    /// The workload's own shape (length variance, early stops).
    WorkloadShape,
    /// External clients / upstream services.
    ClientSide,
}

/// An attribution verdict with supporting evidence.
#[derive(Debug, Clone)]
pub struct Attribution {
    pub cause: RootCause,
    pub confidence: f64,
    pub conditions: Vec<Condition>,
    pub evidence: String,
}

/// A detection's default verdict comes from its catalog entry (the
/// `cause` mapping of [`crate::conditions::ConditionSpec`]) — no
/// per-condition arms live here.
fn default_cause(c: Condition, node: NodeId) -> RootCause {
    (crate::conditions::spec(c).cause)(node)
}

/// §4.2's refinement class: cross-node compute-skew conditions (EW1-EW3),
/// tagged in the catalog, which PCIe-vantage evidence localizes.
fn is_compute_skew(c: Condition) -> bool {
    crate::conditions::spec(c).compute_skew
}

/// Attribute a window's detections. The refinement rules implement §4.2:
///
/// * EW straggler + PCIe-vantage anomaly on a node ⇒ that node's host/GPU is
///   the root cause (high confidence) — not the network.
/// * EW straggler with *healthy* PCIe everywhere ⇒ network-side.
/// * PCIe anomalies alone stay host-local.
pub fn attribute(detections: &[Detection]) -> Vec<Attribution> {
    if detections.is_empty() {
        return Vec::new();
    }
    let mut by_node: BTreeMap<NodeId, Vec<&Detection>> = BTreeMap::new();
    for d in detections {
        by_node.entry(d.node).or_default().push(d);
    }

    let ew_compute: Vec<&Detection> =
        detections.iter().filter(|d| is_compute_skew(d.condition)).collect();
    let pcie_nodes: Vec<NodeId> = detections
        .iter()
        .filter(|d| d.condition.table() == "3b")
        .map(|d| d.node)
        .collect();

    let mut out = Vec::new();

    if !ew_compute.is_empty() {
        if let Some(&culprit) = pcie_nodes.first() {
            // §4.2 local-imbalance branch: PCIe evidence localizes the skew.
            let conds: Vec<Condition> = detections
                .iter()
                .filter(|d| d.node == culprit || !ew_compute.is_empty())
                .map(|d| d.condition)
                .collect();
            out.push(Attribution {
                cause: RootCause::GpuSide(culprit),
                confidence: 0.9,
                conditions: conds,
                evidence: format!(
                    "collective skew corroborated by PCIe-vantage anomaly on {culprit}: \
                     local imbalance, not network"
                ),
            });
        } else {
            // §4.2 network branch: healthy PCIe, stalling collectives.
            out.push(Attribution {
                cause: RootCause::NetworkSide,
                confidence: 0.75,
                conditions: ew_compute.iter().map(|d| d.condition).collect(),
                evidence: "collective skew with healthy PCIe on all nodes: network-side".into(),
            });
        }
    }

    // Remaining detections get their default attribution, grouped by cause.
    let mut grouped: BTreeMap<String, Attribution> = BTreeMap::new();
    for d in detections {
        if !ew_compute.is_empty() && is_compute_skew(d.condition) {
            continue; // already covered by the refined verdict
        }
        let cause = default_cause(d.condition, d.node);
        let key = format!("{cause:?}");
        let slot = grouped.entry(key).or_insert_with(|| Attribution {
            cause: cause.clone(),
            confidence: 0.6,
            conditions: Vec::new(),
            evidence: String::new(),
        });
        slot.conditions.push(d.condition);
        slot.confidence = (slot.confidence + 0.1).min(0.95);
        if !slot.evidence.is_empty() {
            slot.evidence.push_str("; ");
        }
        slot.evidence.push_str(&format!("{} @ {}", d.condition.id(), d.node));
    }
    out.extend(grouped.into_values());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn det(c: Condition, node: u32) -> Detection {
        Detection {
            condition: c,
            node: NodeId(node),
            at: SimTime(1000),
            severity: 5.0,
            evidence: "test".into(),
        }
    }

    #[test]
    fn straggler_with_pcie_evidence_is_local() {
        let ds = vec![det(Condition::Ew1TpStraggler, 0), det(Condition::Pc4IntraNodeSkew, 1)];
        let attr = attribute(&ds);
        assert!(attr
            .iter()
            .any(|a| a.cause == RootCause::GpuSide(NodeId(1)) && a.confidence >= 0.9));
    }

    #[test]
    fn straggler_without_pcie_evidence_is_network() {
        let ds = vec![det(Condition::Ew1TpStraggler, 0)];
        let attr = attribute(&ds);
        assert_eq!(attr.len(), 1);
        assert_eq!(attr[0].cause, RootCause::NetworkSide);
    }

    #[test]
    fn pcie_alone_is_host_local() {
        let ds = vec![det(Condition::Pc8HostCpuBottleneck, 2)];
        let attr = attribute(&ds);
        assert_eq!(attr.len(), 1);
        assert_eq!(attr[0].cause, RootCause::HostLocal(NodeId(2)));
    }

    #[test]
    fn early_stop_family_is_workload_shape() {
        let ds = vec![det(Condition::Ns8EarlyCompletion, 0), det(Condition::Pc10DecodeEarlyStop, 0)];
        let attr = attribute(&ds);
        assert!(attr.iter().any(|a| a.cause == RootCause::WorkloadShape));
    }

    #[test]
    fn td_family_attributes_to_the_export_path() {
        // Telemetry-dropout detections carry the catalog's network-side
        // verdict: the monitoring path (exporter -> oob channel -> DPU) is
        // fabric, not the node's serving plane.
        let ds = vec![det(Condition::Td1StaleFrozen, 1), det(Condition::Td3LaggingDelivery, 2)];
        let attr = attribute(&ds);
        assert_eq!(attr.len(), 1);
        assert_eq!(attr[0].cause, RootCause::NetworkSide);
        assert!(!attr[0].conditions.contains(&Condition::Td2LossyDrop));
    }

    #[test]
    fn empty_detections_empty_attribution() {
        assert!(attribute(&[]).is_empty());
    }
}
