//! Fleet-level (cross-replica) skew sensing from the router/LB vantage —
//! the data-parallel condition family DP1-DP3.
//!
//! A DPU sitting bump-in-the-wire in front of the load balancer sees
//! per-replica flow volume, queue drain, and admission behavior even when
//! intra-replica traffic (NVLink collectives) is invisible to it. This
//! sensor encodes the three fleet signatures:
//!
//! * **DP1 — router flow skew**: one replica's share of routed arrivals far
//!   exceeds the hash-fair share over a sliding horizon.
//! * **DP2 — hot-replica KV exhaustion**: one replica's KV occupancy pins
//!   near capacity with admission failures while peers sit far below it.
//! * **DP3 — straggler replica**: one replica's backlog dominates the fleet
//!   while its iteration rate lags the peers that are keeping up.
//!
//! The sensor is inert on single-replica worlds (skew across replicas is
//! undefined there), which keeps the paper's 28-condition matrix byte-stable.

use std::collections::VecDeque;

use crate::dpu::detectors::{Condition, Detection};
use crate::ids::NodeId;
use crate::sim::SimTime;

/// One window's per-replica observation. Counter fields are cumulative; the
/// sensor differences them against its ring.
#[derive(Debug, Clone)]
pub struct FleetSample {
    /// Cumulative requests routed per replica.
    pub routed: Vec<u64>,
    /// Instantaneous admission-queue depth per replica.
    pub queue_depth: Vec<u64>,
    /// Instantaneous KV occupancy per replica (0..1).
    pub kv_occupancy: Vec<f64>,
    /// Cumulative engine iterations per replica.
    pub iterations: Vec<u64>,
    /// Cumulative KV allocation failures per replica.
    pub alloc_failures: Vec<u64>,
}

/// Windows of history the horizon skew metrics integrate over.
const HORIZON: usize = 40;
/// Minimum arrivals across the horizon before flow-share skew is judged.
const MIN_ARRIVALS: u64 = 32;
/// Consecutive confirmations required per condition.
const CONFIRM_DP1: u32 = 3;
const CONFIRM_DP2: u32 = 2;
const CONFIRM_DP3: u32 = 2;
/// DP2: hot-replica occupancy floor and hot-cold disparity floor.
const KV_HOT_OCC: f64 = 0.85;
const KV_DISPARITY: f64 = 0.3;
/// DP3: backlog dominance + lagging iteration rate.
const STRAGGLER_MIN_QUEUE: u64 = 10;
const STRAGGLER_QUEUE_FACTOR: f64 = 5.0;
const STRAGGLER_ITER_RATIO: f64 = 0.8;

/// Cross-replica skew sensor (one per scenario, fed at window ticks).
#[derive(Debug)]
pub struct FleetSensor {
    n_replicas: usize,
    /// Entry node per replica — the node a fleet detection is attributed to.
    entry_nodes: Vec<NodeId>,
    history: VecDeque<FleetSample>,
    /// Consecutive-hit counters for DP1/DP2/DP3.
    streaks: [u32; 3],
}

impl FleetSensor {
    pub fn new(n_replicas: usize, entry_nodes: Vec<NodeId>) -> Self {
        assert_eq!(entry_nodes.len(), n_replicas);
        FleetSensor {
            n_replicas,
            entry_nodes,
            history: VecDeque::with_capacity(HORIZON + 1),
            streaks: [0; 3],
        }
    }

    /// DP1 fires when one replica's arrival share exceeds the hash-fair
    /// share by an absolute margin. The margin (0.3) sits well above the
    /// binomial noise of hashing the default 64-session population onto any
    /// fleet size, while Zipf-concentrated floods land far past it.
    fn share_threshold(n: usize) -> f64 {
        (1.0 / n as f64 + 0.3).min(0.92)
    }

    /// Feed one window's sample; returns the fleet detections fired.
    pub fn window_tick(&mut self, now: SimTime, sample: FleetSample) -> Vec<Detection> {
        let n = self.n_replicas;
        if n < 2 {
            return Vec::new();
        }
        debug_assert_eq!(sample.routed.len(), n);
        self.history.push_back(sample);
        if self.history.len() > HORIZON + 1 {
            self.history.pop_front();
        }
        // Borrow the horizon endpoints in place — this runs every window of
        // every multi-replica scenario, so no per-tick sample clones.
        let len = self.history.len();
        let cur = &self.history[len - 1];
        let old = &self.history[0];
        let prev = if len >= 2 { Some(&self.history[len - 2]) } else { None };
        let mut fired = Vec::new();

        // --- DP1: flow-share skew over the horizon ---
        let arrivals: Vec<u64> =
            (0..n).map(|r| cur.routed[r].saturating_sub(old.routed[r])).collect();
        let total: u64 = arrivals.iter().sum();
        let mut dp1_hit = false;
        if total >= MIN_ARRIVALS {
            let hot = argmax_u64(&arrivals);
            let share = arrivals[hot] as f64 / total as f64;
            let threshold = Self::share_threshold(n);
            if share >= threshold {
                dp1_hit = true;
                self.streaks[0] += 1;
                if self.streaks[0] >= CONFIRM_DP1 {
                    fired.push(Detection {
                        condition: Condition::Dp1RouterFlowSkew,
                        node: self.entry_nodes[hot],
                        at: now,
                        severity: share * n as f64,
                        evidence: format!(
                            "replica {hot} absorbs {:.0}% of {total} arrivals \
                             (fair share {:.0}%, threshold {:.0}%)",
                            share * 100.0,
                            100.0 / n as f64,
                            threshold * 100.0
                        ),
                    });
                }
            }
        }
        if !dp1_hit {
            self.streaks[0] = 0;
        }

        // --- DP2: hot-replica KV exhaustion (window-level) ---
        let mut dp2_hit = false;
        if let Some(prev) = prev {
            let hot = argmax_f64(&cur.kv_occupancy);
            let hot_occ = cur.kv_occupancy[hot];
            let min_occ = cur
                .kv_occupancy
                .iter()
                .enumerate()
                .filter(|&(r, _)| r != hot)
                .map(|(_, &o)| o)
                .fold(f64::INFINITY, f64::min);
            let failures = cur.alloc_failures[hot].saturating_sub(prev.alloc_failures[hot]);
            if hot_occ >= KV_HOT_OCC && failures >= 1 && hot_occ - min_occ >= KV_DISPARITY {
                dp2_hit = true;
                self.streaks[1] += 1;
                if self.streaks[1] >= CONFIRM_DP2 {
                    fired.push(Detection {
                        condition: Condition::Dp2HotReplicaKv,
                        node: self.entry_nodes[hot],
                        at: now,
                        severity: hot_occ - min_occ,
                        evidence: format!(
                            "replica {hot} KV at {:.0}% with {failures} admission \
                             failures this window; coldest peer at {:.0}%",
                            hot_occ * 100.0,
                            min_occ * 100.0
                        ),
                    });
                }
            }
        }
        if !dp2_hit {
            self.streaks[1] = 0;
        }

        // --- DP3: straggler replica (backlog dominance + lagging rate) ---
        let iters: Vec<u64> =
            (0..n).map(|r| cur.iterations[r].saturating_sub(old.iterations[r])).collect();
        let lag = argmax_u64(&cur.queue_depth);
        let lag_q = cur.queue_depth[lag];
        let others_q: u64 = cur.queue_depth.iter().enumerate().filter(|&(r, _)| r != lag).map(|(_, &q)| q).sum();
        let others_mean_q = others_q as f64 / (n - 1) as f64;
        let others_it: u64 = iters.iter().enumerate().filter(|&(r, _)| r != lag).map(|(_, &i)| i).sum();
        let others_mean_it = others_it as f64 / (n - 1) as f64;
        let dp3_hit = lag_q >= STRAGGLER_MIN_QUEUE
            && lag_q as f64 >= STRAGGLER_QUEUE_FACTOR * (others_mean_q + 1.0)
            && (iters[lag] as f64) < STRAGGLER_ITER_RATIO * (others_mean_it + 1.0);
        if dp3_hit {
            self.streaks[2] += 1;
            if self.streaks[2] >= CONFIRM_DP3 {
                fired.push(Detection {
                    condition: Condition::Dp3StragglerReplica,
                    node: self.entry_nodes[lag],
                    at: now,
                    severity: lag_q as f64 / (others_mean_q + 1.0),
                    evidence: format!(
                        "replica {lag} backlog {lag_q} vs peer mean {others_mean_q:.1}; \
                         {} iterations over the horizon vs peer mean {others_mean_it:.0}",
                        iters[lag]
                    ),
                });
            }
        } else {
            self.streaks[2] = 0;
        }

        fired
    }
}

fn argmax_u64(xs: &[u64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn argmax_f64(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId(i as u32)).collect()
    }

    fn sample(routed: Vec<u64>, q: Vec<u64>, kv: Vec<f64>, it: Vec<u64>, af: Vec<u64>) -> FleetSample {
        FleetSample {
            routed,
            queue_depth: q,
            kv_occupancy: kv,
            iterations: it,
            alloc_failures: af,
        }
    }

    #[test]
    fn single_replica_is_inert() {
        let mut s = FleetSensor::new(1, nodes(1));
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(vec![w * 50], vec![900], vec![1.0], vec![w], vec![w * 3]),
            );
            assert!(fired.is_empty());
        }
    }

    #[test]
    fn balanced_fleet_stays_quiet() {
        let mut s = FleetSensor::new(3, nodes(3));
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 11, w * 9],
                    vec![1, 0, 2],
                    vec![0.3, 0.35, 0.28],
                    vec![w * 5, w * 5, w * 5],
                    vec![0, 0, 0],
                ),
            );
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn dp1_fires_on_flow_concentration() {
        let mut s = FleetSensor::new(3, nodes(3));
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // 80% of arrivals land on replica 0.
                sample(
                    vec![w * 16, w * 2, w * 2],
                    vec![5, 0, 0],
                    vec![0.4, 0.1, 0.1],
                    vec![w * 5, w * 2, w * 2],
                    vec![0, 0, 0],
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp1RouterFlowSkew),
            "{fired_any:?}"
        );
        assert!(fired_any.iter().all(|d| d.condition != Condition::Dp2HotReplicaKv));
    }

    #[test]
    fn dp2_fires_on_hot_kv_with_failures() {
        let mut s = FleetSensor::new(2, nodes(2));
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 10],
                    vec![3, 1],
                    vec![0.97, 0.2],
                    vec![w * 5, w * 5],
                    vec![w * 4, 0], // failures accumulate on replica 0
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp2HotReplicaKv),
            "{fired_any:?}"
        );
        assert_eq!(
            fired_any.iter().find(|d| d.condition == Condition::Dp2HotReplicaKv).unwrap().node,
            NodeId(0)
        );
    }

    #[test]
    fn dp3_fires_on_backlogged_slow_replica() {
        let mut s = FleetSensor::new(2, nodes(2));
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // Replica 1: deep queue, quarter the iteration rate.
                sample(
                    vec![w * 10, w * 10],
                    vec![0, 40 + w],
                    vec![0.3, 0.5],
                    vec![w * 8, w * 2],
                    vec![0, 0],
                ),
            ));
        }
        let dp3: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Dp3StragglerReplica)
            .collect();
        assert!(!dp3.is_empty(), "{fired_any:?}");
        assert_eq!(dp3[0].node, NodeId(1));
    }

    #[test]
    fn confirmation_requires_persistence() {
        let mut s = FleetSensor::new(2, nodes(2));
        // A single anomalous window must not fire (DP2 needs 2 consecutive).
        let quiet = sample(vec![0, 0], vec![0, 0], vec![0.2, 0.2], vec![0, 0], vec![0, 0]);
        s.window_tick(SimTime(0), quiet.clone());
        let hot = sample(vec![10, 10], vec![2, 0], vec![0.95, 0.2], vec![5, 5], vec![4, 0]);
        let fired = s.window_tick(SimTime(1_000_000), hot);
        assert!(fired.is_empty(), "{fired:?}");
        let calm = s.window_tick(SimTime(2_000_000), quiet);
        assert!(calm.is_empty());
    }
}
