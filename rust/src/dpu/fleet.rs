//! Fleet-level (cross-replica) skew sensing from the router/LB vantage —
//! the generic streak-confirmation engine behind the data-parallel (DP1-DP3)
//! and phase-disaggregation (PD1-PD3) condition families.
//!
//! A DPU sitting bump-in-the-wire in front of the load balancer sees
//! per-replica flow volume, queue drain, and admission behavior even when
//! intra-replica traffic (NVLink collectives) is invisible to it.
//!
//! The per-condition knowledge (thresholds, confirmation windows, evidence)
//! does NOT live here: each fleet condition's rule is declared in its
//! [`crate::conditions`] catalog entry (`DetectorBinding::FleetDp` /
//! `FleetPd`), and this sensor is a data-driven evaluator — it feeds each
//! rule a windowed view of the horizon, scoped to one pool at a time, and
//! turns consecutive confirming windows into [`Detection`]s. Adding a fleet
//! condition is a catalog change; the sensor never grows another arm.
//!
//! Skew is only defined among *like* replicas, so every comparison is
//! scoped to a pool ([`crate::engine::PoolTopology`]): on colocated fleets
//! that is all replicas (the classic behavior, byte for byte), on
//! phase-disaggregated fleets DP1 compares prefill-pool members and DP2/DP3
//! decode-pool members — a prefill replica legitimately absorbing 100% of
//! admissions must not read as flow skew. Multi-pool topologies (K prefill
//! pools × M decode pools) evaluate per-pool rules once per pool, each with
//! its own confirmation streak, and `PerPrefillPool` rules see their paired
//! decode pool (pool `p` pairs with `p % M`) as the counterpart.
//!
//! The sensor is inert on single-replica worlds (skew across replicas is
//! undefined there), which keeps the paper's 28-condition matrix
//! byte-stable; PD sensing is inert on colocated fleets for the same reason.

use std::collections::VecDeque;

use crate::cluster::ReplicaRole;
use crate::conditions::{DetectorBinding, FleetScope};
use crate::dpu::detectors::{Condition, Detection};
use crate::engine::PoolTopology;
use crate::ids::NodeId;
use crate::sim::SimTime;

/// One window's per-replica observation. Counter fields are cumulative; the
/// sensor differences them against its ring.
#[derive(Debug, Clone)]
pub struct FleetSample {
    /// Cumulative requests routed per replica.
    pub routed: Vec<u64>,
    /// Instantaneous admission-queue depth per replica.
    pub queue_depth: Vec<u64>,
    /// Instantaneous KV occupancy per replica (0..1).
    pub kv_occupancy: Vec<f64>,
    /// Cumulative engine iterations per replica.
    pub iterations: Vec<u64>,
    /// Cumulative KV allocation failures per replica.
    pub alloc_failures: Vec<u64>,
}

/// One window's phase-disaggregation observation (pool-boundary vantage).
/// Vectors are globally indexed (length = fleet size); the rules read the
/// pool-relevant entries. Counter fields are cumulative.
#[derive(Debug, Clone)]
pub struct PdSample {
    /// Admission-queue depth per replica (prefill-pool backlog signal).
    pub prefill_queue: Vec<u64>,
    /// Running decode sequences per replica.
    pub decode_running: Vec<u64>,
    /// Decode slot capacity per replica.
    pub decode_slots: Vec<u64>,
    /// Cumulative KV-handoff arrivals per replica.
    pub handoff_arrivals: Vec<u64>,
    /// Cumulative handoffs launched fleet-wide.
    pub handoffs_started: u64,
    /// Cumulative handoffs completed fleet-wide.
    pub handoffs_completed: u64,
    /// Cumulative handoff fabric-latency sum, ns.
    pub handoff_lat_sum_ns: u64,
    /// Cumulative logical handoff bytes delivered.
    pub handoff_bytes: u64,
    /// Handoffs parked waiting for decode-side admission.
    pub stalled_wait_depth: u64,
}

/// One window's telemetry-freshness observation (TD family): what the
/// fault boundary between the bus and the DPU observer reports about each
/// replica's signal health. Vectors are per-replica (entry-node stats mapped
/// to replicas by the scenario). `emitted`/`delivered`/`dropped` are
/// cumulative; `age_windows`/`held`/`lag_windows` are instantaneous.
#[derive(Debug, Clone)]
pub struct TdSample {
    /// Windows since the observer last received anything from this replica.
    pub age_windows: Vec<u64>,
    /// Cumulative events that became due at the fault boundary.
    pub emitted: Vec<u64>,
    /// Cumulative events actually handed to the observer.
    pub delivered: Vec<u64>,
    /// Cumulative events discarded at the boundary.
    pub dropped: Vec<u64>,
    /// Events currently parked in the replica's lag hold queue.
    pub held: Vec<u64>,
    /// Current release delay (windows) of the replica's telemetry path.
    pub lag_windows: Vec<u64>,
}

/// What a TD rule sees: the horizon endpoints of the freshness ring. TD
/// rules are fleet-wide (no pool scoping — a single replica's signal age is
/// well-defined, unlike peer skew), so there is exactly one instance per
/// rule and the hit names the worst replica.
pub struct TdCtx<'a> {
    pub cur: &'a TdSample,
    pub old: &'a TdSample,
    pub prev: Option<&'a TdSample>,
}

/// Windows of history the horizon skew metrics integrate over.
const HORIZON: usize = 40;

/// What a DP rule sees for one (window, pool) evaluation: the scoped pool
/// and the horizon endpoints of the serving sample ring.
pub struct DpCtx<'a> {
    /// The pool under judgment (global replica indices).
    pub pool: &'a [usize],
    pub cur: &'a FleetSample,
    pub old: &'a FleetSample,
    pub prev: Option<&'a FleetSample>,
}

/// What a PD rule sees: the scoped pool, its counterpart pool (a
/// `PerPrefillPool` rule's paired decode pool; the prefill union otherwise),
/// the pool-boundary sample ring, and the NIC line rate for line-rate
/// latency expectations.
pub struct PdCtx<'a> {
    pub pool: &'a [usize],
    pub other_pool: &'a [usize],
    pub cur: &'a PdSample,
    pub old: &'a PdSample,
    pub prev: Option<&'a PdSample>,
    /// NIC line rate, bytes/sec.
    pub nic_bw: f64,
}

/// A rule's confirming observation for one window: which replica it
/// localizes to (resolved to that replica's entry node) and the detection
/// payload once the streak confirms.
#[derive(Debug, Clone)]
pub struct RuleHit {
    pub replica: usize,
    pub severity: f64,
    pub evidence: String,
}

/// One catalog-declared DP rule, flattened for the evaluation loop.
#[derive(Clone, Copy)]
struct DpRule {
    condition: Condition,
    scope: FleetScope,
    confirm: u32,
    eval: fn(&DpCtx) -> Option<RuleHit>,
}

/// One catalog-declared PD rule.
#[derive(Clone, Copy)]
struct PdRule {
    condition: Condition,
    scope: FleetScope,
    confirm: u32,
    eval: fn(&PdCtx) -> Option<RuleHit>,
}

/// One catalog-declared TD (telemetry-freshness) rule. Fleet-wide scope:
/// one streak per rule, no pool instances.
#[derive(Clone, Copy)]
struct TdRule {
    condition: Condition,
    confirm: u32,
    eval: fn(&TdCtx) -> Option<RuleHit>,
}

/// Cross-replica skew sensor (one per scenario, fed at window ticks).
#[derive(Debug, Clone)]
pub struct FleetSensor {
    n_replicas: usize,
    /// Entry node per replica — the node a fleet detection is attributed to.
    entry_nodes: Vec<NodeId>,
    /// Pool partition every comparison is scoped to.
    pools: PoolTopology,
    /// NIC line rate, bytes/sec — PD2's latency expectation reference.
    nic_bw: f64,
    history: VecDeque<FleetSample>,
    pd_history: VecDeque<PdSample>,
    td_history: VecDeque<TdSample>,
    dp_rules: Vec<DpRule>,
    pd_rules: Vec<PdRule>,
    td_rules: Vec<TdRule>,
    /// Consecutive-hit counters, per rule × pool instance.
    dp_streaks: Vec<Vec<u32>>,
    pd_streaks: Vec<Vec<u32>>,
    /// TD streaks: one per rule (fleet-wide scope, no pool instances).
    td_streaks: Vec<u32>,
    /// Flattened (rule index, pool index) work lists for the window sweep —
    /// kept in lockstep with the streak tables so the parallel fan-out has a
    /// plain slice to chunk over.
    dp_instances: Vec<(usize, usize)>,
    pd_instances: Vec<(usize, usize)>,
    /// Worker count for the per-window rule sweep ([`crate::util::par`]
    /// semantics; `1` = serial, the default — matrix/fleet sweeps already
    /// parallelize at the cell level, only fleet-stress worlds raise this).
    /// Evaluation order never affects output: rules only read shared window
    /// state, and streak updates are applied serially in (rule, pool) order
    /// afterwards, exactly the serial sweep's order.
    pub threads: usize,
}

impl std::fmt::Debug for DpRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DpRule({:?})", self.condition)
    }
}

impl std::fmt::Debug for PdRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PdRule({:?})", self.condition)
    }
}

impl std::fmt::Debug for TdRule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TdRule({:?})", self.condition)
    }
}

/// How many pool instances a rule of `scope` evaluates against.
fn n_instances(scope: FleetScope, pools: &PoolTopology) -> usize {
    match scope {
        FleetScope::PerPrefillPool => pools.prefill_pools.len(),
        FleetScope::PerDecodePool => pools.decode_pools.len(),
        FleetScope::DecodeUnion => 1,
    }
}

/// Flattened (rule index, pool index) evaluation list — one entry per streak
/// counter, in the serial sweep's rule-then-pool order.
fn instance_list(
    scopes: impl Iterator<Item = FleetScope>,
    pools: &PoolTopology,
) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    for (ri, scope) in scopes.enumerate() {
        for pi in 0..n_instances(scope, pools) {
            v.push((ri, pi));
        }
    }
    v
}

impl FleetSensor {
    /// Classic constructor: `roles` scopes every comparison to the
    /// single-pool partition (one prefill pool, one decode pool); a
    /// colocated fleet compares across the whole fleet, exactly as the
    /// pre-disaggregation sensor did.
    pub fn new(
        n_replicas: usize,
        entry_nodes: Vec<NodeId>,
        roles: Vec<ReplicaRole>,
        nic_bw: f64,
    ) -> Self {
        assert_eq!(roles.len(), n_replicas);
        Self::with_pools(n_replicas, entry_nodes, PoolTopology::from_roles(&roles), nic_bw)
    }

    /// Multi-pool constructor: comparisons are scoped to the given pool
    /// partition (the engine's [`PoolTopology`]).
    pub fn with_pools(
        n_replicas: usize,
        entry_nodes: Vec<NodeId>,
        pools: PoolTopology,
        nic_bw: f64,
    ) -> Self {
        assert_eq!(entry_nodes.len(), n_replicas);
        let mut dp_rules = Vec::new();
        let mut pd_rules = Vec::new();
        let mut td_rules = Vec::new();
        for spec in crate::conditions::all_specs() {
            match spec.binding {
                DetectorBinding::NodeWindow => {}
                // `min_pool` is study-planning knowledge (which triples a
                // topology can host); the rules themselves guard pool size.
                DetectorBinding::FleetDp { scope, confirm, eval, .. } => {
                    dp_rules.push(DpRule { condition: spec.condition, scope, confirm, eval });
                }
                DetectorBinding::FleetPd { scope, confirm, eval, .. } => {
                    pd_rules.push(PdRule { condition: spec.condition, scope, confirm, eval });
                }
                DetectorBinding::FleetTd { confirm, eval } => {
                    td_rules.push(TdRule { condition: spec.condition, confirm, eval });
                }
            }
        }
        let dp_streaks =
            dp_rules.iter().map(|r| vec![0; n_instances(r.scope, &pools)]).collect();
        let pd_streaks =
            pd_rules.iter().map(|r| vec![0; n_instances(r.scope, &pools)]).collect();
        let td_streaks = vec![0; td_rules.len()];
        let dp_instances = instance_list(dp_rules.iter().map(|r| r.scope), &pools);
        let pd_instances = instance_list(pd_rules.iter().map(|r| r.scope), &pools);
        FleetSensor {
            n_replicas,
            entry_nodes,
            pools,
            nic_bw,
            history: VecDeque::with_capacity(HORIZON + 1),
            pd_history: VecDeque::with_capacity(HORIZON + 1),
            td_history: VecDeque::with_capacity(HORIZON + 1),
            dp_rules,
            pd_rules,
            td_rules,
            dp_streaks,
            pd_streaks,
            td_streaks,
            dp_instances,
            pd_instances,
            threads: 1,
        }
    }

    /// Re-scope the pool comparisons after a role shift (`RebalancePools`
    /// moves replicas between pools mid-run). No-op when the partition is
    /// unchanged; on a change, confirmation streaks reset — half-confirmed
    /// skew against the old pools says nothing about the new ones, and a
    /// stale decode pool would read the post-mitigation 100% handoff share
    /// of the sole remaining decode replica as PD3.
    pub fn sync_pools(&mut self, pools: &PoolTopology) {
        if *pools != self.pools {
            self.pools = pools.clone();
            self.dp_streaks = self
                .dp_rules
                .iter()
                .map(|r| vec![0; n_instances(r.scope, &self.pools)])
                .collect();
            self.pd_streaks = self
                .pd_rules
                .iter()
                .map(|r| vec![0; n_instances(r.scope, &self.pools)])
                .collect();
            self.dp_instances = instance_list(self.dp_rules.iter().map(|r| r.scope), &self.pools);
            self.pd_instances = instance_list(self.pd_rules.iter().map(|r| r.scope), &self.pools);
        }
    }

    /// Feed one window's sample; returns the fleet detections fired, rule
    /// (catalog) order then pool order.
    pub fn window_tick(&mut self, now: SimTime, sample: FleetSample) -> Vec<Detection> {
        if self.n_replicas < 2 {
            return Vec::new();
        }
        debug_assert_eq!(sample.routed.len(), self.n_replicas);
        self.history.push_back(sample);
        if self.history.len() > HORIZON + 1 {
            self.history.pop_front();
        }
        // Borrow the horizon endpoints in place — this runs every window of
        // every multi-replica scenario, so no per-tick sample clones.
        let len = self.history.len();
        let cur = &self.history[len - 1];
        let old = &self.history[0];
        let prev = if len >= 2 { Some(&self.history[len - 2]) } else { None };

        // Evaluate every (rule, pool) instance — pure reads of shared window
        // state, so the fan-out is order-free. Streaks are then advanced
        // serially below in instance order, which IS the classic
        // rule-then-pool order, so serial and parallel sweeps fire the same
        // detections in the same order.
        let eval_one = |&(ri, pi): &(usize, usize)| -> Option<RuleHit> {
            let rule = self.dp_rules[ri];
            let pool: &[usize] = match rule.scope {
                FleetScope::PerPrefillPool => &self.pools.prefill_pools[pi],
                FleetScope::PerDecodePool => &self.pools.decode_pools[pi],
                FleetScope::DecodeUnion => &self.pools.decode_members,
            };
            (rule.eval)(&DpCtx { pool, cur, old, prev })
        };
        let hits: Vec<Option<RuleHit>> = if self.threads != 1 && self.dp_instances.len() > 1 {
            crate::util::par::parallel_map(&self.dp_instances, self.threads, eval_one)
        } else {
            self.dp_instances.iter().map(eval_one).collect()
        };

        let mut fired = Vec::new();
        for (&(ri, pi), hit) in self.dp_instances.iter().zip(hits) {
            match hit {
                Some(hit) => {
                    self.dp_streaks[ri][pi] += 1;
                    if self.dp_streaks[ri][pi] >= self.dp_rules[ri].confirm {
                        fired.push(Detection {
                            condition: self.dp_rules[ri].condition,
                            node: self.entry_nodes[hit.replica],
                            at: now,
                            severity: hit.severity,
                            evidence: hit.evidence,
                        });
                    }
                }
                None => self.dp_streaks[ri][pi] = 0,
            }
        }
        fired
    }

    /// Feed one window's pool-boundary observation (disaggregated fleets
    /// only); returns the PD detections fired.
    pub fn pd_window_tick(&mut self, now: SimTime, sample: PdSample) -> Vec<Detection> {
        debug_assert_eq!(sample.prefill_queue.len(), self.n_replicas);
        self.pd_history.push_back(sample);
        if self.pd_history.len() > HORIZON + 1 {
            self.pd_history.pop_front();
        }
        let len = self.pd_history.len();
        let cur = &self.pd_history[len - 1];
        let old = &self.pd_history[0];
        let prev = if len >= 2 { Some(&self.pd_history[len - 2]) } else { None };

        let n_decode = self.pools.decode_pools.len();
        // Same shape as the DP sweep: side-effect-free evaluations (fanned
        // out when `threads` asks for it), then serial streak advancement in
        // instance order — byte-identical to the classic nested loop.
        let eval_one = |&(ri, pi): &(usize, usize)| -> Option<RuleHit> {
            let rule = self.pd_rules[ri];
            // A prefill-scoped rule judges its pool against the decode
            // pool it hands off to (pool p pairs with p % M); decode
            // scopes see the prefill union as the counterpart.
            let (pool, other): (&[usize], &[usize]) = match rule.scope {
                FleetScope::PerPrefillPool => (
                    self.pools.prefill_pools[pi].as_slice(),
                    self.pools.decode_pools[pi % n_decode].as_slice(),
                ),
                FleetScope::PerDecodePool => (
                    self.pools.decode_pools[pi].as_slice(),
                    self.pools.prefill_members.as_slice(),
                ),
                FleetScope::DecodeUnion => (
                    self.pools.decode_members.as_slice(),
                    self.pools.prefill_members.as_slice(),
                ),
            };
            let cx = PdCtx { pool, other_pool: other, cur, old, prev, nic_bw: self.nic_bw };
            (rule.eval)(&cx)
        };
        let hits: Vec<Option<RuleHit>> = if self.threads != 1 && self.pd_instances.len() > 1 {
            crate::util::par::parallel_map(&self.pd_instances, self.threads, eval_one)
        } else {
            self.pd_instances.iter().map(eval_one).collect()
        };

        let mut fired = Vec::new();
        for (&(ri, pi), hit) in self.pd_instances.iter().zip(hits) {
            match hit {
                Some(hit) => {
                    self.pd_streaks[ri][pi] += 1;
                    if self.pd_streaks[ri][pi] >= self.pd_rules[ri].confirm {
                        fired.push(Detection {
                            condition: self.pd_rules[ri].condition,
                            node: self.entry_nodes[hit.replica],
                            at: now,
                            severity: hit.severity,
                            evidence: hit.evidence,
                        });
                    }
                }
                None => self.pd_streaks[ri][pi] = 0,
            }
        }
        fired
    }

    /// Feed one window's telemetry-freshness observation (runs only once the
    /// fault layer is engaged); returns the TD detections fired. Unlike the
    /// skew sweeps this has no single-replica guard — the freshness of one
    /// replica's signal is judgeable on its own — and stays serial: three
    /// rules over pre-diffed vectors is far below fan-out break-even, and a
    /// serial sweep is trivially identical for every worker count.
    pub fn td_window_tick(&mut self, now: SimTime, sample: TdSample) -> Vec<Detection> {
        debug_assert_eq!(sample.age_windows.len(), self.n_replicas);
        self.td_history.push_back(sample);
        if self.td_history.len() > HORIZON + 1 {
            self.td_history.pop_front();
        }
        let len = self.td_history.len();
        let cur = &self.td_history[len - 1];
        let old = &self.td_history[0];
        let prev = if len >= 2 { Some(&self.td_history[len - 2]) } else { None };
        let cx = TdCtx { cur, old, prev };

        let mut fired = Vec::new();
        for (ri, rule) in self.td_rules.iter().enumerate() {
            match (rule.eval)(&cx) {
                Some(hit) => {
                    self.td_streaks[ri] += 1;
                    if self.td_streaks[ri] >= rule.confirm {
                        fired.push(Detection {
                            condition: rule.condition,
                            node: self.entry_nodes[hit.replica],
                            at: now,
                            severity: hit.severity,
                            evidence: hit.evidence,
                        });
                    }
                }
                None => self.td_streaks[ri] = 0,
            }
        }
        fired
    }
}

/// Index of the (first) maximum — shared by the catalog's fleet rules.
pub fn argmax_u64(xs: &[u64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// First (lowest-index) member maximizing `key` — strict-greater comparison
/// keeps the pre-pool argmax tie-break, so a full-membership pool reproduces
/// the classic sensor's picks exactly.
pub fn first_max_by(members: &[usize], key: impl Fn(usize) -> f64) -> usize {
    let mut best = members[0];
    let mut best_k = key(best);
    for &r in &members[1..] {
        let k = key(r);
        if k > best_k {
            best = r;
            best_k = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId(i as u32)).collect()
    }

    /// Classic colocated sensor (full-fleet comparisons).
    fn sensor(n: usize) -> FleetSensor {
        FleetSensor::new(n, nodes(n), vec![ReplicaRole::Colocated; n], 50e9)
    }

    /// Disaggregated sensor: replica 0 prefill, the rest decode.
    fn pd_sensor(n: usize) -> FleetSensor {
        let mut roles = vec![ReplicaRole::Decode; n];
        roles[0] = ReplicaRole::Prefill;
        FleetSensor::new(n, nodes(n), roles, 50e9)
    }

    fn quiet_pd(n: usize) -> PdSample {
        PdSample {
            prefill_queue: vec![0; n],
            decode_running: vec![0; n],
            decode_slots: vec![8; n],
            handoff_arrivals: vec![0; n],
            handoffs_started: 0,
            handoffs_completed: 0,
            handoff_lat_sum_ns: 0,
            handoff_bytes: 0,
            stalled_wait_depth: 0,
        }
    }

    fn sample(routed: Vec<u64>, q: Vec<u64>, kv: Vec<f64>, it: Vec<u64>, af: Vec<u64>) -> FleetSample {
        FleetSample {
            routed,
            queue_depth: q,
            kv_occupancy: kv,
            iterations: it,
            alloc_failures: af,
        }
    }

    #[test]
    fn rules_come_from_the_catalog() {
        let s = sensor(2);
        let dp: Vec<Condition> = s.dp_rules.iter().map(|r| r.condition).collect();
        let pd: Vec<Condition> = s.pd_rules.iter().map(|r| r.condition).collect();
        let td: Vec<Condition> = s.td_rules.iter().map(|r| r.condition).collect();
        assert_eq!(dp, crate::dpu::detectors::DP_CONDITIONS.to_vec());
        assert_eq!(pd, crate::dpu::detectors::PD_CONDITIONS.to_vec());
        assert_eq!(td, crate::dpu::detectors::TD_CONDITIONS.to_vec());
        assert_eq!(s.td_streaks.len(), td.len(), "one fleet-wide streak per TD rule");
    }

    /// A healthy freshness sample: everything delivered promptly.
    fn fresh_td(n: usize, w: u64) -> TdSample {
        TdSample {
            age_windows: vec![0; n],
            emitted: vec![w * 100; n],
            delivered: vec![w * 100; n],
            dropped: vec![0; n],
            held: vec![0; n],
            lag_windows: vec![0; n],
        }
    }

    #[test]
    fn healthy_freshness_stays_quiet() {
        let mut s = sensor(3);
        for w in 0..100u64 {
            let fired = s.td_window_tick(SimTime(w * 1_000_000), fresh_td(3, w));
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn td1_fires_on_frozen_signal_and_only_td1() {
        // Replica 1 goes silent (emissions continue, nothing delivered,
        // nothing held) — the TD1 signature, distinct from TD2/TD3.
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..12u64 {
            let mut t = fresh_td(2, w);
            t.delivered[1] = 300; // frozen at the pre-fault total
            t.dropped[1] = (w * 100).saturating_sub(300);
            t.age_windows[1] = w.saturating_sub(3);
            fired_any.extend(s.td_window_tick(SimTime(w * 1_000_000), t));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Td1StaleFrozen),
            "{fired_any:?}"
        );
        assert_eq!(
            fired_any.iter().find(|d| d.condition == Condition::Td1StaleFrozen).unwrap().node,
            NodeId(1),
            "TD1 localizes to the silent replica"
        );
        // Zero deliveries over the horizon is silence, not partial loss.
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td2LossyDrop));
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td3LaggingDelivery));
    }

    #[test]
    fn td2_fires_on_partial_loss_and_only_td2() {
        // Replica 0 loses 60% of its events but keeps delivering: TD2's
        // signature. Age stays 0 (TD1 quiet) and nothing is held (TD3 quiet).
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..12u64 {
            let mut t = fresh_td(2, w);
            t.delivered[0] = w * 40;
            t.dropped[0] = w * 60;
            fired_any.extend(s.td_window_tick(SimTime(w * 1_000_000), t));
        }
        let td2: Vec<_> =
            fired_any.iter().filter(|d| d.condition == Condition::Td2LossyDrop).collect();
        assert!(!td2.is_empty(), "{fired_any:?}");
        assert_eq!(td2[0].node, NodeId(0));
        assert!(td2[0].evidence.contains("lossy"), "{}", td2[0].evidence);
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td1StaleFrozen));
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td3LaggingDelivery));
    }

    #[test]
    fn td3_fires_on_lagging_delivery_and_only_td3() {
        // Replica 1's events arrive complete but 6 windows late with a
        // standing backlog: TD3. The held>0 guard keeps TD1 quiet even
        // while age grows during the initial build-up.
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..12u64 {
            let mut t = fresh_td(2, w);
            t.delivered[1] = (w * 100).saturating_sub(600);
            t.held[1] = 600.min(w * 100);
            t.lag_windows[1] = 6.min(w);
            t.age_windows[1] = if w < 6 { w } else { 0 };
            fired_any.extend(s.td_window_tick(SimTime(w * 1_000_000), t));
        }
        let td3: Vec<_> =
            fired_any.iter().filter(|d| d.condition == Condition::Td3LaggingDelivery).collect();
        assert!(!td3.is_empty(), "{fired_any:?}");
        assert_eq!(td3[0].node, NodeId(1));
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td1StaleFrozen));
        assert!(fired_any.iter().all(|d| d.condition != Condition::Td2LossyDrop));
    }

    #[test]
    fn td_sensing_works_on_a_single_replica_world() {
        // Unlike skew rules, freshness is judgeable on a singleton fleet —
        // campaign TD cells on the single topology depend on this.
        let mut s = sensor(1);
        let mut fired_any = Vec::new();
        for w in 0..12u64 {
            let mut t = fresh_td(1, w);
            t.delivered[0] = 0;
            t.dropped[0] = w * 100;
            t.age_windows[0] = w;
            fired_any.extend(s.td_window_tick(SimTime(w * 1_000_000), t));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Td1StaleFrozen),
            "{fired_any:?}"
        );
    }

    #[test]
    fn td_confirmation_requires_a_streak() {
        let mut s = sensor(2);
        // Two frozen windows (below confirm=3), then recovery: never fires.
        for w in 0..2u64 {
            let mut t = fresh_td(2, w);
            t.delivered[1] = 0;
            t.dropped[1] = w * 100;
            t.age_windows[1] = w + 4;
            let fired = s.td_window_tick(SimTime(w * 1_000_000), t);
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
        for w in 2..20u64 {
            let fired = s.td_window_tick(SimTime(w * 1_000_000), fresh_td(2, w));
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn single_replica_is_inert() {
        let mut s = sensor(1);
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(vec![w * 50], vec![900], vec![1.0], vec![w], vec![w * 3]),
            );
            assert!(fired.is_empty());
        }
    }

    #[test]
    fn balanced_fleet_stays_quiet() {
        let mut s = sensor(3);
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 11, w * 9],
                    vec![1, 0, 2],
                    vec![0.3, 0.35, 0.28],
                    vec![w * 5, w * 5, w * 5],
                    vec![0, 0, 0],
                ),
            );
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn dp1_fires_on_flow_concentration() {
        let mut s = sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // 80% of arrivals land on replica 0.
                sample(
                    vec![w * 16, w * 2, w * 2],
                    vec![5, 0, 0],
                    vec![0.4, 0.1, 0.1],
                    vec![w * 5, w * 2, w * 2],
                    vec![0, 0, 0],
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp1RouterFlowSkew),
            "{fired_any:?}"
        );
        assert!(fired_any.iter().all(|d| d.condition != Condition::Dp2HotReplicaKv));
    }

    #[test]
    fn dp2_fires_on_hot_kv_with_failures() {
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 10],
                    vec![3, 1],
                    vec![0.97, 0.2],
                    vec![w * 5, w * 5],
                    vec![w * 4, 0], // failures accumulate on replica 0
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp2HotReplicaKv),
            "{fired_any:?}"
        );
        assert_eq!(
            fired_any.iter().find(|d| d.condition == Condition::Dp2HotReplicaKv).unwrap().node,
            NodeId(0)
        );
    }

    #[test]
    fn dp3_fires_on_backlogged_slow_replica() {
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // Replica 1: deep queue, quarter the iteration rate.
                sample(
                    vec![w * 10, w * 10],
                    vec![0, 40 + w],
                    vec![0.3, 0.5],
                    vec![w * 8, w * 2],
                    vec![0, 0],
                ),
            ));
        }
        let dp3: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Dp3StragglerReplica)
            .collect();
        assert!(!dp3.is_empty(), "{fired_any:?}");
        assert_eq!(dp3[0].node, NodeId(1));
    }

    #[test]
    fn disagg_sole_prefill_replica_is_not_flow_skew() {
        // A lone prefill replica legitimately absorbs 100% of admissions;
        // pool scoping must keep DP1 quiet.
        let mut s = pd_sensor(3);
        for w in 0..80u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 30, 0, 0],
                    vec![2, 0, 0],
                    vec![0.2, 0.3, 0.3],
                    vec![w * 5, w * 20, w * 20],
                    vec![0, 0, 0],
                ),
            );
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn pd1_fires_on_prefill_backlog_with_idle_decode() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..20u64 {
            let mut p = quiet_pd(3);
            p.prefill_queue = vec![30 + w * 10, 0, 0];
            p.decode_running = vec![0, 1, 1];
            p.handoff_arrivals = vec![0, w * 3, w * 3];
            p.handoffs_completed = w * 6;
            p.handoff_lat_sum_ns = w * 6 * 20_000;
            p.handoff_bytes = w * 6 * 256 * 1024;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        let pd1: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Pd1PrefillSaturation)
            .collect();
        assert!(!pd1.is_empty(), "{fired_any:?}");
        assert_eq!(pd1[0].node, NodeId(0), "PD1 localizes to the backlogged prefill replica");
        assert!(fired_any.iter().all(|d| d.condition != Condition::Pd2KvHandoffStall));
    }

    #[test]
    fn pd2_fires_on_handoff_latency_blowout() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            let mut p = quiet_pd(3);
            // 256 KB handoffs: line-rate expectation ~25 us; observed 400 us.
            p.handoff_arrivals = vec![0, w * 4, w * 4];
            p.handoffs_completed = w * 8;
            p.handoff_lat_sum_ns = w * 8 * 400_000;
            p.handoff_bytes = w * 8 * 256 * 1024;
            p.decode_running = vec![0, 1, 1];
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pd2KvHandoffStall),
            "{fired_any:?}"
        );
    }

    #[test]
    fn pd2_fires_on_a_total_stall_with_no_latency_samples() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            let mut p = quiet_pd(3);
            // Handoffs launch but essentially never land: no usable latency
            // population, just a growing in-flight backlog.
            p.handoffs_started = 20 + w * 10;
            p.handoffs_completed = 2;
            p.handoff_arrivals = vec![0, 2, 0];
            p.handoff_lat_sum_ns = 2 * 30_000;
            p.handoff_bytes = 2 * 256 * 1024;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pd2KvHandoffStall),
            "{fired_any:?}"
        );
        assert!(fired_any.iter().any(|d| d.evidence.contains("frozen")));
    }

    #[test]
    fn sync_pools_rescopes_after_a_role_shift() {
        let mut s = pd_sensor(3); // decode pool {1, 2}
        // Wedge-like concentration on replica 1 builds a PD3 streak...
        for w in 0..2u64 {
            let mut p = quiet_pd(3);
            p.handoff_arrivals = vec![0, w * 30, 0];
            p.handoffs_started = w * 30;
            p.handoffs_completed = w * 30;
            p.handoff_lat_sum_ns = w * 30 * 20_000;
            p.handoff_bytes = w * 30 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "confirmation not yet reached: {fired:?}");
        }
        // ...then RebalancePools moves replica 2 into the prefill pool:
        // replica 1 is now the SOLE decode member, and its 100% share is
        // simply correct — PD3 must go inert, not fire.
        let roles =
            vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Prefill];
        s.sync_pools(&PoolTopology::from_roles(&roles));
        for w in 2..10u64 {
            let mut p = quiet_pd(3);
            p.handoff_arrivals = vec![0, w * 30, 0];
            p.handoffs_started = w * 30;
            p.handoffs_completed = w * 30;
            p.handoff_lat_sum_ns = w * 30 * 20_000;
            p.handoff_bytes = w * 30 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "stale-pool PD3 after role shift: {fired:?}");
        }
        // Unchanged roles are a no-op (streak state preserved elsewhere).
        s.sync_pools(&PoolTopology::from_roles(&roles));
    }

    #[test]
    fn pd2_quiet_at_line_rate() {
        let mut s = pd_sensor(3);
        for w in 0..40u64 {
            let mut p = quiet_pd(3);
            // 256 KB at ~line-rate latency (expectation ~25 us, observed 30).
            p.handoff_arrivals = vec![0, w * 4, w * 4];
            p.handoffs_completed = w * 8;
            p.handoff_lat_sum_ns = w * 8 * 30_000;
            p.handoff_bytes = w * 8 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn pd3_fires_on_handoff_concentration() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..20u64 {
            let mut p = quiet_pd(3);
            // All handoffs land on decode replica 1; replica 2 starves.
            p.handoff_arrivals = vec![0, w * 10, 0];
            p.handoffs_completed = w * 10;
            p.handoff_lat_sum_ns = w * 10 * 20_000;
            p.handoff_bytes = w * 10 * 256 * 1024;
            p.decode_running = vec![0, 8, 0];
            p.stalled_wait_depth = w;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        let pd3: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Pd3DecodeStarvation)
            .collect();
        assert!(!pd3.is_empty(), "{fired_any:?}");
        assert_eq!(pd3[0].node, NodeId(1), "PD3 localizes to the wedged decode replica");
    }

    #[test]
    fn balanced_disagg_pool_stays_quiet() {
        let mut s = pd_sensor(3);
        for w in 0..60u64 {
            let mut p = quiet_pd(3);
            p.prefill_queue = vec![2, 0, 0];
            p.decode_running = vec![0, 6, 6];
            p.handoff_arrivals = vec![0, w * 5, w * 5 + (w % 2)];
            p.handoffs_completed = w * 10;
            p.handoff_lat_sum_ns = w * 10 * 28_000;
            p.handoff_bytes = w * 10 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn confirmation_requires_persistence() {
        let mut s = sensor(2);
        // A single anomalous window must not fire (DP2 needs 2 consecutive).
        let quiet = sample(vec![0, 0], vec![0, 0], vec![0.2, 0.2], vec![0, 0], vec![0, 0]);
        s.window_tick(SimTime(0), quiet.clone());
        let hot = sample(vec![10, 10], vec![2, 0], vec![0.95, 0.2], vec![5, 5], vec![4, 0]);
        let fired = s.window_tick(SimTime(1_000_000), hot);
        assert!(fired.is_empty(), "{fired:?}");
        let calm = s.window_tick(SimTime(2_000_000), quiet);
        assert!(calm.is_empty());
    }

    #[test]
    fn multi_pool_scoping_judges_each_pool_independently() {
        // 4 colocated replicas split into 2 prefill pools {0,1} and {2,3}:
        // concentration INSIDE pool {2,3} must fire DP1 localized there,
        // even though the fleet-wide share (50%) looks fair.
        let roles = vec![ReplicaRole::Colocated; 4];
        let pools = PoolTopology::build(&roles, 2, 2);
        assert_eq!(pools.prefill_pools, vec![vec![0, 1], vec![2, 3]]);
        let mut s = FleetSensor::with_pools(4, nodes(4), pools, 50e9);
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    // Pool {0,1} balanced; pool {2,3} fully concentrated.
                    vec![w * 10, w * 10, w * 20, 0],
                    vec![0, 0, 0, 0],
                    vec![0.2, 0.2, 0.2, 0.2],
                    vec![w * 5, w * 5, w * 5, w * 5],
                    vec![0, 0, 0, 0],
                ),
            ));
        }
        let dp1: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Dp1RouterFlowSkew)
            .collect();
        assert!(!dp1.is_empty(), "{fired_any:?}");
        assert!(dp1.iter().all(|d| d.node == NodeId(2)), "must localize into pool {{2,3}}");
    }

    #[test]
    fn parallel_rule_sweep_matches_serial_exactly() {
        // Multi-pool world (2 prefill pools × 2 decode pools over 8
        // replicas) driven through both the DP and PD sweeps: the fired
        // detection sequence — order, nodes, severities, evidence strings —
        // must be identical for any worker count.
        let run = |threads: usize| -> String {
            let mut roles = vec![ReplicaRole::Prefill; 4];
            roles.extend(vec![ReplicaRole::Decode; 4]);
            let pools = PoolTopology::build(&roles, 2, 2);
            let mut s = FleetSensor::with_pools(8, nodes(8), pools, 50e9);
            s.threads = threads;
            let mut fired = Vec::new();
            for w in 0..60u64 {
                let t = SimTime(w * 1_000_000);
                fired.extend(s.window_tick(
                    t,
                    sample(
                        // Prefill pool {0,1} concentrated; the rest balanced.
                        vec![w * 20, 0, w * 10, w * 10, 0, 0, 0, 0],
                        vec![6, 0, 0, 0, 0, 0, 0, 0],
                        vec![0.5, 0.1, 0.2, 0.2, 0.3, 0.3, 0.3, 0.3],
                        vec![w * 5, w, w * 3, w * 3, w * 4, w * 4, w * 4, w * 4],
                        vec![0; 8],
                    ),
                ));
                let mut p = quiet_pd(8);
                // Prefill backlog grows while handoffs crawl: PD territory.
                p.prefill_queue = vec![w * 3, w * 3, 0, 0, 0, 0, 0, 0];
                p.decode_running = vec![0, 0, 0, 0, 8, 8, 8, 8];
                p.handoff_arrivals = vec![0, 0, 0, 0, w * 4, w, w * 4, w * 4];
                p.handoffs_started = w * 14;
                p.handoffs_completed = w * 13;
                p.handoff_lat_sum_ns = w * 13 * 2_000_000;
                p.handoff_bytes = w * 13 * 256 * 1024;
                p.stalled_wait_depth = w / 10;
                fired.extend(s.pd_window_tick(t, p));
            }
            format!("{fired:?}")
        };
        let serial = run(1);
        assert!(serial.contains("Dp1RouterFlowSkew"), "world must actually fire: {serial}");
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(8));
        assert_eq!(serial, run(0));
    }
}
