//! Fleet-level (cross-replica) skew sensing from the router/LB vantage —
//! the data-parallel condition family DP1-DP3 and the phase-disaggregation
//! family PD1-PD3.
//!
//! A DPU sitting bump-in-the-wire in front of the load balancer sees
//! per-replica flow volume, queue drain, and admission behavior even when
//! intra-replica traffic (NVLink collectives) is invisible to it. This
//! sensor encodes the three fleet signatures:
//!
//! * **DP1 — router flow skew**: one replica's share of routed arrivals far
//!   exceeds the hash-fair share over a sliding horizon.
//! * **DP2 — hot-replica KV exhaustion**: one replica's KV occupancy pins
//!   near capacity with admission failures while peers sit far below it.
//! * **DP3 — straggler replica**: one replica's backlog dominates the fleet
//!   while its iteration rate lags the peers that are keeping up.
//!
//! Skew is only defined among *like* replicas, so every DP comparison is
//! scoped to a pool: on colocated fleets that is all replicas (the classic
//! behavior, byte for byte), on phase-disaggregated fleets DP1 compares
//! prefill-pool members and DP2/DP3 decode-pool members — a prefill replica
//! legitimately absorbing 100% of admissions must not read as flow skew.
//!
//! Disaggregated fleets additionally expose the pool boundary itself as
//! network traffic (the KV handoff), which the PD family watches:
//!
//! * **PD1 — prefill-pool saturation**: admission backlog accumulates across
//!   the prefill pool while the decode pool sits far below slot capacity.
//! * **PD2 — KV-handoff stall**: the phase-transition transfer's fabric
//!   latency blows past its line-rate expectation.
//! * **PD3 — decode-pool starvation**: handoff arrivals concentrate on one
//!   decode replica while its pool peers starve.
//!
//! The sensor is inert on single-replica worlds (skew across replicas is
//! undefined there), which keeps the paper's 28-condition matrix byte-stable;
//! PD sensing is inert on colocated fleets for the same reason.

use std::collections::VecDeque;

use crate::cluster::ReplicaRole;
use crate::dpu::detectors::{Condition, Detection};
use crate::ids::NodeId;
use crate::sim::SimTime;

/// One window's per-replica observation. Counter fields are cumulative; the
/// sensor differences them against its ring.
#[derive(Debug, Clone)]
pub struct FleetSample {
    /// Cumulative requests routed per replica.
    pub routed: Vec<u64>,
    /// Instantaneous admission-queue depth per replica.
    pub queue_depth: Vec<u64>,
    /// Instantaneous KV occupancy per replica (0..1).
    pub kv_occupancy: Vec<f64>,
    /// Cumulative engine iterations per replica.
    pub iterations: Vec<u64>,
    /// Cumulative KV allocation failures per replica.
    pub alloc_failures: Vec<u64>,
}

/// One window's phase-disaggregation observation (pool-boundary vantage).
/// Vectors are globally indexed (length = fleet size); the sensor reads the
/// pool-relevant entries. Counter fields are cumulative.
#[derive(Debug, Clone)]
pub struct PdSample {
    /// Admission-queue depth per replica (prefill-pool backlog signal).
    pub prefill_queue: Vec<u64>,
    /// Running decode sequences per replica.
    pub decode_running: Vec<u64>,
    /// Decode slot capacity per replica.
    pub decode_slots: Vec<u64>,
    /// Cumulative KV-handoff arrivals per replica.
    pub handoff_arrivals: Vec<u64>,
    /// Cumulative handoffs launched fleet-wide.
    pub handoffs_started: u64,
    /// Cumulative handoffs completed fleet-wide.
    pub handoffs_completed: u64,
    /// Cumulative handoff fabric-latency sum, ns.
    pub handoff_lat_sum_ns: u64,
    /// Cumulative logical handoff bytes delivered.
    pub handoff_bytes: u64,
    /// Handoffs parked waiting for decode-side admission.
    pub stalled_wait_depth: u64,
}

/// Windows of history the horizon skew metrics integrate over.
const HORIZON: usize = 40;
/// Minimum arrivals across the horizon before flow-share skew is judged.
const MIN_ARRIVALS: u64 = 32;
/// Consecutive confirmations required per condition.
const CONFIRM_DP1: u32 = 3;
const CONFIRM_DP2: u32 = 2;
const CONFIRM_DP3: u32 = 2;
/// DP2: hot-replica occupancy floor and hot-cold disparity floor.
const KV_HOT_OCC: f64 = 0.85;
const KV_DISPARITY: f64 = 0.3;
/// DP3: backlog dominance + lagging iteration rate.
const STRAGGLER_MIN_QUEUE: u64 = 10;
const STRAGGLER_QUEUE_FACTOR: f64 = 5.0;
const STRAGGLER_ITER_RATIO: f64 = 0.8;
/// PD1: prefill-pool backlog floor and the decode-utilization ceiling that
/// distinguishes "prefill starves decode" from "everything is busy".
const PD1_MIN_QUEUE: u64 = 24;
const PD1_DECODE_UTIL_MAX: f64 = 0.5;
const CONFIRM_PD1: u32 = 3;
/// PD2: observed-over-expected handoff latency ratio + a minimum population
/// over the horizon so a few straggling transfers can't fire it. The
/// in-flight floor catches the degenerate total stall, where so few
/// transfers land that no latency sample exists at all.
const PD2_LAT_FACTOR: f64 = 3.0;
const PD2_MIN_HANDOFFS: u64 = 4;
const PD2_STALL_INFLIGHT: u64 = 12;
const CONFIRM_PD2: u32 = 2;
/// PD3: handoff-share margin over the fair share (mirrors DP1's margin).
const PD3_SHARE_MARGIN: f64 = 0.35;
const PD3_MIN_ARRIVALS: u64 = 24;
const CONFIRM_PD3: u32 = 3;
/// Hops a handoff traverses (uplink → core → downlink) for the line-rate
/// latency expectation, plus a fixed base allowance.
const PD2_PATH_HOPS: f64 = 3.0;
const PD2_BASE_ALLOWANCE_NS: f64 = 10_000.0;

/// Cross-replica skew sensor (one per scenario, fed at window ticks).
#[derive(Debug)]
pub struct FleetSensor {
    n_replicas: usize,
    /// Entry node per replica — the node a fleet detection is attributed to.
    entry_nodes: Vec<NodeId>,
    /// Prefill-capable members (DP1's comparison pool).
    prefill_members: Vec<usize>,
    /// Decode-capable members (DP2/DP3's and PD3's comparison pool).
    decode_members: Vec<usize>,
    /// NIC line rate, bytes/sec — PD2's latency expectation reference.
    nic_bw: f64,
    history: VecDeque<FleetSample>,
    pd_history: VecDeque<PdSample>,
    /// Consecutive-hit counters for DP1/DP2/DP3.
    streaks: [u32; 3],
    /// Consecutive-hit counters for PD1/PD2/PD3.
    pd_streaks: [u32; 3],
}

impl FleetSensor {
    /// `roles` scopes every skew comparison to its pool; a colocated fleet
    /// (all `ReplicaRole::Colocated`) compares across the whole fleet,
    /// exactly as the pre-disaggregation sensor did.
    pub fn new(
        n_replicas: usize,
        entry_nodes: Vec<NodeId>,
        roles: Vec<ReplicaRole>,
        nic_bw: f64,
    ) -> Self {
        assert_eq!(entry_nodes.len(), n_replicas);
        assert_eq!(roles.len(), n_replicas);
        let prefill_members: Vec<usize> = (0..n_replicas)
            .filter(|&r| roles[r].serves_prefill())
            .collect();
        let decode_members: Vec<usize> = (0..n_replicas)
            .filter(|&r| roles[r].serves_decode())
            .collect();
        FleetSensor {
            n_replicas,
            entry_nodes,
            prefill_members,
            decode_members,
            nic_bw,
            history: VecDeque::with_capacity(HORIZON + 1),
            pd_history: VecDeque::with_capacity(HORIZON + 1),
            streaks: [0; 3],
            pd_streaks: [0; 3],
        }
    }

    /// Re-scope the pool comparisons after a role shift (`RebalancePools`
    /// moves replicas between pools mid-run). No-op when membership is
    /// unchanged; on a change, confirmation streaks reset — half-confirmed
    /// skew against the old pools says nothing about the new ones, and a
    /// stale decode pool would read the post-mitigation 100% handoff share
    /// of the sole remaining decode replica as PD3.
    pub fn sync_pools(&mut self, roles: &[ReplicaRole]) {
        debug_assert_eq!(roles.len(), self.n_replicas);
        let prefill: Vec<usize> =
            (0..self.n_replicas).filter(|&r| roles[r].serves_prefill()).collect();
        let decode: Vec<usize> =
            (0..self.n_replicas).filter(|&r| roles[r].serves_decode()).collect();
        if prefill != self.prefill_members || decode != self.decode_members {
            self.prefill_members = prefill;
            self.decode_members = decode;
            self.streaks = [0; 3];
            self.pd_streaks = [0; 3];
        }
    }

    /// DP1 fires when one replica's arrival share exceeds the hash-fair
    /// share by an absolute margin. The margin (0.3) sits well above the
    /// binomial noise of hashing the default 64-session population onto any
    /// fleet size, while Zipf-concentrated floods land far past it.
    fn share_threshold(n: usize) -> f64 {
        (1.0 / n as f64 + 0.3).min(0.92)
    }

    /// Feed one window's sample; returns the fleet detections fired.
    pub fn window_tick(&mut self, now: SimTime, sample: FleetSample) -> Vec<Detection> {
        let n = self.n_replicas;
        if n < 2 {
            return Vec::new();
        }
        debug_assert_eq!(sample.routed.len(), n);
        self.history.push_back(sample);
        if self.history.len() > HORIZON + 1 {
            self.history.pop_front();
        }
        // Borrow the horizon endpoints in place — this runs every window of
        // every multi-replica scenario, so no per-tick sample clones.
        let len = self.history.len();
        let cur = &self.history[len - 1];
        let old = &self.history[0];
        let prev = if len >= 2 { Some(&self.history[len - 2]) } else { None };
        let mut fired = Vec::new();

        // --- DP1: flow-share skew over the horizon (prefill pool) ---
        let pool = &self.prefill_members;
        let np = pool.len();
        let mut dp1_hit = false;
        if np >= 2 {
            let arrivals: Vec<u64> =
                pool.iter().map(|&r| cur.routed[r].saturating_sub(old.routed[r])).collect();
            let total: u64 = arrivals.iter().sum();
            if total >= MIN_ARRIVALS {
                let hot_k = argmax_u64(&arrivals);
                let hot = pool[hot_k];
                let share = arrivals[hot_k] as f64 / total as f64;
                let threshold = Self::share_threshold(np);
                if share >= threshold {
                    dp1_hit = true;
                    self.streaks[0] += 1;
                    if self.streaks[0] >= CONFIRM_DP1 {
                        fired.push(Detection {
                            condition: Condition::Dp1RouterFlowSkew,
                            node: self.entry_nodes[hot],
                            at: now,
                            severity: share * np as f64,
                            evidence: format!(
                                "replica {hot} absorbs {:.0}% of {total} arrivals \
                                 (fair share {:.0}%, threshold {:.0}%)",
                                share * 100.0,
                                100.0 / np as f64,
                                threshold * 100.0
                            ),
                        });
                    }
                }
            }
        }
        if !dp1_hit {
            self.streaks[0] = 0;
        }

        // --- DP2: hot-replica KV exhaustion (decode pool, window-level) ---
        let pool = &self.decode_members;
        let nd = pool.len();
        let mut dp2_hit = false;
        if nd >= 2 {
            if let Some(prev) = prev {
                let hot = first_max_by(pool, |r| cur.kv_occupancy[r]);
                let hot_occ = cur.kv_occupancy[hot];
                let min_occ = pool
                    .iter()
                    .filter(|&&r| r != hot)
                    .map(|&r| cur.kv_occupancy[r])
                    .fold(f64::INFINITY, f64::min);
                let failures = cur.alloc_failures[hot].saturating_sub(prev.alloc_failures[hot]);
                if hot_occ >= KV_HOT_OCC && failures >= 1 && hot_occ - min_occ >= KV_DISPARITY {
                    dp2_hit = true;
                    self.streaks[1] += 1;
                    if self.streaks[1] >= CONFIRM_DP2 {
                        fired.push(Detection {
                            condition: Condition::Dp2HotReplicaKv,
                            node: self.entry_nodes[hot],
                            at: now,
                            severity: hot_occ - min_occ,
                            evidence: format!(
                                "replica {hot} KV at {:.0}% with {failures} admission \
                                 failures this window; coldest peer at {:.0}%",
                                hot_occ * 100.0,
                                min_occ * 100.0
                            ),
                        });
                    }
                }
            }
        }
        if !dp2_hit {
            self.streaks[1] = 0;
        }

        // --- DP3: straggler replica (decode pool: backlog + lagging rate) ---
        let mut dp3_hit = false;
        if nd >= 2 {
            let lag = first_max_by(pool, |r| cur.queue_depth[r] as f64);
            let lag_q = cur.queue_depth[lag];
            let iters_of =
                |r: usize| cur.iterations[r].saturating_sub(old.iterations[r]);
            let others_q: u64 =
                pool.iter().filter(|&&r| r != lag).map(|&r| cur.queue_depth[r]).sum();
            let others_mean_q = others_q as f64 / (nd - 1) as f64;
            let others_it: u64 = pool.iter().filter(|&&r| r != lag).map(|&r| iters_of(r)).sum();
            let others_mean_it = others_it as f64 / (nd - 1) as f64;
            dp3_hit = lag_q >= STRAGGLER_MIN_QUEUE
                && lag_q as f64 >= STRAGGLER_QUEUE_FACTOR * (others_mean_q + 1.0)
                && (iters_of(lag) as f64) < STRAGGLER_ITER_RATIO * (others_mean_it + 1.0);
            if dp3_hit {
                self.streaks[2] += 1;
                if self.streaks[2] >= CONFIRM_DP3 {
                    fired.push(Detection {
                        condition: Condition::Dp3StragglerReplica,
                        node: self.entry_nodes[lag],
                        at: now,
                        severity: lag_q as f64 / (others_mean_q + 1.0),
                        evidence: format!(
                            "replica {lag} backlog {lag_q} vs peer mean {others_mean_q:.1}; \
                             {} iterations over the horizon vs peer mean {others_mean_it:.0}",
                            iters_of(lag)
                        ),
                    });
                }
            }
        }
        if !dp3_hit {
            self.streaks[2] = 0;
        }

        fired
    }

    /// Feed one window's pool-boundary observation (disaggregated fleets
    /// only); returns the PD detections fired.
    pub fn pd_window_tick(&mut self, now: SimTime, sample: PdSample) -> Vec<Detection> {
        debug_assert_eq!(sample.prefill_queue.len(), self.n_replicas);
        self.pd_history.push_back(sample);
        if self.pd_history.len() > HORIZON + 1 {
            self.pd_history.pop_front();
        }
        let len = self.pd_history.len();
        let cur = &self.pd_history[len - 1];
        let old = &self.pd_history[0];
        let prev = if len >= 2 { Some(&self.pd_history[len - 2]) } else { None };
        let mut fired = Vec::new();

        // --- PD1: prefill-pool saturation while the decode pool idles ---
        let prefill_q: u64 = self.prefill_members.iter().map(|&r| cur.prefill_queue[r]).sum();
        let old_q: u64 = self.prefill_members.iter().map(|&r| old.prefill_queue[r]).sum();
        let slots: u64 = self.decode_members.iter().map(|&r| cur.decode_slots[r]).sum();
        let running: u64 = self.decode_members.iter().map(|&r| cur.decode_running[r]).sum();
        let decode_util = running as f64 / slots.max(1) as f64;
        let pd1_hit =
            prefill_q >= PD1_MIN_QUEUE && prefill_q > old_q && decode_util <= PD1_DECODE_UTIL_MAX;
        if pd1_hit {
            self.pd_streaks[0] += 1;
            if self.pd_streaks[0] >= CONFIRM_PD1 {
                let hot = first_max_by(&self.prefill_members, |r| cur.prefill_queue[r] as f64);
                fired.push(Detection {
                    condition: Condition::Pd1PrefillSaturation,
                    node: self.entry_nodes[hot],
                    at: now,
                    severity: prefill_q as f64 / PD1_MIN_QUEUE as f64,
                    evidence: format!(
                        "prefill pool backlog {prefill_q} (was {old_q} a horizon ago) while \
                         the decode pool runs {running}/{slots} slots ({:.0}% busy)",
                        decode_util * 100.0
                    ),
                });
            }
        } else {
            self.pd_streaks[0] = 0;
        }

        // --- PD2: KV-handoff fabric latency vs line-rate expectation ---
        // Measured over the whole horizon, not one window: completions under
        // a stall arrive sparse-then-bursty, and a single thin window must
        // neither fire nor reset the streak.
        let mut pd2_hit = false;
        if prev.is_some() {
            let done = cur.handoffs_completed.saturating_sub(old.handoffs_completed);
            let inflight = cur.handoffs_started.saturating_sub(cur.handoffs_completed);
            if done < PD2_MIN_HANDOFFS && inflight >= PD2_STALL_INFLIGHT {
                // Degenerate total stall: transfers pile up on the fabric
                // with (almost) nothing landing — no latency sample will
                // ever accumulate, so the backlog itself is the red flag.
                pd2_hit = true;
                self.pd_streaks[1] += 1;
                if self.pd_streaks[1] >= CONFIRM_PD2 {
                    let dst = first_max_by(&self.decode_members, |r| {
                        cur.handoff_arrivals[r] as f64
                    });
                    fired.push(Detection {
                        condition: Condition::Pd2KvHandoffStall,
                        node: self.entry_nodes[dst],
                        at: now,
                        severity: inflight as f64 / PD2_STALL_INFLIGHT as f64,
                        evidence: format!(
                            "KV handoffs frozen: {inflight} in flight on the fabric with \
                             only {done} landing over the horizon"
                        ),
                    });
                }
            } else if done >= PD2_MIN_HANDOFFS {
                let lat_sum = cur.handoff_lat_sum_ns.saturating_sub(old.handoff_lat_sum_ns);
                let bytes = cur.handoff_bytes.saturating_sub(old.handoff_bytes);
                let mean_lat = lat_sum as f64 / done as f64;
                let mean_bytes = bytes as f64 / done as f64;
                let expected = mean_bytes / self.nic_bw.max(1.0) * 1e9 * PD2_PATH_HOPS
                    + PD2_BASE_ALLOWANCE_NS;
                if mean_lat >= PD2_LAT_FACTOR * expected {
                    pd2_hit = true;
                    self.pd_streaks[1] += 1;
                    if self.pd_streaks[1] >= CONFIRM_PD2 {
                        let dst = first_max_by(&self.decode_members, |r| {
                            cur.handoff_arrivals[r].saturating_sub(old.handoff_arrivals[r])
                                as f64
                        });
                        fired.push(Detection {
                            condition: Condition::Pd2KvHandoffStall,
                            node: self.entry_nodes[dst],
                            at: now,
                            severity: mean_lat / expected.max(1.0),
                            evidence: format!(
                                "KV handoffs average {:.0} us over {done} transfers vs \
                                 {:.0} us line-rate expectation ({:.0} KB mean)",
                                mean_lat / 1e3,
                                expected / 1e3,
                                mean_bytes / 1e3
                            ),
                        });
                    }
                }
            }
        }
        if !pd2_hit {
            self.pd_streaks[1] = 0;
        }

        // --- PD3: handoff arrivals concentrate on one decode replica ---
        let pool = &self.decode_members;
        let nd = pool.len();
        let mut pd3_hit = false;
        if nd >= 2 {
            let arrivals: Vec<u64> = pool
                .iter()
                .map(|&r| cur.handoff_arrivals[r].saturating_sub(old.handoff_arrivals[r]))
                .collect();
            let total: u64 = arrivals.iter().sum();
            if total >= PD3_MIN_ARRIVALS {
                let hot_k = argmax_u64(&arrivals);
                let hot = pool[hot_k];
                let share = arrivals[hot_k] as f64 / total as f64;
                let threshold = (1.0 / nd as f64 + PD3_SHARE_MARGIN).min(0.92);
                if share >= threshold {
                    pd3_hit = true;
                    self.pd_streaks[2] += 1;
                    if self.pd_streaks[2] >= CONFIRM_PD3 {
                        fired.push(Detection {
                            condition: Condition::Pd3DecodeStarvation,
                            node: self.entry_nodes[hot],
                            at: now,
                            severity: share * nd as f64,
                            evidence: format!(
                                "decode replica {hot} receives {:.0}% of {total} KV handoffs \
                                 (fair share {:.0}%); {} parked awaiting admission",
                                share * 100.0,
                                100.0 / nd as f64,
                                cur.stalled_wait_depth
                            ),
                        });
                    }
                }
            }
        }
        if !pd3_hit {
            self.pd_streaks[2] = 0;
        }

        fired
    }
}

fn argmax_u64(xs: &[u64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// First (lowest-index) member maximizing `key` — strict-greater comparison
/// keeps the pre-pool argmax tie-break, so a full-membership pool reproduces
/// the classic sensor's picks exactly.
fn first_max_by(members: &[usize], key: impl Fn(usize) -> f64) -> usize {
    let mut best = members[0];
    let mut best_k = key(best);
    for &r in &members[1..] {
        let k = key(r);
        if k > best_k {
            best = r;
            best_k = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(|i| NodeId(i as u32)).collect()
    }

    /// Classic colocated sensor (full-fleet comparisons).
    fn sensor(n: usize) -> FleetSensor {
        FleetSensor::new(n, nodes(n), vec![ReplicaRole::Colocated; n], 50e9)
    }

    /// Disaggregated sensor: replica 0 prefill, the rest decode.
    fn pd_sensor(n: usize) -> FleetSensor {
        let mut roles = vec![ReplicaRole::Decode; n];
        roles[0] = ReplicaRole::Prefill;
        FleetSensor::new(n, nodes(n), roles, 50e9)
    }

    fn quiet_pd(n: usize) -> PdSample {
        PdSample {
            prefill_queue: vec![0; n],
            decode_running: vec![0; n],
            decode_slots: vec![8; n],
            handoff_arrivals: vec![0; n],
            handoffs_started: 0,
            handoffs_completed: 0,
            handoff_lat_sum_ns: 0,
            handoff_bytes: 0,
            stalled_wait_depth: 0,
        }
    }

    fn sample(routed: Vec<u64>, q: Vec<u64>, kv: Vec<f64>, it: Vec<u64>, af: Vec<u64>) -> FleetSample {
        FleetSample {
            routed,
            queue_depth: q,
            kv_occupancy: kv,
            iterations: it,
            alloc_failures: af,
        }
    }

    #[test]
    fn single_replica_is_inert() {
        let mut s = sensor(1);
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(vec![w * 50], vec![900], vec![1.0], vec![w], vec![w * 3]),
            );
            assert!(fired.is_empty());
        }
    }

    #[test]
    fn balanced_fleet_stays_quiet() {
        let mut s = sensor(3);
        for w in 0..200u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 11, w * 9],
                    vec![1, 0, 2],
                    vec![0.3, 0.35, 0.28],
                    vec![w * 5, w * 5, w * 5],
                    vec![0, 0, 0],
                ),
            );
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn dp1_fires_on_flow_concentration() {
        let mut s = sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // 80% of arrivals land on replica 0.
                sample(
                    vec![w * 16, w * 2, w * 2],
                    vec![5, 0, 0],
                    vec![0.4, 0.1, 0.1],
                    vec![w * 5, w * 2, w * 2],
                    vec![0, 0, 0],
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp1RouterFlowSkew),
            "{fired_any:?}"
        );
        assert!(fired_any.iter().all(|d| d.condition != Condition::Dp2HotReplicaKv));
    }

    #[test]
    fn dp2_fires_on_hot_kv_with_failures() {
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 10, w * 10],
                    vec![3, 1],
                    vec![0.97, 0.2],
                    vec![w * 5, w * 5],
                    vec![w * 4, 0], // failures accumulate on replica 0
                ),
            ));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Dp2HotReplicaKv),
            "{fired_any:?}"
        );
        assert_eq!(
            fired_any.iter().find(|d| d.condition == Condition::Dp2HotReplicaKv).unwrap().node,
            NodeId(0)
        );
    }

    #[test]
    fn dp3_fires_on_backlogged_slow_replica() {
        let mut s = sensor(2);
        let mut fired_any = Vec::new();
        for w in 0..60u64 {
            fired_any.extend(s.window_tick(
                SimTime(w * 1_000_000),
                // Replica 1: deep queue, quarter the iteration rate.
                sample(
                    vec![w * 10, w * 10],
                    vec![0, 40 + w],
                    vec![0.3, 0.5],
                    vec![w * 8, w * 2],
                    vec![0, 0],
                ),
            ));
        }
        let dp3: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Dp3StragglerReplica)
            .collect();
        assert!(!dp3.is_empty(), "{fired_any:?}");
        assert_eq!(dp3[0].node, NodeId(1));
    }

    #[test]
    fn disagg_sole_prefill_replica_is_not_flow_skew() {
        // A lone prefill replica legitimately absorbs 100% of admissions;
        // pool scoping must keep DP1 quiet.
        let mut s = pd_sensor(3);
        for w in 0..80u64 {
            let fired = s.window_tick(
                SimTime(w * 1_000_000),
                sample(
                    vec![w * 30, 0, 0],
                    vec![2, 0, 0],
                    vec![0.2, 0.3, 0.3],
                    vec![w * 5, w * 20, w * 20],
                    vec![0, 0, 0],
                ),
            );
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn pd1_fires_on_prefill_backlog_with_idle_decode() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..20u64 {
            let mut p = quiet_pd(3);
            p.prefill_queue = vec![30 + w * 10, 0, 0];
            p.decode_running = vec![0, 1, 1];
            p.handoff_arrivals = vec![0, w * 3, w * 3];
            p.handoffs_completed = w * 6;
            p.handoff_lat_sum_ns = w * 6 * 20_000;
            p.handoff_bytes = w * 6 * 256 * 1024;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        let pd1: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Pd1PrefillSaturation)
            .collect();
        assert!(!pd1.is_empty(), "{fired_any:?}");
        assert_eq!(pd1[0].node, NodeId(0), "PD1 localizes to the backlogged prefill replica");
        assert!(fired_any.iter().all(|d| d.condition != Condition::Pd2KvHandoffStall));
    }

    #[test]
    fn pd2_fires_on_handoff_latency_blowout() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            let mut p = quiet_pd(3);
            // 256 KB handoffs: line-rate expectation ~25 us; observed 400 us.
            p.handoff_arrivals = vec![0, w * 4, w * 4];
            p.handoffs_completed = w * 8;
            p.handoff_lat_sum_ns = w * 8 * 400_000;
            p.handoff_bytes = w * 8 * 256 * 1024;
            p.decode_running = vec![0, 1, 1];
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pd2KvHandoffStall),
            "{fired_any:?}"
        );
    }

    #[test]
    fn pd2_fires_on_a_total_stall_with_no_latency_samples() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..10u64 {
            let mut p = quiet_pd(3);
            // Handoffs launch but essentially never land: no usable latency
            // population, just a growing in-flight backlog.
            p.handoffs_started = 20 + w * 10;
            p.handoffs_completed = 2;
            p.handoff_arrivals = vec![0, 2, 0];
            p.handoff_lat_sum_ns = 2 * 30_000;
            p.handoff_bytes = 2 * 256 * 1024;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        assert!(
            fired_any.iter().any(|d| d.condition == Condition::Pd2KvHandoffStall),
            "{fired_any:?}"
        );
        assert!(fired_any.iter().any(|d| d.evidence.contains("frozen")));
    }

    #[test]
    fn sync_pools_rescopes_after_a_role_shift() {
        let mut s = pd_sensor(3); // decode pool {1, 2}
        // Wedge-like concentration on replica 1 builds a PD3 streak...
        for w in 0..2u64 {
            let mut p = quiet_pd(3);
            p.handoff_arrivals = vec![0, w * 30, 0];
            p.handoffs_started = w * 30;
            p.handoffs_completed = w * 30;
            p.handoff_lat_sum_ns = w * 30 * 20_000;
            p.handoff_bytes = w * 30 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "confirmation not yet reached: {fired:?}");
        }
        // ...then RebalancePools moves replica 2 into the prefill pool:
        // replica 1 is now the SOLE decode member, and its 100% share is
        // simply correct — PD3 must go inert, not fire.
        let roles =
            vec![ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Prefill];
        s.sync_pools(&roles);
        for w in 2..10u64 {
            let mut p = quiet_pd(3);
            p.handoff_arrivals = vec![0, w * 30, 0];
            p.handoffs_started = w * 30;
            p.handoffs_completed = w * 30;
            p.handoff_lat_sum_ns = w * 30 * 20_000;
            p.handoff_bytes = w * 30 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "stale-pool PD3 after role shift: {fired:?}");
        }
        // Unchanged roles are a no-op (streak state preserved elsewhere).
        s.sync_pools(&roles);
    }

    #[test]
    fn pd2_quiet_at_line_rate() {
        let mut s = pd_sensor(3);
        for w in 0..40u64 {
            let mut p = quiet_pd(3);
            // 256 KB at ~line-rate latency (expectation ~25 us, observed 30).
            p.handoff_arrivals = vec![0, w * 4, w * 4];
            p.handoffs_completed = w * 8;
            p.handoff_lat_sum_ns = w * 8 * 30_000;
            p.handoff_bytes = w * 8 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn pd3_fires_on_handoff_concentration() {
        let mut s = pd_sensor(3);
        let mut fired_any = Vec::new();
        for w in 0..20u64 {
            let mut p = quiet_pd(3);
            // All handoffs land on decode replica 1; replica 2 starves.
            p.handoff_arrivals = vec![0, w * 10, 0];
            p.handoffs_completed = w * 10;
            p.handoff_lat_sum_ns = w * 10 * 20_000;
            p.handoff_bytes = w * 10 * 256 * 1024;
            p.decode_running = vec![0, 8, 0];
            p.stalled_wait_depth = w;
            fired_any.extend(s.pd_window_tick(SimTime(w * 1_000_000), p));
        }
        let pd3: Vec<_> = fired_any
            .iter()
            .filter(|d| d.condition == Condition::Pd3DecodeStarvation)
            .collect();
        assert!(!pd3.is_empty(), "{fired_any:?}");
        assert_eq!(pd3[0].node, NodeId(1), "PD3 localizes to the wedged decode replica");
    }

    #[test]
    fn balanced_disagg_pool_stays_quiet() {
        let mut s = pd_sensor(3);
        for w in 0..60u64 {
            let mut p = quiet_pd(3);
            p.prefill_queue = vec![2, 0, 0];
            p.decode_running = vec![0, 6, 6];
            p.handoff_arrivals = vec![0, w * 5, w * 5 + (w % 2)];
            p.handoffs_completed = w * 10;
            p.handoff_lat_sum_ns = w * 10 * 28_000;
            p.handoff_bytes = w * 10 * 256 * 1024;
            let fired = s.pd_window_tick(SimTime(w * 1_000_000), p);
            assert!(fired.is_empty(), "window {w}: {fired:?}");
        }
    }

    #[test]
    fn confirmation_requires_persistence() {
        let mut s = sensor(2);
        // A single anomalous window must not fire (DP2 needs 2 consecutive).
        let quiet = sample(vec![0, 0], vec![0, 0], vec![0.2, 0.2], vec![0, 0], vec![0, 0]);
        s.window_tick(SimTime(0), quiet.clone());
        let hot = sample(vec![10, 10], vec![2, 0], vec![0.95, 0.2], vec![5, 5], vec![4, 0]);
        let fired = s.window_tick(SimTime(1_000_000), hot);
        assert!(fired.is_empty(), "{fired:?}");
        let calm = s.window_tick(SimTime(2_000_000), quiet);
        assert!(calm.is_empty());
    }
}
