//! Experiment runner: the shared harness behind every bench and example.
//! One condition-experiment = healthy run + injected run (+ optionally a
//! mitigated run), with detection quality and serving-impact deltas. Also
//! owns the per-condition scenario shaping and the expected-cause oracle the
//! matrix runner scores attribution against.

use crate::dpu::attribution::RootCause;
use crate::dpu::detectors::Condition;
use crate::dpu::runbook;
use crate::sim::{SimDur, SimTime, MS};
use crate::coordinator::scenario::{RunResult, ScenarioCfg};
use crate::coordinator::snapshot;

/// Standard experiment timing: calibration + measurement phases.
pub fn standard_cfg() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(2600);
    cfg.warmup_windows = 20; // 200ms startup transient discarded
    cfg.calib_windows = 100; // 1s calibration at 10ms windows
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 400.0 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 48 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 16 };
    cfg
}

/// Injection time used by condition experiments (after calibration).
pub fn inject_time(cfg: &ScenarioCfg) -> SimTime {
    SimTime((cfg.warmup_windows + cfg.calib_windows) * cfg.window.ns() + 300 * MS)
}

/// Per-condition scenario shaping (see DESIGN.md §4): some runbook rows only
/// produce their red flag under a compute-dominated profile or a saturated
/// decode pool. The recipes live in the condition catalog (`shape_matrix`
/// on each [`crate::conditions::ConditionSpec`]); this applies them on top
/// of a base config. Shared by the matrix, the sweep CLI, and the benches.
pub fn shaped_cfg(c: Condition, base: &ScenarioCfg) -> ScenarioCfg {
    let mut cfg = base.clone();
    if let Some(shape) = crate::conditions::spec(c).shape_matrix {
        shape(&mut cfg);
    }
    cfg
}

/// Which root-cause classes count as a correct attribution per condition
/// (the catalog's `expected_causes`). EW1-EW3 accept both verdicts of the
/// §4.2 refinement: GPU/host-side when a PCIe-vantage anomaly corroborates,
/// network-side when PCIe looks healthy.
pub fn expected_cause_classes(c: Condition) -> &'static [&'static str] {
    crate::conditions::spec(c).expected_causes
}

/// Cause-class label of an attribution verdict.
pub fn cause_class(c: &RootCause) -> &'static str {
    match c {
        RootCause::HostLocal(_) => "host",
        RootCause::GpuSide(_) => "gpu",
        RootCause::NetworkSide => "network",
        RootCause::WorkloadShape => "workload",
        RootCause::ClientSide => "client",
    }
}

/// Outcome of one condition's inject-and-detect experiment.
#[derive(Debug)]
pub struct ConditionReport {
    pub condition: Condition,
    pub injection_desc: String,
    /// Did the matching detector fire after injection?
    pub detected: bool,
    /// Injection -> first correct detection.
    pub detection_latency: Option<SimDur>,
    /// All conditions that fired after injection (cross-talk view).
    pub fired: Vec<(Condition, usize)>,
    /// Serving metrics: healthy vs injected.
    pub healthy: RunResult,
    pub injected: RunResult,
    /// Optional third phase: injected with the closed loop enabled.
    pub mitigated: Option<RunResult>,
}

impl ConditionReport {
    /// Throughput ratio injected/healthy (the condition's serving impact).
    pub fn throughput_impact(&self) -> f64 {
        let h = self.healthy.metrics.tok_per_s();
        if h <= 0.0 {
            return 1.0;
        }
        self.injected.metrics.tok_per_s() / h
    }

    /// p99 TTFT inflation factor under injection.
    pub fn p99_inflation(&self) -> f64 {
        let h = self.healthy.metrics.ttft_ns.p99();
        if h <= 0.0 {
            return 1.0;
        }
        self.injected.metrics.ttft_ns.p99() / h
    }

    /// Fraction of lost throughput recovered by mitigation.
    pub fn recovery(&self) -> Option<f64> {
        let m = self.mitigated.as_ref()?;
        let h = self.healthy.metrics.tok_per_s();
        let i = self.injected.metrics.tok_per_s();
        let mm = m.metrics.tok_per_s();
        if h - i < 1e-9 {
            return Some(1.0);
        }
        Some(((mm - i) / (h - i)).clamp(0.0, 1.5))
    }
}

/// Run the standard three-phase experiment for one condition. The phases
/// share every pre-injection event, so they go through the snapshot runner
/// as one prefix group: the world is simulated once up to the injection
/// instant and the healthy / injected / mitigated branches fork from that
/// checkpoint (no duplicate healthy prefix simulation).
pub fn condition_experiment(
    c: Condition,
    base: &ScenarioCfg,
    with_mitigation: bool,
) -> ConditionReport {
    let mut inj_cfg = base.clone();
    inj_cfg.inject = Some((c, inject_time(base)));
    let mut cfgs = vec![base.clone(), inj_cfg.clone()];
    if with_mitigation {
        inj_cfg.mitigate = true;
        cfgs.push(inj_cfg);
    }
    let (mut results, _) = snapshot::run_all(cfgs, 1, false);
    let mitigated = if with_mitigation { results.pop() } else { None };
    let injected = results.pop().expect("injected phase result");
    let healthy = results.pop().expect("healthy phase result");

    let t0 = injected.injected_at.unwrap_or(SimTime::ZERO);
    let detected = injected.detections.iter().any(|d| d.condition == c && d.at >= t0);
    let detection_latency = injected.detection_latency(c);
    let mut fired_map = std::collections::BTreeMap::new();
    for d in &injected.detections {
        if d.at >= t0 {
            *fired_map.entry(d.condition).or_insert(0usize) += 1;
        }
    }
    ConditionReport {
        condition: c,
        injection_desc: injected.injection_desc.clone().unwrap_or_default(),
        detected,
        detection_latency,
        fired: fired_map.into_iter().collect(),
        healthy,
        injected,
        mitigated,
    }
}

/// Render a paper-style runbook row + measured columns.
pub fn report_row(r: &ConditionReport) -> Vec<String> {
    let e = runbook::entry(r.condition);
    vec![
        r.condition.id().to_string(),
        if r.detected { "yes".into() } else { "NO".into() },
        r.detection_latency
            .map(|d| crate::util::table::fmt_ns(d.ns() as f64))
            .unwrap_or_else(|| "-".into()),
        format!("{:.2}x", r.throughput_impact()),
        format!("{:.1}x", r.p99_inflation()),
        match r.recovery() {
            Some(f) => format!("{:.0}%", f * 100.0),
            None => "-".into(),
        },
        format!("{:?}", e.directive),
    ]
}

pub fn report_header() -> [&'static str; 7] {
    ["id", "detected", "latency", "tput(inj/healthy)", "p99 ttft infl", "recovered", "directive"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::detectors::{ALL_CONDITIONS, DP_CONDITIONS};

    #[test]
    fn expected_classes_cover_all_conditions() {
        use crate::dpu::detectors::{PD_CONDITIONS, TD_CONDITIONS};
        for c in ALL_CONDITIONS
            .iter()
            .chain(DP_CONDITIONS.iter())
            .chain(PD_CONDITIONS.iter())
            .chain(TD_CONDITIONS.iter())
        {
            assert!(!expected_cause_classes(*c).is_empty(), "{c:?}");
        }
        assert!(expected_cause_classes(Condition::Pc8HostCpuBottleneck).contains(&"host"));
        assert!(expected_cause_classes(Condition::Ew1TpStraggler).contains(&"network"));
        assert!(expected_cause_classes(Condition::Ns8EarlyCompletion).contains(&"workload"));
        assert!(expected_cause_classes(Condition::Dp3StragglerReplica).contains(&"gpu"));
        // The TD family degrades the monitoring path itself; the paper's
        // vantage-point logic files that under the network-side class.
        assert!(expected_cause_classes(Condition::Td1StaleFrozen).contains(&"network"));
    }

    #[test]
    fn shaped_cfg_promotes_compute_profiles() {
        let base = standard_cfg();
        assert_eq!(shaped_cfg(Condition::Ew1TpStraggler, &base).engine.profile.name, "7b");
        assert_eq!(shaped_cfg(Condition::Ns4IngressRetx, &base).engine.profile.name, "small");
        // Shaping never touches the seed or the injection slot.
        let s = shaped_cfg(Condition::Ew2PpBubble, &base);
        assert_eq!(s.seed, base.seed);
        assert!(s.inject.is_none());
    }

    #[test]
    fn cause_class_covers_every_variant() {
        use crate::ids::NodeId;
        assert_eq!(cause_class(&RootCause::HostLocal(NodeId(0))), "host");
        assert_eq!(cause_class(&RootCause::GpuSide(NodeId(1))), "gpu");
        assert_eq!(cause_class(&RootCause::NetworkSide), "network");
        assert_eq!(cause_class(&RootCause::WorkloadShape), "workload");
        assert_eq!(cause_class(&RootCause::ClientSide), "client");
    }

    #[test]
    fn condition_experiment_ew7_detects() {
        let mut cfg = standard_cfg();
        cfg.duration = SimDur::from_ms(2200);
        let rep = condition_experiment(Condition::Ew7CreditStarvation, &cfg, false);
        assert!(rep.detected, "EW7 undetected; fired={:?}", rep.fired);
        assert!(rep.detection_latency.is_some());
        let row = report_row(&rep);
        assert_eq!(row.len(), report_header().len());
    }
}
