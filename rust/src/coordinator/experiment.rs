//! Experiment runner: the shared harness behind every bench and example.
//! One condition-experiment = healthy run + injected run (+ optionally a
//! mitigated run), with detection quality and serving-impact deltas.

use crate::dpu::detectors::Condition;
use crate::dpu::runbook;
use crate::sim::{SimDur, SimTime, MS};
use crate::coordinator::scenario::{RunResult, Scenario, ScenarioCfg};

/// Standard experiment timing: calibration + measurement phases.
pub fn standard_cfg() -> ScenarioCfg {
    let mut cfg = ScenarioCfg::default();
    cfg.duration = SimDur::from_ms(2600);
    cfg.warmup_windows = 20; // 200ms startup transient discarded
    cfg.calib_windows = 100; // 1s calibration at 10ms windows
    cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 400.0 };
    cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 48 };
    cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 4, hi: 16 };
    cfg
}

/// Injection time used by condition experiments (after calibration).
pub fn inject_time(cfg: &ScenarioCfg) -> SimTime {
    SimTime((cfg.warmup_windows + cfg.calib_windows) * cfg.window.ns() + 300 * MS)
}

/// Outcome of one condition's inject-and-detect experiment.
#[derive(Debug)]
pub struct ConditionReport {
    pub condition: Condition,
    pub injection_desc: String,
    /// Did the matching detector fire after injection?
    pub detected: bool,
    /// Injection -> first correct detection.
    pub detection_latency: Option<SimDur>,
    /// All conditions that fired after injection (cross-talk view).
    pub fired: Vec<(Condition, usize)>,
    /// Serving metrics: healthy vs injected.
    pub healthy: RunResult,
    pub injected: RunResult,
    /// Optional third phase: injected with the closed loop enabled.
    pub mitigated: Option<RunResult>,
}

impl ConditionReport {
    /// Throughput ratio injected/healthy (the condition's serving impact).
    pub fn throughput_impact(&self) -> f64 {
        let h = self.healthy.metrics.tok_per_s();
        if h <= 0.0 {
            return 1.0;
        }
        self.injected.metrics.tok_per_s() / h
    }

    /// p99 TTFT inflation factor under injection.
    pub fn p99_inflation(&self) -> f64 {
        let h = self.healthy.metrics.ttft_ns.p99();
        if h <= 0.0 {
            return 1.0;
        }
        self.injected.metrics.ttft_ns.p99() / h
    }

    /// Fraction of lost throughput recovered by mitigation.
    pub fn recovery(&self) -> Option<f64> {
        let m = self.mitigated.as_ref()?;
        let h = self.healthy.metrics.tok_per_s();
        let i = self.injected.metrics.tok_per_s();
        let mm = m.metrics.tok_per_s();
        if h - i < 1e-9 {
            return Some(1.0);
        }
        Some(((mm - i) / (h - i)).clamp(0.0, 1.5))
    }
}

/// Run the standard three-phase experiment for one condition.
pub fn condition_experiment(
    c: Condition,
    base: &ScenarioCfg,
    with_mitigation: bool,
) -> ConditionReport {
    let healthy = Scenario::new(base.clone()).run();

    let mut inj_cfg = base.clone();
    inj_cfg.inject = Some((c, inject_time(base)));
    let injected = Scenario::new(inj_cfg.clone()).run();

    let mitigated = if with_mitigation {
        let mut mit_cfg = inj_cfg.clone();
        mit_cfg.mitigate = true;
        Some(Scenario::new(mit_cfg).run())
    } else {
        None
    };

    let t0 = injected.injected_at.unwrap_or(SimTime::ZERO);
    let detected = injected.detections.iter().any(|d| d.condition == c && d.at >= t0);
    let detection_latency = injected.detection_latency(c);
    let mut fired_map = std::collections::BTreeMap::new();
    for d in &injected.detections {
        if d.at >= t0 {
            *fired_map.entry(d.condition).or_insert(0usize) += 1;
        }
    }
    ConditionReport {
        condition: c,
        injection_desc: injected.injection_desc.clone().unwrap_or_default(),
        detected,
        detection_latency,
        fired: fired_map.into_iter().collect(),
        healthy,
        injected,
        mitigated,
    }
}

/// Render a paper-style runbook row + measured columns.
pub fn report_row(r: &ConditionReport) -> Vec<String> {
    let e = runbook::entry(r.condition);
    vec![
        r.condition.id().to_string(),
        if r.detected { "yes".into() } else { "NO".into() },
        r.detection_latency
            .map(|d| crate::util::table::fmt_ns(d.ns() as f64))
            .unwrap_or_else(|| "-".into()),
        format!("{:.2}x", r.throughput_impact()),
        format!("{:.1}x", r.p99_inflation()),
        match r.recovery() {
            Some(f) => format!("{:.0}%", f * 100.0),
            None => "-".into(),
        },
        format!("{:?}", e.directive),
    ]
}

pub fn report_header() -> [&'static str; 7] {
    ["id", "detected", "latency", "tput(inj/healthy)", "p99 ttft infl", "recovered", "directive"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn condition_experiment_ew7_detects() {
        let mut cfg = standard_cfg();
        cfg.duration = SimDur::from_ms(2200);
        let rep = condition_experiment(Condition::Ew7CreditStarvation, &cfg, false);
        assert!(rep.detected, "EW7 undetected; fired={:?}", rep.fired);
        assert!(rep.detection_latency.is_some());
        let row = report_row(&rep);
        assert_eq!(row.len(), report_header().len());
    }
}
