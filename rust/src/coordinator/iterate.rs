//! Per-replica iteration driving: batch formation and KV admission, the
//! prefill/decode execution handoff to the compute backends, token egress,
//! and retirement of finished sequences. On disaggregated fleets a
//! completed prefill hands its sequences to `coordinator::handoff` instead
//! of its own decode loop.
//!
//! This is the simulator's hot path. A steady-state decode round is O(B)
//! and allocation-free: batch state is read straight off the batcher's SoA
//! [`Lanes`](crate::engine::Lanes) columns, every staging buffer lives in
//! the replica's [`IterScratch`], and per-token egress completions are
//! coalesced into one batched calendar event per iteration
//! ([`Ev::EgressBatch`]) that replays them at their exact legacy
//! `(time, seq)` keys — so the event *order* (and therefore every report
//! byte) is identical to the one-event-per-token path.

use crate::cluster::ReplicaRole;
use crate::engine::exec::{run_iteration_in, IterKind};
use crate::engine::{DecodeSpec, Work};
use crate::ids::ReqId;
use crate::sim::SimTime;
use crate::telemetry::sw::SwSignal;
use crate::workload::request::ReqState;

use super::ingress::{egress_flow, TOKEN_EGRESS_BYTES};
use super::scenario::Scenario;
use super::world::{EgressEntry, Ev, PendingIter};

impl Scenario {
    /// Form the next batch of work on `replica` and launch it.
    pub(crate) fn run_next_iteration(&mut self, replica: usize, now: SimTime) {
        // KV admission happens at prefill-batch formation.
        let work = {
            let rep = &mut self.engine.replicas[replica];
            if !rep.batcher.may_refill() && !rep.batcher.lanes().is_empty() {
                // Static/no-remap mode with a draining batch: decode only.
                Work::DecodeRound
            } else {
                rep.batcher.next_work()
            }
        };
        match work {
            Work::Idle => {
                self.pending[replica] = None;
            }
            Work::Prefill(reqs) => {
                // Admit into KV; anything that doesn't fit goes back.
                let mut admitted = Vec::new();
                for id in reqs {
                    let plen = self.engine.request(id).prompt_len() as u32;
                    let rep = &mut self.engine.replicas[replica];
                    if rep.kv.admit(id, plen) == crate::engine::AllocResult::Ok
                        && !self.free_slots[replica].is_empty()
                    {
                        let slot = self.free_slots[replica].pop().unwrap();
                        self.slot_of.insert(id, slot);
                        admitted.push(id);
                    } else {
                        self.engine.replicas[replica].kv.release(id);
                        self.engine.replicas[replica].batcher.enqueue(id, plen, now);
                        break;
                    }
                }
                if admitted.is_empty() {
                    self.pending[replica] = None;
                    return;
                }
                let prompt_lens: Vec<u32> =
                    admitted.iter().map(|id| self.engine.request(*id).prompt_len() as u32).collect();
                for &id in &admitted {
                    let r = self.engine.request_mut(id);
                    r.state = ReqState::Prefilling;
                    r.prefill_start = Some(now);
                }
                let kind = IterKind::Prefill { reqs: admitted, prompt_lens };
                self.execute(replica, now, kind);
            }
            Work::DecodeRound => {
                // The round *is* the lane slice: copy the admission-ordered
                // columns into the recycled `IterKind` vectors (O(B), no
                // allocation once capacities plateau).
                let mut reqs = std::mem::take(&mut self.iter_scratch[replica].reqs);
                let mut ctx_lens = std::mem::take(&mut self.iter_scratch[replica].ctx_lens);
                reqs.clear();
                ctx_lens.clear();
                {
                    let lanes = self.engine.replicas[replica].batcher.lanes();
                    reqs.extend_from_slice(lanes.reqs());
                    ctx_lens.extend_from_slice(lanes.positions());
                }
                // KV growth for the step.
                for &id in &reqs {
                    let _ = self.engine.replicas[replica].kv.append_token(id);
                }
                let kind = IterKind::Decode { reqs, ctx_lens };
                self.execute(replica, now, kind);
            }
        }
    }

    /// Run one iteration through the cluster hardware model and schedule its
    /// completion.
    pub(crate) fn execute(&mut self, replica: usize, now: SimTime, kind: IterKind) {
        let (done, _flops) = {
            let scratch = &mut self.iter_scratch[replica];
            let rep = &mut self.engine.replicas[replica];
            rep.iterations += 1;
            match &kind {
                IterKind::Prefill { .. } => rep.prefills += 1,
                IterKind::Decode { .. } => rep.decodes += 1,
            }
            run_iteration_in(
                now,
                &kind,
                &mut self.cluster,
                &rep.plan,
                &self.cfg.engine.profile,
                &mut rep.colls,
                &mut self.outbox,
                &mut scratch.exec,
            )
        };
        self.iterations += 1;
        self.flush_outbox();
        self.sw_window.record(SwSignal::StepTime, (done - now).ns() as f64);
        self.sw_window.record(SwSignal::GpuUtil, 0.8);
        self.sw_window
            .record(SwSignal::KvOccupancy, self.engine.replicas[replica].kv.occupancy());
        self.pending[replica] = Some(PendingIter { kind, started: now });
        self.schedule_replica_at(replica, done, Ev::IterDone(replica));
    }

    /// An iteration's hardware time elapsed: produce tokens via the compute
    /// backend, advance batcher/KV state, and emit egress. Hardware-model
    /// telemetry accumulated across the token loop is flushed to the bus
    /// once per iteration, not once per token.
    pub(crate) fn finish_iteration(&mut self, replica: usize, now: SimTime) {
        let Some(pending) = self.pending[replica].take() else { return };
        match pending.kind {
            IterKind::Prefill { reqs, prompt_lens } => {
                let mut slots = std::mem::take(&mut self.iter_scratch[replica].slots);
                slots.clear();
                slots.extend(reqs.iter().map(|id| self.slot_of[id]));
                // Prompts cross to the backend as borrowed slices — a
                // completed prefill never clones token buffers.
                let mut prompts: Vec<&[i32]> = Vec::with_capacity(reqs.len());
                for id in &reqs {
                    prompts.push(self.engine.request(*id).prompt.as_slice());
                }
                let first_tokens = self.backends[replica].prefill(&slots, &prompts);
                drop(prompts);
                self.iter_scratch[replica].slots = slots;
                if self.engine.replicas[replica].plan.shape.role == ReplicaRole::Prefill {
                    // Phase transition: the prefill pool produced the first
                    // token; everything still decoding crosses the pool
                    // boundary as an explicit KV handoff.
                    for (id, tok) in reqs.iter().zip(first_tokens) {
                        let r = self.engine.request_mut(*id);
                        r.generated.push(tok);
                        let finished = r.generated.len() >= r.max_new_tokens;
                        if !finished {
                            r.state = ReqState::KvHandoff;
                        }
                        self.sw_window.record(SwSignal::DecodeProgress, 1.0);
                        self.emit_token(replica, *id, now, finished);
                        self.retire(replica, *id);
                        if !finished {
                            self.start_handoff(replica, *id, now);
                        }
                    }
                } else {
                    let mut specs = std::mem::take(&mut self.iter_scratch[replica].specs);
                    specs.clear();
                    for (id, &plen) in reqs.iter().zip(&prompt_lens) {
                        specs.push(DecodeSpec {
                            req: *id,
                            prompt_len: plen,
                            budget: self.engine.request(*id).max_new_tokens as u32,
                            slot: self.slot_of[id],
                        });
                    }
                    self.engine.replicas[replica].batcher.start_decode(&specs);
                    specs.clear();
                    self.iter_scratch[replica].specs = specs;
                    for (id, tok) in reqs.iter().zip(first_tokens) {
                        let r = self.engine.request_mut(*id);
                        r.state = ReqState::Decoding;
                        r.generated.push(tok);
                        self.sw_window.record(SwSignal::DecodeProgress, r.generated.len() as f64);
                        let finished = self.engine.replicas[replica].batcher.on_token(*id, tok);
                        self.emit_token(replica, *id, now, finished);
                        if finished {
                            self.retire(replica, *id);
                        }
                    }
                }
            }
            IterKind::Decode { reqs, ctx_lens } => {
                // O(B) backend staging straight off the SoA lanes. The round
                // was copied from the lane slice at formation, but `try_adopt`
                // may have *appended* lanes since (a KV handoff landing
                // mid-flight), so resolve each member through the O(1) index.
                // Members can never vanish mid-flight — `finish` only runs
                // inside this function's retire path — so a missing lane is a
                // bookkeeping bug, not a race.
                let mut slots = std::mem::take(&mut self.iter_scratch[replica].slots);
                let mut last_tokens = std::mem::take(&mut self.iter_scratch[replica].last_tokens);
                let mut positions = std::mem::take(&mut self.iter_scratch[replica].positions);
                let mut next_tokens = std::mem::take(&mut self.iter_scratch[replica].next_tokens);
                slots.clear();
                last_tokens.clear();
                positions.clear();
                let max_pos = self.cfg.engine.profile.max_seq as u32 - 1;
                {
                    let lanes = self.engine.replicas[replica].batcher.lanes();
                    for &id in &reqs {
                        let lane = lanes.lane_of(id).unwrap_or_else(|| {
                            panic!("decode round contains untracked request {id:?}")
                        });
                        slots.push(lanes.slots()[lane]);
                        last_tokens.push(lanes.last_tokens()[lane]);
                        positions.push(lanes.positions()[lane].min(max_pos));
                    }
                }
                self.backends[replica].decode_into(&slots, &last_tokens, &positions, &mut next_tokens);
                for (i, &id) in reqs.iter().enumerate() {
                    let tok = next_tokens[i];
                    let r = self.engine.request_mut(id);
                    r.generated.push(tok);
                    let finished = self.engine.replicas[replica].batcher.on_token(id, tok);
                    self.emit_token(replica, id, now, finished);
                    if finished {
                        self.retire(replica, id);
                    }
                }
                let scratch = &mut self.iter_scratch[replica];
                scratch.slots = slots;
                scratch.last_tokens = last_tokens;
                scratch.positions = positions;
                scratch.next_tokens = next_tokens;
                scratch.reqs = reqs;
                scratch.ctx_lens = ctx_lens;
            }
        }
        self.flush_outbox();
        self.kick(replica, now);
    }

    /// Stream one generated token out through the replica's exit node. The
    /// egress completion time (and all NIC telemetry) is computed per token
    /// exactly as before, but the completion is parked on the replica's
    /// coalesced lane and dispatched by one `Ev::EgressBatch` calendar event
    /// per iteration. Each entry carries the `(time, seq)` key its legacy
    /// per-token event would have occupied — minted here, at the same point
    /// in the deterministic sequence stream — so dispatch order and every
    /// downstream timestamp are byte-identical.
    pub(crate) fn emit_token(&mut self, replica: usize, id: ReqId, now: SimTime, last: bool) {
        let node = self.exit_node(replica);
        let flow = egress_flow(id);
        let done = self.cluster.egress(now, node, flow, TOKEN_EGRESS_BYTES, &mut self.outbox);
        let done = done.max(now); // the calendar clamp a scheduled event gets
        if self.cfg.per_token_egress {
            self.schedule_replica_at(replica, done, Ev::EgressDone { req: id, last });
            return;
        }
        let seq = self.cal.alloc_seq();
        let lane = &mut self.egress_lanes[replica];
        let arm = lane.is_empty();
        lane.push_back(EgressEntry { req: id, done, seq, last });
        if arm {
            // First entry on an idle lane: arm the batch event at this
            // entry's own key. A non-empty lane already has its event in
            // flight at the front entry's key (NIC completion times are
            // monotone per node, so later entries never precede it).
            self.schedule_replica_at_seq(replica, done, seq, Ev::EgressBatch(replica));
        }
    }

    /// Dispatch a replica's coalesced egress lane: drain every entry whose
    /// `(done, seq)` key precedes the calendar's next event — exactly the
    /// set of legacy per-token events that would have popped consecutively
    /// here — then re-arm the batch event at the first survivor's key.
    pub(crate) fn on_egress_batch(&mut self, replica: usize) {
        // `on_egress_done` never schedules calendar events (it only mutates
        // request/router/bus state), so the drain limit is computed once.
        let limit = self.cal.peek_key();
        loop {
            let Some(front) = self.egress_lanes[replica].front().copied() else { return };
            if let Some(limit) = limit {
                if (front.done, front.seq) >= limit {
                    // The remainder belongs after the calendar's next event:
                    // re-arm at the front's own pre-minted key and yield.
                    self.schedule_replica_at_seq(
                        replica,
                        front.done,
                        front.seq,
                        Ev::EgressBatch(replica),
                    );
                    return;
                }
            }
            self.egress_lanes[replica].pop_front();
            self.on_egress_done(front.req, front.last, front.done);
        }
    }

    /// Free a finished sequence's batcher slot, KV pages, and backend slot;
    /// freed decode capacity immediately seats any parked KV handoffs.
    pub(crate) fn retire(&mut self, replica: usize, id: ReqId) {
        self.engine.replicas[replica].batcher.finish(id);
        self.engine.replicas[replica].kv.release(id);
        if let Some(slot) = self.slot_of.remove(&id) {
            self.free_slots[replica].push(slot);
        }
        if !self.handoff_wait[replica].is_empty() {
            // `retire` runs inside finish_iteration's token loop, so adopt
            // at the current sim time; the adopted sequence joins the next
            // decode round.
            let now = self.cal.now();
            self.drain_handoff_wait(replica, now);
        }
    }
}
