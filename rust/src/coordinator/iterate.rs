//! Per-replica iteration driving: batch formation and KV admission, the
//! prefill/decode execution handoff to the compute backends, token egress,
//! and retirement of finished sequences. On disaggregated fleets a
//! completed prefill hands its sequences to `coordinator::handoff` instead
//! of its own decode loop.

use crate::cluster::ReplicaRole;
use crate::engine::exec::{run_iteration, IterKind};
use crate::engine::Work;
use crate::ids::ReqId;
use crate::sim::SimTime;
use crate::telemetry::sw::SwSignal;
use crate::workload::request::ReqState;

use super::ingress::{egress_flow, TOKEN_EGRESS_BYTES};
use super::scenario::Scenario;
use super::world::{Ev, PendingIter};

impl Scenario {
    /// Form the next batch of work on `replica` and launch it.
    pub(crate) fn run_next_iteration(&mut self, replica: usize, now: SimTime) {
        // KV admission happens at prefill-batch formation.
        let work = {
            let rep = &mut self.engine.replicas[replica];
            if !rep.batcher.may_refill() && !rep.batcher.running().is_empty() {
                // Static/no-remap mode with a draining batch: decode only.
                Work::DecodeRound(rep.batcher.running().iter().map(|s| s.req).collect())
            } else {
                rep.batcher.next_work()
            }
        };
        match work {
            Work::Idle => {
                self.pending[replica] = None;
            }
            Work::Prefill(reqs) => {
                // Admit into KV; anything that doesn't fit goes back.
                let mut admitted = Vec::new();
                for id in reqs {
                    let plen = self.engine.request(id).prompt_len() as u32;
                    let rep = &mut self.engine.replicas[replica];
                    if rep.kv.admit(id, plen) == crate::engine::AllocResult::Ok
                        && !self.free_slots[replica].is_empty()
                    {
                        let slot = self.free_slots[replica].pop().unwrap();
                        self.slot_of.insert(id, slot);
                        admitted.push(id);
                    } else {
                        self.engine.replicas[replica].kv.release(id);
                        self.engine.replicas[replica].batcher.enqueue(id, plen, now);
                        break;
                    }
                }
                if admitted.is_empty() {
                    self.pending[replica] = None;
                    return;
                }
                let prompt_lens: Vec<u32> =
                    admitted.iter().map(|id| self.engine.request(*id).prompt_len() as u32).collect();
                for &id in &admitted {
                    let r = self.engine.request_mut(id);
                    r.state = ReqState::Prefilling;
                    r.prefill_start = Some(now);
                }
                let kind = IterKind::Prefill { reqs: admitted, prompt_lens };
                self.execute(replica, now, kind);
            }
            Work::DecodeRound(reqs) => {
                let ctx_lens: Vec<u32> = reqs
                    .iter()
                    .map(|id| {
                        self.engine.replicas[replica]
                            .batcher
                            .running()
                            .iter()
                            .find(|s| s.req == *id)
                            .map(|s| s.position)
                            .unwrap_or(1)
                    })
                    .collect();
                // KV growth for the step.
                for &id in &reqs {
                    let rep = &mut self.engine.replicas[replica];
                    let _ = rep.kv.append_token(id);
                }
                let kind = IterKind::Decode { reqs, ctx_lens };
                self.execute(replica, now, kind);
            }
        }
    }

    /// Run one iteration through the cluster hardware model and schedule its
    /// completion.
    pub(crate) fn execute(&mut self, replica: usize, now: SimTime, kind: IterKind) {
        let timing = {
            let rep = &mut self.engine.replicas[replica];
            rep.iterations += 1;
            match &kind {
                IterKind::Prefill { .. } => rep.prefills += 1,
                IterKind::Decode { .. } => rep.decodes += 1,
            }
            run_iteration(
                now,
                &kind,
                &mut self.cluster,
                &rep.plan,
                &self.cfg.engine.profile,
                &mut rep.colls,
                &mut self.outbox,
            )
        };
        self.iterations += 1;
        self.flush_outbox();
        self.sw_window.record(SwSignal::StepTime, (timing.done - now).ns() as f64);
        self.sw_window.record(SwSignal::GpuUtil, 0.8);
        self.sw_window
            .record(SwSignal::KvOccupancy, self.engine.replicas[replica].kv.occupancy());
        self.pending[replica] = Some(PendingIter { kind, started: now });
        self.schedule_replica_at(replica, timing.done, Ev::IterDone(replica));
    }

    /// An iteration's hardware time elapsed: produce tokens via the compute
    /// backend, advance batcher/KV state, and emit egress.
    pub(crate) fn finish_iteration(&mut self, replica: usize, now: SimTime) {
        let Some(pending) = self.pending[replica].take() else { return };
        match pending.kind {
            IterKind::Prefill { reqs, prompt_lens } => {
                let slots: Vec<usize> = reqs.iter().map(|id| self.slot_of[id]).collect();
                let prompts: Vec<Vec<i32>> =
                    reqs.iter().map(|id| self.engine.request(*id).prompt.clone()).collect();
                let first_tokens = self.backends[replica].prefill(&slots, &prompts);
                if self.engine.replicas[replica].plan.shape.role == ReplicaRole::Prefill {
                    // Phase transition: the prefill pool produced the first
                    // token; everything still decoding crosses the pool
                    // boundary as an explicit KV handoff.
                    for (id, tok) in reqs.iter().zip(first_tokens) {
                        let r = self.engine.request_mut(*id);
                        r.generated.push(tok);
                        let finished = r.generated.len() >= r.max_new_tokens;
                        if !finished {
                            r.state = ReqState::KvHandoff;
                        }
                        self.sw_window.record(SwSignal::DecodeProgress, 1.0);
                        self.emit_token(replica, *id, now, finished);
                        self.retire(replica, *id);
                        if !finished {
                            self.start_handoff(replica, *id, now);
                        }
                    }
                } else {
                    let specs: Vec<(ReqId, u32, u32)> = reqs
                        .iter()
                        .zip(&prompt_lens)
                        .map(|(id, &plen)| {
                            (*id, plen, self.engine.request(*id).max_new_tokens as u32)
                        })
                        .collect();
                    self.engine.replicas[replica].batcher.start_decode(&specs);
                    for ((id, tok), _plen) in reqs.iter().zip(first_tokens).zip(&prompt_lens) {
                        let r = self.engine.request_mut(*id);
                        r.state = ReqState::Decoding;
                        r.generated.push(tok);
                        self.sw_window.record(SwSignal::DecodeProgress, r.generated.len() as f64);
                        let finished = self.engine.replicas[replica].batcher.on_token(*id);
                        self.emit_token(replica, *id, now, finished);
                        if finished {
                            self.retire(replica, *id);
                        }
                    }
                }
            }
            IterKind::Decode { reqs, .. } => {
                let slots: Vec<usize> = reqs.iter().map(|id| self.slot_of[id]).collect();
                let last_tokens: Vec<i32> = reqs
                    .iter()
                    .map(|id| *self.engine.request(*id).generated.last().unwrap_or(&1))
                    .collect();
                let positions: Vec<u32> = reqs
                    .iter()
                    .map(|id| {
                        self.engine.replicas[replica]
                            .batcher
                            .running()
                            .iter()
                            .find(|s| s.req == *id)
                            .map(|s| s.position)
                            .unwrap_or(1)
                            .min(self.cfg.engine.profile.max_seq as u32 - 1)
                    })
                    .collect();
                let next = self.backends[replica].decode(&slots, &last_tokens, &positions);
                for (id, tok) in reqs.iter().zip(next) {
                    let r = self.engine.request_mut(*id);
                    r.generated.push(tok);
                    let finished = self.engine.replicas[replica].batcher.on_token(*id);
                    self.emit_token(replica, *id, now, finished);
                    if finished {
                        self.retire(replica, *id);
                    }
                }
            }
        }
        self.kick(replica, now);
    }

    /// Stream one generated token out through the replica's exit node.
    pub(crate) fn emit_token(&mut self, replica: usize, id: ReqId, now: SimTime, last: bool) {
        let node = self.exit_node(replica);
        let flow = egress_flow(id);
        let done = self.cluster.egress(now, node, flow, TOKEN_EGRESS_BYTES, &mut self.outbox);
        self.flush_outbox();
        self.schedule_replica_at(replica, done, Ev::EgressDone { req: id, last });
    }

    /// Free a finished sequence's batcher slot, KV pages, and backend slot;
    /// freed decode capacity immediately seats any parked KV handoffs.
    pub(crate) fn retire(&mut self, replica: usize, id: ReqId) {
        self.engine.replicas[replica].batcher.finish(id);
        self.engine.replicas[replica].kv.release(id);
        if let Some(slot) = self.slot_of.remove(&id) {
            self.free_slots[replica].push(slot);
        }
        if !self.handoff_wait[replica].is_empty() {
            // `retire` runs inside finish_iteration's token loop, so adopt
            // at the current sim time; the adopted sequence joins the next
            // decode round.
            let now = self.cal.now();
            self.drain_handoff_wait(replica, now);
        }
    }
}
