//! Machine-readable experiment reports: serialize run results, detections,
//! attributions, and runbook metadata to JSON for downstream tooling
//! (dashboards, CI trend lines, the paper's tables as data) — plus the
//! matrix scorecard report type ([`MatrixReport`]) with its paper-style
//! table renderer and deterministic JSON form.

use crate::coordinator::scenario::RunResult;
use crate::dpu::detectors::Condition;
use crate::dpu::runbook;
use crate::metrics::{ConfusionMatrix, Scorecard};
use crate::util::json::Json;
use crate::util::table::{fmt_ns, Table};

/// Serialize the serving metrics of a run.
pub fn metrics_json(res: &RunResult) -> Json {
    Json::obj()
        .set("completed", res.metrics.completed)
        .set("rejected", res.metrics.rejected)
        .set("tokens_out", res.metrics.tokens_out)
        .set("req_per_s", res.metrics.req_per_s())
        .set("tok_per_s", res.metrics.tok_per_s())
        .set("ttft_p50_ns", res.metrics.ttft_ns.p50())
        .set("ttft_p95_ns", res.metrics.ttft_ns.p95())
        .set("ttft_p99_ns", res.metrics.ttft_ns.p99())
        .set("tpot_p50_ns", res.metrics.tpot_ns.p50())
        .set("tpot_p99_ns", res.metrics.tpot_ns.p99())
}

/// Serialize a full run: metrics + telemetry accounting + detections.
pub fn run_json(label: &str, res: &RunResult) -> Json {
    let mut detections = Json::arr();
    for d in &res.detections {
        detections.push(
            Json::obj()
                .set("condition", d.condition.id())
                .set("node", d.node.0)
                .set("at_ns", d.at.ns())
                .set("severity", d.severity)
                .set("evidence", d.evidence.as_str()),
        );
    }
    let mut actions = Json::arr();
    for a in &res.actions {
        actions.push(
            Json::obj()
                .set("at_ns", a.at.ns())
                .set("directive", format!("{:?}", a.directive))
                .set("detail", a.detail.as_str()),
        );
    }
    let mut attributions = Json::arr();
    for a in &res.attributions {
        attributions.push(
            Json::obj()
                .set("cause", format!("{:?}", a.cause))
                .set("confidence", a.confidence)
                .set("evidence", a.evidence.as_str()),
        );
    }
    Json::obj()
        .set("label", label)
        .set("real_compute", res.real_compute)
        .set("metrics", metrics_json(res))
        .set("telemetry_published", res.telemetry_published)
        .set("dpu_ingested", res.dpu_ingested)
        .set("dpu_invisible_dropped", res.dpu_invisible_dropped)
        .set("windows", res.windows)
        .set("iterations", res.iterations)
        .set(
            "injected_at_ns",
            res.injected_at.map(|t| Json::Int(t.ns() as i64)).unwrap_or(Json::Null),
        )
        .set(
            "injection",
            res.injection_desc
                .as_deref()
                .map(|d| Json::Str(d.to_string()))
                .unwrap_or(Json::Null),
        )
        .set("detections", detections)
        .set("actions", actions)
        .set("attributions", attributions)
}

/// The encoded paper runbooks (Tables 3a-c) as JSON — the tables as data.
pub fn runbook_json() -> Json {
    let mut rows = Json::arr();
    for e in runbook::all_entries() {
        rows.push(
            Json::obj()
                .set("id", e.condition.id())
                .set("table", e.condition.table())
                .set("signal", e.signal)
                .set("stages", e.stages)
                .set("effect", e.effect)
                .set("root_cause", e.root_cause)
                .set("directive", format!("{:?}", e.directive))
                .set("directive_paper_text", e.directive.paper_text()),
        );
    }
    Json::obj().set("paper", "Khan & Moye 2025").set("conditions", rows)
}

/// Condition-experiment row as JSON (the bench tables as data).
pub fn condition_json(rep: &crate::coordinator::experiment::ConditionReport) -> Json {
    let mut fired = Json::arr();
    for (c, n) in &rep.fired {
        fired.push(Json::obj().set("condition", c.id()).set("count", *n));
    }
    Json::obj()
        .set("condition", rep.condition.id())
        .set("injection", rep.injection_desc.as_str())
        .set("detected", rep.detected)
        .set(
            "detection_latency_ns",
            rep.detection_latency.map(|d| Json::Int(d.ns() as i64)).unwrap_or(Json::Null),
        )
        .set("throughput_impact", rep.throughput_impact())
        .set("p99_ttft_inflation", rep.p99_inflation())
        .set(
            "recovery",
            rep.recovery().map(Json::Num).unwrap_or(Json::Null),
        )
        .set("fired", fired)
}

/// Convenience: does this JSON document mention a condition id?
pub fn mentions(json: &Json, condition: Condition) -> bool {
    json.render().contains(condition.id())
}

/// §4.3 negative-control aggregate.
#[derive(Debug, Clone)]
pub struct NegativeControlReport {
    pub runs: u64,
    /// EW1 firings after injection — must be zero (NVLink blindness).
    pub ew1_detections: u64,
    /// Events rejected at the visibility boundary across control runs.
    pub invisible_dropped: u64,
}

/// Everything a matrix run produces (built by `coordinator::matrix`).
#[derive(Debug)]
pub struct MatrixReport {
    /// One scorecard per condition, ALL_CONDITIONS order.
    pub scorecards: Vec<Scorecard>,
    pub confusion: ConfusionMatrix,
    pub replicates: u64,
    pub base_seed: u64,
    pub window_ns: u64,
    pub healthy_runs: u64,
    pub healthy_windows: u64,
    pub healthy_false_alarms: u64,
    pub negative_control: Option<NegativeControlReport>,
    pub cells_run: usize,
    pub threads_used: usize,
    /// Wall-clock of the parallel cell sweep, ms. Perf metadata: reported
    /// in the human output and `dpulens perf`, excluded from `to_json` so
    /// the scorecard JSON stays byte-identical across thread counts.
    pub elapsed_ms: f64,
    /// Telemetry events delivered across all cells' pipelines.
    pub events_total: u64,
    /// Snapshot-and-branch prefix-reuse accounting for the sweep. Perf
    /// metadata like `elapsed_ms`: surfaced by the human output and
    /// `dpulens perf`, excluded from `to_json` so the scorecard JSON stays
    /// byte-identical whether or not reuse was enabled.
    pub reuse: crate::coordinator::snapshot::ReuseStats,
}

impl MatrixReport {
    /// Pipeline ingest throughput of the whole sweep (events/sec).
    pub fn events_per_sec(&self) -> f64 {
        crate::util::perf::events_per_sec(self.events_total, self.elapsed_ms)
    }

    /// Conditions identified in at least one replicate.
    pub fn detected_count(&self) -> usize {
        self.scorecards.iter().filter(|s| s.identified()).count()
    }

    /// Mean per-condition recall.
    pub fn macro_recall(&self) -> f64 {
        if self.scorecards.is_empty() {
            return 0.0;
        }
        self.scorecards.iter().map(|s| s.recall()).sum::<f64>() / self.scorecards.len() as f64
    }

    /// Paper-style scorecard + confusion tables.
    pub fn render_tables(&self) -> String {
        let mut t = Table::new("E5 — detection-quality scorecard (28 conditions × replicates)")
            .header(&[
                "id",
                "recall",
                "ttd p50",
                "ttd (win)",
                "fp rate",
                "diag prec",
                "attr acc",
                "SW id/not",
                "coverage",
                "directive",
            ]);
        for s in &self.scorecards {
            let (ttd, ttd_win) = if s.latency_ns.is_empty() {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    fmt_ns(s.latency_ns.p50()),
                    format!("{:.1}", s.latency_ns.p50() / self.window_ns.max(1) as f64),
                )
            };
            t.row(vec![
                s.condition.id().to_string(),
                format!("{}/{}", s.detected_runs, s.runs),
                ttd,
                ttd_win,
                format!("{:.3}", s.false_positive_rate()),
                format!("{:.2}", s.diagonal_precision),
                format!("{:.0}%", s.attribution_accuracy() * 100.0),
                format!("{}/{}", s.sw_identified_runs, s.sw_noticed_runs),
                s.coverage_delta().to_string(),
                format!("{:?}", runbook::entry(s.condition).directive),
            ]);
        }
        let mut out = t.render();
        out.push_str(&self.confusion.render());
        out
    }

    /// One-paragraph human summary (incl. the §4.3 control verdict).
    pub fn summary_line(&self) -> String {
        let sw_not = self.scorecards.iter().filter(|s| s.sw_noticed_runs > 0).count();
        let sw_id = self.scorecards.iter().filter(|s| s.sw_identified_runs > 0).count();
        let mut s = format!(
            "DPU identified {}/{} (macro recall {:.2}); SW noticed {}/{} but identified {}/{}; \
             healthy false alarms {} over {} windows ({} runs)",
            self.detected_count(),
            self.scorecards.len(),
            self.macro_recall(),
            sw_not,
            self.scorecards.len(),
            sw_id,
            self.scorecards.len(),
            self.healthy_false_alarms,
            self.healthy_windows,
            self.healthy_runs,
        );
        if let Some(nc) = &self.negative_control {
            s.push_str(&format!(
                "\n4.3 negative control (TP on NVLink, straggler injected): EW1 detections = {} \
                 across {} runs (expected 0 — NVLink collectives bypass the DPU; {} invisible \
                 events dropped)",
                nc.ew1_detections, nc.runs, nc.invisible_dropped
            ));
        }
        s
    }

    /// Deterministic JSON scorecard: same config + seed ⇒ byte-identical
    /// output, independent of worker-thread count. Wallclock, events/sec,
    /// and thread metadata are deliberately excluded — they live in
    /// `elapsed_ms`/`events_total` and surface via `dpulens perf`'s
    /// `BENCH_pipeline.json` instead.
    pub fn to_json(&self) -> Json {
        let mut conds = Json::arr();
        for s in &self.scorecards {
            let latency = if s.latency_ns.is_empty() {
                Json::Null
            } else {
                Json::obj()
                    .set("min_ns", s.latency_ns.min())
                    .set("p50_ns", s.latency_ns.p50())
                    .set("max_ns", s.latency_ns.max())
            };
            conds.push(
                Json::obj()
                    .set("id", s.condition.id())
                    .set("table", s.condition.table())
                    .set("runs", s.runs)
                    .set("detected_runs", s.detected_runs)
                    .set("recall", s.recall())
                    .set("latency", latency)
                    .set("self_firings", s.self_firings)
                    .set("other_firings", s.other_firings)
                    .set("diagonal_precision", s.diagonal_precision)
                    .set("false_positive_runs", s.false_positive_runs)
                    .set("other_condition_runs", s.other_condition_runs)
                    .set("false_positive_rate", s.false_positive_rate())
                    .set("healthy_false_alarms", s.healthy_false_alarms)
                    .set("attribution_accuracy", s.attribution_accuracy())
                    .set("sw_noticed_runs", s.sw_noticed_runs)
                    .set("sw_identified_runs", s.sw_identified_runs)
                    .set("coverage", s.coverage_delta())
                    .set("directive", format!("{:?}", runbook::entry(s.condition).directive)),
            );
        }
        let negative = match &self.negative_control {
            None => Json::Null,
            Some(nc) => Json::obj()
                .set("runs", nc.runs)
                .set("ew1_detections", nc.ew1_detections)
                .set("invisible_dropped", nc.invisible_dropped),
        };
        Json::obj()
            .set("schema", "dpulens.matrix.v1")
            .set("replicates", self.replicates)
            .set("base_seed", self.base_seed)
            .set("window_ns", self.window_ns)
            .set("detected", self.detected_count())
            .set("macro_recall", self.macro_recall())
            .set(
                "healthy",
                Json::obj()
                    .set("runs", self.healthy_runs)
                    .set("windows", self.healthy_windows)
                    .set("false_alarms", self.healthy_false_alarms),
            )
            .set("negative_control", negative)
            .set("conditions", conds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{Scenario, ScenarioCfg};
    use crate::sim::SimDur;

    fn tiny_run() -> RunResult {
        let mut cfg = ScenarioCfg::default();
        cfg.duration = SimDur::from_ms(300);
        cfg.warmup_windows = 5;
        cfg.calib_windows = 10;
        Scenario::new(cfg).run()
    }

    #[test]
    fn run_json_is_valid_and_complete() {
        let res = tiny_run();
        let j = run_json("unit", &res);
        let s = j.render();
        assert!(s.starts_with('{') && s.ends_with('}'));
        for key in [
            "\"label\"",
            "\"metrics\"",
            "\"telemetry_published\"",
            "\"detections\"",
            "\"dpu_invisible_dropped\"",
        ] {
            assert!(s.contains(key), "missing {key} in {s:.200}");
        }
    }

    #[test]
    fn runbook_json_covers_all_28() {
        let j = runbook_json();
        let s = j.render();
        for c in crate::dpu::detectors::ALL_CONDITIONS {
            assert!(s.contains(&format!("\"{}\"", c.id())), "{} missing", c.id());
        }
        assert!(mentions(&j, Condition::Ew8KvBottleneck));
    }

    #[test]
    fn metrics_json_has_finite_numbers() {
        let res = tiny_run();
        let s = metrics_json(&res).render();
        assert!(!s.contains("NaN") && !s.contains("inf"));
        assert!(s.contains("\"tok_per_s\""));
    }
}
