//! Machine-readable experiment reports: serialize run results, detections,
//! attributions, and runbook metadata to JSON for downstream tooling
//! (dashboards, CI trend lines, the paper's tables as data).

use crate::coordinator::scenario::RunResult;
use crate::dpu::detectors::Condition;
use crate::dpu::runbook;
use crate::util::json::Json;

/// Serialize the serving metrics of a run.
pub fn metrics_json(res: &RunResult) -> Json {
    Json::obj()
        .set("completed", res.metrics.completed)
        .set("rejected", res.metrics.rejected)
        .set("tokens_out", res.metrics.tokens_out)
        .set("req_per_s", res.metrics.req_per_s())
        .set("tok_per_s", res.metrics.tok_per_s())
        .set("ttft_p50_ns", res.metrics.ttft_ns.p50())
        .set("ttft_p95_ns", res.metrics.ttft_ns.p95())
        .set("ttft_p99_ns", res.metrics.ttft_ns.p99())
        .set("tpot_p50_ns", res.metrics.tpot_ns.p50())
        .set("tpot_p99_ns", res.metrics.tpot_ns.p99())
}

/// Serialize a full run: metrics + telemetry accounting + detections.
pub fn run_json(label: &str, res: &RunResult) -> Json {
    let mut detections = Json::arr();
    for d in &res.detections {
        detections.push(
            Json::obj()
                .set("condition", d.condition.id())
                .set("node", d.node.0)
                .set("at_ns", d.at.ns())
                .set("severity", d.severity)
                .set("evidence", d.evidence.as_str()),
        );
    }
    let mut actions = Json::arr();
    for a in &res.actions {
        actions.push(
            Json::obj()
                .set("at_ns", a.at.ns())
                .set("directive", format!("{:?}", a.directive))
                .set("detail", a.detail.as_str()),
        );
    }
    let mut attributions = Json::arr();
    for a in &res.attributions {
        attributions.push(
            Json::obj()
                .set("cause", format!("{:?}", a.cause))
                .set("confidence", a.confidence)
                .set("evidence", a.evidence.as_str()),
        );
    }
    Json::obj()
        .set("label", label)
        .set("real_compute", res.real_compute)
        .set("metrics", metrics_json(res))
        .set("telemetry_published", res.telemetry_published)
        .set("dpu_ingested", res.dpu_ingested)
        .set("dpu_invisible_dropped", res.dpu_invisible_dropped)
        .set("windows", res.windows)
        .set("iterations", res.iterations)
        .set(
            "injected_at_ns",
            res.injected_at.map(|t| Json::Int(t.ns() as i64)).unwrap_or(Json::Null),
        )
        .set(
            "injection",
            res.injection_desc
                .as_deref()
                .map(|d| Json::Str(d.to_string()))
                .unwrap_or(Json::Null),
        )
        .set("detections", detections)
        .set("actions", actions)
        .set("attributions", attributions)
}

/// The encoded paper runbooks (Tables 3a-c) as JSON — the tables as data.
pub fn runbook_json() -> Json {
    let mut rows = Json::arr();
    for e in runbook::all_entries() {
        rows.push(
            Json::obj()
                .set("id", e.condition.id())
                .set("table", e.condition.table())
                .set("signal", e.signal)
                .set("stages", e.stages)
                .set("effect", e.effect)
                .set("root_cause", e.root_cause)
                .set("directive", format!("{:?}", e.directive))
                .set("directive_paper_text", e.directive.paper_text()),
        );
    }
    Json::obj().set("paper", "Khan & Moye 2025").set("conditions", rows)
}

/// Condition-experiment row as JSON (the bench tables as data).
pub fn condition_json(rep: &crate::coordinator::experiment::ConditionReport) -> Json {
    let mut fired = Json::arr();
    for (c, n) in &rep.fired {
        fired.push(Json::obj().set("condition", c.id()).set("count", *n));
    }
    Json::obj()
        .set("condition", rep.condition.id())
        .set("injection", rep.injection_desc.as_str())
        .set("detected", rep.detected)
        .set(
            "detection_latency_ns",
            rep.detection_latency.map(|d| Json::Int(d.ns() as i64)).unwrap_or(Json::Null),
        )
        .set("throughput_impact", rep.throughput_impact())
        .set("p99_ttft_inflation", rep.p99_inflation())
        .set(
            "recovery",
            rep.recovery().map(Json::Num).unwrap_or(Json::Null),
        )
        .set("fired", fired)
}

/// Convenience: does this JSON document mention a condition id?
pub fn mentions(json: &Json, condition: Condition) -> bool {
    json.render().contains(condition.id())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{Scenario, ScenarioCfg};
    use crate::sim::SimDur;

    fn tiny_run() -> RunResult {
        let mut cfg = ScenarioCfg::default();
        cfg.duration = SimDur::from_ms(300);
        cfg.warmup_windows = 5;
        cfg.calib_windows = 10;
        Scenario::new(cfg).run()
    }

    #[test]
    fn run_json_is_valid_and_complete() {
        let res = tiny_run();
        let j = run_json("unit", &res);
        let s = j.render();
        assert!(s.starts_with('{') && s.ends_with('}'));
        for key in [
            "\"label\"",
            "\"metrics\"",
            "\"telemetry_published\"",
            "\"detections\"",
            "\"dpu_invisible_dropped\"",
        ] {
            assert!(s.contains(key), "missing {key} in {s:.200}");
        }
    }

    #[test]
    fn runbook_json_covers_all_28() {
        let j = runbook_json();
        let s = j.render();
        for c in crate::dpu::detectors::ALL_CONDITIONS {
            assert!(s.contains(&format!("\"{}\"", c.id())), "{} missing", c.id());
        }
        assert!(mentions(&j, Condition::Ew8KvBottleneck));
    }

    #[test]
    fn metrics_json_has_finite_numbers() {
        let res = tiny_run();
        let s = metrics_json(&res).render();
        assert!(!s.contains("NaN") && !s.contains("inf"));
        assert!(s.contains("\"tok_per_s\""));
    }
}
