//! World construction + calendar wiring for the serving plane: the event
//! alphabet the scenario loop dispatches on, the builders that assemble the
//! full simulated world (cluster, engine, DPU plane, SW baseline, fleet
//! sensor, workload generator, compute backends), and the shared helpers
//! every stage of the loop leans on (outbox draining, arrival scheduling,
//! replica kicks, result assembly).

use crate::cluster::{Cluster, Outbox};
use crate::dpu::agent::DpuPlane;
use crate::dpu::detectors::DetectConfig;
use crate::dpu::fleet::FleetSensor;
use crate::dpu::swdet::SwSuite;
use crate::engine::exec::{ComputeBackend, ExecScratch, IterKind, SurrogateBackend};
use crate::engine::{build_replicas, build_shaped_replicas, CollSeq, DecodeSpec, Engine};
use crate::ids::{NodeId, ReqId};
use crate::metrics::ServeMetrics;
use crate::sim::{Engine as Calendar, SimTime};
use crate::telemetry::event::TelemetryEvent;
use crate::telemetry::sw::SwWindow;
use crate::telemetry::TelemetryBus;
use crate::workload::generator::WorkloadGen;
use crate::workload::request::InferenceRequest;

use super::scenario::{RunResult, Scenario, ScenarioCfg};

/// The scenario event alphabet (calendar entries).
///
/// Telemetry deliberately has no calendar entry: events flow through the
/// batched [`TelemetryBus`] (outbox → per-node buffer → window-tick slice
/// delivery), not one-heap-op-per-event through the calendar.
#[derive(Debug, Clone)]
pub(crate) enum Ev {
    /// Generate the next request. Chained at the workload generator's
    /// *undelayed* clock, not at request delivery: per-request delivery
    /// jitter (thin sessions) must delay only that request, never the
    /// generation of everything behind it.
    GenNext,
    Arrival(Box<InferenceRequest>),
    Delivered(ReqId),
    Iterate(usize),
    IterDone(usize),
    EgressDone { req: ReqId, last: bool },
    /// Batched egress dispatch for one replica's coalesced token lane: one
    /// calendar event per iteration instead of one per token. The event is
    /// always scheduled at its lane-front entry's pre-minted `(time, seq)`
    /// key, so it pops exactly when the front's legacy per-token event
    /// would have (see `Scenario::on_egress_batch`).
    EgressBatch(usize),
    /// A prefill→decode KV handoff's last byte arrived at decode replica
    /// `to` (disaggregated fleets only).
    KvHandoffDone { req: ReqId, to: usize },
    WindowTick,
    End,
}

/// Cumulative KV-handoff accounting for one run (all zeros on colocated
/// fleets). `bytes_sent` counts at handoff launch, `bytes_delivered` at
/// fabric arrival — the conservation pair the property suite checks.
#[derive(Debug, Default, Clone)]
pub struct HandoffStats {
    pub started: u64,
    pub completed: u64,
    pub bytes_sent: u64,
    pub bytes_delivered: u64,
    /// Sum of fabric latencies over completed handoffs, ns.
    pub lat_sum_ns: u64,
    /// Cumulative handoff arrivals per replica (decode-pool skew signal).
    pub arrivals_per_replica: Vec<u64>,
    /// Arrivals that could not be adopted immediately (decode admission
    /// full) and were parked on the wait queue.
    pub stalled_waits: u64,
    /// Per (prefill pool, decode pool) launch accounting — the pool-pair
    /// traffic matrix of a multi-pool plane (one all-zero row on colocated
    /// and classic 2-pool fleets until handoffs flow).
    pub per_pair: Vec<PairFlow>,
}

/// One pool pair's handoff volume (counted at launch, like `started` /
/// `bytes_sent`; the pair's share of the global conservation identity).
#[derive(Debug, Default, Clone)]
pub struct PairFlow {
    pub prefill_pool: u32,
    pub decode_pool: u32,
    pub started: u64,
    pub bytes_sent: u64,
}

impl HandoffStats {
    /// Record one launched handoff on the (p, d) pool pair.
    pub(crate) fn record_pair(&mut self, p: usize, d: usize, bytes: u64) {
        let (p, d) = (p as u32, d as u32);
        match self
            .per_pair
            .iter_mut()
            .find(|e| e.prefill_pool == p && e.decode_pool == d)
        {
            Some(e) => {
                e.started += 1;
                e.bytes_sent += bytes;
            }
            // A role shift can mint a pool pair that didn't exist at
            // construction; append it (deterministic first-launch order).
            None => self.per_pair.push(PairFlow {
                prefill_pool: p,
                decode_pool: d,
                started: 1,
                bytes_sent: bytes,
            }),
        }
    }
}

/// An iteration in flight on one replica.
#[derive(Debug, Clone)]
pub(crate) struct PendingIter {
    pub(crate) kind: IterKind,
    #[allow(dead_code)]
    pub(crate) started: SimTime,
}

/// One generated token parked on a replica's coalesced egress lane,
/// awaiting batched dispatch.
#[derive(Debug, Clone, Copy)]
pub(crate) struct EgressEntry {
    pub(crate) req: ReqId,
    /// NIC egress completion time, computed per token exactly as the
    /// legacy per-token `Ev::EgressDone` would have carried (clamped to
    /// the emission instant like any calendar entry).
    pub(crate) done: SimTime,
    /// The calendar sequence number minted for this token at emission.
    /// `(done, seq)` is the key the legacy event would have popped at;
    /// batched dispatch replays entries in exactly that global order.
    pub(crate) seq: u64,
    pub(crate) last: bool,
}

/// Per-replica reusable buffers for the iteration hot path. After warmup
/// every vector's capacity plateaus, so a steady-state decode round touches
/// the heap zero times (asserted by `tests/iter_hot_path.rs` under
/// `--features perf-probe`).
#[derive(Debug, Clone, Default)]
pub(crate) struct IterScratch {
    /// `IterKind::Decode` vectors, recycled through `pending` each round.
    pub(crate) reqs: Vec<ReqId>,
    pub(crate) ctx_lens: Vec<u32>,
    /// Backend-call staging, read straight off the batcher's SoA lanes.
    pub(crate) slots: Vec<usize>,
    pub(crate) last_tokens: Vec<i32>,
    pub(crate) positions: Vec<u32>,
    pub(crate) next_tokens: Vec<i32>,
    pub(crate) specs: Vec<DecodeSpec>,
    /// Stage-walk arena for `run_iteration_in`.
    pub(crate) exec: ExecScratch,
}

/// Replica plans for a scenario config: heterogeneous shapes when the
/// engine declares pools, the uniform colocated layout otherwise.
fn build_plans(cfg: &ScenarioCfg) -> Vec<crate::engine::ParallelPlan> {
    match &cfg.engine.shapes {
        Some(shapes) => build_shaped_replicas(&cfg.cluster, shapes),
        None => build_replicas(&cfg.cluster, cfg.engine.nodes_per_stage),
    }
}

impl Scenario {
    /// Build with surrogate (sim-only) compute backends.
    pub fn new(cfg: ScenarioCfg) -> Self {
        cfg.cluster.validate().expect("bad cluster spec");
        let vocab = cfg.engine.profile.vocab;
        let plans = build_plans(&cfg);
        let backends: Vec<Box<dyn ComputeBackend>> = (0..plans.len())
            .map(|_| Box::new(SurrogateBackend::new(vocab)) as Box<dyn ComputeBackend>)
            .collect();
        Self::assemble(cfg, plans, backends)
    }

    /// Build with caller-provided compute backends (e.g. the real PJRT
    /// `TransformerSession`), one per replica.
    pub fn with_backends(cfg: ScenarioCfg, backends: Vec<Box<dyn ComputeBackend>>) -> Self {
        cfg.cluster.validate().expect("bad cluster spec");
        let plans = build_plans(&cfg);
        Self::assemble(cfg, plans, backends)
    }

    /// Shared assembly: replica plans are built exactly once per scenario
    /// (the matrix/fleet sweeps construct scenarios in bulk).
    fn assemble(
        cfg: ScenarioCfg,
        plans: Vec<crate::engine::ParallelPlan>,
        backends: Vec<Box<dyn ComputeBackend>>,
    ) -> Self {
        assert_eq!(plans.len(), backends.len(), "one backend per replica");
        let engine = Engine::new(cfg.engine.clone(), plans);
        let cluster = Cluster::new(cfg.cluster.clone(), cfg.seed);
        let mut dpu = DpuPlane::new(
            cfg.cluster.n_nodes,
            cfg.cluster.gpus_per_node,
            DetectConfig { nic_bw: cfg.cluster.nic_bw, z_fire: 4.0 },
        );
        dpu.warmup_windows = cfg.warmup_windows;
        dpu.observe_threads = cfg.observe_threads;
        let gen = WorkloadGen::new(cfg.workload.clone(), cfg.engine.profile.vocab, cfg.seed);
        let n_rep = engine.n_replicas();
        let entry_nodes: Vec<NodeId> =
            engine.replicas.iter().map(|r| r.plan.entry_nodes()[0]).collect();
        let max_batch = cfg.engine.policy.max_batch;
        let real = backends.iter().any(|b| b.is_real());
        let mut fleet =
            FleetSensor::with_pools(n_rep, entry_nodes, engine.pools().clone(), cfg.cluster.nic_bw);
        fleet.threads = cfg.observe_threads;
        // Replica → calendar shard: shard 0 is the global lane (workload
        // generation, arrivals, window ticks), then one shard per prefill
        // pool, then one per decode pool. Pop order is globally determined
        // by `(time, seq)` regardless of shard, so a map gone stale after a
        // mid-run role shift stays correct — it only changes which bucket
        // ring an event waits in.
        let (n_shards, cal_shard) = {
            let pools = engine.pools();
            let k = pools.prefill_pools.len();
            let m = pools.decode_pools.len();
            let mut map = vec![1usize; n_rep];
            for (p, pool) in pools.prefill_pools.iter().enumerate() {
                for &r in pool {
                    map[r] = 1 + p;
                }
            }
            for (d, pool) in pools.decode_pools.iter().enumerate() {
                for &r in pool {
                    map[r] = 1 + k + d;
                }
            }
            (1 + k + m, map)
        };
        Scenario {
            cluster,
            dpu,
            sw_suite: SwSuite::new(),
            sw_window: SwWindow::new(),
            controller: crate::mitigation::Controller::new(cfg.mitigate),
            fleet,
            bus: TelemetryBus::new(cfg.cluster.n_nodes),
            cal: Calendar::with_shards(cfg.calendar, n_shards),
            cal_shard,
            gen,
            backends,
            pending: (0..n_rep).map(|_| None).collect(),
            iter_scratch: (0..n_rep).map(|_| Default::default()).collect(),
            egress_lanes: (0..n_rep).map(|_| Default::default()).collect(),
            slot_of: Default::default(),
            free_slots: (0..n_rep).map(|_| (0..max_batch).rev().collect()).collect(),
            outbox: Outbox::new(),
            windows_seen: 0,
            injected_at: None,
            injection_desc: None,
            generated: 0,
            arrived: 0,
            iterations: 0,
            attributions: Vec::new(),
            kv_peak: vec![0.0; n_rep],
            handoff_wait: (0..n_rep).map(|_| Default::default()).collect(),
            tele_faults: crate::telemetry::TelemetryFaults::new(cfg.seed, cfg.cluster.n_nodes),
            watchdog: crate::dpu::watchdog::FreshnessWatchdog::new(),
            ladder_log: Vec::new(),
            handoff_colls: CollSeq::default(),
            handoff_stats: HandoffStats {
                arrivals_per_replica: vec![0; n_rep],
                // Pre-populate the pool-pair matrix so the healthy report
                // shows every pair (including zero-traffic ones) in a
                // deterministic order.
                per_pair: {
                    let pools = engine.pools();
                    (0..pools.prefill_pools.len())
                        .flat_map(|p| {
                            (0..pools.decode_pools.len()).map(move |d| PairFlow {
                                prefill_pool: p as u32,
                                decode_pool: d as u32,
                                ..Default::default()
                            })
                        })
                        .collect()
                },
                ..Default::default()
            },
            engine,
            real_compute: real,
            started: false,
            finished: false,
            cfg,
        }
    }

    /// Drain hardware-model emissions into the telemetry bus's per-node
    /// buffers (zero-copy: each event is moved, not boxed into the calendar
    /// or cloned). Time-ordered batch delivery happens at window ticks via
    /// [`Scenario::deliver_telemetry`].
    pub(crate) fn flush_outbox(&mut self) {
        for (t, node, kind) in self.outbox.items.drain(..) {
            self.bus.enqueue(TelemetryEvent { t, node, kind });
        }
    }

    /// Generate one request: chain the *next* generation at the generator's
    /// undelayed clock, and schedule this request's delivery at its (possibly
    /// jittered) arrival time. Keeping the two decoupled is what lets a thin
    /// session dribble in late without stalling the rest of the stream.
    pub(crate) fn schedule_next_arrival(&mut self) {
        if self.cfg.max_requests > 0 && self.generated >= self.cfg.max_requests {
            return;
        }
        let req = self.gen.next_request();
        self.generated += 1;
        self.cal.schedule_at(self.gen.clock(), Ev::GenNext);
        self.cal.schedule_at(req.arrival, Ev::Arrival(Box::new(req)));
    }

    pub(crate) fn entry_node(&self, replica: usize) -> NodeId {
        self.engine.replicas[replica].plan.entry_nodes()[0]
    }

    pub(crate) fn exit_node(&self, replica: usize) -> NodeId {
        self.engine.replicas[replica].plan.exit_nodes()[0]
    }

    /// Schedule a replica-scoped event on that replica's calendar shard
    /// (shard choice never affects pop order; it only spreads the bucket
    /// rings so no single shard serializes a 1000-replica fleet's churn).
    pub(crate) fn schedule_replica_at(&mut self, replica: usize, at: SimTime, ev: Ev) {
        self.cal.schedule_at_shard(self.cal_shard[replica], at, ev);
    }

    /// Schedule a replica-scoped event at a pre-minted `(time, seq)` key —
    /// how the coalesced egress path re-arms its batch event at exactly the
    /// calendar position a legacy per-token event held.
    pub(crate) fn schedule_replica_at_seq(&mut self, replica: usize, at: SimTime, seq: u64, ev: Ev) {
        self.cal.schedule_at_shard_seq(self.cal_shard[replica], at, seq, ev);
    }

    /// Schedule an iteration on an idle replica; the placeholder pending
    /// entry marks it busy so we don't double-schedule (replaced in
    /// `Ev::Iterate`).
    pub(crate) fn kick(&mut self, replica: usize, now: SimTime) {
        if self.pending[replica].is_none() {
            self.schedule_replica_at(replica, now, Ev::Iterate(replica));
            self.pending[replica] = Some(PendingIter {
                kind: IterKind::Decode { reqs: vec![], ctx_lens: vec![] },
                started: now,
            });
        }
    }

    /// Assemble the result bundle after the loop ends.
    pub(crate) fn finish(mut self) -> RunResult {
        // Scenario teardown: fully reset the calendar (clock, seq, processed
        // count) so nothing can leak between back-to-back cells if a caller
        // ever recycles the world — `clear()` alone deliberately keeps the
        // clock and sequence running for mid-run teardown.
        self.cal.reset();
        let span = self.cfg.duration;
        let n_rep = self.engine.n_replicas();
        let metrics = ServeMetrics::collect_fleet(
            self.engine.requests.values(),
            &self.engine.placement,
            n_rep,
            span,
        );
        let tenants = crate::metrics::collect_tenants(
            self.engine.requests.values(),
            &self.cfg.workload.tenants,
        );
        let sw_alarm_log = std::mem::take(&mut self.sw_suite.detections);
        let handoff_parked: u64 = self.handoff_wait.iter().map(|q| q.len() as u64).sum();
        RunResult {
            metrics,
            tenants,
            requests_generated: self.generated,
            requests_arrived: self.arrived,
            requests_tracked: self.engine.requests.len(),
            handoffs: std::mem::take(&mut self.handoff_stats),
            handoffs_parked_at_end: handoff_parked,
            detections: std::mem::take(&mut self.dpu.detections),
            attributions: self.attributions,
            sw_detections: sw_alarm_log.len(),
            sw_alarm_log,
            actions: self.controller.log.clone(),
            injected_at: self.injected_at,
            injection_desc: self.injection_desc,
            telemetry_published: self.bus.total_published(),
            dpu_ingested: self.dpu.total_ingested(),
            dpu_invisible_dropped: self.dpu.total_invisible_dropped(),
            windows: self.windows_seen,
            iterations: self.iterations,
            replica_iterations: self.engine.replicas.iter().map(|r| r.iterations).collect(),
            replica_routed: self.engine.router.routed_per_replica().to_vec(),
            replica_kv_peak: self.kv_peak,
            real_compute: self.real_compute,
            class_counts: self.bus.class_counts_map(),
            fault_dropped: self.tele_faults.total_dropped(),
            fault_held_at_end: self.tele_faults.total_held(),
            ladder_transitions: self.ladder_log,
        }
    }
}
