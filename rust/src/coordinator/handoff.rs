//! The prefill→decode phase transition on a disaggregated fleet: launch the
//! KV handoff over the fabric, land it on the decode pool, and adopt the
//! sequence into its new replica's decode loop.
//!
//! The handoff is ordinary east-west traffic (RDMA + a KvTransfer burst at
//! the destination), which is exactly why the paper's DPU vantage can see a
//! disaggregated fleet's phase boundary at all: what a colocated engine
//! keeps in HBM becomes wire bytes here. Accounting is strictly conserved —
//! every started handoff either lands (bytes_delivered grows by its exact
//! size) or is still on the fabric when the run ends.

use crate::engine::AllocResult;
use crate::ids::ReqId;
use crate::sim::SimTime;
use crate::workload::request::ReqState;

use super::scenario::Scenario;
use super::world::Ev;

impl Scenario {
    /// Prefill completed for `id` on `from_replica` and the request still
    /// has tokens to generate: close the admission router's accounting,
    /// pick a decode replica, and stream the KV across the fabric.
    pub(crate) fn start_handoff(&mut self, from_replica: usize, id: ReqId, now: SimTime) {
        // Prefill capacity is free the moment the batch completes.
        self.engine.router.complete(from_replica);
        let to = self.engine.route_decode(id);
        let bytes = {
            let r = self.engine.request(id);
            self.cfg
                .engine
                .profile
                .kv_bytes(r.prompt_len() + r.tokens_generated())
                .max(512)
        };
        {
            let r = self.engine.request_mut(id);
            r.state = ReqState::KvHandoff;
            r.handoff_start = Some(now);
            r.kv_handoff_bytes = bytes;
        }
        self.handoff_stats.started += 1;
        self.handoff_stats.bytes_sent += bytes;
        // Pool-pair accounting: which admission pool fed which handoff pool.
        if let (Some(p), Some(d)) = (
            self.engine.pools().prefill_pool_of(from_replica),
            self.engine.pools().decode_pool_of(to),
        ) {
            self.handoff_stats.record_pair(p, d, bytes);
        }
        let src = self.exit_node(from_replica);
        let dst = self.entry_node(to);
        let coll = self.handoff_colls.next();
        let arrive = self.cluster.kv_handoff(now, src, dst, bytes, coll, &mut self.outbox);
        self.flush_outbox();
        self.schedule_replica_at(to, arrive, Ev::KvHandoffDone { req: id, to });
    }

    /// The handoff's last byte arrived at decode replica `to`: adopt the
    /// sequence now, or park it until the replica can admit.
    pub(crate) fn on_kv_handoff_done(&mut self, id: ReqId, to: usize, now: SimTime) {
        self.handoff_stats.completed += 1;
        self.handoff_stats.arrivals_per_replica[to] += 1;
        let bytes = {
            let r = self.engine.request_mut(id);
            r.handoff_done = Some(now);
            r.kv_handoff_bytes
        };
        self.handoff_stats.bytes_delivered += bytes;
        if let Some(lat) = self.engine.request(id).handoff_latency() {
            self.handoff_stats.lat_sum_ns += lat.ns();
        }
        if !self.try_adopt(to, id, now) {
            self.handoff_stats.stalled_waits += 1;
            self.handoff_wait[to].push_back(id);
        }
    }

    /// Attempt to seat a landed handoff in `replica`'s decode loop: a free
    /// decode slot, a free backend slot, and KV pages for the full context.
    /// Returns false (state untouched) when the replica cannot admit yet.
    fn try_adopt(&mut self, replica: usize, id: ReqId, now: SimTime) -> bool {
        let (tokens, generated, budget) = {
            let r = self.engine.request(id);
            (
                (r.prompt_len() + r.tokens_generated()) as u32,
                r.tokens_generated() as u32,
                r.max_new_tokens as u32,
            )
        };
        if self.engine.replicas[replica].batcher.free_slots() == 0
            || self.free_slots[replica].is_empty()
        {
            return false;
        }
        if self.engine.replicas[replica].kv.admit(id, tokens) != AllocResult::Ok {
            return false;
        }
        let slot = self.free_slots[replica].pop().unwrap();
        self.slot_of.insert(id, slot);
        // Position sits one past the whole context, exactly where a
        // colocated replica would be after its own prefill + first token;
        // the prefill-side first token seeds the lane's decode input.
        let last_token = self.engine.request(id).generated.last().copied().unwrap_or(1);
        self.engine.replicas[replica].batcher.adopt(id, tokens, generated, budget, slot, last_token);
        self.engine.request_mut(id).state = ReqState::Decoding;
        self.kick(replica, now);
        true
    }

    /// Seat as many parked handoffs as `replica` can now admit (called when
    /// retirement frees capacity and at every window tick).
    pub(crate) fn drain_handoff_wait(&mut self, replica: usize, now: SimTime) {
        while let Some(&id) = self.handoff_wait[replica].front() {
            if self.try_adopt(replica, id, now) {
                self.handoff_wait[replica].pop_front();
            } else {
                break;
            }
        }
    }
}
