//! Orchestration: the scenario world (event loop), experiment runner, and
//! the CLI surface.

pub mod experiment;
pub mod matrix;
pub mod report;
pub mod scenario;

pub use experiment::{condition_experiment, ConditionReport};
pub use matrix::{run_matrix, run_sweep, MatrixConfig, MatrixReport};
pub use scenario::{target_node_for, RunResult, Scenario, ScenarioCfg};
