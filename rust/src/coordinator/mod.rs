//! Orchestration: the scenario world (event loop), experiment runner, and
//! the CLI surface.

pub mod experiment;
pub mod report;
pub mod scenario;

pub use experiment::{condition_experiment, ConditionReport};
pub use scenario::{target_node_for, RunResult, Scenario, ScenarioCfg};
