//! Orchestration: the decomposed serving plane and the runners over it.
//!
//! The serving plane is composed of four sub-modules with `scenario` as a
//! thin orchestrator over them:
//!
//! * `world` — world state construction + calendar wiring (event alphabet,
//!   builders, shared helpers, result assembly)
//! * `ingress` — arrival, routing/admission, egress completion, and
//!   replica-aware pathology injection targeting
//! * `iterate` — per-replica iteration driving (batch formation, KV
//!   admission, prefill/decode execution, retirement)
//! * `observe` — DPU/SW window observation, the fleet skew sensor, and the
//!   closed mitigation loop
//!
//! On top sit the runners: `experiment` (three-phase condition experiments),
//! `matrix` (the parallel 28-condition scorecard), `fleet` (the replicas ×
//! routing-policy sweep with the DP condition family), `campaign` (the
//! manifest-driven workload × topology × condition expander behind
//! `dpulens campaign`), `perf` (the pipeline benchmark behind `dpulens perf`
//! / `BENCH_pipeline.json`), and `report` (machine-readable outputs).
//! `snapshot` threads the runners through shared-prefix checkpoint/fork
//! execution: cells whose worlds are identical until injection simulate
//! their pre-injection prefix once and fork per-cell branches from it.

pub mod campaign;
pub mod experiment;
pub mod fleet;
pub mod handoff;
pub mod ingress;
pub mod iterate;
pub mod matrix;
pub mod observe;
pub mod perf;
pub mod report;
pub mod scenario;
pub mod snapshot;
pub mod world;

pub use campaign::{run_campaign, CampaignConfig, CampaignReport};
pub use experiment::{condition_experiment, ConditionReport};
pub use fleet::{
    run_disagg_study, run_fleet, run_multipool_study, DisaggReport, FleetConfig, FleetReport,
    MultiPoolReport, MultiPoolSpec,
};
pub use ingress::target_node_for;
pub use matrix::{run_matrix, run_sweep, MatrixConfig, MatrixReport};
pub use perf::{run_perf, FleetStressConfig, PerfConfig, PerfReport};
pub use scenario::{RunResult, Scenario, ScenarioCfg};
pub use snapshot::{ReuseStats, WorldSnapshot};
pub use world::{HandoffStats, PairFlow};
