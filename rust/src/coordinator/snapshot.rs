//! Snapshot-and-branch execution: checkpoint a simulated world at its
//! injection instant once, then fork healthy / injected / mitigated branches
//! from the checkpoint instead of re-simulating the identical pre-injection
//! prefix per cell.
//!
//! Soundness rests on three facts the suite (`snapshot_fork_suite.rs`)
//! pins down as byte-identical forked-vs-scratch JSON:
//!
//! 1. **The prefix is injection-invariant.** Before its injection instant a
//!    cell's world evolves exactly like the neutral world: `cfg.inject` is
//!    only compared against `now` (no state changes until it trips),
//!    `cfg.victim_replica` is only read when an injection applies, and the
//!    mitigation controller is a total no-op while no detection has fired
//!    (`Controller::react` short-circuits on disabled, and it is only
//!    invoked with a non-empty detection batch). So the checkpoint captured
//!    from the neutralized config *is* every branch's state at the fork
//!    point — except a mitigated branch forked after a pre-injection false
//!    alarm, which [`run_all`] detects via [`WorldSnapshot::neutral`] and
//!    re-simulates from scratch.
//! 2. **The fork boundary is exact.** [`Scenario::run_to`] drains events
//!    with `t < stop` only (peek-before-pop); ties at `stop` stay pending
//!    and replay in the branch in the identical global `(t, seq)` order.
//! 3. **The copy is deep.** [`WorldSnapshot::fork`] deep-clones every state
//!    plane — sharded calendar (bucket lanes, overflow heaps, seq counter),
//!    engine (batcher, KV, routers incl. the degraded ladder), telemetry
//!    (bus buffers, fault layer incl. its PCG stream, window accumulators),
//!    DPU plane (baselines, fleet-sensor streaks, watchdog trust), and the
//!    workload generator's RNG streams — so branches share nothing.

use std::collections::HashMap;

use crate::sim::SimTime;

use super::experiment::inject_time;
use super::scenario::{RunResult, Scenario, ScenarioCfg};

/// Prefix-reuse accounting for one `run_all` sweep. All counters are plain
/// sums, so per-group contributions absorb in any order and the totals are
/// deterministic for every `--threads` value.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReuseStats {
    /// Cells executed (every cell yields exactly one `RunResult`).
    pub cells_total: u64,
    /// Shared prefixes actually simulated (one per fingerprint group, plus
    /// one per from-scratch fallback cell).
    pub prefixes_simulated: u64,
    /// Cells served by forking a checkpoint instead of re-simulating.
    pub forked_branches: u64,
    /// Simulated prefix nanoseconds a from-scratch sweep would burn
    /// (`fork point × cells`).
    pub prefix_ns_total: u64,
    /// Simulated prefix nanoseconds actually burned.
    pub prefix_ns_simulated: u64,
}

impl ReuseStats {
    /// Fold another sweep's (or group's) counters into this one.
    pub fn absorb(&mut self, o: ReuseStats) {
        self.cells_total += o.cells_total;
        self.prefixes_simulated += o.prefixes_simulated;
        self.forked_branches += o.forked_branches;
        self.prefix_ns_total += o.prefix_ns_total;
        self.prefix_ns_simulated += o.prefix_ns_simulated;
    }

    /// Simulated prefix time eliminated by reuse.
    pub fn sim_ns_saved(&self) -> u64 {
        self.prefix_ns_total.saturating_sub(self.prefix_ns_simulated)
    }

    /// From-scratch prefix time over actually-simulated prefix time
    /// (1.0 when nothing was simulated or nothing was saved).
    pub fn reuse_ratio(&self) -> f64 {
        if self.prefix_ns_simulated == 0 {
            1.0
        } else {
            self.prefix_ns_total as f64 / self.prefix_ns_simulated as f64
        }
    }
}

/// The canonical prefix identity of a cell: its config with the
/// injection-variant fields (condition, mitigation, victim) neutralized.
/// Two cells with equal fingerprints evolve identically until the fork
/// point, so they can share one simulated prefix.
fn neutralized(cfg: &ScenarioCfg) -> ScenarioCfg {
    let mut n = cfg.clone();
    n.inject = None;
    n.mitigate = false;
    n.victim_replica = 0;
    n
}

/// Render the neutralized config into a grouping key. `ScenarioCfg` is a
/// plain data tree (no maps, no pointers), so its `Debug` rendering is a
/// canonical, collision-honest fingerprint of everything that shapes the
/// prefix: cluster, engine, workload, seed, durations, calendar backend,
/// observe threads.
pub fn fingerprint(cfg: &ScenarioCfg) -> String {
    format!("{:?}", neutralized(cfg))
}

/// The shared fork point of a cell group: the earliest injection instant
/// (the standard post-calibration instant for never-injecting groups),
/// clamped to the run's end. Every event strictly before it is
/// injection-invariant across the group.
fn fork_point<'a, I>(cfgs: I) -> SimTime
where
    I: IntoIterator<Item = &'a ScenarioCfg>,
{
    let mut iter = cfgs.into_iter();
    let first = iter.next().expect("fork_point of an empty group");
    let mut at = first.inject.map(|(_, t)| t).unwrap_or_else(|| inject_time(first));
    for c in iter {
        if let Some((_, t)) = c.inject {
            at = at.min(t);
        }
    }
    let end = SimTime::ZERO + first.duration;
    at.min(end)
}

/// A paused deep copy of a simulated world at its fork boundary.
pub struct WorldSnapshot {
    world: Scenario,
    /// The fork boundary: every event with `t < at` has run; ties at `at`
    /// are still pending and belong to the branches.
    pub at: SimTime,
    /// True when no detection had fired by the fork point. Mitigated
    /// branches may only fork from a neutral checkpoint (a pre-fork false
    /// alarm would have armed a from-scratch run's controller earlier).
    pub neutral: bool,
}

impl WorldSnapshot {
    /// Simulate `cfg`'s world up to `stop` and freeze it. `cfg` should be
    /// the group's neutralized config; the world must use forkable
    /// (surrogate) compute backends — real PJRT backends hold device state
    /// and panic in `clone_box`.
    pub fn capture(cfg: ScenarioCfg, stop: SimTime) -> Self {
        let mut world = Scenario::new(cfg);
        world.run_to(stop);
        let neutral = world.dpu.detections.is_empty();
        WorldSnapshot { world, at: stop, neutral }
    }

    /// Deep-copy the checkpoint and retarget the copy at `cfg` — the
    /// branch's own injection/mitigation identity. The clone shares no
    /// state with the checkpoint or with sibling branches.
    pub fn fork(&self, cfg: ScenarioCfg) -> Scenario {
        let mut w = clone_world(&self.world);
        // `mitigate` is baked into the controller at construction; re-arm
        // it for the branch. Sound from a neutral checkpoint: a disabled
        // controller is a total no-op, so the from-scratch branch's
        // controller held identical (empty) state at this instant.
        w.controller.enabled = cfg.mitigate;
        w.cfg = cfg;
        w
    }

    /// Fork a branch and run it to completion.
    pub fn resume_from(&self, cfg: ScenarioCfg) -> RunResult {
        self.fork(cfg).run()
    }
}

/// Field-wise deep copy of a paused world. Lives here (not as a `Clone`
/// impl) so a scenario can't be cloned casually: the backends copy goes
/// through [`crate::engine::exec::ComputeBackend::clone_box`], which only
/// surrogate backends support.
fn clone_world(s: &Scenario) -> Scenario {
    Scenario {
        cfg: s.cfg.clone(),
        cluster: s.cluster.clone(),
        engine: s.engine.clone(),
        dpu: s.dpu.clone(),
        sw_suite: s.sw_suite.clone(),
        sw_window: s.sw_window.clone(),
        controller: s.controller.clone(),
        fleet: s.fleet.clone(),
        bus: s.bus.clone(),
        cal: s.cal.clone(),
        cal_shard: s.cal_shard.clone(),
        gen: s.gen.clone(),
        backends: s.backends.iter().map(|b| b.clone_box()).collect(),
        pending: s.pending.clone(),
        iter_scratch: s.iter_scratch.clone(),
        egress_lanes: s.egress_lanes.clone(),
        slot_of: s.slot_of.clone(),
        free_slots: s.free_slots.clone(),
        outbox: s.outbox.clone(),
        windows_seen: s.windows_seen,
        injected_at: s.injected_at,
        injection_desc: s.injection_desc.clone(),
        generated: s.generated,
        arrived: s.arrived,
        iterations: s.iterations,
        attributions: s.attributions.clone(),
        kv_peak: s.kv_peak.clone(),
        handoff_wait: s.handoff_wait.clone(),
        handoff_colls: s.handoff_colls.clone(),
        handoff_stats: s.handoff_stats.clone(),
        tele_faults: s.tele_faults.clone(),
        watchdog: s.watchdog.clone(),
        ladder_log: s.ladder_log.clone(),
        real_compute: s.real_compute,
        started: s.started,
        finished: s.finished,
    }
}

/// Run one fingerprint group: simulate the shared prefix once, then fork a
/// branch per member. Singleton groups (and `--no-reuse` sweeps, which make
/// every cell a singleton) skip the checkpoint — it would have no second
/// consumer.
fn run_group(members: Vec<(usize, ScenarioCfg)>) -> (Vec<(usize, RunResult)>, ReuseStats) {
    let stop = fork_point(members.iter().map(|(_, c)| c));
    let mut stats = ReuseStats {
        cells_total: members.len() as u64,
        prefix_ns_total: stop.ns() * members.len() as u64,
        ..Default::default()
    };
    if members.len() == 1 {
        stats.prefixes_simulated = 1;
        stats.prefix_ns_simulated = stop.ns();
        let (i, cfg) = members.into_iter().next().expect("singleton group");
        return (vec![(i, Scenario::new(cfg).run())], stats);
    }
    let snap = WorldSnapshot::capture(neutralized(&members[0].1), stop);
    stats.prefixes_simulated = 1;
    stats.prefix_ns_simulated = stop.ns();
    let mut out = Vec::with_capacity(members.len());
    for (i, cfg) in members {
        if cfg.mitigate && !snap.neutral {
            // Pre-fork false alarm: a from-scratch mitigated run would have
            // reacted before the fork point. Fall back to scratch.
            stats.prefixes_simulated += 1;
            stats.prefix_ns_simulated += stop.ns();
            out.push((i, Scenario::new(cfg).run()));
        } else {
            stats.forked_branches += 1;
            out.push((i, snap.resume_from(cfg)));
        }
    }
    (out, stats)
}

/// Execute every cell, reusing shared prefixes: cells group by
/// [`fingerprint`], each group's prefix simulates once, and members fork
/// from the checkpoint. Results come back in input order and are
/// byte-identical to per-cell `Scenario::new(cfg).run()` for any thread
/// count (groups parallelize; a snapshot never crosses a thread boundary).
/// `no_reuse` forces every cell into its own from-scratch group — the
/// `--no-reuse` equivalence-debugging escape hatch.
pub fn run_all(
    cfgs: Vec<ScenarioCfg>,
    threads: usize,
    no_reuse: bool,
) -> (Vec<RunResult>, ReuseStats) {
    let n = cfgs.len();
    let mut groups: Vec<Vec<(usize, ScenarioCfg)>> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    for (i, cfg) in cfgs.into_iter().enumerate() {
        if no_reuse {
            groups.push(vec![(i, cfg)]);
            continue;
        }
        let fp = fingerprint(&cfg);
        match index.get(&fp) {
            Some(&g) => groups[g].push((i, cfg)),
            None => {
                index.insert(fp, groups.len());
                groups.push(vec![(i, cfg)]);
            }
        }
    }
    let outcomes = crate::util::par::parallel_map_owned(groups, threads, run_group);
    let mut stats = ReuseStats::default();
    let mut slots: Vec<Option<RunResult>> = (0..n).map(|_| None).collect();
    for (group_results, group_stats) in outcomes {
        stats.absorb(group_stats);
        for (i, res) in group_results {
            slots[i] = Some(res);
        }
    }
    let results = slots
        .into_iter()
        .map(|r| r.expect("every cell produces exactly one result"))
        .collect();
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpu::detectors::Condition;
    use crate::sim::{SimDur, MS};

    fn quick_cfg() -> ScenarioCfg {
        let mut cfg = ScenarioCfg::default();
        cfg.duration = SimDur::from_ms(900);
        cfg.window = SimDur::from_ms(10);
        cfg.warmup_windows = 10;
        cfg.calib_windows = 40;
        cfg.workload.arrival = crate::sim::dist::Arrival::Poisson { rate: 300.0 };
        cfg.workload.prompt_len = crate::sim::dist::LengthDist::Uniform { lo: 8, hi: 32 };
        cfg.workload.output_len = crate::sim::dist::LengthDist::Uniform { lo: 2, hi: 8 };
        cfg
    }

    fn injected_cfg() -> ScenarioCfg {
        let mut cfg = quick_cfg();
        cfg.inject = Some((Condition::Ew6Retransmissions, SimTime(600 * MS)));
        cfg
    }

    #[test]
    fn fingerprint_ignores_injection_identity_only() {
        let base = quick_cfg();
        assert_eq!(fingerprint(&base), fingerprint(&injected_cfg()));
        let mut mitigated = injected_cfg();
        mitigated.mitigate = true;
        assert_eq!(fingerprint(&base), fingerprint(&mitigated));
        let mut other_seed = quick_cfg();
        other_seed.seed += 1;
        assert_ne!(fingerprint(&base), fingerprint(&other_seed));
        let mut other_cal = quick_cfg();
        other_cal.calendar = crate::sim::CalendarKind::Heap;
        assert_ne!(fingerprint(&base), fingerprint(&other_cal));
    }

    #[test]
    fn forked_branch_matches_scratch_run() {
        let cfg = injected_cfg();
        let scratch = Scenario::new(cfg.clone()).run();
        let snap = WorldSnapshot::capture(neutralized(&cfg), fork_point(&[cfg.clone()]));
        let forked = snap.resume_from(cfg);
        assert_eq!(format!("{scratch:?}"), format!("{forked:?}"));
    }

    #[test]
    fn sibling_branches_do_not_leak_into_each_other() {
        let healthy = quick_cfg();
        let injected = injected_cfg();
        let snap = WorldSnapshot::capture(neutralized(&healthy), fork_point(&[injected.clone()]));
        // Run the injected branch first; the healthy branch forked after it
        // must still match a from-scratch healthy run exactly.
        let _ = snap.resume_from(injected);
        let forked_healthy = snap.resume_from(healthy.clone());
        let scratch_healthy = Scenario::new(healthy).run();
        assert_eq!(format!("{scratch_healthy:?}"), format!("{forked_healthy:?}"));
    }

    #[test]
    fn run_all_groups_and_reports_reuse() {
        let cells = vec![quick_cfg(), injected_cfg(), quick_cfg(), injected_cfg()];
        let (results, stats) = run_all(cells.clone(), 2, false);
        assert_eq!(results.len(), 4);
        assert_eq!(stats.cells_total, 4);
        assert_eq!(stats.prefixes_simulated, 1);
        assert_eq!(stats.forked_branches, 4);
        assert!(stats.reuse_ratio() >= 2.0, "ratio {}", stats.reuse_ratio());
        let (scratch, no_stats) = run_all(cells, 1, true);
        assert_eq!(no_stats.forked_branches, 0);
        assert_eq!(no_stats.sim_ns_saved(), 0);
        for (a, b) in results.iter().zip(scratch.iter()) {
            assert_eq!(format!("{a:?}"), format!("{b:?}"));
        }
    }
}
